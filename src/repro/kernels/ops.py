"""Jitted public wrappers around the Pallas kernels.

Handles arbitrary leading dims, row/vocab padding to tile multiples, and
the CPU-vs-TPU interpret switch. `exit_gate` is what repro.core.exits calls
with use_kernel=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.exit_gate import NEG, exit_gate_kernel


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def exit_gate(logits, temperature=1.0, block_rows: int = 8, block_cols: int = 512):
    """(confidence, prediction, entropy) of softmax(logits/T).

    logits: (..., vocab). Matches repro.core.exits.gate_statistics' return
    order (confidence, prediction, entropy).
    """
    shape = logits.shape
    vocab = shape[-1]
    rows = 1
    for d in shape[:-1]:
        rows *= d
    z = logits.reshape(rows, vocab)

    pr = (-rows) % block_rows
    pc = (-vocab) % block_cols
    if pr or pc:
        z = jnp.pad(z, ((0, pr), (0, pc)), constant_values=NEG)

    conf, ent, idx = exit_gate_kernel(
        z,
        temperature,
        block_rows=block_rows,
        block_cols=block_cols,
        interpret=not _is_tpu(),
    )
    conf = conf[:rows].reshape(shape[:-1])
    ent = ent[:rows].reshape(shape[:-1])
    idx = idx[:rows].reshape(shape[:-1])
    return conf, idx, ent


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def calib_stats(logits, labels, temperature, block_rows: int = 8, block_cols: int = 512):
    """One-pass Newton statistics for Temperature Scaling over (N, vocab)
    validation logits: returns (nll_mean, dNLL/dT, d2NLL/dT2).

        dNLL/dT   = mean (z_y - E_p[z]) / T^2
        d2NLL/dT2 = mean [ -2 (z_y - E_p[z]) / T^3 + Var_p[z] / T^4 ]
    """
    from repro.kernels.calib_nll import calib_nll_kernel

    rows, vocab = logits.shape
    pr = (-rows) % block_rows
    pc = (-vocab) % block_cols
    z = logits
    y = labels.astype(jnp.int32)
    if pr or pc:
        # pad constant: large enough to underflow exp() at any T >= 0.05,
        # small enough that z^2 stays finite in fp32 (1e30^2 would be inf
        # and poison the E[z^2] accumulator with inf*0 = nan)
        z = jnp.pad(z, ((0, pr), (0, pc)), constant_values=-3e4)
        y = jnp.pad(y, (0, pr))
    e1, e2, zy, nll = calib_nll_kernel(
        z, y, temperature, block_rows=block_rows, block_cols=block_cols,
        interpret=not _is_tpu(),
    )
    e1, e2, zy, nll = e1[:rows], e2[:rows], zy[:rows], nll[:rows]
    t = jnp.asarray(temperature, jnp.float32)
    var = e2 - e1 * e1
    d1 = jnp.mean((zy - e1) / (t * t))
    d2 = jnp.mean(-2.0 * (zy - e1) / t**3 + var / t**4)
    return jnp.mean(nll), d1, d2


def fit_temperature_kernel(logits, labels, t0=1.0, iters: int = 25,
                           t_min: float = 0.05, t_max: float = 20.0):
    """Newton's method on T using the fused one-pass kernel statistics."""

    def step(t, _):
        nll, d1, d2 = calib_stats(logits, labels, t)
        delta = jnp.where(jnp.abs(d2) > 1e-12, d1 / d2, jnp.sign(d1) * 0.1)
        delta = jnp.clip(delta, -0.5 * t, 0.5 * t)
        return jnp.clip(t - delta, t_min, t_max), nll

    t, nlls = jax.lax.scan(step, jnp.float32(t0), None, length=iters)
    return t, nlls[-1]
