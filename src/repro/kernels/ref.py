"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def exit_gate_ref(logits, temperature):
    """(confidence, entropy, argmax) of softmax(logits / T), row-wise.

    logits: (..., vocab). Float32 math throughout.
    """
    z = logits.astype(jnp.float32) / jnp.asarray(temperature, jnp.float32)
    m = jnp.max(z, axis=-1, keepdims=True)
    logp = z - m - jnp.log(jnp.sum(jnp.exp(z - m), axis=-1, keepdims=True))
    p = jnp.exp(logp)
    conf = jnp.max(p, axis=-1)
    ent = -jnp.sum(p * logp, axis=-1)
    idx = jnp.argmax(z, axis=-1).astype(jnp.int32)
    return conf, ent, idx


def calib_nll_ref(logits, labels, temperature):
    """(E_p[z], E_p[z^2], z_y, nll) per row; p = softmax(z/T)."""
    z = logits.astype(jnp.float32)
    t = jnp.asarray(temperature, jnp.float32)
    u = z / t
    m = jnp.max(u, axis=-1, keepdims=True)
    e = jnp.exp(u - m)
    S = jnp.sum(e, axis=-1)
    p = e / S[..., None]
    e1 = jnp.sum(p * z, axis=-1)
    e2 = jnp.sum(p * z * z, axis=-1)
    zy = jnp.take_along_axis(z, labels[..., None], axis=-1)[..., 0]
    nll = jnp.log(S) + m[..., 0] - zy / t
    return e1, e2, zy, nll
