"""Pure-jnp/numpy oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def exit_gate_ref(logits, temperature):
    """(confidence, entropy, argmax) of softmax(logits / T), row-wise.

    logits: (..., vocab). Float32 math throughout.
    """
    z = logits.astype(jnp.float32) / jnp.asarray(temperature, jnp.float32)
    m = jnp.max(z, axis=-1, keepdims=True)
    logp = z - m - jnp.log(jnp.sum(jnp.exp(z - m), axis=-1, keepdims=True))
    p = jnp.exp(logp)
    conf = jnp.max(p, axis=-1)
    ent = -jnp.sum(p * logp, axis=-1)
    idx = jnp.argmax(z, axis=-1).astype(jnp.int32)
    return conf, ent, idx


# ------------------------------------------------- bottleneck codec oracle
#: elements per scale group -- one float32 scale per TILE consecutive
#: features of a sample's flattened payload (the TPU lane width, so the
#: kernel's (8, TILE) block owns whole scale groups)
CODEC_TILE = 128
#: level -> integer bits per quantized value (level 0 is identity and
#: never reaches the codec)
CODEC_BITS = {1: 8, 2: 4}


def _codec_layout(shape):
    """Canonical 2D view: one row per leading-axis sample, features
    flattened into columns (the per-sample vector the tiles run over)."""
    if len(shape) <= 1:
        return 1, int(shape[0]) if shape else 1
    rows = int(shape[0])
    cols = 1
    for d in shape[1:]:
        cols *= int(d)
    return rows, cols


def encode_codec_ref(x, level: int):
    """Absmax per-tile quantize + pack, the bit-exact oracle for the
    Pallas encode kernel.

    x: any-shape float array, canonicalized to (rows, features). Per
    (row, TILE-feature group): scale = absmax/qmax (float32), values
    round-to-nearest-even to `CODEC_BITS[level]`-bit signed ints packed
    little-endian into uint32 words. Non-finite inputs are zeroed before
    absmax (an inf scale would silently flush the whole tile); an
    all-zero tile stores scale 0 and divides by 1 instead.

    Returns (words, scales): words (rows, padded_features * bits / 32)
    uint32, scales (rows, padded_features / TILE) float32.
    """
    bits = CODEC_BITS[int(level)]
    per = 32 // bits
    qmax = np.float32((1 << (bits - 1)) - 1)
    x = np.asarray(x)
    rows, cols = _codec_layout(x.shape)
    z = x.reshape(rows, cols).astype(np.float32)
    pad = (-cols) % CODEC_TILE
    if pad:
        z = np.concatenate([z, np.zeros((rows, pad), np.float32)], axis=1)
    z = np.where(np.isfinite(z), z, np.float32(0.0))
    g = z.shape[1] // CODEC_TILE
    zt = z.reshape(rows, g, CODEC_TILE)
    # multiply by the f32 reciprocal instead of dividing: a compiler may
    # strength-reduce a constant divide to exactly this, so doing it
    # explicitly keeps the oracle and the kernel bit-identical
    scales = (np.max(np.abs(zt), axis=2) * (np.float32(1.0) / qmax)).astype(np.float32)
    safe = np.where(scales > 0, scales, np.float32(1.0))
    q = np.clip(np.round(zt / safe[:, :, None]), -qmax, qmax).astype(np.int32)
    qf = q.reshape(rows, g * CODEC_TILE)
    mask = np.uint32((1 << bits) - 1)
    words = np.zeros((rows, qf.shape[1] // per), np.uint32)
    for k in range(per):
        words |= (qf[:, k::per].astype(np.uint32) & mask) << np.uint32(bits * k)
    return words, scales


def decode_codec_ref(words, scales, shape, level: int):
    """Inverse of `encode_codec_ref`: unpack, sign-extend, rescale.
    Returns float32 in the original `shape`."""
    bits = CODEC_BITS[int(level)]
    per = 32 // bits
    half, full = 1 << (bits - 1), 1 << bits
    mask = np.uint32(full - 1)
    words = np.asarray(words, np.uint32)
    scales = np.asarray(scales, np.float32)
    rows, nw = words.shape
    v = np.empty((rows, nw * per), np.int32)
    for k in range(per):
        u = ((words >> np.uint32(bits * k)) & mask).astype(np.int32)
        v[:, k::per] = np.where(u >= half, u - full, u)
    zt = v.reshape(rows, -1, CODEC_TILE).astype(np.float32) * scales[:, :, None]
    _, cols = _codec_layout(shape)
    return zt.reshape(rows, -1)[:, :cols].reshape(shape)


def roundtrip_codec_ref(x, level: int):
    """decode(encode(x)) -- what the cloud sees after a compressed
    offload. Level 0 is the identity (the input object, no cast), which
    is what makes level-0 runs bit-exact with the pre-codec stacks."""
    if int(level) == 0:
        return np.asarray(x)
    words, scales = encode_codec_ref(x, level)
    return decode_codec_ref(words, scales, np.asarray(x).shape, level)


def calib_nll_ref(logits, labels, temperature):
    """(E_p[z], E_p[z^2], z_y, nll) per row; p = softmax(z/T)."""
    z = logits.astype(jnp.float32)
    t = jnp.asarray(temperature, jnp.float32)
    u = z / t
    m = jnp.max(u, axis=-1, keepdims=True)
    e = jnp.exp(u - m)
    S = jnp.sum(e, axis=-1)
    p = e / S[..., None]
    e1 = jnp.sum(p * z, axis=-1)
    e2 = jnp.sum(p * z * z, axis=-1)
    zy = jnp.take_along_axis(z, labels[..., None], axis=-1)[..., 0]
    nll = jnp.log(S) + m[..., 0] - zy / t
    return e1, e2, zy, nll
