"""Fused early-exit gate kernel (Pallas TPU).

Computes, per row of a (rows, vocab) logits matrix and a scalar temperature:
    confidence = max softmax(z / T)
    entropy    = H(softmax(z / T))        (nats)
    argmax     = argmax z
WITHOUT materializing the softmax: an online-softmax sweep over vocab tiles
keeps only (running max m, rescaled denom S, rescaled sum W = sum u*e^u,
best value/index) per row in VMEM scratch.

Why this is the paper's hot spot on TPU: the gate runs after every early
exit for every token; at Qwen-scale vocab (151,936) a naive
softmax().max() + entropy materializes and re-reads a (tokens, vocab) fp32
tensor from HBM three times. The fused kernel streams each logits tile
HBM->VMEM once -- it is purely memory-bound, so this is a ~3x traffic cut.

Tiling: rows block R=8 (fp32 sublane), vocab block C=512 lanes; the vocab
grid dimension is 'arbitrary' (sequential) so scratch carries across tiles.

Math: with u_i = z_i/T - m (m = running max of z/T):
    S = sum e^{u_i};  W = sum u_i e^{u_i}
    confidence = e^{u_max}/S = 1/S  (since m is the global max)
    entropy    = log S - W/S
Rescaling when the max improves from m to m': S *= e^{m-m'},
W' = e^{m-m'} (W + (m-m') S).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in newer jax
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG = -1e30


def _kernel(temp_ref, z_ref, conf_ref, ent_ref, idx_ref, m_s, s_s, w_s, bv_s, bi_s):
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    C = z_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        m_s[:] = jnp.full_like(m_s, NEG)
        s_s[:] = jnp.zeros_like(s_s)
        w_s[:] = jnp.zeros_like(w_s)
        bv_s[:] = jnp.full_like(bv_s, NEG)
        bi_s[:] = jnp.zeros_like(bi_s)

    t = temp_ref[0, 0]
    z = z_ref[:].astype(jnp.float32) / t  # (R, C)

    # --- running max / rescale ---
    m_old = m_s[:]  # (R,)
    tile_max = jnp.max(z, axis=1)
    m_new = jnp.maximum(m_old, tile_max)
    scale = jnp.exp(m_old - m_new)
    s_old = s_s[:] * scale
    w_old = (w_s[:] + (m_old - m_new) * s_s[:]) * scale

    u = z - m_new[:, None]
    e = jnp.exp(u)
    s_s[:] = s_old + jnp.sum(e, axis=1)
    w_s[:] = w_old + jnp.sum(u * e, axis=1)
    m_s[:] = m_new

    # --- streaming argmax (on raw logits; T > 0 preserves argmax) ---
    tile_arg = jnp.argmax(z, axis=1).astype(jnp.int32)
    tile_best = tile_max
    better = tile_best > bv_s[:]
    bv_s[:] = jnp.where(better, tile_best, bv_s[:])
    bi_s[:] = jnp.where(better, tile_arg + j * C, bi_s[:])

    @pl.when(j == nj - 1)
    def _finish():
        S = s_s[:]
        conf_ref[:] = 1.0 / S
        ent_ref[:] = jnp.log(S) - w_s[:] / S
        idx_ref[:] = bi_s[:]


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def exit_gate_kernel(
    logits, temperature, block_rows: int = 8, block_cols: int = 512, interpret: bool = True
):
    """logits: (rows, vocab); temperature: scalar. Returns (conf, ent, idx).

    rows must be a multiple of block_rows and vocab of block_cols (ops.py
    pads). interpret=True executes on CPU for validation; on TPU pass False.
    """
    rows, vocab = logits.shape
    assert rows % block_rows == 0 and vocab % block_cols == 0
    grid = (rows // block_rows, vocab // block_cols)
    temp = jnp.asarray(temperature, jnp.float32).reshape(1, 1)

    out_shapes = (
        jax.ShapeDtypeStruct((rows,), jnp.float32),  # confidence
        jax.ShapeDtypeStruct((rows,), jnp.float32),  # entropy
        jax.ShapeDtypeStruct((rows,), jnp.int32),  # argmax
    )
    row_spec = pl.BlockSpec((block_rows,), lambda i, j: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
        ],
        out_specs=(row_spec, row_spec, row_spec),
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((block_rows,), jnp.float32),  # running max
            pltpu.VMEM((block_rows,), jnp.float32),  # S
            pltpu.VMEM((block_rows,), jnp.float32),  # W
            pltpu.VMEM((block_rows,), jnp.float32),  # best value
            pltpu.VMEM((block_rows,), jnp.int32),  # best index
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(temp, logits)
