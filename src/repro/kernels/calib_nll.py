"""Fused calibration-NLL kernel (Pallas TPU) -- kernel #2.

Temperature Scaling fits T by minimizing
    NLL(T) = mean_r [ logsumexp(z_r / T) - z_{r,y_r} / T ].
Each Newton iteration needs NLL plus its first/second derivatives in T:
    dNLL/dT   = (z_y - E_p[z]) / T^2
    d2NLL/dT2 = -2 (z_y - E_p[z]) / T^3 + Var_p[z] / T^4
with p = softmax(z/T). All three reduce to FOUR streaming row statistics
    m  = max(z/T),  S = sum e^{z/T - m},
    W1 = sum z e^{z/T - m},  W2 = sum z^2 e^{z/T - m},
plus the label logit z_y -- so one pass over the (rows, vocab) logits in
VMEM tiles yields the whole Newton step. The jnp path reads the logits
~3x per iteration (logsumexp, E[z], E[z^2]); at Qwen-scale vocab and a
3k-sample validation split this kernel makes calibration one HBM sweep
per iteration.

Grid: (row blocks, vocab blocks); vocab dim is 'arbitrary' (sequential)
with rescale-on-new-max in VMEM scratch, like exit_gate. The label logit
is picked up by masking the tile whose column range contains y_r.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in newer jax
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG = -1e30


def _kernel(temp_ref, labels_ref, z_ref, e1_ref, e2_ref, zy_ref, nll_ref,
            m_s, s_s, w1_s, w2_s, zy_s):
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    C = z_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        m_s[:] = jnp.full_like(m_s, NEG)
        s_s[:] = jnp.zeros_like(s_s)
        w1_s[:] = jnp.zeros_like(w1_s)
        w2_s[:] = jnp.zeros_like(w2_s)
        zy_s[:] = jnp.zeros_like(zy_s)

    t = temp_ref[0, 0]
    zraw = z_ref[:].astype(jnp.float32)  # (R, C)
    u = zraw / t

    # --- label logit: the tile that contains column y_r contributes it ---
    labels = labels_ref[:]  # (R,)
    col0 = j * C
    cols = col0 + jax.lax.broadcasted_iota(jnp.int32, zraw.shape, 1)
    hit = cols == labels[:, None]
    zy_s[:] = zy_s[:] + jnp.sum(jnp.where(hit, zraw, 0.0), axis=1)

    # --- streaming max rescale ---
    m_old = m_s[:]
    m_new = jnp.maximum(m_old, jnp.max(u, axis=1))
    scale = jnp.exp(m_old - m_new)
    e = jnp.exp(u - m_new[:, None])
    s_s[:] = s_s[:] * scale + jnp.sum(e, axis=1)
    w1_s[:] = w1_s[:] * scale + jnp.sum(zraw * e, axis=1)
    w2_s[:] = w2_s[:] * scale + jnp.sum(zraw * zraw * e, axis=1)
    m_s[:] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        S = s_s[:]
        e1_ref[:] = w1_s[:] / S  # E_p[z]
        e2_ref[:] = w2_s[:] / S  # E_p[z^2]
        zy_ref[:] = zy_s[:]
        nll_ref[:] = jnp.log(S) + m_s[:] - zy_s[:] / t


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def calib_nll_kernel(logits, labels, temperature,
                     block_rows: int = 8, block_cols: int = 512,
                     interpret: bool = True):
    """logits (rows, vocab), labels (rows,) int32, temperature scalar.

    Returns (e1, e2, zy, nll) per row; rows/vocab must be tile multiples
    (ops.py pads: rows with label 0 / NEG logits, masked out afterwards).
    """
    rows, vocab = logits.shape
    assert rows % block_rows == 0 and vocab % block_cols == 0
    grid = (rows // block_rows, vocab // block_cols)
    temp = jnp.asarray(temperature, jnp.float32).reshape(1, 1)
    row_spec = pl.BlockSpec((block_rows,), lambda i, j: (i,))
    out_shapes = tuple(
        jax.ShapeDtypeStruct((rows,), jnp.float32) for _ in range(4)
    )
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            row_spec,
            pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
        ],
        out_specs=(row_spec, row_spec, row_spec, row_spec),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((block_rows,), jnp.float32) for _ in range(5)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(temp, labels, logits)
