"""Pallas bottleneck codec for the offload payload (encode on the edge,
decode in the cloud).

The paper prices every offload as the raw float32 intermediate activation
crossing the 18.8 Mbps uplink; this kernel makes the payload a control
knob. Per (row, 128-feature tile) of the flattened activation it computes
an absmax scale and quantizes to signed int8 (level 1) or int4 (level 2),
packing values little-endian into uint32 words with the float32 scales
emitted in the same pass -- one HBM read of the activation produces the
whole wire image, which is what makes the encode affordable on the edge
hot path (the activation is re-read zero extra times).

Wire format (shared bit-exactly with the numpy oracle in `ref.py`):

    words  (rows, padded_features * bits / 32) uint32, little-endian
           packed two's-complement `bits`-bit values
    scales (rows, padded_features / 128)       float32, absmax / qmax

Compressed size is analytic -- `compressed_nbytes(n, level)` = n*bits/8
payload + 4 bytes per 128-wide scale group -- so the control plane can
price a (branch, level) candidate without touching a tensor; level 2
(int4) lands at ~7.5x under the float32 payload, level 1 (int8) at ~3.9x.

Edge cases: non-finite inputs are zeroed before the absmax (one inf
would otherwise flush its whole tile to zeros with an inf scale), and an
all-zero tile stores scale 0 but divides by 1, so encode never divides
by zero. Level 0 is the identity and never reaches these kernels.

Tiling: rows block 8 (fp32 sublane) x features block 512 lanes; every
(8, 512) block owns four whole scale groups, so the grid is fully
parallel (no cross-tile carry, unlike the online-softmax gate kernel).
The group reshape (8, 512) -> (8, 4, 128) stays within the lane axis.
`interpret=True` executes on CPU for validation; ops-level wrappers pass
`interpret=not _is_tpu()` exactly as `ops.exit_gate` does.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as _np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import CODEC_BITS, CODEC_TILE, _codec_layout

# renamed TPUCompilerParams -> CompilerParams in newer jax
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

#: the codec's public level axis: 0 = identity float32, 1 = int8, 2 = int4
LEVELS = (0, 1, 2)


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def compressed_nbytes(n_elements: int, level: int) -> int:
    """Wire bytes for an n-element float32 payload at `level` (analytic,
    unpadded): packed values + one float32 scale per 128-element group.
    The single source of truth every pricing surface derives from."""
    n = int(n_elements)
    if int(level) == 0:
        return 4 * n
    bits = CODEC_BITS[int(level)]
    groups = -(-n // CODEC_TILE)
    return (n * bits + 7) // 8 + 4 * groups


def scaled_payload_nbytes(raw_nbytes: int, level: int) -> int:
    """Wire bytes for a payload whose RAW float32 size is `raw_nbytes` --
    the (branch, level) table entry. Level 0 returns `raw_nbytes`
    unchanged (the bit-exact identity the parity suites pin)."""
    if int(level) == 0:
        return int(raw_nbytes)
    return compressed_nbytes(int(raw_nbytes) // 4, level)


# ---------------------------------------------------------------- kernels
def _encode_kernel(x_ref, words_ref, scale_ref, *, bits: int):
    per = 32 // bits
    qmax = jnp.float32((1 << (bits - 1)) - 1)
    mask = jnp.uint32((1 << bits) - 1)
    z = x_ref[:].astype(jnp.float32)  # (R, C)
    z = jnp.where(jnp.isfinite(z), z, jnp.float32(0.0))
    R, C = z.shape
    g = C // CODEC_TILE
    zt = z.reshape(R, g, CODEC_TILE)
    # reciprocal-multiply, matching ref.encode_codec_ref bit-for-bit
    scale = jnp.max(jnp.abs(zt), axis=2) * jnp.float32(_np.float32(1.0) / _np.float32((1 << (bits - 1)) - 1))
    safe = jnp.where(scale > 0, scale, jnp.float32(1.0))
    q = jnp.clip(jnp.round(zt / safe[:, :, None]), -qmax, qmax)
    q = q.astype(jnp.int32).reshape(R, C // per, per)
    w = jnp.zeros((R, C // per), jnp.uint32)
    for k in range(per):  # static unroll: 4 (int8) or 8 (int4) ors
        w = w | ((q[:, :, k].astype(jnp.uint32) & mask) << jnp.uint32(bits * k))
    words_ref[:] = w
    scale_ref[:] = scale


def _decode_kernel(words_ref, scale_ref, out_ref, *, bits: int):
    per = 32 // bits
    half, full = 1 << (bits - 1), 1 << bits
    mask = jnp.uint32(full - 1)
    w = words_ref[:]  # (R, C // per) uint32
    vs = []
    for k in range(per):
        u = ((w >> jnp.uint32(bits * k)) & mask).astype(jnp.int32)
        vs.append(jnp.where(u >= half, u - full, u))
    R, nw = w.shape
    v = jnp.stack(vs, axis=-1).reshape(R, nw * per)
    zt = v.reshape(R, -1, CODEC_TILE).astype(jnp.float32)
    out_ref[:] = (zt * scale_ref[:][:, :, None]).reshape(R, nw * per)


@functools.partial(
    jax.jit, static_argnames=("bits", "block_rows", "block_cols", "interpret")
)
def encode_pallas(
    z, bits: int, block_rows: int = 8, block_cols: int = 512,
    interpret: bool = True,
):
    """z: (rows, cols) float32, rows % block_rows == 0, cols % block_cols
    == 0. Returns (words uint32, scales float32) covering all of z."""
    rows, cols = z.shape
    assert rows % block_rows == 0 and cols % block_cols == 0
    per = 32 // bits
    grid = (rows // block_rows, cols // block_cols)
    return pl.pallas_call(
        functools.partial(_encode_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
        ],
        out_specs=(
            pl.BlockSpec((block_rows, block_cols // per), lambda i, j: (i, j)),
            pl.BlockSpec(
                (block_rows, block_cols // CODEC_TILE), lambda i, j: (i, j)
            ),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((rows, cols // per), jnp.uint32),
            jax.ShapeDtypeStruct((rows, cols // CODEC_TILE), jnp.float32),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(z)


@functools.partial(
    jax.jit, static_argnames=("bits", "block_rows", "block_cols", "interpret")
)
def decode_pallas(
    words, scales, bits: int, block_rows: int = 8, block_cols: int = 512,
    interpret: bool = True,
):
    """Inverse of `encode_pallas`; returns (rows, cols) float32."""
    per = 32 // bits
    rows, nw = words.shape
    cols = nw * per
    assert rows % block_rows == 0 and cols % block_cols == 0
    grid = (rows // block_rows, cols // block_cols)
    return pl.pallas_call(
        functools.partial(_decode_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_cols // per), lambda i, j: (i, j)),
            pl.BlockSpec(
                (block_rows, block_cols // CODEC_TILE), lambda i, j: (i, j)
            ),
        ],
        out_specs=pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(words, scales)


# ----------------------------------------------------------- public wrappers
@dataclass(frozen=True)
class EncodedPayload:
    """One encoded offload payload: the wire image + enough metadata to
    decode. `nbytes` is the analytic unpadded wire size (what the uplink
    is charged), not the padded device buffer size."""

    words: Any  # (rows, ceil(features/128)*128 * bits / 32) uint32
    scales: Any  # (rows, ceil(features/128)) float32
    shape: Tuple[int, ...]
    level: int

    @property
    def nbytes(self) -> int:
        rows, cols = _codec_layout(self.shape)
        return rows * compressed_nbytes(cols, self.level)


def encode(x, level: int, block_rows: int = 8, block_cols: int = 512) -> EncodedPayload:
    """Encode an arbitrary-shape float payload through the Pallas kernel
    (interpret mode off-TPU). The emitted words/scales are sliced to the
    128-aligned wire format of `ref.encode_codec_ref`, bit-exactly."""
    level = int(level)
    if level == 0:
        raise ValueError("level 0 is the identity; nothing to encode")
    bits = CODEC_BITS[level]
    per = 32 // bits
    x = jnp.asarray(x)
    rows, cols = _codec_layout(x.shape)
    z = x.reshape(rows, cols).astype(jnp.float32)
    cols128 = -(-cols // CODEC_TILE) * CODEC_TILE
    pr = (-rows) % block_rows
    pc = (-cols) % block_cols
    if pr or pc:
        z = jnp.pad(z, ((0, pr), (0, pc)))
    words, scales = encode_pallas(
        z, bits, block_rows=block_rows, block_cols=block_cols,
        interpret=not _is_tpu(),
    )
    return EncodedPayload(
        words=words[:rows, : cols128 * bits // 32],
        scales=scales[:rows, : cols128 // CODEC_TILE],
        shape=tuple(int(d) for d in x.shape),
        level=level,
    )


def decode(enc: EncodedPayload, block_rows: int = 8, block_cols: int = 512):
    """Decode an `EncodedPayload` back to float32 in its original shape."""
    bits = CODEC_BITS[int(enc.level)]
    per = 32 // bits
    rows, cols = _codec_layout(enc.shape)
    words = jnp.asarray(enc.words)
    scales = jnp.asarray(enc.scales)
    nw, ng = words.shape[1], scales.shape[1]
    pr = (-rows) % block_rows
    pw = (-(nw * per)) % block_cols
    if pr or pw:
        words = jnp.pad(words, ((0, pr), (0, pw // per)))
        scales = jnp.pad(scales, ((0, pr), (0, pw // CODEC_TILE)))
    out = decode_pallas(
        words, scales, bits, block_rows=block_rows, block_cols=block_cols,
        interpret=not _is_tpu(),
    )
    return out[:rows, :cols].reshape(enc.shape)


def roundtrip(x, level: int):
    """decode(encode(x)) through the kernels; level 0 is the identity."""
    if int(level) == 0:
        return jnp.asarray(x)
    return decode(encode(x, level))
