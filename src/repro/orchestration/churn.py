"""Cell churn: seeded join/leave event schedules.

A `ChurnSchedule` is an immutable, time-sorted list of `ChurnEvent`s; the
`Orchestrator` holds its own cursor into it and applies every event whose
time has come at each window boundary (events therefore take effect at
the first boundary >= their scheduled time -- the same window-boundary
granularity every other config change in the fleet simulator has).
"fail"/"recover" are not separate kinds: a failure IS a leave and a
recovery IS a join; what differs is who scheduled it, which the schedule
does not model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Sequence, Tuple

import numpy as np

JOIN = "join"
LEAVE = "leave"


@dataclass(frozen=True)
class ChurnEvent:
    t_s: float
    cell: int
    kind: str  # JOIN | LEAVE

    def __post_init__(self):
        if self.kind not in (JOIN, LEAVE):
            raise ValueError(f"kind must be {JOIN!r} or {LEAVE!r}, got {self.kind!r}")
        if self.t_s < 0:
            raise ValueError("t_s must be >= 0")
        if self.cell < 0:
            raise ValueError("cell must be >= 0")


class ChurnSchedule:
    """Time-sorted churn events (ties broken by cell, then join-before-
    leave so a same-instant bounce nets out to down)."""

    def __init__(self, events: Iterable[ChurnEvent] = ()):
        self.events: Tuple[ChurnEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.t_s, e.cell, e.kind != JOIN))
        )

    def __len__(self) -> int:
        return len(self.events)

    def due(self, cursor: int, t_s: float) -> Tuple[Tuple[ChurnEvent, ...], int]:
        """Events at index >= cursor with scheduled time <= t_s ->
        (events, new cursor). The caller owns the cursor, so one schedule
        can drive many runs."""
        j = cursor
        while j < len(self.events) and self.events[j].t_s <= t_s:
            j += 1
        return self.events[cursor:j], j

    @classmethod
    def outage(
        cls, cells: Sequence[int], start_s: float, duration_s: float
    ) -> "ChurnSchedule":
        """The simplest correlated failure: `cells` all leave at `start_s`
        and rejoin `duration_s` later."""
        if duration_s <= 0:
            raise ValueError("duration_s must be positive")
        evs: List[ChurnEvent] = []
        for c in cells:
            evs.append(ChurnEvent(start_s, c, LEAVE))
            evs.append(ChurnEvent(start_s + duration_s, c, JOIN))
        return cls(evs)

    @classmethod
    def random(
        cls,
        n_cells: int,
        horizon_s: float,
        seed: int = 0,
        outage_rate_hz: float = 0.02,
        mean_downtime_s: float = 5.0,
    ) -> "ChurnSchedule":
        """Seeded background churn: per cell, outages arrive Poisson at
        `outage_rate_hz` and last Exp(`mean_downtime_s`). Deterministic
        under the seed; an outage still open at the horizon never rejoins
        (the run ends with the cell down)."""
        rng = np.random.default_rng(seed)
        evs: List[ChurnEvent] = []
        for c in range(n_cells):
            t = float(rng.exponential(1.0 / outage_rate_hz))
            while t < horizon_s:
                dur = float(rng.exponential(mean_downtime_s))
                evs.append(ChurnEvent(t, c, LEAVE))
                if t + dur < horizon_s:
                    evs.append(ChurnEvent(t + dur, c, JOIN))
                t += dur + float(rng.exponential(1.0 / outage_rate_hz))
        return cls(evs)
