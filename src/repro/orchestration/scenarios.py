"""Adversarial fleet scenarios: the orchestration plane's proving ground.

Each scenario is a seeded, deterministic stressor registered with
`@register_scenario`; `run_scenarios` sweeps them into uniform records
that `benchmarks/run.py --fleet` writes to ``BENCH_fleet.json`` and CI
asserts on. Every record has:

    {"name": ..., "arms": {arm: fleet_summary + extras},
     "wins": {metric: {...,"win": bool}}, "events": {...}, "pass": bool}

where ``pass`` is the AND of the scenario's required wins. The arms are
always a CONTROL (static configuration, or rollout disabled) against the
treatment (fleet controller, or the QoS-gated rollout), on identical
workloads and seeds -- the same controller-vs-static discipline as the
PR 4 fleet bench, under operations instead of steady load.

The registry is intentionally open: `register_scenario` is public, and a
scenario is any callable ``fn(quick: bool, seed: int) -> dict``.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.bank import PlanBank
from repro.core.calibration import TemperatureScaling
from repro.fleet.scenarios import (
    FleetScenario,
    fleet_gate_table,
    reference_fleet,
    run_fleet,
)
from repro.fleet.simulator import FleetConfig
from repro.fleet.topology import CellConfig, CellWorkload, FleetTopology
from repro.orchestration.churn import ChurnSchedule
from repro.orchestration.plane import Orchestrator
from repro.orchestration.qos import CellSLO, QoSConfig, QoSMonitor
from repro.orchestration.rollout import PROMOTED, ROLLED_BACK, RolloutManager
from repro.serving.drift import PiecewiseSchedule

SCENARIOS: Dict[str, Callable] = {}


def register_scenario(name: str):
    """Register ``fn(quick, seed) -> record`` under `name`; later
    registrations override (so downstream code can swap a stressor)."""

    def deco(fn):
        SCENARIOS[name] = fn
        return fn

    return deco


def run_scenarios(
    names: Optional[Sequence[str]] = None,
    quick: bool = False,
    seed: int = 0,
) -> List[dict]:
    """Run the named scenarios (None/"all" -> every registered one, in
    registration order) -> their records."""
    if names is None:
        picked = list(SCENARIOS)
    else:
        unknown = [n for n in names if n not in SCENARIOS]
        if unknown:
            raise ValueError(
                f"unknown scenario(s) {unknown}; registered: {list(SCENARIOS)}"
            )
        picked = list(names)
    return [SCENARIOS[n](quick=quick, seed=seed) for n in picked]


# ------------------------------------------------------------ shared pieces
_DATA: Dict[int, tuple] = {}


def _drift_data(seed: int = 0):
    """One (val, test) split per seed, cached: every scenario stresses the
    SAME drift data so their numbers are comparable across the matrix."""
    if seed not in _DATA:
        from repro.serving.scenarios import synthetic_distorted_cascade

        _DATA[seed] = synthetic_distorted_cascade(
            seed=seed, directions={"gaussian_blur": "under"}
        )
    return _DATA[seed]


def _plans(seed: int = 0):
    from repro.serving.scenarios import fit_drift_plans

    val, test = _drift_data(seed)
    return fit_drift_plans(val)  # (uncalibrated, global single, expert bank)


def poisoned_bank(bank: PlanBank, temp_scale: float = 0.05) -> PlanBank:
    """A miscalibrated candidate: every expert's temperatures scaled by
    `temp_scale` (T << 1 sharpens softmax -> systematic overconfidence),
    version-bumped so the rollout manager accepts it. The poison is
    exactly the failure mode the paper calibrates away, injected as an
    artifact a fleet might actually ship."""
    if temp_scale <= 0:
        raise ValueError("temp_scale must be positive")
    plans = {
        k: p._copy(
            calibrators=[
                TemperatureScaling.from_temperature(t * temp_scale)
                for t in p.temperatures
            ]
        )
        for k, p in bank.plans.items()
    }
    return PlanBank(
        plans=plans,
        default_context=bank.default_context,
        estimator=bank.estimator,
        metadata={**bank.metadata, "poisoned": True},
        bank_version=bank.bank_version + 1,
    )


def _summary(tel) -> dict:
    s = tel.fleet_summary()
    return {k: (float(v) if isinstance(v, float) else v) for k, v in s.items()}


def _win(wins: dict, metric: str, treatment: dict, control: dict,
         margin: float = 1.0) -> bool:
    """Record a lower-is-better win on `metric` (treatment must beat
    control by the multiplicative margin) -> the verdict."""
    t, c = treatment[metric], control[metric]
    ok = bool(np.isfinite(t) and np.isfinite(c) and t < c * margin)
    wins[metric] = {"treatment": t, "control": c, "margin": margin, "win": ok}
    return ok


def _record(name: str, arms: dict, wins: dict, events: dict,
            passed: bool) -> dict:
    return {"name": name, "arms": arms, "wins": wins, "events": events,
            "pass": bool(passed)}


def _quick_size(quick: bool) -> dict:
    return dict(
        n_cells=8,
        requests_per_cell=300 if quick else 700,
        cloud_servers=2,
    )


# --------------------------------------------------------------- scenarios
@register_scenario("weather_front")
def weather_front(quick: bool = False, seed: int = 0) -> dict:
    """Correlated cross-cell drift: a contrast front sweeps the ring, each
    cell entering the overconfident regime a beat after its neighbor --
    the spatially-correlated version of the drift the bank was built for.
    Control: the paper's single global plan (clean-fit temperatures),
    static. Treatment: expert bank + fleet controller. Required win:
    reliability gap (the front breaks the clean-fit gate's contract in
    every cell it crosses)."""
    val, test = _drift_data(seed)
    _, global_plan, bank = _plans(seed)
    size = _quick_size(quick)
    base = reference_fleet(seed=seed, val=val, test=test, **size)
    # same workloads/links, but the Markov weather is replaced by one
    # deterministic front: cell i is distorted during [6 + 1.5 i, 18 + 1.5 i)
    cells = []
    for i, cell in enumerate(base.topology.cells):
        front = PiecewiseSchedule([
            (0.0, "clean"),
            (6.0 + 1.5 * i, "contrast@4"),
            (18.0 + 1.5 * i, "clean"),
        ])
        cells.append(CellConfig(
            network=cell.network, workload=cell.workload,
            n_devices=cell.n_devices, schedule=front,
            deadline_s=cell.deadline_s,
        ))
    scn = FleetScenario(
        topology=FleetTopology(cells, cloud_servers=size["cloud_servers"]),
        val=val, test=test, contexts=base.contexts,
    )
    control = _summary(run_fleet(global_plan, scn))
    treatment = _summary(run_fleet(bank, scn, with_controller=True))
    wins: dict = {}
    ok = _win(wins, "miscalibration_gap", treatment, control)
    _win(wins, "p99_ms", treatment, control)  # recorded, not required
    return _record(
        "weather_front",
        {"static_global": control, "bank_controller": treatment},
        wins, {"front_span_s": [6.0, 18.0 + 1.5 * (size["n_cells"] - 1)]}, ok,
    )


def _burst_workload(
    rate_hz: float, burst_rate_hz: float, burst: tuple,
    n_requests: int, n_samples: int, n_devices: int, seed: int,
) -> CellWorkload:
    """Poisson arrivals at `rate_hz`, spiking to `burst_rate_hz` inside
    the `burst` = (start_s, end_s) interval -- a piecewise-homogeneous
    process materialized gap by gap, deterministic under the seed."""
    rng = np.random.default_rng(seed)
    a, b = burst
    t, arrivals = 0.0, np.empty(n_requests, np.float64)
    for i in range(n_requests):
        r = burst_rate_hz if a <= t < b else rate_hz
        t += float(rng.exponential(1.0 / r))
        arrivals[i] = t
    idx = np.arange(n_requests, dtype=np.int64)
    return CellWorkload(arrivals, idx % n_samples, idx % n_devices)


@register_scenario("flash_crowd")
def flash_crowd(quick: bool = False, seed: int = 0) -> dict:
    """Fleet-wide arrival spike: every cell's rate jumps 5x for ten
    seconds (think a broadcast event). Control: the expert bank, static
    deployment. Treatment: the same bank + fleet controller, which can
    concede p_tar / move branches where the spike saturates a link.
    Required win: p99 latency."""
    val, test = _drift_data(seed)
    _, _, bank = _plans(seed)
    size = _quick_size(quick)
    base = reference_fleet(seed=seed, val=val, test=test, **size)
    n_samples = len(test["labels"])
    burst = (8.0, 18.0)
    cells = []
    for i, cell in enumerate(base.topology.cells):
        wl = _burst_workload(
            20.0, 100.0, burst, size["requests_per_cell"], n_samples,
            cell.n_devices, seed + 300 + i,
        )
        cells.append(CellConfig(
            network=cell.network, workload=wl, n_devices=cell.n_devices,
            schedule=cell.schedule, deadline_s=cell.deadline_s,
        ))
    scn = FleetScenario(
        topology=FleetTopology(cells, cloud_servers=size["cloud_servers"]),
        val=val, test=test, contexts=base.contexts,
    )
    control = _summary(run_fleet(bank, scn))
    treatment = _summary(run_fleet(bank, scn, with_controller=True))
    wins: dict = {}
    ok = _win(wins, "p99_ms", treatment, control)
    _win(wins, "miscalibration_gap", treatment, control, margin=1.5)
    return _record(
        "flash_crowd",
        {"bank_static": control, "bank_controller": treatment},
        wins, {"burst_s": list(burst), "burst_rate_x": 5.0}, ok,
    )


@register_scenario("link_outage")
def link_outage(quick: bool = False, seed: int = 0) -> dict:
    """Churn: a quarter of the cells fail mid-run and recover ten seconds
    later; their load sheds onto ring neighbors, doubling the hosts'
    demand. Both arms run the SAME outage through the orchestrator;
    treatment adds the fleet controller (whose utilization estimate sees
    the shed arrivals). Required win: p99 latency. Also asserts request
    conservation -- every shed request is still served and attributed."""
    val, test = _drift_data(seed)
    _, _, bank = _plans(seed)
    size = _quick_size(quick)
    scn = reference_fleet(seed=seed, val=val, test=test, **size)
    down = list(range(0, size["n_cells"], 4))
    churn = ChurnSchedule.outage(down, start_s=8.0, duration_s=10.0)

    tel_c = run_fleet(bank, scn, orchestrator=Orchestrator(churn=churn))
    tel_t = run_fleet(
        bank, scn, with_controller=True, orchestrator=Orchestrator(churn=churn)
    )
    control, treatment = _summary(tel_c), _summary(tel_t)
    conserved = (
        tel_c.requests() == scn.topology.n_requests
        and tel_t.requests() == scn.topology.n_requests
    )
    wins: dict = {}
    ok = _win(wins, "p99_ms", treatment, control) and conserved
    _win(wins, "miscalibration_gap", treatment, control, margin=1.5)
    finish = [e for e in tel_t.orchestration_events if e[1] == "finish"][0]
    return _record(
        "link_outage",
        {"bank_static": control, "bank_controller": treatment},
        wins,
        {"down_cells": down, "outage_s": [8.0, 18.0],
         "shed_requests": int(finish[2]["shed_requests"]),
         "requests_conserved": conserved},
        ok,
    )


@register_scenario("cloud_brownout")
def cloud_brownout(quick: bool = False, seed: int = 0) -> dict:
    """The shared cloud tier loses most of its capacity for a stretch
    (service times x6 for jobs landing in the interval). Control: the
    conventional uncalibrated plan, static. Treatment: expert bank +
    controller. Required win: reliability gap -- during a brownout the
    cloud stops being an escape hatch, so what the edge answers on-device
    had better honor p_tar, which is exactly what calibration buys."""
    val, test = _drift_data(seed)
    uncal, _, bank = _plans(seed)
    size = _quick_size(quick)
    scn = reference_fleet(seed=seed, val=val, test=test, **size)
    brown = (8.0, 20.0, 6.0)
    cfg = FleetConfig(window_s=0.5, cloud_slowdowns=(brown,))
    control = _summary(run_fleet(uncal, scn, fleet_config=cfg))
    treatment = _summary(
        run_fleet(bank, scn, with_controller=True, fleet_config=cfg)
    )
    wins: dict = {}
    ok = _win(wins, "miscalibration_gap", treatment, control)
    _win(wins, "deadline_miss_rate", treatment, control, margin=1.5)
    return _record(
        "cloud_brownout",
        {"static_uncalibrated": control, "bank_controller": treatment},
        wins, {"brownout": list(brown)}, ok,
    )


def _rollout_pieces(scn: FleetScenario, candidate: PlanBank,
                    incumbent_version: int = 0,
                    slo: Optional[CellSLO] = None):
    """The shared canary wiring: watch the reliability SHORTFALL per cell
    (accuracy below the promised p_tar; over-delivery never trips) with
    hysteresis, canary on two cells, promote after 8 clear windows. The
    gate-sample floor is what separates the honest bank (offloads its
    hard traffic, few on-device outcomes per window) from the poisoned
    one (overconfident, keeps everything, floods the audit stream).
    `slo` overrides the default shortfall SLO (e.g. to add the
    calibration-health caps, `CellSLO.ece_cap`/`coverage_floor`)."""
    monitor = QoSMonitor(
        slo if slo is not None else CellSLO(
            reliability_shortfall=0.12, min_requests=12,
            min_gate_samples=25,
        ),
        QoSConfig(window_s=3.0, trip_after=2, clear_after=4),
    )
    rollout = RolloutManager(
        candidate,
        table_factory=lambda b: fleet_gate_table(b, scn),
        canary_cells=(0, 1),
        promote_after=8,
        start_at_s=4.0,
        incumbent_version=incumbent_version,
    )
    return Orchestrator(monitor=monitor, rollout=rollout), monitor, rollout


@register_scenario("poisoned_canary")
def poisoned_canary(quick: bool = False, seed: int = 0) -> dict:
    """A new bank ships with catastrophically sharpened temperatures
    (T x0.05: systematic overconfidence). Guarded arm: the rollout
    manager canaries it on two cells; their on-device reliability gap
    blows the SLO, the monitor trips, and the fleet rolls back to the
    incumbent. Unguarded arm: the same bank promoted fleet-wide
    immediately. Required: the rollback happens, the guarded fleet's
    gap stays within 1.5x the incumbent's while the unguarded one does
    not, AND the whole trip -> rollback causal chain reconstructs from
    the guarded arm's decision audit log alone
    (`repro.obs.check.verify_rollback_chain`)."""
    from repro.obs import AuditLog, Observability
    from repro.obs.check import verify_rollback_chain

    val, test = _drift_data(seed)
    _, _, bank = _plans(seed)
    size = _quick_size(quick)
    scn = reference_fleet(seed=seed, val=val, test=test, **size)
    bad = poisoned_bank(bank)
    orch, monitor, rollout = _rollout_pieces(scn, bad)
    audit = AuditLog()

    incumbent = _summary(run_fleet(bank, scn))
    guarded = _summary(
        run_fleet(bank, scn, orchestrator=orch, obs=Observability(audit=audit))
    )
    unguarded = _summary(run_fleet(bad, scn))

    chain = verify_rollback_chain(audit.records)
    rolled_back = rollout.state == ROLLED_BACK
    gap_i = incumbent["miscalibration_gap"]
    gap_g = guarded["miscalibration_gap"]
    gap_u = unguarded["miscalibration_gap"]
    contained = bool(np.isfinite(gap_g) and gap_g <= 1.5 * gap_i)
    damage_shown = bool(np.isfinite(gap_u) and gap_u > 1.5 * gap_i)
    wins = {
        "rolled_back": {"win": rolled_back,
                        "at_s": rollout.rolled_back_at,
                        "tripped_canaries": rollout.tripped_canaries},
        "gap_contained": {"incumbent": gap_i, "guarded": gap_g,
                          "unguarded": gap_u, "cap": 1.5 * gap_i,
                          "win": contained and damage_shown},
        "audit_chain": {"win": chain["ok"], "why": chain["why"]},
    }
    ok = rolled_back and contained and damage_shown and chain["ok"]
    return _record(
        "poisoned_canary",
        {"incumbent": incumbent, "guarded_rollout": guarded,
         "unguarded_rollout": unguarded},
        wins,
        {"trips": [(t, int(c), m) for t, c, m in monitor.trip_log],
         "rollout_state": rollout.state,
         "candidate_version": bad.bank_version,
         "audit_records": len(audit)},
        ok,
    )


@register_scenario("good_rollout")
def good_rollout(quick: bool = False, seed: int = 0) -> dict:
    """The happy path: the candidate is the incumbent bank re-minted at
    the next version (identical calibration). The canary stays clear for
    the full probation, the rollout PROMOTES fleet-wide, and -- because
    the candidate gates identically -- the orchestrated run reproduces
    the incumbent run's fleet metrics to float round-off. Promotion of a
    good bank must be a no-op; anything else is the rollout machinery
    itself distorting service."""
    val, test = _drift_data(seed)
    _, _, bank = _plans(seed)
    size = _quick_size(quick)
    scn = reference_fleet(seed=seed, val=val, test=test, **size)
    candidate = bank.bumped()
    orch, monitor, rollout = _rollout_pieces(scn, candidate)

    incumbent = _summary(run_fleet(bank, scn))
    promoted_run = _summary(run_fleet(bank, scn, orchestrator=orch))

    promoted = rollout.state == PROMOTED
    close = all(
        (math.isnan(incumbent[k]) and math.isnan(promoted_run[k]))
        or abs(incumbent[k] - promoted_run[k])
        <= 1e-9 * max(1.0, abs(incumbent[k]))
        for k in ("p99_ms", "miscalibration_gap", "deadline_miss_rate",
                  "offload_rate", "accuracy")
    )
    wins = {
        "promoted": {"win": promoted, "at_s": rollout.promoted_at},
        "no_op_promotion": {"win": close},
    }
    ok = promoted and close and not monitor.trip_log
    return _record(
        "good_rollout",
        {"incumbent": incumbent, "promoted_rollout": promoted_run},
        wins,
        {"promoted_at_s": rollout.promoted_at,
         "candidate_version": candidate.bank_version,
         "trips": len(monitor.trip_log)},
        ok,
    )
