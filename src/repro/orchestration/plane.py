"""The orchestrator: one object the fleet simulator calls per window.

Wiring order inside a window boundary at time t0 (the simulator calls
`on_window` BEFORE the controller tick, and pops the live cloud view
first, so the monitor judges completions strictly before t0):

    1. churn   -- apply every scheduled join/leave with t <= t0;
    2. monitor -- one QoS evaluation pass over the trailing window;
    3. rollout -- advance the canary state machine on the fresh verdicts.

Every action lands in `FleetTelemetry.orchestration_events`, so a run's
operational history replays from its telemetry alone. The orchestrator
re-arms itself on `attach`, so one instance can drive many runs (each
run replays the same schedule from the top -- determinism is per-run).
"""
from __future__ import annotations

from typing import Optional

from repro.orchestration.churn import JOIN, ChurnSchedule
from repro.orchestration.qos import QoSMonitor
from repro.orchestration.rollout import CANARY, PROMOTED, ROLLED_BACK, RolloutManager


class Orchestrator:
    def __init__(
        self,
        churn: Optional[ChurnSchedule] = None,
        monitor: Optional[QoSMonitor] = None,
        rollout: Optional[RolloutManager] = None,
    ):
        if rollout is not None and monitor is None:
            raise ValueError(
                "a rollout needs a QoS monitor (its trip verdicts are what "
                "gate promotion)"
            )
        self.churn = churn
        self.monitor = monitor
        self.rollout = rollout
        self._cursor = 0
        self._audit = None  # repro.obs.AuditLog, injected via attach

    # ------------------------------------------------------ simulator hooks
    def attach(self, sim, tel, audit=None) -> None:
        n = sim.topology.n_cells
        self._cursor = 0
        self._audit = audit
        if self.churn is not None:
            for ev in self.churn.events:
                if ev.cell >= n:
                    raise ValueError(
                        f"churn event targets cell {ev.cell} in a {n}-cell fleet"
                    )
        if self.monitor is not None:
            self.monitor.reset(n)
        if self.rollout is not None:
            if max(self.rollout.canary_cells) >= n:
                raise ValueError(
                    f"canary cells {self.rollout.canary_cells} exceed the "
                    f"{n}-cell fleet"
                )
            self.rollout.reset()

    def on_window(self, sim, tel, window: int, t0: float) -> None:
        if self.churn is not None:
            due, self._cursor = self.churn.due(self._cursor, t0)
            for ev in due:
                sim.set_active(ev.cell, ev.kind == JOIN)
                tel.record_orchestration(
                    t0, f"churn_{ev.kind}", cell=ev.cell, scheduled_t_s=ev.t_s
                )
                if self._audit is not None:
                    self._audit.record(t0, "churn", f"churn_{ev.kind}",
                                       cell=int(ev.cell),
                                       scheduled_t_s=float(ev.t_s))
        if self.monitor is not None:
            result = self.monitor.observe(tel, t0)
            evidence = result.get("evidence", {})
            for c, metric in result["tripped"]:
                tel.record_orchestration(t0, "qos_trip", cell=int(c), metric=metric)
                if self._audit is not None:
                    self._audit.record(t0, "qos_monitor", "qos_trip",
                                       cell=int(c), **evidence.get(c, {}))
            for c in result["cleared"]:
                tel.record_orchestration(t0, "qos_clear", cell=int(c))
                if self._audit is not None:
                    self._audit.record(t0, "qos_monitor", "qos_clear",
                                       cell=int(c), **evidence.get(c, {}))
        if self.rollout is not None:
            before = self.rollout.state
            self.rollout.step(sim, tel, self.monitor, t0)
            after = self.rollout.state
            if self._audit is not None and after != before:
                rm = self.rollout
                if after == CANARY:
                    self._audit.record(
                        t0, "rollout_manager", "rollout_canary",
                        bank_version=rm.candidate.bank_version,
                        incumbent_version=rm.incumbent_version,
                        cells=list(rm.canary_cells))
                elif after == ROLLED_BACK:
                    self._audit.record(
                        t0, "rollout_manager", "rollout_rollback",
                        bank_version=rm.candidate.bank_version,
                        restored_version=rm.incumbent_version,
                        tripped=list(rm.tripped_canaries))
                elif after == PROMOTED:
                    self._audit.record(
                        t0, "rollout_manager", "rollout_promote",
                        bank_version=rm.candidate.bank_version)

    def finish(self, sim, tel, t_end: float) -> None:
        tel.record_orchestration(
            t_end, "finish",
            active_cells=int(sim.active_mask().sum()),
            shed_requests=int(sim.shed_counts.sum()),
            rollout_state=None if self.rollout is None else self.rollout.state,
        )
