"""Per-cell QoS monitoring against declared SLOs, with hysteresis.

The monitor watches `FleetTelemetry.cell_qos_estimate` -- the LIVE
trailing-window view the orchestrated simulator maintains (edge
completions exact, offloaded ones streamed through the incremental cloud
solve) -- and compares three tails against a `CellSLO`:

* ``p99_ms``             -- trailing-window p99 end-to-end latency;
* ``deadline_miss_rate`` -- share of completed requests past deadline;
* ``reliability_gap``    -- |on-device accuracy - mean p_tar|, the
                            paper's calibration contract, auditable at
                            the edge without the cloud;
* ``ece`` / ``coverage`` -- streaming calibration health (windowed
                            expected calibration error and on-device
                            precision) from the live reliability-bin
                            stream; ``ece_cap`` is a cap, while
                            ``coverage_floor`` trips when precision
                            drops BELOW the floor.

Hysteresis both ways: a cell TRIPS only after `trip_after` consecutive
violating windows and, once tripped, CLEARS only after `clear_after`
consecutive clean ones -- a single bad (or good) window moves nothing. A
window with fewer than ``min_requests`` resolved completions returns no
verdict and freezes both streaks: silence is not evidence of health, and
a drained cell must not clear a trip by being idle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: The SLO fields checked, in check order (first violation names the trip).
QOS_METRICS = (
    "p99_ms",
    "deadline_miss_rate",
    "reliability_gap",
    "reliability_shortfall",
    "ece",
    "coverage",
)
#: Metrics whose evidence is GATE samples (on-device label outcomes), not
#: completions -- judged against ``min_gate_samples`` instead.
_GATE_METRICS = ("reliability_gap", "reliability_shortfall")
#: Calibration metrics: evidence is the live calibration stream (every
#: gated request, offloaded ones included) -- judged against
#: ``min_gate_samples`` on the ``cal_samples`` count.
_CAL_METRICS = ("ece", "coverage")
#: Metrics where LOWER values violate the SLO (the cap is a floor).
_LOWER_IS_BAD = frozenset({"coverage"})
#: SLO field name per metric where they differ (the cap/floor naming).
_SLO_FIELD = {"ece": "ece_cap", "coverage": "coverage_floor"}


def _slo_threshold(slo: "CellSLO", metric: str) -> Optional[float]:
    return getattr(slo, _SLO_FIELD.get(metric, metric))


@dataclass(frozen=True)
class CellSLO:
    """Per-cell service-level objectives; None = unwatched metric.

    ``reliability_gap`` caps the symmetric |on-device accuracy - mean
    p_tar|; ``reliability_shortfall`` caps only the dangerous direction,
    max(0, mean p_tar - accuracy) -- over-delivering on the contract is
    never an incident. Reliability verdicts need ``min_gate_samples``
    on-device label outcomes in the window (a handful of gate samples
    cannot audit an accuracy contract); the latency/deadline verdicts
    need ``min_requests`` resolved completions."""

    p99_ms: Optional[float] = None
    deadline_miss_rate: Optional[float] = None
    reliability_gap: Optional[float] = None
    reliability_shortfall: Optional[float] = None
    #: calibration-health SLOs (streaming reliability-sketch gauges):
    #: ``ece_cap`` caps the windowed expected calibration error;
    #: ``coverage_floor`` is a FLOOR -- the on-device precision (share of
    #: kept answers that were correct) dropping BELOW it trips.
    ece_cap: Optional[float] = None
    coverage_floor: Optional[float] = None
    min_requests: int = 20  # fewer resolved completions -> no verdict
    min_gate_samples: Optional[int] = None  # default: min_requests

    def __post_init__(self):
        if all(_slo_threshold(self, m) is None for m in QOS_METRICS):
            raise ValueError("an SLO must watch at least one metric")
        if self.min_requests < 1:
            raise ValueError("min_requests must be >= 1")
        if self.min_gate_samples is not None and self.min_gate_samples < 1:
            raise ValueError("min_gate_samples must be >= 1")


@dataclass(frozen=True)
class QoSConfig:
    window_s: float = 2.0  # trailing evidence window per check
    trip_after: int = 2  # consecutive violating windows before a trip
    clear_after: int = 4  # consecutive clean windows before a clear

    def __post_init__(self):
        if self.window_s <= 0:
            raise ValueError("window_s must be positive")
        if self.trip_after < 1 or self.clear_after < 1:
            raise ValueError("trip_after/clear_after must be >= 1")


class QoSMonitor:
    """Trip/clear state machine per cell. `reset(n_cells)` arms it for a
    run (the Orchestrator calls it on attach); `observe(tel, now)` is one
    evaluation pass over every watched cell."""

    def __init__(
        self,
        slo: CellSLO,
        config: Optional[QoSConfig] = None,
        cells: Optional[Sequence[int]] = None,
    ):
        self.slo = slo
        self.config = config or QoSConfig()
        #: None = watch every cell; otherwise the watched subset
        self.cells = None if cells is None else tuple(int(c) for c in cells)
        self.reset(0)

    def reset(self, n_cells: int) -> None:
        self._n = n_cells
        self._bad = np.zeros(n_cells, np.int64)
        self._good = np.zeros(n_cells, np.int64)
        self._tripped = np.zeros(n_cells, bool)
        self.trip_log: List[Tuple[float, int, str]] = []
        self.clear_log: List[Tuple[float, int]] = []

    # ------------------------------------------------------------- queries
    def is_tripped(self, cell: int) -> bool:
        return bool(self._tripped[cell])

    def tripped_cells(self) -> np.ndarray:
        return np.flatnonzero(self._tripped)

    def tripped_mask(self) -> np.ndarray:
        """Boolean per-cell trip state -- the distress signal the fleet
        controller consumes (`FleetController.update(distressed=...)`)."""
        return self._tripped.copy()

    def violation(self, qos: Dict[str, float]) -> Optional[str]:
        """One window's verdict: None = no verdict (no watched metric had
        enough evidence), '' = clean, otherwise the name of the first
        violated metric. Each metric is judged only when its OWN evidence
        suffices -- completions for the latency/deadline SLOs, on-device
        gate samples for the reliability ones."""
        slo = self.slo
        min_gate = (
            slo.min_requests
            if slo.min_gate_samples is None
            else slo.min_gate_samples
        )
        judged = False
        for metric in QOS_METRICS:
            cap = _slo_threshold(slo, metric)
            if cap is None:
                continue
            if metric in _GATE_METRICS:
                if qos.get("gate_samples", 0) < min_gate:
                    continue
            elif metric in _CAL_METRICS:
                if qos.get("cal_samples", 0) < min_gate:
                    continue
            elif qos["requests"] < slo.min_requests:
                continue
            judged = True
            v = qos[metric]
            if not np.isfinite(v):
                continue
            bad = v < cap if metric in _LOWER_IS_BAD else v > cap
            if bad:
                return metric
        return "" if judged else None

    # ------------------------------------------------------------- observe
    def observe(self, tel, now: float) -> Dict[str, list]:
        """Evaluate every watched cell's trailing window at `now` ->
        {"tripped": [(cell, metric), ...], "cleared": [cell, ...],
        "evidence": {cell: {...}}} for the transitions THIS pass caused
        (already-tripped cells staying bad report nothing). `evidence`
        carries, per transitioning cell, the windowed metric value, the
        SLO cap, and the streak that crossed the hysteresis threshold --
        the audit trail a trip must be reconstructible from."""
        watch = range(self._n) if self.cells is None else self.cells
        tripped: List[Tuple[int, str]] = []
        cleared: List[int] = []
        evidence: Dict[int, Dict] = {}
        for c in watch:
            qos = tel.cell_qos_estimate(c, self.config.window_s, now)
            verdict = self.violation(qos)
            if verdict is None:
                continue
            if verdict:
                self._bad[c] += 1
                self._good[c] = 0
                if not self._tripped[c] and self._bad[c] >= self.config.trip_after:
                    self._tripped[c] = True
                    tripped.append((c, verdict))
                    self.trip_log.append((now, c, verdict))
                    ev = {
                        "metric": verdict,
                        "value": float(qos[verdict]),
                        "cap": float(_slo_threshold(self.slo, verdict)),
                        "op": "<" if verdict in _LOWER_IS_BAD else ">",
                        "bad_streak": int(self._bad[c]),
                        "requests": int(qos["requests"]),
                        "gate_samples": int(qos["gate_samples"]),
                    }
                    if verdict in _CAL_METRICS:
                        ev["cal_samples"] = int(qos.get("cal_samples", 0))
                        bins = qos.get("cal_bins") or []
                        # the offending bins: largest count-weighted
                        # conf-vs-acc residuals, the reliability-diagram
                        # evidence an operator reconstructs the trip from
                        ev["bins"] = sorted(
                            bins,
                            key=lambda b: -b["count"] * abs(b["residual"]),
                        )[:3]
                    evidence[c] = ev
            else:
                self._good[c] += 1
                self._bad[c] = 0
                if self._tripped[c] and self._good[c] >= self.config.clear_after:
                    self._tripped[c] = False
                    cleared.append(c)
                    self.clear_log.append((now, c))
                    evidence[c] = {"good_streak": int(self._good[c])}
        return {"tripped": tripped, "cleared": cleared, "evidence": evidence}
