"""QoS-gated canary rollout of versioned `PlanBank`s.

The rollout manager is a four-state machine driven once per simulator
window:

    IDLE --(t >= start_at_s)--> CANARY: the candidate bank's gate table
        (built by `table_factory`, so it serves the exact same data as
        the incumbent) is installed on the k canary cells only;
    CANARY --(any canary cell QoS-tripped)--> ROLLED_BACK: every
        override is removed; the fleet is back on the incumbent;
    CANARY --(promote_after consecutive windows with no canary cell
        tripped)--> PROMOTED: the candidate table is installed
        fleet-wide.

Versions are monotonic: the candidate's ``bank_version`` must exceed the
incumbent's (`PlanBank.bumped` mints the next generation). Everything is
deterministic -- the same candidate, SLO, and workload replay the same
promotion or rollback at the same window.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.bank import PlanBank

IDLE = "idle"
CANARY = "canary"
PROMOTED = "promoted"
ROLLED_BACK = "rolled_back"


class RolloutManager:
    def __init__(
        self,
        candidate: PlanBank,
        table_factory: Callable[[PlanBank], object],
        canary_cells: Sequence[int],
        promote_after: int = 8,
        start_at_s: float = 0.0,
        incumbent_version: int = 0,
    ):
        if candidate.bank_version <= incumbent_version:
            raise ValueError(
                f"candidate bank_version {candidate.bank_version} must exceed "
                f"the incumbent's {incumbent_version} (versions are monotonic)"
            )
        if not canary_cells:
            raise ValueError("need at least one canary cell")
        if promote_after < 1:
            raise ValueError("promote_after must be >= 1")
        self.candidate = candidate
        self.table_factory = table_factory
        self.canary_cells: Tuple[int, ...] = tuple(int(c) for c in canary_cells)
        self.promote_after = int(promote_after)
        self.start_at_s = float(start_at_s)
        self.incumbent_version = int(incumbent_version)
        self.reset()

    def reset(self) -> None:
        self.state = IDLE
        self._table = None
        self._clear_windows = 0
        self.started_at: Optional[float] = None
        self.promoted_at: Optional[float] = None
        self.rolled_back_at: Optional[float] = None
        self.tripped_canaries: List[int] = []

    # ---------------------------------------------------------------- step
    def step(self, sim, tel, monitor, now: float) -> None:
        """One window boundary. `monitor` must have been observed for this
        boundary already (the Orchestrator orders it so)."""
        if self.state == IDLE:
            if now >= self.start_at_s:
                self._table = self.table_factory(self.candidate)
                for c in self.canary_cells:
                    sim.set_cell_table(c, self._table)
                self.state = CANARY
                self.started_at = now
                tel.record_orchestration(
                    now, "rollout_canary",
                    bank_version=self.candidate.bank_version,
                    incumbent_version=self.incumbent_version,
                    cells=list(self.canary_cells),
                )
        elif self.state == CANARY:
            bad = [c for c in self.canary_cells if monitor.is_tripped(c)]
            if bad:
                for c in self.canary_cells:
                    sim.set_cell_table(c, None)
                self.state = ROLLED_BACK
                self.rolled_back_at = now
                self.tripped_canaries = bad
                tel.record_orchestration(
                    now, "rollout_rollback",
                    bank_version=self.candidate.bank_version,
                    restored_version=self.incumbent_version,
                    tripped=bad,
                )
            else:
                self._clear_windows += 1
                if self._clear_windows >= self.promote_after:
                    for c in range(sim.topology.n_cells):
                        sim.set_cell_table(c, self._table)
                    self.state = PROMOTED
                    self.promoted_at = now
                    tel.record_orchestration(
                        now, "rollout_promote",
                        bank_version=self.candidate.bank_version,
                    )
        # PROMOTED / ROLLED_BACK are terminal for one run
