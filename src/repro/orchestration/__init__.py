"""Live orchestration plane over the fleet simulator.

The fleet of `repro.fleet` survives load; this package makes it survive
*operations*. An `Orchestrator` rides the simulator's window loop and
drives three event sources against it:

* `churn`    -- `ChurnSchedule`: seeded join/leave events flip per-cell
               activation mid-run; a dead cell's arrivals shed to the
               nearest live ring neighbor (or the cloud backhaul);
* `qos`      -- `QoSMonitor`: per-cell trailing-window p99 / deadline-miss
               / reliability-gap checks against a declared `CellSLO`,
               with trip/clear hysteresis, fed from the simulator's LIVE
               completion view;
* `rollout`  -- `RolloutManager`: a versioned `PlanBank` candidate
               canaries on k cells and promotes fleet-wide only after m
               consecutive clear QoS windows -- any canary trip rolls the
               fleet back to the incumbent;
* `scenarios`-- the `@register_scenario` registry of adversarial
               stressors (weather fronts, flash crowds, link outages,
               cloud brownouts, poisoned canaries) that `benchmarks/run.py`
               sweeps into ``BENCH_fleet.json``.

Everything is seeded and deterministic: the same schedule, SLO, and
candidate bank replay the same trips, rollbacks, and telemetry. With no
churn events and no rollout the orchestrated simulator is bit-identical
to the unorchestrated one (the final metrics still come from the exact
deferred cloud solve; the live view only feeds the monitor).
"""
from repro.orchestration.churn import JOIN, LEAVE, ChurnEvent, ChurnSchedule
from repro.orchestration.plane import Orchestrator
from repro.orchestration.qos import CellSLO, QoSConfig, QoSMonitor
from repro.orchestration.rollout import (
    CANARY,
    IDLE,
    PROMOTED,
    ROLLED_BACK,
    RolloutManager,
)
from repro.orchestration.scenarios import (
    SCENARIOS,
    poisoned_bank,
    register_scenario,
    run_scenarios,
)

__all__ = [
    "JOIN",
    "LEAVE",
    "ChurnEvent",
    "ChurnSchedule",
    "Orchestrator",
    "CellSLO",
    "QoSConfig",
    "QoSMonitor",
    "IDLE",
    "CANARY",
    "PROMOTED",
    "ROLLED_BACK",
    "RolloutManager",
    "SCENARIOS",
    "poisoned_bank",
    "register_scenario",
    "run_scenarios",
]
