"""Reference fleet scenarios shared by the acceptance tests and the
fleet benchmark, so the numbers CI asserts on and the numbers the tests
pin down come from the same construction.

`reference_fleet` scales the ISSUE 3 drift scenario out to C cells: the
same `synthetic_distorted_cascade` data and plans, but each cell gets its
own uplink (a heterogeneous mix of the paper's nominal fixed link, a
degraded fixed link, and the congested Markov Wi-Fi of the serving
bench) and its own Markov severity schedule (per-cell seeds -- weather is
not synchronized across sites). All cells feed one shared cloud tier.

`run_fleet` serves a plan/bank over that topology, optionally with the
`FleetController` re-scoring every cell each second under the shared
cloud cap -- the fleet-scale analogue of `run_distortion_drift`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.gatepath import GateTable, get_gate_backend
from repro.fleet.controller import FleetController, FleetControllerConfig
from repro.fleet.simulator import FleetConfig, FleetSimulator
from repro.fleet.telemetry import FleetTelemetry
from repro.fleet.topology import CellConfig, FleetTopology, poisson_cell_workload
from repro.offload import latency as L
from repro.serving.drift import MarkovContextSchedule
from repro.serving.network import FixedRateNetwork, MarkovNetwork
from repro.serving.scenarios import drift_contexts


def cell_network(i: int, nominal_bps: float = 18.8e6):
    """The reference heterogeneous link mix: most cells keep the paper's
    nominal Wi-Fi, one in eight runs a degraded fixed link, and one in
    eight the mostly-bad Markov chain of the serving bench. The minority
    of congested cells is the point: a fleet controller can concede
    latency-for-reliability trades *locally* (only where the link demands
    it) while the healthy majority keeps the full calibration win --
    which no fleet-wide static configuration can do."""
    kind = i % 16
    if kind == 3:
        return FixedRateNetwork(8e6)
    if kind == 11:
        return MarkovNetwork(
            good_bps=nominal_bps, bad_bps=1.5e6,
            p_good_to_bad=0.5, p_bad_to_good=0.2,
            dwell_s=1.0, seed=1000 + i, start_state=1,
        )
    return FixedRateNetwork(nominal_bps)


@dataclass
class FleetScenario:
    topology: FleetTopology
    val: dict
    test: dict
    contexts: List[str]


def reference_fleet(
    n_cells: int = 64,
    requests_per_cell: int = 1600,
    arrival_rate_hz: float = 20.0,
    deadline_s: float = 0.1,
    n_devices: int = 2,
    dwell_s: float = 3.0,
    cloud_servers: int = 4,
    seed: int = 0,
    val: Optional[dict] = None,
    test: Optional[dict] = None,
) -> FleetScenario:
    """The reference C-cell topology over the ISSUE 3 drift data, with one
    twist: blur drifts UNDERCONFIDENT (the direction the trained model of
    ``examples/offload_under_distortion.py`` exhibits) while noise and
    contrast stay overconfident. Under drift both directions coexist in
    one fleet, and a clean-fit uncalibrated plan loses on both axes: the
    overconfident regimes break its reliability, the underconfident one
    floods its uplinks."""
    if val is None or test is None:
        from repro.serving.scenarios import synthetic_distorted_cascade

        val, test = synthetic_distorted_cascade(
            seed=seed, directions={"gaussian_blur": "under"}
        )
    keys = [spec.key for spec in drift_contexts()]
    n_samples = len(test["labels"])
    cells = []
    for i in range(n_cells):
        cells.append(
            CellConfig(
                network=cell_network(i),
                workload=poisson_cell_workload(
                    arrival_rate_hz, requests_per_cell, n_samples,
                    n_devices=n_devices, seed=seed + 200 + i,
                ),
                n_devices=n_devices,
                schedule=MarkovContextSchedule(
                    keys, dwell_s=dwell_s, p_stay=0.5, seed=seed + 100 + i,
                    start_context="clean",
                ),
                deadline_s=deadline_s,
            )
        )
    return FleetScenario(
        topology=FleetTopology(cells, cloud_servers=cloud_servers),
        val=val, test=test, contexts=keys,
    )


def fleet_gate_table(plan_or_bank, scenario: FleetScenario, backend=None) -> GateTable:
    """The scenario's dense gate table for a plan/bank -- the shared
    construction `run_fleet` uses, exposed so orchestration scenarios can
    build CANDIDATE tables (same data, different bank) for rollout."""
    test = scenario.test
    return GateTable(
        test["exit_logits"], test["final"], plan_or_bank,
        labels=test["labels"], features_by_context=test.get("features"),
        backend=backend,
    )


def run_fleet(
    plan_or_bank,
    scenario: FleetScenario,
    with_controller: bool = False,
    window_s: float = 0.5,
    controller_config: Optional[FleetControllerConfig] = None,
    profile: Optional[L.LatencyProfile] = None,
    backend=None,
    orchestrator=None,
    fleet_config: Optional[FleetConfig] = None,
    obs=None,
) -> FleetTelemetry:
    """Serve the scenario's test split with a plan or expert bank.

    The gate table precomputes per-(context, expert, branch) blocks once;
    `with_controller` adds the fleet controller re-scoring every cell's
    (branch, p_tar) from its windowed telemetry under the shared cloud
    cap, fit on the CLEAN validation logits exactly as the single-cell
    controller in `run_distortion_drift`. `backend` selects the gate
    execution path (`repro.core.gatepath`: host numpy default, or the
    jitted ``"jax"`` window gate). `orchestrator` attaches an
    orchestration plane (`repro.orchestration`) driving churn, QoS
    monitoring, and rollouts; `fleet_config` overrides the simulator
    config (e.g. cloud brownout intervals) and wins over `window_s`.
    `obs` attaches a `repro.obs.Observability` bundle (sampled traces,
    decision audit log, metrics); None (the default) is zero-perturbation.

    backend="compiled" runs the whole window pipeline device-side as one
    jitted program (`repro.fleet.compiled.CompiledFleetSimulator`,
    parity-pinned against the host simulator); it serves static
    deployments only, so it rejects `with_controller` and rollouts.
    """
    profile = profile or L.paper_2020()
    val = scenario.val
    table = fleet_gate_table(plan_or_bank, scenario, backend=backend)
    controller = None
    if with_controller:
        controller = FleetController(
            plan_or_bank, profile,
            val["exit_logits"],  # per-context: the mix-weighted re-score
            n_cells=scenario.topology.n_cells,
            final_logits=val["final"], labels=val["labels"],
            cloud_servers=scenario.topology.cloud_servers,
            config=controller_config
            or FleetControllerConfig(
                interval_s=1.0, window_s=2.0,
                p_tar_grid=(0.3, 0.5, 0.7, 0.8), min_accuracy=0.8,
                cloud_rho_max=0.9,
            ),
        )
    sim_cls = FleetSimulator
    if get_gate_backend(backend).name == "compiled":
        from repro.fleet.compiled import CompiledFleetSimulator

        sim_cls = CompiledFleetSimulator
    sim = sim_cls(
        table, scenario.topology, profile,
        config=fleet_config or FleetConfig(window_s=window_s),
        controller=controller, orchestrator=orchestrator, obs=obs,
    )
    return sim.run()
