"""Fleet-level offload controller: fleet policy over the shared core.

The candidate-table construction, mix-weighted context-aware re-scoring,
feasibility rules, and the distress-gated p_tar concession all live in
`repro.core.control` and are shared with the event runtime's
`OnlineController`. What remains here is genuinely fleet-scale policy:
every cell sees a different link and arrival rate, and all cells share
one cloud tier -- a re-score that is locally optimal per cell can
collectively saturate the cloud. `FleetController` therefore runs the
shared re-score per cell (same calibrators, same candidate table,
per-cell measured bandwidth/arrivals/traffic mix from the windowed fleet
telemetry) and then applies a shared-cloud pass: while the aggregate
cloud utilization

    rho = sum_c arrival_c * offload_prob_c * cloud_time(branch_c) / K

exceeds ``cloud_rho_max``, the heaviest-contributing cell is moved to its
least-cloud-hungry accuracy-feasible candidate. Cells the simulator never
saw transfer fall back to the profile's nominal uplink, mirroring the
single-cell controller's cold-start rule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.control import (
    ControlConfig,
    ControllerCore,
    choose_with_concession,
    row_feasible,
)
from repro.offload import latency as L


@dataclass
class FleetControllerConfig(ControlConfig):
    """The shared control knobs (`repro.core.control.ControlConfig`) plus
    the shared-cloud utilization cap. The concession threshold
    ``distress_utilization`` is inherited: a cell holds the PLAN's p_tar
    (moving only its branch) while any candidate at full p_tar keeps its
    uplink stable, and otherwise makes the WEAKEST concession -- the
    highest p_tar whose offload traffic fits the measured link -- rather
    than the latency-greedy one."""

    cloud_rho_max: Optional[float] = 0.9  # shared-cloud utilization cap


class FleetController:
    """Per-cell (branch, p_tar) decisions under a shared cloud budget.

    Construction mirrors `OnlineController` (held-out validation logits
    per physical branch, optional labels/final logits for the accuracy
    floor); a `PlanBank` contributes its default plan, composing with the
    per-sample expert selection inside the gate table exactly as in the
    event runtime. ``cloud_servers`` is the shared tier's parallelism --
    the denominator of the utilization cap.

    `exit_logits` is either ``{branch: (N, C)}`` -- the context-blind
    form -- or ``{context: {branch: (N, C)}}`` with matching
    `final_logits` per context, which makes the re-score CONTEXT-AWARE:
    each tick, every cell's candidate table is computed with the
    validation samples weighted by that cell's estimated traffic mix over
    the trailing window (`FleetTelemetry.context_mix_estimate`), so
    offload probabilities and accuracies price the drifting inputs the
    cell is actually serving. A context-blind controller under drift can
    badly underestimate a candidate's offload traffic (clean inputs gate
    confidently; distorted ones do not) and leave a distressed cell
    saturated -- the rescoring the event runtime's `OnlineController`
    now shares.
    """

    def __init__(
        self,
        plan,
        profile: L.LatencyProfile,
        exit_logits: Dict,
        n_cells: int,
        final_logits=None,
        labels: Optional[np.ndarray] = None,
        cloud_servers: int = 1,
        config: Optional[FleetControllerConfig] = None,
        payload_nbytes=None,
    ):
        cfg = config or FleetControllerConfig()
        self.core = ControllerCore(
            plan, profile, exit_logits,
            final_logits=final_logits, labels=labels,
            payload_nbytes=payload_nbytes,
            compression_levels=cfg.compression_levels,
        )
        self.plan = self.core.plan
        self.profile = profile
        self.n_cells = n_cells
        self.cloud_servers = cloud_servers
        self.config = cfg
        if (
            self.config.p_tar_grid is not None
            and self.plan.p_tar not in self.config.p_tar_grid
        ):
            # the contract-holding stage of choose_with_concession matches
            # rows at the PLAN's p_tar; a grid omitting it would silently
            # treat every cell as distressed, so always keep it available
            self.config = FleetControllerConfig(
                **{**self.config.__dict__,
                   "p_tar_grid": tuple(self.config.p_tar_grid)
                   + (self.plan.p_tar,)}
            )
        self.history: List[Tuple[float, List[Tuple[int, float, int]]]] = []
        #: optional repro.obs.AuditLog (injected by `run_fleet(obs=...)` /
        #: FleetSimulator); records per-cell rescore evidence + decisions
        self.audit = None
        self._last_decisions: Optional[List[Tuple[int, float, int]]] = None

    @property
    def branches(self) -> List[int]:
        return self.core.branches

    @property
    def ctx_keys(self) -> List[Optional[str]]:
        return self.core.ctx_keys

    @property
    def interval_s(self) -> float:
        return self.config.interval_s

    # ------------------------------------------------------------- update
    def _cell_mix(self, telemetry, c: int, t: float) -> Optional[Dict[str, float]]:
        """This cell's trailing-window traffic mix as {context: share};
        None when context-blind or nothing recognizable was observed."""
        if not self.core.context_aware:
            return None
        raw = telemetry.context_mix_estimate(c, self.config.window_s, now=t)
        if raw is None:
            return None
        return dict(zip(telemetry.context_keys, np.asarray(raw, np.float64)))

    def update(
        self, t: float, telemetry, active=None, distressed=None
    ) -> List[Tuple[int, float, int]]:
        """-> per-cell (physical branch, p_tar, compression_level)
        decisions.

        `active` (orchestrated runs): a (C,) bool mask; a DOWN cell is not
        re-scored -- its telemetry window mixes its own last traffic with
        shed service on other cells' links -- and instead parks at the
        plan's deployment, the state it must come back up in. It also
        contributes zero load to the shared-cloud pass (its arrivals are
        priced on the host cell that serves them).

        `distressed` (orchestrated runs with a QoS monitor): a (C,) bool
        mask of cells whose declared SLO is TRIPPED. A distressed cell
        stops holding the contract p_tar and takes the fastest stable
        feasible candidate (`choose_with_concession(force_concession=
        True)`) until the monitor clears it -- the trip verdict IS the
        distress signal, not a second utilization heuristic."""
        cfg = self.config
        chosen_rows, tables, rates, inputs = [], [], [], []
        for c in range(self.n_cells):
            if active is not None and not active[c]:
                chosen_rows.append(None)
                tables.append(None)
                rates.append(0.0)
                inputs.append(None)
                continue
            bw = telemetry.bandwidth_estimate(c, cfg.window_s, now=t)
            if bw is None:
                bw = self.profile.uplink_bps  # nothing measured: trust nominal
            rate_hz = (
                telemetry.arrival_rate_estimate(c, cfg.window_s, now=t)
                if cfg.utilization_aware
                else None
            )
            _, table = self.core.rescore(
                self.plan,
                uplink_bps=bw,
                arrival_rate_hz=rate_hz,
                p_tar_grid=cfg.p_tar_grid,
                branches=cfg.branches,
                min_accuracy=cfg.min_accuracy,
                max_reliability_gap=cfg.max_reliability_gap,
                sample_weight=self.core.sample_weight_for_mix(
                    self._cell_mix(telemetry, c, t)
                ),
            )
            force = bool(distressed is not None and distressed[c])
            chosen_rows.append(
                choose_with_concession(
                    table, self.plan.p_tar, cfg.distress_utilization,
                    min_accuracy=cfg.min_accuracy,
                    max_reliability_gap=cfg.max_reliability_gap,
                    force_concession=force,
                )
            )
            tables.append(table)
            rates.append(rate_hz or 0.0)
            inputs.append({"bandwidth_bps": float(bw),
                           "arrival_rate_hz": None if rate_hz is None
                           else float(rate_hz),
                           "distressed": force})

        if cfg.cloud_rho_max is not None:
            chosen_rows = self._shared_cloud_pass(chosen_rows, tables, rates)

        hold = (
            self.plan.exit_index + 1,
            float(self.plan.p_tar),
            int(getattr(self.plan, "compression_level", 0)),
        )
        decisions = [
            hold if r is None
            else (r["exit_index"] + 1, float(r["p_tar"]),
                  int(r.get("compression_level", 0)))
            for r in chosen_rows
        ]
        if self.audit is not None:
            self._audit_decisions(t, decisions, chosen_rows, inputs)
        self._last_decisions = decisions
        self.history.append((t, decisions))
        return decisions

    def _audit_decisions(self, t, decisions, chosen_rows, inputs) -> None:
        """One audit record per cell whose decision changed or that is
        under QoS distress -- the evidence (measured inputs + chosen
        candidate row) a concession must be reconstructible from."""
        prev = self._last_decisions
        for c, (d, row, inp) in enumerate(zip(decisions, chosen_rows, inputs)):
            if inp is None:
                continue  # parked (inactive) cell: no rescore happened
            changed = prev is None or prev[c] != d
            if not (changed or inp["distressed"]):
                continue
            chosen = {"branch": int(d[0]), "p_tar": float(d[1]),
                      "compression_level": int(d[2])}
            if row is not None:
                chosen.update(
                    offload_prob=float(row["offload_prob"]),
                    expected_latency_s=float(row["expected_latency_s"]),
                    uplink_utilization=float(row["uplink_utilization"]),
                )
            self.audit.record(
                t, "fleet_controller", "controller_rescore", cell=c,
                changed=bool(changed), chosen=chosen, **inp)

    # ---------------------------------------------------- shared-cloud cap
    def _feasible(self, row) -> bool:
        return row_feasible(
            row, self.config.min_accuracy, self.config.max_reliability_gap
        )

    def _cloud_load(self, row, rate_hz: float) -> float:
        return (
            rate_hz * row["offload_prob"]
            * self.core.cloud_times_s[row["exit_index"]]
        )

    def _shared_cloud_pass(self, chosen, tables, rates):
        """Demote the heaviest cloud contributors until the shared tier's
        utilization fits under the cap (or no feasible demotion remains)."""
        cap = self.config.cloud_rho_max * self.cloud_servers
        loads = [
            0.0 if r is None else self._cloud_load(r, rate)
            for r, rate in zip(chosen, rates)
        ]
        frozen = {c for c, r in enumerate(chosen) if r is None}
        while sum(loads) > cap:
            order = sorted(
                (c for c in range(self.n_cells) if c not in frozen),
                key=lambda c: loads[c],
                reverse=True,
            )
            moved = False
            for c in order:
                alts = [
                    r for r in tables[c]
                    if self._feasible(r)
                    and self._cloud_load(r, rates[c]) < loads[c]
                ]
                if not alts:
                    frozen.add(c)
                    continue
                # least cloud-hungry feasible candidate; latency breaks ties
                best = min(
                    alts,
                    key=lambda r: (
                        self._cloud_load(r, rates[c]),
                        r["expected_latency_s"],
                    ),
                )
                chosen[c] = best
                loads[c] = self._cloud_load(best, rates[c])
                frozen.add(c)
                moved = True
                break
            if not moved:
                break
        return chosen
