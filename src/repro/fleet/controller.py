"""Fleet-level offload controller.

`repro.serving.controller.OnlineController` re-scores ONE cell's deployed
(branch, p_tar) against its measured uplink. At fleet scale two things
change: every cell sees a different link and arrival rate, and all cells
share one cloud tier -- a re-score that is locally optimal per cell can
collectively saturate the cloud. `FleetController` therefore runs the
same Edgent-style `rescore_plan` per cell (same calibrators, same
candidate table, per-cell measured bandwidth/arrivals from the windowed
fleet telemetry) and then applies a shared-cloud pass: while the
aggregate cloud utilization

    rho = sum_c arrival_c * offload_prob_c * cloud_time(branch_c) / K

exceeds ``cloud_rho_max``, the heaviest-contributing cell is moved to its
least-cloud-hungry accuracy-feasible candidate. Cells the simulator never
saw transfer fall back to the profile's nominal uplink, mirroring the
single-cell controller's cold-start rule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import rescore_plan
from repro.offload import latency as L


@dataclass
class FleetControllerConfig:
    interval_s: float = 1.0  # re-score cadence (must be a multiple of window_s)
    window_s: float = 2.0  # trailing telemetry window per cell
    p_tar_grid: Optional[Sequence[float]] = None  # None = keep the plan's
    min_accuracy: Optional[float] = None  # accuracy floor for candidates
    utilization_aware: bool = True  # per-cell M/M/1 uplink correction
    cloud_rho_max: Optional[float] = 0.9  # shared-cloud utilization cap
    distress_utilization: float = 0.95  # uplink rho above which a cell may
    # concede p_tar: the reliability target is the operator's contract, so a
    # cell holds the PLAN's p_tar (moving only its branch) while any
    # candidate at full p_tar keeps its uplink stable, and otherwise makes
    # the WEAKEST concession -- the highest p_tar whose offload traffic
    # fits the measured link -- rather than the latency-greedy one


class FleetController:
    """Per-cell (branch, p_tar) decisions under a shared cloud budget.

    Construction mirrors `OnlineController` (held-out validation logits
    per physical branch, optional labels/final logits for the accuracy
    floor); a `PlanBank` contributes its default plan, composing with the
    per-sample expert selection inside the gate table exactly as in the
    event runtime. ``cloud_servers`` is the shared tier's parallelism --
    the denominator of the utilization cap.

    `exit_logits` is either ``{branch: (N, C)}`` -- the single-cell
    controller's context-blind stats -- or ``{context: {branch: (N, C)}}``
    with matching `final_logits` per context, which makes the re-score
    CONTEXT-AWARE: each tick, every cell's candidate table is computed
    with the validation samples weighted by that cell's estimated traffic
    mix over the trailing window (`FleetTelemetry.context_mix_estimate`),
    so offload probabilities and accuracies price the drifting inputs the
    cell is actually serving. A context-blind controller under drift can
    badly underestimate a candidate's offload traffic (clean inputs gate
    confidently; distorted ones do not) and leave a distressed cell
    saturated -- the ROADMAP's "context-aware controller" item.
    """

    def __init__(
        self,
        plan,
        profile: L.LatencyProfile,
        exit_logits: Dict,
        n_cells: int,
        final_logits=None,
        labels: Optional[np.ndarray] = None,
        cloud_servers: int = 1,
        config: Optional[FleetControllerConfig] = None,
        payload_nbytes=None,
    ):
        from repro.core.bank import PlanBank

        if isinstance(plan, PlanBank):
            plan = plan.default_plan
        if plan.criterion != "confidence":
            raise ValueError(
                "FleetController re-scores the confidence target p_tar; "
                f"{plan.criterion!r}-criterion plans are not re-scorable"
            )
        self.plan = plan
        self.profile = profile
        self.n_cells = n_cells
        self.cloud_servers = cloud_servers
        self.config = config or FleetControllerConfig()
        if (
            self.config.p_tar_grid is not None
            and plan.p_tar not in self.config.p_tar_grid
        ):
            # the contract-holding stage of _choose_cell matches rows at
            # the PLAN's p_tar; a grid omitting it would silently treat
            # every cell as distressed, so always keep it available
            self.config = FleetControllerConfig(
                **{**self.config.__dict__,
                   "p_tar_grid": tuple(self.config.p_tar_grid) + (plan.p_tar,)}
            )

        # normalize to {context: {branch: logits}}; None key = context-blind
        if all(isinstance(k, str) for k in exit_logits):
            by_ctx = {k: exit_logits[k] for k in sorted(exit_logits)}
            if final_logits is not None and not isinstance(final_logits, dict):
                raise ValueError(
                    "per-context exit_logits need per-context final_logits"
                )
            final_by_ctx = final_logits
        else:
            by_ctx = {None: exit_logits}
            final_by_ctx = None if final_logits is None else {None: final_logits}
        self.ctx_keys = list(by_ctx)
        first = next(iter(by_ctx.values()))
        self.branches = sorted(first)
        if self.branches != list(range(1, len(self.branches) + 1)):
            raise ValueError(
                "exit_logits keys must be contiguous physical branches 1..K; "
                f"got {self.branches}"
            )
        for ctx, per_branch in by_ctx.items():
            if sorted(per_branch) != self.branches:
                raise ValueError(f"context {ctx!r} covers different branches")

        self.labels = None if labels is None else np.asarray(labels)
        if payload_nbytes is None:
            from repro.models.convnet import payload_bytes

            payload_nbytes = payload_bytes
        self.payload_bytes = [payload_nbytes(b) for b in self.branches]
        self.edge_times_s = [L.edge_time(profile, b) for b in self.branches]
        self.cloud_times_s = [L.cloud_time(profile, b) for b in self.branches]

        # calibrated (conf, pred) never change between ticks: compute once
        # per (context, branch), concatenated in ctx_keys order so a tick
        # only supplies per-sample weights
        self._block_len = [len(next(iter(by_ctx[k].values()))) for k in self.ctx_keys]
        self.exit_logits_list = [
            np.concatenate([np.asarray(by_ctx[k][b]) for k in self.ctx_keys])
            for b in self.branches
        ]
        self._exit_stats = []
        for bi, b in enumerate(self.branches):
            stats = [plan.gate_block(by_ctx[k][b], branch=bi) for k in self.ctx_keys]
            self._exit_stats.append(
                (np.concatenate([c for c, _ in stats]),
                 np.concatenate([p for _, p in stats]))
            )
        if self.labels is not None:
            self._labels_cat = np.concatenate(
                [self.labels for _ in self.ctx_keys]
            )
        else:
            self._labels_cat = None
        if final_by_ctx is not None:
            missing = set(self.ctx_keys) - set(final_by_ctx)
            if missing:
                raise ValueError(f"final_logits missing contexts {sorted(missing)}")
            self._final_cat = np.concatenate(
                [np.asarray(final_by_ctx[k]) for k in self.ctx_keys]
            )
        else:
            self._final_cat = None
        self.history: List[Tuple[float, List[Tuple[int, float]]]] = []

    @property
    def interval_s(self) -> float:
        return self.config.interval_s

    # ------------------------------------------------------------- update
    def _feasible(self, row) -> bool:
        floor = self.config.min_accuracy
        return floor is None or (
            row["accuracy"] is not None and row["accuracy"] >= floor
        )

    def _choose_cell(self, table) -> dict:
        """Pick one cell's row from its re-scored candidate table.

        1. If an accuracy-feasible candidate at the PLAN's p_tar keeps the
           uplink under the distress threshold, take the fastest such row
           (the branch is the only knob, as in the single-cell scenario).
        2. Otherwise the link cannot carry full-p_tar traffic: make the
           weakest reliability concession -- among stable feasible rows,
           the highest p_tar, fastest within it.
        3. No stable row at all: fastest feasible; no feasible row: most
           accurate (the `rescore_plan` degradation rule).
        """
        rho = self.config.distress_utilization
        feasible = [r for r in table if self._feasible(r)]
        full = [
            r for r in feasible
            if r["p_tar"] == self.plan.p_tar and r["uplink_utilization"] < rho
        ]
        if full:
            return min(full, key=lambda r: r["expected_latency_s"])
        stable = [r for r in feasible if r["uplink_utilization"] < rho]
        if stable:
            return min(stable, key=lambda r: (-r["p_tar"], r["expected_latency_s"]))
        if feasible:
            return min(feasible, key=lambda r: r["expected_latency_s"])
        return max(table, key=lambda r: (r["accuracy"] or 0.0))

    def _cell_weights(self, telemetry, c: int, t: float) -> Optional[np.ndarray]:
        """Per-sample weights pricing this cell's estimated traffic mix;
        None (uniform over all contexts' samples) when context-blind or
        nothing recognizable was observed yet."""
        if len(self.ctx_keys) == 1:
            return None
        raw = telemetry.context_mix_estimate(c, self.config.window_s, now=t)
        if raw is None:
            return None
        mix = np.zeros(len(self.ctx_keys))
        for i, key in enumerate(telemetry.context_keys):
            if key in self.ctx_keys:
                mix[self.ctx_keys.index(key)] += raw[i]
        if mix.sum() <= 0:
            return None
        mix /= mix.sum()
        return np.concatenate(
            [np.full(n, m / n) for n, m in zip(self._block_len, mix)]
        )

    def update(self, t: float, telemetry) -> List[Tuple[int, float]]:
        """-> per-cell (physical branch, p_tar) decisions."""
        cfg = self.config
        chosen_rows, tables, rates = [], [], []
        for c in range(self.n_cells):
            bw = telemetry.bandwidth_estimate(c, cfg.window_s, now=t)
            if bw is None:
                bw = self.profile.uplink_bps  # nothing measured: trust nominal
            rate_hz = (
                telemetry.arrival_rate_estimate(c, cfg.window_s, now=t)
                if cfg.utilization_aware
                else None
            )
            _, table = rescore_plan(
                self.plan,
                self.exit_logits_list,
                edge_times_s=self.edge_times_s,
                cloud_times_s=self.cloud_times_s,
                payload_bytes=self.payload_bytes,
                uplink_bps=bw,
                labels=self._labels_cat,
                final_logits=self._final_cat,
                p_tar_grid=cfg.p_tar_grid,
                min_accuracy=cfg.min_accuracy,
                arrival_rate_hz=rate_hz,
                exit_stats=self._exit_stats,
                sample_weight=self._cell_weights(telemetry, c, t),
            )
            chosen_rows.append(self._choose_cell(table))
            tables.append(table)
            rates.append(rate_hz or 0.0)

        if cfg.cloud_rho_max is not None:
            chosen_rows = self._shared_cloud_pass(chosen_rows, tables, rates)

        decisions = [
            (r["exit_index"] + 1, float(r["p_tar"])) for r in chosen_rows
        ]
        self.history.append((t, decisions))
        return decisions

    # ---------------------------------------------------- shared-cloud cap
    def _cloud_load(self, row, rate_hz: float) -> float:
        return rate_hz * row["offload_prob"] * self.cloud_times_s[row["exit_index"]]

    def _shared_cloud_pass(self, chosen, tables, rates):
        """Demote the heaviest cloud contributors until the shared tier's
        utilization fits under the cap (or no feasible demotion remains)."""
        cap = self.config.cloud_rho_max * self.cloud_servers
        loads = [self._cloud_load(r, rate) for r, rate in zip(chosen, rates)]
        frozen = set()
        while sum(loads) > cap:
            order = sorted(
                (c for c in range(self.n_cells) if c not in frozen),
                key=lambda c: loads[c],
                reverse=True,
            )
            moved = False
            for c in order:
                alts = [
                    r for r in tables[c]
                    if self._feasible(r)
                    and self._cloud_load(r, rates[c]) < loads[c]
                ]
                if not alts:
                    frozen.add(c)
                    continue
                # least cloud-hungry feasible candidate; latency breaks ties
                best = min(
                    alts,
                    key=lambda r: (
                        self._cloud_load(r, rates[c]),
                        r["expected_latency_s"],
                    ),
                )
                chosen[c] = best
                loads[c] = self._cloud_load(best, rates[c])
                frozen.add(c)
                moved = True
                break
            if not moved:
                break
        return chosen
