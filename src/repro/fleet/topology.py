"""Multi-cell fleet topology.

A *cell* is the unit the paper studies once: a group of edge devices
behind ONE shared uplink, serving one request stream under one local
context (distortion) regime. A fleet is C such cells feeding a single
shared cloud tier. Each cell owns its workload seed, its `NetworkModel`,
and (optionally) its `ContextSchedule`, so a 64-cell fleet models 64
sites with different links and different weather -- the regime Danek et
al. (2025) measure, where shared-uplink contention across many devices
decides whether offloading pays off.

Workloads are materialized as plain arrays at construction
(`CellWorkload`), never as per-request objects: the fleet simulator
consumes arrival/sample/device columns directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.serving.drift import ContextSchedule
from repro.serving.network import NetworkModel


@dataclass
class CellWorkload:
    """One cell's request stream as columns (sorted by arrival)."""

    arrival_s: np.ndarray  # (N,) float64, sorted
    sample: np.ndarray  # (N,) int64 indices into the gate table
    device: np.ndarray  # (N,) int64 in [0, n_devices)

    def __post_init__(self):
        self.arrival_s = np.asarray(self.arrival_s, np.float64)
        self.sample = np.asarray(self.sample, np.int64)
        self.device = np.asarray(self.device, np.int64)
        n = self.arrival_s.shape[0]
        if self.sample.shape != (n,) or self.device.shape != (n,):
            raise ValueError("arrival_s/sample/device must be equal-length 1-D")
        if n and np.any(np.diff(self.arrival_s) < 0):
            raise ValueError("arrival_s must be sorted")

    def __len__(self) -> int:
        return int(self.arrival_s.shape[0])


@dataclass(frozen=True)
class DiurnalEnvelope:
    """Deterministic sinusoidal rate modulation for Poisson arrivals.

    The instantaneous rate is ``base_rate * (1 + amplitude *
    sin(2*pi*(t + phase_s)/period_s))`` -- the fleet-realism knob the
    per-cell constant-rate Poisson lacks (traffic peaks and troughs over
    the simulated day). `period_s` is whatever "a day" means at the
    simulation's time scale; staggering `phase_s` across cells models
    sites in different time zones. ``amplitude == 1.0`` is allowed and
    means the trough rate touches exactly zero (a site that goes fully
    quiet once per period); the thinning sampler handles the zero-rate
    stretch by construction (keep probability 0 there).
    """

    period_s: float = 60.0
    amplitude: float = 0.5  # in [0, 1]: 1.0 -> zero-rate trough
    phase_s: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.amplitude <= 1.0:
            raise ValueError("amplitude must be in [0, 1]")
        if self.period_s <= 0:
            raise ValueError("period_s must be positive")

    def rate_factor(self, t) -> np.ndarray:
        """Multiplier on the base rate at time(s) t."""
        t = np.asarray(t, np.float64)
        return 1.0 + self.amplitude * np.sin(
            2.0 * np.pi * (t + self.phase_s) / self.period_s
        )


def poisson_cell_workload(
    rate_hz: float,
    n_requests: int,
    n_samples: int,
    n_devices: int = 1,
    seed: int = 0,
    envelope: Optional[DiurnalEnvelope] = None,
) -> CellWorkload:
    """Poisson arrivals; samples walk the dataset sequentially and devices
    round-robin -- the same conventions as `repro.serving.workload`, as
    columns instead of `Request` objects.

    `envelope` switches the stream to an inhomogeneous Poisson process
    under the given diurnal rate modulation, materialized by seeded
    thinning (candidates at the peak rate, each kept with probability
    rate(t)/peak) -- deterministic under the seed, exactly `n_requests`
    arrivals. The default (None) keeps the homogeneous stream
    bit-identical to what this function always produced."""
    rng = np.random.default_rng(seed)
    if envelope is None:
        arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n_requests))
    else:
        peak = rate_hz * (1.0 + envelope.amplitude)
        arrivals = np.empty(n_requests, np.float64)
        count, t = 0, 0.0
        while count < n_requests:
            m = 2 * (n_requests - count) + 16
            cand = t + np.cumsum(rng.exponential(1.0 / peak, m))
            keep = rng.random(m) * (1.0 + envelope.amplitude) \
                < envelope.rate_factor(cand)
            acc = cand[keep]
            take = min(len(acc), n_requests - count)
            arrivals[count:count + take] = acc[:take]
            count += take
            t = float(cand[-1])
    idx = np.arange(n_requests, dtype=np.int64)
    return CellWorkload(arrivals, idx % n_samples, idx % n_devices)


@dataclass
class CellConfig:
    """One cell: device group + shared uplink + local context regime.

    ``initially_active=False`` models a cell that exists in the topology
    but has not joined the fleet yet (it comes up mid-run via an
    orchestration ``join`` event); until then its arrivals are shed like
    a failed cell's.
    """

    network: NetworkModel
    workload: CellWorkload
    n_devices: int = 1
    schedule: Optional[ContextSchedule] = None  # None -> static context
    deadline_s: Optional[float] = None
    initially_active: bool = True

    def __post_init__(self):
        if self.n_devices < 1:
            raise ValueError("n_devices must be >= 1")
        if len(self.workload) and int(self.workload.device.max()) >= self.n_devices:
            raise ValueError(
                f"workload uses device {int(self.workload.device.max())} but "
                f"the cell has {self.n_devices} device(s)"
            )


@dataclass
class FleetTopology:
    """C cells -> one shared cloud tier of `cloud_servers` servers.

    Cells are arranged on a ring for orchestration purposes: when a cell
    fails or leaves mid-run (`repro.orchestration`), its arrivals are shed
    to the nearest ACTIVE neighbor by ring distance (`shed_order`), and to
    the shared cloud over a backhaul when no live neighbor exists. The
    per-run activation state itself lives in the simulator (seeded event
    schedules move it); the topology only declares the starting mask
    (`initial_active_mask` from each cell's ``initially_active``) and the
    neighbor geometry.
    """

    cells: List[CellConfig]
    cloud_servers: int = 1

    def __post_init__(self):
        if not self.cells:
            raise ValueError("a fleet needs at least one cell")
        if self.cloud_servers < 1:
            raise ValueError("cloud_servers must be >= 1")

    @property
    def n_cells(self) -> int:
        return len(self.cells)

    def initial_active_mask(self) -> np.ndarray:
        """(C,) bool: which cells are up at t=0."""
        return np.asarray([c.initially_active for c in self.cells], bool)

    def shed_order(self, cell: int) -> np.ndarray:
        """Every OTHER cell ordered by ring distance from `cell` (ties
        broken toward the lower index): the order in which a dead cell's
        load looks for a live host."""
        if not 0 <= cell < self.n_cells:
            raise ValueError(f"no cell {cell} in a {self.n_cells}-cell fleet")
        others = np.asarray(
            [c for c in range(self.n_cells) if c != cell], np.int64
        )
        if others.size == 0:
            return others
        dist = np.abs(others - cell)
        dist = np.minimum(dist, self.n_cells - dist)
        return others[np.lexsort((others, dist))]

    @property
    def n_requests(self) -> int:
        return sum(len(c.workload) for c in self.cells)

    @property
    def horizon_s(self) -> float:
        """Last arrival across the fleet (the simulated span lower bound)."""
        return max(
            float(c.workload.arrival_s[-1]) if len(c.workload) else 0.0
            for c in self.cells
        )
