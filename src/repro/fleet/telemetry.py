"""Columnar telemetry for fleet-scale runs.

At 100k+ requests, one `RequestRecord` object per request is the
bottleneck, so the fleet stores per-cell *columns* (numpy arrays appended
once per window) and computes the same metrics the event-driven
`Telemetry` defines -- p50/p95/p99 latency, deadline-miss rate, offload
rate, accuracy, and the on-device-weighted miscalibration gap -- through
the shared control-plane primitives in `repro.core.control`
(`latency_stats_ms`, `on_device_gap`, and the windowed
`windowed_mean`/`windowed_rate`/`windowed_mix` estimators), so the two
simulators can never disagree about what a metric or a controller-facing
estimate means.

Reports come at three altitudes: `cell_summary(c)` (one cell),
`fleet_summary()` (every request in one pool, gap still aggregated
per-(cell, context) regime so opposite-sign regimes cannot cancel), and
`per_cell_summary()` (the fleet operator's table).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bank import UNKNOWN_CONTEXT
from repro.core.control import (
    latency_stats_ms,
    on_device_gap,
    windowed_mean,
    windowed_mix,
    windowed_rate,
)


class _Observations:
    """Append-only (t, value) stream in amortized growing buffers, so a
    controller windowing it every tick reads a zero-copy view instead of
    re-concatenating the full chunk history each tick. (Times are NOT
    globally sorted -- a congested cell emits future-dated transfer
    observations -- so reads mask the whole view; that is a cheap
    vectorized scan, the churn was the per-tick reallocation.)"""

    def __init__(self, dtype):
        self._t = np.empty(64, np.float64)
        self._v = np.empty(64, dtype)
        self._n = 0

    def append(self, times, values) -> None:
        times = np.asarray(times, np.float64)
        k = times.shape[0]
        while self._n + k > self._t.shape[0]:
            self._t = np.concatenate([self._t, np.empty_like(self._t)])
            self._v = np.concatenate([self._v, np.empty_like(self._v)])
        self._t[self._n:self._n + k] = times
        self._v[self._n:self._n + k] = values
        self._n += k

    @property
    def empty(self) -> bool:
        return self._n == 0

    def arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        return self._t[:self._n], self._v[:self._n]


class _CellColumns:
    """Append-only per-cell columns; concatenated lazily on first read."""

    FIELDS = ("latency_s", "on_device", "correct", "p_tar", "branch",
              "ctx_id", "est_id", "missed", "energy_j")

    def __init__(self):
        self.chunks: Dict[str, List[np.ndarray]] = {f: [] for f in self.FIELDS}
        self._cache: Optional[Dict[str, np.ndarray]] = None

    def append(self, **cols: np.ndarray) -> None:
        if set(cols) != set(self.FIELDS):
            missing = set(self.FIELDS) ^ set(cols)
            raise ValueError(f"window columns mismatch: {sorted(missing)}")
        n = len(cols["latency_s"])
        for f, v in cols.items():
            v = np.asarray(v)
            if v.shape != (n,):
                raise ValueError(f"column {f!r} has shape {v.shape}, want ({n},)")
            self.chunks[f].append(v)
        self._cache = None

    def column(self, name: str) -> np.ndarray:
        if self._cache is None:
            self._cache = {
                f: (np.concatenate(c) if c else np.empty(0))
                for f, c in self.chunks.items()
            }
        return self._cache[name]

    def __len__(self) -> int:
        return int(self.column("latency_s").shape[0])


class FleetTelemetry:
    """Fleet-wide roll-ups + the windowed per-cell estimates the fleet
    controller consumes (observed uplink rates, arrival counts)."""

    def __init__(self, n_cells: int, context_keys: List[str],
                 bank_keys: Optional[List[str]] = None):
        self.n_cells = n_cells
        self.context_keys = list(context_keys)
        self.bank_keys = None if bank_keys is None else list(bank_keys)
        self._cells = [_CellColumns() for _ in range(n_cells)]
        # (t, rate) observations per cell, one per uplink transfer
        self._bw = [_Observations(np.float64) for _ in range(n_cells)]
        # (t, context id) observations per cell, one per gated request --
        # the edge-side context verdicts a context-aware controller windows
        self._ctx = [_Observations(np.int64) for _ in range(n_cells)]
        self._arrivals: List[np.ndarray] = [np.empty(0)] * n_cells
        # (t, cell, branch, p_tar, compression_level) per adopted switch
        self.controller_events: List[Tuple[float, int, int, float, int]] = []
        # live QoS streams (orchestrated runs only): per-cell lockstep
        # (t, latency) + (t, missed) and (t, correct) + (t, p_tar) pairs,
        # fed from resolved completions DURING the run so a QoS monitor
        # can window per-cell tails mid-simulation. Times are completion
        # times; attribution follows the ORIGIN cell under load shedding.
        self._live_lat = [_Observations(np.float64) for _ in range(n_cells)]
        self._live_miss = [_Observations(np.int8) for _ in range(n_cells)]
        self._live_cor = [_Observations(np.int8) for _ in range(n_cells)]
        self._live_pt = [_Observations(np.float64) for _ in range(n_cells)]
        # live calibration streams: (t, gate confidence) + lockstep
        # (t, edge correctness) + (t, kept on-device) for EVERY gated
        # request (offloaded ones included -- the reliability diagram is
        # about the gate's confidence, not about who answered), fed at
        # edge-completion time so the QoS monitor can window ECE /
        # coverage mid-run
        self._live_conf = [_Observations(np.float64) for _ in range(n_cells)]
        self._live_ccor = [_Observations(np.int8) for _ in range(n_cells)]
        self._live_con = [_Observations(np.int8) for _ in range(n_cells)]
        # arrivals a cell serves on BEHALF of dead neighbors (load shedding)
        # -- folded into its arrival-rate estimate so a utilization-aware
        # controller prices the host cell's true demand
        self._shed_arr = [_Observations(np.int8) for _ in range(n_cells)]
        #: (t, kind, payload) orchestration audit log -- churn flips, QoS
        #: trips/clears, rollout transitions -- in event order
        self.orchestration_events: List[Tuple[float, str, Dict]] = []

    # ------------------------------------------------------------- ingest
    def set_arrivals(self, cell: int, arrival_s: np.ndarray) -> None:
        self._arrivals[cell] = np.asarray(arrival_s, np.float64)

    def add_window(self, cell: int, **cols: np.ndarray) -> None:
        self._cells[cell].append(**cols)

    def observe_bandwidth(self, cell: int, times: np.ndarray, rates: np.ndarray) -> None:
        self._bw[cell].append(times, rates)

    def observe_contexts(self, cell: int, times: np.ndarray, ctx_ids: np.ndarray) -> None:
        """Per-request context verdicts (indices into `context_keys`, -1 =
        unrecognized) at gate time -- estimator verdicts on the honest
        path, true contexts in oracle mode."""
        self._ctx[cell].append(times, ctx_ids)

    def record_controller(
        self, t: float, cell: int, branch: int, p_tar: float, level: int = 0
    ) -> None:
        self.controller_events.append((t, cell, branch, p_tar, int(level)))

    def observe_live_latency(
        self, cell: int, times: np.ndarray, latency_s: np.ndarray,
        missed: np.ndarray,
    ) -> None:
        """Resolved completions as they happen (missed: 1/0, -1 = no
        deadline declared). Edge completions land exactly; offloaded ones
        stream through the simulator's live cloud view."""
        self._live_lat[cell].append(times, latency_s)
        self._live_miss[cell].append(times, missed)

    def observe_live_gate(
        self, cell: int, times: np.ndarray, correct: np.ndarray,
        p_tar: np.ndarray,
    ) -> None:
        """Label outcomes of ON-DEVICE answers as they resolve -- the
        stream the reliability-gap SLO is audited against."""
        self._live_cor[cell].append(times, correct)
        self._live_pt[cell].append(times, p_tar)

    def observe_live_calibration(
        self, cell: int, times: np.ndarray, conf: np.ndarray,
        correct: np.ndarray, on: np.ndarray,
    ) -> None:
        """Gate confidences + EDGE correctness + on-device flags of every
        gated request as its edge pass resolves -- the stream the
        calibration SLOs (`ece_cap` / `coverage_floor`) window."""
        self._live_conf[cell].append(times, conf)
        self._live_ccor[cell].append(times, correct)
        self._live_con[cell].append(times, on)

    def record_orchestration(self, t: float, kind: str, **payload) -> None:
        self.orchestration_events.append((float(t), str(kind), dict(payload)))

    def observe_shed_arrivals(self, cell: int, times: np.ndarray) -> None:
        """Arrivals shed TO `cell` from a dead neighbor; they join the
        host's arrival-rate estimate (not its latency columns -- those
        stay with the origin)."""
        self._shed_arr[cell].append(times, np.zeros(len(times), np.int8))

    def cell_qos_estimate(
        self, cell: int, window_s: float, now: float
    ) -> Dict[str, float]:
        """Trailing-window QoS as the monitor sees it: p99 latency,
        deadline-miss rate, on-device reliability gap, and how many
        completions the window holds. NaN where the window has no
        evidence for a metric (the monitor treats NaN as 'no verdict')."""
        out = {"requests": 0, "gate_samples": 0, "cal_samples": 0,
               "p99_ms": float("nan"),
               "deadline_miss_rate": float("nan"),
               "reliability_gap": float("nan"),
               "reliability_shortfall": float("nan"),
               "ece": float("nan"), "coverage": float("nan"),
               "cal_bins": None}
        if not self._live_lat[cell].empty:
            t, lat = self._live_lat[cell].arrays()
            m = (t > now - window_s) & (t <= now)
            out["requests"] = int(m.sum())
            if m.any():
                out["p99_ms"] = float(np.quantile(lat[m], 0.99) * 1000.0)
                _, miss = self._live_miss[cell].arrays()
                mm = m & (miss >= 0)
                if mm.any():
                    out["deadline_miss_rate"] = float(miss[mm].mean())
        if not self._live_cor[cell].empty:
            t, cor = self._live_cor[cell].arrays()
            _, pt = self._live_pt[cell].arrays()
            m = (t > now - window_s) & (t <= now)
            out["gate_samples"] = int(m.sum())
            if m.any():
                gap = on_device_gap(cor[m], pt[m])
                if gap is not None:
                    out["reliability_gap"] = gap
                # the SLO-facing direction: how far BELOW the promised
                # target the on-device accuracy fell (over-delivery is 0)
                out["reliability_shortfall"] = float(
                    max(0.0, pt[m].mean() - cor[m].mean())
                )
        if not self._live_conf[cell].empty:
            t, conf = self._live_conf[cell].arrays()
            _, ccor = self._live_ccor[cell].arrays()
            _, con = self._live_con[cell].arrays()
            m = (t > now - window_s) & (t <= now)
            out["cal_samples"] = int(m.sum())
            if m.any():
                # the sketch's binning math, shared so the windowed gauge
                # and the end-of-run sketch can never disagree
                from repro.obs.calibration import (
                    bin_block,
                    block_coverage,
                    block_ece,
                    block_reliability,
                )

                blk = bin_block(conf[m], ccor[m], con[m])
                out["ece"] = block_ece(blk)
                out["coverage"] = block_coverage(blk)
                out["cal_bins"] = block_reliability(blk)
        return out

    # --------------------------------------------------- controller window
    def bandwidth_estimate(
        self, cell: int, window_s: float, now: float
    ) -> Optional[float]:
        """Mean observed uplink rate over the trailing window; stale most
        recent sample if the window is empty (the `Telemetry` contract);
        None when the cell never transferred."""
        if self._bw[cell].empty:
            return None
        t, v = self._bw[cell].arrays()
        return windowed_mean(t, v, window_s, now, stale_fallback=True)

    def context_mix_estimate(
        self, cell: int, window_s: float, now: float
    ) -> Optional[np.ndarray]:
        """Share of the cell's trailing-window traffic per context key ->
        (len(context_keys),) weights summing to 1, or None when nothing
        (recognizable) was observed. Unrecognized (-1) verdicts are
        excluded: the bank serves them with the default plan, but their
        gate statistics belong to no fitted context."""
        if self._ctx[cell].empty:
            return None
        t, v = self._ctx[cell].arrays()
        return windowed_mix(t, v, len(self.context_keys), window_s, now)

    def arrival_rate_estimate(
        self, cell: int, window_s: float, now: float
    ) -> Optional[float]:
        base = windowed_rate(self._arrivals[cell], window_s, now)
        if self._shed_arr[cell].empty:
            return base
        t, _ = self._shed_arr[cell].arrays()
        shed = float(((t > now - window_s) & (t <= now)).sum()) / window_s
        if base is None:
            return shed if shed > 0 else None
        return base + shed

    # ------------------------------------------------------------ reports
    def requests(self, cell: Optional[int] = None) -> int:
        cells = self._cells if cell is None else [self._cells[cell]]
        return sum(len(c) for c in cells)

    def _gap_groups(self, cells) -> Tuple[List[float], List[int]]:
        """Per-(cell, context) on-device reliability gaps + weights. The
        regime is (cell, context): two cells in the same context are
        separate reliability contracts, exactly as two contexts in one
        cell are."""
        gaps, weights = [], []
        for c in cells:
            on = c.column("on_device")
            correct = c.column("correct")
            p_tar = c.column("p_tar")
            ctx = c.column("ctx_id")
            known = on & (correct >= 0)
            for cid in np.unique(ctx[known]):
                m = known & (ctx == cid)
                gap = on_device_gap(correct[m], p_tar[m])
                if gap is not None:
                    gaps.append(gap)
                    weights.append(int(m.sum()))
        return gaps, weights

    def _summary_of(self, cells) -> Dict[str, float]:
        lat = np.concatenate([c.column("latency_s") for c in cells]) \
            if cells else np.empty(0)
        out = latency_stats_ms(lat)
        out["requests"] = int(lat.shape[0])
        if lat.shape[0] == 0:
            nan = float("nan")
            out.update(offload_rate=nan, deadline_miss_rate=nan, accuracy=nan,
                       miscalibration_gap=nan, energy_j_total=0.0)
            return out
        out["energy_j_total"] = float(
            sum(c.column("energy_j").sum() for c in cells)
        )
        on = np.concatenate([c.column("on_device") for c in cells])
        correct = np.concatenate([c.column("correct") for c in cells])
        missed = np.concatenate([c.column("missed") for c in cells])
        out["offload_rate"] = float((~on).mean())
        known = correct >= 0  # correct is -1 when labels are unknown
        out["accuracy"] = float(correct[known].mean()) if known.any() else float("nan")
        has_deadline = missed >= 0
        out["deadline_miss_rate"] = (
            float(missed[has_deadline].mean()) if has_deadline.any() else float("nan")
        )
        gaps, weights = self._gap_groups(cells)
        out["miscalibration_gap"] = (
            float(np.average(gaps, weights=weights)) if gaps else float("nan")
        )
        return out

    def cell_summary(self, cell: int) -> Dict[str, float]:
        return self._summary_of([self._cells[cell]])

    def fleet_summary(self) -> Dict[str, float]:
        s = self._summary_of(self._cells)
        s["cells"] = self.n_cells
        s["controller_switches"] = len(self.controller_events)
        return s

    def per_cell_summary(self) -> List[Dict[str, float]]:
        return [self.cell_summary(c) for c in range(self.n_cells)]

    def per_context_summary(self) -> Dict[str, Dict[str, float]]:
        """Fleet-wide per-TRUE-context roll-up (the `Telemetry` analogue):
        request count, offload rate, accuracy, miscalibration gap, and how
        often the estimator named the context correctly."""
        out: Dict[str, Dict[str, float]] = {}
        for cid, key in enumerate(self.context_keys):
            lat_n, on_l, cor_l, pt_l, est_l = 0, [], [], [], []
            for c in self._cells:
                m = c.column("ctx_id") == cid
                if not m.any():
                    continue
                lat_n += int(m.sum())
                on_l.append(c.column("on_device")[m])
                cor_l.append(c.column("correct")[m])
                pt_l.append(c.column("p_tar")[m])
                est_l.append(c.column("est_id")[m])
            if lat_n == 0:
                continue
            on = np.concatenate(on_l)
            correct = np.concatenate(cor_l)
            p_tar = np.concatenate(pt_l)
            est = np.concatenate(est_l)
            known = correct >= 0
            on_known = on & known
            gap = on_device_gap(correct[on_known], p_tar[on_known]) \
                if on_known.any() else None
            # est ids: >=0 index bank_keys, -1 = unknown verdict, -2 = no
            # estimator ran (oracle/single-plan selection)
            match = float("nan")
            ran = est > -2
            if self.bank_keys is not None and ran.any():
                names = np.asarray(self.bank_keys + [UNKNOWN_CONTEXT])
                got = names[est[ran]]  # -1 wraps onto the sentinel
                match = float((got == key).mean())
            out[key] = {
                "requests": lat_n,
                "offload_rate": float((~on).mean()),
                "accuracy": float(correct[known].mean()) if known.any() else float("nan"),
                "on_device_accuracy": (
                    float(correct[on_known].mean()) if on_known.any() else float("nan")
                ),
                "miscalibration_gap": float("nan") if gap is None else gap,
                "est_match_rate": match,
            }
        return out
