"""Max-plus semiring solvers for deterministic-service FIFO queues.

The FIFO recurrence

    done_i = max(t_i, done_{i-1}) + s_i

is an affine map over the max-plus semiring: with f_i(x) = max(x + A_i, b_i),
A_i = s_i and b_i = t_i + s_i, we have done_i = (f_i . f_{i-1} . ... . f_1)(free).
Composition is associative,

    (f2 . f1) = (A1 + A2, max(b1 + A2, b2)),

so the whole chain resolves with `lax.associative_scan` in O(log n) depth:

    done_i = max(b_scan_i, free + A_scan_i)

The identity element (A, b) = (0, -inf) lets masked-out rows pass through
unchanged, which is what the compiled fleet pipeline uses to run one padded
scan per device lane. The formula is valid for UNSORTED arrival times t
(done_i = max_{j<=i} (t_j + sum_{k=j..i} s_k) holds regardless of ordering).

`fifo_oracle` / `kserver_oracle` are the deliberately naive per-request
Python references; `tests/test_fleet_properties.py` pins the scan solvers
against them (exactly, on dyadic-rational inputs where float addition is
associative).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.gatepath import _next_pow2

__all__ = [
    "fifo_oracle",
    "kserver_oracle",
    "maxplus_fifo",
    "fifo_done_maxplus",
    "kserver_done_maxplus",
]


def fifo_oracle(t, service, free_s: float = 0.0) -> np.ndarray:
    """Per-request Python FIFO: the ground-truth oracle for the scan solver."""
    t = np.asarray(t, dtype=np.float64)
    service = np.asarray(service, dtype=np.float64)
    done = np.empty(t.shape[0], dtype=np.float64)
    prev = float(free_s)
    for i in range(t.shape[0]):
        prev = max(float(t[i]), prev) + float(service[i])
        done[i] = prev
    return done


def kserver_oracle(t, service, k: int) -> np.ndarray:
    """Naive K-server FIFO: each job goes to the earliest-free server.

    With constant service times this matches the residue-class decomposition
    (job i waits for job i-K) used by the fleet cloud tier.
    """
    t = np.asarray(t, dtype=np.float64)
    service = np.asarray(service, dtype=np.float64)
    free = [0.0] * int(k)
    done = np.empty(t.shape[0], dtype=np.float64)
    for i in range(t.shape[0]):
        r = min(range(len(free)), key=lambda j: free[j])
        d = max(float(t[i]), free[r]) + float(service[i])
        free[r] = d
        done[i] = d
    return done


def _combine(x, y):
    """Max-plus affine composition, elementwise over stacked (A, b) pairs."""
    import jax.numpy as jnp

    a1, b1 = x
    a2, b2 = y
    return a1 + a2, jnp.maximum(b1 + a2, b2)


def maxplus_fifo(t, service, mask, free):
    """Masked FIFO completion times via `lax.associative_scan` (jnp -> jnp).

    Works on any leading axis layout `associative_scan` accepts (scan is over
    axis 0). Rows with ``mask == False`` are the semiring identity; their
    output positions are undefined and must be re-masked by the caller.
    """
    import jax.numpy as jnp
    from jax import lax

    a = jnp.where(mask, service, 0.0)
    b = jnp.where(mask, t + service, -jnp.inf)
    a_s, b_s = lax.associative_scan(_combine, (a, b))
    return jnp.maximum(b_s, free + a_s)


_JIT_CACHE: dict = {}


def _scan_fn():
    if "fifo" not in _JIT_CACHE:
        import jax

        _JIT_CACHE["fifo"] = jax.jit(maxplus_fifo)
    return _JIT_CACHE["fifo"]


def fifo_done_maxplus(t, service, free_s: float = 0.0) -> np.ndarray:
    """Host-callable max-plus FIFO solver (float64, jitted scan).

    Pads to the next power of two so a sweep over chain lengths 1..N costs at
    most log2(N)+1 compilations, mirroring the gate-path padding contract.
    """
    from jax.experimental import enable_x64

    t = np.asarray(t, dtype=np.float64)
    service = np.asarray(service, dtype=np.float64)
    n = t.shape[0]
    if n == 0:
        return np.empty(0, dtype=np.float64)
    m = _next_pow2(n)
    tp = np.zeros(m, dtype=np.float64)
    sp = np.zeros(m, dtype=np.float64)
    mask = np.zeros(m, dtype=bool)
    tp[:n] = t
    sp[:n] = service
    mask[:n] = True
    with enable_x64():
        out = _scan_fn()(tp, sp, mask, np.float64(free_s))
    return np.asarray(out)[:n]


def kserver_done_maxplus(t, service, k: int) -> np.ndarray:
    """K-server completion times via residue-class max-plus chains.

    Jobs must already be in FIFO order; chain r serves jobs r, r+K, r+2K, ...
    exactly as the fleet cloud tier decomposes its shared servers.
    """
    t = np.asarray(t, dtype=np.float64)
    service = np.asarray(service, dtype=np.float64)
    done = np.empty(t.shape[0], dtype=np.float64)
    for r in range(min(int(k), t.shape[0])):
        idx = np.arange(r, t.shape[0], int(k))
        done[idx] = fifo_done_maxplus(t[idx], service[idx])
    return done
