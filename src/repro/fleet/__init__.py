"""Fleet-scale vectorized serving simulation.

The event-driven `repro.serving.ServingRuntime` is exact but per-request:
one Python callback per arrival, gate, transfer, and completion. That is
the right tool for one cell, and the wrong one for the ROADMAP's
"millions of users": simulating 100k requests takes minutes of heap
churn. This package trades per-event exactness for *windowed, vectorized*
semantics -- whole arrival windows move through each tier as numpy
blocks -- and simulates hundreds of thousands of requests across dozens
of cells in seconds, while provably collapsing onto the event runtime in
the single-cell, single-device, fixed-link limit (pinned by
`tests/test_fleet.py`).

* `topology`   -- `CellConfig`/`FleetTopology`: C cells, each with its
                  own device group, shared uplink (`NetworkModel`), drift
                  schedule, and workload, all feeding one cloud tier;
* `gate`       -- `FleetGateTable`: per-(context, expert, branch)
                  confidence/prediction blocks precomputed through the
                  batched `OffloadPlan.gate_block`/`PlanBank.gate_block`
                  path, with integer context ids for fancy indexing;
* `simulator`  -- `FleetSimulator`: the time-stepped vectorized pipeline
                  (edge FIFO recurrences, per-cell uplink queue, shared
                  multi-server cloud), all O(window) numpy;
* `controller` -- `FleetController`: per-cell Edgent-style re-scoring of
                  (branch, p_tar) from windowed per-cell telemetry, with
                  a shared-cloud utilization cap across cells;
* `telemetry`  -- `FleetTelemetry`: per-cell and fleet-wide p50/p95/p99,
                  miss rate, offload rate, and miscalibration gap, sharing
                  the metric definitions of `repro.serving.telemetry`;
* `scenarios`  -- the reference multi-cell drift scenario the acceptance
                  tests and `BENCH_fleet.json` both run.
"""
from repro.fleet.controller import FleetController, FleetControllerConfig
from repro.fleet.gate import FleetGateTable
from repro.fleet.simulator import FleetConfig, FleetSimulator
from repro.fleet.telemetry import FleetTelemetry
from repro.fleet.topology import CellConfig, FleetTopology

__all__ = [
    "CellConfig",
    "FleetTopology",
    "FleetGateTable",
    "FleetConfig",
    "FleetSimulator",
    "FleetController",
    "FleetControllerConfig",
    "FleetTelemetry",
]
