"""Fleet-scale vectorized serving simulation.

The event-driven `repro.serving.ServingRuntime` is exact but per-request:
one Python callback per arrival, gate, transfer, and completion. That is
the right tool for one cell, and the wrong one for the ROADMAP's
"millions of users": simulating 100k requests takes minutes of heap
churn. This package trades per-event exactness for *windowed, vectorized*
semantics -- whole arrival windows move through each tier as numpy
blocks -- and simulates hundreds of thousands of requests across dozens
of cells in seconds, while provably collapsing onto the event runtime in
the single-cell, single-device, fixed-link limit (pinned by
`tests/test_fleet.py`).

* `topology`   -- `CellConfig`/`FleetTopology`: C cells, each with its
                  own device group, shared uplink (`NetworkModel`), drift
                  schedule, and workload, all feeding one cloud tier;
* `gate`       -- a shim over `repro.core.gatepath.GateTable` (the
                  name `FleetGateTable` remains): per-(context, expert,
                  branch) confidence/prediction blocks precomputed and
                  window-gated through the selectable `GateBackend`
                  (host numpy or one jitted JAX call per window);
* `simulator`  -- `FleetSimulator`: the time-stepped vectorized pipeline
                  (edge FIFO recurrences, per-cell uplink queue, shared
                  multi-server cloud), all O(window) numpy;
* `controller` -- `FleetController`: fleet policy over the shared
                  `repro.core.control.ControllerCore` (per-cell
                  context-aware re-scoring from windowed telemetry,
                  distress-gated p_tar concession) plus the fleet-only
                  shared-cloud utilization cap across cells;
* `telemetry`  -- `FleetTelemetry`: per-cell and fleet-wide p50/p95/p99,
                  miss rate, offload rate, and miscalibration gap, sharing
                  the metric definitions of `repro.serving.telemetry`;
* `scenarios`  -- the reference multi-cell drift scenario the acceptance
                  tests and `BENCH_fleet.json` both run.
"""
from repro.core.gatepath import GateBackend, GateTable, get_gate_backend
from repro.fleet.controller import FleetController, FleetControllerConfig
from repro.fleet.simulator import FleetConfig, FleetSimulator

#: Historical alias (the batched gate grew into `GateTable`); kept here
#: warning-free, while `repro.fleet.gate` now deprecation-warns.
FleetGateTable = GateTable
from repro.fleet.telemetry import FleetTelemetry
from repro.fleet.topology import (
    CellConfig,
    DiurnalEnvelope,
    FleetTopology,
    poisson_cell_workload,
)

__all__ = [
    "CellConfig",
    "DiurnalEnvelope",
    "FleetTopology",
    "poisson_cell_workload",
    "GateBackend",
    "GateTable",
    "get_gate_backend",
    "FleetGateTable",
    "FleetConfig",
    "FleetSimulator",
    "FleetController",
    "FleetControllerConfig",
    "FleetTelemetry",
]
