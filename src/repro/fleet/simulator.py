"""Time-stepped, vectorized fleet simulator.

Instead of one heap event per arrival/gate/transfer, requests move
through the tiers as whole *arrival windows* of numpy columns:

    per cell:  arrivals in [t0, t1)  ->  per-device FIFO edge service
               -> batched gate (GateTable through the selected backend)
               -> per-cell shared-uplink FIFO
    fleet:     all cells' transfers -> ONE cloud tier (K parallel servers)

Every queue is a deterministic-service FIFO, which admits an exact O(n)
vectorized solve: for done_i = max(t_i, done_{i-1}) + s_i, substituting
g_i = done_i - cumsum(s)_i turns the recurrence into a running maximum
(`np.maximum.accumulate`) -- no Python loop per request. The cloud's K
parallel servers decompose into K independent such chains (job i waits
for job i-K when service is deterministic); the cloud is solved once,
globally sorted by transfer completion, after the windowed loop (see
`_CloudJobs` for why that ordering is the correct one).

Exactness: in the single-cell, single-device, fixed-link, per-sample
case the windowed pipeline IS the event simulator -- same gate values
(shared `gate_statistics` math), same FIFO algebra -- and
`tests/test_fleet.py` pins equality to float round-off, queues empty or
not. The windowed semantics differ from the event heap only where
documented: (1) deployed (branch, p_tar) changes at window boundaries
and applies per ARRIVAL window (the event runtime captures config at
edge-service start); (2) a multi-device cell enqueues window w's uplink
transfers before window w+1's even if an idle device finished a later
arrival earlier; (3) time-varying links price a transfer at its start
time via one fixed-point repricing pass (exact for piecewise-constant
links whose state doesn't change between the two passes, and always
exact for fixed links); (4) offloads ship per sample (no microbatcher).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.gatepath import GateTable
from repro.fleet.telemetry import FleetTelemetry
from repro.fleet.topology import FleetTopology
from repro.offload import latency as L


def fifo_done(t: np.ndarray, service: np.ndarray, free_s: float) -> np.ndarray:
    """Completion times of a FIFO single-server queue, vectorized.

    t: sorted job-ready times; service: per-job service times; free_s:
    when the server frees up from earlier work. Solves
    done_i = max(t_i, done_{i-1}) + s_i via cumsum + running max.
    """
    csum = np.cumsum(service)
    x = t - (csum - service)  # t_i - cumsum_{<i}
    if x.size:
        x[0] = max(x[0], free_s)
    return np.maximum.accumulate(x) + csum


@dataclass
class FleetConfig:
    window_s: float = 0.25  # arrival-window width (config switch granularity)


class _CloudJobs:
    """Every offloaded job of the whole run, as growing columns.

    The cloud tier is solved ONCE, after the windowed loop, over all jobs
    sorted by uplink-completion time. Processing it window-by-window would
    be wrong, not just inexact: a saturated cell's uplink emits transfers
    whose completion lies far in the future, and feeding those to the
    cloud in *generation* order would make jobs from healthy cells queue
    behind phantom busy servers. Nothing downstream of the cloud feeds
    back into the simulation, so deferring it is exact.
    """

    def __init__(self):
        self.t: List[np.ndarray] = []
        self.service: List[np.ndarray] = []
        self.win: List[np.ndarray] = []  # index into the window-cols list
        self.pos: List[np.ndarray] = []  # index into that window's arrays

    def add(self, t, service, win, pos):
        self.t.append(t)
        self.service.append(np.full(len(t), service))
        self.win.append(np.full(len(t), win, np.int64))
        self.pos.append(pos)


class FleetSimulator:
    """Run a whole fleet topology through the windowed pipeline.

    table: the shared `GateTable` (all cells serve the same model and
    deployed plan/bank; per-cell state is (branch, p_tar), moved by the
    optional fleet controller). Each cell's `ContextSchedule` must visit
    only contexts the table covers; cells without a schedule serve the
    table's only context. The table's selected `GateBackend` decides how
    each window gates (host numpy fancy-indexing, or one jitted JAX call
    on device-resident tables).
    """

    def __init__(
        self,
        table: GateTable,
        topology: FleetTopology,
        profile: L.LatencyProfile,
        config: Optional[FleetConfig] = None,
        controller=None,
        payload_nbytes: Optional[Callable[[int], int]] = None,
    ):
        self.table = table
        self.topology = topology
        self.profile = profile
        self.config = config or FleetConfig()
        if self.config.window_s <= 0:
            raise ValueError("window_s must be positive")
        self.controller = controller
        if controller is not None:
            ratio = controller.interval_s / self.config.window_s
            if abs(ratio - round(ratio)) > 1e-9 or round(ratio) < 1:
                raise ValueError(
                    f"controller interval {controller.interval_s}s must be a "
                    f"positive multiple of window_s={self.config.window_s}s"
                )
            self._ticks_per_update = int(round(ratio))
            if not set(controller.branches) <= set(table.branches):
                raise ValueError(
                    f"controller may deploy branches {controller.branches} "
                    f"but the table only serves {table.branches}"
                )
        if payload_nbytes is None:
            from repro.models.convnet import payload_bytes  # the paper's model

            payload_nbytes = payload_bytes
        self.payload_nbytes = payload_nbytes

        plan = table.plan
        branch = plan.exit_index + 1
        if branch not in table.branches:
            raise ValueError(
                f"plan deploys branch {branch} but the table only serves "
                f"{table.branches}"
            )
        self._initial_state = (branch, float(plan.p_tar))
        self._state: List[Tuple[int, float]] = []
        # estimator verdicts (bank key indices) -> table context ids, for
        # the context-mix telemetry the controller windows
        self._bank_to_table = np.asarray(
            [table.ctx_index.get(k, -1) for k in table.bank_keys] or [-1],
            np.int64,
        )

        # per-cell schedule-context -> table-context id mapping
        self._sched_map: List[Optional[np.ndarray]] = []
        self._static_ctx: List[int] = []
        for cell in topology.cells:
            if cell.schedule is None:
                if len(table.ctx_keys) != 1:
                    raise ValueError(
                        "cells without a schedule need a single-context "
                        f"table; this one covers {table.ctx_keys}"
                    )
                self._sched_map.append(None)
                self._static_ctx.append(0)
            else:
                missing = set(cell.schedule.contexts) - set(table.ctx_keys)
                if missing:
                    raise ValueError(
                        f"schedule visits contexts with no logits: "
                        f"{sorted(missing)}"
                    )
                self._sched_map.append(
                    np.asarray(
                        [table.ctx_index[k] for k in cell.schedule.contexts],
                        np.int64,
                    )
                )
                self._static_ctx.append(-1)
            if len(cell.workload) and int(cell.workload.sample.max()) >= table.n_samples:
                raise ValueError("workload samples exceed the gate table")

    # ----------------------------------------------------------------- run
    def run(self) -> FleetTelemetry:
        topo, cfg, table = self.topology, self.config, self.table
        tel = FleetTelemetry(
            topo.n_cells,
            context_keys=table.ctx_keys,
            bank_keys=table.bank_keys or None,
        )
        for c, cell in enumerate(topo.cells):
            tel.set_arrivals(c, cell.workload.arrival_s)

        # every run starts from the plan's deployment (a controller from a
        # previous run() must not leak its final decisions into this one)
        self._state = [self._initial_state for _ in topo.cells]
        dev_free = [np.zeros(cell.n_devices) for cell in topo.cells]
        uplink_free = np.zeros(topo.n_cells)
        ptr = np.zeros(topo.n_cells, np.int64)
        n_windows = int(math.ceil(max(topo.horizon_s, 0.0) / cfg.window_s)) + 1

        jobs = _CloudJobs()
        window_cols = []  # (cell, dict of columns), patched by the cloud solve
        for w in range(n_windows):
            t0, t1 = w * cfg.window_s, (w + 1) * cfg.window_s
            if (
                self.controller is not None
                and w > 0
                and w % self._ticks_per_update == 0
            ):
                self._apply_controller(t0, tel)

            for c, cell in enumerate(topo.cells):
                arr = cell.workload.arrival_s
                hi = int(np.searchsorted(arr, t1, side="left"))
                lo = int(ptr[c])
                ptr[c] = hi
                if hi == lo:
                    continue
                branch, p_tar = self._state[c]
                cols = self._edge_and_gate(
                    c, cell, lo, hi, branch, p_tar, dev_free[c]
                )
                est = cols["est_id"]
                tel.observe_contexts(
                    c, cols["edge_done"],
                    np.where(est >= 0, self._bank_to_table[np.maximum(est, 0)],
                             np.where(est == -2, cols["ctx_id"], -1)),
                )
                off = ~cols["on_device"]
                if off.any():
                    order = np.argsort(cols["edge_done"][off], kind="stable")
                    pos = np.flatnonzero(off)[order]
                    t_ready = cols["edge_done"][pos]
                    nbytes = float(self.payload_nbytes(branch))
                    rates = cell.network.rates_bps(t_ready)
                    done = fifo_done(t_ready, nbytes * 8.0 / rates,
                                     float(uplink_free[c]))
                    # reprice at the actual transfer start (one fixed-point
                    # pass; exact for fixed links)
                    comm = nbytes * 8.0 / cell.network.rates_bps(
                        done - nbytes * 8.0 / rates
                    )
                    done = fifo_done(t_ready, comm, float(uplink_free[c]))
                    uplink_free[c] = done[-1]
                    tel.observe_bandwidth(c, t_ready, nbytes * 8.0 / comm)
                    jobs.add(done, L.cloud_time(self.profile, branch),
                             len(window_cols), pos)
                window_cols.append((c, cols))

        self._cloud_solve(jobs, window_cols)
        self._flush(window_cols, tel)
        return tel

    # ---------------------------------------------------------- edge tier
    def _edge_and_gate(self, c, cell, lo, hi, branch, p_tar, dev_free):
        arr = cell.workload.arrival_s[lo:hi]
        samples = cell.workload.sample[lo:hi]
        devices = cell.workload.device[lo:hi]
        s_edge = L.edge_time(self.profile, branch)
        edge_done = np.empty(hi - lo)
        for d in range(cell.n_devices):
            m = devices == d
            k = int(m.sum())
            if k == 0:
                continue
            done = fifo_done(arr[m], np.full(k, s_edge), float(dev_free[d]))
            edge_done[m] = done
            dev_free[d] = done[-1]

        if self._sched_map[c] is None:
            ctx_ids = np.full(hi - lo, self._static_ctx[c], np.int64)
        else:
            ctx_ids = self._sched_map[c][
                cell.schedule.context_ids_at(edge_done)
            ]
        conf, pred, on = self.table.gate_window(ctx_ids, samples, branch, p_tar)
        est = self.table.est_ids(ctx_ids, samples)
        correct = self.table.correct(samples, pred)
        n = hi - lo
        return {
            "arrival": arr,
            "samples": samples,
            "edge_done": edge_done,
            "complete": edge_done.copy(),
            "on_device": on,
            "ctx_id": ctx_ids,
            "est_id": np.full(n, -2, np.int64) if est is None else est,
            "correct": (
                np.full(n, -1, np.int8)
                if correct is None
                else correct.astype(np.int8)
            ),
            "branch": np.full(n, branch, np.int64),
            "p_tar": np.full(n, p_tar),
            "deadline": cell.deadline_s,
        }

    # ---------------------------------------------------------- cloud tier
    def _cloud_solve(self, jobs, window_cols):
        """One global K-server FIFO solve over every offloaded job, sorted
        by uplink completion: job i waits for job i-K (deterministic
        service), so each of the K residue classes is an independent
        single-server chain. Exact for uniform service times; with mixed
        branches in flight the completion order can locally deviate from
        the event heap's argmin-server rule (documented approximation)."""
        if not jobs.t:
            return
        t = np.concatenate(jobs.t)
        service = np.concatenate(jobs.service)
        win_of = np.concatenate(jobs.win)
        pos_of = np.concatenate(jobs.pos)
        order = np.argsort(t, kind="stable")
        t, service = t[order], service[order]
        win_of, pos_of = win_of[order], pos_of[order]
        k = self.topology.cloud_servers
        done = np.empty(len(t))
        for r in range(min(k, len(t))):
            idx = np.arange(r, len(t), k)
            done[idx] = fifo_done(t[idx], service[idx], 0.0)
        for w in np.unique(win_of):
            m = win_of == w
            _, cols = window_cols[int(w)]
            pos = pos_of[m]
            cols["complete"][pos] = done[m]
            cpred = self.table.cloud_pred(cols["ctx_id"][pos],
                                          cols["samples"][pos])
            correct = self.table.correct(cols["samples"][pos], cpred)
            if correct is not None:
                cols["correct"][pos] = correct.astype(np.int8)

    def _flush(self, window_cols, tel):
        for c, cols in window_cols:
            lat = cols["complete"] - cols["arrival"]
            if cols["deadline"] is None:
                missed = np.full(len(lat), -1, np.int8)
            else:
                missed = (lat > cols["deadline"]).astype(np.int8)
            tel.add_window(
                c,
                latency_s=lat,
                on_device=cols["on_device"],
                correct=cols["correct"],
                p_tar=cols["p_tar"],
                branch=cols["branch"],
                ctx_id=cols["ctx_id"],
                est_id=cols["est_id"],
                missed=missed,
            )

    # ---------------------------------------------------------- controller
    def _apply_controller(self, t: float, tel: FleetTelemetry) -> None:
        decisions = self.controller.update(t, tel)
        if len(decisions) != self.topology.n_cells:
            raise ValueError(
                f"controller returned {len(decisions)} decisions for "
                f"{self.topology.n_cells} cells"
            )
        for c, (branch, p_tar) in enumerate(decisions):
            if (branch, p_tar) != self._state[c]:
                tel.record_controller(t, c, branch, float(p_tar))
            self._state[c] = (int(branch), float(p_tar))
