"""Time-stepped, vectorized fleet simulator.

Instead of one heap event per arrival/gate/transfer, requests move
through the tiers as whole *arrival windows* of numpy columns:

    per cell:  arrivals in [t0, t1)  ->  per-device FIFO edge service
               -> batched gate (GateTable through the selected backend)
               -> per-cell shared-uplink FIFO
    fleet:     all cells' transfers -> ONE cloud tier (K parallel servers)

Every queue is a deterministic-service FIFO, which admits an exact O(n)
vectorized solve: for done_i = max(t_i, done_{i-1}) + s_i, substituting
g_i = done_i - cumsum(s)_i turns the recurrence into a running maximum
(`np.maximum.accumulate`) -- no Python loop per request. The cloud's K
parallel servers decompose into K independent such chains (job i waits
for job i-K when service is deterministic); the cloud is solved once,
globally sorted by transfer completion, after the windowed loop (see
`_CloudJobs` for why that ordering is the correct one).

Exactness: in the single-cell, single-device, fixed-link, per-sample
case the windowed pipeline IS the event simulator -- same gate values
(shared `gate_statistics` math), same FIFO algebra -- and
`tests/test_fleet.py` pins equality to float round-off, queues empty or
not. The windowed semantics differ from the event heap only where
documented: (1) deployed (branch, p_tar) changes at window boundaries
and applies per ARRIVAL window (the event runtime captures config at
edge-service start); (2) a multi-device cell enqueues window w's uplink
transfers before window w+1's even if an idle device finished a later
arrival earlier; (3) time-varying links price a transfer at its start
time via one fixed-point repricing pass (exact for piecewise-constant
links whose state doesn't change between the two passes, and always
exact for fixed links); (4) offloads ship per sample (no microbatcher).

Orchestration hooks (`repro.orchestration` drives them; all default off,
and the default path is operation-for-operation the pre-orchestration
simulator): an `orchestrator` object is called once per window boundary
and may flip per-cell ACTIVATION (a dead cell's window arrivals are shed
to the nearest live ring neighbor -- served on that cell's devices,
uplink, deployed state, and gate table, with the ORIGIN cell's context
regime -- or, with no live neighbor, shipped whole-window to the shared
cloud over a nominal-rate backhaul), swap per-cell GATE TABLES (canary /
fleet-wide rollout of a new `PlanBank`; candidate tables must serve the
same contexts, samples, and branches as the incumbent), and declare
CLOUD SLOWDOWN intervals (brownouts: cloud service time scaled for jobs
whose transfer completes inside the interval). Shed service runs
shed-batch-after-(or before)-own-batch within a window, the same batch
ordering approximation as (2). While an orchestrator is attached the
simulator also maintains a LIVE completion view (edge completions exact;
offloaded completions streamed through an incremental copy of the cloud
solve, equal to the final deferred solve up to chunked-cumsum round-off)
so a QoS monitor can watch per-cell tails mid-run; final telemetry still
comes from the exact deferred solve.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.gatepath import GateTable
from repro.fleet.telemetry import FleetTelemetry
from repro.fleet.topology import FleetTopology
from repro.offload import latency as L


def fifo_done(t: np.ndarray, service: np.ndarray, free_s: float) -> np.ndarray:
    """Completion times of a FIFO single-server queue, vectorized.

    t: sorted job-ready times; service: per-job service times; free_s:
    when the server frees up from earlier work. Solves
    done_i = max(t_i, done_{i-1}) + s_i via cumsum + running max.
    """
    csum = np.cumsum(service)
    x = t - (csum - service)  # t_i - cumsum_{<i}
    if x.size:
        x[0] = max(x[0], free_s)
    return np.maximum.accumulate(x) + csum


@dataclass
class FleetConfig:
    window_s: float = 0.25  # arrival-window width (config switch granularity)
    #: ((start_s, end_s, factor), ...) cloud brownout intervals: a cloud job
    #: whose uplink transfer completes in [start, end) has its service time
    #: scaled by `factor` (capacity loss at the shared tier). Empty = the
    #: pre-orchestration behavior, bit for bit.
    cloud_slowdowns: Tuple[Tuple[float, float, float], ...] = ()


class _CloudJobs:
    """Every offloaded job of the whole run, as growing columns.

    The cloud tier is solved ONCE, after the windowed loop, over all jobs
    sorted by uplink-completion time. Processing it window-by-window would
    be wrong, not just inexact: a saturated cell's uplink emits transfers
    whose completion lies far in the future, and feeding those to the
    cloud in *generation* order would make jobs from healthy cells queue
    behind phantom busy servers. Nothing downstream of the cloud feeds
    back into the simulation, so deferring it is exact.
    """

    def __init__(self):
        self.t: List[np.ndarray] = []
        self.service: List[np.ndarray] = []
        self.win: List[np.ndarray] = []  # index into the window-cols list
        self.pos: List[np.ndarray] = []  # index into that window's arrays

    def add(self, t, service, win, pos):
        self.t.append(t)
        service = np.asarray(service, np.float64)
        self.service.append(
            np.full(len(t), service) if service.ndim == 0 else service
        )
        self.win.append(np.full(len(t), win, np.int64))
        self.pos.append(pos)


class _LiveCloud:
    """Streaming copy of the deferred cloud solve, for the QoS monitor.

    The deferred global solve is exact but only runs after the last
    window; a QoS monitor needs completions DURING the run. Any cloud job
    generated in window w has transfer-completion >= w's start, so at a
    boundary t0 every pending job with t < t0 is final: popping those in
    (stable) sorted order reproduces the deferred solve's global ordering
    batch by batch, and each of the K residue-class chains streams with a
    carried server-free time. Equal to the deferred solve up to chunked-
    cumsum round-off; never fed back into the final telemetry columns.
    """

    def __init__(self, k_servers: int):
        self.k = k_servers
        self._pend: List[np.ndarray] = []  # [t, service, cell, arrival, ded]
        self._free = np.zeros(k_servers)
        self._n_popped = 0

    def add(self, t, service, cell, arrival, deadline):
        ded = np.nan if deadline is None else float(deadline)
        self._pend.append(
            np.stack([
                t, np.broadcast_to(service, t.shape),
                np.full(len(t), cell, np.float64), arrival,
                np.full(len(t), ded),
            ])
        )

    def pop(self, now: float):
        """-> (cell, completion, latency, missed) for every pending job
        whose transfer completed before `now`."""
        if not self._pend:
            return None
        cols = np.concatenate(self._pend, axis=1)
        ready = cols[0] < now
        if not ready.any():
            return None
        keep = cols[:, ~ready]
        self._pend = [keep] if keep.shape[1] else []
        t, service, cell, arrival, ded = cols[:, ready]
        order = np.argsort(t, kind="stable")
        t, service = t[order], service[order]
        cell, arrival, ded = cell[order], arrival[order], ded[order]
        done = np.empty(len(t))
        idx = self._n_popped + np.arange(len(t))
        for r in range(self.k):
            m = idx % self.k == r
            if m.any():
                out = fifo_done(t[m], service[m], float(self._free[r]))
                done[m] = out
                self._free[r] = out[-1]
        self._n_popped += len(t)
        lat = done - arrival
        missed = np.where(np.isnan(ded), -1, (lat > ded).astype(np.int8))
        return cell.astype(np.int64), done, lat, missed.astype(np.int8)


class FleetSimulator:
    """Run a whole fleet topology through the windowed pipeline.

    table: the shared `GateTable` (all cells serve the same model and
    deployed plan/bank; per-cell state is (branch, p_tar), moved by the
    optional fleet controller). Each cell's `ContextSchedule` must visit
    only contexts the table covers; cells without a schedule serve the
    table's only context. The table's selected `GateBackend` decides how
    each window gates (host numpy fancy-indexing, or one jitted JAX call
    on device-resident tables).
    """

    def __init__(
        self,
        table: GateTable,
        topology: FleetTopology,
        profile: L.LatencyProfile,
        config: Optional[FleetConfig] = None,
        controller=None,
        payload_nbytes: Optional[Callable[[int], int]] = None,
        orchestrator=None,
        obs=None,
    ):
        self.table = table
        self.topology = topology
        self.profile = profile
        self.config = config or FleetConfig()
        self.orchestrator = orchestrator
        # observability (repro.obs.Observability). Zero-perturbation: the
        # obs=None path adds no columns and runs no emission; pinned
        # bit-exactly by tests/test_obs.py. Trace emission is SAMPLED
        # (obs.trace_sample_every) and happens after the deferred cloud
        # solve, from the final patched columns.
        self.obs = obs
        self._tracing = obs is not None and obs.trace is not None
        self._metrics = None if obs is None else obs.metrics
        self._audit = None if obs is None else obs.audit
        # streaming reliability-bin sketch (repro.obs.calibration),
        # accumulated columnarly per window at gate time, keyed by the
        # ORIGIN cell / active context / deployed branch
        self._cal = None if obs is None else getattr(obs, "calibration", None)
        if obs is not None and obs.audit is not None \
                and controller is not None and hasattr(controller, "audit"):
            controller.audit = obs.audit
        if self.config.window_s <= 0:
            raise ValueError("window_s must be positive")
        self.controller = controller
        if controller is not None:
            ratio = controller.interval_s / self.config.window_s
            if abs(ratio - round(ratio)) > 1e-9 or round(ratio) < 1:
                raise ValueError(
                    f"controller interval {controller.interval_s}s must be a "
                    f"positive multiple of window_s={self.config.window_s}s"
                )
            self._ticks_per_update = int(round(ratio))
            if not set(controller.branches) <= set(table.branches):
                raise ValueError(
                    f"controller may deploy branches {controller.branches} "
                    f"but the table only serves {table.branches}"
                )
        if payload_nbytes is None:
            from repro.models.convnet import payload_bytes  # the paper's model

            payload_nbytes = payload_bytes
        self.payload_nbytes = payload_nbytes

        plan = table.plan
        branch = plan.exit_index + 1
        if branch not in table.branches:
            raise ValueError(
                f"plan deploys branch {branch} but the table only serves "
                f"{table.branches}"
            )
        self._initial_state = (
            branch, float(plan.p_tar), int(getattr(plan, "compression_level", 0))
        )
        self._state: List[Tuple[int, float, int]] = []
        # estimator verdicts (bank key indices) -> table context ids, for
        # the context-mix telemetry the controller windows
        self._bank_to_table = np.asarray(
            [table.ctx_index.get(k, -1) for k in table.bank_keys] or [-1],
            np.int64,
        )

        # per-cell schedule-context -> table-context id mapping
        self._sched_map: List[Optional[np.ndarray]] = []
        self._static_ctx: List[int] = []
        for cell in topology.cells:
            if cell.schedule is None:
                if len(table.ctx_keys) != 1:
                    raise ValueError(
                        "cells without a schedule need a single-context "
                        f"table; this one covers {table.ctx_keys}"
                    )
                self._sched_map.append(None)
                self._static_ctx.append(0)
            else:
                missing = set(cell.schedule.contexts) - set(table.ctx_keys)
                if missing:
                    raise ValueError(
                        f"schedule visits contexts with no logits: "
                        f"{sorted(missing)}"
                    )
                self._sched_map.append(
                    np.asarray(
                        [table.ctx_index[k] for k in cell.schedule.contexts],
                        np.int64,
                    )
                )
                self._static_ctx.append(-1)
            if len(cell.workload) and int(cell.workload.sample.max()) >= table.n_samples:
                raise ValueError("workload samples exceed the gate table")

        # orchestration state (reset per run; see `run`)
        self._active = topology.initial_active_mask()
        self._cell_tables: List[Optional[GateTable]] = [None] * topology.n_cells
        self._backhaul_free = np.zeros(topology.n_cells)
        self._live: Optional[_LiveCloud] = None
        self.shed_counts = np.zeros(topology.n_cells, np.int64)

    # ------------------------------------------------- orchestration surface
    def set_active(self, cell: int, active: bool) -> None:
        """Flip a cell's activation (churn engine): an inactive cell's
        arrivals are shed to the nearest live ring neighbor (or the cloud
        backhaul) until it comes back."""
        self._active[cell] = bool(active)

    def active_mask(self) -> np.ndarray:
        return self._active.copy()

    def set_cell_table(self, cell: int, table: Optional[GateTable]) -> None:
        """Override one cell's gate table (canary / fleet-wide rollout of a
        new `PlanBank`); None restores the fleet-wide incumbent. The
        override must serve the same contexts, samples, branches, and bank
        keys -- a rollout changes CALIBRATION, not the data the fleet is
        benchmarked on."""
        if table is not None:
            base = self.table
            if (
                table.ctx_keys != base.ctx_keys
                or table.n_samples != base.n_samples
                or table.branches != base.branches
                or table.bank_keys != base.bank_keys
            ):
                raise ValueError(
                    "cell table override must match the incumbent's contexts/"
                    "samples/branches/bank keys"
                )
        self._cell_tables[cell] = table

    def _table_for(self, cell: int) -> GateTable:
        t = self._cell_tables[cell]
        return self.table if t is None else t

    def _payload_nbytes_for(self, branch: int, level: int) -> int:
        """Wire bytes for one offload from `branch` at codec `level`: the
        caller-supplied raw table untouched at level 0 (bit-exact legacy
        pricing), the codec's analytic size otherwise."""
        raw = self.payload_nbytes(branch)
        if level == 0:
            return raw
        from repro.kernels.compress import scaled_payload_nbytes

        return scaled_payload_nbytes(raw, level)

    def _energy_col(self, edge_time_s, on_device, branch, level) -> np.ndarray:
        """Per-request edge-side energy column: compute J for every gated
        request plus radio J for the offloaded payload's wire bytes (see
        `repro.offload.latency.energy_per_request_j`)."""
        compute_j = edge_time_s * self.profile.edge_power_w
        radio_j = (
            float(self._payload_nbytes_for(branch, level)) * 8.0
            * self.profile.uplink_j_per_bit
        )
        return np.where(on_device, compute_j, compute_j + radio_j)

    def _cloud_scale_at(self, times: np.ndarray) -> np.ndarray:
        scale = np.ones(len(times))
        for a, b, f in self.config.cloud_slowdowns:
            scale[(times >= a) & (times < b)] *= f
        return scale

    # ----------------------------------------------------------------- run
    def run(self) -> FleetTelemetry:
        topo, cfg, table = self.topology, self.config, self.table
        tel = FleetTelemetry(
            topo.n_cells,
            context_keys=table.ctx_keys,
            bank_keys=table.bank_keys or None,
        )
        for c, cell in enumerate(topo.cells):
            tel.set_arrivals(c, cell.workload.arrival_s)

        # every run starts from the plan's deployment (a controller from a
        # previous run() must not leak its final decisions into this one),
        # and from the topology's declared activation mask / no overrides
        self._state = [self._initial_state for _ in topo.cells]
        self._active = topo.initial_active_mask()
        self._cell_tables = [None] * topo.n_cells
        self._backhaul_free = np.zeros(topo.n_cells)
        self.shed_counts = np.zeros(topo.n_cells, np.int64)
        orch = self.orchestrator
        self._live = _LiveCloud(topo.cloud_servers) if orch is not None else None
        dev_free = [np.zeros(cell.n_devices) for cell in topo.cells]
        uplink_free = np.zeros(topo.n_cells)
        ptr = np.zeros(topo.n_cells, np.int64)
        n_windows = int(math.ceil(max(topo.horizon_s, 0.0) / cfg.window_s)) + 1

        jobs = _CloudJobs()
        window_cols = []  # (cell, dict of columns), patched by the cloud solve
        if orch is not None:
            orch.attach(self, tel, audit=self._audit)
        for w in range(n_windows):
            t0, t1 = w * cfg.window_s, (w + 1) * cfg.window_s
            if orch is not None:
                if w > 0:
                    self._pop_live(t0, tel)
                orch.on_window(self, tel, w, t0)
            if (
                self.controller is not None
                and w > 0
                and w % self._ticks_per_update == 0
            ):
                self._apply_controller(t0, tel)

            for c, cell in enumerate(topo.cells):
                arr = cell.workload.arrival_s
                hi = int(np.searchsorted(arr, t1, side="left"))
                lo = int(ptr[c])
                ptr[c] = hi
                if hi == lo:
                    continue
                if self._active[c]:
                    branch, p_tar, clevel = self._state[c]
                    cols = self._edge_and_gate(
                        c, cell, lo, hi, branch, p_tar, clevel, dev_free[c]
                    )
                    serve_c = c
                else:
                    serve_c, cols = self._shed_window(
                        c, cell, lo, hi, dev_free, tel
                    )
                if self._tracing:
                    cols["serve_cell"] = serve_c
                est = cols["est_id"]
                tel.observe_contexts(
                    serve_c if serve_c >= 0 else c,
                    cols["edge_done"],
                    np.where(est >= 0, self._bank_to_table[np.maximum(est, 0)],
                             np.where(est == -2, cols["ctx_id"], -1)),
                )
                off = ~cols["on_device"]
                if self._metrics is not None:
                    self._metrics.inc("fleet_requests_total", hi - lo, cell=c)
                    n_off = int(off.sum())
                    if n_off:
                        self._metrics.inc("fleet_offloaded_total", n_off,
                                          cell=c)
                if off.any():
                    branch = int(cols["branch"][0])
                    order = np.argsort(cols["edge_done"][off], kind="stable")
                    pos = np.flatnonzero(off)[order]
                    t_ready = cols["edge_done"][pos]
                    nbytes = float(self._payload_nbytes_for(
                        branch, int(cols["clevel"][0])
                    ))
                    if self._metrics is not None:
                        # uplink AND backhaul payloads count: both cross a
                        # link toward the cloud, attributed to the origin
                        # cell (matching the trace records' `cell`)
                        self._metrics.inc("fleet_uplink_bytes_total",
                                          nbytes * len(pos), cell=c)
                    if serve_c >= 0:
                        net = topo.cells[serve_c].network
                        rates = net.rates_bps(t_ready)
                        done = fifo_done(t_ready, nbytes * 8.0 / rates,
                                         float(uplink_free[serve_c]))
                        # reprice at the actual transfer start (one fixed-
                        # point pass; exact for fixed links)
                        comm = nbytes * 8.0 / net.rates_bps(
                            done - nbytes * 8.0 / rates
                        )
                        done = fifo_done(t_ready, comm,
                                         float(uplink_free[serve_c]))
                        uplink_free[serve_c] = done[-1]
                        tel.observe_bandwidth(serve_c, t_ready,
                                              nbytes * 8.0 / comm)
                    else:  # whole-fleet outage: nominal-rate cloud backhaul
                        comm = np.full(
                            len(t_ready),
                            nbytes * 8.0 / self.profile.uplink_bps,
                        )
                        done = fifo_done(t_ready, comm,
                                         float(self._backhaul_free[c]))
                        self._backhaul_free[c] = done[-1]
                    service = L.cloud_time(self.profile, branch)
                    if cfg.cloud_slowdowns:
                        service = service * self._cloud_scale_at(done)
                    if self._tracing:
                        cols["uplink_start"][pos] = done - comm
                        cols["uplink_done"][pos] = done
                        cols["cloud_service"][pos] = service
                    jobs.add(done, service, len(window_cols), pos)
                    if self._live is not None:
                        self._live.add(done, service, c,
                                       cols["arrival"][pos], cols["deadline"])
                if self._live is not None:
                    self._observe_edge_live(c, cols, tel)
                window_cols.append((c, cols))

        self._cloud_solve(jobs, window_cols)
        self._flush(window_cols, tel)
        if self.obs is not None and self.obs.enabled:
            self._finish_obs(window_cols, tel)
        if orch is not None:
            orch.finish(self, tel, n_windows * cfg.window_s)
        return tel

    def _pop_live(self, now: float, tel) -> None:
        """Stream cloud completions whose transfer finished before `now`
        into the live QoS view (see `_LiveCloud`)."""
        live = self._live.pop(now)
        if live is None:
            return
        cells, done, lat, missed = live
        for c in np.unique(cells):
            m = cells == c
            tel.observe_live_latency(int(c), done[m], lat[m], missed[m])

    def _observe_edge_live(self, c, cols, tel) -> None:
        """Edge-resolved live observations: on-device requests complete at
        edge_done, so their latency/deadline/gate outcomes are final the
        moment the window is served. The calibration stream takes EVERY
        gated request (offloaded ones included -- the reliability
        diagram judges the gate's confidence, not who answered), with
        EDGE correctness, which at this point in the run is what
        cols['correct'] holds (the cloud solve patches it later)."""
        on = cols["on_device"]
        conf = cols.get("conf")
        if conf is not None:
            ec = cols.get("edge_correct", cols["correct"])
            g = np.isfinite(conf) & (ec >= 0)
            if g.any():
                tel.observe_live_calibration(
                    c, cols["edge_done"][g], conf[g], ec[g],
                    on[g].astype(np.int8),
                )
        if not on.any():
            return
        t = cols["edge_done"][on]
        lat = t - cols["arrival"][on]
        ded = cols["deadline"]
        missed = (
            np.full(len(lat), -1, np.int8)
            if ded is None
            else (lat > ded).astype(np.int8)
        )
        tel.observe_live_latency(c, t, lat, missed)
        ok = cols["correct"][on] >= 0
        if ok.any():
            tel.observe_live_gate(
                c, t[ok], cols["correct"][on][ok], cols["p_tar"][on][ok]
            )

    # ---------------------------------------------------------- edge tier
    def _edge_and_gate(self, c, cell, lo, hi, branch, p_tar, clevel, dev_free):
        wl = cell.workload
        return self._serve_cols(
            c, wl.arrival_s[lo:hi], wl.sample[lo:hi], wl.device[lo:hi],
            cell.n_devices, branch, p_tar, clevel, dev_free,
            ctx_cell=c, deadline_s=cell.deadline_s,
        )

    def _ctx_ids(self, c: int, times: np.ndarray) -> np.ndarray:
        """Table context ids in force at `times` under cell c's regime."""
        if self._sched_map[c] is None:
            return np.full(len(times), self._static_ctx[c], np.int64)
        return self._sched_map[c][
            self.topology.cells[c].schedule.context_ids_at(times)
        ]

    def _serve_cols(self, serve_c, arr, samples, devices, n_devices,
                    branch, p_tar, clevel, dev_free, ctx_cell, deadline_s):
        """Serve one window's columns on cell `serve_c`'s devices and gate
        table, under cell `ctx_cell`'s context regime (they differ only
        when a dead cell's load was shed here)."""
        s_edge = L.edge_time(self.profile, branch)
        n = len(arr)
        edge_done = np.empty(n)
        for d in range(n_devices):
            m = devices == d
            k = int(m.sum())
            if k == 0:
                continue
            done = fifo_done(arr[m], np.full(k, s_edge), float(dev_free[d]))
            edge_done[m] = done
            dev_free[d] = done[-1]

        ctx_ids = self._ctx_ids(ctx_cell, edge_done)
        table = self._table_for(serve_c)
        conf, pred, on = table.gate_window(ctx_ids, samples, branch, p_tar)
        est = table.est_ids(ctx_ids, samples)
        correct = table.correct(samples, pred)
        if self._cal is not None and correct is not None:
            # columnar sketch update at gate time: EDGE correctness
            # (before any cloud answer patches it), attributed to the
            # origin cell's context regime
            for cid in np.unique(ctx_ids):
                m = ctx_ids == cid
                self._cal.update(ctx_cell, table.ctx_keys[int(cid)], branch,
                                 conf[m], correct[m], on[m])
        cols = {
            "arrival": arr,
            "samples": samples,
            "edge_done": edge_done,
            "complete": edge_done.copy(),
            "on_device": on,
            "ctx_id": ctx_ids,
            "est_id": np.full(n, -2, np.int64) if est is None else est,
            "correct": (
                np.full(n, -1, np.int8)
                if correct is None
                else correct.astype(np.int8)
            ),
            "branch": np.full(n, branch, np.int64),
            "p_tar": np.full(n, p_tar),
            "clevel": np.full(n, int(clevel), np.int64),
            "energy_j": self._energy_col(
                L.edge_time(self.profile, branch), on, branch, int(clevel)
            ),
            "deadline": deadline_s,
        }
        if self._tracing:
            self._add_trace_cols(cols, conf)
        elif self._live is not None:
            # the live calibration stream needs the gate confidences even
            # without a trace sink (QoS windows ECE/coverage from them)
            cols["conf"] = np.asarray(conf, np.float64)
        return cols

    def _add_trace_cols(self, cols, conf) -> None:
        """Extra per-request columns kept ONLY while a trace sink is
        attached (never fed to telemetry): the gate confidence, the
        EDGE correctness (cols['correct'] before the cloud solve patches
        offloaded rows), plus the uplink/cloud span timestamps `run`
        stamps after the FIFO solves. conf=None marks a backhauled
        window where no gate ran."""
        n = len(cols["arrival"])
        cols["conf"] = (
            np.full(n, np.nan) if conf is None
            else np.asarray(conf, np.float64)
        )
        cols["edge_correct"] = cols["correct"].copy()
        cols["uplink_start"] = np.full(n, np.nan)
        cols["uplink_done"] = np.full(n, np.nan)
        cols["cloud_service"] = np.full(n, np.nan)

    def _shed_window(self, c, cell, lo, hi, dev_free, tel):
        """A dead cell's window: serve it on the nearest ACTIVE ring
        neighbor (that cell's devices, uplink, deployed state, and gate
        table; the ORIGIN cell's context regime and deadline), or, with no
        live neighbor anywhere, backhaul the whole window straight to the
        shared cloud at the nominal uplink rate. Latency columns stay
        attributed to the origin cell either way."""
        wl = cell.workload
        arr = wl.arrival_s[lo:hi]
        samples = wl.sample[lo:hi]
        n = hi - lo
        self.shed_counts[c] += n
        if self._metrics is not None:
            self._metrics.inc("fleet_shed_total", n, cell=c)
        for s in self.topology.shed_order(c):
            if self._active[s]:
                host = self.topology.cells[int(s)]
                branch, p_tar, clevel = self._state[int(s)]
                cols = self._serve_cols(
                    int(s), arr, samples,
                    wl.device[lo:hi] % host.n_devices, host.n_devices,
                    branch, p_tar, clevel, dev_free[int(s)],
                    ctx_cell=c, deadline_s=cell.deadline_s,
                )
                tel.observe_shed_arrivals(int(s), arr)
                if self._audit is not None:
                    self._audit.record(
                        float(arr[0]), "simulator", "shed_route", cell=c,
                        host_cell=int(s), backhaul=False, requests=int(n))
                return int(s), cols
        # whole-fleet outage: every request offloads over the backhaul
        if self._audit is not None:
            self._audit.record(
                float(arr[0]), "simulator", "shed_route", cell=c,
                host_cell=None, backhaul=True, requests=int(n))
        if self._cal is not None:
            # no gate ran: count the window so sketch totals still match
            # the fleet_requests_total counter
            self._cal.note_ungated(c, n)
        branch, p_tar, clevel = self._state[c]
        cols = {
            "arrival": arr,
            "samples": samples,
            "edge_done": arr.copy(),
            "complete": arr.copy(),
            "on_device": np.zeros(n, bool),
            "ctx_id": self._ctx_ids(c, arr),
            "est_id": np.full(n, -2, np.int64),
            "correct": np.full(n, -1, np.int8),
            "branch": np.full(n, branch, np.int64),
            "p_tar": np.full(n, p_tar),
            "clevel": np.full(n, int(clevel), np.int64),
            # no edge service ran on a backhauled window: radio J only
            "energy_j": self._energy_col(0.0, np.zeros(n, bool), branch,
                                         int(clevel)),
            "deadline": cell.deadline_s,
        }
        if self._tracing:
            self._add_trace_cols(cols, None)
        return -1, cols

    # ---------------------------------------------------------- cloud tier
    def _cloud_solve(self, jobs, window_cols):
        """One global K-server FIFO solve over every offloaded job, sorted
        by uplink completion: job i waits for job i-K (deterministic
        service), so each of the K residue classes is an independent
        single-server chain. Exact for uniform service times; with mixed
        branches in flight the completion order can locally deviate from
        the event heap's argmin-server rule (documented approximation)."""
        if not jobs.t:
            return
        t = np.concatenate(jobs.t)
        service = np.concatenate(jobs.service)
        win_of = np.concatenate(jobs.win)
        pos_of = np.concatenate(jobs.pos)
        order = np.argsort(t, kind="stable")
        t, service = t[order], service[order]
        win_of, pos_of = win_of[order], pos_of[order]
        k = self.topology.cloud_servers
        done = np.empty(len(t))
        for r in range(min(k, len(t))):
            idx = np.arange(r, len(t), k)
            done[idx] = fifo_done(t[idx], service[idx], 0.0)
        for w in np.unique(win_of):
            m = win_of == w
            cell_of_w, cols = window_cols[int(w)]
            table = self._table_for(cell_of_w)
            pos = pos_of[m]
            cols["complete"][pos] = done[m]
            # the deployed codec level is constant within a window, so the
            # per-level main-head table resolves once per window
            cpred = table.cloud_pred(cols["ctx_id"][pos],
                                     cols["samples"][pos],
                                     level=int(cols["clevel"][0]))
            correct = table.correct(cols["samples"][pos], cpred)
            if correct is not None:
                cols["correct"][pos] = correct.astype(np.int8)

    def _flush(self, window_cols, tel):
        for c, cols in window_cols:
            lat = cols["complete"] - cols["arrival"]
            if cols["deadline"] is None:
                missed = np.full(len(lat), -1, np.int8)
            else:
                missed = (lat > cols["deadline"]).astype(np.int8)
            tel.add_window(
                c,
                latency_s=lat,
                on_device=cols["on_device"],
                correct=cols["correct"],
                p_tar=cols["p_tar"],
                branch=cols["branch"],
                ctx_id=cols["ctx_id"],
                est_id=cols["est_id"],
                missed=missed,
                energy_j=cols["energy_j"],
            )

    # ------------------------------------------------------- observability
    def _finish_obs(self, window_cols, tel) -> None:
        """Post-run export: conservation gauges (expected vs completed vs
        offloaded, straight from the final patched columns), the fleet
        telemetry summary as gauges, then sampled trace emission."""
        if self._metrics is not None:
            from repro.obs import fleet_metrics

            offloaded = sum(
                int((~cols["on_device"]).sum()) for _, cols in window_cols
            )
            self._metrics.set_gauge(
                "fleet_requests_expected", self.topology.n_requests
            )
            self._metrics.set_gauge("fleet_requests_completed", tel.requests())
            self._metrics.set_gauge("fleet_offloaded_telemetry", offloaded)
            if self._tracing:
                self._metrics.set_gauge(
                    "trace_sample_every",
                    max(1, int(self.obs.trace_sample_every)),
                    source="fleet",
                )
            fleet_metrics(tel, self._metrics)
            if self._cal is not None:
                from repro.obs import export_calibration

                export_calibration(self._cal, self._metrics)
        if self._tracing:
            self._emit_traces(window_cols)

    def _emit_traces(self, window_cols) -> None:
        """Emit sampled per-request trace records from the final patched
        columns. Sampling is a deterministic global stride over the
        flattened window order, so a run emits the same records every
        time; req_id is the request's global index in that order. Edge
        service start is recovered exactly (deterministic service time);
        uplink/cloud span edges were stamped during the FIFO solves."""
        from repro.obs import build_spans, request_record

        sink = self.obs.trace
        every = max(1, int(self.obs.trace_sample_every))
        ctx_keys = self.table.ctx_keys
        bank_keys = self.table.bank_keys
        counter = 0
        emitted = 0
        for c, cols in window_cols:
            n = len(cols["arrival"])
            backhaul = int(cols["serve_cell"]) < 0
            branch = int(cols["branch"][0])
            s_edge = 0.0 if backhaul else L.edge_time(self.profile, branch)
            clevel = int(cols["clevel"][0])
            pn_off = float(self._payload_nbytes_for(branch, clevel))
            for i in range((-counter) % every, n, every):
                arrival = float(cols["arrival"][i])
                edge_done = float(cols["edge_done"][i])
                complete = float(cols["complete"][i])
                on = bool(cols["on_device"][i])
                edge_start = edge_done - s_edge
                if on:
                    spans = build_spans(arrival, edge_start, edge_done)
                else:
                    spans = build_spans(
                        arrival, edge_start, edge_done,
                        uplink_start_s=float(cols["uplink_start"][i]),
                        uplink_done_s=float(cols["uplink_done"][i]),
                        cloud_start_s=(
                            complete - float(cols["cloud_service"][i])
                        ),
                        complete_s=complete,
                    )
                if backhaul:
                    gate = None  # no gate ran: the window went straight up
                else:
                    ctx_id = int(cols["ctx_id"][i])
                    est_id = int(cols["est_id"][i])
                    ec = int(cols["edge_correct"][i])
                    gate = {
                        "branch": branch,
                        "p_tar": float(cols["p_tar"][i]),
                        "confidence": float(cols["conf"][i]),
                        "criterion": "confidence",
                        "context": ctx_keys[ctx_id] if ctx_id >= 0 else None,
                        "est_context": (
                            bank_keys[est_id]
                            if bank_keys and 0 <= est_id < len(bank_keys)
                            else None
                        ),
                        # EDGE correctness at gate time (-1 = unlabeled),
                        # what the calibration sketch accumulated
                        "correct": None if ec < 0 else ec,
                    }
                    if not on:
                        gate["compression_level"] = clevel
                sink.emit(request_record(
                    "fleet", counter + i, arrival, complete, on, spans,
                    gate=gate, cell=c,
                    payload_nbytes=None if on else pn_off,
                ))
                emitted += 1
            counter += n
        if self._metrics is not None and emitted:
            self._metrics.inc("trace_records_total", emitted, source="fleet")

    # ---------------------------------------------------------- controller
    def _apply_controller(self, t: float, tel: FleetTelemetry) -> None:
        if self.orchestrator is not None:
            mon = getattr(self.orchestrator, "monitor", None)
            if mon is not None:
                # satellite wiring (ROADMAP): the QoS monitor's trip verdict
                # IS the controller's distress signal -- a tripped cell takes
                # the rescue concession until the monitor clears it
                decisions = self.controller.update(
                    t, tel, active=self._active,
                    distressed=mon.tripped_mask(),
                )
            else:
                decisions = self.controller.update(t, tel, active=self._active)
        else:
            decisions = self.controller.update(t, tel)
        if len(decisions) != self.topology.n_cells:
            raise ValueError(
                f"controller returned {len(decisions)} decisions for "
                f"{self.topology.n_cells} cells"
            )
        for c, dec in enumerate(decisions):
            # legacy controllers return (branch, p_tar) 2-tuples; the
            # compression-aware fleet controller appends the codec level
            if len(dec) == 2:
                branch, p_tar = dec
                level = 0
            else:
                branch, p_tar, level = dec
            state = (int(branch), float(p_tar), int(level))
            if state != self._state[c]:
                tel.record_controller(t, c, branch, float(p_tar),
                                      level=int(level))
            self._state[c] = state
