"""Batched gate evaluation for the fleet simulator.

`FleetGateTable` is the vectorized analogue of the serving cores
(`LogitsCore` / `ContextualLogitsCore`): the same per-(context, expert,
branch) confidence/prediction precompute, stored as dense stacked arrays
indexed by integer context ids so a whole event window gates with one
fancy-indexing expression instead of one Python call per request.

All gate math goes through the batched `OffloadPlan.gate_block` /
`PlanBank.gate_block` path (i.e. the existing calibrator states and
`gate_statistics`), so fleet decisions agree bit-for-bit with the
event-driven runtime on the same logits -- the equivalence the
single-cell limit tests pin down.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.bank import PlanBank
from repro.core.policy import OffloadPlan

#: context id used when a core has no drift axis (plain logits, no schedule)
STATIC_CONTEXT = "__all__"


class FleetGateTable:
    """Precomputed per-(context, branch) gate blocks under per-sample
    expert selection.

    exit_logits_by_context: {context: {physical_branch: (N, C) logits}};
    final_logits_by_context the matching cloud main heads. For the
    non-drifting case pass ``{STATIC_CONTEXT: {...}}`` (or use
    `FleetGateTable.from_logits`).

    plan_or_bank decides calibration exactly as in `ContextualLogitsCore`:
    a single `OffloadPlan` applies one calibrator set everywhere; a
    `PlanBank` picks each sample's expert -- via its embedded estimator on
    `features_by_context` (the honest edge-side path; unknown verdicts
    fall back to the default plan) or by the true context (oracle bound).

    The precompute gathers, per (true context, branch), each sample's
    confidence under ITS expert plan into one dense (n_ctx, n_branch, N)
    array, so the runtime cost of a window is one fancy-index + compare.
    """

    def __init__(
        self,
        exit_logits_by_context: Dict[str, Dict[int, np.ndarray]],
        final_logits_by_context: Dict[str, np.ndarray],
        plan_or_bank,
        labels: Optional[np.ndarray] = None,
        features_by_context: Optional[Dict[str, np.ndarray]] = None,
    ):
        if isinstance(plan_or_bank, PlanBank):
            self.bank: Optional[PlanBank] = plan_or_bank
            self.plan = plan_or_bank.default_plan
            criteria = {p.criterion for p in plan_or_bank.plans.values()}
        else:
            self.bank = None
            self.plan = plan_or_bank
            criteria = {plan_or_bank.criterion}
        if criteria != {"confidence"}:
            # every expert, not just the default: the ContextualLogitsCore
            # contract, so the fleet cannot silently serve a bank the
            # event runtime would reject
            raise ValueError(
                "the fleet gate thresholds the runtime's moving confidence "
                f"target; plan criteria {sorted(criteria)} are not supported"
            )
        self.ctx_keys: List[str] = sorted(exit_logits_by_context)
        self.ctx_index = {k: i for i, k in enumerate(self.ctx_keys)}
        if set(final_logits_by_context) != set(self.ctx_keys):
            raise ValueError("exit and final logits must cover the same contexts")
        self.branches = sorted(next(iter(exit_logits_by_context.values())))
        self._branch_index = {b: i for i, b in enumerate(self.branches)}
        for ctx, per_branch in exit_logits_by_context.items():
            if sorted(per_branch) != self.branches:
                raise ValueError(f"context {ctx!r} covers different branches")
        n = int(np.asarray(final_logits_by_context[self.ctx_keys[0]]).shape[0])
        self.n_samples = n

        # per-(ctx, sample) expert selection, as in ContextualLogitsCore:
        # estimator verdicts on real features when available, oracle else
        self._oracle = not (
            self.bank is not None
            and self.bank.estimator is not None
            and features_by_context is not None
        )
        bank_keys = self.bank.contexts if self.bank is not None else []
        # est ids index into bank_keys; -1 = unknown verdict; whole array
        # None in oracle mode (no estimator to report in telemetry)
        self._est_ids: Optional[np.ndarray] = None
        if not self._oracle:
            est = self.bank.estimator
            est_ids = np.empty((len(self.ctx_keys), n), np.int64)
            key_to_bank = {k: i for i, k in enumerate(bank_keys)}
            est_to_bank = np.asarray(
                [key_to_bank[k] for k in est.contexts], np.int64
            )
            for ci, ctx in enumerate(self.ctx_keys):
                if ctx not in features_by_context:
                    raise ValueError(f"no features for context {ctx!r}")
                ids = est.predict_ids(features_by_context[ctx])
                est_ids[ci] = np.where(ids >= 0, est_to_bank[ids], -1)
            self._est_ids = est_ids

        self.conf = np.empty((len(self.ctx_keys), len(self.branches), n))
        self.pred = np.empty_like(self.conf, dtype=np.int64)
        for ci, ctx in enumerate(self.ctx_keys):
            for bi, b in enumerate(self.branches):
                z = np.asarray(exit_logits_by_context[ctx][b])
                if self.bank is None:
                    c, p = self.plan.gate_block(z, branch=b - 1)
                    eids = None
                elif self._oracle:
                    eids = np.full(
                        n, bank_keys.index(ctx) if ctx in bank_keys else -1,
                        np.int64,
                    )
                    c, p, _ = self.bank.gate_block(
                        z, branch=b - 1, expert_ids=eids
                    )
                else:
                    c, p, _ = self.bank.gate_block(
                        z, branch=b - 1, expert_ids=self._est_ids[ci]
                    )
                self.conf[ci, bi], self.pred[ci, bi] = c, p
        self.final_pred = np.stack(
            [
                np.argmax(np.asarray(final_logits_by_context[k]), axis=-1)
                for k in self.ctx_keys
            ]
        ).astype(np.int64)
        self.labels = None if labels is None else np.asarray(labels, np.int64)
        self.bank_keys = bank_keys

    @classmethod
    def from_logits(
        cls,
        exit_logits: Dict[int, np.ndarray],
        final_logits: np.ndarray,
        plan: OffloadPlan,
        labels: Optional[np.ndarray] = None,
    ) -> "FleetGateTable":
        """Non-drifting table over one logit set (the `LogitsCore` case)."""
        return cls({STATIC_CONTEXT: exit_logits}, {STATIC_CONTEXT: final_logits},
                   plan, labels=labels)

    # ------------------------------------------------------- window lookups
    def branch_idx(self, branch: int) -> int:
        if branch not in self._branch_index:
            raise ValueError(
                f"branch {branch} not served (table covers {self.branches})"
            )
        return self._branch_index[branch]

    def gate(self, ctx_ids: np.ndarray, samples: np.ndarray, branch: int):
        """-> (confidence, edge prediction) for a whole window."""
        bi = self.branch_idx(branch)
        return self.conf[ctx_ids, bi, samples], self.pred[ctx_ids, bi, samples]

    def cloud_pred(self, ctx_ids: np.ndarray, samples: np.ndarray) -> np.ndarray:
        return self.final_pred[ctx_ids, samples]

    def est_ids(self, ctx_ids: np.ndarray, samples: np.ndarray) -> Optional[np.ndarray]:
        """Estimator verdicts (indices into `bank_keys`, -1 unknown) for a
        window; None when selection is oracle/single-plan."""
        if self._est_ids is None:
            return None
        return self._est_ids[ctx_ids, samples]

    def correct(self, samples: np.ndarray, preds: np.ndarray) -> Optional[np.ndarray]:
        if self.labels is None:
            return None
        return self.labels[samples] == preds
