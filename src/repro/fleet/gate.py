"""Deprecation shim: the batched fleet gate moved into the control plane.

`FleetGateTable` grew into the repo-wide dense gate table and now lives
in `repro.core.gatepath` as `GateTable`, where it routes both its
precompute and its window lookups through the selectable `GateBackend`
(host numpy or jitted JAX). This module keeps the long-standing
``repro.fleet.gate`` imports working; new code should import
`repro.core.gatepath.GateTable` (or `repro.fleet.FleetGateTable`, which
re-exports the same class).
"""
from __future__ import annotations

from repro.core.gatepath import (  # noqa: F401
    GateBackend,
    GateTable,
    JaxGateBackend,
    NumpyGateBackend,
    STATIC_CONTEXT,
    get_gate_backend,
)

#: Deprecated alias (the class itself -- isinstance checks keep working).
FleetGateTable = GateTable
