"""Deprecation shim: the batched fleet gate moved into the control plane.

`FleetGateTable` grew into the repo-wide dense gate table and now lives
in `repro.core.gatepath` as `GateTable`, where it routes both its
precompute and its window lookups through the selectable `GateBackend`
(host numpy or jitted JAX). Importing ANY name from this module emits a
`DeprecationWarning`; new code should import `repro.core.gatepath`
directly (or `repro.fleet.FleetGateTable`, which re-exports the same
class warning-free). The shim resolves lazily (PEP 562) so merely
importing `repro.fleet` stays silent.
"""
from __future__ import annotations

import warnings

from repro.core import gatepath as _gatepath

#: Every name this module ever re-exported; `FleetGateTable` is the
#: deprecated alias of `GateTable` (the class itself -- isinstance checks
#: keep working).
_SHIMMED = (
    "FleetGateTable",
    "GateBackend",
    "GateTable",
    "JaxGateBackend",
    "NumpyGateBackend",
    "STATIC_CONTEXT",
    "get_gate_backend",
)


def __getattr__(name: str):
    if name in _SHIMMED:
        target = "GateTable" if name == "FleetGateTable" else name
        warnings.warn(
            f"repro.fleet.gate.{name} is deprecated; import "
            f"repro.core.gatepath.{target} instead",
            DeprecationWarning,
            stacklevel=2,
        )
        return getattr(_gatepath, target)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_SHIMMED))
