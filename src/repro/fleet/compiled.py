"""Compiled fleet pipeline: the whole window loop as ONE jitted program.

`FleetSimulator.run` steps the fleet in host numpy: a Python loop over
(window, cell) batches, each doing a handful of small vectorized solves.
This module moves the full pipeline -- per-device FIFO edge queues ->
context lookup -> gate -> per-cell uplink (with Markov/trace link
repricing) -> the shared K-server cloud tier -- into one jitted JAX
program, `vmap`ped (and optionally `shard_map`ped over a "cells" mesh
axis, see `repro.sharding.fleet_mesh`) over serving cells:

* every FIFO recurrence becomes a masked `lax.associative_scan` over the
  max-plus semiring (`repro.fleet.maxplus`, property-tested against a
  per-request Python oracle);
* windows do not need a host loop at all: window boundaries only decide
  BATCH MEMBERSHIP (which uplink batch a request joins) and the per-batch
  link repricing order, so the host precomputes the (window, origin) ->
  serving-cell batch layout (including churn shed routing, which is pure
  time-based) and the device program runs the per-cell batch sequence
  under `lax.scan` -- that scan IS the window loop, fused;
* the `GateTable` conf block and the materialized context/network tables
  live device-resident for the whole run.

Parity contract (pinned by tests/test_gatepath.py, test_fleet.py,
test_fleet_properties.py, test_obs.py): against the host simulator on the
same scenario, every integer/bool column (gate decision, context id,
estimator verdict, correctness, shed routing, churn accounting) matches
EXACTLY -- the gate compares the same float64 table values against the
same threshold -- while latency columns match to float round-off (the
scan evaluates the same max-plus algebra with a different, tree-shaped
rounding order than the host's sequential cumsum).

Scope: the compiled path serves a STATIC deployment (no mid-run
controller rescoring, no canary rollout -- both mutate per-window state
the fused program has already consumed; use backend="numpy"/"jax" for
those). Churn shed/backhaul, cloud brownouts, the QoS monitor, and obs
trace/audit/metrics emission are fully supported: the device program
returns the per-request columns and the host replays the boundary
bookkeeping (orchestrator hooks, live QoS view, sampled traces) from
them, operation-for-operation in the host simulator's order.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.gatepath import GateTable, NumpyGateBackend, _next_pow2
from repro.fleet.simulator import FleetConfig, FleetSimulator, _LiveCloud
from repro.fleet.telemetry import FleetTelemetry
from repro.fleet.topology import FleetTopology
from repro.offload import latency as L
from repro.serving.drift import MarkovContextSchedule, PiecewiseSchedule
from repro.serving.network import FixedRateNetwork, MarkovNetwork, TraceNetwork

__all__ = ["CompiledGateBackend", "CompiledFleetSimulator"]

_BIG_DWELL = 1e18  # one-slot "slotted" table: floor(t / BIG) == 0 for any t


class CompiledGateBackend(NumpyGateBackend):
    """Backend marker that routes `run_fleet` to the compiled simulator.

    Table precompute and host-side window gates are the exact float64
    numpy path (this class IS `NumpyGateBackend` plus a name), so gate
    decisions on the compiled path are bit-identical to the host
    simulator's; what changes is WHERE the fleet pipeline runs -- see
    `CompiledFleetSimulator`.
    """

    name = "compiled"


@dataclass
class _Batch:
    """One (window, origin-cell) arrival batch and where it serves."""

    w: int
    origin: int
    serve: int  # serving cell, or -1 = whole-fleet-outage cloud backhaul
    lo: int
    hi: int
    shed: bool
    row0: int = 0  # start row in the serving cell's lane (or backhaul lane)
    blocal: int = 0  # batch index within the serving cell's lane


class CompiledFleetSimulator(FleetSimulator):
    """Drop-in `FleetSimulator` whose `run` executes device-side.

    mesh: None = single-device `vmap`; a `jax.sharding.Mesh` with axis
    "cells" = `shard_map` over cells (cell count must divide the mesh
    size evenly); "auto" = `repro.sharding.fleet_mesh()` when more than
    one device is visible.
    """

    def __init__(
        self,
        table: GateTable,
        topology: FleetTopology,
        profile: L.LatencyProfile,
        config: Optional[FleetConfig] = None,
        controller=None,
        payload_nbytes: Optional[Callable[[int], int]] = None,
        orchestrator=None,
        obs=None,
        mesh="auto",
    ):
        if controller is not None:
            raise ValueError(
                "the compiled fleet pipeline serves a static deployment; "
                "run the controller on the host backend "
                "(backend='numpy' or 'jax')"
            )
        if orchestrator is not None and getattr(orchestrator, "rollout", None) is not None:
            raise ValueError(
                "the compiled fleet pipeline does not support canary "
                "rollouts (per-window table swaps); use the host backend"
            )
        super().__init__(
            table, topology, profile, config=config, controller=None,
            payload_nbytes=payload_nbytes, orchestrator=orchestrator, obs=obs,
        )
        self.mesh = mesh
        self._programs: dict = {}

    # ------------------------------------------------------------- helpers
    def _resolve_mesh(self, n_cells: int):
        if self.mesh is None:
            return None
        if self.mesh == "auto":
            import jax

            if jax.device_count() > 1 and n_cells % jax.device_count() == 0:
                from repro.sharding import fleet_mesh

                return fleet_mesh()
            return None
        if n_cells % self.mesh.size != 0:
            raise ValueError(
                f"{n_cells} cells do not shard evenly over a "
                f"{self.mesh.size}-device mesh"
            )
        return self.mesh

    def _min_rate(self, net) -> float:
        if isinstance(net, MarkovNetwork):
            return min(net.good_bps, net.bad_bps)
        if isinstance(net, TraceNetwork):
            return float(np.min(net.trace_rates_bps))
        if isinstance(net, FixedRateNetwork):
            return float(net.bps)
        raise ValueError(
            f"compiled fleet pipeline supports Fixed/Markov/Trace networks, "
            f"not {type(net).__name__}; use the host backend"
        )

    def _net_tables(self, t_bound: float):
        """Materialize every cell's link-rate lookup device-side.

        Slotted mode replicates `MarkovNetwork.rates_bps` exactly
        (floor-division into sequentially materialized dwell slots; a
        fixed link is a one-slot table); knot mode replicates
        `TraceNetwork.rates_bps` (searchsorted over knot times, modulo the
        replay period). Same lookup, same floats -- only the memory lives
        on device for the run.
        """
        topo = self.topology
        C = topo.n_cells
        mode = np.zeros(C, np.int64)
        dwell = np.full(C, _BIG_DWELL)
        period = np.zeros(C)
        slot_rates: List[np.ndarray] = []
        knot_ts: List[np.ndarray] = []
        knot_rates: List[np.ndarray] = []
        for cell in topo.cells:
            net = cell.network
            if isinstance(net, MarkovNetwork):
                n_slots = int(max(t_bound, 0.0) // net.dwell_s) + 2
                rates = net.rates_bps(
                    (np.arange(n_slots) + 0.5) * net.dwell_s
                )
                dwell[len(slot_rates)] = net.dwell_s
                slot_rates.append(np.asarray(rates, np.float64))
                knot_ts.append(np.zeros(1))
                knot_rates.append(np.zeros(1))
            elif isinstance(net, TraceNetwork):
                mode[len(slot_rates)] = 1
                period[len(slot_rates)] = (
                    0.0 if net.period_s is None else float(net.period_s)
                )
                slot_rates.append(np.asarray([1.0]))
                knot_ts.append(np.asarray(net.times_s, np.float64))
                knot_rates.append(np.asarray(net.trace_rates_bps, np.float64))
            elif isinstance(net, FixedRateNetwork):
                slot_rates.append(np.asarray([net.bps], np.float64))
                knot_ts.append(np.zeros(1))
                knot_rates.append(np.zeros(1))
            else:  # pragma: no cover - guarded by _min_rate earlier
                raise ValueError(f"unsupported network {type(net).__name__}")
        S_net = max(len(r) for r in slot_rates)
        Kn = max(len(k) for k in knot_ts)
        slots = np.empty((C, S_net))
        kts = np.full((C, Kn), np.inf)
        krs = np.empty((C, Kn))
        for c in range(C):
            r = slot_rates[c]
            slots[c, : len(r)] = r
            slots[c, len(r):] = r[-1]
            kt, kr = knot_ts[c], knot_rates[c]
            kts[c, : len(kt)] = kt
            krs[c, : len(kr)] = kr
            krs[c, len(kr):] = kr[-1]
        return dict(
            net_mode=mode, net_dwell=dwell, net_period=period,
            net_slots=slots, net_knots=kts, net_rates=krs,
        ), bool((mode == 1).any())

    def _ctx_tables(self, t_bound: float):
        """Materialize every cell's context-regime lookup device-side,
        already mapped through the schedule-context -> table-context ids
        (`_sched_map`), mirroring `FleetSimulator._ctx_ids` exactly."""
        topo = self.topology
        C = topo.n_cells
        mode = np.zeros(C, np.int64)
        dwell = np.full(C, _BIG_DWELL)
        slot_ids: List[np.ndarray] = []
        knot_ts: List[np.ndarray] = []
        knot_ids: List[np.ndarray] = []
        for c, cell in enumerate(topo.cells):
            sched = cell.schedule
            if sched is None:
                slot_ids.append(np.asarray([self._static_ctx[c]], np.int64))
                knot_ts.append(np.zeros(1))
                knot_ids.append(np.zeros(1, np.int64))
            elif isinstance(sched, MarkovContextSchedule):
                n_slots = int(max(t_bound, 0.0) // sched.dwell_s) + 2
                mids = (np.arange(n_slots) + 0.5) * sched.dwell_s
                ids = self._sched_map[c][sched.context_ids_at(mids)]
                dwell[c] = sched.dwell_s
                slot_ids.append(np.asarray(ids, np.int64))
                knot_ts.append(np.zeros(1))
                knot_ids.append(np.zeros(1, np.int64))
            elif isinstance(sched, PiecewiseSchedule):
                mode[c] = 1
                slot_ids.append(np.zeros(1, np.int64))
                knot_ts.append(np.asarray(sched.starts, np.float64))
                seg_ids = self._sched_map[c][
                    sched.context_ids_at(sched.starts)
                ]
                knot_ids.append(np.asarray(seg_ids, np.int64))
            else:
                raise ValueError(
                    f"compiled fleet pipeline supports Markov/Piecewise "
                    f"context schedules, not {type(sched).__name__}; use "
                    f"the host backend"
                )
        S_ctx = max(len(s) for s in slot_ids)
        Kc = max(len(k) for k in knot_ts)
        slots = np.empty((C, S_ctx), np.int64)
        kts = np.full((C, Kc), np.inf)
        kids = np.zeros((C, Kc), np.int64)
        for c in range(C):
            s = slot_ids[c]
            slots[c, : len(s)] = s
            slots[c, len(s):] = s[-1]
            kt, ki = knot_ts[c], knot_ids[c]
            kts[c, : len(kt)] = kt
            kids[c, : len(ki)] = ki
            kids[c, len(ki):] = ki[-1]
        return dict(
            ctx_mode=mode, ctx_dwell=dwell,
            ctx_slots=slots, ctx_knots=kts, ctx_kctx=kids,
        ), bool((mode == 1).any())

    # ------------------------------------------------------- device program
    def _program(self, S):
        if S in self._programs:
            return self._programs[S]
        import jax
        import jax.numpy as jnp
        from jax import lax

        from repro.fleet.maxplus import maxplus_fifo

        (C, R, B, Rb, RB, D, K, N_pad, S_ctx, Kc, S_net, Kn,
         slowdowns, ctx_knots, net_knots, mesh_axes, n_ctx, cal_bins) = S
        mesh = self._mesh_obj  # resolved by run(); part of the cache key

        def scale_at(t):
            sc = jnp.ones_like(t)
            for a, b, f in slowdowns:
                sc = sc * jnp.where((t >= a) & (t < b), f, 1.0)
            return sc

        def ctx_at(tbl, org, t):
            tpos = jnp.maximum(t, 0.0)
            slot = jnp.clip(
                (tpos // tbl["ctx_dwell"][org]).astype(jnp.int32),
                0, S_ctx - 1,
            )
            out = tbl["ctx_slots"][org, slot]
            if ctx_knots:
                seg = jax.vmap(
                    lambda kn, x: jnp.searchsorted(kn, x, side="right")
                )(tbl["ctx_knots"][org], tpos) - 1
                seg = jnp.clip(seg, 0, Kc - 1)
                out = jnp.where(
                    tbl["ctx_mode"][org] == 1, tbl["ctx_kctx"][org, seg], out
                )
            return out

        def rate_at(tbl, c, t):
            tpos = jnp.maximum(t, 0.0)
            slot = jnp.clip(
                (tpos // tbl["net_dwell"][c]).astype(jnp.int32),
                0, S_net - 1,
            )
            out = tbl["net_slots"][c, slot]
            if net_knots:
                per = tbl["net_period"][c]
                tt = jnp.where(per > 0, jnp.mod(t, per), t)
                seg = jnp.maximum(
                    jnp.searchsorted(tbl["net_knots"][c], tt, side="right")
                    - 1,
                    0,
                )
                out = jnp.where(
                    tbl["net_mode"][c] == 1, tbl["net_rates"][c, seg], out
                )
            return out

        def cell_fn(cell_id, arr, smp, dev, org, bl, valid, tbl):
            # --- edge tier: one masked max-plus chain per device lane.
            # Rows arrive in (window, origin) batch order, which is
            # exactly the host's carried-dev_free chain order.
            srv = jnp.full(R, tbl["s_edge"])
            edge_done = jnp.zeros(R)
            for d in range(D):
                m = valid & (dev == d)
                done = maxplus_fifo(arr, srv, m, 0.0)
                edge_done = jnp.where(m, done, edge_done)
            # --- context + gate (same float64 conf vs p_tar as the host)
            ctx = jnp.where(valid, ctx_at(tbl, org, edge_done), 0)
            conf = tbl["conf"][ctx, smp]
            on = conf >= tbl["p_tar"]
            offl = valid & ~on
            # --- uplink: sort offloads to the front in (batch, ready-time)
            # order, then price each batch with the host's two-pass link
            # repricing under a lax.scan carrying the uplink-free time.
            # That scan is the window loop, fused.
            rowpos = jnp.arange(R)
            order = jnp.lexsort((rowpos, edge_done, bl, ~offl))
            t_s = edge_done[order]
            o_s = offl[order]
            counts = jax.ops.segment_sum(
                o_s.astype(jnp.int32), bl[order], num_segments=B
            )
            starts = jnp.concatenate(
                [jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]]
            )
            sub = jnp.arange(Rb)
            idx = jnp.clip(starts[:, None] + sub[None, :], 0, R - 1)
            sv = sub[None, :] < counts[:, None]  # (B, Rb) in-batch validity
            t_b = t_s[idx]
            nbytes8 = tbl["nbytes8"]

            def step(free, xs):
                t_row, m_row = xs
                r1 = rate_at(tbl, cell_id, t_row)
                c1 = nbytes8 / r1
                d1 = maxplus_fifo(t_row, c1, m_row, free)
                # reprice at the actual transfer start (host's fixed-point
                # pass: rates at done - comm1)
                c2 = nbytes8 / rate_at(tbl, cell_id, d1 - c1)
                d2 = maxplus_fifo(t_row, c2, m_row, free)
                free2 = jnp.where(
                    m_row.any(),
                    jnp.max(jnp.where(m_row, d2, -jnp.inf)),
                    free,
                )
                return free2, (d2, c2)

            _, (d_b, c_b) = lax.scan(step, jnp.asarray(0.0), (t_b, sv))
            flat_i = idx.reshape(-1)
            flat_v = sv.reshape(-1)
            safe = jnp.where(flat_v, order[flat_i], R)
            up_done = jnp.full(R + 1, jnp.nan).at[safe].set(
                d_b.reshape(-1)
            )[:R]
            up_comm = jnp.full(R + 1, jnp.nan).at[safe].set(
                c_b.reshape(-1)
            )[:R]
            return edge_done, ctx, conf, on, up_done, up_comm

        def bh_fn(cell_id, arr, smp, valid, tbl):
            # whole-fleet outage: nominal-rate cloud backhaul per origin
            done = maxplus_fifo(
                arr, jnp.full(RB, tbl["comm_bh"]), valid, 0.0
            )
            org = jnp.full(RB, cell_id)
            ctx = jnp.where(valid, ctx_at(tbl, org, arr), 0)
            return ctx, done

        def cells_fn(cell_ids, lane, bh, tbl):
            outA = jax.vmap(
                cell_fn, in_axes=(0, 0, 0, 0, 0, 0, 0, None)
            )(cell_ids, lane["arr"], lane["smp"], lane["dev"], lane["org"],
              lane["bl"], lane["valid"], tbl)
            outB = jax.vmap(bh_fn, in_axes=(0, 0, 0, 0, None))(
                cell_ids, bh["arr"], bh["smp"], bh["valid"], tbl
            )
            return outA, outB

        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            sh = P("cells", None)
            cells_fn = shard_map(
                cells_fn,
                mesh=mesh,
                in_specs=(
                    P("cells"),
                    {k: sh for k in
                     ("arr", "smp", "dev", "org", "bl", "valid")},
                    {k: sh for k in ("arr", "smp", "valid")},
                    jax.tree_util.tree_map(lambda _: P(), self._tbl_struct),
                ),
                out_specs=((sh,) * 6, (sh,) * 2),
                check_rep=False,
            )

        def program(cell_ids, lane, bh, tbl):
            lane_in = {k: lane[k] for k in
                       ("arr", "smp", "dev", "org", "bl", "valid")}
            bh_in = {k: bh[k] for k in ("arr", "smp", "valid")}
            (edge_done, ctx, conf, on, up_done, up_comm), (ctx_bh, bh_done) \
                = cells_fn(cell_ids, lane_in, bh_in, tbl)
            # --- shared cloud tier, solved once globally: stable sort by
            # transfer completion (generation order breaks ties), K
            # residue-class max-plus chains as the columns of a row-major
            # (M, K) reshape, then unsort.
            s_cloud = tbl["s_cloud"]
            okA = (lane["valid"] & ~on).reshape(-1)
            tA = up_done.reshape(-1)
            sA = s_cloud * scale_at(tA)
            okB = bh["valid"].reshape(-1)
            tB = bh_done.reshape(-1)
            sB = s_cloud * scale_at(tB)
            t = jnp.concatenate([tA, tB])
            ok = jnp.concatenate([okA, okB])
            sv = jnp.concatenate([sA, sB])
            gid = jnp.concatenate(
                [lane["gid"].reshape(-1), bh["gid"].reshape(-1)]
            )
            ready = jnp.concatenate(
                [edge_done.reshape(-1), bh["arr"].reshape(-1)]
            )
            n = t.shape[0]
            fi = jnp.arange(n)
            gorder = jnp.lexsort((fi, ready, gid, ~ok))
            grank = jnp.zeros(n, fi.dtype).at[gorder].set(fi)
            key_t = jnp.where(ok, t, jnp.inf)
            order2 = jnp.lexsort((grank, key_t))
            t_sorted = key_t[order2]
            s_sorted = jnp.where(ok, sv, 0.0)[order2]
            pad = N_pad - n
            if pad:
                t_sorted = jnp.concatenate(
                    [t_sorted, jnp.full(pad, jnp.inf)]
                )
                s_sorted = jnp.concatenate([s_sorted, jnp.zeros(pad)])
            mat_t = t_sorted.reshape(-1, K)
            mat_s = s_sorted.reshape(-1, K)

            def combine(x, y):
                a1, b1 = x
                a2, b2 = y
                return a1 + a2, jnp.maximum(b1 + a2, b2)

            a_s, b_s = lax.associative_scan(
                combine, (mat_s, mat_t + mat_s), axis=0
            )
            done_sorted = jnp.maximum(b_s, a_s).reshape(-1)[:n]
            cloud = jnp.zeros(n).at[order2].set(done_sorted)
            nA = C * R
            res = dict(
                edge_done=edge_done, ctx=ctx, conf=conf, on=on,
                up_done=up_done, up_comm=up_comm,
                s_eff=sA.reshape(C, R), cloud=cloud[:nA].reshape(C, R),
                ctx_bh=ctx_bh, bh_done=bh_done,
                s_eff_bh=sB.reshape(C, RB),
                cloud_bh=cloud[nA:].reshape(C, RB),
            )
            if cal_bins:
                # --- reliability-bin sketch, accumulated IN the fused
                # program: the same float64 edges the host sketch bins
                # with (passed in via tbl, not recomputed on device, so
                # `searchsorted` assigns bit-identical bins), summed by
                # (origin cell, context, bin) segment ids. Backhaul lanes
                # carry no gate decision and are excluded -- the host
                # counts them via `note_ungated`.
                nb1 = cal_bins + 1
                vf = lane["valid"].reshape(-1).astype(conf.dtype)
                ctx_f = ctx.reshape(-1)
                org_f = lane["org"].reshape(-1)
                conf_f = conf.reshape(-1)
                ec = tbl["ecorrect"][ctx_f, lane["smp"].reshape(-1)]
                onf = on.reshape(-1).astype(conf.dtype)
                bin_ = jnp.searchsorted(tbl["cal_edges"], conf_f,
                                        side="left") - 1
                bin_ = jnp.where(bin_ < 0, cal_bins, bin_)
                seg = (org_f * n_ctx + ctx_f) * nb1 + bin_
                rows = jnp.stack([
                    vf, ec * vf, conf_f * vf, conf_f * conf_f * vf,
                    conf_f * ec * vf, onf * vf, onf * ec * vf,
                ])
                calsum = jax.vmap(
                    lambda r: jax.ops.segment_sum(
                        r, seg, num_segments=C * n_ctx * nb1
                    )
                )(rows)
                res["cal"] = calsum.reshape(7, C, n_ctx, nb1)
            return res

        prog = jax.jit(program)
        self._programs[S] = prog
        return prog

    # ----------------------------------------------------------------- run
    def run(self) -> FleetTelemetry:
        topo, cfg, table = self.topology, self.config, self.table
        tel = FleetTelemetry(
            topo.n_cells,
            context_keys=table.ctx_keys,
            bank_keys=table.bank_keys or None,
        )
        for c, cell in enumerate(topo.cells):
            tel.set_arrivals(c, cell.workload.arrival_s)

        self._state = [self._initial_state for _ in topo.cells]
        self._active = topo.initial_active_mask()
        self._cell_tables = [None] * topo.n_cells
        self._backhaul_free = np.zeros(topo.n_cells)
        self.shed_counts = np.zeros(topo.n_cells, np.int64)
        orch = self.orchestrator
        self._live = _LiveCloud(topo.cloud_servers) if orch is not None else None

        ws = cfg.window_s
        C = topo.n_cells
        n_windows = int(math.ceil(max(topo.horizon_s, 0.0) / ws)) + 1
        branch, p_tar, clevel = self._initial_state
        s_edge = L.edge_time(self.profile, branch)
        s_cloud = L.cloud_time(self.profile, branch)
        # the static deployment fixes (branch, level), so the device-resident
        # (branch, level) -> bytes table collapses to one scalar; level 0
        # reuses the raw tensor bytes unchanged (bit-exact legacy pricing)
        nbytes = float(self._payload_nbytes_for(branch, clevel))
        comm_bh = nbytes * 8.0 / self.profile.uplink_bps

        # ---- churn pre-pass: activation is pure time-based, so the
        # (window, origin) -> serving cell routing is known up front.
        active_w = np.empty((n_windows, C), bool)
        active = topo.initial_active_mask()
        churn = None if orch is None else orch.churn
        cursor = 0
        if churn is not None:
            from repro.orchestration.churn import JOIN
        for w in range(n_windows):
            if churn is not None:
                due, cursor = churn.due(cursor, w * ws)
                for ev in due:
                    active[ev.cell] = ev.kind == JOIN
            active_w[w] = active

        # ---- batch layout in host (window, origin) order
        shed_orders: dict = {}
        batches: List[_Batch] = []
        by_window: List[List[_Batch]] = [[] for _ in range(n_windows)]
        ptr = np.zeros(C, np.int64)
        for w in range(n_windows):
            t1 = (w + 1) * ws
            act = active_w[w]
            for c, cell in enumerate(topo.cells):
                arr = cell.workload.arrival_s
                hi = int(np.searchsorted(arr, t1, side="left"))
                lo = int(ptr[c])
                ptr[c] = hi
                if hi == lo:
                    continue
                if act[c]:
                    serve, shed = c, False
                else:
                    shed = True
                    serve = -1
                    if c not in shed_orders:
                        shed_orders[c] = topo.shed_order(c)
                    for s in shed_orders[c]:
                        if act[s]:
                            serve = int(s)
                            break
                b = _Batch(w, c, serve, lo, hi, shed)
                batches.append(b)
                by_window[w].append(b)

        rowsA = np.zeros(C, np.int64)
        rowsB = np.zeros(C, np.int64)
        nbatchA = np.zeros(C, np.int64)
        max_batch = 1
        for b in batches:
            n = b.hi - b.lo
            max_batch = max(max_batch, n)
            if b.serve >= 0:
                b.row0 = int(rowsA[b.serve])
                b.blocal = int(nbatchA[b.serve])
                rowsA[b.serve] += n
                nbatchA[b.serve] += 1
            else:
                b.row0 = int(rowsB[b.origin])
                rowsB[b.origin] += n
        R = _next_pow2(max(1, int(rowsA.max())))
        RB = _next_pow2(max(1, int(rowsB.max())))
        B = max(1, int(nbatchA.max()))
        Rb = _next_pow2(max_batch)
        D = max(cell.n_devices for cell in topo.cells)

        lane = dict(
            arr=np.zeros((C, R)), smp=np.zeros((C, R), np.int64),
            dev=np.zeros((C, R), np.int64), org=np.zeros((C, R), np.int64),
            bl=np.zeros((C, R), np.int64), gid=np.zeros((C, R), np.int64),
            valid=np.zeros((C, R), bool),
        )
        bh = dict(
            arr=np.zeros((C, RB)), smp=np.zeros((C, RB), np.int64),
            gid=np.zeros((C, RB), np.int64), valid=np.zeros((C, RB), bool),
        )
        for b in batches:
            n = b.hi - b.lo
            wl = topo.cells[b.origin].workload
            gid = b.w * C + b.origin
            if b.serve >= 0:
                sl = (b.serve, slice(b.row0, b.row0 + n))
                lane["arr"][sl] = wl.arrival_s[b.lo:b.hi]
                lane["smp"][sl] = wl.sample[b.lo:b.hi]
                dev = wl.device[b.lo:b.hi]
                if b.shed:
                    dev = dev % topo.cells[b.serve].n_devices
                lane["dev"][sl] = dev
                lane["org"][sl] = b.origin
                lane["bl"][sl] = b.blocal
                lane["gid"][sl] = gid
                lane["valid"][sl] = True
            else:
                sl = (b.origin, slice(b.row0, b.row0 + n))
                bh["arr"][sl] = wl.arrival_s[b.lo:b.hi]
                bh["smp"][sl] = wl.sample[b.lo:b.hi]
                bh["gid"][sl] = gid
                bh["valid"][sl] = True

        # ---- materialized lookup tables (bounded by the worst completion
        # time any lookup can be queried at)
        t_edge_bound = topo.horizon_s + ws + (R + 1) * s_edge + 1.0
        max_comm = max(
            (nbytes * 8.0 / self._min_rate(cell.network)
             for cell in topo.cells),
            default=0.0,
        )
        t_net_bound = t_edge_bound + (R + 1) * max(max_comm, comm_bh) + 1.0
        net_tbl, any_net_knots = self._net_tables(t_net_bound)
        ctx_tbl, any_ctx_knots = self._ctx_tables(t_edge_bound)
        bi = table.branch_idx(branch)
        tbl = dict(
            conf=np.asarray(table.conf[:, bi, :], np.float64),
            s_edge=np.float64(s_edge), s_cloud=np.float64(s_cloud),
            nbytes8=np.float64(nbytes * 8.0),
            comm_bh=np.float64(comm_bh), p_tar=np.float64(p_tar),
            **net_tbl, **ctx_tbl,
        )
        cal_on = self._cal is not None and table.labels is not None
        if cal_on:
            from repro.obs.calibration import bin_edges

            # host-computed float64 edges + per-(ctx, sample) EDGE
            # correctness table, so the device program's binning and
            # correctness match the host sketch bit-for-bit
            tbl["cal_edges"] = bin_edges(self._cal.n_bins)
            tbl["ecorrect"] = (
                table.pred[:, bi, :] == table.labels[None, :]
            ).astype(np.float64)
        self._tbl_struct = tbl

        K = topo.cloud_servers
        n_jobs = C * R + C * RB
        N_pad = int(math.ceil(n_jobs / K)) * K
        self._mesh_obj = self._resolve_mesh(C)
        S = (
            C, R, B, Rb, RB, D, K, N_pad,
            ctx_tbl["ctx_slots"].shape[1], ctx_tbl["ctx_knots"].shape[1],
            net_tbl["net_slots"].shape[1], net_tbl["net_knots"].shape[1],
            tuple(cfg.cloud_slowdowns), any_ctx_knots, any_net_knots,
            None if self._mesh_obj is None else tuple(self._mesh_obj.shape.items()),
            int(table.conf.shape[0]),
            0 if not cal_on else int(self._cal.n_bins),
        )
        prog = self._program(S)

        from jax.experimental import enable_x64

        with enable_x64():
            out = prog(np.arange(C, dtype=np.int64), lane, bh, tbl)
            out = {k: np.asarray(v) for k, v in out.items()}

        # ---- host recovery: per-request verdict columns (exact numpy
        # table math, same as the host simulator's gate aftermath)
        est = table.est_ids(out["ctx"].ravel(), lane["smp"].ravel())
        estA = (
            np.full((C, R), -2, np.int64) if est is None
            else est.reshape(C, R)
        )
        pred = table.pred[:, bi, :][out["ctx"], lane["smp"]]
        cpredA = table.cloud_pred(out["ctx"].ravel(),
                                  lane["smp"].ravel(),
                                  level=clevel).reshape(C, R)
        ce = table.correct(lane["smp"].ravel(), pred.ravel())
        cc = table.correct(lane["smp"].ravel(), cpredA.ravel())
        # EDGE-branch correctness, kept separately from the cloud-patched
        # column: the calibration stream audits the gate's own verdict
        self._ecA = None if ce is None else ce.reshape(C, R).astype(np.int8)
        if ce is None:
            correctA = np.full((C, R), -1, np.int8)
        else:
            correctA = np.where(
                out["on"], ce.reshape(C, R), cc.reshape(C, R)
            ).astype(np.int8)
        completeA = np.where(out["on"], out["edge_done"], out["cloud"])
        cpredB = table.cloud_pred(out["ctx_bh"].ravel(),
                                  bh["smp"].ravel(),
                                  level=clevel).reshape(C, RB)
        ccB = table.correct(bh["smp"].ravel(), cpredB.ravel())
        correctB = (
            np.full((C, RB), -1, np.int8) if ccB is None
            else ccB.reshape(C, RB).astype(np.int8)
        )

        deadlines = [cell.deadline_s for cell in topo.cells]
        has_shed = any(b.shed for b in batches)
        obs_on = self.obs is not None and self.obs.enabled

        if orch is None and not obs_on and not has_shed:
            self._flush_fast(tel, lane, out, estA, correctA, completeA,
                             rowsA, deadlines, branch, p_tar, clevel, nbytes)
        else:
            self._replay(tel, lane, bh, out, estA, correctA, completeA,
                         correctB, by_window, n_windows, ws, deadlines,
                         branch, p_tar, clevel, nbytes, orch)
        if orch is not None:
            orch.finish(self, tel, n_windows * ws)
        return tel

    # ------------------------------------------------- host-side recovery
    def _est_mapped(self, est, ctx):
        return np.where(
            est >= 0, self._bank_to_table[np.maximum(est, 0)],
            np.where(est == -2, ctx, -1),
        )

    def _flush_fast(self, tel, lane, out, estA, correctA, completeA,
                    rowsA, deadlines, branch, p_tar, clevel, nbytes):
        """No churn, no orchestrator, no obs: flush whole per-cell columns.

        Chunking telemetry per cell instead of per (window, cell) batch is
        invisible to every reader (`_CellColumns` concatenates chunks and
        the observation streams are windowed by value), and the row order
        is the host's batch order, so the streams are element-identical.
        """
        C = self.topology.n_cells
        for c in range(C):
            n = int(rowsA[c])
            if n == 0:
                continue
            sl = (c, slice(0, n))
            arr = lane["arr"][sl]
            edge_done = out["edge_done"][sl]
            on = out["on"][sl]
            ctx = out["ctx"][sl]
            est = estA[sl]
            complete = completeA[sl]
            lat = complete - arr
            ded = deadlines[c]
            missed = (
                np.full(n, -1, np.int8) if ded is None
                else (lat > ded).astype(np.int8)
            )
            tel.observe_contexts(c, edge_done, self._est_mapped(est, ctx))
            off = ~on
            if off.any():
                order = np.lexsort((
                    np.arange(n)[off], edge_done[off], lane["bl"][sl][off],
                ))
                t_ready = edge_done[off][order]
                rates = nbytes * 8.0 / out["up_comm"][sl][off][order]
                tel.observe_bandwidth(c, t_ready, rates)
            tel.add_window(
                c, latency_s=lat, on_device=on, correct=correctA[sl],
                p_tar=np.full(n, p_tar), branch=np.full(n, branch, np.int64),
                ctx_id=ctx, est_id=est, missed=missed,
                energy_j=self._energy_col(
                    L.edge_time(self.profile, branch), on, branch, clevel
                ),
            )

    def _batch_cols(self, b, lane, bh, out, estA, correctA, completeA,
                    correctB, deadlines, branch, p_tar, clevel):
        n = b.hi - b.lo
        if b.serve >= 0:
            sl = (b.serve, slice(b.row0, b.row0 + n))
            cols = {
                "arrival": lane["arr"][sl],
                "samples": lane["smp"][sl],
                "edge_done": out["edge_done"][sl],
                "complete": completeA[sl],
                "on_device": out["on"][sl],
                "ctx_id": out["ctx"][sl],
                "est_id": estA[sl],
                "correct": correctA[sl],
                "branch": np.full(n, branch, np.int64),
                "p_tar": np.full(n, p_tar),
                "clevel": np.full(n, int(clevel), np.int64),
                "energy_j": self._energy_col(
                    L.edge_time(self.profile, branch), out["on"][sl],
                    branch, int(clevel),
                ),
                "deadline": deadlines[b.origin],
            }
            # cols["correct"] above is already cloud-patched; the live
            # calibration stream and gate trace records need the gate's
            # own verdict, so the edge column always rides along
            cols["edge_correct"] = (
                np.full(n, -1, np.int8) if self._ecA is None
                else self._ecA[sl]
            )
            if self._tracing:
                cols["conf"] = out["conf"][sl]
                cols["uplink_done"] = out["up_done"][sl]
                cols["uplink_start"] = out["up_done"][sl] - out["up_comm"][sl]
                cols["cloud_service"] = np.where(
                    cols["on_device"], np.nan, out["s_eff"][sl]
                )
                cols["serve_cell"] = b.serve
            elif self._live is not None:
                cols["conf"] = out["conf"][sl]
            return cols, out["up_comm"][sl], out["s_eff"][sl]
        sl = (b.origin, slice(b.row0, b.row0 + n))
        arr = bh["arr"][sl]
        cols = {
            "arrival": arr,
            "samples": bh["smp"][sl],
            "edge_done": arr.copy(),
            "complete": out["cloud_bh"][sl],
            "on_device": np.zeros(n, bool),
            "ctx_id": out["ctx_bh"][sl],
            "est_id": np.full(n, -2, np.int64),
            "correct": correctB[sl],
            "branch": np.full(n, branch, np.int64),
            "p_tar": np.full(n, p_tar),
            "clevel": np.full(n, int(clevel), np.int64),
            "energy_j": self._energy_col(0.0, np.zeros(n, bool), branch,
                                         int(clevel)),
            "deadline": deadlines[b.origin],
        }
        cols["edge_correct"] = np.full(n, -1, np.int8)
        comm = np.full(n, float(self._tbl_struct["comm_bh"]))
        if self._tracing:
            cols["conf"] = np.full(n, np.nan)
            cols["uplink_done"] = out["bh_done"][sl]
            cols["uplink_start"] = out["bh_done"][sl] - comm
            cols["cloud_service"] = out["s_eff_bh"][sl]
            cols["serve_cell"] = -1
        elif self._live is not None:
            cols["conf"] = np.full(n, np.nan)
        return cols, comm, out["s_eff_bh"][sl]

    def _replay(self, tel, lane, bh, out, estA, correctA, completeA,
                correctB, by_window, n_windows, ws, deadlines, branch,
                p_tar, clevel, nbytes, orch):
        """Replay the host simulator's boundary bookkeeping from the
        device-solved columns, operation-for-operation in its order:
        live-cloud pops, orchestrator hooks (churn audit + QoS monitor),
        shed accounting, telemetry/metrics/audit per batch, then the
        shared flush + obs emission."""
        window_cols: List[Tuple[int, dict]] = []
        if orch is not None:
            orch.attach(self, tel, audit=self._audit)
        for w in range(n_windows):
            t0 = w * ws
            if orch is not None:
                if w > 0:
                    self._pop_live(t0, tel)
                orch.on_window(self, tel, w, t0)
            for b in by_window[w]:
                n = b.hi - b.lo
                cols, comm, s_eff = self._batch_cols(
                    b, lane, bh, out, estA, correctA, completeA, correctB,
                    deadlines, branch, p_tar, clevel,
                )
                if bool(self._active[b.origin]) == b.shed:
                    # pragma: no cover - internal consistency
                    raise RuntimeError(
                        "churn replay diverged from the precomputed "
                        "activation schedule"
                    )
                if b.shed:
                    self.shed_counts[b.origin] += n
                    if b.serve < 0 and self._cal is not None:
                        # backhauled without a gate decision: no
                        # calibration signal, but the sketch totals must
                        # still conserve fleet_requests_total
                        self._cal.note_ungated(b.origin, n)
                    if self._metrics is not None:
                        self._metrics.inc(
                            "fleet_shed_total", n, cell=b.origin
                        )
                    arr = cols["arrival"]
                    if b.serve >= 0:
                        tel.observe_shed_arrivals(b.serve, arr)
                        if self._audit is not None:
                            self._audit.record(
                                float(arr[0]), "simulator", "shed_route",
                                cell=b.origin, host_cell=b.serve,
                                backhaul=False, requests=int(n))
                    elif self._audit is not None:
                        self._audit.record(
                            float(arr[0]), "simulator", "shed_route",
                            cell=b.origin, host_cell=None,
                            backhaul=True, requests=int(n))
                est = cols["est_id"]
                tel.observe_contexts(
                    b.serve if b.serve >= 0 else b.origin,
                    cols["edge_done"],
                    self._est_mapped(est, cols["ctx_id"]),
                )
                off = ~cols["on_device"]
                if self._metrics is not None:
                    self._metrics.inc("fleet_requests_total", n,
                                      cell=b.origin)
                    n_off = int(off.sum())
                    if n_off:
                        self._metrics.inc("fleet_offloaded_total", n_off,
                                          cell=b.origin)
                if off.any():
                    pos = np.flatnonzero(off)[
                        np.argsort(cols["edge_done"][off], kind="stable")
                    ]
                    t_ready = cols["edge_done"][pos]
                    if self._metrics is not None:
                        # uplink AND backhaul payloads count, attributed
                        # to the origin cell (host simulator's rule)
                        self._metrics.inc("fleet_uplink_bytes_total",
                                          nbytes * len(pos), cell=b.origin)
                    if b.serve >= 0:
                        tel.observe_bandwidth(
                            b.serve, t_ready, nbytes * 8.0 / comm[pos]
                        )
                        done = (out["up_done"][b.serve,
                                              b.row0:b.row0 + n][pos])
                    else:
                        done = out["bh_done"][b.origin,
                                              b.row0:b.row0 + n][pos]
                    if self._live is not None:
                        self._live.add(
                            done, s_eff[pos], b.origin,
                            cols["arrival"][pos], cols["deadline"],
                        )
                if self._live is not None:
                    self._observe_edge_live(b.origin, cols, tel)
                window_cols.append((b.origin, cols))
        if self._cal is not None and "cal" in out:
            self._ingest_cal(out["cal"], branch)
        self._flush(window_cols, tel)
        if self.obs is not None and self.obs.enabled:
            self._finish_obs(window_cols, tel)

    def _ingest_cal(self, cal: np.ndarray, branch: int) -> None:
        """Fold the device-binned `(7, C, n_ctx, n_bins+1)` reliability
        blocks into the sketch. Zero-count (cell, context) blocks are
        skipped so the sketch's key set matches the host simulator's
        (which only creates keys for contexts it actually served)."""
        keys = self.table.ctx_keys
        for c in range(cal.shape[1]):
            for k in range(cal.shape[2]):
                blk = cal[:, c, k, :]
                if blk[0].sum() <= 0:
                    continue
                self._cal.update_binned(c, keys[k], branch, blk)
