"""Synthetic datasets (no external downloads in this container).

Two generators, both fully seeded/deterministic:

1. `cifar_like`: a 10-class 32x32x3 image task standing in for CIFAR-10
   with the paper's 45k/3k/7k split. Class templates are smooth random
   fields; each sample = template + per-sample deformation + noise whose
   magnitude is drawn from an easy/hard mixture. The mixture is what gives
   early exits their operating regime: easy samples are separable from
   shallow features (the paper's premise that "a large portion of the
   input samples" can exit early).

2. `lm_sequences`: token streams for the language-model end-to-end driver.
   A hidden 2nd-order Markov teacher over the vocab generates structure a
   ~100M model can learn in a few hundred steps (loss drops well below the
   uniform-entropy floor), mixed with span-copy segments that reward
   attention/state-tracking.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class ImageSplits:
    train_x: np.ndarray
    train_y: np.ndarray
    val_x: np.ndarray
    val_y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray


def _smooth_field(rng, shape, smooth=4):
    f = rng.standard_normal(shape).astype(np.float32)
    # cheap separable box blur for spatial smoothness
    for axis in (0, 1):
        for _ in range(smooth):
            f = 0.5 * f + 0.25 * (np.roll(f, 1, axis) + np.roll(f, -1, axis))
    return f


def cifar_like(
    n_train: int = 45_000,
    n_val: int = 3_000,
    n_test: int = 7_000,
    n_classes: int = 10,
    easy_frac: float = 0.6,
    noise: float = 1.2,
    seed: int = 0,
) -> ImageSplits:
    """Paper split: 45,000 / 3,000 / 7,000 (Sec. III).

    Easy samples: the class template + noise (learnable to ~high accuracy).
    Hard samples: a convex MIX of two class templates with mixing weight
    alpha in [0.5, 0.85], and the LABEL DRAWN FROM THE MIXTURE (y_a with
    prob alpha, y_b otherwise). That is irreducible aleatoric uncertainty:
    the Bayes-optimal accuracy on hard samples is E[max(alpha, 1-alpha)]
    ~ 0.68, so overall Bayes accuracy ~ easy_frac + (1-easy_frac)*0.68 --
    the ~80% regime of the paper's CIFAR-10 B-AlexNet. A conventionally
    trained network fits one-hot labels on ambiguous inputs and becomes
    overconfident at test time -- exactly the miscalibration the paper
    studies; a calibrated exit should report confidence ~ alpha.
    """
    rng = np.random.default_rng(seed)
    templates = np.stack(
        [_smooth_field(rng, (32, 32, 3)) for _ in range(n_classes)]
    )  # (C,32,32,3)
    templates /= np.sqrt(np.mean(templates**2, axis=(1, 2, 3), keepdims=True))

    def make(n, rng):
        ya = rng.integers(0, n_classes, size=n).astype(np.int32)
        easy = rng.random(n) < easy_frac
        yb = (ya + rng.integers(1, n_classes, size=n)).astype(np.int32) % n_classes
        alpha = np.where(easy, 1.0, rng.uniform(0.5, 0.85, size=n)).astype(np.float32)
        base = (
            alpha[:, None, None, None] * templates[ya]
            + (1.0 - alpha[:, None, None, None]) * templates[yb]
        )
        x = base + noise * rng.standard_normal((n, 32, 32, 3)).astype(np.float32)
        # label drawn from the mixture (aleatoric)
        take_a = rng.random(n) < alpha
        y = np.where(take_a, ya, yb).astype(np.int32)
        return x.astype(np.float32), y

    tx, ty = make(n_train, rng)
    vx, vy = make(n_val, rng)
    sx, sy = make(n_test, rng)
    return ImageSplits(tx, ty, vx, vy, sx, sy)


def lm_sequences(
    n_tokens: int,
    vocab_size: int,
    seed: int = 0,
    order: int = 2,
    branch: int = 8,
    copy_prob: float = 0.15,
    copy_span: int = 16,
) -> np.ndarray:
    """Deterministic token stream with learnable structure.

    Markov teacher: each (t-2, t-1) context admits only `branch` successors
    (hashed), giving a ceiling of log(branch) nats instead of log(V). Span
    copy: with prob copy_prob a recent span is replayed verbatim.
    """
    rng = np.random.default_rng(seed)
    out = np.empty(n_tokens, np.int64)
    out[:order] = rng.integers(0, vocab_size, order)
    i = order
    while i < n_tokens:
        if i > copy_span * 2 and rng.random() < copy_prob:
            start = rng.integers(max(0, i - 512), i - copy_span)
            span = min(copy_span, n_tokens - i)
            out[i : i + span] = out[start : start + span]
            i += span
            continue
        if order == 1:
            c = (out[i - 1] * 10_007) % (2**31)
        else:
            c = (out[i - 2] * 1_000_003 + out[i - 1] * 10_007) % (2**31)
        successors = (c + np.arange(branch) * 97_911) % vocab_size
        out[i] = successors[rng.integers(0, branch)]
        i += 1
    return out.astype(np.int32)
