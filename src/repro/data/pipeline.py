"""Input pipeline: sharded, prefetched, deterministic batching.

Host-side numpy iterators that yield globally-batched arrays; the train
loop places them against the batch sharding (jax.device_put with a
NamedSharding) so each data shard only materializes its slice on device.
A background thread keeps `prefetch` batches ahead of the step.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


class BatchIterator:
    """Deterministic epoch-shuffled batches over in-memory arrays."""

    def __init__(self, arrays: dict, batch_size: int, seed: int = 0, drop_last=True):
        self.arrays = arrays
        self.n = len(next(iter(arrays.values())))
        self.batch_size = batch_size
        self.seed = seed
        self.drop_last = drop_last

    def __iter__(self) -> Iterator[dict]:
        epoch = 0
        while True:
            rng = np.random.default_rng((self.seed, epoch))
            order = rng.permutation(self.n)
            stop = self.n - self.batch_size + 1 if self.drop_last else self.n
            for s in range(0, stop, self.batch_size):
                idx = order[s : s + self.batch_size]
                yield {k: v[idx] for k, v in self.arrays.items()}
            epoch += 1


class TokenIterator:
    """Contiguous (batch, seq+1) windows over a token stream -> tokens/labels."""

    def __init__(self, stream: np.ndarray, batch_size: int, seq_len: int, seed=0):
        self.stream = stream
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.seed = seed

    def __iter__(self):
        rng = np.random.default_rng(self.seed)
        hi = len(self.stream) - self.seq_len - 1
        while True:
            starts = rng.integers(0, hi, self.batch_size)
            win = np.stack(
                [self.stream[s : s + self.seq_len + 1] for s in starts]
            )
            yield {"tokens": win[:, :-1].astype(np.int32), "labels": win[:, 1:].astype(np.int32)}


def prefetch(it, size: int = 2, sharding: Optional[jax.sharding.Sharding] = None):
    """Background-thread prefetch; optionally device_put against a sharding."""
    q: queue.Queue = queue.Queue(maxsize=size)
    sentinel = object()

    def worker():
        for item in it:
            if sharding is not None:
                item = jax.tree.map(
                    lambda a: jax.device_put(a, sharding), item
                )
            q.put(item)
        q.put(sentinel)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is sentinel:
            return
        yield item
