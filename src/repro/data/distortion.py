"""Seeded, parametric input distortions (the drifting-input workload axis).

Pacheco et al. ("Early-exit DNNs for distorted images", 2108.09343) show
that one calibrator fit on clean validation data breaks when inputs arrive
blurred or noisy, and that per-distortion *expert* calibrators restore
reliable offloading. This module supplies the distortion side of that
experiment for the synthetic `cifar_like` task:

* a taxonomy of parametric distortions -- `gaussian_noise`, `gaussian_blur`,
  `box_blur`, `contrast`, `brightness` -- each at severity levels 1..5
  (severity 0 / kind ``clean`` is the identity);
* `apply_distortion`, fully seeded and deterministic, plus `distort_splits`
  to distort whole `ImageSplits`;
* `input_features`: the cheap per-image statistics (Laplacian variance,
  pixel moments, total variation) a `repro.core.bank.DistortionEstimator`
  uses on the edge device to recognize the current distortion context --
  no extra DNN, just a handful of numpy reductions per image.

Parameters are scale-free where the distortion is relative to image
statistics (noise/brightness in units of per-image std, contrast around the
per-image mean), and in pixels where it is geometric (blur widths), so the
same severity tables apply to any roughly-stationary image distribution.
Blurs use periodic (roll-based) boundaries, matching how `cifar_like`
synthesizes its smooth class templates.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.data.synthetic import ImageSplits

MAX_SEVERITY = 5

# severity 1..5 parameter tables (index 0 = severity 1)
SEVERITY_PARAMS: Dict[str, List[float]] = {
    "gaussian_noise": [0.2, 0.4, 0.7, 1.1, 1.6],  # sigma, units of image std
    "gaussian_blur": [0.5, 1.0, 1.5, 2.0, 3.0],  # sigma, pixels
    "box_blur": [3, 5, 7, 9, 11],  # box width, pixels (odd)
    "contrast": [0.8, 0.6, 0.45, 0.3, 0.2],  # scale about per-image mean
    "brightness": [0.4, 0.8, 1.2, 1.7, 2.3],  # shift, units of image std
}
DISTORTION_KINDS: Tuple[str, ...] = ("clean",) + tuple(sorted(SEVERITY_PARAMS))


@dataclass(frozen=True)
class DistortionSpec:
    """One point in the taxonomy: (kind, severity). Hashable and orderable
    by its string `key` (``"gaussian_noise@3"``, ``"clean"``), which is what
    `PlanBank` and the serving schedules use as the context key."""

    kind: str
    severity: int = 0

    def __post_init__(self):
        if self.kind == "clean":
            if self.severity != 0:
                raise ValueError("clean admits only severity 0")
            return
        if self.kind not in SEVERITY_PARAMS:
            raise ValueError(
                f"unknown distortion kind {self.kind!r}; "
                f"known: {sorted(DISTORTION_KINDS)}"
            )
        if not 1 <= self.severity <= MAX_SEVERITY:
            raise ValueError(
                f"severity must be 1..{MAX_SEVERITY} for {self.kind!r}, "
                f"got {self.severity}"
            )

    @property
    def key(self) -> str:
        return "clean" if self.kind == "clean" else f"{self.kind}@{self.severity}"

    @property
    def param(self) -> float:
        return 0.0 if self.kind == "clean" else SEVERITY_PARAMS[self.kind][self.severity - 1]

    @classmethod
    def parse(cls, key: str) -> "DistortionSpec":
        if key == "clean":
            return cls("clean", 0)
        kind, _, sev = key.partition("@")
        if not sev:
            raise ValueError(f"expected 'kind@severity' or 'clean', got {key!r}")
        return cls(kind, int(sev))


CLEAN = DistortionSpec("clean")


def _roll_conv1d(x: np.ndarray, weights: np.ndarray, axis: int) -> np.ndarray:
    """Periodic 1-D convolution along `axis` via weighted np.roll sums."""
    r = len(weights) // 2
    out = np.zeros_like(x)
    for k, w in enumerate(weights):
        out += w * np.roll(x, k - r, axis=axis)
    return out


def _gaussian_kernel(sigma: float) -> np.ndarray:
    radius = max(1, int(3.0 * sigma + 0.5))
    t = np.arange(-radius, radius + 1, dtype=np.float64)
    w = np.exp(-0.5 * (t / sigma) ** 2)
    return (w / w.sum()).astype(np.float32)


def _image_stats(x: np.ndarray):
    """Per-image mean/std over (H, W, C); x is (N, H, W, C)."""
    mean = x.mean(axis=(1, 2, 3), keepdims=True)
    std = x.std(axis=(1, 2, 3), keepdims=True)
    return mean, np.maximum(std, 1e-6)


def apply_distortion(
    x: np.ndarray, spec: DistortionSpec, seed: int = 0
) -> np.ndarray:
    """Distort a batch of images (N, H, W, C) -> a new float32 array.

    Deterministic: the only stochastic kind (gaussian_noise) draws from
    ``default_rng((seed, severity))``, so the same (x, spec, seed) always
    produces the same output regardless of call order.
    """
    x = np.asarray(x, np.float32)
    if x.ndim != 4:
        raise ValueError(f"expected (N, H, W, C) images, got shape {x.shape}")
    if spec.kind == "clean":
        return x.copy()
    p = spec.param
    if spec.kind == "gaussian_noise":
        rng = np.random.default_rng((seed, spec.severity))
        _, std = _image_stats(x)
        return x + (p * std).astype(np.float32) * rng.standard_normal(
            x.shape
        ).astype(np.float32)
    if spec.kind == "gaussian_blur":
        w = _gaussian_kernel(p)
        return _roll_conv1d(_roll_conv1d(x, w, axis=1), w, axis=2)
    if spec.kind == "box_blur":
        w = np.full(int(p), 1.0 / int(p), np.float32)
        return _roll_conv1d(_roll_conv1d(x, w, axis=1), w, axis=2)
    if spec.kind == "contrast":
        mean, _ = _image_stats(x)
        return (mean + p * (x - mean)).astype(np.float32)
    if spec.kind == "brightness":
        _, std = _image_stats(x)
        return (x + p * std).astype(np.float32)
    raise AssertionError(f"unhandled kind {spec.kind!r}")  # guarded in __post_init__


def distort_splits(splits: ImageSplits, spec: DistortionSpec, seed: int = 0) -> ImageSplits:
    """Distort all three image splits (labels untouched). Each split draws
    from its own derived seed so train/val/test noise is independent."""
    return ImageSplits(
        train_x=apply_distortion(splits.train_x, spec, seed=seed * 3 + 0),
        train_y=splits.train_y,
        val_x=apply_distortion(splits.val_x, spec, seed=seed * 3 + 1),
        val_y=splits.val_y,
        test_x=apply_distortion(splits.test_x, spec, seed=seed * 3 + 2),
        test_y=splits.test_y,
    )


# ------------------------------------------------- edge-side input features
FEATURE_NAMES: Tuple[str, ...] = ("mean", "std", "lap_var", "tv")


def input_features(x: np.ndarray) -> np.ndarray:
    """Cheap per-image statistics -> (N, 4) float64, columns FEATURE_NAMES.

    * ``mean`` / ``std``   -- pixel moments (brightness / contrast axes);
    * ``lap_var``          -- variance of the 4-neighbor Laplacian: collapses
                              under blur, explodes under additive noise;
    * ``tv``               -- mean absolute first difference (total
                              variation), a second blur/noise axis with a
                              different severity response than lap_var.

    This is the whole edge-side "distortion classifier" input: a few numpy
    reductions per image, no learned feature extractor.
    """
    x = np.asarray(x, np.float32)
    if x.ndim != 4:
        raise ValueError(f"expected (N, H, W, C) images, got shape {x.shape}")
    mean = x.mean(axis=(1, 2, 3))
    std = x.std(axis=(1, 2, 3))
    lap = (
        4.0 * x
        - np.roll(x, 1, axis=1)
        - np.roll(x, -1, axis=1)
        - np.roll(x, 1, axis=2)
        - np.roll(x, -1, axis=2)
    )
    lap_var = lap.var(axis=(1, 2, 3))
    tv = 0.5 * (
        np.abs(x - np.roll(x, 1, axis=1)).mean(axis=(1, 2, 3))
        + np.abs(x - np.roll(x, 1, axis=2)).mean(axis=(1, 2, 3))
    )
    return np.stack([mean, std, lap_var, tv], axis=1).astype(np.float64)


def default_contexts(
    kinds: Sequence[str] = ("gaussian_noise", "gaussian_blur", "contrast"),
    severities: Sequence[int] = (3,),
    include_clean: bool = True,
) -> List[DistortionSpec]:
    """A compact context set for experiments: clean + each kind at the
    given severities (the Pacheco setup keeps one expert per kind)."""
    specs = [CLEAN] if include_clean else []
    specs += [DistortionSpec(k, s) for k in kinds for s in severities]
    return specs
