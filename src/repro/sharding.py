"""Sharding rules: mesh axes, rule-based parameter PartitionSpecs, and
activation sharding constraints.

Conventions (Megatron-style tensor parallelism + (pod,) data parallelism):
  * batch dims shard on the data axes ('pod','data') when present;
  * attention heads / ffn hidden / vocab / MoE experts / mamba channels
    shard on the 'model' axis;
  * norms, routers, scalar SSM params replicate.

Parameter specs are assigned by *path rules* over the params pytree, so they
can never structurally drift from the initializers: `param_specs` walks the
actual tree. Stacked (scanned) segments have one extra leading layer dim,
which maps to None automatically (specs are aligned to trailing dims).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------- mesh context
_MESH: Optional[Mesh] = None
_DP_AXES: tuple = ()
_TP_AXIS: Optional[str] = None


def set_mesh(mesh: Optional[Mesh]):
    """Install mesh for activation constraints. None disables (CPU tests)."""
    global _MESH, _DP_AXES, _TP_AXIS
    _MESH = mesh
    if mesh is None:
        _DP_AXES, _TP_AXIS = (), None
        return
    names = mesh.axis_names
    _TP_AXIS = "model" if "model" in names else None
    _DP_AXES = tuple(n for n in names if n in ("pod", "data"))


def dp_axes():
    return _DP_AXES


def tp_axis():
    return _TP_AXIS


def _resolve(sym):
    if sym == "dp":
        return _DP_AXES if _DP_AXES else None
    if sym == "tp":
        return _TP_AXIS
    return sym


def _axis_size(ax) -> int:
    if ax is None or _MESH is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= dict(zip(_MESH.axis_names, _MESH.devices.shape))[a]
        return n
    return dict(zip(_MESH.axis_names, _MESH.devices.shape))[ax]


def fit_spec(spec_axes, shape) -> P:
    """Drop sharding on dims the mesh axes don't evenly divide (e.g. a
    global_batch=1 decode can't shard batch over 16 data shards)."""
    fitted = []
    for ax, dim in zip(spec_axes, shape):
        n = _axis_size(ax)
        fitted.append(ax if (n > 1 and dim % n == 0) else (None if n > 1 else ax))
    return P(*fitted)


def constrain(x, *spec_syms):
    """with_sharding_constraint using symbolic axes ('dp', 'tp', None)."""
    if _MESH is None:
        return x
    axes = [_resolve(s) for s in spec_syms]
    spec = fit_spec(axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


# ---------------------------------------------------------- param spec rules
# (path-regex, trailing-dim spec symbols). First match wins. The spec covers
# the LAST len(spec) dims; any leading dims (stacked scan layers) get None.
_RULES = [
    (r"embed/w$", ("tp", None)),
    (r"(lm_head|head)/w$", (None, "tp")),
    (r"pos_embed$", (None, None)),
    # attention
    (r"attn.*/w[qkv]$", (None, "tp", None)),
    (r"attn.*/b[qkv]$", ("tp", None)),
    (r"attn.*/wo$", ("tp", None, None)),
    (r"attn.*/(q_norm|k_norm)$", (None,)),
    # dense mlp
    (r"mlp/w_(gate|up)$", (None, "tp")),
    (r"mlp/w_down$", ("tp", None)),
    # moe (expert parallel on model axis)
    (r"moe/router$", (None, None)),
    (r"moe/w_(gate|up|down)$", ("tp", None, None)),
    # mamba
    (r"mamba/in_proj$", (None, "tp")),
    (r"mamba/dt_proj$", None),  # head-count width; replicate (split-proj variant)
    (r"mamba/conv_w$", (None, "tp")),
    (r"mamba/conv_b$", ("tp",)),
    (r"mamba/(A_log|D|dt_bias)$", ("tp",)),
    (r"mamba/norm_scale$", ("tp",)),
    (r"mamba/out_proj$", ("tp", None)),
    # convnet (paper's B-AlexNet): small; replicate
    (r"conv\d*/(w|b)$", None),
    (r"fc\d*/(w|b)$", None),
    # norms and everything else: replicate
    (r".*", None),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for(path_str: str, shape) -> P:
    ndim = len(shape)
    for pat, spec in _RULES:
        if re.search(pat, path_str):
            if spec is None:
                return P()
            spec = [_resolve(s) for s in spec]
            if ndim < len(spec):
                return P()
            pad = [None] * (ndim - len(spec))
            return fit_spec(pad + spec, shape)
    return P()


def param_specs(params):
    """PartitionSpec pytree matching `params` (call inside set_mesh context)."""

    def f(path, leaf):
        return spec_for(_path_str(path), leaf.shape)

    return jax.tree_util.tree_map_with_path(f, params)


def named_shardings(params, mesh: Mesh):
    set_mesh(mesh)
    specs = param_specs(params)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ------------------------------------------------------------ decode caches
def cache_specs_tree(cache_shapes, batch_sharded: bool = True):
    """PartitionSpecs for a decode cache pytree (from registry.cache_specs).

    batch_sharded=True: shard the cache batch dim over the data axes (the
    decode_32k regime). batch_sharded=False (long_500k, global_batch=1):
    shard the KV *sequence* dim over the data axes instead -- distributed
    flash-decode; softmax over the sharded axis lowers to an all-reduce.
    """
    b = _DP_AXES if (batch_sharded and _DP_AXES) else None
    s = None if batch_sharded else (_DP_AXES if _DP_AXES else None)

    def f(path, leaf):
        ps = _path_str(path)
        nd = leaf.ndim
        if ps.endswith("conv"):
            spec = [b, None, _TP_AXIS]
        elif ps.endswith("ssd"):
            spec = [b, _TP_AXIS, None, None]
        else:  # k / v KV caches: (batch, L, kv_heads, head_dim)
            spec = [b, s, _TP_AXIS, None]
        if nd < len(spec):
            spec = spec[-nd:] if nd else []
        pad = [None] * (nd - len(spec))
        return fit_spec(pad + spec, leaf.shape)

    return jax.tree_util.tree_map_with_path(f, cache_shapes)


def batch_specs_tree(batch_shapes):
    """PartitionSpecs for model inputs: batch dim on data axes, rest replicated."""
    b = _DP_AXES if _DP_AXES else None

    def f(path, leaf):
        nd = leaf.ndim
        if nd == 0:
            return P()
        return fit_spec([b] + [None] * (nd - 1), leaf.shape)

    return jax.tree_util.tree_map_with_path(f, batch_shapes)


# ------------------------------------------------------------ fleet mesh
def fleet_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D device mesh with axis ``"cells"`` for the compiled fleet
    pipeline (`repro.fleet.compiled`): per-cell request lanes and queue
    state shard over this axis via `shard_map`; gate/context/link tables
    replicate. `n_devices` caps the mesh (useful for tests forcing a
    specific shape); default is every visible device."""
    import numpy as np

    devs = jax.devices()
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(
                f"asked for {n_devices} mesh devices, have {len(devs)}"
            )
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), ("cells",))
