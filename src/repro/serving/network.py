"""Stochastic and time-varying uplink models.

The paper prices communication at a single fixed 18.8 Mbps Wi-Fi rate.
Adaptive partitioning (Edgent, 1806.07840) only pays off when the link
moves, so the serving layer models the uplink behind one interface:

    comm_time(nbytes, t) -> seconds to ship nbytes starting at sim time t

Three implementations:

* `FixedRateNetwork` -- the paper's constant link;
* `MarkovNetwork`    -- Gilbert-Elliott two-state (good/bad) Wi-Fi chain,
                        piecewise-constant over dwell slots, fully
                        deterministic under a seed regardless of query
                        order (slots are materialized sequentially);
* `TraceNetwork`     -- replay of a measured bandwidth trace as a step
                        function, optionally periodic.

`repro.offload.latency.comm_time` and
`repro.offload.simulator.simulate_batches` accept any of these in place of
the profile's fixed uplink; `repro.serving.runtime` drives them with the
simulation clock.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np


class NetworkModel:
    """Uplink with a (possibly time-varying) instantaneous rate.

    Transfers are priced at the rate in effect when they start -- a
    piecewise-constant approximation that keeps the event simulator exact
    and reproducible.
    """

    name = "network"

    def rate_bps(self, t: float = 0.0) -> float:
        raise NotImplementedError

    def rates_bps(self, times) -> np.ndarray:
        """Vectorized instantaneous rates at an array of times -> (N,)
        float64. The base implementation loops over `rate_bps`; subclasses
        whose rate is a step function override it with one indexing op --
        the fleet simulator prices whole transfer windows through this."""
        t = np.asarray(times, np.float64)
        return np.asarray([self.rate_bps(float(x)) for x in t.ravel()],
                          np.float64).reshape(t.shape)

    def comm_time(self, nbytes: float, t: float = 0.0) -> float:
        rate = self.rate_bps(t)
        if rate <= 0:
            raise ValueError(f"{self.name}: non-positive rate {rate} at t={t}")
        return nbytes * 8.0 / rate


@dataclass(frozen=True)
class FixedRateNetwork(NetworkModel):
    """The paper's model: a constant-rate uplink (18.8 Mbps Wi-Fi)."""

    bps: float
    name: str = "fixed"

    def rate_bps(self, t: float = 0.0) -> float:
        return self.bps

    def rates_bps(self, times) -> np.ndarray:
        return np.full(np.asarray(times, np.float64).shape, self.bps)


class MarkovNetwork(NetworkModel):
    """Gilbert-Elliott good/bad Wi-Fi: the chain advances once per
    `dwell_s` slot, so `rate_bps` is deterministic in `t` given the seed --
    slot states are materialized in order, one RNG draw per slot, no matter
    in what order times are queried."""

    name = "markov"

    def __init__(
        self,
        good_bps: float = 18.8e6,
        bad_bps: float = 2.0e6,
        p_good_to_bad: float = 0.2,
        p_bad_to_good: float = 0.2,
        dwell_s: float = 0.5,
        seed: int = 0,
        start_state: int = 0,  # 0 = good, 1 = bad
    ):
        if dwell_s <= 0:
            raise ValueError("dwell_s must be positive")
        self.good_bps = float(good_bps)
        self.bad_bps = float(bad_bps)
        self.p_good_to_bad = float(p_good_to_bad)
        self.p_bad_to_good = float(p_bad_to_good)
        self.dwell_s = float(dwell_s)
        self._rng = np.random.default_rng(seed)
        self._states = [int(start_state)]

    def _state(self, slot: int) -> int:
        while len(self._states) <= slot:
            s = self._states[-1]
            u = self._rng.random()
            if s == 0:
                s = 1 if u < self.p_good_to_bad else 0
            else:
                s = 0 if u < self.p_bad_to_good else 1
            self._states.append(s)
        return self._states[slot]

    def rate_bps(self, t: float = 0.0) -> float:
        slot = int(max(t, 0.0) // self.dwell_s)
        return self.bad_bps if self._state(slot) else self.good_bps

    def rates_bps(self, times) -> np.ndarray:
        t = np.asarray(times, np.float64)
        slots = (np.maximum(t, 0.0) // self.dwell_s).astype(np.int64)
        if slots.size:
            self._state(int(slots.max()))  # materialize in order, once
        states = np.asarray(self._states, np.int64)[slots]
        return np.where(states == 1, self.bad_bps, self.good_bps)


class TraceNetwork(NetworkModel):
    """Bandwidth-trace replay: rate is a step function of time.

    `times_s` must be sorted and start at 0; segment i holds the i-th
    trace rate until `times_s[i+1]`. With `period_s` set, the trace
    loops. The trace array is stored as ``trace_rates_bps`` (the
    `rates_bps` name is the vectorized-lookup method every NetworkModel
    exposes).
    """

    name = "trace"

    def __init__(
        self,
        times_s: Sequence[float],
        rates_bps: Sequence[float],
        period_s: Optional[float] = None,
    ):
        t = np.asarray(times_s, np.float64)
        r = np.asarray(rates_bps, np.float64)
        if t.ndim != 1 or t.shape != r.shape or t.size == 0:
            raise ValueError("times_s and rates_bps must be equal-length 1-D")
        if t[0] != 0.0 or np.any(np.diff(t) <= 0):
            raise ValueError("times_s must start at 0 and strictly increase")
        if period_s is not None and period_s <= t[-1]:
            raise ValueError("period_s must exceed the last trace time")
        self.times_s = t
        self.trace_rates_bps = r
        self.period_s = period_s

    def rate_bps(self, t: float = 0.0) -> float:
        t = max(float(t), 0.0)
        if self.period_s is not None:
            t = t % self.period_s
        i = int(np.searchsorted(self.times_s, t, side="right")) - 1
        return float(self.trace_rates_bps[max(i, 0)])

    def rates_bps(self, times) -> np.ndarray:
        t = np.maximum(np.asarray(times, np.float64), 0.0)
        if self.period_s is not None:
            t = t % self.period_s
        i = np.searchsorted(self.times_s, t, side="right") - 1
        return self.trace_rates_bps[np.maximum(i, 0)]


def network_for(profile) -> FixedRateNetwork:
    """The fixed-rate network a LatencyProfile implies (its uplink_bps)."""
    return FixedRateNetwork(profile.uplink_bps)
