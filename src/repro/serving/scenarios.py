"""Reference serving scenarios shared by the acceptance tests and the
benchmark suite, so the scenario CI asserts on and the scenario the tests
pin down cannot silently drift apart.

`synthetic_cascade_logits` is a deterministic stand-in for a trained
two-exit B-AlexNet's logits: branch 1 moderately confident, branch 2
strictly more confident on the same samples, and a near-oracle cloud main
head. `run_congested_markov` is the acceptance scenario from ISSUE 2: a
Poisson fleet against a mostly-bad Markov Wi-Fi link, served either by the
static plan or with the online controller re-scoring it.

`synthetic_distorted_cascade` + `run_distortion_drift` are the ISSUE 3
acceptance scenario: the same cascade pushed through the distortion
taxonomy of `repro.data.distortion`. Images and the edge-side features the
estimator sees are REAL (cifar_like frames, really distorted); the logits
are a documented synthetic stand-in whose miscalibration grows with
severity -- margins shrink while logit magnitudes grow, the overconfident
failure mode Pacheco et al. (2108.09343) measure on trained networks. A
single temperature fit on clean data therefore under-corrects distorted
regimes, which is exactly the gap the expert `PlanBank` closes.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bank import fit_bank
from repro.core.policy import OffloadPlan, make_plan
from repro.data.distortion import (
    DistortionSpec,
    apply_distortion,
    input_features,
)
from repro.offload import latency as L
from repro.serving.controller import ControllerConfig, OnlineController
from repro.serving.drift import ContextualLogitsCore, MarkovContextSchedule
from repro.serving.network import MarkovNetwork
from repro.serving.runtime import LogitsCore, RuntimeConfig, ServingRuntime
from repro.serving.telemetry import Telemetry
from repro.serving.workload import poisson_workload


def synthetic_cascade_logits(
    n: int = 512, c: int = 10, seed: int = 0
) -> Tuple[Dict[int, np.ndarray], np.ndarray, np.ndarray]:
    """-> ({1: z1, 2: z2}, final_logits, labels)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, c, n)
    z1 = (rng.normal(size=(n, c)) * 2).astype(np.float32)
    z1[np.arange(n), y] += 3.0
    z2 = z1.copy()
    z2[np.arange(n), y] += 2.0
    final = np.zeros((n, c), np.float32)
    final[np.arange(n), y] = 9.0
    return {1: z1, 2: z2}, final, y


def congested_markov_network(
    good_bps: float = 18.8e6, bad_bps: float = 1.5e6
) -> MarkovNetwork:
    """The paper's nominal link that spends most of its time degraded."""
    return MarkovNetwork(
        good_bps=good_bps, bad_bps=bad_bps,
        p_good_to_bad=0.5, p_bad_to_good=0.1,
        dwell_s=1.0, seed=1, start_state=1,
    )


def run_congested_markov(
    plan: OffloadPlan,
    exit_logits: Dict[int, np.ndarray],
    final_logits: np.ndarray,
    labels: np.ndarray,
    n_requests: int = 800,
    arrival_rate_hz: float = 80.0,
    deadline_s: float = 0.1,
    with_controller: bool = False,
    controller_config: Optional[ControllerConfig] = None,
    profile: Optional[L.LatencyProfile] = None,
    obs=None,
) -> Telemetry:
    profile = profile or L.paper_2020()
    core = LogitsCore(exit_logits, final_logits, plan, labels=labels)
    reqs = poisson_workload(
        arrival_rate_hz, n_requests, len(labels), deadline_s=deadline_s, seed=2
    )
    controller = None
    if with_controller:
        controller = OnlineController(
            plan, profile, exit_logits, final_logits=final_logits,
            labels=labels,
            config=controller_config
            or ControllerConfig(interval_s=0.5, window_s=1.0, min_accuracy=0.9),
        )
    rt = ServingRuntime(
        core, profile, plan, reqs,
        network=congested_markov_network(),
        config=RuntimeConfig(max_batch=4, batch_window_s=0.02),
        controller=controller, obs=obs,
    )
    return rt.run()


# ------------------------------------------------- ISSUE 3: input drift
def drift_contexts() -> List[DistortionSpec]:
    """The reference context set: clean + one expert-worthy regime per
    distortion family, at staggered severities so the experts genuinely
    differ from one another (not just from the clean fit)."""
    return [
        DistortionSpec("clean"),
        DistortionSpec("gaussian_noise", 2),
        DistortionSpec("gaussian_blur", 3),
        DistortionSpec("contrast", 4),
    ]


def synthetic_distorted_cascade(
    contexts: Optional[List[DistortionSpec]] = None,
    n: int = 1024,
    n_val: int = 1024,
    c: int = 10,
    seed: int = 0,
    directions: Optional[Dict[str, str]] = None,
) -> Tuple[dict, dict]:
    """-> (val, test) per-context cascade data for the drift scenario.

    Each dict has keys ``exit_logits`` ({ctx: {1: z1, 2: z2}}), ``final``
    ({ctx: (N, C)}), ``features`` ({ctx: (N, F)} -- input_features of the
    REALLY distorted cifar_like images), and ``labels`` ((N,) shared across
    contexts: the same base samples, distorted).

    The logit model (a stand-in for a trained B-AlexNet under distortion,
    severity s; the distortion KIND shapes only the real images/features):

    * each sample carries a margin d ~ U(2, 9) and an aleatoric accuracy
      ceiling q(d) = 1 - 0.6 exp(-0.18 d) -- the branch's perceived class
      is the label with probability q, a confusable class otherwise, so
      even confident samples top out near-but-above p_tar rather than at
      1.0 (the paper's ~80%-accuracy CIFAR regime);
    * severity marks a growing fraction phi(s) = 0.2 + 0.12 s of samples
      as AFFECTED: their perceived class is re-drawn near chance (the
      branch is fooled) and their margin collapses to 0.45 d (the
      evidence genuinely weakens);
    * every logit is scaled by 1.4 (1 + 0.5 s): the head is overconfident
      on clean inputs (clean T fits ~2.3, the paper's Fig. 2 regime) and
      gets MORE overconfident as inputs degrade -- Pacheco et al.'s
      observation, and the reason one clean-fit temperature under-corrects
      every distorted regime.

    `directions` optionally flips individual distortion KINDS to the
    UNDERCONFIDENT drift the trained model of
    ``examples/offload_under_distortion.py`` exhibits: severity then
    deflates the logit scale to 1.4 / (1 + 0.6 s) while the affected
    fraction stays small (phi = 0.05 + 0.03 s) -- accuracy barely moves
    but confidence evaporates, so a clean-fit gate starves the edge and
    floods the uplink for no reliability gain, and the matching expert
    re-SHARPENS (expert T below the clean fit). Keys are distortion kinds
    (``{"gaussian_blur": "under"}``), value ``"over"`` (the default) or
    ``"under"``. Omitting the argument reproduces the pre-existing
    all-overconfident data bit-for-bit.

    All per-sample draws happen ONCE per split and are shared by every
    context, so plan comparisons see purely the systematic severity
    effect, never different noise realizations.
    """
    from repro.data.synthetic import cifar_like

    contexts = drift_contexts() if contexts is None else contexts
    directions = directions or {}
    unknown_dir = set(directions.values()) - {"over", "under"}
    if unknown_dir:
        raise ValueError(f"directions must be 'over'/'under', got {unknown_dir}")
    unknown_kind = set(directions) - {spec.kind for spec in contexts}
    if unknown_kind:  # a typoed kind must not silently measure the default
        raise ValueError(
            f"directions name kinds absent from the context set: "
            f"{sorted(unknown_kind)}"
        )
    rng = np.random.default_rng(seed)
    images = cifar_like(n_train=8, n_val=n_val, n_test=n, seed=seed + 1)

    def perceived(y, ok_prob, rng, m):
        """The class a branch head locks onto: the label w.p. ok_prob,
        else a confusable other class."""
        ok = rng.random(m) < ok_prob
        confused = (y + rng.integers(1, c, m)) % c
        return np.where(ok, y, confused)

    def make_split(m, img_x, img_seed):
        y = rng.integers(0, c, m)
        base = (rng.normal(size=(m, c)) * 1.2).astype(np.float32)
        d = rng.uniform(2.0, 9.0, m).astype(np.float32)
        u = rng.random(m)  # severity-affected position (nested: s' > s)
        q1 = 1.0 - 0.6 * np.exp(-0.18 * d)
        q2 = 1.0 - 0.45 * np.exp(-0.18 * d)  # the deeper exit sees more
        views = {
            1: (perceived(y, q1, rng, m), perceived(y, 0.35, rng, m), 1.0),
            2: (perceived(y, q2, rng, m), perceived(y, 0.5, rng, m), 1.2),
        }
        out = {"exit_logits": {}, "final": {}, "features": {}, "labels": y}
        idx = np.arange(m)
        for spec in contexts:
            s = spec.severity
            if directions.get(spec.kind, "over") == "under" and s:
                # underconfident drift: evidence survives, magnitude doesn't
                affected = u < 0.05 + 0.03 * s
                scale = 1.4 / (1.0 + 0.6 * s)
            else:
                affected = u < (0.2 + 0.12 * s if s else 0.0)
                scale = 1.4 * (1.0 + 0.5 * s)
            per_branch = {}
            for b, (c_clean, c_dist, dmul) in views.items():
                z = base.copy()
                z[idx, np.where(affected, c_dist, c_clean)] += np.where(
                    affected, 0.45 * d, d
                ) * dmul
                per_branch[b] = (z * scale).astype(np.float32)
            final = np.zeros((m, c), np.float32)
            final[idx, y] = 9.0 * (1.0 - 0.03 * s)
            out["exit_logits"][spec.key] = per_branch
            out["final"][spec.key] = final
            out["features"][spec.key] = input_features(
                apply_distortion(img_x, spec, seed=img_seed)
            )
        return out

    val = make_split(n_val, images.val_x, img_seed=seed + 11)
    test = make_split(n, images.test_x, img_seed=seed + 12)
    return val, test


def fit_drift_plans(val: dict, p_tar: float = 0.8):
    """-> (uncalibrated, global single, expert bank) fit on the val split.

    * uncalibrated: identity calibrators (the conventional-DNN baseline);
    * global: ONE temperature pair fit on the CLEAN validation logits (the
      paper's procedure, blind to distortion);
    * bank: one expert plan per context + the feature estimator.
    """
    clean = val["exit_logits"]["clean"]
    y = val["labels"]
    uncal = make_plan([clean[1], clean[2]], y, p_tar=p_tar, calibrated=False)
    global_plan = make_plan([clean[1], clean[2]], y, p_tar=p_tar)
    bank = fit_bank(
        {ctx: [z[1], z[2]] for ctx, z in val["exit_logits"].items()},
        y,
        p_tar=p_tar,
        default_context="clean",
        features_by_context=val["features"],
    )
    return uncal, global_plan, bank


def drift_controller_config(
    interval_s: float = 1.0,
) -> ControllerConfig:
    """The reference controller configuration for the drift scenario's
    controller arms -- shared by the acceptance test and the distortion
    bench so the config CI asserts under and the config the tests pin
    down are the same object.

    The p_tar grid and the reliability-gap cap are what give a
    context-aware re-score something to use: under overconfident drift
    the gap-minimizing effective p_tar is context-dependent (high on
    clean inputs, low on heavily distorted ones), so a controller that
    prices candidates on the OBSERVED mix can track it while the
    clean-validation-only re-score, whose gap estimates are always tiny,
    cannot. The accuracy floor is deliberately below the clean floor:
    holding the paper's reliability contract under heavy distortion
    costs end-to-end accuracy, and a floor at the clean level would
    forbid exactly the honest low-p_tar candidates the contract needs.
    """
    return ControllerConfig(
        interval_s=interval_s,
        window_s=2.0 * interval_s,
        min_accuracy=0.75,
        p_tar_grid=(0.5, 0.6, 0.7, 0.8, 0.9),
        max_reliability_gap=0.05,
    )


def severity_drift_schedule(
    contexts: Optional[List[DistortionSpec]] = None,
    dwell_s: float = 3.0,
    seed: int = 10,
) -> MarkovContextSchedule:
    """Markov regime drift over the reference contexts, starting clean.
    The default (dwell, seed) pair visits ALL four regimes within the
    ~37 s the reference 1500-request workload spans."""
    contexts = drift_contexts() if contexts is None else contexts
    return MarkovContextSchedule(
        [spec.key for spec in contexts],
        dwell_s=dwell_s, p_stay=0.5, seed=seed, start_context="clean",
    )


def run_distortion_drift(
    plan_or_bank,
    test: dict,
    schedule=None,
    n_requests: int = 1500,
    arrival_rate_hz: float = 40.0,
    deadline_s: float = 0.1,
    with_controller: bool = False,
    val: Optional[dict] = None,
    profile: Optional[L.LatencyProfile] = None,
    controller_interval_s: float = 1.0,
    context_aware: bool = False,
    controller_config: Optional[ControllerConfig] = None,
    obs=None,
) -> Telemetry:
    """Serve `test` under severity drift with a plan or an expert bank.

    The network is the paper's fixed link: holding bandwidth constant
    isolates the input-drift axis, so any miscalibration-gap difference
    between plans is attributable to calibration alone. with_controller
    (needs `val`) layers the Edgent-style re-scorer on top, demonstrating
    that bandwidth-driven (branch, p_tar) moves compose with
    distortion-driven expert selection; `controller_interval_s` sets its
    cadence (the dwell-vs-interval bench sweeps it against the schedule's
    dwell time).

    `context_aware` switches the controller from the CLEAN-validation-only
    re-score (the original arm: candidate tables priced on clean logits,
    blind to drift) to the fleet's mix-weighted rule ported back to the
    event runtime: the controller receives ALL contexts' validation
    logits and each tick weights them by the traffic mix its own
    telemetry observed over the trailing window, so candidate offload
    probabilities, accuracies, and reliability gaps price the inputs
    actually being served. `controller_config` overrides the reference
    controller configuration (shared by both arms so the information,
    not the knobs, is the difference).
    """
    profile = profile or L.paper_2020()
    schedule = severity_drift_schedule() if schedule is None else schedule
    core = ContextualLogitsCore(
        test["exit_logits"], test["final"], plan_or_bank, schedule,
        labels=test["labels"], features_by_context=test["features"],
    )
    reqs = poisson_workload(
        arrival_rate_hz, n_requests, core.n_samples,
        deadline_s=deadline_s, seed=7,
    )
    controller = None
    if with_controller:
        if val is None:
            raise ValueError("with_controller needs the val split")
        config = controller_config or ControllerConfig(
            interval_s=controller_interval_s,
            window_s=2.0 * controller_interval_s,
            min_accuracy=0.85,
        )
        if context_aware:  # all contexts' val logits -> mix-weighted tables
            exit_logits, final_logits = val["exit_logits"], val["final"]
        else:  # the original clean-validation-only re-score
            exit_logits = val["exit_logits"]["clean"]
            final_logits = val["final"]["clean"]
        controller = OnlineController(
            plan_or_bank, profile, exit_logits,
            final_logits=final_logits, labels=val["labels"],
            config=config,
        )
    rt = ServingRuntime(
        core, profile, plan_or_bank, reqs,
        config=RuntimeConfig(max_batch=4, batch_window_s=0.02),
        controller=controller, obs=obs,
    )
    return rt.run()
