"""Reference serving scenarios shared by the acceptance tests and the
benchmark suite, so the scenario CI asserts on and the scenario the tests
pin down cannot silently drift apart.

`synthetic_cascade_logits` is a deterministic stand-in for a trained
two-exit B-AlexNet's logits: branch 1 moderately confident, branch 2
strictly more confident on the same samples, and a near-oracle cloud main
head. `run_congested_markov` is the acceptance scenario from ISSUE 2: a
Poisson fleet against a mostly-bad Markov Wi-Fi link, served either by the
static plan or with the online controller re-scoring it.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.policy import OffloadPlan
from repro.offload import latency as L
from repro.serving.controller import ControllerConfig, OnlineController
from repro.serving.network import MarkovNetwork
from repro.serving.runtime import LogitsCore, RuntimeConfig, ServingRuntime
from repro.serving.telemetry import Telemetry
from repro.serving.workload import poisson_workload


def synthetic_cascade_logits(
    n: int = 512, c: int = 10, seed: int = 0
) -> Tuple[Dict[int, np.ndarray], np.ndarray, np.ndarray]:
    """-> ({1: z1, 2: z2}, final_logits, labels)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, c, n)
    z1 = (rng.normal(size=(n, c)) * 2).astype(np.float32)
    z1[np.arange(n), y] += 3.0
    z2 = z1.copy()
    z2[np.arange(n), y] += 2.0
    final = np.zeros((n, c), np.float32)
    final[np.arange(n), y] = 9.0
    return {1: z1, 2: z2}, final, y


def congested_markov_network(
    good_bps: float = 18.8e6, bad_bps: float = 1.5e6
) -> MarkovNetwork:
    """The paper's nominal link that spends most of its time degraded."""
    return MarkovNetwork(
        good_bps=good_bps, bad_bps=bad_bps,
        p_good_to_bad=0.5, p_bad_to_good=0.1,
        dwell_s=1.0, seed=1, start_state=1,
    )


def run_congested_markov(
    plan: OffloadPlan,
    exit_logits: Dict[int, np.ndarray],
    final_logits: np.ndarray,
    labels: np.ndarray,
    n_requests: int = 800,
    arrival_rate_hz: float = 80.0,
    deadline_s: float = 0.1,
    with_controller: bool = False,
    controller_config: Optional[ControllerConfig] = None,
    profile: Optional[L.LatencyProfile] = None,
) -> Telemetry:
    profile = profile or L.paper_2020()
    core = LogitsCore(exit_logits, final_logits, plan, labels=labels)
    reqs = poisson_workload(
        arrival_rate_hz, n_requests, len(labels), deadline_s=deadline_s, seed=2
    )
    controller = None
    if with_controller:
        controller = OnlineController(
            plan, profile, exit_logits, final_logits=final_logits,
            labels=labels,
            config=controller_config
            or ControllerConfig(interval_s=0.5, window_s=1.0, min_accuracy=0.9),
        )
    rt = ServingRuntime(
        core, profile, plan, reqs,
        network=congested_markov_network(),
        config=RuntimeConfig(max_batch=4, batch_window_s=0.02),
        controller=controller,
    )
    return rt.run()
