"""Online offload controller (Edgent-style, 1806.07840).

Every `interval_s` of simulated time the controller looks at a trailing
window of telemetry -- the mean uplink rate observed by actual transfers
and the mean queue depth -- and re-scores the deployed `OffloadPlan` with
`repro.core.policy.rescore_plan`: the plan's fitted per-exit calibrators
are applied to held-out validation logits (no re-fitting), each candidate
(branch, effective p_tar) is priced with the Neurosurgeon expected-latency
objective at the MEASURED bandwidth, and the cheapest candidate that still
meets the accuracy floor wins. Queue pressure scales the effective edge
service time (each queued request adds one service quantum of wait), so a
backed-up fleet biases toward configurations that offload less.

The controller owns no queues and no clock: `ServingRuntime` calls
`update(t, telemetry)` and applies the returned plan's (exit_index, p_tar).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import OffloadPlan, rescore_plan
from repro.offload import latency as L


@dataclass
class ControllerConfig:
    interval_s: float = 1.0  # re-score cadence (simulated seconds)
    window_s: float = 2.0  # trailing telemetry window
    p_tar_grid: Optional[Sequence[float]] = None  # None = keep the plan's
    min_accuracy: Optional[float] = None  # accuracy floor for candidates
    hysteresis: float = 0.05  # min relative latency gain to switch
    queue_aware: bool = True  # inflate edge time by observed queue depth
    utilization_aware: bool = True  # M/M/1 uplink correction from arrivals


class OnlineController:
    """Re-selects (deployed branch, effective p_tar) from telemetry.

    exit_logits: {physical_branch: (N, C) held-out validation logits},
    the same convention as `LogitsCore`. `labels`/`final_logits` enable the
    accuracy floor; without them candidates are ranked by latency alone.

    Accepts a `repro.core.bank.PlanBank` in place of the plan: the bank's
    default plan is re-scored, so the controller moves the fleet-wide
    (branch, p_tar) while the bank keeps picking per-context expert
    calibrators inside the contextual core -- bandwidth-driven re-scoring
    and distortion-driven expert selection compose without touching each
    other's state.
    """

    def __init__(
        self,
        plan: OffloadPlan,
        profile: L.LatencyProfile,
        exit_logits: Dict[int, np.ndarray],
        final_logits: Optional[np.ndarray] = None,
        labels: Optional[np.ndarray] = None,
        config: Optional[ControllerConfig] = None,
        payload_nbytes=None,
    ):
        from repro.core.bank import PlanBank

        if isinstance(plan, PlanBank):
            plan = plan.default_plan
        if plan.criterion != "confidence":
            raise ValueError(
                "OnlineController re-scores the confidence target p_tar; "
                f"{plan.criterion!r}-criterion plans are not re-scorable"
            )
        self.plan = plan
        self.profile = profile
        self.config = config or ControllerConfig()
        self.branches = sorted(exit_logits)
        if self.branches != list(range(1, len(self.branches) + 1)):
            raise ValueError(
                "exit_logits keys must be contiguous physical branches 1..K "
                "(branch k gates with plan.calibrators[k-1]); got "
                f"{self.branches}"
            )
        self.exit_logits_list = [exit_logits[b] for b in self.branches]
        self.final_logits = final_logits
        self.labels = labels
        if payload_nbytes is None:
            from repro.models.convnet import payload_bytes

            payload_nbytes = payload_bytes
        # calibrated (conf, pred) never change between ticks: compute once
        from repro.core.exits import gate_statistics

        self._exit_stats = []
        for i, z in enumerate(self.exit_logits_list):
            conf, pred, _ = gate_statistics(plan.calibrated_logits(z, i))
            self._exit_stats.append((np.asarray(conf), np.asarray(pred)))
        self.edge_times_s = [L.edge_time(profile, b) for b in self.branches]
        self.cloud_times_s = [L.cloud_time(profile, b) for b in self.branches]
        self.payload_bytes = [payload_nbytes(b) for b in self.branches]
        self.history: List[Tuple[float, float, int, float]] = []  # (t, bw, branch, p_tar)

    @property
    def interval_s(self) -> float:
        return self.config.interval_s

    def update(self, t: float, telemetry) -> OffloadPlan:
        cfg = self.config
        bw = telemetry.bandwidth_estimate(cfg.window_s, now=t)
        if bw is None:
            bw = self.profile.uplink_bps  # nothing measured yet: trust nominal
        edge_times = self.edge_times_s
        if cfg.queue_aware:
            depth = telemetry.queue_estimate(cfg.window_s, now=t)
            if depth is not None and depth > 0:
                edge_times = [e * (1.0 + depth) for e in edge_times]
        rate_hz = None
        if cfg.utilization_aware:
            rate_hz = telemetry.arrival_rate_estimate(cfg.window_s, now=t)

        # candidate table under measured conditions (calibrators re-used)
        candidate, table = rescore_plan(
            self.plan,
            self.exit_logits_list,
            edge_times_s=edge_times,
            cloud_times_s=self.cloud_times_s,
            payload_bytes=self.payload_bytes,
            uplink_bps=bw,
            labels=self.labels,
            final_logits=self.final_logits,
            p_tar_grid=cfg.p_tar_grid,
            min_accuracy=cfg.min_accuracy,
            arrival_rate_hz=rate_hz,
            exit_stats=self._exit_stats,
        )
        # hysteresis: keep the incumbent unless the ADOPTED candidate (the
        # accuracy-feasible winner, not the global latency minimum) is
        # clearly better -- but never retain an incumbent that itself
        # violates the accuracy floor
        def row_for(p):
            return next(
                (
                    r for r in table
                    if r["exit_index"] == p.exit_index and r["p_tar"] == p.p_tar
                ),
                None,
            )

        cur, new = row_for(self.plan), row_for(candidate)
        cur_feasible = cur is not None and (
            cfg.min_accuracy is None
            or (cur["accuracy"] is not None and cur["accuracy"] >= cfg.min_accuracy)
        )
        if (
            cur_feasible
            and new is not None
            and new["expected_latency_s"]
            > (1.0 - cfg.hysteresis) * cur["expected_latency_s"]
        ):
            candidate = self.plan  # not worth churning the fleet
        self.plan = candidate
        self.history.append((t, bw, candidate.exit_index + 1, candidate.p_tar))
        return candidate
