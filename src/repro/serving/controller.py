"""Online offload controller (Edgent-style, 1806.07840).

Every `interval_s` of simulated time the controller looks at a trailing
window of telemetry -- the mean uplink rate observed by actual transfers
and the mean queue depth -- and re-scores the deployed `OffloadPlan`
through the shared `repro.core.control.ControllerCore`: the plan's fitted
per-exit calibrators are applied to held-out validation logits (no
re-fitting), each candidate (branch, effective p_tar) is priced with the
Neurosurgeon expected-latency objective at the MEASURED bandwidth, and
the cheapest candidate that still meets the accuracy floor (and, when
capped, the estimated reliability-gap contract) wins. Queue pressure
scales the effective edge service time (each queued request adds one
service quantum of wait), so a backed-up fleet biases toward
configurations that offload less.

Built with per-context validation logits (``{context: {branch: (N, C)}}``
+ per-context final logits), the controller is CONTEXT-AWARE -- the
fleet's mix-weighted re-scoring, ported back to the event runtime: each
tick it asks its own telemetry for the trailing-window traffic mix
(`Telemetry.context_mix_estimate`, fed by gate-time context verdicts) and
weights the validation samples by each context's observed share, so the
candidate table prices the drifting inputs actually being served instead
of the clean distribution.

The controller owns no queues and no clock: `ServingRuntime` calls
`update(t, telemetry)` and applies the returned plan's (exit_index, p_tar).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.control import ControlConfig, ControllerCore, hold_incumbent
from repro.core.policy import OffloadPlan, rescore_plan  # noqa: F401  (re-export)
from repro.offload import latency as L


@dataclass
class ControllerConfig(ControlConfig):
    """The shared control knobs (`repro.core.control.ControlConfig`) plus
    the event runtime's queue-awareness."""

    queue_aware: bool = True  # inflate edge time by observed queue depth


class OnlineController:
    """Re-selects (deployed branch, effective p_tar) from telemetry.

    exit_logits: {physical_branch: (N, C) held-out validation logits}
    (the `LogitsCore` convention), or {context: {branch: (N, C)}} with
    per-context `final_logits` for the context-aware mix-weighted
    re-score. `labels`/`final_logits` enable the accuracy floor and the
    reliability-gap cap; without them candidates are ranked by latency
    alone.

    Accepts a `repro.core.bank.PlanBank` in place of the plan: the bank's
    default plan is re-scored, so the controller moves the fleet-wide
    (branch, p_tar) while the bank keeps picking per-context expert
    calibrators inside the contextual core -- bandwidth-driven re-scoring
    and distortion-driven expert selection compose without touching each
    other's state.
    """

    def __init__(
        self,
        plan: OffloadPlan,
        profile: L.LatencyProfile,
        exit_logits: Dict,
        final_logits=None,
        labels: Optional[np.ndarray] = None,
        config: Optional[ControllerConfig] = None,
        payload_nbytes=None,
    ):
        self.config = config or ControllerConfig()
        self.core = ControllerCore(
            plan, profile, exit_logits,
            final_logits=final_logits, labels=labels,
            payload_nbytes=payload_nbytes,
            compression_levels=self.config.compression_levels,
        )
        if self.config.max_reliability_gap is not None and not self.core.has_labels:
            raise ValueError(
                "max_reliability_gap needs labels to estimate candidate "
                "on-device accuracy"
            )
        self.plan = self.core.plan
        self.profile = profile
        self.history: List[Tuple[float, float, int, float]] = []  # (t, bw, branch, p_tar)
        #: optional repro.obs.AuditLog; ServingRuntime injects it when an
        #: Observability bundle is attached. Purely write-only evidence.
        self.audit = None

    @property
    def branches(self) -> List[int]:
        return self.core.branches

    @property
    def interval_s(self) -> float:
        return self.config.interval_s

    def update(self, t: float, telemetry) -> OffloadPlan:
        cfg = self.config
        bw = telemetry.bandwidth_estimate(cfg.window_s, now=t)
        if bw is None:
            bw = self.profile.uplink_bps  # nothing measured yet: trust nominal
        edge_times = None
        if cfg.queue_aware:
            depth = telemetry.queue_estimate(cfg.window_s, now=t)
            if depth is not None and depth > 0:
                edge_times = [
                    e * (1.0 + depth) for e in self.core.edge_times_s
                ]
        rate_hz = None
        if cfg.utilization_aware:
            rate_hz = telemetry.arrival_rate_estimate(cfg.window_s, now=t)
        weight = None
        if self.core.context_aware:
            mix = telemetry.context_mix_estimate(cfg.window_s, now=t)
            weight = self.core.sample_weight_for_mix(mix)

        # candidate table under measured conditions (calibrators re-used)
        candidate, table = self.core.rescore(
            self.plan,
            uplink_bps=bw,
            edge_times_s=edge_times,
            arrival_rate_hz=rate_hz,
            p_tar_grid=cfg.p_tar_grid,
            branches=cfg.branches,
            min_accuracy=cfg.min_accuracy,
            max_reliability_gap=cfg.max_reliability_gap,
            sample_weight=weight,
        )
        # hysteresis: keep the incumbent unless the ADOPTED candidate (the
        # feasible winner, not the global latency minimum) is clearly
        # better -- but never retain an incumbent that itself violates the
        # accuracy floor or the reliability-gap cap
        if hold_incumbent(
            table, self.plan, candidate, cfg.hysteresis,
            min_accuracy=cfg.min_accuracy,
            max_reliability_gap=cfg.max_reliability_gap,
        ):
            candidate = self.plan  # not worth churning the fleet
        held = candidate is self.plan
        prev = self.plan
        self.plan = candidate
        self.history.append((t, bw, candidate.exit_index + 1, candidate.p_tar))
        if self.audit is not None:
            self.audit.record(
                t, "online_controller", "controller_rescore",
                bandwidth_bps=float(bw),
                arrival_rate_hz=None if rate_hz is None else float(rate_hz),
                held=bool(held),
                changed=bool(candidate.exit_index != prev.exit_index
                             or candidate.p_tar != prev.p_tar
                             or candidate.compression_level
                             != prev.compression_level),
                chosen={"branch": candidate.exit_index + 1,
                        "p_tar": float(candidate.p_tar),
                        "compression_level": int(candidate.compression_level)},
            )
        return candidate
