"""Request generators for the serving runtime.

A workload is a list of `Request`s sorted by arrival time. Every generator
is fully seeded/deterministic; samples index into whatever dataset (or
precomputed-logits array) the compute core serves. The default sequential
sample order walks the dataset exactly once per pass, so aggregate gate
statistics match the offline batch simulator on the same logits.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class Request:
    req_id: int
    arrival_s: float
    sample: int  # index into the dataset / logits arrays
    device: int  # which edge device receives it
    deadline_s: Optional[float] = None  # per-request latency budget


def _build(arrivals, n_samples, n_devices, deadline_s, sample_order, seed):
    if sample_order == "sequential":
        samples = [i % n_samples for i in range(len(arrivals))]
    elif sample_order == "random":
        rng = np.random.default_rng(seed + 1)
        samples = rng.integers(0, n_samples, len(arrivals)).tolist()
    else:
        raise ValueError(f"unknown sample_order {sample_order!r}")
    return [
        Request(
            req_id=i,
            arrival_s=float(t),
            sample=samples[i],
            device=i % n_devices,
            deadline_s=deadline_s,
        )
        for i, t in enumerate(arrivals)
    ]


def poisson_workload(
    rate_hz: float,
    n_requests: int,
    n_samples: int,
    n_devices: int = 1,
    deadline_s: Optional[float] = None,
    sample_order: str = "sequential",
    seed: int = 0,
) -> List[Request]:
    """Poisson arrivals at `rate_hz` (exponential i.i.d. interarrivals)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n_requests))
    return _build(arrivals, n_samples, n_devices, deadline_s, sample_order, seed)


def constant_workload(
    rate_hz: float,
    n_requests: int,
    n_samples: int,
    n_devices: int = 1,
    deadline_s: Optional[float] = None,
    sample_order: str = "sequential",
    seed: int = 0,
) -> List[Request]:
    """Deterministically spaced arrivals (period 1/rate_hz) -- with the
    period above the worst-case service time, queues provably stay empty,
    which is the static special case the runtime tests pin down."""
    period = 1.0 / rate_hz
    arrivals = period * np.arange(1, n_requests + 1)
    return _build(arrivals, n_samples, n_devices, deadline_s, sample_order, seed)


def trace_workload(
    arrival_times_s: Sequence[float],
    n_samples: int,
    n_devices: int = 1,
    deadline_s: Optional[float] = None,
    sample_order: str = "sequential",
    seed: int = 0,
) -> List[Request]:
    """Replay measured arrival timestamps (must be sorted)."""
    arrivals = np.asarray(arrival_times_s, np.float64)
    if np.any(np.diff(arrivals) < 0):
        raise ValueError("arrival_times_s must be sorted")
    return _build(arrivals, n_samples, n_devices, deadline_s, sample_order, seed)
