"""Per-request bookkeeping for the serving runtime.

`Telemetry` collects one `RequestRecord` per served request plus timestamped
observations of uplink bandwidth, queue depth, gate-time context verdicts,
and controller decisions. It answers both the reporting questions
(p50/p95/p99 latency, deadline-miss rate, offload rate, accuracy,
throughput) and the control questions (what did the link/queues/traffic
mix look like over the last window) -- the latter is what
`OnlineController` consumes.

The metric and estimator definitions live in `repro.core.control`
(`latency_stats_ms`, `on_device_gap`, `windowed_mean`/`windowed_rate`/
`windowed_mix`) and are shared with `repro.fleet.telemetry`, so the two
stacks cannot disagree about what a number means; they are re-exported
here for the long-standing import sites.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.control import (  # noqa: F401  (shared, re-exported)
    latency_stats_ms,
    on_device_gap,
    windowed_mean,
    windowed_mix,
    windowed_rate,
)


@dataclass
class RequestRecord:
    req_id: int
    arrival_s: float
    device: int
    branch: int  # physical branch deployed when the request was gated
    p_tar: float  # effective target in force when the request was gated
    on_device: bool
    edge_start_s: float
    edge_done_s: float
    complete_s: float
    correct: Optional[bool] = None  # None when the core has no labels
    deadline_s: Optional[float] = None
    context: Optional[str] = None  # true distortion context at gate time
    est_context: Optional[str] = None  # edge-side estimator's verdict
    # edge-side energy (compute J + radio J for the shipped payload; see
    # `repro.offload.latency.energy_per_request_j`); None on legacy paths
    energy_j: Optional[float] = None

    @property
    def latency_s(self) -> float:
        return self.complete_s - self.arrival_s

    @property
    def edge_wait_s(self) -> float:
        """Time spent queued for the edge device (batching/uplink/cloud
        contention show up in latency_s, not here)."""
        return self.edge_start_s - self.arrival_s

    @property
    def missed_deadline(self) -> Optional[bool]:
        if self.deadline_s is None:
            return None
        return self.latency_s > self.deadline_s


class Telemetry:
    def __init__(self):
        self.records: List[RequestRecord] = []
        self.arrival_times: List[float] = []
        self.bandwidth_samples: List[Tuple[float, float]] = []  # (t, bps)
        self.queue_samples: List[Tuple[float, float]] = []  # (t, mean per-device depth)
        self.context_samples: List[Tuple[float, str]] = []  # (t, context key)
        # (t, branch, p_tar, compression_level) per adopted switch
        self.controller_events: List[Tuple[float, int, float, int]] = []

    # ------------------------------------------------------------ ingest
    def add(self, record: RequestRecord) -> None:
        self.records.append(record)

    def observe_arrival(self, t: float) -> None:
        self.arrival_times.append(t)

    def observe_bandwidth(self, t: float, bps: float) -> None:
        self.bandwidth_samples.append((t, bps))

    def observe_queue(self, t: float, depth: int) -> None:
        self.queue_samples.append((t, depth))

    def observe_context(self, t: float, context: str) -> None:
        """The edge-side context verdict at gate time (the estimator's
        when one ran, else the true context) -- what a context-aware
        controller windows into a traffic-mix estimate."""
        self.context_samples.append((t, context))

    def record_controller(
        self, t: float, branch: int, p_tar: float, level: int = 0
    ) -> None:
        self.controller_events.append((t, branch, p_tar, int(level)))

    # ----------------------------------------------------------- reports
    def latencies(self) -> np.ndarray:
        return np.asarray([r.latency_s for r in self.records], np.float64)

    def percentile(self, q: float) -> float:
        lat = self.latencies()
        return float(np.percentile(lat, q)) if lat.size else float("nan")

    @property
    def p50_s(self) -> float:
        return self.percentile(50)

    @property
    def p95_s(self) -> float:
        return self.percentile(95)

    @property
    def p99_s(self) -> float:
        return self.percentile(99)

    @property
    def offload_rate(self) -> float:
        if not self.records:
            return float("nan")
        return float(np.mean([not r.on_device for r in self.records]))

    @property
    def deadline_miss_rate(self) -> float:
        misses = [r.missed_deadline for r in self.records if r.missed_deadline is not None]
        return float(np.mean(misses)) if misses else float("nan")

    @property
    def accuracy(self) -> float:
        known = [r.correct for r in self.records if r.correct is not None]
        return float(np.mean(known)) if known else float("nan")

    @property
    def energy_j_total(self) -> float:
        """Total edge-side energy over records that carry it (0.0 when no
        path stamped energy -- legacy simulators)."""
        return float(sum(r.energy_j for r in self.records
                         if r.energy_j is not None))

    @property
    def mean_queue_depth(self) -> float:
        if not self.queue_samples:
            return float("nan")
        return float(np.mean([d for _, d in self.queue_samples]))

    @property
    def throughput_rps(self) -> float:
        if len(self.records) < 2:
            return float("nan")
        t0 = min(r.arrival_s for r in self.records)
        t1 = max(r.complete_s for r in self.records)
        return len(self.records) / max(t1 - t0, 1e-12)

    # -------------------------------------------------- per-context reports
    def _context_groups(self) -> Dict[str, List[RequestRecord]]:
        """Records grouped by TRUE context; contextless records (plain
        LogitsCore/EngineCore runs) all land in one "__all__" group, so the
        same metrics work with and without drift."""
        groups: Dict[str, List[RequestRecord]] = {}
        for r in self.records:
            groups.setdefault(r.context or "__all__", []).append(r)
        return groups

    @staticmethod
    def _gap(records: List[RequestRecord]) -> Optional[float]:
        """One group's reliability gap (see `on_device_gap`)."""
        on_dev = [r for r in records if r.on_device and r.correct is not None]
        if not on_dev:
            return None
        return on_device_gap(
            np.asarray([r.correct for r in on_dev]),
            np.asarray([r.p_tar for r in on_dev]),
        )

    def per_context_summary(self) -> Dict[str, Dict[str, float]]:
        """Per true-context roll-up: request count, offload rate, end-to-end
        accuracy, on-device accuracy, miscalibration gap, and how often the
        edge-side estimator named the context correctly."""
        out: Dict[str, Dict[str, float]] = {}
        for ctx, recs in sorted(self._context_groups().items()):
            on_dev = [r for r in recs if r.on_device and r.correct is not None]
            known = [r.correct for r in recs if r.correct is not None]
            est = [r for r in recs if r.est_context is not None]
            gap = self._gap(recs)
            out[ctx] = {
                "requests": len(recs),
                "offload_rate": float(np.mean([not r.on_device for r in recs])),
                "accuracy": float(np.mean(known)) if known else float("nan"),
                "on_device_accuracy": (
                    float(np.mean([r.correct for r in on_dev]))
                    if on_dev else float("nan")
                ),
                "miscalibration_gap": float("nan") if gap is None else gap,
                "est_match_rate": (
                    float(np.mean([r.est_context == r.context for r in est]))
                    if est else float("nan")
                ),
            }
        return out

    def miscalibration_gap(self) -> float:
        """On-device-count-weighted mean of per-context |on-device accuracy
        - p_tar|. Aggregating |gap| per regime and then averaging is the
        honest number under drift: a +5pp regime and a -5pp regime do NOT
        cancel into "calibrated"."""
        gaps, weights = [], []
        for recs in self._context_groups().values():
            gap = self._gap(recs)
            if gap is None:
                continue
            gaps.append(gap)
            weights.append(sum(1 for r in recs if r.on_device))
        if not gaps:
            return float("nan")
        return float(np.average(gaps, weights=weights))

    # ----------------------------------------------- controller's window
    def bandwidth_estimate(
        self, window_s: Optional[float] = None, now: Optional[float] = None
    ) -> Optional[float]:
        """Mean observed uplink rate over the trailing window. If the window
        holds no transfer but older observations exist, the most recent one
        is returned (stale beats assuming the nominal best-case link); None
        only when nothing was ever observed."""
        t = [t for t, _ in self.bandwidth_samples]
        v = [b for _, b in self.bandwidth_samples]
        return windowed_mean(t, v, window_s, now, stale_fallback=True)

    def queue_estimate(
        self, window_s: Optional[float] = None, now: Optional[float] = None
    ) -> Optional[float]:
        t = [t for t, _ in self.queue_samples]
        v = [d for _, d in self.queue_samples]
        return windowed_mean(t, v, window_s, now, stale_fallback=False)

    def arrival_rate_estimate(
        self, window_s: float, now: float
    ) -> Optional[float]:
        """Fleet-wide arrivals/second over the trailing window (None if no
        arrival landed in it). A simulation younger than the window divides
        by the elapsed time instead, so early estimates aren't biased low."""
        return windowed_rate(self.arrival_times, window_s, now)

    def context_mix_estimate(
        self, window_s: float, now: float
    ) -> Optional[Dict[str, float]]:
        """Share of the trailing window's gated traffic per context key
        ({context: share} summing to 1), from the gate-time verdicts
        `observe_context` recorded; None when nothing (recognizable) was
        observed. `UNKNOWN_CONTEXT` verdicts are excluded: the bank
        serves them with the default plan, but their gate statistics
        belong to no fitted context."""
        from repro.core.bank import UNKNOWN_CONTEXT

        if not self.context_samples:
            return None
        keys = sorted(
            {c for _, c in self.context_samples if c != UNKNOWN_CONTEXT}
        )
        if not keys:
            return None
        index = {k: i for i, k in enumerate(keys)}
        t = [t for t, c in self.context_samples]
        ids = [index.get(c, -1) for _, c in self.context_samples]
        mix = windowed_mix(t, ids, len(keys), window_s, now)
        if mix is None:
            return None
        return {k: float(m) for k, m in zip(keys, mix)}

    # ----------------------------------------------------------- summary
    def summary(self) -> Dict[str, float]:
        """Machine-readable (JSON-safe) roll-up of the run."""
        return {
            "requests": len(self.records),
            "p50_ms": self.p50_s * 1e3,
            "p95_ms": self.p95_s * 1e3,
            "p99_ms": self.p99_s * 1e3,
            "mean_ms": float(self.latencies().mean() * 1e3) if self.records else float("nan"),
            "offload_rate": self.offload_rate,
            "deadline_miss_rate": self.deadline_miss_rate,
            "accuracy": self.accuracy,
            "mean_queue_depth": self.mean_queue_depth,
            "throughput_rps": self.throughput_rps,
            "controller_switches": len(self.controller_events),
            "miscalibration_gap": self.miscalibration_gap(),
            "energy_j_total": self.energy_j_total,
        }
