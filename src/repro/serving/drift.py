"""Drifting input conditions for the serving runtime.

PR 2 gave the runtime drifting *networks* (Markov Wi-Fi, trace replay);
this module adds the third workload axis: drifting *inputs*. A
`ContextSchedule` maps simulated time to a distortion context key (the
camera fogs up at t=40s, clears at t=90s), and `ContextualLogitsCore`
serves per-context precomputed logits through that schedule -- so a
request gated at time t sees the logits its branch would have produced on
inputs distorted by the context in force at t.

Plan selection is the edge device's problem, not the oracle's: when the
core is built from a `PlanBank` with an embedded estimator, each sample's
expert plan is chosen from the estimator's verdict on that sample's cheap
input statistics (`repro.data.distortion.input_features`), NOT from the
true scheduled context. Estimator mistakes therefore cost exactly what
they would cost on a real device: gating with the wrong expert's
calibrator. Telemetry records both the true and the estimated context per
request, so `Telemetry.per_context_summary` can report the confusion.

Both schedule types are deterministic under their seed, matching the
repo-wide reproducibility contract:

* `PiecewiseSchedule` -- explicit (start time, context) segments;
* `MarkovContextSchedule` -- a Markov chain over contexts advancing once
  per dwell slot (slot states materialized sequentially, like
  `MarkovNetwork`), modeling weather-style regime drift.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bank import PlanBank
from repro.core.gatepath import get_gate_backend
from repro.core.policy import OffloadPlan


# ------------------------------------------------------- context schedules
class ContextSchedule:
    """Maps simulated time -> the distortion context key in force."""

    def context_at(self, t: float) -> str:
        raise NotImplementedError

    def context_ids_at(self, times) -> np.ndarray:
        """Vectorized lookup -> (N,) int64 indices into `contexts`. The
        base implementation loops over `context_at`; both schedule types
        override it with one indexing op (the fleet simulator resolves
        whole event windows through this)."""
        index = {k: i for i, k in enumerate(self.contexts)}
        t = np.asarray(times, np.float64)
        return np.asarray(
            [index[self.context_at(float(x))] for x in t.ravel()], np.int64
        ).reshape(t.shape)

    @property
    def contexts(self) -> List[str]:
        raise NotImplementedError


class PiecewiseSchedule(ContextSchedule):
    """Explicit regime segments: [(start_s, context), ...], start times
    sorted and beginning at 0; segment i holds until segment i+1 starts."""

    def __init__(self, segments: Sequence[Tuple[float, str]]):
        if not segments:
            raise ValueError("need at least one segment")
        starts = [float(t) for t, _ in segments]
        if starts[0] != 0.0 or any(b <= a for a, b in zip(starts, starts[1:])):
            raise ValueError("segment starts must begin at 0 and strictly increase")
        self.starts = np.asarray(starts, np.float64)
        self.keys = [k for _, k in segments]

    def context_at(self, t: float) -> str:
        i = int(np.searchsorted(self.starts, max(float(t), 0.0), side="right")) - 1
        return self.keys[max(i, 0)]

    def context_ids_at(self, times) -> np.ndarray:
        t = np.maximum(np.asarray(times, np.float64), 0.0)
        seg = np.maximum(np.searchsorted(self.starts, t, side="right") - 1, 0)
        index = {k: i for i, k in enumerate(self.contexts)}
        seg_to_ctx = np.asarray([index[k] for k in self.keys], np.int64)
        return seg_to_ctx[seg]

    @property
    def contexts(self) -> List[str]:
        return sorted(set(self.keys))


class MarkovContextSchedule(ContextSchedule):
    """Markov regime drift: every `dwell_s` the chain either stays in the
    current context (prob `p_stay`) or jumps uniformly to another one; an
    explicit row-stochastic `transition` matrix overrides that default.
    Slot states are materialized sequentially from the seed, so
    `context_at` is deterministic regardless of query order."""

    def __init__(
        self,
        contexts: Sequence[str],
        dwell_s: float = 10.0,
        p_stay: float = 0.7,
        transition: Optional[np.ndarray] = None,
        seed: int = 0,
        start_context: Optional[str] = None,
    ):
        if dwell_s <= 0:
            raise ValueError("dwell_s must be positive")
        if len(contexts) != len(set(contexts)) or not contexts:
            raise ValueError("contexts must be a non-empty list of unique keys")
        self._contexts = list(contexts)
        k = len(self._contexts)
        if transition is None:
            if not 0.0 <= p_stay <= 1.0:
                raise ValueError("p_stay must be in [0, 1]")
            off = (1.0 - p_stay) / max(k - 1, 1)
            transition = np.full((k, k), off)
            np.fill_diagonal(transition, p_stay if k > 1 else 1.0)
        transition = np.asarray(transition, np.float64)
        if transition.shape != (k, k) or not np.allclose(transition.sum(axis=1), 1.0):
            raise ValueError(f"transition must be row-stochastic ({k}, {k})")
        self.transition = transition
        self.dwell_s = float(dwell_s)
        self._rng = np.random.default_rng(seed)
        start = 0 if start_context is None else self._contexts.index(start_context)
        self._states = [start]

    def _state(self, slot: int) -> int:
        while len(self._states) <= slot:
            row = self.transition[self._states[-1]]
            self._states.append(int(self._rng.choice(len(row), p=row)))
        return self._states[slot]

    def context_at(self, t: float) -> str:
        slot = int(max(float(t), 0.0) // self.dwell_s)
        return self._contexts[self._state(slot)]

    def context_ids_at(self, times) -> np.ndarray:
        t = np.asarray(times, np.float64)
        slots = (np.maximum(t, 0.0) // self.dwell_s).astype(np.int64)
        if slots.size:
            self._state(int(slots.max()))  # materialize in order, once
        return np.asarray(self._states, np.int64)[slots]

    @property
    def contexts(self) -> List[str]:
        return list(self._contexts)


# -------------------------------------------------- contextual compute core
class ContextualLogitsCore:
    """LogitsCore over per-context logits under a drifting schedule.

    exit_logits_by_context: {context: {physical_branch: (N, C) logits}} --
    the SAME n samples pushed through the model under each context's
    distortion; final_logits_by_context the matching cloud main heads.

    plan_or_bank decides calibration:
      * an `OffloadPlan` applies one calibrator set to every context (the
        single-global-plan baseline, or the uncalibrated one);
      * a `PlanBank` picks the expert plan per sample -- via its embedded
        estimator on `features_by_context` (the honest edge-side path) or,
        with no estimator/features, by the true context (the oracle bound).

    Confidence/prediction are precomputed per (true context, expert plan,
    branch); only the mask depends on the runtime's moving p_tar, so
    controller branch/target switches stay free, exactly as in LogitsCore.
    The precompute routes through the selected `GateBackend`
    (`repro.core.gatepath`), the same execution layer the fleet's dense
    gate table uses.
    """

    contextual = True

    def __init__(
        self,
        exit_logits_by_context: Dict[str, Dict[int, np.ndarray]],
        final_logits_by_context: Dict[str, np.ndarray],
        plan_or_bank,
        schedule: ContextSchedule,
        labels: Optional[np.ndarray] = None,
        features_by_context: Optional[Dict[str, np.ndarray]] = None,
        backend=None,
    ):
        self.backend = get_gate_backend(backend)
        if isinstance(plan_or_bank, PlanBank):
            self.bank: Optional[PlanBank] = plan_or_bank
            plans = dict(plan_or_bank.plans)
        else:
            self.bank = None
            plans = {"__plan__": plan_or_bank}
        criteria = {p.criterion for p in plans.values()}
        if criteria != {"confidence"}:
            raise ValueError(
                "ContextualLogitsCore gates on the runtime's moving "
                f"confidence target; plan criteria {sorted(criteria)} "
                "are not supported"
            )
        self.schedule = schedule
        self.ctx_keys = sorted(exit_logits_by_context)
        missing = set(schedule.contexts) - set(self.ctx_keys)
        if missing:
            raise ValueError(
                f"schedule visits contexts with no logits: {sorted(missing)}"
            )
        if set(final_logits_by_context) != set(self.ctx_keys):
            raise ValueError("exit and final logits must cover the same contexts")

        self.branches = sorted(next(iter(exit_logits_by_context.values())))
        for ctx, per_branch in exit_logits_by_context.items():
            if sorted(per_branch) != self.branches:
                raise ValueError(f"context {ctx!r} covers different branches")

        # expert selection per (true context, sample)
        self._est: Dict[str, List[str]] = {}
        self._oracle = not (
            self.bank is not None
            and self.bank.estimator is not None
            and features_by_context is not None
        )
        if not self._oracle:
            est = self.bank.estimator
            for ctx in self.ctx_keys:
                if ctx not in features_by_context:
                    raise ValueError(f"no features for context {ctx!r}")
                self._est[ctx] = est.predict_per_sample(features_by_context[ctx])
        else:  # oracle selection (single plans ignore the key anyway)
            n_by_ctx = {
                c: len(next(iter(b.values())))
                for c, b in exit_logits_by_context.items()
            }
            for ctx in self.ctx_keys:
                key = ctx if self.bank is not None else "__plan__"
                self._est[ctx] = [key] * n_by_ctx[ctx]

        # (true ctx, plan key, branch) -> precomputed conf/pred; only plan
        # keys the estimator can actually emit for that context are needed
        self.conf: Dict[tuple, np.ndarray] = {}
        self.pred: Dict[tuple, np.ndarray] = {}
        for ctx in self.ctx_keys:
            needed = set(self._est[ctx])
            for pk in needed:
                plan = plans[pk] if self.bank is None else self.bank.plan_for(pk)
                for b in self.branches:
                    c, p = self.backend.plan_gate_block(
                        plan, exit_logits_by_context[ctx][b], branch=b - 1
                    )
                    self.conf[(ctx, pk, b)] = c
                    self.pred[(ctx, pk, b)] = p
        self.final_pred = {
            ctx: np.argmax(np.asarray(z), axis=-1)
            for ctx, z in final_logits_by_context.items()
        }
        # retained for lazy per-codec-level cloud tables (see cloud_predict)
        self._final_logits = {
            ctx: np.asarray(z) for ctx, z in final_logits_by_context.items()
        }
        self._final_pred_by_level: Dict[int, Dict[str, np.ndarray]] = {
            0: self.final_pred
        }
        self.labels = None if labels is None else np.asarray(labels)
        self.n_samples = int(next(iter(self.final_pred.values())).shape[0])

    def gate(self, sample: int, branch: int, p_tar: float, t: float = 0.0):
        """-> (on_device, prediction, confidence, true_ctx, est_ctx);
        est_ctx is None unless a real estimator produced it (oracle-mode
        selection must not masquerade as a perfect estimator in
        telemetry's est_match_rate)."""
        ctx = self.schedule.context_at(t)
        pk = self._est[ctx][sample]
        conf = self.conf[(ctx, pk, branch)][sample]
        pred = int(self.pred[(ctx, pk, branch)][sample])
        est = None if self._oracle else pk
        return bool(conf >= p_tar), pred, float(conf), ctx, est

    def cloud_predict(self, sample: int, branch: int,
                      context: Optional[str] = None, level: int = 0) -> int:
        """Main-head prediction for an offloaded sample. `level` is the
        codec level the payload shipped at: non-zero levels round-trip the
        stored final logits through the `kernels.ref` oracle once per
        (level, context) -- the same accuracy-delta model the controller
        priced at fit time. Level 0 is the untouched legacy table."""
        ctx = self.ctx_keys[0] if context is None else context
        level = int(level)
        if level not in self._final_pred_by_level:
            from repro.kernels.ref import roundtrip_codec_ref

            self._final_pred_by_level[level] = {
                c: np.argmax(roundtrip_codec_ref(z, level), axis=-1)
                for c, z in self._final_logits.items()
            }
        return int(self._final_pred_by_level[level][ctx][sample])

    def correct(self, sample: int, prediction: int) -> Optional[bool]:
        if self.labels is None:
            return None
        return bool(prediction == self.labels[sample])
