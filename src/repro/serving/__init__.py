"""Event-driven edge-cloud serving layer.

The paper prices offloading at one fixed 18.8 Mbps uplink and reports mean
batch latency; this package turns the reproduction into a load-testable
serving system:

* `network`   -- stochastic / time-varying uplink models behind a single
                 ``comm_time(nbytes, t)`` interface (fixed-rate, Markov
                 good/bad Wi-Fi, bandwidth-trace replay);
* `workload`  -- seeded Poisson / constant-rate / trace request generators;
* `telemetry` -- per-request bookkeeping (p50/p95/p99 latency, deadline
                 misses, queue depth, offload rate) plus the windowed
                 bandwidth/queue estimates the controller consumes;
* `runtime`   -- the discrete-event simulator: N edge devices, a shared
                 uplink, a cloud tier, and a microbatcher that coalesces
                 refused samples into cloud batches;
* `controller`-- an Edgent-style online controller over the shared
                 `repro.core.control.ControllerCore`: re-selects the
                 deployed branch and effective p_tar by re-scoring the
                 OffloadPlan's fitted calibrators under measured bandwidth
                 (no re-fitting), optionally weighting the candidate table
                 by the traffic mix its own telemetry observed
                 (context-aware, the fleet controller's rule);
* `drift`     -- drifting INPUT conditions: context schedules (piecewise /
                 Markov regime drift) and `ContextualLogitsCore`, which
                 serves per-distortion-context logits and picks each
                 sample's expert plan from a `PlanBank` via the cheap
                 edge-side distortion estimator.
"""
from repro.serving.controller import ControllerConfig, OnlineController
from repro.serving.drift import (
    ContextSchedule,
    ContextualLogitsCore,
    MarkovContextSchedule,
    PiecewiseSchedule,
)
from repro.serving.network import (
    FixedRateNetwork,
    MarkovNetwork,
    NetworkModel,
    TraceNetwork,
    network_for,
)
from repro.serving.runtime import (
    EngineCore,
    LogitsCore,
    RuntimeConfig,
    ServingRuntime,
)
from repro.serving.telemetry import RequestRecord, Telemetry
from repro.serving.workload import (
    Request,
    constant_workload,
    poisson_workload,
    trace_workload,
)

__all__ = [
    "ControllerConfig",
    "OnlineController",
    "ContextSchedule",
    "ContextualLogitsCore",
    "MarkovContextSchedule",
    "PiecewiseSchedule",
    "NetworkModel",
    "FixedRateNetwork",
    "MarkovNetwork",
    "TraceNetwork",
    "network_for",
    "RuntimeConfig",
    "ServingRuntime",
    "LogitsCore",
    "EngineCore",
    "Telemetry",
    "RequestRecord",
    "Request",
    "poisson_workload",
    "constant_workload",
    "trace_workload",
]
