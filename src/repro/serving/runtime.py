"""Discrete-event edge-cloud serving runtime.

Models the paper's two-tier system under load instead of in the mean:

    N edge devices (FIFO, one request in service at a time)
        -> calibrated gate (the deployed OffloadPlan, current branch/p_tar)
        -> microbatcher (coalesces refused samples into cloud batches)
        -> ONE shared uplink (NetworkModel prices each transfer at the
           instantaneous rate when it starts)
        -> cloud tier (`cloud_servers` parallel servers, per-sample serial
           service within a batch)

Event list is a heap of (time, seq, fn); all randomness lives in the
workload and network models, so a run is bit-reproducible. Service times
come from a `LatencyProfile` via `offload.latency.edge_time`/`cloud_time`,
which makes the empty-queue single-device fixed-network special case agree
with the paper's closed-form per-sample numbers to float round-off.

Compute cores decouple the queueing model from the math that decides the
gate: `LogitsCore` serves precomputed per-branch logits (fast, exact,
drives tests/benchmarks); `EngineCore` drives a real `OffloadEngine` pair
of jitted partitions per request batch, reusing its timing hooks. A core
with ``contextual = True`` (`repro.serving.drift.ContextualLogitsCore`)
additionally models drifting input conditions: its gate takes the event
time and reports the (true, estimated) distortion context, and the runtime
threads both into telemetry. Passing a `PlanBank` instead of a single
`OffloadPlan` deploys the bank's default plan for (branch, p_tar) while
the contextual core picks each sample's expert calibrator.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.exits import gate_statistics
from repro.core.policy import OffloadPlan
from repro.obs.calibration import GLOBAL_CONTEXT as _GLOBAL_CONTEXT
from repro.offload import latency as L
from repro.serving.network import NetworkModel, network_for
from repro.serving.telemetry import RequestRecord, Telemetry
from repro.serving.workload import Request


# ------------------------------------------------------------ compute cores
class LogitsCore:
    """Gate/cloud decisions from precomputed logits.

    exit_logits: {physical_branch: (N, C) array} -- e.g. {1: z1, 2: z2};
    physical branch k gates with plan.calibrators[k-1] (engine convention).
    Confidence/prediction/entropy per branch are precomputed once; only the
    mask depends on the runtime's current p_tar, so branch/target switches
    by the controller are free. Both of the plan's criteria are honored:
    'confidence' gates on conf >= p_tar (the runtime's moving target),
    'entropy' on the plan's fixed entropy_threshold.
    """

    def __init__(
        self,
        exit_logits: Dict[int, np.ndarray],
        final_logits: np.ndarray,
        plan: OffloadPlan,
        labels: Optional[np.ndarray] = None,
    ):
        if plan.criterion == "entropy" and plan.entropy_threshold is None:
            raise ValueError("entropy criterion needs plan.entropy_threshold")
        self.criterion = plan.criterion
        self.entropy_threshold = plan.entropy_threshold
        self.branches = sorted(exit_logits)
        self.conf: Dict[int, np.ndarray] = {}
        self.pred: Dict[int, np.ndarray] = {}
        self.ent: Dict[int, np.ndarray] = {}
        for b in self.branches:
            c, p, e = gate_statistics(plan.calibrated_logits(exit_logits[b], b - 1))
            self.conf[b] = np.asarray(c, np.float64)
            self.pred[b] = np.asarray(p)
            self.ent[b] = np.asarray(e, np.float64)
        self.final_pred = np.argmax(np.asarray(final_logits), axis=-1)
        self._final_logits = np.asarray(final_logits)
        self._final_pred_by_level: Dict[int, np.ndarray] = {}
        self.labels = None if labels is None else np.asarray(labels)
        self.n_samples = int(self.final_pred.shape[0])

    def gate(self, sample: int, branch: int, p_tar: float):
        """-> (on_device, prediction, confidence) for one sample."""
        conf = self.conf[branch][sample]
        if self.criterion == "entropy":
            on_device = bool(self.ent[branch][sample] <= self.entropy_threshold)
        else:
            on_device = bool(conf >= p_tar)
        return on_device, int(self.pred[branch][sample]), float(conf)

    def cloud_predict(self, sample: int, branch: int, level: int = 0) -> int:
        # every cloud path computes the same main head, whichever branch
        # the split happened at; a non-zero codec level round-trips the
        # stored final logits through the kernels.ref oracle (lazily, once
        # per level) -- the fit-time accuracy-delta model, made live
        level = int(level)
        if level == 0:
            return int(self.final_pred[sample])
        if level not in self._final_pred_by_level:
            from repro.kernels.ref import roundtrip_codec_ref

            self._final_pred_by_level[level] = np.argmax(
                roundtrip_codec_ref(self._final_logits, level), axis=-1
            )
        return int(self._final_pred_by_level[level][sample])

    def correct(self, sample: int, prediction: int) -> Optional[bool]:
        if self.labels is None:
            return None
        return bool(prediction == self.labels[sample])


class EngineCore:
    """Gate/cloud decisions computed live by OffloadEngine partitions.

    engines: {physical_branch: OffloadEngine} (one per deployable branch;
    a single-entry dict serves the paper's fixed-branch case). `data` is
    the batch pytree of the full dataset; requests index into its leading
    axis. Uses the engines' edge_step/cloud_step so their timing hooks and
    EngineStats keep working under the simulated clock.
    """

    def __init__(
        self,
        engines: Dict[int, "OffloadEngine"],  # noqa: F821
        data: Dict[str, np.ndarray],
        labels: Optional[np.ndarray] = None,
    ):
        import jax

        self._jax = jax
        self.engines = engines
        self.branches = sorted(engines)
        self.data = data
        self.labels = None if labels is None else np.asarray(labels)
        leaves = jax.tree.leaves(data)
        self.n_samples = int(leaves[0].shape[0])
        # (sample, branch) -> edge activation. Keyed by branch so a repeat
        # of the same sample after a controller branch switch cannot hand
        # an in-flight cloud batch the other partition's payload; kept (not
        # popped) because the payload is deterministic per key, bounding
        # the cache at n_samples * n_branches entries.
        self._payload: Dict[tuple, object] = {}

    def gate(self, sample: int, branch: int, p_tar: float):
        eng = self.engines[branch]
        batch = self._jax.tree.map(lambda x: x[sample : sample + 1], self.data)
        edge_out = eng.edge_step(batch)
        gate = eng.plan.gate(edge_out["exit_logits"], branch=eng.branch,
                             use_kernel=eng.use_kernel)
        conf = float(np.asarray(gate.confidence)[0])
        pred = int(np.asarray(gate.prediction)[0])
        on_device = bool(conf >= p_tar) if eng.plan.criterion == "confidence" \
            else bool(np.asarray(gate.exit_mask)[0])
        if not on_device:
            self._payload[(sample, branch)] = edge_out["payload"]
        return on_device, pred, conf

    def cloud_predict(self, sample: int, branch: int, level: int = 0) -> int:
        payload = self._payload[(sample, branch)]
        if int(level) != 0:
            # the REAL codec on the real activation: what the cloud
            # partition actually receives after a compressed offload
            from repro.kernels import compress

            payload = self._jax.tree.map(
                lambda x: compress.roundtrip(x, int(level)), payload
            )
        out = self.engines[branch].cloud_step(payload)
        return int(np.argmax(np.asarray(out["logits"]), axis=-1)[0])

    def correct(self, sample: int, prediction: int) -> Optional[bool]:
        if self.labels is None:
            return None
        return bool(prediction == self.labels[sample])


# ------------------------------------------------------------------ runtime
@dataclass
class RuntimeConfig:
    n_devices: int = 1
    max_batch: int = 1  # microbatcher: flush at this many refused samples
    batch_window_s: float = 0.0  # ... or when the oldest has waited this long
    cloud_servers: int = 1


@dataclass
class _Pending:
    """A refused request waiting in the microbatcher / cloud pipeline."""

    request: Request
    branch: int
    p_tar: float
    confidence: float
    edge_start_s: float
    edge_done_s: float
    payload_nbytes: int  # WIRE bytes at the deployed codec level
    compression_level: int = 0  # codec level the payload shipped at
    context: Optional[str] = None  # true distortion context at gate time
    est_context: Optional[str] = None  # what the edge-side estimator said
    # EDGE prediction's correctness captured at gate time (before the
    # cloud answer overrides it); stamped only while obs is attached
    edge_correct: Optional[bool] = None
    # span timestamps, stamped only while a trace sink is attached
    uplink_start_s: float = 0.0
    uplink_done_s: float = 0.0
    cloud_start_s: float = 0.0


class ServingRuntime:
    """Run a workload through the two-tier system; returns `Telemetry`.

    The deployed configuration starts at the plan's (exit_index+1, p_tar)
    and is updated in place whenever the optional `controller` re-scores
    the plan at its tick interval. A branch switch flushes the pending
    microbatch so every cloud batch is gated under one configuration.
    """

    def __init__(
        self,
        core,
        profile: L.LatencyProfile,
        plan: OffloadPlan,
        requests: Sequence[Request],
        network: Optional[NetworkModel] = None,
        config: RuntimeConfig = None,
        controller=None,
        telemetry: Optional[Telemetry] = None,
        payload_nbytes: Optional[Callable[[int], int]] = None,
        obs=None,
    ):
        from repro.core.bank import PlanBank

        self.core = core
        self.profile = profile
        if isinstance(plan, PlanBank):
            # the bank's default plan seeds (branch, p_tar); per-sample
            # expert calibration happens inside the contextual core
            plan = plan.default_plan
        self.plan = plan
        self._contextual = bool(getattr(core, "contextual", False))
        self.requests = sorted(requests, key=lambda r: r.arrival_s)
        self.network = network or network_for(profile)
        self.config = config or RuntimeConfig()
        self.controller = controller
        self.telemetry = telemetry or Telemetry()
        # observability (repro.obs.Observability); zero-perturbation when
        # absent -- the obs=None path runs operation-for-operation the
        # same code, pinned bit-exactly by tests/test_obs.py
        self.obs = obs
        self._trace = None if obs is None else obs.trace
        self._metrics = None if obs is None else obs.metrics
        self._cal = None if obs is None else getattr(obs, "calibration", None)
        if obs is not None and obs.audit is not None \
                and controller is not None and hasattr(controller, "audit"):
            controller.audit = obs.audit
        if self._metrics is not None:
            self._metrics.set_gauge("trace_sample_every", 1, source="serving")
        if payload_nbytes is None:
            from repro.models.convnet import payload_bytes  # the paper's model

            payload_nbytes = payload_bytes
        self.payload_nbytes = payload_nbytes

        self.branch = plan.exit_index + 1
        self.p_tar = float(plan.p_tar)
        self.level = int(getattr(plan, "compression_level", 0))
        if self.branch not in core.branches:
            raise ValueError(
                f"plan deploys branch {self.branch} but the core only "
                f"serves branches {core.branches}"
            )
        if controller is not None and not set(controller.branches) <= set(
            core.branches
        ):
            raise ValueError(
                f"controller may deploy branches {controller.branches} but "
                f"the core only serves {core.branches}"
            )

        # event machinery
        self._heap: List = []
        self._seq = 0
        self._now = 0.0
        # device state
        n = self.config.n_devices
        self._dev_queue: List[List[Request]] = [[] for _ in range(n)]
        self._dev_busy = [False] * n
        # microbatcher / uplink / cloud state
        self._batch: List[_Pending] = []
        self._batch_epoch = 0  # invalidates stale window-flush timers
        self._uplink_free_s = 0.0
        self._cloud_free_s = [0.0] * self.config.cloud_servers

    # -------------------------------------------------------------- events
    def _push(self, t: float, fn: Callable, *args) -> None:
        heapq.heappush(self._heap, (t, self._seq, fn, args))
        self._seq += 1

    def run(self) -> Telemetry:
        for req in self.requests:
            self._push(req.arrival_s, self._on_arrival, req)
        if self.controller is not None and self.requests:
            # first tick only; each tick re-schedules the next while the
            # simulation still has events, so adaptation continues through
            # the drain phase after the last arrival
            self._push(self.controller.interval_s, self._on_controller_tick)
        while self._heap:
            t, _, fn, args = heapq.heappop(self._heap)
            self._now = t
            fn(t, *args)
        self._flush_batch(self._now)  # drain stragglers (window=0, partial batch)
        while self._heap:
            t, _, fn, args = heapq.heappop(self._heap)
            self._now = t
            fn(t, *args)
        if self._metrics is not None:
            from repro.obs import serving_metrics

            serving_metrics(self.telemetry, self._metrics)
            if self._cal is not None:
                from repro.obs import export_calibration

                export_calibration(self._cal, self._metrics)
        return self.telemetry

    # ---------------------------------------------------------- edge tier
    def _on_arrival(self, t: float, req: Request) -> None:
        d = req.device % self.config.n_devices
        self.telemetry.observe_arrival(t)
        self._dev_queue[d].append(req)
        # mean PER-DEVICE edge backlog (batcher excluded): this is what the
        # controller multiplies edge service time by, so a 4-device fleet
        # must not look 4x more backed up than each device actually is
        self.telemetry.observe_queue(
            t, sum(len(q) for q in self._dev_queue) / self.config.n_devices
        )
        if not self._dev_busy[d]:
            self._start_edge(t, d)

    def _start_edge(self, t: float, d: int) -> None:
        req = self._dev_queue[d].pop(0)
        self._dev_busy[d] = True
        # capture the WHOLE configuration now: a controller tick during the
        # service must not pair this branch's logits with a p_tar tuned for
        # another branch
        branch, p_tar, level = self.branch, self.p_tar, self.level
        service = L.edge_time(self.profile, branch)
        self._push(
            t + service, self._on_edge_done, req, d, t, branch, p_tar, level
        )

    def _payload_nbytes_for(self, branch: int, level: int) -> int:
        """Wire bytes for one offload: the raw activation size at level 0
        (the caller-supplied table untouched -- bit-exact legacy pricing),
        the codec's analytic size otherwise."""
        raw = self.payload_nbytes(branch)
        if level == 0:
            return raw
        from repro.kernels.compress import scaled_payload_nbytes

        return scaled_payload_nbytes(raw, level)

    def _on_edge_done(
        self, t: float, req: Request, d: int, start_s: float, branch: int,
        p_tar: float, level: int = 0,
    ) -> None:
        if self._contextual:
            on_device, pred, conf, ctx, est = self.core.gate(
                req.sample, branch, p_tar, t
            )
            if ctx is not None:
                # the edge-side verdict when an estimator ran, else the
                # true context -- the stream a context-aware controller
                # windows into its traffic-mix estimate
                self.telemetry.observe_context(t, est if est is not None else ctx)
        else:
            on_device, pred, conf = self.core.gate(req.sample, branch, p_tar)
            ctx = est = None
        if on_device:
            ok = self.core.correct(req.sample, pred)
            self.telemetry.add(
                RequestRecord(
                    req_id=req.req_id,
                    arrival_s=req.arrival_s,
                    device=d,
                    branch=branch,
                    p_tar=p_tar,
                    on_device=True,
                    edge_start_s=start_s,
                    edge_done_s=t,
                    complete_s=t,
                    correct=ok,
                    deadline_s=req.deadline_s,
                    context=ctx,
                    est_context=est,
                    energy_j=L.energy_per_request_j(self.profile, t - start_s),
                )
            )
            if self.obs is not None and self.obs.enabled:
                self._observe_complete(req, d, branch, p_tar, conf, ctx, est,
                                       start_s, t, on_device=True,
                                       edge_correct=ok)
        else:
            p = _Pending(req, branch, p_tar, conf, start_s, t,
                         self._payload_nbytes_for(branch, level),
                         compression_level=level, context=ctx,
                         est_context=est)
            if self.obs is not None and self.obs.enabled:
                # the edge branch's own verdict, evaluated before the
                # cloud main head replaces the answer: the calibration
                # stream audits the GATE, not the cloud
                p.edge_correct = self.core.correct(req.sample, pred)
            self._batch.append(p)
            if len(self._batch) >= self.config.max_batch:
                self._flush_batch(t)
            elif len(self._batch) == 1 and self.config.batch_window_s > 0:
                self._push(
                    t + self.config.batch_window_s,
                    self._on_batch_window,
                    self._batch_epoch,
                )
        self._dev_busy[d] = False
        if self._dev_queue[d]:
            self._start_edge(t, d)

    # ------------------------------------------------- microbatch + uplink
    def _on_batch_window(self, t: float, epoch: int) -> None:
        if epoch == self._batch_epoch and self._batch:
            self._flush_batch(t)

    def _flush_batch(self, t: float) -> None:
        if not self._batch:
            return
        batch, self._batch = self._batch, []
        self._batch_epoch += 1
        nbytes = sum(p.payload_nbytes for p in batch)
        if self._metrics is not None:
            self._metrics.inc("serving_uplink_bytes_total", nbytes)
        start = max(t, self._uplink_free_s)
        # observation timestamped NOW (flush time), not at the transfer's
        # start: under backlog `start` lies in the future and a sample
        # there would fall outside the controller's trailing window
        # exactly when it matters most
        self.telemetry.observe_bandwidth(t, self.network.rate_bps(start))
        done = start + self.network.comm_time(nbytes, start)
        self._uplink_free_s = done
        if self._trace is not None:
            for p in batch:
                p.uplink_start_s, p.uplink_done_s = start, done
        self._push(done, self._on_uplink_done, batch)

    # ----------------------------------------------------------- cloud tier
    def _on_uplink_done(self, t: float, batch: List[_Pending]) -> None:
        i = int(np.argmin(self._cloud_free_s))
        start = max(t, self._cloud_free_s[i])
        service = sum(L.cloud_time(self.profile, p.branch) for p in batch)
        done = start + service
        self._cloud_free_s[i] = done
        if self._trace is not None:
            for p in batch:
                p.cloud_start_s = start
        self._push(done, self._on_cloud_done, batch)

    def _on_cloud_done(self, t: float, batch: List[_Pending]) -> None:
        for p in batch:
            if self._contextual:
                # the cloud main head also sees the distorted input, so its
                # prediction is conditioned on the gate-time true context
                pred = self.core.cloud_predict(p.request.sample, p.branch,
                                               p.context,
                                               level=p.compression_level)
            else:
                pred = self.core.cloud_predict(p.request.sample, p.branch,
                                               level=p.compression_level)
            self.telemetry.add(
                RequestRecord(
                    req_id=p.request.req_id,
                    arrival_s=p.request.arrival_s,
                    device=p.request.device % self.config.n_devices,
                    branch=p.branch,
                    p_tar=p.p_tar,
                    on_device=False,
                    edge_start_s=p.edge_start_s,
                    edge_done_s=p.edge_done_s,
                    complete_s=t,
                    correct=self.core.correct(p.request.sample, pred),
                    deadline_s=p.request.deadline_s,
                    context=p.context,
                    est_context=p.est_context,
                    energy_j=L.energy_per_request_j(
                        self.profile, p.edge_done_s - p.edge_start_s,
                        p.payload_nbytes,
                    ),
                )
            )
            if self.obs is not None and self.obs.enabled:
                self._observe_complete(
                    p.request, p.request.device % self.config.n_devices,
                    p.branch, p.p_tar, p.confidence, p.context,
                    p.est_context, p.edge_start_s, p.edge_done_s,
                    on_device=False, uplink_start_s=p.uplink_start_s,
                    uplink_done_s=p.uplink_done_s,
                    cloud_start_s=p.cloud_start_s, complete_s=t,
                    edge_correct=p.edge_correct,
                    payload_nbytes=p.payload_nbytes,
                    level=p.compression_level,
                )

    # -------------------------------------------------------- observability
    def _observe_complete(
        self, req: Request, d: int, branch: int, p_tar: float, conf: float,
        ctx, est, edge_start_s: float, edge_done_s: float, on_device: bool,
        uplink_start_s: Optional[float] = None,
        uplink_done_s: Optional[float] = None,
        cloud_start_s: Optional[float] = None,
        complete_s: Optional[float] = None,
        edge_correct: Optional[bool] = None,
        payload_nbytes: Optional[int] = None,
        level: int = 0,
    ) -> None:
        """Trace + metrics for one completed request (sinks attached)."""
        from repro.obs import build_spans, request_record

        complete = edge_done_s if complete_s is None else complete_s
        if self._metrics is not None:
            self._metrics.inc("serving_requests_total",
                              path="edge" if on_device else "cloud")
            self._metrics.observe("serving_latency_ms",
                                  (complete - req.arrival_s) * 1e3)
        if self._cal is not None and edge_correct is not None:
            self._cal.update_one(
                0, ctx if ctx is not None else _GLOBAL_CONTEXT, branch,
                conf, edge_correct, on_device)
        if self._trace is None:
            return
        gate = {
            "branch": int(branch),
            "p_tar": float(p_tar),
            "confidence": float(conf),
            "criterion": getattr(self.core, "criterion",
                                 getattr(self.plan, "criterion", None)),
            "context": ctx,
            "est_context": est,
            "correct": None if edge_correct is None else int(edge_correct),
        }
        if not on_device:
            gate["compression_level"] = int(level)
        spans = build_spans(req.arrival_s, edge_start_s, edge_done_s,
                            uplink_start_s, uplink_done_s, cloud_start_s,
                            complete_s)
        self._trace.emit(request_record(
            "serving", req.req_id, req.arrival_s, complete, on_device,
            spans, gate=gate, device=d, payload_nbytes=payload_nbytes))
        if self._metrics is not None:
            self._metrics.inc("trace_records_total", source="serving")

    # ----------------------------------------------------------- controller
    def _on_controller_tick(self, t: float) -> None:
        new_plan = self.controller.update(t, self.telemetry)
        new_branch = new_plan.exit_index + 1  # validated against the core at init
        new_p_tar = float(new_plan.p_tar)
        new_level = int(getattr(new_plan, "compression_level", 0))
        if new_branch != self.branch:
            self._flush_batch(t)  # pending batch was gated under the old config
        if (new_branch != self.branch or new_p_tar != self.p_tar
                or new_level != self.level):
            self.telemetry.record_controller(t, new_branch, new_p_tar,
                                             level=new_level)
        self.branch, self.p_tar, self.level = new_branch, new_p_tar, new_level
        if self._heap:  # more simulation ahead (requests in flight/queued)
            self._push(t + self.controller.interval_s, self._on_controller_tick)
