"""Config registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

Every assigned architecture has a module here exporting CONFIG and SMOKE.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    ModelConfig,
    ShapeConfig,
    smoke_variant,
)

ARCHS = [
    "mamba2_130m",
    "granite_moe_3b_a800m",
    "chameleon_34b",
    "olmo_1b",
    "qwen3_8b",
    "qwen3_moe_30b_a3b",
    "internlm2_20b",
    "jamba_v01_52b",
    "whisper_base",
    "qwen2_72b",
    "b_alexnet",  # the paper's own architecture
]

# Assigned ids use dashes; module names use underscores.
_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update(
    {
        "mamba2-130m": "mamba2_130m",
        "granite-moe-3b-a800m": "granite_moe_3b_a800m",
        "chameleon-34b": "chameleon_34b",
        "olmo-1b": "olmo_1b",
        "qwen3-8b": "qwen3_8b",
        "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
        "internlm2-20b": "internlm2_20b",
        "jamba-v0.1-52b": "jamba_v01_52b",
        "whisper-base": "whisper_base",
        "qwen2-72b": "qwen2_72b",
        "b-alexnet": "b_alexnet",
    }
)


def _module(arch: str):
    mod_name = _ALIASES.get(arch, arch.replace("-", "_").replace(".", ""))
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def list_archs():
    return list(ARCHS)
