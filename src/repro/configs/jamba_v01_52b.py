"""jamba-v0.1-52b [hybrid] -- Mamba+attn 1:7 interleave + MoE, arXiv:2403.19887.

Jamba block structure: in every 8 layers, 1 is attention and 7 are Mamba
(attn_every=8); MoE replaces the dense MLP on every other layer
(moe_every=2), 16 experts top-2. SSM state 16 (Mamba-1 sizing; implemented
here with the SSD scan, noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=65_536,
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=14_336,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,  # attention at layers 4, 12, 20, 28 (1:7 ratio)
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,  # d_inner=8192 -> 128 mamba heads
    ssm_conv=4,
    ssm_chunk=256,
    ssm_n_groups=1,
    use_rope=False,  # Jamba: no positional encoding (Mamba provides order)
    norm_type="rmsnorm",
    exit_layers=(7, 15),
    source="arXiv:2403.19887 (Jamba-v0.1: 32L d4096 32H kv8 ff14336 16e top-2, attn:mamba 1:7)",
)

SMOKE = smoke_variant(CONFIG)
