"""mamba2-130m [ssm] -- SSD (state-space duality), arXiv:2405.21060."""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,  # attention-free, MLP-free: pure SSD blocks
    vocab_size=50_280,
    head_dim=1,
    use_rope=False,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,  # d_inner=1536 -> 24 SSD heads
    ssm_conv=4,
    ssm_chunk=256,
    ssm_n_groups=1,
    norm_type="rmsnorm",
    tie_embeddings=True,
    exit_layers=(5, 11),  # early exits at 1/4 and 1/2 depth
    source="arXiv:2405.21060 (Mamba-2 130m: 24L d768 state128)",
)

SMOKE = smoke_variant(CONFIG)
