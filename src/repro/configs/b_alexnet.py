"""b_alexnet [convnet] -- the paper's own architecture (B-AlexNet, CIFAR-10)."""
from repro.models.convnet import B_ALEXNET

CONFIG = B_ALEXNET
SMOKE = B_ALEXNET  # already CPU-scale
