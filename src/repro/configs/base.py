"""Config system: architecture + input-shape + run configs.

Every assigned architecture gets a module ``repro/configs/<id>.py`` exporting
``CONFIG`` (the exact assigned spec) and ``SMOKE`` (a reduced variant of the
same family: <=2 layers, d_model<=512, <=4 experts) used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. All models in the zoo are driven by this."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | convnet
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention features -------------------------------------------------
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 = full causal; >0 = window size
    use_rope: bool = True

    # --- norm / mlp ---------------------------------------------------------
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    mlp_type: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False

    # --- MoE ----------------------------------------------------------------
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    moe_every: int = 1  # apply MoE on layers where (i % moe_every == moe_offset)
    moe_offset: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_loss_weight: float = 0.01
    # perf-pass flags (off = paper-faithful baseline; see EXPERIMENTS.md #Perf)
    moe_shard_capacity: bool = False  # shard dispatch capacity dim over data
    decode_unroll: bool = False  # unroll decode layers; in-place stacked cache
    mamba_split_proj: bool = False  # split dt out of in_proj so it TP-shards

    # --- SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64
    ssm_n_groups: int = 1

    # --- hybrid (Jamba): attention on layers where i % attn_every == attn_offset
    attn_every: int = 0  # 0 = attention everywhere (or nowhere for pure ssm)
    attn_offset: int = 0

    # --- encoder-decoder (Whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0  # stubbed frontend: precomputed frame embeddings
    max_position_embeddings: int = 0  # learned pos-emb size (0 = none/rope)

    # --- early exits (the paper's technique) ---------------------------------
    exit_layers: Tuple[int, ...] = ()  # exit head after block i (0-based)
    exit_loss_weights: Tuple[float, ...] = ()  # per-exit loss weight (training)

    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    source: str = ""  # citation

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.exit_layers and not self.exit_loss_weights:
            object.__setattr__(
                self, "exit_loss_weights", tuple(1.0 for _ in self.exit_layers)
            )

    # ------------------------------------------------------------------ utils
    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_plan(self):
        """Per-layer (mixer, ffn) kinds.

        mixer: 'attn' | 'mamba'      ffn: 'dense' | 'moe' | 'none'
        """
        plan = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                mixer = "mamba"
            elif self.family == "hybrid":
                mixer = (
                    "attn" if (i % self.attn_every) == self.attn_offset else "mamba"
                )
            else:
                mixer = "attn"
            if self.moe_num_experts > 0 and (i % self.moe_every) == self.moe_offset:
                ffn = "moe"
            elif self.d_ff > 0:
                ffn = "dense"
            else:
                ffn = "none"
            plan.append((mixer, ffn))
        return plan

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size  # lm head
        for mixer, ffn in self.layer_plan():
            if mixer == "attn":
                n += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                n += self.num_heads * hd * d
                if self.qkv_bias:
                    n += (self.num_heads + 2 * self.num_kv_heads) * hd
            else:
                di, st, g = self.d_inner, self.ssm_state, self.ssm_n_groups
                # in_proj -> [z, x, B, C, dt]; conv over x,B,C; A,D,dt_bias; out
                conv_ch = di + 2 * g * st
                n += d * (2 * di + 2 * g * st + self.ssm_heads)
                n += self.ssm_conv * conv_ch
                n += 3 * self.ssm_heads
                n += di * d + di  # out_proj + gated-norm scale
            if ffn == "dense":
                mult = 3 if self.mlp_type == "swiglu" else 2
                n += mult * d * self.d_ff
            elif ffn == "moe":
                mult = 3 if self.mlp_type == "swiglu" else 2
                n += d * self.moe_num_experts  # router
                n += self.moe_num_experts * mult * d * self.moe_d_ff
            n += 2 * d if self.norm_type != "nonparametric_ln" else 0
        for _ in self.exit_layers:
            n += d * self.vocab_size + (d if self.norm_type != "nonparametric_ln" else 0)
        if self.is_encoder_decoder:
            # encoder self-attn+mlp, decoder cross-attn
            enc = self.encoder_layers * (
                4 * d * self.num_heads * hd + 2 * d * self.d_ff + 2 * d
            )
            dec_cross = self.num_layers * (
                2 * d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + d
            )
            n += enc + dec_cross + self.encoder_seq * d
        if self.max_position_embeddings:
            n += self.max_position_embeddings * d
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k of num_experts)."""
        if self.moe_num_experts == 0:
            return self.param_count()
        n = self.param_count()
        mult = 3 if self.mlp_type == "swiglu" else 2
        per_expert = mult * self.d_model * self.moe_d_ff
        n_moe_layers = sum(1 for _, f in self.layer_plan() if f == "moe")
        n -= n_moe_layers * (self.moe_num_experts - self.moe_top_k) * per_expert
        return n

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads)
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d // heads if heads else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
    )
    if cfg.moe_num_experts:
        kw.update(
            moe_num_experts=4,
            moe_top_k=min(cfg.moe_top_k, 2),
            moe_d_ff=min(cfg.moe_d_ff, 128),
        )
    if cfg.ssm_state:
        kw.update(ssm_state=min(cfg.ssm_state, 16), ssm_head_dim=32, ssm_chunk=16)
    if cfg.is_encoder_decoder:
        kw.update(encoder_layers=2, encoder_seq=max(16, min(cfg.encoder_seq, 32)))
    if cfg.max_position_embeddings:
        kw.update(max_position_embeddings=4096)
    if cfg.attn_every:
        kw.update(attn_every=2, attn_offset=cfg.attn_offset % 2)
    if cfg.exit_layers:
        kw.update(exit_layers=(0,), exit_loss_weights=(1.0,))
    return cfg.replace(**kw)
