"""chameleon-34b [vlm] -- early-fusion, VQ image tokens, arXiv:2405.09818.

Early fusion means image patches are VQ-quantized into ordinary vocabulary
ids, so the backbone is a plain decoder over a 65536 mixed-modal vocab; the
VQ-GAN image tokenizer is the stubbed frontend (input_specs provides token
ids directly). Chameleon uses qk-norm for training stability.
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_016,
    vocab_size=65_536,
    qk_norm=True,  # Chameleon's QK-Norm stabilization
    norm_type="rmsnorm",
    exit_layers=(11, 23),
    source="arXiv:2405.09818 (Chameleon-34B: 48L d8192 64H kv8 ff22016 vocab 65536)",
)

SMOKE = smoke_variant(CONFIG)
