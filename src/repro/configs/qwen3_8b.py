"""qwen3-8b [dense] -- qk_norm + GQA, hf:Qwen/Qwen3-8B."""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12_288,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    exit_layers=(8, 17),
    source="hf:Qwen/Qwen3-8B (36L d4096 32H kv8 ff12288 vocab 151936, qk_norm)",
)

SMOKE = smoke_variant(CONFIG)
