"""internlm2-20b [dense] -- GQA, arXiv:2403.17297."""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16_384,
    vocab_size=92_544,
    norm_type="rmsnorm",
    exit_layers=(11, 23),
    source="arXiv:2403.17297 (InternLM2-20B: 48L d6144 48H kv8 ff16384 vocab 92544)",
)

SMOKE = smoke_variant(CONFIG)
