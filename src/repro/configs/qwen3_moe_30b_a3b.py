"""qwen3-moe-30b-a3b [moe] -- 128 experts top-8, hf:Qwen/Qwen3-30B-A3B."""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,  # all FFNs are MoE
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe_num_experts=128,
    moe_top_k=8,
    moe_d_ff=768,
    moe_every=1,
    norm_type="rmsnorm",
    exit_layers=(11, 23),
    source="hf:Qwen/Qwen3-30B-A3B (48L d2048 32H kv4 128e top-8 d_ff 768 vocab 151936)",
)

SMOKE = smoke_variant(CONFIG)
