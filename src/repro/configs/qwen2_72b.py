"""qwen2-72b [dense] -- GQA + QKV bias, arXiv:2407.10671."""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29_568,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    exit_layers=(19, 39),
    source="arXiv:2407.10671 (Qwen2-72B: 80L d8192 64H kv8 ff29568 vocab 152064, QKV bias)",
)

SMOKE = smoke_variant(CONFIG)
