"""olmo-1b [dense] -- non-parametric LayerNorm, arXiv:2402.00838."""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,  # MHA (GQA kv=16)
    d_ff=8192,
    vocab_size=50_304,
    norm_type="nonparametric_ln",  # OLMo: LN without scale/bias
    tie_embeddings=True,
    exit_layers=(3, 7),
    source="arXiv:2402.00838 (OLMo-1B: 16L d2048 16H ff8192 vocab 50304)",
)

SMOKE = smoke_variant(CONFIG)
