"""whisper-base [audio] -- enc-dec, conv frontend stubbed, arXiv:2212.04356.

The mel-spectrogram + 2xConv1d frontend is a stub per the assignment:
input_specs provides (batch, 1500, 512) frame embeddings (30 s of audio at
Whisper's 50 Hz encoder rate). This config describes the transformer
backbone: 6-layer bidirectional encoder + 6-layer causal decoder with
cross-attention, LayerNorm + GELU, learned absolute positions.
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,  # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    use_rope=False,
    norm_type="layernorm",
    mlp_type="gelu",
    is_encoder_decoder=True,
    encoder_layers=6,
    encoder_seq=1500,
    max_position_embeddings=524_288,  # learned positions sized for the shapes
    exit_layers=(1, 3),
    source="arXiv:2212.04356 (Whisper base: 6L enc + 6L dec, d512 8H ff2048 vocab 51865)",
)

SMOKE = smoke_variant(CONFIG)
