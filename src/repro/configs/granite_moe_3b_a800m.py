"""granite-moe-3b-a800m [moe] -- 40 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base family; assigned spec]
"""
from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,  # GQA kv=8
    d_ff=0,  # all FFNs are MoE
    vocab_size=49_155,
    moe_num_experts=40,
    moe_top_k=8,
    moe_d_ff=512,
    moe_every=1,
    norm_type="rmsnorm",
    tie_embeddings=True,
    exit_layers=(7, 15),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (assigned: 32L d1536 24H kv8 40e top-8 d_ff 512)",
)

SMOKE = smoke_variant(CONFIG)
