"""Early-exit confidence gating (paper Sec. III).

Given side-branch logits z_i, the gate computes the calibrated probability
vector p_i = softmax(z_i / T) and classifies on-device iff
max p_i >= p_tar. An entropy criterion (BranchyNet's original rule) is also
provided. The fused Pallas kernel in repro.kernels.exit_gate computes the
same quantities without materializing the softmax over large vocabularies;
this module is the jnp reference path and the public API.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GateResult:
    """Per-sample gate outputs (all arrays share leading batch dims)."""

    confidence: jnp.ndarray  # max softmax(z/T)
    prediction: jnp.ndarray  # argmax
    entropy: jnp.ndarray  # entropy of softmax(z/T), nats
    exit_mask: jnp.ndarray  # True -> classify at this exit (on-device)


def gate_statistics(logits, temperature=1.0, use_kernel: bool = False):
    """(confidence, prediction, entropy) of softmax(logits / T).

    logits: (..., num_classes); temperature: scalar or broadcastable.
    use_kernel: route through the fused Pallas kernel (TPU hot path).
    """
    if use_kernel:
        from repro.kernels.ops import exit_gate

        return exit_gate(logits, temperature)
    z = logits.astype(jnp.float32) / temperature
    z = z - jax.lax.stop_gradient(jnp.max(z, axis=-1, keepdims=True))
    logp = jax.nn.log_softmax(z, axis=-1)
    p = jnp.exp(logp)
    confidence = jnp.max(p, axis=-1)
    prediction = jnp.argmax(z, axis=-1).astype(jnp.int32)
    entropy = -jnp.sum(p * logp, axis=-1)
    return confidence, prediction, entropy


def apply_gate(
    logits,
    p_tar: float,
    temperature=1.0,
    criterion: str = "confidence",
    entropy_threshold: Optional[float] = None,
    use_kernel: bool = False,
) -> GateResult:
    """The paper's offloading gate.

    criterion 'confidence': exit iff max softmax(z/T) >= p_tar (SPINN / paper).
    criterion 'entropy':    exit iff H(softmax(z/T)) <= entropy_threshold
                            (BranchyNet's rule).
    """
    conf, pred, ent = gate_statistics(logits, temperature, use_kernel=use_kernel)
    if criterion == "confidence":
        mask = conf >= p_tar
    elif criterion == "entropy":
        if entropy_threshold is None:
            raise ValueError("entropy criterion needs entropy_threshold")
        mask = ent <= entropy_threshold
    else:
        raise ValueError(f"unknown criterion {criterion!r}")
    return GateResult(conf, pred, ent, mask)


def cascade_gate(exit_logits_list, final_logits, p_tar=None, temperatures=None,
                 plan=None):
    """Multi-branch cascade (paper Sec. IV-F).

    Walks the exits in order; each sample is classified by the FIRST exit
    whose confidence clears p_tar, else by the final (cloud) head.

    Calibration comes either from `plan` (an OffloadPlan: per-exit
    CalibratorState + p_tar) or from the legacy `temperatures` list with an
    explicit `p_tar`; an explicit p_tar overrides the plan's.

    Returns dict with:
      exit_index: (batch,) int32, index of serving exit (len(exits) = cloud)
      prediction: (batch,) int32
      confidence: (batch,) float32 (of the serving head)
    """
    n_exits = len(exit_logits_list)
    if plan is not None:
        if p_tar is None:
            p_tar = plan.p_tar
        exit_logits_list = [
            plan.calibrated_logits(z, i) for i, z in enumerate(exit_logits_list)
        ]
        temperatures = [1.0] * n_exits
    elif p_tar is None:
        raise ValueError("cascade_gate needs p_tar or plan")
    if temperatures is None:
        temperatures = [1.0] * n_exits
    batch = final_logits.shape[0]
    exit_index = jnp.full((batch,), n_exits, jnp.int32)
    prediction = jnp.argmax(final_logits.astype(jnp.float32), axis=-1).astype(jnp.int32)
    fconf, _, _ = gate_statistics(final_logits)
    confidence = fconf
    # walk backwards so the earliest qualifying exit wins
    for i in range(n_exits - 1, -1, -1):
        conf, pred, _ = gate_statistics(exit_logits_list[i], temperatures[i])
        take = conf >= p_tar
        exit_index = jnp.where(take, i, exit_index)
        prediction = jnp.where(take, pred, prediction)
        confidence = jnp.where(take, conf, confidence)
    return {
        "exit_index": exit_index,
        "prediction": prediction,
        "confidence": confidence,
    }
