"""The shared gate execution layer: one decision, two backends.

Every serving surface in this repo makes the same per-sample decision --
calibrate a branch's logits, take max-softmax confidence and the argmax
prediction, compare against the moving target ``p_tar`` -- but before
this module each surface carried its own copy of the evaluation loop:
`OffloadPlan.gate_block` / `PlanBank.gate_block` on the host, the fleet
gate table's per-(context, expert, branch) precompute, and the
contextual serving core's per-plan-key loop. `GateBackend` extracts that
evaluation into one swappable object:

* `NumpyGateBackend` (``"numpy"``, the default) -- the pre-existing host
  path: eager `gate_statistics` per block, one call per distinct expert,
  float64 numpy outputs. Bit-identical to the code it replaced; the
  single-cell fleet/event-runtime parity tests pin it.
* `JaxGateBackend` (``"jax"``) -- jitted whole-window evaluation: per-
  sample expert temperatures are gathered and the calibrate -> softmax
  confidence -> argmax -> compare -> per-cell segment reductions chain
  runs as ONE compiled function. Windows are padded to the next power of
  two so the trace cache stays O(log N) over a run, and the gate tables
  live device-resident between calls -- the layout that shards across
  cells on a multi-device mesh (cells are independent rows of the same
  gather, the natural `shard_map` axis).

Consumers select a backend per run: `OffloadPlan.gate_block(...,
backend=)`, `PlanBank.gate_block(..., backend=)`, `GateTable(...,
backend=)` (the fleet's dense table, formerly `fleet.gate.FleetGateTable`
-- that name remains as a shim), and
`repro.serving.drift.ContextualLogitsCore(..., backend=)`.

Numerics: both backends run the same float32 `gate_statistics` math; the
jitted path may differ in the last ulp (XLA fusion), which is why the
parity tests assert decisions exactly on reference data but confidences
to ~1e-6. A sample whose confidence lands exactly on ``p_tar`` could in
principle flip between backends; no reference dataset exercises that
measure-zero boundary.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

#: context id used when a core has no drift axis (plain logits, no schedule)
STATIC_CONTEXT = "__all__"


# ------------------------------------------------------------- the backends
class GateBackend:
    """Evaluates gate blocks and whole arrival windows.

    Block primitives (`plan_gate_block`, `bank_gate_block`) produce the
    per-sample (confidence, prediction) arrays every consumer thresholds;
    window primitives (`window_gate`, `window_gate_cells`) evaluate a
    precomputed dense table over an arrival window's (context, sample)
    indices, the fleet simulator's inner loop.
    """

    name: str = "base"

    # ------------------------------------------------------- block level
    def plan_gate_block(
        self, plan, exit_logits, branch: Optional[int] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def bank_gate_block(
        self, bank, exit_logits, expert_ids: np.ndarray,
        branch: Optional[int] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    # ------------------------------------------------------ window level
    def window_gate(
        self, conf_table, pred_table, ctx_ids, samples, branch_idx: int,
        p_tar: float,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """-> (confidence, prediction, on_device) for one cell's window."""
        raise NotImplementedError

    def window_gate_cells(
        self, conf_table, pred_table, ctx_ids, samples, cell_ids,
        branch_idx_by_cell, p_tar_by_cell, n_cells: int,
    ):
        """Whole-fleet window: every cell's arrivals in one evaluation.

        -> dict with per-sample ``confidence``/``prediction``/``on_device``
        plus the per-cell segment reductions ``on_count``/``offload_count``
        (shape (n_cells,)) -- the telemetry-facing sums computed inside
        the same pass that gates.
        """
        raise NotImplementedError

    def as_table(self, array):
        """Backend-resident view of a dense gate table (host numpy in,
        whatever the backend gathers from out)."""
        return array


class NumpyGateBackend(GateBackend):
    """The host fancy-index path -- the exact code the serving and fleet
    stacks ran before the backends were extracted, so every existing
    parity/tolerance test pins it bit-for-bit."""

    name = "numpy"

    def plan_gate_block(self, plan, exit_logits, branch=None):
        from repro.core.exits import gate_statistics

        conf, pred, _ = gate_statistics(plan.calibrated_logits(exit_logits, branch))
        return np.asarray(conf, np.float64), np.asarray(pred, np.int64)

    def bank_gate_block(self, bank, exit_logits, expert_ids, branch=None):
        z = np.asarray(exit_logits)
        expert_ids = np.asarray(expert_ids, np.int64)
        keys = bank.contexts
        conf = np.empty(z.shape[0], np.float64)
        pred = np.empty(z.shape[0], np.int64)
        for eid in np.unique(expert_ids):
            plan = bank.plan_for(keys[eid]) if eid >= 0 else bank.default_plan
            m = expert_ids == eid
            c, p = self.plan_gate_block(plan, z[m], branch=branch)
            conf[m], pred[m] = c, p
        return conf, pred

    def window_gate(self, conf_table, pred_table, ctx_ids, samples,
                    branch_idx, p_tar):
        conf = conf_table[ctx_ids, branch_idx, samples]
        pred = pred_table[ctx_ids, branch_idx, samples]
        return conf, pred, conf >= p_tar

    def window_gate_cells(self, conf_table, pred_table, ctx_ids, samples,
                          cell_ids, branch_idx_by_cell, p_tar_by_cell,
                          n_cells):
        cell_ids = np.asarray(cell_ids, np.int64)
        bi = np.asarray(branch_idx_by_cell, np.int64)[cell_ids]
        conf = conf_table[ctx_ids, bi, samples]
        pred = pred_table[ctx_ids, bi, samples]
        on = conf >= np.asarray(p_tar_by_cell, np.float64)[cell_ids]
        on_count = np.bincount(cell_ids, weights=on, minlength=n_cells)
        total = np.bincount(cell_ids, minlength=n_cells)
        return {
            "confidence": conf,
            "prediction": pred,
            "on_device": on,
            "on_count": on_count.astype(np.int64),
            "offload_count": (total - on_count).astype(np.int64),
        }


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


class JaxGateBackend(GateBackend):
    """Jitted whole-window gate evaluation.

    Per-sample expert temperatures are gathered on device, so a bank
    block with K distinct experts costs the same single fused kernel as a
    plain plan block (the numpy path pays one Python call per expert).
    Windows are padded to the next power of two before the compiled call
    (bounding retraces to O(log N)); richer-than-temperature calibrators
    fall back to the host path, which keeps the backend exact for every
    plan the repo can serialize.
    """

    name = "jax"

    def __init__(self):
        self._jit_cache: Dict[str, Callable] = {}
        self._host = NumpyGateBackend()

    # ------------------------------------------------------ jitted bodies
    def _stats_fn(self):
        if "stats" not in self._jit_cache:
            import jax

            from repro.core.exits import gate_statistics

            def f(z, t):
                conf, pred, _ = gate_statistics(z, t)
                return conf, pred

            self._jit_cache["stats"] = jax.jit(f)
        return self._jit_cache["stats"]

    def _gather_fn(self):
        if "gather" not in self._jit_cache:
            import jax
            import jax.numpy as jnp

            def f(conf_t, pred_t, ctx, bi, samples, p_tar):
                conf = conf_t[ctx, bi, samples]
                pred = pred_t[ctx, bi, samples]
                return conf, pred, conf >= p_tar

            self._jit_cache["gather"] = jax.jit(f)
        return self._jit_cache["gather"]

    def _cells_fn(self):
        if "cells" not in self._jit_cache:
            import jax
            import jax.numpy as jnp

            def f(conf_t, pred_t, ctx, samples, cells, bi_by_cell,
                  p_tar_by_cell, valid, n_cells):
                bi = bi_by_cell[cells]
                conf = conf_t[ctx, bi, samples]
                pred = pred_t[ctx, bi, samples]
                on = (conf >= p_tar_by_cell[cells]) & valid
                seg = jax.ops.segment_sum
                on_count = seg(on.astype(jnp.int32), cells,
                               num_segments=n_cells)
                total = seg(valid.astype(jnp.int32), cells,
                            num_segments=n_cells)
                return conf, pred, on, on_count, total - on_count

            self._jit_cache["cells"] = jax.jit(f, static_argnames=("n_cells",))
        return self._jit_cache["cells"]

    # ------------------------------------------------------- block level
    @staticmethod
    def _scalar_temperature(state) -> Optional[float]:
        if state.kind == "identity":
            return 1.0
        if state.kind == "temperature":
            return float(state.params["temperature"])
        return None

    def plan_gate_block(self, plan, exit_logits, branch=None):
        state = plan._state_for(branch)
        t = self._scalar_temperature(state)
        if t is None:  # richer calibrator: exact host path
            return self._host.plan_gate_block(plan, exit_logits, branch)
        conf, pred = self._stats_fn()(np.asarray(exit_logits), t)
        return np.asarray(conf, np.float64), np.asarray(pred, np.int64)

    def bank_gate_block(self, bank, exit_logits, expert_ids, branch=None):
        keys = bank.contexts
        plans = [bank.plan_for(k) for k in keys] + [bank.default_plan]
        temps = [
            self._scalar_temperature(p._state_for(branch)) for p in plans
        ]
        if any(t is None for t in temps):
            return self._host.bank_gate_block(
                bank, exit_logits, expert_ids, branch
            )
        z = np.asarray(exit_logits)
        expert_ids = np.asarray(expert_ids, np.int64)
        # -1 (unknown -> default plan) maps onto the appended last slot
        idx = np.where(expert_ids >= 0, expert_ids, len(keys))
        t_vec = np.asarray(temps, np.float32)[idx][:, None]
        conf, pred = self._stats_fn()(z, t_vec)
        return np.asarray(conf, np.float64), np.asarray(pred, np.int64)

    # ------------------------------------------------------ window level
    def as_table(self, array):
        import jax.numpy as jnp

        return jnp.asarray(array)

    def _pad(self, *cols):
        n = len(cols[0])
        m = _next_pow2(n)
        if m == n:
            return cols, n
        return tuple(
            np.concatenate([c, np.zeros(m - n, dtype=np.asarray(c).dtype)])
            for c in cols
        ), n

    def window_gate(self, conf_table, pred_table, ctx_ids, samples,
                    branch_idx, p_tar):
        n = len(ctx_ids)
        if n == 0:
            return (np.empty(0), np.empty(0, np.int64),
                    np.empty(0, bool))
        (ctx, smp), _ = self._pad(np.asarray(ctx_ids, np.int64),
                                  np.asarray(samples, np.int64))
        conf, pred, on = self._gather_fn()(
            conf_table, pred_table, ctx, np.int64(branch_idx), smp,
            np.float32(p_tar),
        )
        return (np.asarray(conf, np.float64)[:n],
                np.asarray(pred, np.int64)[:n],
                np.asarray(on, bool)[:n])

    def window_gate_cells(self, conf_table, pred_table, ctx_ids, samples,
                          cell_ids, branch_idx_by_cell, p_tar_by_cell,
                          n_cells):
        n = len(ctx_ids)
        if n == 0:
            zero = np.zeros(n_cells, np.int64)
            return {
                "confidence": np.empty(0),
                "prediction": np.empty(0, np.int64),
                "on_device": np.empty(0, bool),
                "on_count": zero,
                "offload_count": zero.copy(),
            }
        valid = np.ones(n, bool)
        (ctx, smp, cells, valid), _ = self._pad(
            np.asarray(ctx_ids, np.int64), np.asarray(samples, np.int64),
            np.asarray(cell_ids, np.int64), valid,
        )
        conf, pred, on, on_count, off_count = self._cells_fn()(
            conf_table, pred_table, ctx, smp, cells,
            np.asarray(branch_idx_by_cell, np.int64),
            np.asarray(p_tar_by_cell, np.float32), valid, int(n_cells),
        )
        return {
            "confidence": np.asarray(conf, np.float64)[:n],
            "prediction": np.asarray(pred, np.int64)[:n],
            "on_device": np.asarray(on, bool)[:n],
            "on_count": np.asarray(on_count, np.int64),
            "offload_count": np.asarray(off_count, np.int64),
        }


# -------------------------------------------------------------- registry
def _compiled_backend_factory() -> GateBackend:
    # lazy: repro.fleet imports this module, so the compiled backend (which
    # lives with the compiled fleet simulator) registers by name here and
    # resolves on first use
    from repro.fleet.compiled import CompiledGateBackend

    return CompiledGateBackend()


_GATE_BACKENDS: Dict[str, Callable[[], GateBackend]] = {
    "numpy": NumpyGateBackend,
    "jax": JaxGateBackend,
    "compiled": _compiled_backend_factory,
}
_INSTANCES: Dict[str, GateBackend] = {}


def register_gate_backend(name: str, factory: Callable[[], GateBackend]) -> None:
    _GATE_BACKENDS[name] = factory
    _INSTANCES.pop(name, None)


def available_gate_backends() -> List[str]:
    return sorted(_GATE_BACKENDS)


def get_gate_backend(backend=None) -> GateBackend:
    """Resolve a backend instance from None (-> numpy), a registered
    name, or an instance (passed through)."""
    if backend is None:
        backend = "numpy"
    if isinstance(backend, GateBackend):
        return backend
    if backend not in _GATE_BACKENDS:
        raise ValueError(
            f"unknown gate backend {backend!r} "
            f"(registered: {available_gate_backends()})"
        )
    if backend not in _INSTANCES:  # backends cache jitted fns: share them
        _INSTANCES[backend] = _GATE_BACKENDS[backend]()
    return _INSTANCES[backend]


# ----------------------------------------------------- the dense gate table
class GateTable:
    """Precomputed per-(context, branch) gate blocks under per-sample
    expert selection -- the fleet's batched analogue of the serving cores.

    exit_logits_by_context: {context: {physical_branch: (N, C) logits}};
    final_logits_by_context the matching cloud main heads. For the
    non-drifting case pass ``{STATIC_CONTEXT: {...}}`` (or use
    `GateTable.from_logits`).

    plan_or_bank decides calibration exactly as in `ContextualLogitsCore`:
    a single `OffloadPlan` applies one calibrator set everywhere; a
    `PlanBank` picks each sample's expert -- via its embedded estimator on
    `features_by_context` (the honest edge-side path; unknown verdicts
    fall back to the default plan) or by the true context (oracle bound).

    The precompute gathers, per (true context, branch), each sample's
    confidence under ITS expert plan into one dense (n_ctx, n_branch, N)
    array, so the runtime cost of a window is one fancy-index + compare.
    Both the precompute and the window lookups route through the selected
    `GateBackend` (``"numpy"`` default; ``"jax"`` keeps the tables
    device-resident and gates a window in one compiled call).
    """

    def __init__(
        self,
        exit_logits_by_context: Dict[str, Dict[int, np.ndarray]],
        final_logits_by_context: Dict[str, np.ndarray],
        plan_or_bank,
        labels: Optional[np.ndarray] = None,
        features_by_context: Optional[Dict[str, np.ndarray]] = None,
        backend=None,
    ):
        from repro.core.bank import PlanBank

        self.backend = get_gate_backend(backend)
        if isinstance(plan_or_bank, PlanBank):
            self.bank: Optional[PlanBank] = plan_or_bank
            self.plan = plan_or_bank.default_plan
            criteria = {p.criterion for p in plan_or_bank.plans.values()}
        else:
            self.bank = None
            self.plan = plan_or_bank
            criteria = {plan_or_bank.criterion}
        if criteria != {"confidence"}:
            # every expert, not just the default: the ContextualLogitsCore
            # contract, so the fleet cannot silently serve a bank the
            # event runtime would reject
            raise ValueError(
                "the fleet gate thresholds the runtime's moving confidence "
                f"target; plan criteria {sorted(criteria)} are not supported"
            )
        self.ctx_keys: List[str] = sorted(exit_logits_by_context)
        self.ctx_index = {k: i for i, k in enumerate(self.ctx_keys)}
        if set(final_logits_by_context) != set(self.ctx_keys):
            raise ValueError("exit and final logits must cover the same contexts")
        self.branches = sorted(next(iter(exit_logits_by_context.values())))
        self._branch_index = {b: i for i, b in enumerate(self.branches)}
        for ctx, per_branch in exit_logits_by_context.items():
            if sorted(per_branch) != self.branches:
                raise ValueError(f"context {ctx!r} covers different branches")
        n = int(np.asarray(final_logits_by_context[self.ctx_keys[0]]).shape[0])
        self.n_samples = n

        # per-(ctx, sample) expert selection, as in ContextualLogitsCore:
        # estimator verdicts on real features when available, oracle else
        self._oracle = not (
            self.bank is not None
            and self.bank.estimator is not None
            and features_by_context is not None
        )
        bank_keys = self.bank.contexts if self.bank is not None else []
        # est ids index into bank_keys; -1 = unknown verdict; whole array
        # None in oracle mode (no estimator to report in telemetry)
        self._est_ids: Optional[np.ndarray] = None
        if not self._oracle:
            est = self.bank.estimator
            est_ids = np.empty((len(self.ctx_keys), n), np.int64)
            key_to_bank = {k: i for i, k in enumerate(bank_keys)}
            est_to_bank = np.asarray(
                [key_to_bank[k] for k in est.contexts], np.int64
            )
            for ci, ctx in enumerate(self.ctx_keys):
                if ctx not in features_by_context:
                    raise ValueError(f"no features for context {ctx!r}")
                ids = est.predict_ids(features_by_context[ctx])
                est_ids[ci] = np.where(ids >= 0, est_to_bank[ids], -1)
            self._est_ids = est_ids

        self.conf = np.empty((len(self.ctx_keys), len(self.branches), n))
        self.pred = np.empty_like(self.conf, dtype=np.int64)
        for ci, ctx in enumerate(self.ctx_keys):
            for bi, b in enumerate(self.branches):
                z = np.asarray(exit_logits_by_context[ctx][b])
                if self.bank is None:
                    c, p = self.backend.plan_gate_block(
                        self.plan, z, branch=b - 1
                    )
                elif self._oracle:
                    eids = np.full(
                        n, bank_keys.index(ctx) if ctx in bank_keys else -1,
                        np.int64,
                    )
                    c, p = self.backend.bank_gate_block(
                        self.bank, z, eids, branch=b - 1
                    )
                else:
                    c, p = self.backend.bank_gate_block(
                        self.bank, z, self._est_ids[ci], branch=b - 1
                    )
                self.conf[ci, bi], self.pred[ci, bi] = c, p
        self.final_pred = np.stack(
            [
                np.argmax(np.asarray(final_logits_by_context[k]), axis=-1)
                for k in self.ctx_keys
            ]
        ).astype(np.int64)
        # retained for the codec's per-level cloud tables (computed lazily
        # in `cloud_pred` -- a level-0-only run never touches them)
        self._final_logits = {
            k: np.asarray(final_logits_by_context[k]) for k in self.ctx_keys
        }
        self._final_pred_by_level: Dict[int, np.ndarray] = {0: self.final_pred}
        self.labels = None if labels is None else np.asarray(labels, np.int64)
        self.bank_keys = bank_keys
        # backend-resident views (device arrays for the jax backend) used
        # by the window lookups; host numpy stays the source of truth
        self._conf_t = self.backend.as_table(self.conf)
        self._pred_t = self.backend.as_table(self.pred)

    @classmethod
    def from_logits(
        cls,
        exit_logits: Dict[int, np.ndarray],
        final_logits: np.ndarray,
        plan,
        labels: Optional[np.ndarray] = None,
        backend=None,
    ) -> "GateTable":
        """Non-drifting table over one logit set (the `LogitsCore` case)."""
        return cls({STATIC_CONTEXT: exit_logits}, {STATIC_CONTEXT: final_logits},
                   plan, labels=labels, backend=backend)

    # ------------------------------------------------------- window lookups
    def branch_idx(self, branch: int) -> int:
        if branch not in self._branch_index:
            raise ValueError(
                f"branch {branch} not served (table covers {self.branches})"
            )
        return self._branch_index[branch]

    def gate(self, ctx_ids: np.ndarray, samples: np.ndarray, branch: int):
        """-> (confidence, edge prediction) for a whole window."""
        bi = self.branch_idx(branch)
        return self.conf[ctx_ids, bi, samples], self.pred[ctx_ids, bi, samples]

    def gate_window(
        self, ctx_ids: np.ndarray, samples: np.ndarray, branch: int,
        p_tar: float,
    ):
        """-> (confidence, prediction, on_device) through the backend --
        what the fleet simulator thresholds per (cell, window)."""
        return self.backend.window_gate(
            self._conf_t, self._pred_t, ctx_ids, samples,
            self.branch_idx(branch), p_tar,
        )

    def gate_window_cells(
        self, ctx_ids, samples, cell_ids, branch_by_cell, p_tar_by_cell,
        n_cells: int,
    ):
        """Whole-fleet window in one backend call (+ per-cell on/offload
        segment counts); `branch_by_cell` holds PHYSICAL branch numbers."""
        bi = np.asarray([self.branch_idx(int(b)) for b in branch_by_cell],
                        np.int64)
        return self.backend.window_gate_cells(
            self._conf_t, self._pred_t, ctx_ids, samples, cell_ids, bi,
            np.asarray(p_tar_by_cell, np.float64), n_cells,
        )

    def cloud_pred(
        self, ctx_ids: np.ndarray, samples: np.ndarray, level: int = 0
    ) -> np.ndarray:
        """Cloud (main-head) predictions for a window. `level` is the
        payload codec level the offload shipped at: the main head then
        sees the activation after a codec round-trip, modeled here by
        round-tripping the stored final logits through the `kernels.ref`
        oracle (level 0 stays the untouched legacy table)."""
        level = int(level)
        if level not in self._final_pred_by_level:
            from repro.kernels.ref import roundtrip_codec_ref

            self._final_pred_by_level[level] = np.stack(
                [
                    np.argmax(
                        roundtrip_codec_ref(self._final_logits[k], level),
                        axis=-1,
                    )
                    for k in self.ctx_keys
                ]
            ).astype(np.int64)
        return self._final_pred_by_level[level][ctx_ids, samples]

    def est_ids(self, ctx_ids: np.ndarray, samples: np.ndarray) -> Optional[np.ndarray]:
        """Estimator verdicts (indices into `bank_keys`, -1 unknown) for a
        window; None when selection is oracle/single-plan."""
        if self._est_ids is None:
            return None
        return self._est_ids[ctx_ids, samples]

    def correct(self, samples: np.ndarray, preds: np.ndarray) -> Optional[np.ndarray]:
        if self.labels is None:
            return None
        return self.labels[samples] == preds
