"""Adaptive partition-point selection (Neurosurgeon-style, paper Sec. I-II).

Given per-layer edge/cloud compute latencies and per-boundary payload sizes,
choose the partition layer (equivalently, which early exit to place on the
edge) that minimizes expected end-to-end latency subject to the reliability
target. The expected latency depends on the offloading probability at each
candidate exit, which itself depends on the calibrated confidence
distribution -- so the optimizer consumes measured exit statistics from a
validation pass (the adaptive part that Edgent/DADS solve with static layer
graphs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.core.exits import gate_statistics


@dataclass
class PartitionCandidate:
    exit_index: int
    partition_layer: int  # model layer after which the split happens
    edge_time_s: float  # time to run layers [0..partition] + exit head
    cloud_time_s: float  # time to run remaining layers on the cloud
    payload_bytes: int  # activation size shipped when offloading
    offload_prob: float  # P(confidence < p_tar) at this exit (calibrated)
    expected_latency_s: float


def expected_latency(
    edge_time_s: float,
    cloud_time_s: float,
    payload_bytes: int,
    offload_prob: float,
    uplink_bps: float,
    comm_wait_factor: float = 1.0,
) -> float:
    """Neurosurgeon objective. `comm_wait_factor` scales the transfer term
    for contention on a shared link (1.0 = the paper's uncontended link);
    the online controller passes an M/M/1 busy-ratio correction here."""
    comm = payload_bytes * 8.0 / uplink_bps
    return edge_time_s + offload_prob * (comm * comm_wait_factor + cloud_time_s)


def choose_partition(
    exit_logits_list: Sequence[np.ndarray],
    temperatures: Sequence[float] = None,
    p_tar: float = None,
    edge_times_s: Sequence[float] = (),
    cloud_times_s: Sequence[float] = (),
    payload_bytes: Sequence[int] = (),
    exit_layer_indices: Sequence[int] = (),
    uplink_bps: float = 18.8e6,
    plan=None,
) -> List[PartitionCandidate]:
    """Rank candidate partitions by expected latency. First entry wins.

    Calibration comes either from `plan` (an OffloadPlan: the offload
    probability at each exit uses that exit's CalibratorState and the plan's
    p_tar) or from the legacy `temperatures` list with an explicit `p_tar`.
    """
    if plan is not None:
        if p_tar is None:
            p_tar = plan.p_tar
    elif temperatures is None or p_tar is None:
        raise ValueError("choose_partition needs (temperatures, p_tar) or plan")
    cands = []
    for i, logits in enumerate(exit_logits_list):
        if plan is not None:
            conf, _, _ = gate_statistics(plan.calibrated_logits(logits, i))
        else:
            conf, _, _ = gate_statistics(logits, temperatures[i])
        offload_prob = float(np.mean(np.asarray(conf) < p_tar))
        lat = expected_latency(
            edge_times_s[i], cloud_times_s[i], payload_bytes[i], offload_prob, uplink_bps
        )
        cands.append(
            PartitionCandidate(
                exit_index=i,
                partition_layer=exit_layer_indices[i],
                edge_time_s=edge_times_s[i],
                cloud_time_s=cloud_times_s[i],
                payload_bytes=payload_bytes[i],
                offload_prob=offload_prob,
                expected_latency_s=lat,
            )
        )
    return sorted(cands, key=lambda c: c.expected_latency_s)


def select_partition(
    plan,
    exit_logits_list: Sequence[np.ndarray],
    edge_times_s: Sequence[float],
    cloud_times_s: Sequence[float],
    payload_bytes: Sequence[int],
    exit_layer_indices: Sequence[int],
    uplink_bps: float,
):
    """Choose the latency-optimal partition and record it in the plan.

    Returns (plan', candidates): plan' is a copy of `plan` with exit_index
    and partition_layer set from the winning candidate -- the complete
    deployable artifact (calibration + gate + split point).
    """
    cands = choose_partition(
        exit_logits_list,
        edge_times_s=edge_times_s,
        cloud_times_s=cloud_times_s,
        payload_bytes=payload_bytes,
        exit_layer_indices=exit_layer_indices,
        uplink_bps=uplink_bps,
        plan=plan,
    )
    best = cands[0]
    return plan.with_partition(best.exit_index, best.partition_layer), cands
