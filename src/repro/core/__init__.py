"""The paper's contribution as a composable library.

The pipeline is: run a validation pass over the early-exit network, fit a
`Calibrator` per exit, bundle the resulting `CalibratorState`s with the
gating criterion, `p_tar`, and the chosen partition point into an
`OffloadPlan`, serialize it to JSON, and hand it to the serving stack.
A reloaded plan gates bit-identically -- the artifact fit in the lab is
the artifact deployed on the device.

  exits        confidence gating (max-softmax / entropy) + cascades
  calibration  the Calibrator protocol + registry: Temperature Scaling
               (paper Eq. 2), vector scaling, identity baseline; states
               are JAX pytrees so gating stays jit/vmap-compatible
  policy       OffloadPlan -- the single deployable artifact (per-exit
               calibrator states + gate + partition), JSON round-trip
  bank         PlanBank -- one expert OffloadPlan per input-distortion
               context + the cheap edge-side DistortionEstimator that
               picks the expert per batch; same JSON contract as plans
  gatepath     the shared gate execution layer: GateBackend (host numpy /
               jitted JAX) + the dense GateTable both serving stacks gate
               whole windows through
  control      the shared controller core: rescore_plan candidate tables,
               feasibility/hysteresis/concession rules, ControllerCore
               (context-aware mix-weighted re-scoring), and the telemetry
               primitives both serving stacks report and window with
  partition    adaptive partition-point selection (expected-latency
               optimal); select_partition writes the choice into the plan
  metrics      ECE, reliability diagrams, inference outage, missed deadline

Consumers: repro.offload.engine (serving), repro.offload.simulator
(missed-deadline experiments), benchmarks/ and examples/.
"""
from repro.core.bank import (  # noqa: F401
    UNKNOWN_CONTEXT,
    DistortionEstimator,
    PlanBank,
    fit_bank,
)
from repro.core.calibration import (  # noqa: F401
    Calibrator,
    CalibratorState,
    apply_calibrator,
    available_calibrators,
    calibrate_cascade,
    fit_temperature,
    get_calibrator,
    register_calibrator,
)
from repro.core.control import (  # noqa: F401
    ControlConfig,
    ControllerCore,
    choose_with_concession,
    hold_incumbent,
    latency_stats_ms,
    on_device_gap,
    row_feasible,
    select_candidate,
    windowed_mean,
    windowed_mix,
    windowed_rate,
)
from repro.core.exits import apply_gate, cascade_gate, gate_statistics  # noqa: F401
from repro.core.gatepath import (  # noqa: F401
    STATIC_CONTEXT,
    GateBackend,
    GateTable,
    JaxGateBackend,
    NumpyGateBackend,
    available_gate_backends,
    get_gate_backend,
    register_gate_backend,
)
from repro.core.metrics import (  # noqa: F401
    ece,
    inference_outage_probability,
    outage_probability_cascade,
    overall_accuracy,
    reliability_diagram,
)
from repro.core.partition import choose_partition, select_partition  # noqa: F401
from repro.core.policy import (  # noqa: F401
    OffloadPlan,
    OffloadPolicy,
    make_plan,
    make_policy,
    rescore_plan,
)
