"""The paper's contribution as a composable library.

The pipeline is: run a validation pass over the early-exit network, fit a
`Calibrator` per exit, bundle the resulting `CalibratorState`s with the
gating criterion, `p_tar`, and the chosen partition point into an
`OffloadPlan`, serialize it to JSON, and hand it to the serving stack.
A reloaded plan gates bit-identically -- the artifact fit in the lab is
the artifact deployed on the device.

  exits        confidence gating (max-softmax / entropy) + cascades
  calibration  the Calibrator protocol + registry: Temperature Scaling
               (paper Eq. 2), vector scaling, identity baseline; states
               are JAX pytrees so gating stays jit/vmap-compatible
  policy       OffloadPlan -- the single deployable artifact (per-exit
               calibrator states + gate + partition), JSON round-trip
  bank         PlanBank -- one expert OffloadPlan per input-distortion
               context + the cheap edge-side DistortionEstimator that
               picks the expert per batch; same JSON contract as plans
  partition    adaptive partition-point selection (expected-latency
               optimal); select_partition writes the choice into the plan
  metrics      ECE, reliability diagrams, inference outage, missed deadline

Consumers: repro.offload.engine (serving), repro.offload.simulator
(missed-deadline experiments), benchmarks/ and examples/.
"""
from repro.core.bank import (  # noqa: F401
    UNKNOWN_CONTEXT,
    DistortionEstimator,
    PlanBank,
    fit_bank,
)
from repro.core.calibration import (  # noqa: F401
    Calibrator,
    CalibratorState,
    apply_calibrator,
    available_calibrators,
    calibrate_cascade,
    fit_temperature,
    get_calibrator,
    register_calibrator,
)
from repro.core.exits import apply_gate, cascade_gate, gate_statistics  # noqa: F401
from repro.core.metrics import (  # noqa: F401
    ece,
    inference_outage_probability,
    outage_probability_cascade,
    overall_accuracy,
    reliability_diagram,
)
from repro.core.partition import choose_partition, select_partition  # noqa: F401
from repro.core.policy import (  # noqa: F401
    OffloadPlan,
    OffloadPolicy,
    make_plan,
    make_policy,
    rescore_plan,
)
