"""The paper's contribution as a composable library.

  exits        confidence gating (max-softmax / entropy) + cascades
  calibration  Temperature Scaling (+ vector scaling, sequential cascades)
  metrics      ECE, reliability diagrams, inference outage, missed deadline
  policy       deployable OffloadPolicy built from a calibration pass
  partition    adaptive partition-point selection (expected-latency optimal)
"""
from repro.core.calibration import fit_temperature, calibrate_cascade  # noqa: F401
from repro.core.exits import apply_gate, cascade_gate, gate_statistics  # noqa: F401
from repro.core.metrics import (  # noqa: F401
    ece,
    inference_outage_probability,
    outage_probability_cascade,
    overall_accuracy,
    reliability_diagram,
)
from repro.core.policy import OffloadPolicy, make_policy  # noqa: F401
