"""The shared controller core: one control plane for both serving stacks.

The paper's loop -- calibrate offline, gate on calibrated confidence at
serve time, and adapt the deployed (branch, p_tar) when conditions move
-- used to be implemented twice: the event-driven `ServingRuntime` path
(`repro.serving.controller.OnlineController`) and the fleet path
(`repro.fleet.controller.FleetController`) each carried their own
candidate-table construction, plan re-scoring, and telemetry reductions.
This module is the single home for the pieces both share:

* `rescore_plan` -- the Edgent-style candidate table (re-used calibrators,
  measured bandwidth, M/M/1 uplink correction, optional per-sample mix
  weights). Moved here from `repro.core.policy`, which keeps a re-export.
  Each row now also prices the paper's reliability contract: the
  candidate's estimated ON-DEVICE accuracy and ``reliability_gap``
  |on-device accuracy - p_tar|, so a controller can refuse candidates
  that would silently break calibration.
* selection rules -- `row_feasible` / `select_candidate` (accuracy floor
  + reliability-gap cap, latency-greedy among feasible, graceful
  degradation), `hold_incumbent` (hysteresis), and
  `choose_with_concession` (the distress-gated p_tar concession:
  hold the operator's contract while the link can carry it, otherwise
  make the WEAKEST stable concession).
* `ControllerCore` -- owns the validation blocks (context-blind or
  per-context), the once-per-run calibrated exit statistics, the latency
  profile columns, and the mix -> per-sample-weight mapping that makes a
  re-score CONTEXT-AWARE (validation samples weighted by the traffic mix
  a telemetry window actually observed).
* shared telemetry primitives -- `latency_stats_ms`, `on_device_gap`,
  and the windowed estimators (`windowed_mean`, `windowed_rate`,
  `windowed_mix`) that both `repro.serving.telemetry.Telemetry` and
  `repro.fleet.telemetry.FleetTelemetry` answer control questions with,
  so the two stacks cannot disagree about what an estimate means.

`OnlineController` and `FleetController` are thin policy layers over this
core: the event controller adds queue-aware edge-time inflation and
hysteresis, the fleet controller adds per-cell iteration, distress
gating, and the shared-cloud utilization cap.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


# ------------------------------------------------ shared telemetry primitives
def latency_stats_ms(latencies_s: np.ndarray) -> Dict[str, float]:
    """p50/p95/p99/mean in ms from an array of per-request latencies --
    the one definition of the repo's latency roll-up, shared by the
    event-driven `Telemetry` and the fleet-scale aggregator."""
    lat = np.asarray(latencies_s, np.float64)
    if lat.size == 0:
        nan = float("nan")
        return {"p50_ms": nan, "p95_ms": nan, "p99_ms": nan, "mean_ms": nan}
    p50, p95, p99 = np.percentile(lat, [50, 95, 99])
    return {
        "p50_ms": float(p50) * 1e3,
        "p95_ms": float(p95) * 1e3,
        "p99_ms": float(p99) * 1e3,
        "mean_ms": float(lat.mean()) * 1e3,
    }


def on_device_gap(correct: np.ndarray, p_tar: np.ndarray) -> Optional[float]:
    """|on-device accuracy - mean p_tar in force| for one regime group --
    the paper's reliability contract, measured where it is made: on the
    samples the gate kept on the device. None for an empty group."""
    correct = np.asarray(correct, np.float64)
    if correct.size == 0:
        return None
    return abs(float(correct.mean()) - float(np.mean(p_tar)))


def windowed_mean(
    times,
    values,
    window_s: Optional[float] = None,
    now: Optional[float] = None,
    stale_fallback: bool = True,
) -> Optional[float]:
    """Mean of the (t, value) observations in the trailing window.

    With no window (or no `now`), the mean over everything. With
    `stale_fallback`, an empty window falls back to the single nearest
    observation: the most recent one at or before `now`, or -- when every
    observation post-dates `now`, as happens on a congested fleet cell
    whose in-flight transfers are priced at their future ready times --
    the earliest upcoming one (stale beats assuming the nominal best
    case -- the bandwidth-estimate contract). Without `stale_fallback`,
    an empty window is None (the queue-estimate contract). None only
    when nothing was ever observed."""
    t = np.asarray(times, np.float64)
    v = np.asarray(values, np.float64)
    if t.size == 0:
        return None
    if window_s is None or now is None:
        return float(v.mean())
    past = t <= now
    in_win = past & (t >= now - window_s)
    if in_win.any():
        return float(v[in_win].mean())
    if not stale_fallback:
        return None
    if past.any():
        return float(v[past][np.argmax(t[past])])
    return float(v[np.argmin(t)])


def windowed_rate(times, window_s: float, now: float) -> Optional[float]:
    """Arrivals/second over the trailing window (None if no arrival
    landed in it). A run younger than the window divides by the elapsed
    time instead, so early estimates aren't biased low."""
    t = np.asarray(times, np.float64)
    n = int(((t >= now - window_s) & (t <= now)).sum())
    if n == 0:
        return None
    return n / max(min(window_s, now), 1e-9)


def windowed_mix(
    times, ids, n_keys: int, window_s: float, now: float
) -> Optional[np.ndarray]:
    """Share of the trailing window's observations per key id ->
    (n_keys,) weights summing to 1, or None when nothing (recognizable)
    was observed. Negative ids (unrecognized-context verdicts) are
    excluded: the bank serves them with the default plan, but their gate
    statistics belong to no fitted context."""
    t = np.asarray(times, np.float64)
    v = np.asarray(ids, np.int64)
    m = (t >= now - window_s) & (t <= now) & (v >= 0)
    if not m.any():
        return None
    counts = np.bincount(v[m], minlength=n_keys)
    return counts / counts.sum()


# ----------------------------------------------------- online re-scoring
def rescore_plan(
    plan,
    exit_logits_list,
    edge_times_s: Sequence[float],
    cloud_times_s: Sequence[float],
    payload_bytes: Sequence[int],
    uplink_bps: float,
    labels=None,
    final_logits=None,
    p_tar_grid: Optional[Sequence[float]] = None,
    min_accuracy: Optional[float] = None,
    exit_layer_indices: Optional[Sequence[int]] = None,
    arrival_rate_hz: Optional[float] = None,
    exit_stats: Optional[Sequence] = None,
    sample_weight=None,
    max_reliability_gap: Optional[float] = None,
    compression_levels: Optional[Sequence[int]] = None,
    final_correct_by_level: Optional[Dict[int, np.ndarray]] = None,
    branches: Optional[Sequence[int]] = None,
):
    """Re-select (deployed exit, effective p_tar, codec level) under
    CURRENT conditions.

    `branches` restricts the candidate table to the given physical
    branches (1-based, matching `exit_logits_list` order); None scores
    every branch. Pinning the deployed branch with `p_tar_grid=None`
    leaves the codec level as the only axis.

    Edgent-style adaptation: the plan's fitted per-exit calibrators are
    re-used as-is (no re-fitting); only the offload probability and the
    expected-latency objective are re-evaluated at the measured
    `uplink_bps`. With `labels` and `final_logits`, each candidate's
    end-to-end accuracy (on-device samples by the exit head, offloaded
    samples by the cloud main head) is computed and candidates below
    `min_accuracy` are rejected; if none qualify, the most accurate
    candidate wins regardless of latency.

    `arrival_rate_hz` (fleet-wide, for a SHARED uplink) adds an M/M/1-style
    busy-ratio correction: a candidate whose offloads would load the link
    at utilization rho sees its comm term scaled by 1/(1-rho), capped at
    100x past saturation -- without it, the open-loop objective happily
    picks configurations whose offload traffic exceeds link capacity.

    `exit_stats` skips the calibrate+softmax pass: a list of per-exit
    (confidence, prediction) arrays already computed with this plan's
    calibrators (they don't change between re-scores, so a periodic
    controller computes them once and passes them every tick).

    `sample_weight` (length-N, renormalized internally) weights the
    validation samples when computing each candidate's offload probability
    and accuracy. This is how a context-aware controller re-scores under
    input drift: concatenate per-context validation logits and weight each
    context's block by its estimated share of recent traffic, so the
    candidate table prices the traffic mix actually being served rather
    than the clean distribution (see `ControllerCore.sample_weight_for_mix`).

    With labels, each row also carries ``on_device_accuracy`` (accuracy of
    the exit head on the samples the candidate keeps on-device) and
    ``reliability_gap`` = |on_device_accuracy - p_tar| -- the candidate's
    estimated miscalibration under the (weighted) validation traffic.
    `max_reliability_gap` makes that a feasibility constraint alongside
    `min_accuracy`: candidates estimated to break the paper's reliability
    contract by more than the cap are rejected; if none survive, the
    accuracy-feasible row with the smallest gap wins (the contract
    degrades as little as possible).

    `compression_levels` adds the payload-codec axis: the candidate table
    becomes branch x p_tar x level, each row priced at that level's
    analytic wire bytes (comm term, M/M/1 utilization) and, with labels,
    at its measured accuracy delta -- offloaded samples score against
    `final_correct_by_level[level]` (cloud correctness after the payload
    round-trips the codec; computed here from `final_logits` via the
    `kernels.ref` oracle when not supplied pre-computed). None (the
    default) is exactly the legacy level-0-only table, and the level loop
    is innermost so legacy row order is preserved. The reliability gap is
    level-independent (the gate runs before the codec), so
    `max_reliability_gap` bounds every level equally.

    Returns (new_plan, table): new_plan carries the winning exit_index,
    p_tar, and compression_level; table lists every candidate as a dict,
    best first.
    """
    from repro.core.partition import expected_latency

    if plan.criterion != "confidence":
        raise ValueError(
            "rescore_plan moves the confidence target p_tar; an "
            f"{plan.criterion!r}-criterion plan has nothing to re-score"
        )
    if min_accuracy is not None and (labels is None or final_logits is None):
        raise ValueError(
            "min_accuracy needs labels and final_logits to evaluate "
            "candidate accuracy"
        )
    if max_reliability_gap is not None and labels is None:
        raise ValueError(
            "max_reliability_gap needs labels to estimate each candidate's "
            "on-device accuracy"
        )
    grid = [plan.p_tar] if p_tar_grid is None else list(p_tar_grid)
    levels = (
        (0,) if compression_levels is None
        else tuple(int(l) for l in compression_levels)
    )
    y = None if labels is None else np.asarray(labels)
    final_correct = None
    if final_logits is not None and y is not None:
        final_correct = np.argmax(np.asarray(final_logits), axis=-1) == y
    # per-level cloud correctness: level 0 is the untouched legacy array
    fc_by_level: Dict[int, Optional[np.ndarray]] = {0: final_correct}
    if final_correct_by_level is not None:
        for l, v in final_correct_by_level.items():
            fc_by_level.setdefault(int(l), None if v is None else np.asarray(v))
    for l in levels:
        if l in fc_by_level:
            continue
        if final_logits is not None and y is not None:
            from repro.kernels.ref import roundtrip_codec_ref

            fc_by_level[l] = (
                np.argmax(roundtrip_codec_ref(np.asarray(final_logits), l),
                          axis=-1) == y
            )
        else:
            fc_by_level[l] = None
    if any(l != 0 for l in levels):
        from repro.kernels.compress import scaled_payload_nbytes
    w = None
    if sample_weight is not None:
        w = np.asarray(sample_weight, np.float64)
        if w.ndim != 1 or np.any(w < 0) or w.sum() <= 0:
            raise ValueError("sample_weight must be 1-D, non-negative, sum > 0")
    branch_set = None
    if branches is not None:
        branch_set = {int(b) for b in branches}
        known = set(range(1, len(exit_logits_list) + 1))
        if not branch_set or not branch_set <= known:
            raise ValueError(
                f"branches {sorted(branch_set)} outside the fitted "
                f"branches {sorted(known)}"
            )
    table = []
    for i, z in enumerate(exit_logits_list):
        if branch_set is not None and (i + 1) not in branch_set:
            continue
        if exit_stats is not None:
            conf, pred = exit_stats[i]
        else:
            conf, pred = plan.gate_block(z, branch=i)
        conf, pred = np.asarray(conf), np.asarray(pred)
        exit_correct = None if y is None else pred == y
        for p in grid:
            on = conf >= p
            offload_prob = float(np.average(~on, weights=w))
            on_acc = gap = None
            if exit_correct is not None:
                w_on = None if w is None else w[on]
                if on.any() and (w_on is None or w_on.sum() > 0):
                    on_acc = float(np.average(exit_correct[on], weights=w_on))
                    gap = abs(on_acc - float(p))
            for lvl in levels:
                # level 0 keeps the caller's object so legacy pricing is
                # bit-identical; other levels use the analytic wire size
                pb = (
                    payload_bytes[i] if lvl == 0
                    else scaled_payload_nbytes(payload_bytes[i], lvl)
                )
                comm = pb * 8.0 / uplink_bps
                utilization = (
                    arrival_rate_hz * offload_prob * comm
                    if arrival_rate_hz is not None
                    else 0.0
                )
                wait_factor = 1.0 / max(1.0 - utilization, 1e-2)
                lat = expected_latency(
                    edge_times_s[i], cloud_times_s[i], pb,
                    offload_prob, uplink_bps, comm_wait_factor=wait_factor,
                )
                fc = fc_by_level.get(lvl)
                acc = None
                if exit_correct is not None and fc is not None:
                    acc = float(np.average(np.where(on, exit_correct, fc),
                                           weights=w))
                table.append(
                    dict(
                        exit_index=i,
                        p_tar=float(p),
                        compression_level=int(lvl),
                        offload_prob=offload_prob,
                        expected_latency_s=lat,
                        uplink_utilization=utilization,
                        uplink_nbytes=float(pb) * offload_prob,
                        accuracy=acc,
                        on_device_accuracy=on_acc,
                        reliability_gap=gap,
                    )
                )
    best = select_candidate(
        table, min_accuracy=min_accuracy,
        max_reliability_gap=max_reliability_gap,
    )
    table = sorted(table, key=lambda r: r["expected_latency_s"])
    if exit_layer_indices is not None:
        layer = exit_layer_indices[best["exit_index"]]
    elif best["exit_index"] == plan.exit_index:
        layer = plan.partition_layer
    else:  # exit moved and we don't know its layer: don't keep a stale one
        layer = None
    new_plan = (
        plan.with_partition(best["exit_index"], layer)
        .with_p_tar(best["p_tar"])
        .with_compression(best.get("compression_level", 0))
    )
    return new_plan, table


# ----------------------------------------------------------- selection rules
def row_feasible(
    row: dict,
    min_accuracy: Optional[float] = None,
    max_reliability_gap: Optional[float] = None,
) -> bool:
    """The shared feasibility test: the accuracy floor and (when capped)
    the estimated reliability-gap contract."""
    if min_accuracy is not None and not (
        row["accuracy"] is not None and row["accuracy"] >= min_accuracy
    ):
        return False
    if max_reliability_gap is not None:
        gap = row.get("reliability_gap")
        if gap is None:
            # an all-offload candidate keeps nothing on the device, so the
            # on-device contract is vacuously held; a gap unknown for any
            # other reason is not trusted
            if row.get("offload_prob") != 1.0:
                return False
        elif gap > max_reliability_gap:
            return False
    return True


def select_candidate(
    table: List[dict],
    min_accuracy: Optional[float] = None,
    max_reliability_gap: Optional[float] = None,
) -> dict:
    """Latency-greedy among feasible rows, degrading gracefully: no row
    under the gap cap -> the accuracy-feasible row with the smallest
    estimated gap; nothing meets the accuracy floor -> most accurate."""
    feasible = [
        r for r in table if row_feasible(r, min_accuracy, max_reliability_gap)
    ]
    if feasible:
        return min(feasible, key=lambda r: r["expected_latency_s"])
    if max_reliability_gap is not None:
        acc_ok = [
            r for r in table
            if row_feasible(r, min_accuracy)
            and r.get("reliability_gap") is not None
        ]
        if acc_ok:
            return min(
                acc_ok,
                key=lambda r: (r["reliability_gap"], r["expected_latency_s"]),
            )
    return max(table, key=lambda r: (r["accuracy"] or 0.0))


def _row_for(table: List[dict], plan) -> Optional[dict]:
    level = int(getattr(plan, "compression_level", 0))
    return next(
        (
            r for r in table
            if r["exit_index"] == plan.exit_index
            and r["p_tar"] == plan.p_tar
            and r.get("compression_level", 0) == level
        ),
        None,
    )


def hold_incumbent(
    table: List[dict],
    incumbent,
    candidate,
    hysteresis: float,
    min_accuracy: Optional[float] = None,
    max_reliability_gap: Optional[float] = None,
) -> bool:
    """True when the incumbent plan should be retained: it is still
    feasible under current conditions and the ADOPTED candidate's latency
    gain is below the hysteresis margin. An incumbent that itself
    violates the feasibility constraints is never retained."""
    cur = _row_for(table, incumbent)
    new = _row_for(table, candidate)
    return (
        cur is not None
        and row_feasible(cur, min_accuracy, max_reliability_gap)
        and new is not None
        and new["expected_latency_s"]
        > (1.0 - hysteresis) * cur["expected_latency_s"]
    )


def choose_with_concession(
    table: List[dict],
    contract_p_tar: float,
    distress_utilization: float,
    min_accuracy: Optional[float] = None,
    max_reliability_gap: Optional[float] = None,
    force_concession: bool = False,
) -> dict:
    """Distress-gated p_tar concession (the fleet's per-cell rule).

    1. If a feasible candidate at the CONTRACT p_tar keeps the uplink
       under the distress threshold, take the fastest such row (the
       branch is the only knob, as in the single-cell scenario).
    2. Otherwise the link cannot carry full-p_tar traffic: make the
       weakest reliability concession -- among stable feasible rows,
       the highest p_tar, fastest within it.
    3. No stable row at all: fastest feasible; no feasible row: most
       accurate (the `rescore_plan` degradation rule).

    `force_concession` is the QoS monitor's distress override: a cell
    whose declared SLO has TRIPPED stops holding the operator's contract
    p_tar (stage 1 is skipped) and takes the fastest stable feasible
    row -- the rescue configuration -- until the monitor clears it. The
    model-side feasibility caps (`min_accuracy`, `max_reliability_gap`)
    still bind; only the latency-vs-contract preference flips.
    """
    feasible = [
        r for r in table if row_feasible(r, min_accuracy, max_reliability_gap)
    ]
    if not force_concession:
        full = [
            r for r in feasible
            if r["p_tar"] == contract_p_tar
            and r["uplink_utilization"] < distress_utilization
        ]
        if full:
            return min(full, key=lambda r: r["expected_latency_s"])
    stable = [
        r for r in feasible if r["uplink_utilization"] < distress_utilization
    ]
    if stable:
        if force_concession:
            return min(stable, key=lambda r: r["expected_latency_s"])
        return min(stable, key=lambda r: (-r["p_tar"], r["expected_latency_s"]))
    if feasible:
        return min(feasible, key=lambda r: r["expected_latency_s"])
    return max(table, key=lambda r: (r["accuracy"] or 0.0))


# ----------------------------------------------------------- shared config
@dataclass
class ControlConfig:
    """Fields every controller shares; the serving / fleet configs extend
    this with their stack-specific knobs."""

    interval_s: float = 1.0  # re-score cadence (simulated seconds)
    window_s: float = 2.0  # trailing telemetry window
    p_tar_grid: Optional[Sequence[float]] = None  # None = keep the plan's
    branches: Optional[Sequence[int]] = None  # physical branches (1-based)
    # to score; None = every fitted branch. Pinning the branch (and
    # leaving p_tar_grid=None) isolates the codec axis: the controller
    # moves ONLY the payload wire format of a fixed split.
    min_accuracy: Optional[float] = None  # accuracy floor for candidates
    max_reliability_gap: Optional[float] = None  # estimated-gap cap
    hysteresis: float = 0.05  # min relative latency gain to switch
    utilization_aware: bool = True  # M/M/1 uplink correction from arrivals
    distress_utilization: float = 0.95  # uplink rho above which a cell may
    # concede p_tar (see `choose_with_concession`)
    compression_levels: Optional[Sequence[int]] = None  # payload codec
    # levels to score (None = level 0 only, the bytes-blind legacy table)


# ------------------------------------------------------- the controller core
class ControllerCore:
    """Validation blocks + cached gate statistics + the mix-weighted
    re-score -- everything a controller needs that is not policy.

    `exit_logits` is either ``{physical_branch: (N, C)}`` (context-blind:
    the single-cell controller's original form) or ``{context: {branch:
    (N, C)}}`` with matching per-context `final_logits`, which makes
    `rescore` CONTEXT-AWARE: per-context blocks are concatenated once,
    and a tick only supplies per-sample weights derived from an observed
    traffic mix (`sample_weight_for_mix`). `labels` is shared across
    contexts (the usual case: the same validation samples, distorted per
    context). A `PlanBank` contributes its default plan -- bandwidth-
    driven re-scoring and per-sample expert selection compose without
    touching each other's state.
    """

    def __init__(
        self,
        plan,
        profile,
        exit_logits: Dict,
        final_logits=None,
        labels: Optional[np.ndarray] = None,
        payload_nbytes=None,
        backend=None,
        compression_levels: Optional[Sequence[int]] = None,
    ):
        from repro.core.bank import PlanBank
        from repro.core.gatepath import get_gate_backend
        from repro.offload import latency as L

        if isinstance(plan, PlanBank):
            plan = plan.default_plan
        if plan.criterion != "confidence":
            raise ValueError(
                "the controller core re-scores the confidence target p_tar; "
                f"{plan.criterion!r}-criterion plans are not re-scorable"
            )
        self.plan = plan
        self.profile = profile
        self.backend = get_gate_backend(backend)

        # normalize to {context: {branch: logits}}; None key = context-blind
        if all(isinstance(k, str) for k in exit_logits):
            by_ctx = {k: exit_logits[k] for k in sorted(exit_logits)}
            if final_logits is not None and not isinstance(final_logits, dict):
                raise ValueError(
                    "per-context exit_logits need per-context final_logits"
                )
            final_by_ctx = final_logits
        else:
            by_ctx = {None: exit_logits}
            final_by_ctx = None if final_logits is None else {None: final_logits}
        self.ctx_keys: List[Optional[str]] = list(by_ctx)
        first = next(iter(by_ctx.values()))
        self.branches = sorted(first)
        if self.branches != list(range(1, len(self.branches) + 1)):
            raise ValueError(
                "exit_logits keys must be contiguous physical branches 1..K "
                "(branch k gates with plan.calibrators[k-1]); got "
                f"{self.branches}"
            )
        for ctx, per_branch in by_ctx.items():
            if sorted(per_branch) != self.branches:
                raise ValueError(f"context {ctx!r} covers different branches")

        self.labels = None if labels is None else np.asarray(labels)
        if payload_nbytes is None:
            from repro.models.convnet import payload_bytes

            payload_nbytes = payload_bytes
        self.payload_bytes = [payload_nbytes(b) for b in self.branches]
        self.edge_times_s = [L.edge_time(profile, b) for b in self.branches]
        self.cloud_times_s = [L.cloud_time(profile, b) for b in self.branches]

        # calibrated (conf, pred) never change between ticks: compute once
        # per (context, branch), concatenated in ctx_keys order so a tick
        # only supplies per-sample weights
        self._block_len = [len(next(iter(by_ctx[k].values()))) for k in self.ctx_keys]
        self.exit_logits_list = [
            np.concatenate([np.asarray(by_ctx[k][b]) for k in self.ctx_keys])
            for b in self.branches
        ]
        self._exit_stats = []
        for bi, b in enumerate(self.branches):
            stats = [
                self.backend.plan_gate_block(plan, by_ctx[k][b], branch=bi)
                for k in self.ctx_keys
            ]
            self._exit_stats.append(
                (np.concatenate([c for c, _ in stats]),
                 np.concatenate([p for _, p in stats]))
            )
        if self.labels is not None:
            self._labels_cat = np.concatenate(
                [self.labels for _ in self.ctx_keys]
            )
        else:
            self._labels_cat = None
        if final_by_ctx is not None:
            missing = set(self.ctx_keys) - set(final_by_ctx)
            if missing:
                raise ValueError(f"final_logits missing contexts {sorted(missing)}")
            self._final_cat = np.concatenate(
                [np.asarray(final_by_ctx[k]) for k in self.ctx_keys]
            )
        else:
            self._final_cat = None

        # payload-codec axis: measure each non-zero level's accuracy delta
        # ONCE at construction (cloud correctness after the concatenated
        # final logits round-trip the codec oracle) so a tick only prices it
        self.compression_levels = (
            (0,) if compression_levels is None
            else tuple(int(l) for l in compression_levels)
        )
        self._final_correct_by_level: Optional[Dict[int, np.ndarray]] = None
        nonzero = [l for l in self.compression_levels if l != 0]
        if nonzero and self._labels_cat is not None and self._final_cat is not None:
            from repro.kernels.ref import roundtrip_codec_ref

            self._final_correct_by_level = {
                l: np.argmax(
                    roundtrip_codec_ref(self._final_cat, l), axis=-1
                ) == self._labels_cat
                for l in nonzero
            }

    @property
    def context_aware(self) -> bool:
        return self.ctx_keys != [None]

    @property
    def has_labels(self) -> bool:
        return self._labels_cat is not None

    def sample_weight_for_mix(
        self, mix: Optional[Dict[str, float]]
    ) -> Optional[np.ndarray]:
        """Per-sample weights pricing an observed traffic mix ({context:
        share}); None (uniform over all contexts' samples) when the core
        is context-blind, the mix is empty, or no observed context
        matches a fitted block."""
        if mix is None or not self.context_aware:
            return None
        w_ctx = np.asarray([max(mix.get(k, 0.0), 0.0) for k in self.ctx_keys])
        if w_ctx.sum() <= 0:
            return None
        w_ctx = w_ctx / w_ctx.sum()
        return np.concatenate(
            [np.full(n, m / n) for n, m in zip(self._block_len, w_ctx)]
        )

    def rescore(
        self,
        plan,
        uplink_bps: float,
        edge_times_s: Optional[Sequence[float]] = None,
        arrival_rate_hz: Optional[float] = None,
        p_tar_grid: Optional[Sequence[float]] = None,
        min_accuracy: Optional[float] = None,
        max_reliability_gap: Optional[float] = None,
        sample_weight=None,
        compression_levels: Optional[Sequence[int]] = None,
        branches: Optional[Sequence[int]] = None,
    ) -> Tuple[Any, List[dict]]:
        """One candidate table under measured conditions; `plan` is the
        current deployment (same calibrators as at construction -- the
        cached exit statistics assume it). `compression_levels` defaults
        to the levels fixed at construction (whose accuracy deltas are
        pre-measured)."""
        levels = (
            self.compression_levels if compression_levels is None
            else tuple(int(l) for l in compression_levels)
        )
        return rescore_plan(
            plan,
            self.exit_logits_list,
            edge_times_s=self.edge_times_s if edge_times_s is None else edge_times_s,
            cloud_times_s=self.cloud_times_s,
            payload_bytes=self.payload_bytes,
            uplink_bps=uplink_bps,
            labels=self._labels_cat,
            final_logits=self._final_cat,
            p_tar_grid=p_tar_grid,
            min_accuracy=min_accuracy,
            max_reliability_gap=max_reliability_gap,
            arrival_rate_hz=arrival_rate_hz,
            exit_stats=self._exit_stats,
            sample_weight=sample_weight,
            compression_levels=levels,
            final_correct_by_level=self._final_correct_by_level,
            branches=branches,
        )
