"""Post-hoc calibration (paper Sec. IV-A, following Guo et al. 2017).

Two layers of API:

1. Fit primitives (`fit_temperature`, `fit_vector_scaling`,
   `calibrate_cascade`) -- pure JAX optimizers over validation logits.

2. The `Calibrator` protocol -- the deployable abstraction the rest of the
   system consumes. A calibrator turns a validation pass into a
   `CalibratorState` (a JAX pytree, so gating stays jit/vmap-compatible and
   the state can ride inside compiled serving steps) and maps raw logits to
   calibrated logits at inference time:

       state  = get_calibrator("temperature").fit(logits, labels)
       logits = apply_calibrator(state, logits)

   Implementations are looked up by name in a registry
   (`register_calibrator` / `get_calibrator`): ``temperature`` (the paper's
   method, Eq. 2), ``vector`` (per-class affine, beyond-paper), and
   ``identity`` (the conventional-DNN baseline, T=1). States serialize to
   plain dicts (`CalibratorState.to_dict`/`from_dict`) so an `OffloadPlan`
   can ship them as JSON.

Temperature Scaling fits a single scalar T per exit on validation logits by
minimizing NLL with frozen weights. The optimum is found by Newton's method
on dNLL/d(log T) with a golden-section fallback -- both pure JAX, both
deterministic. Per-exit cascade fits can weight samples by reachability
(`sequential=True`), matching the deployment-time conditional distribution.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


def nll(logits, labels, temperature, weights=None):
    """Mean negative log-likelihood of softmax(logits/T).

    weights: optional per-sample non-negative weights; None = uniform.
    """
    z = logits.astype(jnp.float32) / temperature
    logp = jax.nn.log_softmax(z, axis=-1)
    per_sample = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if weights is None:
        return jnp.mean(per_sample)
    w = weights.astype(jnp.float32)
    return jnp.sum(per_sample * w) / jnp.maximum(jnp.sum(w), 1e-9)


def fit_temperature(
    logits,
    labels,
    t_min: float = 0.05,
    t_max: float = 20.0,
    newton_steps: int = 30,
    weights=None,
) -> Tuple[float, dict]:
    """Fit T by NLL minimization over log-T (convex in practice).

    weights: optional per-sample weights (used by sequential cascade
    calibration to restrict the fit to samples that reach the exit without
    gathering/padding the index set). Returns (T, info). Pure JAX;
    jit-friendly.
    """
    logits = logits.astype(jnp.float32)

    def loss_logt(logt):
        return nll(logits, labels, jnp.exp(logt), weights=weights)

    g = jax.grad(loss_logt)
    h = jax.grad(g)

    def newton_step(logt, _):
        grad = g(logt)
        hess = h(logt)
        step = jnp.where(jnp.abs(hess) > 1e-8, grad / hess, jnp.sign(grad) * 0.1)
        step = jnp.clip(step, -1.0, 1.0)
        new = jnp.clip(logt - step, jnp.log(t_min), jnp.log(t_max))
        return new, jnp.abs(step)

    logt0 = jnp.zeros(())
    logt, steps = jax.lax.scan(newton_step, logt0, None, length=newton_steps)
    T = jnp.exp(logt)

    # golden-section fallback if Newton walked to the boundary
    def golden(lo, hi, iters=60):
        phi = 0.6180339887498949

        def body(carry, _):
            lo, hi = carry
            m1 = hi - phi * (hi - lo)
            m2 = lo + phi * (hi - lo)
            f1, f2 = loss_logt(m1), loss_logt(m2)
            lo = jnp.where(f1 < f2, lo, m1)
            hi = jnp.where(f1 < f2, m2, hi)
            return (lo, hi), None

        (lo, hi), _ = jax.lax.scan(body, (lo, hi), None, length=iters)
        return (lo + hi) / 2

    logt_g = golden(jnp.log(t_min), jnp.log(t_max))
    T_g = jnp.exp(logt_g)
    T_final = jnp.where(loss_logt(jnp.log(T)) <= loss_logt(logt_g), T, T_g)
    info = {
        "nll_before": nll(logits, labels, 1.0, weights=weights),
        "nll_after": nll(logits, labels, T_final, weights=weights),
        "converged_step": jnp.min(steps),
    }
    return T_final, info


def fit_vector_scaling(logits, labels, steps: int = 200, lr: float = 0.05):
    """Beyond-paper: per-class affine calibration p = softmax(w*z + b).

    Gradient descent on NLL; returns (w, b, info).
    """
    logits = logits.astype(jnp.float32)
    k = logits.shape[-1]

    def loss(wb):
        w, b = wb
        z = logits * w + b
        logp = jax.nn.log_softmax(z, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))

    wb = (jnp.ones((k,)), jnp.zeros((k,)))
    g = jax.grad(loss)

    def step(wb, _):
        grads = g(wb)
        wb = jax.tree.map(lambda p, gg: p - lr * gg, wb, grads)
        return wb, None

    wb, _ = jax.lax.scan(step, wb, None, length=steps)
    info = {"nll_before": loss((jnp.ones((k,)), jnp.zeros((k,)))), "nll_after": loss(wb)}
    return wb[0], wb[1], info


def calibrate_cascade(exit_logits_list, labels, sequential: bool = False, p_tar: float = 0.8):
    """Fit one temperature per exit.

    sequential=False (paper / Guo): each exit fit on ALL validation samples.
    sequential=True (beyond-paper): exit i is fit only on the samples that
    reach it under the already-calibrated earlier exits -- matching the
    deployment-time conditional distribution of the cascade. Reachability
    enters the fit as per-sample NLL weights (a padded gather would
    duplicate sample 0 into the index set and bias the fit).
    """
    temps = []
    reach = jnp.ones(labels.shape[0], bool)
    for logits in exit_logits_list:
        if sequential and not bool(jnp.all(reach)):
            T, _ = fit_temperature(logits, labels, weights=reach.astype(jnp.float32))
        else:
            T, _ = fit_temperature(logits, labels)
        temps.append(float(T))
        if sequential:
            from repro.core.exits import gate_statistics

            conf, _, _ = gate_statistics(logits, T)
            reach = reach & (conf < p_tar)
    return temps


# --------------------------------------------------------------------------
# Calibrator protocol: fit -> CalibratorState (pytree) -> apply
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclass
class CalibratorState:
    """The deployable output of a calibration pass for ONE exit.

    `kind` names the calibrator in the registry (static / aux data);
    `params` holds its arrays (pytree leaves), so a state can cross jit
    boundaries, be vmapped over, and ride inside compiled serving steps.
    """

    kind: str
    params: Dict[str, jnp.ndarray]

    def tree_flatten(self):
        keys = tuple(sorted(self.params))
        return tuple(self.params[k] for k in keys), (self.kind, keys)

    @classmethod
    def tree_unflatten(cls, aux, children):
        kind, keys = aux
        return cls(kind=kind, params=dict(zip(keys, children)))

    # -- serialization (JSON-safe plain dicts; float32 round-trips exactly
    #    through Python floats, so reloaded states gate bit-identically)
    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "params": {
                k: np.asarray(v, np.float32).tolist() for k, v in self.params.items()
            },
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CalibratorState":
        return cls(
            kind=d["kind"],
            params={k: jnp.asarray(v, jnp.float32) for k, v in d["params"].items()},
        )

    @property
    def temperature(self) -> Optional[float]:
        """Effective scalar temperature, or None if not expressible as one.

        'temperature' states report their fitted T, 'identity' reports 1.0;
        richer calibrators (vector scaling) return None -- consumers must
        go through apply_calibrator for those.
        """
        if self.kind == "temperature":
            return float(self.params["temperature"])
        if self.kind == "identity":
            return 1.0
        return None


@runtime_checkable
class Calibrator(Protocol):
    """A named calibration method: fit on validation logits, apply at serve.

    apply() must be pure JAX on the logits so gating stays jit/vmap-safe.
    """

    name: str

    def fit(self, logits, labels, **kwargs) -> CalibratorState: ...

    def apply(self, state: CalibratorState, logits) -> jnp.ndarray: ...


_CALIBRATORS: Dict[str, Calibrator] = {}


def register_calibrator(calibrator: Calibrator) -> Calibrator:
    """Register (an instance of) a Calibrator under its `name`."""
    _CALIBRATORS[calibrator.name] = calibrator
    return calibrator


def get_calibrator(name: str) -> Calibrator:
    try:
        return _CALIBRATORS[name]
    except KeyError:
        raise KeyError(
            f"unknown calibrator {name!r}; registered: {sorted(_CALIBRATORS)}"
        ) from None


def available_calibrators():
    return sorted(_CALIBRATORS)


def apply_calibrator(state: CalibratorState, logits) -> jnp.ndarray:
    """Dispatch `apply` through the registry on the state's kind."""
    return get_calibrator(state.kind).apply(state, logits)


class TemperatureScaling:
    """The paper's method (Guo et al. Eq. 2): z -> z / T."""

    name = "temperature"

    def fit(self, logits, labels, weights=None, **kwargs) -> CalibratorState:
        T, _ = fit_temperature(logits, labels, weights=weights, **kwargs)
        return CalibratorState(
            self.name, {"temperature": jnp.asarray(T, jnp.float32)}
        )

    def apply(self, state, logits):
        return logits.astype(jnp.float32) / state.params["temperature"]

    @staticmethod
    def from_temperature(t: float) -> CalibratorState:
        return CalibratorState(
            "temperature", {"temperature": jnp.asarray(t, jnp.float32)}
        )


class VectorScaling:
    """Beyond-paper per-class affine: z -> w * z + b."""

    name = "vector"

    def fit(self, logits, labels, **kwargs) -> CalibratorState:
        w, b, _ = fit_vector_scaling(logits, labels, **kwargs)
        return CalibratorState(self.name, {"w": w, "b": b})

    def apply(self, state, logits):
        return logits.astype(jnp.float32) * state.params["w"] + state.params["b"]


class Identity:
    """The conventional-DNN baseline: no calibration (T=1 everywhere)."""

    name = "identity"

    def fit(self, logits, labels, **kwargs) -> CalibratorState:
        return CalibratorState(self.name, {})

    def apply(self, state, logits):
        return logits


register_calibrator(TemperatureScaling())
register_calibrator(VectorScaling())
register_calibrator(Identity())
