"""Post-hoc calibration (paper Sec. IV-A, following Guo et al. 2017).

Temperature Scaling: a single scalar T per exit, fit on validation logits by
minimizing NLL with frozen weights (Eq. 2). The optimum is found by Newton's
method on dNLL/d(log T) with a golden-section fallback -- both pure JAX, both
deterministic.

Beyond-paper extensions included because they slot into the same interface:
  * vector scaling (per-class affine on logits),
  * per-exit temperature for cascades (fit each branch on the samples that
    *reach* it, matching deployment distribution -- Guo et al. fit on all).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def nll(logits, labels, temperature):
    """Mean negative log-likelihood of softmax(logits/T)."""
    z = logits.astype(jnp.float32) / temperature
    logp = jax.nn.log_softmax(z, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def fit_temperature(
    logits,
    labels,
    t_min: float = 0.05,
    t_max: float = 20.0,
    newton_steps: int = 30,
) -> Tuple[float, dict]:
    """Fit T by NLL minimization over log-T (convex in practice).

    Returns (T, info). Pure JAX; jit-friendly.
    """
    logits = logits.astype(jnp.float32)

    def loss_logt(logt):
        return nll(logits, labels, jnp.exp(logt))

    g = jax.grad(loss_logt)
    h = jax.grad(g)

    def newton_step(logt, _):
        grad = g(logt)
        hess = h(logt)
        step = jnp.where(jnp.abs(hess) > 1e-8, grad / hess, jnp.sign(grad) * 0.1)
        step = jnp.clip(step, -1.0, 1.0)
        new = jnp.clip(logt - step, jnp.log(t_min), jnp.log(t_max))
        return new, jnp.abs(step)

    logt0 = jnp.zeros(())
    logt, steps = jax.lax.scan(newton_step, logt0, None, length=newton_steps)
    T = jnp.exp(logt)

    # golden-section fallback if Newton walked to the boundary
    def golden(lo, hi, iters=60):
        phi = 0.6180339887498949

        def body(carry, _):
            lo, hi = carry
            m1 = hi - phi * (hi - lo)
            m2 = lo + phi * (hi - lo)
            f1, f2 = loss_logt(m1), loss_logt(m2)
            lo = jnp.where(f1 < f2, lo, m1)
            hi = jnp.where(f1 < f2, m2, hi)
            return (lo, hi), None

        (lo, hi), _ = jax.lax.scan(body, (lo, hi), None, length=iters)
        return (lo + hi) / 2

    logt_g = golden(jnp.log(t_min), jnp.log(t_max))
    T_g = jnp.exp(logt_g)
    T_final = jnp.where(loss_logt(jnp.log(T)) <= loss_logt(logt_g), T, T_g)
    info = {
        "nll_before": nll(logits, labels, 1.0),
        "nll_after": nll(logits, labels, T_final),
        "converged_step": jnp.min(steps),
    }
    return T_final, info


def fit_vector_scaling(logits, labels, steps: int = 200, lr: float = 0.05):
    """Beyond-paper: per-class affine calibration p = softmax(w*z + b).

    Gradient descent on NLL; returns (w, b, info).
    """
    logits = logits.astype(jnp.float32)
    k = logits.shape[-1]

    def loss(wb):
        w, b = wb
        z = logits * w + b
        logp = jax.nn.log_softmax(z, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))

    wb = (jnp.ones((k,)), jnp.zeros((k,)))
    g = jax.grad(loss)

    def step(wb, _):
        grads = g(wb)
        wb = jax.tree.map(lambda p, gg: p - lr * gg, wb, grads)
        return wb, None

    wb, _ = jax.lax.scan(step, wb, None, length=steps)
    info = {"nll_before": loss((jnp.ones((k,)), jnp.zeros((k,)))), "nll_after": loss(wb)}
    return wb[0], wb[1], info


def calibrate_cascade(exit_logits_list, labels, sequential: bool = False, p_tar: float = 0.8):
    """Fit one temperature per exit.

    sequential=False (paper / Guo): each exit fit on ALL validation samples.
    sequential=True (beyond-paper): exit i is fit only on the samples that
    reach it under the already-calibrated earlier exits -- matching the
    deployment-time conditional distribution of the cascade.
    """
    temps = []
    reach = jnp.ones(labels.shape[0], bool)
    for logits in exit_logits_list:
        if sequential:
            # fit on reached samples (mask via weighting: drop others)
            idx = jnp.nonzero(reach, size=labels.shape[0], fill_value=0)[0]
            T, _ = fit_temperature(logits[idx], labels[idx])
        else:
            T, _ = fit_temperature(logits, labels)
        temps.append(float(T))
        if sequential:
            from repro.core.exits import gate_statistics

            conf, _, _ = gate_statistics(logits, T)
            reach = reach & (conf < p_tar)
    return temps
