"""Reliability metrics (paper Secs. II, IV-B..IV-E).

  * ECE + reliability diagram (Guo et al. 2017) -- Fig. 3(a);
  * offloading probability / on-device classification probability -- Fig. 2;
  * on-device & overall accuracy vs p_tar -- Fig. 3(b,c);
  * inference outage probability (paper's new metric, Sec. IV-D) -- Fig. 4;
  * missed-deadline probability (paper's new metric, Sec. IV-E) -- Fig. 5/6
    (latency comes from repro.offload.latency).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exits import gate_statistics

PAPER_OUTAGE_BATCH = 512  # paper: "batches with 512 images each"


def ece(confidences, correct, n_bins: int = 15):
    """Expected Calibration Error with equal-width confidence bins."""
    confidences = np.asarray(confidences, np.float64)
    correct = np.asarray(correct, np.float64)
    bins = np.linspace(0.0, 1.0, n_bins + 1)
    e = 0.0
    n = len(confidences)
    for lo, hi in zip(bins[:-1], bins[1:]):
        m = (confidences > lo) & (confidences <= hi)
        if m.sum() == 0:
            continue
        e += (m.sum() / n) * abs(correct[m].mean() - confidences[m].mean())
    return float(e)


def reliability_diagram(confidences, correct, n_bins: int = 15):
    """Per-bin (mean confidence, accuracy, count) -- Fig. 3(a) data."""
    confidences = np.asarray(confidences, np.float64)
    correct = np.asarray(correct, np.float64)
    bins = np.linspace(0.0, 1.0, n_bins + 1)
    rows = []
    for lo, hi in zip(bins[:-1], bins[1:]):
        m = (confidences > lo) & (confidences <= hi)
        if m.sum() == 0:
            rows.append((0.5 * (lo + hi), np.nan, 0))
        else:
            rows.append((confidences[m].mean(), correct[m].mean(), int(m.sum())))
    return rows


def device_statistics(exit_logits, labels, p_tar, temperature=1.0):
    """Single-branch device-side stats for one p_tar (Figs. 2, 3a, 3b).

    Returns dict: on_device_prob, device_accuracy, mean_confidence.
    """
    conf, pred, _ = gate_statistics(exit_logits, temperature)
    mask = conf >= p_tar
    n_dev = jnp.sum(mask)
    correct = (pred == labels) & mask
    acc = jnp.where(n_dev > 0, jnp.sum(correct) / jnp.maximum(n_dev, 1), jnp.nan)
    mean_conf = jnp.where(
        n_dev > 0, jnp.sum(conf * mask) / jnp.maximum(n_dev, 1), jnp.nan
    )
    return {
        "on_device_prob": n_dev / labels.shape[0],
        "device_accuracy": acc,
        "mean_confidence": mean_conf,
    }


def overall_accuracy(exit_logits_list, final_logits, labels, p_tar, temperatures=None):
    """Cascade accuracy over ALL samples (device + cloud) -- Fig. 3(c)."""
    from repro.core.exits import cascade_gate

    out = cascade_gate(exit_logits_list, final_logits, p_tar, temperatures)
    return float(jnp.mean((out["prediction"] == labels).astype(jnp.float32)))


def inference_outage_probability(
    exit_logits,
    labels,
    p_tar,
    temperature=1.0,
    batch_size: int = PAPER_OUTAGE_BATCH,
    rng: np.random.Generator | None = None,
):
    """Paper Sec. IV-D: P(batch on-device accuracy < p_tar).

    The test set is divided into batches of `batch_size`; for each batch the
    average accuracy of the on-device-classified samples is compared to
    p_tar. Batches where no sample exits count as no outage (nothing was
    classified on-device, so no on-device accuracy shortfall occurred).
    """
    conf, pred, _ = gate_statistics(exit_logits, temperature)
    conf = np.asarray(conf)
    pred = np.asarray(pred)
    labels = np.asarray(labels)
    n = len(labels)
    idx = np.arange(n)
    if rng is not None:
        idx = rng.permutation(n)
    outages, batches = 0, 0
    for s in range(0, n - batch_size + 1, batch_size):
        b = idx[s : s + batch_size]
        m = conf[b] >= p_tar
        batches += 1
        if m.sum() == 0:
            continue
        acc = (pred[b][m] == labels[b][m]).mean()
        if acc < p_tar:
            outages += 1
    return outages / max(batches, 1)


def outage_probability_cascade(
    exit_logits_list,
    labels,
    p_tar,
    temperatures=None,
    batch_size: int = PAPER_OUTAGE_BATCH,
):
    """Multi-branch outage (Fig. 7): on-device = classified by ANY branch."""
    n_exits = len(exit_logits_list)
    if temperatures is None:
        temperatures = [1.0] * n_exits
    n = len(labels)
    served = np.zeros(n, bool)
    pred = np.zeros(n, np.int64)
    for logits, T in zip(exit_logits_list, temperatures):
        conf, p, _ = gate_statistics(logits, T)
        conf, p = np.asarray(conf), np.asarray(p)
        take = (~served) & (conf >= p_tar)
        pred[take] = p[take]
        served |= take
    labels = np.asarray(labels)
    outages, batches = 0, 0
    for s in range(0, n - batch_size + 1, batch_size):
        sl = slice(s, s + batch_size)
        m = served[sl]
        batches += 1
        if m.sum() == 0:
            continue
        acc = (pred[sl][m] == labels[sl][m]).mean()
        if acc < p_tar:
            outages += 1
    return outages / max(batches, 1)
