"""OffloadPlan: the single deployable artifact of a calibration pass.

The paper's pipeline produces three coupled decisions -- per-exit
calibration, the confidence gate, and the partition point. `OffloadPlan`
bundles all of them:

  * one `CalibratorState` per early exit (any registered `Calibrator`);
  * the gating criterion (max-softmax confidence or entropy) and `p_tar`;
  * the deployed exit / partition layer chosen by the partition optimizer.

A plan serializes to JSON (`to_json`/`from_json`, `save`/`load`); a
reloaded plan gates bit-identically, so the artifact fit in the lab is the
artifact deployed on the device. Consumed by `repro.offload.engine`,
`repro.offload.simulator`, `repro.core.partition`, and
`repro.core.exits.cascade_gate`.

`OffloadPolicy` / `make_policy` remain as thin deprecation shims over the
temperature-list API.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.calibration import (
    CalibratorState,
    TemperatureScaling,
    apply_calibrator,
    calibrate_cascade,
    get_calibrator,
)
from repro.core.exits import apply_gate

PLAN_FORMAT_VERSION = 1


@dataclass
class OffloadPlan:
    p_tar: float
    calibrators: List[CalibratorState]  # one per exit, shallowest first
    criterion: str = "confidence"  # confidence | entropy
    entropy_threshold: Optional[float] = None
    exit_index: int = 0  # deployed exit: which calibrator single-branch paths use
    partition_layer: Optional[int] = None  # model layer of the split, if chosen
    metadata: Dict[str, Any] = field(default_factory=dict)

    @property
    def num_exits(self) -> int:
        return len(self.calibrators)

    @property
    def temperatures(self) -> List[float]:
        """Legacy temperature-list view (1.0 for states with no scalar T)."""
        return [s.temperature if s.temperature is not None else 1.0
                for s in self.calibrators]

    # ------------------------------------------------------------- gating
    def _state_for(self, branch: Optional[int]) -> CalibratorState:
        branch = self.exit_index if branch is None else branch
        if not 0 <= branch < self.num_exits:
            raise ValueError(
                f"exit {branch} has no calibrator state "
                f"(plan covers {self.num_exits} exit(s))"
            )
        return self.calibrators[branch]

    def calibrated_logits(self, exit_logits, branch: Optional[int] = None):
        return apply_calibrator(self._state_for(branch), exit_logits)

    def gate(self, exit_logits, branch: Optional[int] = None, use_kernel: bool = False):
        """Gate one exit's logits under this plan's calibrator + criterion.

        Fast path: when the branch's calibration is expressible as a scalar
        temperature (temperature scaling or identity), the raw logits and T
        go straight to apply_gate, which can route through the fused Pallas
        exit-gate kernel (use_kernel=True) without materializing calibrated
        logits. Richer calibrators apply first and gate at T=1. The kind
        dispatch is static (pytree aux data), so this traces under jit/vmap
        even when the CalibratorState arrives as a traced argument.
        """
        state = self._state_for(branch)
        if state.kind in ("temperature", "identity"):
            t = state.params["temperature"] if state.kind == "temperature" else 1.0
            return apply_gate(
                exit_logits,
                self.p_tar,
                temperature=t,
                criterion=self.criterion,
                entropy_threshold=self.entropy_threshold,
                use_kernel=use_kernel,
            )
        return apply_gate(
            apply_calibrator(state, exit_logits),
            self.p_tar,
            temperature=1.0,
            criterion=self.criterion,
            entropy_threshold=self.entropy_threshold,
            use_kernel=use_kernel,
        )

    def gate_block(self, exit_logits, branch: Optional[int] = None):
        """Batched gate statistics for a whole logit block -> numpy
        (confidence float64, prediction int64) of shape (N,).

        Same math as `gate` (via `gate_statistics` on this branch's
        calibrated logits, so fleet-scale consumers agree bit-for-bit with
        the per-request serving cores), returned as host arrays ready for
        vectorized thresholding `conf >= p_tar` over the whole block.
        """
        from repro.core.exits import gate_statistics

        conf, pred, _ = gate_statistics(self.calibrated_logits(exit_logits, branch))
        return np.asarray(conf, np.float64), np.asarray(pred, np.int64)

    def _copy(self, **overrides) -> "OffloadPlan":
        """Fresh OffloadPlan (never the OffloadPolicy shim subclass, whose
        __init__ takes a temperature list) with mutable fields copied --
        the single place plan fields are threaded through, so new fields
        survive with_partition/with_p_tar automatically."""
        kw = dict(
            p_tar=self.p_tar,
            calibrators=list(self.calibrators),
            criterion=self.criterion,
            entropy_threshold=self.entropy_threshold,
            exit_index=self.exit_index,
            partition_layer=self.partition_layer,
            metadata=dict(self.metadata),
        )
        kw.update(overrides)
        return OffloadPlan(**kw)

    def with_partition(self, exit_index: int, partition_layer: int) -> "OffloadPlan":
        """New plan with the chosen partition point recorded."""
        return self._copy(exit_index=exit_index, partition_layer=partition_layer)

    def with_p_tar(self, p_tar: float) -> "OffloadPlan":
        """New plan with a different effective reliability target -- the
        calibrators are untouched, so the online controller can move the
        gate without re-fitting."""
        return self._copy(p_tar=float(p_tar))

    # ------------------------------------------------------ serialization
    def to_dict(self) -> dict:
        return {
            "version": PLAN_FORMAT_VERSION,
            "p_tar": float(self.p_tar),
            "calibrators": [s.to_dict() for s in self.calibrators],
            "criterion": self.criterion,
            "entropy_threshold": (
                None if self.entropy_threshold is None else float(self.entropy_threshold)
            ),
            "exit_index": int(self.exit_index),
            "partition_layer": (
                None if self.partition_layer is None else int(self.partition_layer)
            ),
            "metadata": self.metadata,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "OffloadPlan":
        version = d.get("version", PLAN_FORMAT_VERSION)
        if version > PLAN_FORMAT_VERSION:
            raise ValueError(f"plan format v{version} is newer than supported "
                             f"v{PLAN_FORMAT_VERSION}")
        return cls(
            p_tar=d["p_tar"],
            calibrators=[CalibratorState.from_dict(s) for s in d["calibrators"]],
            criterion=d.get("criterion", "confidence"),
            entropy_threshold=d.get("entropy_threshold"),
            exit_index=d.get("exit_index", 0),
            partition_layer=d.get("partition_layer"),
            metadata=d.get("metadata", {}),
        )

    def to_json(self, **kwargs) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, **kwargs)

    @classmethod
    def from_json(cls, s: str) -> "OffloadPlan":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2))

    @classmethod
    def load(cls, path: str) -> "OffloadPlan":
        with open(path) as f:
            return cls.from_json(f.read())


def make_plan(
    exit_logits_list,
    labels,
    p_tar: float,
    method: str = "temperature",
    calibrated: bool = True,
    sequential: bool = False,
    criterion: str = "confidence",
    entropy_threshold: Optional[float] = None,
    exit_index: int = 0,
    metadata: Optional[Dict[str, Any]] = None,
) -> OffloadPlan:
    """Build a deployable plan from a validation pass.

    calibrated=False reproduces the paper's 'conventional DNN' baseline
    (identity calibration, T=1 everywhere); otherwise `method` picks the
    registered calibrator fit per exit. sequential=True (temperature only)
    fits exit i on the samples that reach it in the cascade.
    """
    if not calibrated:
        method = "identity"
    cal = get_calibrator(method)
    if method == "temperature":
        temps = calibrate_cascade(
            exit_logits_list, labels, sequential=sequential, p_tar=p_tar
        )
        states = [TemperatureScaling.from_temperature(t) for t in temps]
    else:
        states = [cal.fit(z, labels) for z in exit_logits_list]
    return OffloadPlan(
        p_tar=p_tar,
        calibrators=states,
        criterion=criterion,
        entropy_threshold=entropy_threshold,
        exit_index=exit_index,
        metadata=metadata or {},
    )


# ----------------------------------------------------- online re-scoring
def rescore_plan(
    plan: OffloadPlan,
    exit_logits_list,
    edge_times_s: Sequence[float],
    cloud_times_s: Sequence[float],
    payload_bytes: Sequence[int],
    uplink_bps: float,
    labels=None,
    final_logits=None,
    p_tar_grid: Optional[Sequence[float]] = None,
    min_accuracy: Optional[float] = None,
    exit_layer_indices: Optional[Sequence[int]] = None,
    arrival_rate_hz: Optional[float] = None,
    exit_stats: Optional[Sequence] = None,
    sample_weight=None,
):
    """Re-select (deployed exit, effective p_tar) under CURRENT conditions.

    Edgent-style adaptation: the plan's fitted per-exit calibrators are
    re-used as-is (no re-fitting); only the offload probability and the
    expected-latency objective are re-evaluated at the measured
    `uplink_bps`. With `labels` and `final_logits`, each candidate's
    end-to-end accuracy (on-device samples by the exit head, offloaded
    samples by the cloud main head) is computed and candidates below
    `min_accuracy` are rejected; if none qualify, the most accurate
    candidate wins regardless of latency.

    `arrival_rate_hz` (fleet-wide, for a SHARED uplink) adds an M/M/1-style
    busy-ratio correction: a candidate whose offloads would load the link
    at utilization rho sees its comm term scaled by 1/(1-rho), capped at
    100x past saturation -- without it, the open-loop objective happily
    picks configurations whose offload traffic exceeds link capacity.

    `exit_stats` skips the calibrate+softmax pass: a list of per-exit
    (confidence, prediction) arrays already computed with this plan's
    calibrators (they don't change between re-scores, so a periodic
    controller computes them once and passes them every tick).

    `sample_weight` (length-N, renormalized internally) weights the
    validation samples when computing each candidate's offload probability
    and accuracy. This is how a context-aware controller re-scores under
    input drift: concatenate per-context validation logits and weight each
    context's block by its estimated share of recent traffic, so the
    candidate table prices the traffic mix actually being served rather
    than the clean distribution (see `repro.fleet.controller`).

    Returns (new_plan, table): new_plan carries the winning exit_index and
    p_tar; table lists every candidate as a dict, best first.
    """
    import numpy as np

    from repro.core.exits import gate_statistics
    from repro.core.partition import expected_latency

    if plan.criterion != "confidence":
        raise ValueError(
            "rescore_plan moves the confidence target p_tar; an "
            f"{plan.criterion!r}-criterion plan has nothing to re-score"
        )
    if min_accuracy is not None and (labels is None or final_logits is None):
        raise ValueError(
            "min_accuracy needs labels and final_logits to evaluate "
            "candidate accuracy"
        )
    grid = [plan.p_tar] if p_tar_grid is None else list(p_tar_grid)
    y = None if labels is None else np.asarray(labels)
    final_correct = None
    if final_logits is not None and y is not None:
        final_correct = np.argmax(np.asarray(final_logits), axis=-1) == y
    w = None
    if sample_weight is not None:
        w = np.asarray(sample_weight, np.float64)
        if w.ndim != 1 or np.any(w < 0) or w.sum() <= 0:
            raise ValueError("sample_weight must be 1-D, non-negative, sum > 0")
    table = []
    for i, z in enumerate(exit_logits_list):
        if exit_stats is not None:
            conf, pred = exit_stats[i]
        else:
            conf, pred, _ = gate_statistics(plan.calibrated_logits(z, i))
        conf, pred = np.asarray(conf), np.asarray(pred)
        exit_correct = None if y is None else pred == y
        for p in grid:
            on = conf >= p
            offload_prob = float(np.average(~on, weights=w))
            comm = payload_bytes[i] * 8.0 / uplink_bps
            utilization = (
                arrival_rate_hz * offload_prob * comm
                if arrival_rate_hz is not None
                else 0.0
            )
            wait_factor = 1.0 / max(1.0 - utilization, 1e-2)
            lat = expected_latency(
                edge_times_s[i], cloud_times_s[i], payload_bytes[i],
                offload_prob, uplink_bps, comm_wait_factor=wait_factor,
            )
            acc = None
            if exit_correct is not None and final_correct is not None:
                acc = float(np.average(np.where(on, exit_correct, final_correct),
                                       weights=w))
            table.append(
                dict(
                    exit_index=i,
                    p_tar=float(p),
                    offload_prob=offload_prob,
                    expected_latency_s=lat,
                    uplink_utilization=utilization,
                    accuracy=acc,
                )
            )
    feasible = [
        r for r in table
        if min_accuracy is None
        or (r["accuracy"] is not None and r["accuracy"] >= min_accuracy)
    ]
    if feasible:
        best = min(feasible, key=lambda r: r["expected_latency_s"])
    else:  # nothing meets the floor: degrade gracefully to most accurate
        best = max(table, key=lambda r: (r["accuracy"] or 0.0))
    table = sorted(table, key=lambda r: r["expected_latency_s"])
    if exit_layer_indices is not None:
        layer = exit_layer_indices[best["exit_index"]]
    elif best["exit_index"] == plan.exit_index:
        layer = plan.partition_layer
    else:  # exit moved and we don't know its layer: don't keep a stale one
        layer = None
    new_plan = plan.with_partition(best["exit_index"], layer).with_p_tar(best["p_tar"])
    return new_plan, table


# ------------------------------------------------------- deprecation shims
class OffloadPolicy(OffloadPlan):
    """Deprecated temperature-list constructor; use OffloadPlan/make_plan."""

    def __init__(
        self,
        p_tar: float,
        temperatures: Sequence[float],
        criterion: str = "confidence",
        entropy_threshold: Optional[float] = None,
        exit_index: int = 0,
        calibrated: bool = True,
    ):
        OffloadPlan.__init__(
            self,
            p_tar=p_tar,
            calibrators=[TemperatureScaling.from_temperature(t) for t in temperatures],
            criterion=criterion,
            entropy_threshold=entropy_threshold,
            exit_index=exit_index,
            metadata={"calibrated": calibrated},
        )
        self.calibrated = calibrated


def make_policy(
    exit_logits_list,
    labels,
    p_tar: float,
    calibrated: bool = True,
    sequential: bool = False,
) -> OffloadPlan:
    """Deprecated: thin wrapper over make_plan (kept for the seed API)."""
    return make_plan(
        exit_logits_list,
        labels,
        p_tar=p_tar,
        calibrated=calibrated,
        sequential=sequential,
    )
