"""OffloadPlan: the single deployable artifact of a calibration pass.

The paper's pipeline produces three coupled decisions -- per-exit
calibration, the confidence gate, and the partition point. `OffloadPlan`
bundles all of them:

  * one `CalibratorState` per early exit (any registered `Calibrator`);
  * the gating criterion (max-softmax confidence or entropy) and `p_tar`;
  * the deployed exit / partition layer chosen by the partition optimizer.

A plan serializes to JSON (`to_json`/`from_json`, `save`/`load`); a
reloaded plan gates bit-identically, so the artifact fit in the lab is the
artifact deployed on the device. Consumed by `repro.offload.engine`,
`repro.offload.simulator`, `repro.core.partition`, and
`repro.core.exits.cascade_gate`.

`OffloadPolicy` / `make_policy` remain as thin deprecation shims over the
temperature-list API.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.calibration import (
    CalibratorState,
    TemperatureScaling,
    apply_calibrator,
    calibrate_cascade,
    get_calibrator,
)
from repro.core.exits import apply_gate

PLAN_FORMAT_VERSION = 1


@dataclass
class OffloadPlan:
    p_tar: float
    calibrators: List[CalibratorState]  # one per exit, shallowest first
    criterion: str = "confidence"  # confidence | entropy
    entropy_threshold: Optional[float] = None
    exit_index: int = 0  # deployed exit: which calibrator single-branch paths use
    partition_layer: Optional[int] = None  # model layer of the split, if chosen
    compression_level: int = 0  # payload codec level (0 = raw float32)
    metadata: Dict[str, Any] = field(default_factory=dict)

    @property
    def num_exits(self) -> int:
        return len(self.calibrators)

    @property
    def temperatures(self) -> List[float]:
        """Legacy temperature-list view (1.0 for states with no scalar T)."""
        return [s.temperature if s.temperature is not None else 1.0
                for s in self.calibrators]

    # ------------------------------------------------------------- gating
    def _state_for(self, branch: Optional[int]) -> CalibratorState:
        branch = self.exit_index if branch is None else branch
        if not 0 <= branch < self.num_exits:
            raise ValueError(
                f"exit {branch} has no calibrator state "
                f"(plan covers {self.num_exits} exit(s))"
            )
        return self.calibrators[branch]

    def calibrated_logits(self, exit_logits, branch: Optional[int] = None):
        return apply_calibrator(self._state_for(branch), exit_logits)

    def gate(self, exit_logits, branch: Optional[int] = None, use_kernel: bool = False):
        """Gate one exit's logits under this plan's calibrator + criterion.

        Fast path: when the branch's calibration is expressible as a scalar
        temperature (temperature scaling or identity), the raw logits and T
        go straight to apply_gate, which can route through the fused Pallas
        exit-gate kernel (use_kernel=True) without materializing calibrated
        logits. Richer calibrators apply first and gate at T=1. The kind
        dispatch is static (pytree aux data), so this traces under jit/vmap
        even when the CalibratorState arrives as a traced argument.
        """
        state = self._state_for(branch)
        if state.kind in ("temperature", "identity"):
            t = state.params["temperature"] if state.kind == "temperature" else 1.0
            return apply_gate(
                exit_logits,
                self.p_tar,
                temperature=t,
                criterion=self.criterion,
                entropy_threshold=self.entropy_threshold,
                use_kernel=use_kernel,
            )
        return apply_gate(
            apply_calibrator(state, exit_logits),
            self.p_tar,
            temperature=1.0,
            criterion=self.criterion,
            entropy_threshold=self.entropy_threshold,
            use_kernel=use_kernel,
        )

    def gate_block(self, exit_logits, branch: Optional[int] = None,
                   backend=None):
        """Batched gate statistics for a whole logit block -> numpy
        (confidence float64, prediction int64) of shape (N,).

        Same math as `gate` (via `gate_statistics` on this branch's
        calibrated logits, so fleet-scale consumers agree bit-for-bit with
        the per-request serving cores), returned as host arrays ready for
        vectorized thresholding `conf >= p_tar` over the whole block.
        `backend` selects the execution path (`repro.core.gatepath`): None
        -> the default host numpy backend; ``"jax"`` -> one jitted call.
        """
        from repro.core.gatepath import get_gate_backend

        return get_gate_backend(backend).plan_gate_block(
            self, exit_logits, branch=branch
        )

    def _copy(self, **overrides) -> "OffloadPlan":
        """Fresh OffloadPlan (never the OffloadPolicy shim subclass, whose
        __init__ takes a temperature list) with mutable fields copied --
        the single place plan fields are threaded through, so new fields
        survive with_partition/with_p_tar automatically."""
        kw = dict(
            p_tar=self.p_tar,
            calibrators=list(self.calibrators),
            criterion=self.criterion,
            entropy_threshold=self.entropy_threshold,
            exit_index=self.exit_index,
            partition_layer=self.partition_layer,
            compression_level=self.compression_level,
            metadata=dict(self.metadata),
        )
        kw.update(overrides)
        return OffloadPlan(**kw)

    def with_partition(self, exit_index: int, partition_layer: int) -> "OffloadPlan":
        """New plan with the chosen partition point recorded."""
        return self._copy(exit_index=exit_index, partition_layer=partition_layer)

    def with_p_tar(self, p_tar: float) -> "OffloadPlan":
        """New plan with a different effective reliability target -- the
        calibrators are untouched, so the online controller can move the
        gate without re-fitting."""
        return self._copy(p_tar=float(p_tar))

    def with_compression(self, level: int) -> "OffloadPlan":
        """New plan with a different payload codec level (see
        `repro.kernels.compress.LEVELS`; 0 ships the raw float32
        activation, the paper's pricing)."""
        return self._copy(compression_level=int(level))

    # ------------------------------------------------------ serialization
    def to_dict(self) -> dict:
        return {
            "version": PLAN_FORMAT_VERSION,
            "p_tar": float(self.p_tar),
            "calibrators": [s.to_dict() for s in self.calibrators],
            "criterion": self.criterion,
            "entropy_threshold": (
                None if self.entropy_threshold is None else float(self.entropy_threshold)
            ),
            "exit_index": int(self.exit_index),
            "partition_layer": (
                None if self.partition_layer is None else int(self.partition_layer)
            ),
            "compression_level": int(self.compression_level),
            "metadata": self.metadata,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "OffloadPlan":
        version = d.get("version", PLAN_FORMAT_VERSION)
        if version > PLAN_FORMAT_VERSION:
            raise ValueError(f"plan format v{version} is newer than supported "
                             f"v{PLAN_FORMAT_VERSION}")
        return cls(
            p_tar=d["p_tar"],
            calibrators=[CalibratorState.from_dict(s) for s in d["calibrators"]],
            criterion=d.get("criterion", "confidence"),
            entropy_threshold=d.get("entropy_threshold"),
            exit_index=d.get("exit_index", 0),
            partition_layer=d.get("partition_layer"),
            compression_level=d.get("compression_level", 0),
            metadata=d.get("metadata", {}),
        )

    def to_json(self, **kwargs) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, **kwargs)

    @classmethod
    def from_json(cls, s: str) -> "OffloadPlan":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2))

    @classmethod
    def load(cls, path: str) -> "OffloadPlan":
        with open(path) as f:
            return cls.from_json(f.read())


def make_plan(
    exit_logits_list,
    labels,
    p_tar: float,
    method: str = "temperature",
    calibrated: bool = True,
    sequential: bool = False,
    criterion: str = "confidence",
    entropy_threshold: Optional[float] = None,
    exit_index: int = 0,
    metadata: Optional[Dict[str, Any]] = None,
) -> OffloadPlan:
    """Build a deployable plan from a validation pass.

    calibrated=False reproduces the paper's 'conventional DNN' baseline
    (identity calibration, T=1 everywhere); otherwise `method` picks the
    registered calibrator fit per exit. sequential=True (temperature only)
    fits exit i on the samples that reach it in the cascade.
    """
    if not calibrated:
        method = "identity"
    cal = get_calibrator(method)
    if method == "temperature":
        temps = calibrate_cascade(
            exit_logits_list, labels, sequential=sequential, p_tar=p_tar
        )
        states = [TemperatureScaling.from_temperature(t) for t in temps]
    else:
        states = [cal.fit(z, labels) for z in exit_logits_list]
    return OffloadPlan(
        p_tar=p_tar,
        calibrators=states,
        criterion=criterion,
        entropy_threshold=entropy_threshold,
        exit_index=exit_index,
        metadata=metadata or {},
    )


# ----------------------------------------------------- online re-scoring
# rescore_plan moved to `repro.core.control` (the shared controller core);
# this import keeps the long-standing `repro.core.policy.rescore_plan`
# call sites working. It sits below the class definitions so the control
# module can be imported first without a cycle.
from repro.core.control import rescore_plan  # noqa: E402


# ------------------------------------------------------- deprecation shims
class OffloadPolicy(OffloadPlan):
    """Deprecated temperature-list constructor; use OffloadPlan/make_plan."""

    def __init__(
        self,
        p_tar: float,
        temperatures: Sequence[float],
        criterion: str = "confidence",
        entropy_threshold: Optional[float] = None,
        exit_index: int = 0,
        calibrated: bool = True,
    ):
        OffloadPlan.__init__(
            self,
            p_tar=p_tar,
            calibrators=[TemperatureScaling.from_temperature(t) for t in temperatures],
            criterion=criterion,
            entropy_threshold=entropy_threshold,
            exit_index=exit_index,
            metadata={"calibrated": calibrated},
        )
        self.calibrated = calibrated


def make_policy(
    exit_logits_list,
    labels,
    p_tar: float,
    calibrated: bool = True,
    sequential: bool = False,
) -> OffloadPlan:
    """Deprecated: thin wrapper over make_plan (kept for the seed API)."""
    return make_plan(
        exit_logits_list,
        labels,
        p_tar=p_tar,
        calibrated=calibrated,
        sequential=sequential,
    )
