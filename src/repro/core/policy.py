"""Offloading policy: the deployable decision object.

Bundles everything the edge runtime needs to make the paper's decision:
which exit(s) to consult, the calibrated temperature(s), the confidence
criterion, and the target p_tar. Produced by `make_policy` from a
calibration pass; consumed by repro.offload.engine and the simulator.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.calibration import calibrate_cascade
from repro.core.exits import apply_gate


@dataclass
class OffloadPolicy:
    p_tar: float
    temperatures: List[float]  # one per exit; 1.0 = uncalibrated
    criterion: str = "confidence"  # confidence | entropy
    entropy_threshold: Optional[float] = None
    exit_index: int = 0  # which exit the single-branch paths use
    calibrated: bool = True

    def gate(self, exit_logits, branch: int = 0, use_kernel: bool = False):
        return apply_gate(
            exit_logits,
            self.p_tar,
            temperature=self.temperatures[branch],
            criterion=self.criterion,
            entropy_threshold=self.entropy_threshold,
            use_kernel=use_kernel,
        )


def make_policy(
    exit_logits_list,
    labels,
    p_tar: float,
    calibrated: bool = True,
    sequential: bool = False,
) -> OffloadPolicy:
    """Build a policy from validation logits.

    calibrated=False reproduces the paper's 'conventional DNN' baseline
    (T=1 everywhere); calibrated=True runs Temperature Scaling per exit.
    """
    n = len(exit_logits_list)
    if calibrated:
        temps = calibrate_cascade(
            exit_logits_list, labels, sequential=sequential, p_tar=p_tar
        )
    else:
        temps = [1.0] * n
    return OffloadPolicy(p_tar=p_tar, temperatures=temps, calibrated=calibrated)
