"""Expert calibration banks: one OffloadPlan per input-distortion context.

The paper fits one set of branch temperatures on clean validation data.
Pacheco et al. (2108.09343) show that gate breaks under blur/noise: the
side branch stays confident while its accuracy collapses, so the single
global calibrator silently misses `p_tar`. The fix is a bank of *expert*
plans -- one `OffloadPlan` fit per distortion context -- plus a cheap
edge-side estimator that recognizes the current context from input
statistics and picks the matching expert.

Two pieces, both JSON-serializable so the whole bank ships as one artifact:

* `DistortionEstimator` -- nearest-centroid classifier over the per-image
  statistics of `repro.data.distortion.input_features` (Laplacian variance
  + pixel moments + total variation). Features are z-scored with the
  fit-pool moments; no DNN, no gradient, ~10 flops per feature at serve
  time. It is domain-agnostic: any (N, F) feature matrix works.

* `PlanBank` -- {context key: OffloadPlan} with a designated default
  context (the fallback for unrecognized conditions), an optional embedded
  estimator, and the same versioned JSON round-trip contract as
  `OffloadPlan` (a reloaded bank gates bit-identically per context).

`fit_bank` builds both from per-context validation logits in one call.
Consumed by `repro.serving.drift.ContextualLogitsCore` (serving under
input drift) and `benchmarks/run.py` (the distortion bench).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import OffloadPlan, make_plan

BANK_FORMAT_VERSION = 1

#: The estimator's verdict when the input matches no fitted context: never a
#: real context key, and `PlanBank.plan_for` resolves it to the default plan.
UNKNOWN_CONTEXT = "__unknown__"


# ---------------------------------------------------- distortion estimator
@dataclass
class DistortionEstimator:
    """Nearest-centroid context classifier over cheap input statistics.

    Fit: pool every context's features, z-score with the pooled mean/std,
    store one normalized centroid per context. Predict: normalize, return
    the context whose centroid is nearest in L2 -- per batch (`predict`,
    the serving path: one decision per microbatch of inputs) or per sample
    (`predict_per_sample` / `predict_ids`, what the drift simulators
    precompute).

    Unknown verdict (estimator robustness under inputs the bank was never
    fit for, e.g. composed distortions like noise+blur): with
    ``unknown_distance`` set, an input whose nearest-centroid distance
    exceeds it is off-manifold; with ``unknown_margin`` set, an input whose
    two nearest centroids are closer than the margin is ambiguous between
    experts. Either way the verdict is `UNKNOWN_CONTEXT`, which a `PlanBank`
    resolves to its DEFAULT plan -- falling back to the broadest calibrator
    instead of gating with the nearest *wrong* expert. Distances live in the
    z-scored feature space; batch-mean distances (`predict`) concentrate
    much tighter than per-sample ones (`predict_per_sample`), so thresholds
    are calibrated for whichever path consumes them. Both default to None
    (verdicts never unknown, the pre-existing behavior).
    """

    contexts: List[str]
    centroids: np.ndarray  # (K, F), z-scored feature space
    norm_mean: np.ndarray  # (F,)
    norm_std: np.ndarray  # (F,)
    feature_names: Optional[Tuple[str, ...]] = None
    unknown_distance: Optional[float] = None  # d1 above this -> unknown
    unknown_margin: Optional[float] = None  # d2 - d1 below this -> unknown

    @classmethod
    def fit(
        cls,
        features_by_context: Dict[str, np.ndarray],
        feature_names: Optional[Sequence[str]] = None,
        unknown_distance: Optional[float] = None,
        unknown_margin: Optional[float] = None,
    ) -> "DistortionEstimator":
        if not features_by_context:
            raise ValueError("need at least one context to fit")
        keys = sorted(features_by_context)
        feats = {k: np.asarray(features_by_context[k], np.float64) for k in keys}
        pool = np.concatenate([feats[k] for k in keys], axis=0)
        mean = pool.mean(axis=0)
        std = np.maximum(pool.std(axis=0), 1e-9)
        centroids = np.stack(
            [((feats[k] - mean) / std).mean(axis=0) for k in keys]
        )
        return cls(
            contexts=list(keys),
            centroids=centroids,
            norm_mean=mean,
            norm_std=std,
            feature_names=None if feature_names is None else tuple(feature_names),
            unknown_distance=unknown_distance,
            unknown_margin=unknown_margin,
        )

    def _distances(self, features: np.ndarray) -> np.ndarray:
        f = np.asarray(features, np.float64)
        if f.ndim == 1:
            f = f[None, :]
        z = (f - self.norm_mean) / self.norm_std
        return np.linalg.norm(z[:, None, :] - self.centroids[None, :, :], axis=-1)

    def _ids_from_distances(self, d: np.ndarray) -> np.ndarray:
        """Nearest-centroid index per row, -1 where the unknown verdict
        fires (distance cap exceeded, or nearest-vs-second margin too thin
        to trust with fewer than two contexts the margin rule is moot)."""
        idx = np.argmin(d, axis=1).astype(np.int64)
        if self.unknown_distance is not None or self.unknown_margin is not None:
            part = np.sort(d, axis=1)
            unknown = np.zeros(len(d), bool)
            if self.unknown_distance is not None:
                unknown |= part[:, 0] > self.unknown_distance
            if self.unknown_margin is not None and d.shape[1] > 1:
                unknown |= (part[:, 1] - part[:, 0]) < self.unknown_margin
            idx[unknown] = -1
        return idx

    def predict(self, features: np.ndarray) -> str:
        """One context for a whole batch: classify the batch-mean feature
        vector (the per-batch selection rule of the serving path)."""
        f = np.asarray(features, np.float64)
        batch_mean = f if f.ndim == 1 else f.mean(axis=0)
        i = int(self._ids_from_distances(self._distances(batch_mean))[0])
        return UNKNOWN_CONTEXT if i < 0 else self.contexts[i]

    def predict_ids(self, features: np.ndarray) -> np.ndarray:
        """Vectorized per-sample verdicts as indices into `contexts`
        (-1 = unknown) -- the batched path the fleet simulator consumes."""
        return self._ids_from_distances(self._distances(features))

    def predict_per_sample(self, features: np.ndarray) -> List[str]:
        return [
            UNKNOWN_CONTEXT if i < 0 else self.contexts[i]
            for i in self.predict_ids(features)
        ]

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {
            "contexts": list(self.contexts),
            "centroids": self.centroids.tolist(),
            "norm_mean": self.norm_mean.tolist(),
            "norm_std": self.norm_std.tolist(),
            "feature_names": (
                None if self.feature_names is None else list(self.feature_names)
            ),
            "unknown_distance": (
                None if self.unknown_distance is None else float(self.unknown_distance)
            ),
            "unknown_margin": (
                None if self.unknown_margin is None else float(self.unknown_margin)
            ),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "DistortionEstimator":
        names = d.get("feature_names")
        return cls(
            contexts=list(d["contexts"]),
            centroids=np.asarray(d["centroids"], np.float64),
            norm_mean=np.asarray(d["norm_mean"], np.float64),
            norm_std=np.asarray(d["norm_std"], np.float64),
            feature_names=None if names is None else tuple(names),
            unknown_distance=d.get("unknown_distance"),
            unknown_margin=d.get("unknown_margin"),
        )


# --------------------------------------------------------------- plan bank
@dataclass
class PlanBank:
    """{context key: expert OffloadPlan} + fallback + optional estimator.

    The bank is the drifting-conditions analogue of a single plan: the lab
    fits one expert per expected input regime, serializes the whole bank,
    and the edge device picks `plan_for(estimated context)` per batch.
    Context keys are free-form strings; `repro.data.distortion` uses
    `DistortionSpec.key` (``"gaussian_noise@3"``, ``"clean"``).
    """

    plans: Dict[str, OffloadPlan]
    default_context: str
    estimator: Optional[DistortionEstimator] = None
    metadata: Dict[str, Any] = field(default_factory=dict)
    #: Monotonic deployment version (`repro.orchestration.rollout` bumps it
    #: per candidate): which bank GENERATION this is, as opposed to
    #: `schema_version`, which says how the JSON is laid out. Old files
    #: without the field load as generation 0.
    bank_version: int = 0

    def __post_init__(self):
        if not self.plans:
            raise ValueError("PlanBank needs at least one plan")
        if self.default_context not in self.plans:
            raise ValueError(
                f"default context {self.default_context!r} has no plan "
                f"(bank covers {self.contexts})"
            )
        if self.estimator is not None:
            unknown = set(self.estimator.contexts) - set(self.plans)
            if unknown:
                raise ValueError(
                    f"estimator may predict contexts with no expert plan: "
                    f"{sorted(unknown)}"
                )

    @property
    def contexts(self) -> List[str]:
        return sorted(self.plans)

    @property
    def default_plan(self) -> OffloadPlan:
        return self.plans[self.default_context]

    @property
    def compression_level(self) -> int:
        """Codec level of the DEFAULT plan -- what the serving layers
        price uplink payloads at (experts share the wire format, only
        their calibrators differ)."""
        return int(getattr(self.default_plan, "compression_level", 0))

    def with_compression(self, level: int) -> "PlanBank":
        """New bank with every expert's payload codec set to `level`
        (see `OffloadPlan.with_compression`): distortion-driven expert
        selection and the wire format compose without touching each
        other's state."""
        return replace(
            self,
            plans={c: p.with_compression(level)
                   for c, p in self.plans.items()},
        )

    def plan_for(self, context: Optional[str]) -> OffloadPlan:
        """The expert for `context`, or the default plan for unknown/None
        contexts (an edge device must never be left without a gate)."""
        if context is None:
            return self.default_plan
        return self.plans.get(context, self.default_plan)

    def select(self, features: np.ndarray) -> Tuple[str, OffloadPlan]:
        """Estimate the context of an input batch's features and return
        (context, expert plan) -- the per-batch edge-side decision. An
        `UNKNOWN_CONTEXT` verdict (estimator's distance/margin rule fired)
        resolves to the default plan, never to the nearest wrong expert."""
        if self.estimator is None:
            raise ValueError("this bank has no embedded estimator")
        ctx = self.estimator.predict(features)
        return ctx, self.plan_for(ctx)

    def gate_block(
        self,
        exit_logits: np.ndarray,
        features: Optional[np.ndarray] = None,
        branch: Optional[int] = None,
        expert_ids: Optional[np.ndarray] = None,
        backend=None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched per-sample expert gating over a whole logit block.

        -> (confidence, prediction, expert_ids): each sample's confidence
        and argmax under the calibrator of ITS expert plan, where experts
        come from `expert_ids` (indices into ``self.contexts``, -1 =
        unknown -> default plan) or, if omitted, from the embedded
        estimator on `features`. `backend` selects the execution path
        (`repro.core.gatepath`): the default numpy backend makes one
        `OffloadPlan.gate_block` call per DISTINCT expert in the block;
        the ``"jax"`` backend gathers per-sample expert temperatures and
        evaluates the whole block in one jitted call.
        """
        from repro.core.gatepath import get_gate_backend

        z = np.asarray(exit_logits)
        if expert_ids is None:
            if features is None:
                raise ValueError("need features or expert_ids to pick experts")
            if self.estimator is None:
                raise ValueError("this bank has no embedded estimator")
            expert_ids = self.estimator.predict_ids(features)
        expert_ids = np.asarray(expert_ids, np.int64)
        if expert_ids.shape[0] != z.shape[0]:
            raise ValueError(
                f"expert_ids covers {expert_ids.shape[0]} samples but the "
                f"logit block has {z.shape[0]}"
            )
        conf, pred = get_gate_backend(backend).bank_gate_block(
            self, z, expert_ids, branch=branch
        )
        return conf, pred, expert_ids

    def bumped(self, bank_version: Optional[int] = None) -> "PlanBank":
        """A copy at the next (or the given) deployment version -- what a
        rollout manager registers as the candidate generation. Plans and
        estimator are shared, not copied: a version bump is bookkeeping."""
        v = self.bank_version + 1 if bank_version is None else int(bank_version)
        if v <= self.bank_version:
            raise ValueError(
                f"bank_version must increase (have {self.bank_version}, "
                f"got {v})"
            )
        return PlanBank(
            plans=self.plans,
            default_context=self.default_context,
            estimator=self.estimator,
            metadata=dict(self.metadata),
            bank_version=v,
        )

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {
            # "version" is the legacy spelling of the schema version; both
            # keys are written so pre-orchestration readers keep loading
            # new files (the schema only ever ADDED optional fields)
            "version": BANK_FORMAT_VERSION,
            "schema_version": BANK_FORMAT_VERSION,
            "bank_version": int(self.bank_version),
            "default_context": self.default_context,
            "plans": {k: p.to_dict() for k, p in self.plans.items()},
            "estimator": None if self.estimator is None else self.estimator.to_dict(),
            "metadata": self.metadata,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PlanBank":
        # "version" is the legacy spelling of schema_version; a file
        # declaring a too-new layout under EITHER key is refused
        declared = [d[k] for k in ("schema_version", "version") if k in d]
        version = max(declared) if declared else BANK_FORMAT_VERSION
        if version > BANK_FORMAT_VERSION:
            raise ValueError(
                f"bank format v{version} is newer than supported "
                f"v{BANK_FORMAT_VERSION}"
            )
        est = d.get("estimator")
        return cls(
            plans={k: OffloadPlan.from_dict(p) for k, p in d["plans"].items()},
            default_context=d["default_context"],
            estimator=None if est is None else DistortionEstimator.from_dict(est),
            metadata=d.get("metadata", {}),
            bank_version=int(d.get("bank_version", 0)),
        )

    def to_json(self, **kwargs) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, **kwargs)

    @classmethod
    def from_json(cls, s: str) -> "PlanBank":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2))

    @classmethod
    def load(cls, path: str) -> "PlanBank":
        with open(path) as f:
            return cls.from_json(f.read())


def fit_bank(
    exit_logits_by_context: Dict[str, Sequence],
    labels,
    p_tar: float,
    default_context: str = "clean",
    features_by_context: Optional[Dict[str, np.ndarray]] = None,
    labels_by_context: Optional[Dict[str, Any]] = None,
    metadata: Optional[Dict[str, Any]] = None,
    estimator_kwargs: Optional[Dict[str, Any]] = None,
    **make_plan_kwargs,
) -> PlanBank:
    """Fit one expert OffloadPlan per context + (optionally) the estimator.

    exit_logits_by_context: {context: [exit1_logits, exit2_logits, ...]}
    from a validation pass over that context's distorted inputs. `labels`
    is shared across contexts (the usual case: the SAME validation images
    distorted per context); `labels_by_context` overrides per context.
    `features_by_context` ({context: (N, F)} from `input_features` on the
    distorted validation images) additionally fits the embedded
    `DistortionEstimator`; `estimator_kwargs` forwards its extra fit
    options (e.g. ``unknown_distance`` / ``unknown_margin``). Extra kwargs
    go to `make_plan` (method, criterion, sequential, ...).
    """
    if default_context not in exit_logits_by_context:
        raise ValueError(
            f"default context {default_context!r} not among fitted contexts "
            f"{sorted(exit_logits_by_context)}"
        )
    from repro.core.exits import gate_statistics
    from repro.core.metrics import ece as _ece

    plans = {}
    fit_ece: Dict[str, Dict[str, float]] = {}
    for ctx in sorted(exit_logits_by_context):
        y = labels if labels_by_context is None else labels_by_context[ctx]
        plans[ctx] = make_plan(
            exit_logits_by_context[ctx], y, p_tar=p_tar, **make_plan_kwargs
        )
        # fit-time calibration health, frozen into the artifact: the val
        # ECE each expert shipped with, per branch. The deployed-side
        # drift report (repro.obs.calibration_report) diffs the windowed
        # serving ECE against these to flag regimes that drifted.
        yv = np.asarray(y)
        per_branch: Dict[str, float] = {}
        for bi, z in enumerate(exit_logits_by_context[ctx]):
            conf, pred, _ = gate_statistics(
                plans[ctx].calibrated_logits(z, bi)
            )
            per_branch[str(bi + 1)] = float(
                _ece(np.asarray(conf, np.float64),
                     (np.asarray(pred) == yv).astype(np.float64))
            )
        fit_ece[ctx] = per_branch
    estimator = None
    if features_by_context is not None:
        missing = set(features_by_context) - set(plans)
        if missing:
            raise ValueError(
                f"features provided for contexts with no logits: {sorted(missing)}"
            )
        from repro.data.distortion import FEATURE_NAMES

        names = FEATURE_NAMES if all(
            np.asarray(f).shape[-1] == len(FEATURE_NAMES)
            for f in features_by_context.values()
        ) else None
        estimator = DistortionEstimator.fit(
            features_by_context, feature_names=names, **(estimator_kwargs or {})
        )
    meta = dict(metadata or {})
    meta.setdefault("fit_ece", fit_ece)
    return PlanBank(
        plans=plans,
        default_context=default_context,
        estimator=estimator,
        metadata=meta,
    )
