"""Calibration drift report: deployed reliability vs fit-time promises.

``python -m repro.obs.calibration_report`` reads the reliability-sketch
artifacts a run emitted (`benchmarks/run.py --emit-obs` writes
``OBS_*_calibration.json`` next to the BENCH files), renders one
reliability diagram per context regime, and -- when the deployed
`PlanBank` artifact is given -- diffs each regime's DEPLOYED windowed
ECE against the fit-time validation ECE frozen into the bank's
``metadata["fit_ece"]`` by `repro.core.bank.fit_bank`. A regime whose
deployed ECE exceeds its fit-time ECE by more than ``--drift-cap`` is
flagged: the expert no longer keeps the calibration promise it shipped
with (input drift, a poisoned candidate, a stale calibrator).

Multiple ``--sketch`` files merge exactly (the sketch is a sum), so one
report can span the serving and fleet stacks. An optional every-request
trace cross-checks the sketch: the ECE recomputed from the raw gate
records must match the merged sketch to round-off.

Output: a human-readable report on stdout; ``--out`` additionally
writes the full report as JSON (the CI artifact the poisoned-canary
assertion reads).
"""
from __future__ import annotations

import argparse
import json
import math
from typing import Dict, List, Optional

from .calibration import (
    GLOBAL_CONTEXT,
    ReliabilitySketch,
    block_coverage,
    block_ece,
    block_reliability,
    merge_sketches,
)

_BAR = 24  # diagram bar width (characters)


def _fit_lookup(fit_ece: Dict, default_context: Optional[str], ctx: str,
                branch: int) -> Optional[float]:
    """Fit-time val ECE for (context, branch), if the bank recorded one.
    The non-contextual serving stack keys everything by
    `GLOBAL_CONTEXT`; that resolves to the bank's default context (the
    plan a context-free deployment actually gates with)."""
    key = ctx
    if key not in fit_ece and ctx == GLOBAL_CONTEXT:
        key = default_context
    per_branch = fit_ece.get(key)
    if per_branch is None:
        return None
    v = per_branch.get(str(branch))
    return None if v is None else float(v)


def build_report(sketch: ReliabilitySketch,
                 bank_meta: Optional[dict] = None,
                 trace_records: Optional[list] = None,
                 drift_cap: float = 0.05) -> dict:
    """The report as plain data; `main` renders + serializes it."""
    fit_ece = {} if bank_meta is None else bank_meta.get("fit_ece", {})
    default_context = None if bank_meta is None else bank_meta.get(
        "default_context")
    regimes: Dict[str, dict] = {}
    flags: List[str] = []
    for ctx in sketch.contexts():
        blk = sketch.merged_block(context=ctx)
        count = float(blk[0].sum())
        if count <= 0:
            continue
        branches = sorted(
            {b for _, k, b in sketch.keys() if k == ctx},
            key=lambda b: -float(sketch.merged_block(context=ctx,
                                                     branch=b)[0].sum()),
        )
        branch = branches[0]
        deployed = block_ece(blk)
        fit = _fit_lookup(fit_ece, default_context, ctx, branch)
        drift = None if fit is None else deployed - fit
        drifted = drift is not None and drift > drift_cap
        regimes[ctx] = {
            "count": int(count),
            "branch": int(branch),
            "ece": deployed,
            "coverage": block_coverage(blk),
            "bins": block_reliability(blk),
            "fit_ece": fit,
            "drift": drift,
            "drifted": drifted,
        }
        if drifted:
            flags.append(
                f"regime {ctx!r} drifted: deployed ECE {deployed:.4f} vs "
                f"fit-time {fit:.4f} (+{drift:.4f} > cap {drift_cap:.4f})"
            )
    report = {
        "n_bins": sketch.n_bins,
        "drift_cap": float(drift_cap),
        "cells": {
            str(c): {
                "ece": sketch.ece(cell=c),
                "brier": sketch.brier(cell=c),
                "gated": sketch.gated_count(c),
                "ungated": sketch.ungated_count(c),
            }
            for c in sketch.cells()
        },
        "regimes": regimes,
        "global": {"ece": sketch.ece(), "coverage": sketch.coverage()},
        "flags": flags,
        "flagged": bool(flags),
    }
    if trace_records is not None:
        conf, cor = [], []
        for r in trace_records:
            g = r.get("gate")
            if g and g.get("correct") is not None:
                conf.append(float(g["confidence"]))
                cor.append(float(g["correct"]))
        report["trace"] = {"gate_records": len(conf)}
        if conf:
            import numpy as np

            from repro.core.metrics import ece as _ece

            t_ece = float(_ece(np.asarray(conf), np.asarray(cor)))
            report["trace"]["ece"] = t_ece
            report["trace"]["matches_sketch"] = (
                len(conf) == sum(sketch.gated_count(c)
                                 for c in sketch.cells())
                and abs(t_ece - sketch.ece()) <= 1e-9
            )
    return report


def _render(report: dict) -> str:
    out: List[str] = []
    g = report["global"]
    out.append(
        f"calibration report: global ECE {g['ece']:.4f}, "
        f"coverage {g['coverage']:.4f}" if not math.isnan(g["ece"])
        else "calibration report: empty sketch"
    )
    for ctx, reg in sorted(report["regimes"].items()):
        head = (f"\nregime {ctx!r} (branch {reg['branch']}, "
                f"n={reg['count']}): ECE {reg['ece']:.4f}")
        if reg["fit_ece"] is not None:
            head += (f", fit {reg['fit_ece']:.4f}, "
                     f"drift {reg['drift']:+.4f}")
            head += "  ** DRIFTED **" if reg["drifted"] else "  ok"
        out.append(head)
        for b in reg["bins"]:
            bar = "#" * max(1, round(b["accuracy"] * _BAR))
            out.append(
                f"  ({b['lo']:.2f},{b['hi']:.2f}]  conf {b['mean_conf']:.3f}"
                f"  acc {b['accuracy']:.3f}  {bar:<{_BAR}}"
                f" n={b['count']:<6d} resid {b['residual']:+.3f}"
            )
    if "trace" in report:
        t = report["trace"]
        out.append(f"\ntrace cross-check: {t['gate_records']} gate records"
                   + ("" if "ece" not in t else
                      f", ECE {t['ece']:.4f}, "
                      + ("matches sketch" if t["matches_sketch"]
                         else "DOES NOT match sketch")))
    out.append("")
    if report["flags"]:
        out.append("FLAGS:")
        out.extend(f"  - {f}" for f in report["flags"])
    else:
        out.append("no drifted regimes")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.calibration_report",
        description="Reliability diagrams per regime + fit-vs-deployed "
                    "ECE drift flags from sketch artifacts.",
    )
    ap.add_argument("--sketch", nargs="+", required=True,
                    help="reliability-sketch JSON artifact(s); several merge")
    ap.add_argument("--bank", default=None,
                    help="deployed PlanBank JSON (for fit-time ECE diffs)")
    ap.add_argument("--trace", default=None,
                    help="every-request trace JSONL (sketch cross-check)")
    ap.add_argument("--drift-cap", type=float, default=0.05,
                    help="flag a regime when deployed - fit ECE exceeds this")
    ap.add_argument("--out", default=None, help="write the report JSON here")
    args = ap.parse_args(argv)

    sketch = merge_sketches(ReliabilitySketch.load(p) for p in args.sketch)
    bank_meta = None
    if args.bank is not None:
        with open(args.bank) as f:
            d = json.load(f)
        bank_meta = dict(d.get("metadata", {}))
        bank_meta.setdefault("default_context", d.get("default_context"))
    trace = None
    if args.trace is not None:
        from . import read_jsonl

        trace = read_jsonl(args.trace)
    report = build_report(sketch, bank_meta=bank_meta, trace_records=trace,
                          drift_cap=args.drift_cap)
    print(_render(report))
    if args.out is not None:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
    return 1 if report["flagged"] else 0


if __name__ == "__main__":  # pragma: no cover - CLI entry
    raise SystemExit(main())
