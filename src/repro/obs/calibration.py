"""Streaming calibration-health sketches: mergeable reliability bins.

The paper's core failure mode -- miscalibrated confidence silently
corrupting offload decisions -- is invisible to the coarse
``|on-device acc - p_tar|`` gap until accuracy has already been lost.
The real diagnostic is the reliability diagram (`repro.core.metrics`),
which until this module existed only as an offline helper over full
logit arrays. `ReliabilitySketch` makes it STREAMING: a fixed-size
per-(cell, context, branch) bin sketch that every serving stack can
update online (per request in `ServingRuntime`, columnarly per window
in the host `FleetSimulator`, and inside the jitted window program of
`CompiledFleetSimulator` via a ``segment_sum`` over (cell x context x
bin) ids) and that merges EXACTLY -- elementwise addition -- so
per-cell sketches roll up to fleet regimes without touching raw
samples.

Binning reproduces `repro.core.metrics.ece` bit-for-bit: ``B`` equal
bins over (0, 1], each left-open/right-closed, assigned by
``searchsorted(edges, conf, side='left') - 1`` on the SAME float64
edges on every backend (binary search is exact, so host numpy and the
jitted path agree bin-for-bin). Confidences <= 0 fall outside every
ece bin but still count toward its denominator; they land in a
dedicated overflow slot (column ``B``) so totals stay conserved.

Each (cell, context, branch) key holds a ``(7, B+1)`` float64 block:

    row 0  count            gated requests in the bin
    row 1  correct          edge-prediction correctness sum
    row 2  conf_sum         sum of gate confidences
    row 3  conf_sq_sum      sum of squared confidences (Brier)
    row 4  conf_correct_sum sum of conf * correct (Brier cross term)
    row 5  on_count         requests the gate kept on-device
    row 6  on_correct       on-device requests that were correct

plus a per-cell ``ungated`` counter for requests that never saw a gate
(backhaul routing during an outage) so that a sketch's total equals
the `fleet_requests_total` counter -- an invariant `repro.obs.check`
cross-examines. Derived gauges (windowed ECE, coverage = on-device
precision, Brier score, per-bin conf-vs-acc residual) are pure
functions of a block, shared with the live QoS estimator.
"""
from __future__ import annotations

import bisect
import json
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

N_BINS = 15
_ROWS = 7
#: context key used by single-context serving stacks (matches
#: `repro.core.gatepath.GateTable.STATIC_CONTEXT`)
GLOBAL_CONTEXT = "__all__"


def bin_edges(n_bins: int = N_BINS) -> np.ndarray:
    """The float64 bin edges every backend must share. Identical values
    feed `np.searchsorted` on the host and `jnp.searchsorted` in the
    compiled window program, so bin assignment is exact on both."""
    return np.linspace(0.0, 1.0, n_bins + 1)


def bin_index(conf: np.ndarray, n_bins: int = N_BINS) -> np.ndarray:
    """Bin ids for `conf`: bin b covers (edges[b], edges[b+1]] exactly as
    `core.metrics.ece` masks it; conf <= 0 maps to the overflow slot
    `n_bins` (counted in totals, excluded from every ECE bin)."""
    idx = np.searchsorted(bin_edges(n_bins), conf, side="left") - 1
    return np.where(idx < 0, n_bins, idx).astype(np.int64)


def bin_block(
    conf: np.ndarray,
    correct: np.ndarray,
    on: np.ndarray,
    n_bins: int = N_BINS,
) -> np.ndarray:
    """Accumulate raw gate outcomes into one ``(7, n_bins+1)`` block --
    the shared binning core for sketch updates and the live windowed
    QoS estimate."""
    conf = np.asarray(conf, np.float64)
    correct = np.asarray(correct, np.float64)
    on = np.asarray(on, np.float64)
    idx = bin_index(conf, n_bins)
    block = np.empty((_ROWS, n_bins + 1), np.float64)
    for r, w in enumerate((
        np.ones_like(conf), correct, conf, conf * conf, conf * correct,
        on, on * correct,
    )):
        block[r] = np.bincount(idx, weights=w, minlength=n_bins + 1)
    return block


def block_ece(block: np.ndarray, total: Optional[float] = None) -> float:
    """Expected calibration error of a block: sum_b (n_b/N) |acc_b -
    mean_conf_b| over the real bins, N = all gated requests (overflow
    slot included in the denominator, exactly like `core.metrics.ece`).
    NaN when the block is empty."""
    n_bins = block.shape[1] - 1
    n_b = block[0, :n_bins]
    n = float(block[0].sum()) if total is None else float(total)
    if n <= 0:
        return float("nan")
    m = n_b > 0
    acc = block[1, :n_bins][m] / n_b[m]
    conf = block[2, :n_bins][m] / n_b[m]
    return float(np.sum(n_b[m] / n * np.abs(acc - conf)))


def block_coverage(block: np.ndarray) -> float:
    """Fraction of on-device exits (confidence cleared p_tar) that were
    correct -- the precision the gate promised >= p_tar. NaN when
    nothing stayed on-device."""
    on = float(block[5].sum())
    return float(block[6].sum() / on) if on > 0 else float("nan")


def block_brier(block: np.ndarray) -> float:
    """Mean squared error of confidence vs correctness, from the three
    accumulated moments. NaN on an empty block."""
    n = float(block[0].sum())
    if n <= 0:
        return float("nan")
    return float(
        (block[3].sum() - 2.0 * block[4].sum() + block[1].sum()) / n
    )


def block_reliability(block: np.ndarray) -> List[dict]:
    """Per-bin reliability rows for non-empty bins: mean confidence,
    accuracy, count, and the signed conf-vs-acc residual (positive =
    overconfident). The overflow slot is skipped (no defined bin)."""
    n_bins = block.shape[1] - 1
    rows = []
    edges = bin_edges(n_bins)
    for b in range(n_bins):
        n = block[0, b]
        if n <= 0:
            continue
        conf = block[2, b] / n
        acc = block[1, b] / n
        rows.append({
            "bin": b,
            "lo": float(edges[b]),
            "hi": float(edges[b + 1]),
            "count": int(n),
            "mean_conf": float(conf),
            "accuracy": float(acc),
            "residual": float(conf - acc),
        })
    return rows


Key = Tuple[int, str, int]  # (cell, context, branch)


class ReliabilitySketch:
    """Mergeable windowed reliability-bin sketch keyed by
    (cell, context, branch). All updates are pure accumulation, so
    ``merge`` (elementwise add) is exact and order-independent --
    per-cell sketches built by different backends roll up identically.
    """

    def __init__(self, n_bins: int = N_BINS):
        if n_bins < 1:
            raise ValueError("n_bins must be >= 1")
        self.n_bins = int(n_bins)
        self._blocks: Dict[Key, np.ndarray] = {}
        self._ungated: Dict[int, int] = {}
        # plain-float copy of the shared edges: `bisect` over these is
        # the same binary search as `np.searchsorted(side="left")` on
        # the identical float64 values, minus the per-call array setup
        self._edges: List[float] = [float(e) for e in bin_edges(self.n_bins)]

    # ------------------------------------------------------------ updates
    def update(
        self,
        cell: int,
        context: str,
        branch: int,
        conf: np.ndarray,
        correct: np.ndarray,
        on: np.ndarray,
    ) -> None:
        """Accumulate a batch of gate outcomes for one key. `conf` are
        gate confidences, `correct` the EDGE prediction's correctness
        (0/1 -- captured at gate time, before any cloud answer patches
        it), `on` whether the gate kept the request on-device."""
        block = bin_block(conf, correct, on, self.n_bins)
        key = (int(cell), str(context), int(branch))
        have = self._blocks.get(key)
        if have is None:
            self._blocks[key] = block
        else:
            have += block

    def update_one(self, cell: int, context: str, branch: int,
                   conf: float, correct: float, on: bool) -> None:
        """Scalar fast path for a single gate outcome -- the event-driven
        serving runtime records one request at a time, where routing
        through `bin_block` would pay seven one-element bincounts per
        request. Bin assignment and the per-bin additions are identical
        to `update`, so the resulting block is bit-for-bit the same."""
        c = float(conf)
        idx = bisect.bisect_left(self._edges, c) - 1
        if idx < 0 or idx >= self.n_bins:
            idx = self.n_bins
        key = (int(cell), str(context), int(branch))
        block = self._blocks.get(key)
        if block is None:
            block = np.zeros((_ROWS, self.n_bins + 1), np.float64)
            self._blocks[key] = block
        k = float(correct)
        o = 1.0 if on else 0.0
        col = block[:, idx]
        col[0] += 1.0
        col[1] += k
        col[2] += c
        col[3] += c * c
        col[4] += c * k
        col[5] += o
        col[6] += o * k

    def update_binned(self, cell: int, context: str, branch: int,
                      block: np.ndarray) -> None:
        """Accumulate a pre-binned ``(7, n_bins+1)`` block -- the entry
        point for the compiled fleet backend, whose jitted window
        program bins via `segment_sum` on device."""
        block = np.asarray(block, np.float64)
        if block.shape != (_ROWS, self.n_bins + 1):
            raise ValueError(
                f"block shape {block.shape} != ({_ROWS}, {self.n_bins + 1})"
            )
        key = (int(cell), str(context), int(branch))
        have = self._blocks.get(key)
        if have is None:
            self._blocks[key] = block.copy()
        else:
            have += block

    def note_ungated(self, cell: int, n: int) -> None:
        """Count `n` requests served WITHOUT a gate decision (backhaul
        routing while a cell is down). They carry no calibration signal
        but must be counted for sketch totals to match
        `fleet_requests_total`."""
        if n:
            c = int(cell)
            self._ungated[c] = self._ungated.get(c, 0) + int(n)

    def merge(self, other: "ReliabilitySketch") -> "ReliabilitySketch":
        """Exact in-place merge (elementwise add); returns self."""
        if other.n_bins != self.n_bins:
            raise ValueError("cannot merge sketches with different n_bins")
        for key, block in other._blocks.items():
            have = self._blocks.get(key)
            if have is None:
                self._blocks[key] = block.copy()
            else:
                have += block
        for c, n in other._ungated.items():
            self._ungated[c] = self._ungated.get(c, 0) + n
        return self

    # ------------------------------------------------------------ queries
    def __len__(self) -> int:
        return len(self._blocks)

    def keys(self) -> List[Key]:
        return sorted(self._blocks)

    def block(self, cell: int, context: str, branch: int) -> np.ndarray:
        return self._blocks[(int(cell), str(context), int(branch))]

    def merged_block(
        self,
        cell: Optional[int] = None,
        context: Optional[str] = None,
        branch: Optional[int] = None,
    ) -> np.ndarray:
        """Sum of all blocks matching the given key components (None =
        wildcard) -- the exact-merge property in query form."""
        out = np.zeros((_ROWS, self.n_bins + 1), np.float64)
        for (c, ctx, b), block in self._blocks.items():
            if cell is not None and c != cell:
                continue
            if context is not None and ctx != context:
                continue
            if branch is not None and b != branch:
                continue
            out += block
        return out

    def cells(self) -> List[int]:
        got = {c for c, _, _ in self._blocks}
        got.update(self._ungated)
        return sorted(got)

    def contexts(self) -> List[str]:
        return sorted({ctx for _, ctx, _ in self._blocks})

    def gated_count(self, cell: Optional[int] = None) -> int:
        return int(round(self.merged_block(cell=cell)[0].sum()))

    def ungated_count(self, cell: Optional[int] = None) -> int:
        if cell is None:
            return sum(self._ungated.values())
        return self._ungated.get(int(cell), 0)

    def total_count(self, cell: Optional[int] = None) -> int:
        """Gated + ungated requests -- must equal the request counters
        the serving stacks maintain (`repro.obs.check` asserts it)."""
        return self.gated_count(cell) + self.ungated_count(cell)

    def ece(self, cell: Optional[int] = None,
            context: Optional[str] = None,
            branch: Optional[int] = None) -> float:
        return block_ece(self.merged_block(cell, context, branch))

    def coverage(self, cell: Optional[int] = None,
                 context: Optional[str] = None,
                 branch: Optional[int] = None) -> float:
        return block_coverage(self.merged_block(cell, context, branch))

    def brier(self, cell: Optional[int] = None,
              context: Optional[str] = None,
              branch: Optional[int] = None) -> float:
        return block_brier(self.merged_block(cell, context, branch))

    def reliability(self, cell: Optional[int] = None,
                    context: Optional[str] = None,
                    branch: Optional[int] = None) -> List[dict]:
        return block_reliability(self.merged_block(cell, context, branch))

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {
            "n_bins": self.n_bins,
            "blocks": [
                {"cell": c, "context": ctx, "branch": b,
                 "data": self._blocks[(c, ctx, b)].tolist()}
                for c, ctx, b in self.keys()
            ],
            "ungated": {str(c): n for c, n in sorted(self._ungated.items())},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ReliabilitySketch":
        sk = cls(n_bins=int(d["n_bins"]))
        for rec in d["blocks"]:
            sk.update_binned(rec["cell"], rec["context"], rec["branch"],
                             np.asarray(rec["data"], np.float64))
        for c, n in d.get("ungated", {}).items():
            sk.note_ungated(int(c), int(n))
        return sk

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "ReliabilitySketch":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def merge_sketches(
    sketches: Iterable[ReliabilitySketch],
) -> ReliabilitySketch:
    """Merge independent sketches into a fresh one (exact, associative)."""
    out: Optional[ReliabilitySketch] = None
    for sk in sketches:
        if out is None:
            out = ReliabilitySketch(n_bins=sk.n_bins)
        out.merge(sk)
    return out if out is not None else ReliabilitySketch()
