"""Decision audit log: every control-plane action, with its evidence.

Aggregate telemetry says *what* happened (p99 rose, the gap blew out);
the audit log says *why the system responded the way it did* -- which
QoS window tripped with what metric value against what cap, what
bandwidth/arrival evidence the controller rescored on and which
candidate row it chose, which bank version a rollout moved to and which
it restored on rollback, and where shed traffic was routed.

Records are flat dicts ``{"t_s", "actor", "action", "evidence": {...}}``
so the log greps cleanly as JSONL and reconstructs causal chains offline
(`repro.obs.check.verify_rollback_chain` rebuilds the poisoned-canary
rollback -- trip evidence -> rollback transition -> restored version --
from the log alone).

Actors/actions currently emitted:

=================  =====================================================
actor              actions
=================  =====================================================
qos_monitor        qos_trip, qos_clear
rollout_manager    rollout_canary, rollout_promote, rollout_rollback
churn              churn_leave, churn_join
simulator          shed_route (neighbor or cloud backhaul)
fleet_controller   controller_rescore (per-cell decision + inputs)
online_controller  controller_rescore (single-cell serving runtime)
=================  =====================================================
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional


class AuditLog:
    """Append-only in-memory audit log with JSONL import/export."""

    def __init__(self):
        self.records: List[Dict] = []

    def record(self, t: float, actor: str, action: str, **evidence) -> Dict:
        rec = {"t_s": float(t), "actor": str(actor), "action": str(action),
               "evidence": evidence}
        self.records.append(rec)
        return rec

    def filter(self, action: Optional[str] = None,
               actor: Optional[str] = None,
               cell: Optional[int] = None) -> List[Dict]:
        out = self.records
        if action is not None:
            out = [r for r in out if r["action"] == action]
        if actor is not None:
            out = [r for r in out if r["actor"] == actor]
        if cell is not None:
            out = [r for r in out if r["evidence"].get("cell") == cell]
        return list(out)

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as fh:
            for r in self.records:
                fh.write(json.dumps(r))
                fh.write("\n")

    @staticmethod
    def read_jsonl(path: str) -> List[Dict]:
        out = []
        with open(path) as fh:
            for line in fh:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out

    def __len__(self) -> int:
        return len(self.records)
