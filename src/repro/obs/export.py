"""Summary-metric export: telemetry roll-ups -> MetricsRegistry gauges.

The simulators populate *counters* live (requests, offloads, sheds,
trace records) because those feed conservation checks; the headline
aggregates (p99, gap, miss rate) are computed once at the end by the
telemetry objects, and this module maps them onto gauges so one
registry holds both views. `benchmarks/run.py --emit-obs` writes the
result as JSON + Prometheus text next to the BENCH files.
"""
from __future__ import annotations

import math
from typing import Optional

from .metrics import MetricsRegistry


def _set_finite(reg: MetricsRegistry, name: str, value, **labels) -> None:
    if value is None:
        return
    v = float(value)
    if math.isnan(v) or math.isinf(v):
        return
    reg.set_gauge(name, v, **labels)


def serving_metrics(telemetry,
                    registry: Optional[MetricsRegistry] = None) -> MetricsRegistry:
    """Gauges from a `repro.serving.telemetry.Telemetry` summary."""
    reg = registry if registry is not None else MetricsRegistry()
    s = telemetry.summary()
    for k, v in s.items():
        _set_finite(reg, f"serving_{k}", v)
    return reg


def fleet_metrics(telemetry, registry: Optional[MetricsRegistry] = None,
                  per_cell: bool = True) -> MetricsRegistry:
    """Gauges from a `repro.fleet.telemetry.FleetTelemetry`: the fleet
    summary plus (optionally) the operator's per-cell table."""
    reg = registry if registry is not None else MetricsRegistry()
    for k, v in telemetry.fleet_summary().items():
        _set_finite(reg, f"fleet_{k}", v)
    if per_cell:
        for c in range(telemetry.n_cells):
            for k, v in telemetry.cell_summary(c).items():
                _set_finite(reg, f"fleet_cell_{k}", v, cell=c)
    return reg


def export_calibration(sketch,
                       registry: Optional[MetricsRegistry] = None,
                       ) -> MetricsRegistry:
    """Calibration-health gauges + histogram from a `ReliabilitySketch`.

    Stable names, one row per populated key slice:

      calibration_ece{cell,context}        windowed ECE
      calibration_coverage{cell,branch}    on-device precision vs p_tar
      calibration_brier{cell}              Brier score
      calibration_gated_total{cell}        gated requests in the sketch
      calibration_ungated_total{cell}      backhauled (no-gate) requests
      calibration_confidence_bucket{...}   the reliability bins as a
                                           declared Prometheus histogram

    The histogram declares bounds at the sketch's own bin edges
    (excluding 0), so slot i holds bin i exactly; the sketch's overflow
    slot (conf <= 0) folds into slot 0 -- consistent with the
    registry's left-open/right-closed bucket rule -- and the terminal
    +Inf bucket is structurally empty (confidence <= 1)."""
    from .calibration import bin_edges

    reg = registry if registry is not None else MetricsRegistry()
    edges = bin_edges(sketch.n_bins)
    reg.declare_histogram("calibration_confidence", edges[1:])
    for cell in sketch.cells():
        _set_finite(reg, "calibration_brier", sketch.brier(cell=cell),
                    cell=cell)
        reg.set_gauge("calibration_gated_total", sketch.gated_count(cell),
                      cell=cell)
        reg.set_gauge("calibration_ungated_total",
                      sketch.ungated_count(cell), cell=cell)
        for ctx in sketch.contexts():
            block = sketch.merged_block(cell=cell, context=ctx)
            if block[0].sum() <= 0:
                continue
            _set_finite(reg, "calibration_ece",
                        sketch.ece(cell=cell, context=ctx),
                        cell=cell, context=ctx)
        branches = sorted({b for c, _, b in sketch.keys() if c == cell})
        for br in branches:
            _set_finite(reg, "calibration_coverage",
                        sketch.coverage(cell=cell, branch=br),
                        cell=cell, branch=br)
        blk = sketch.merged_block(cell=cell)
        counts = list(blk[0, :sketch.n_bins])
        counts[0] += blk[0, sketch.n_bins]  # overflow (conf <= 0) -> slot 0
        counts.append(0)  # +Inf terminal bucket: confidence <= 1 by construction
        reg.observe_counts("calibration_confidence", counts,
                           float(blk[2].sum()), cell=cell)
    _set_finite(reg, "calibration_ece", sketch.ece())
    _set_finite(reg, "calibration_coverage", sketch.coverage())
    return reg
