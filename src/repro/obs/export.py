"""Summary-metric export: telemetry roll-ups -> MetricsRegistry gauges.

The simulators populate *counters* live (requests, offloads, sheds,
trace records) because those feed conservation checks; the headline
aggregates (p99, gap, miss rate) are computed once at the end by the
telemetry objects, and this module maps them onto gauges so one
registry holds both views. `benchmarks/run.py --emit-obs` writes the
result as JSON + Prometheus text next to the BENCH files.
"""
from __future__ import annotations

import math
from typing import Optional

from .metrics import MetricsRegistry


def _set_finite(reg: MetricsRegistry, name: str, value, **labels) -> None:
    if value is None:
        return
    v = float(value)
    if math.isnan(v) or math.isinf(v):
        return
    reg.set_gauge(name, v, **labels)


def serving_metrics(telemetry,
                    registry: Optional[MetricsRegistry] = None) -> MetricsRegistry:
    """Gauges from a `repro.serving.telemetry.Telemetry` summary."""
    reg = registry if registry is not None else MetricsRegistry()
    s = telemetry.summary()
    for k, v in s.items():
        _set_finite(reg, f"serving_{k}", v)
    return reg


def fleet_metrics(telemetry, registry: Optional[MetricsRegistry] = None,
                  per_cell: bool = True) -> MetricsRegistry:
    """Gauges from a `repro.fleet.telemetry.FleetTelemetry`: the fleet
    summary plus (optionally) the operator's per-cell table."""
    reg = registry if registry is not None else MetricsRegistry()
    for k, v in telemetry.fleet_summary().items():
        _set_finite(reg, f"fleet_{k}", v)
    if per_cell:
        for c in range(telemetry.n_cells):
            for k, v in telemetry.cell_summary(c).items():
                _set_finite(reg, f"fleet_cell_{k}", v, cell=c)
    return reg
