"""Trace-derived invariant checks: `python -m repro.obs.check`.

The observability plane is only trustworthy if it can be cross-examined.
These checks replay nothing -- they audit the *artifacts* (trace JSONL,
metrics JSON, audit JSONL) against invariants the simulators are
supposed to guarantee:

1. **Latency decomposition** -- every trace record's spans tile
   ``[arrival, complete]`` contiguously and their durations sum to the
   end-to-end latency within float tolerance.
2. **Gate consistency** -- a record is on-device iff its timeline has no
   uplink/cloud spans, and (confidence criterion) the recorded verdict
   matches ``confidence >= p_tar``.
3. **Conservation** -- requests are conserved across churn/shedding:
   completed == expected, the live per-cell counters sum to the same
   total, and the offload counters match what telemetry stored.
4. **Trace accounting** -- the sink saw exactly as many records as the
   emitters counted (and, when unsampled, as many as the counters say
   completed, with the offloaded records' `payload_nbytes` summing to
   the uplink byte counters).
5. **Audit causality** (optional) -- a canary rollback is reconstructible
   from the audit log alone: canary start -> QoS trip on a canary cell
   with over-cap (or, for floor SLOs like coverage, under-floor)
   evidence -> rollback restoring the incumbent version.
6. **Calibration sketch** (optional, ``--calibration``) -- the
   reliability sketch's gated+ungated totals equal the request
   counters, and on an unsampled trace the merged sketch reproduces
   `repro.core.metrics.ece` from the raw gate confidences.

Each check returns a list of human-readable error strings; the CLI
prints a summary and exits non-zero if any check fails. CI runs this
against the artifacts `benchmarks/run.py --emit-obs` writes.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Sequence

from .metrics import MetricsRegistry
from .trace import read_jsonl


def _tol(latency_s: float, rel: float) -> float:
    return rel * max(1.0, abs(latency_s)) + 1e-9


def check_span_telescoping(records: Sequence[Dict],
                           rel_tol: float = 1e-6) -> List[str]:
    """Spans tile [arrival, complete]; durations sum to latency."""
    errors = []
    for r in records:
        if r.get("kind") != "request":
            continue
        rid, spans = r.get("req_id"), r.get("spans") or []
        tol = _tol(r["latency_s"], rel_tol)
        if not spans:
            errors.append(f"req {rid}: no spans")
            continue
        if abs(spans[0]["start_s"] - r["arrival_s"]) > tol:
            errors.append(f"req {rid}: first span starts at "
                          f"{spans[0]['start_s']}, arrival {r['arrival_s']}")
        if abs(spans[-1]["end_s"] - r["complete_s"]) > tol:
            errors.append(f"req {rid}: last span ends at "
                          f"{spans[-1]['end_s']}, complete {r['complete_s']}")
        for a, b in zip(spans, spans[1:]):
            if abs(a["end_s"] - b["start_s"]) > tol:
                errors.append(f"req {rid}: gap between {a['name']} and "
                              f"{b['name']}: {a['end_s']} != {b['start_s']}")
        total = 0.0
        for s in spans:
            d = s["end_s"] - s["start_s"]
            if d < -tol:
                errors.append(f"req {rid}: span {s['name']} has negative "
                              f"duration {d}")
            total += d
        if abs(total - r["latency_s"]) > tol:
            errors.append(f"req {rid}: span durations sum to {total}, "
                          f"latency is {r['latency_s']}")
    return errors


def check_gate_consistency(records: Sequence[Dict],
                           conf_tol: float = 1e-6) -> List[str]:
    """Trace gate verdict agrees with the timeline and the threshold."""
    errors = []
    for r in records:
        if r.get("kind") != "request":
            continue
        rid = r.get("req_id")
        names = {s["name"] for s in r.get("spans") or []}
        offloaded_spans = bool(names & {"uplink", "cloud"})
        if r["on_device"] == offloaded_spans:
            errors.append(f"req {rid}: on_device={r['on_device']} but spans "
                          f"{'include' if offloaded_spans else 'lack'} "
                          "uplink/cloud")
        gate = r.get("gate")
        if not gate or gate.get("confidence") is None:
            continue
        if gate.get("criterion") not in (None, "confidence"):
            continue
        conf, p_tar = float(gate["confidence"]), float(gate["p_tar"])
        # tolerance: the fleet gate compares in float32; exact-boundary
        # verdicts may legitimately differ from the float64 replay
        if r["on_device"] and conf < p_tar - conf_tol:
            errors.append(f"req {rid}: on-device but confidence {conf} < "
                          f"p_tar {p_tar}")
        if not r["on_device"] and conf >= p_tar + conf_tol:
            errors.append(f"req {rid}: offloaded but confidence {conf} >= "
                          f"p_tar {p_tar}")
    return errors


def check_conservation(metrics: MetricsRegistry) -> List[str]:
    """Requests conserved across churn/shedding; offload counters match
    what telemetry stored. Applies to whichever stacks (serving/fleet)
    published their gauges into this registry."""
    errors = []
    expected = metrics.gauge_value("fleet_requests_expected")
    completed = metrics.gauge_value("fleet_requests_completed")
    if expected is not None:
        if completed != expected:
            errors.append(f"fleet: completed {completed} != expected "
                          f"{expected}")
        served = metrics.counter_total("fleet_requests_total")
        if served != expected:
            errors.append(f"fleet: per-cell served counters sum to {served}, "
                          f"expected {expected}")
        off_tel = metrics.gauge_value("fleet_offloaded_telemetry")
        off_ctr = metrics.counter_total("fleet_offloaded_total")
        if off_tel is not None and off_ctr != off_tel:
            errors.append(f"fleet: gate-verdict offload counter {off_ctr} != "
                          f"telemetry offload count {off_tel}")
    srv = metrics.gauge_value("serving_requests")
    if srv is not None:
        ctr = metrics.counter_total("serving_requests_total")
        if ctr != srv:
            errors.append(f"serving: completion counters sum to {ctr}, "
                          f"telemetry has {srv}")
        rate = metrics.gauge_value("serving_offload_rate")
        off = metrics.counter_total("serving_requests_total", path="cloud")
        if rate is not None and abs(off - rate * srv) > 0.5:
            errors.append(f"serving: offloaded counter {off} != "
                          f"offload_rate*requests {rate * srv:.1f}")
    return errors


def check_trace_counts(records: Sequence[Dict],
                       metrics: MetricsRegistry) -> List[str]:
    """The sink saw every record the emitters counted; unsampled traces
    account for every completed request."""
    errors = []
    by_source: Dict[str, int] = {}
    offloaded: Dict[str, int] = {}
    for r in records:
        if r.get("kind") != "request":
            continue
        src = r.get("source", "?")
        by_source[src] = by_source.get(src, 0) + 1
        if not r["on_device"]:
            offloaded[src] = offloaded.get(src, 0) + 1
    for src, n in sorted(by_source.items()):
        ctr = metrics.counter_total("trace_records_total", source=src)
        if ctr and ctr != n:
            errors.append(f"{src}: trace file holds {n} records, emitters "
                          f"counted {ctr}")
        every = metrics.gauge_value("trace_sample_every", source=src)
        if every != 1:
            continue  # sampled: per-record invariants only
        total = {"fleet": "fleet_requests_total",
                 "serving": "serving_requests_total"}.get(src)
        if total is not None:
            want = metrics.counter_total(total)
            if want and n != want:
                errors.append(f"{src}: unsampled trace holds {n} records, "
                              f"{want} requests completed")
        off_ctr = {"fleet": "fleet_offloaded_total"}.get(src)
        if off_ctr is not None:
            want = metrics.counter_total(off_ctr)
            if want != offloaded.get(src, 0):
                errors.append(f"{src}: trace shows {offloaded.get(src, 0)} "
                              f"offloads, counters say {want}")
    return errors


def check_uplink_bytes(records: Sequence[Dict],
                       metrics: MetricsRegistry) -> List[str]:
    """On an UNSAMPLED trace, the per-request `payload_nbytes` of the
    offloaded records sum exactly to the byte counters the stacks
    maintain (`serving_uplink_bytes_total`; `fleet_uplink_bytes_total`
    summed over cells) -- the wire-pricing analogue of request
    conservation. Sampled traces are skipped: a stride of the records
    cannot reproduce a total."""
    errors = []
    by_source: Dict[str, float] = {}
    for r in records:
        if r.get("kind") != "request" or r["on_device"]:
            continue
        pn = r.get("payload_nbytes")
        if pn is None:
            return []  # legacy trace (pre-codec): nothing to audit
        src = r.get("source", "?")
        by_source[src] = by_source.get(src, 0.0) + float(pn)
    counters = {"fleet": "fleet_uplink_bytes_total",
                "serving": "serving_uplink_bytes_total"}
    for src, total in sorted(by_source.items()):
        if metrics.gauge_value("trace_sample_every", source=src) != 1:
            continue
        name = counters.get(src)
        if name is None:
            continue
        want = metrics.counter_total(name)
        if abs(want - total) > 0.5:
            errors.append(f"{src}: trace payloads sum to {total:.0f} bytes, "
                          f"{name} counted {want:.0f}")
    return errors


def check_calibration(sketch,
                      metrics: Optional[MetricsRegistry] = None,
                      trace_records: Optional[Sequence[Dict]] = None,
                      ece_tol: float = 1e-9) -> List[str]:
    """Calibration-sketch invariants.

    1. **Totals conserved** -- gated + ungated sketch counts equal the
       request counters the stacks maintain (`fleet_requests_total`
       per cell, or `serving_requests_total` for the event runtime).
    2. **ECE reproduction** -- on an UNSAMPLED trace, the merged
       sketch's ECE equals `repro.core.metrics.ece` recomputed from the
       raw per-request gate confidences/correctness in the trace
       (counts must match exactly; the float sums differ only by
       accumulation order, hence `ece_tol`).
    """
    errors: List[str] = []
    if metrics is not None:
        if metrics.counter_total("fleet_requests_total") > 0:
            for cell in sketch.cells():
                want = metrics.counter_total("fleet_requests_total",
                                             cell=cell)
                got = sketch.total_count(cell)
                if want and got != want:
                    errors.append(
                        f"calibration: cell {cell} sketch total {got} != "
                        f"fleet_requests_total {want:.0f}")
        elif metrics.counter_total("serving_requests_total") > 0:
            want = metrics.counter_total("serving_requests_total")
            got = sketch.total_count()
            if got != want:
                errors.append(
                    f"calibration: sketch total {got} != "
                    f"serving_requests_total {want:.0f}")
    if trace_records is not None:
        unsampled = metrics is None or all(
            metrics.gauge_value("trace_sample_every", source=s) in (None, 1)
            for s in {r.get("source", "?") for r in trace_records
                      if r.get("kind") == "request"}
        )
        if unsampled:
            conf, correct = [], []
            for r in trace_records:
                if r.get("kind") != "request":
                    continue
                gate = r.get("gate")
                if not gate or gate.get("confidence") is None \
                        or gate.get("correct") is None:
                    continue
                conf.append(float(gate["confidence"]))
                correct.append(int(gate["correct"]))
            if conf:
                import numpy as np

                from repro.core.metrics import ece as _ece

                want = float(_ece(np.asarray(conf),
                                  np.asarray(correct, bool)))
                got = sketch.ece()
                n_trace, n_sketch = len(conf), sketch.gated_count()
                if n_trace != n_sketch:
                    errors.append(
                        f"calibration: trace holds {n_trace} gated "
                        f"records, sketch accumulated {n_sketch}")
                elif abs(got - want) > ece_tol:
                    errors.append(
                        f"calibration: sketch ECE {got!r} != "
                        f"core.metrics.ece {want!r} on the unsampled trace")
    return errors


def verify_rollback_chain(audit_records: Sequence[Dict]) -> Dict:
    """Reconstruct a canary rollback from the audit log alone.

    Returns ``{"ok": bool, "why": str, "canary": rec, "trips": [rec],
    "rollback": rec}`` -- ok only when the log shows, in time order, a
    canary start, at least one QoS trip on a canary cell whose evidence
    puts the metric value over its cap, and a rollback of that bank
    version restoring the incumbent version the canary recorded."""
    out: Dict = {"ok": False, "why": "", "canary": None, "trips": [],
                 "rollback": None}
    canaries = [r for r in audit_records if r["action"] == "rollout_canary"]
    if not canaries:
        out["why"] = "no rollout_canary record"
        return out
    ca = canaries[0]
    out["canary"] = ca
    version = ca["evidence"].get("bank_version")
    incumbent = ca["evidence"].get("incumbent_version")
    cells = set(ca["evidence"].get("cells") or ())
    trips = [r for r in audit_records
             if r["action"] == "qos_trip" and r["t_s"] >= ca["t_s"]
             and r["evidence"].get("cell") in cells]
    out["trips"] = trips
    if not trips:
        out["why"] = f"no qos_trip on canary cells {sorted(cells)}"
        return out
    for tr in trips:
        ev = tr["evidence"]
        if not ({"metric", "value", "cap"} <= set(ev)):
            out["why"] = f"trip at t={tr['t_s']} lacks metric/value/cap"
            return out
        # direction-aware: floor SLOs (e.g. coverage) record op="<" and
        # trip when the value drops BELOW the cap; caps default to ">"
        op = ev.get("op", ">")
        violated = ev["value"] < ev["cap"] if op == "<" \
            else ev["value"] > ev["cap"]
        if not violated:
            out["why"] = (f"trip at t={tr['t_s']}: value {ev['value']} not "
                          f"{'under' if op == '<' else 'over'} cap "
                          f"{ev['cap']}")
            return out
    rollbacks = [r for r in audit_records
                 if r["action"] == "rollout_rollback"
                 and r["evidence"].get("bank_version") == version]
    if not rollbacks:
        out["why"] = f"no rollout_rollback for bank_version {version}"
        return out
    rb = rollbacks[0]
    out["rollback"] = rb
    if rb["t_s"] < trips[0]["t_s"]:
        out["why"] = "rollback precedes first trip"
        return out
    if rb["evidence"].get("restored_version") != incumbent:
        out["why"] = (f"rollback restored "
                      f"{rb['evidence'].get('restored_version')}, canary "
                      f"recorded incumbent {incumbent}")
        return out
    out["ok"] = True
    out["why"] = (f"canary v{version} tripped on cells "
                  f"{sorted({t['evidence']['cell'] for t in trips})}, "
                  f"rolled back to v{incumbent} at t={rb['t_s']}s")
    return out


def run_checks(trace_records: Optional[Sequence[Dict]] = None,
               metrics: Optional[MetricsRegistry] = None,
               audit_records: Optional[Sequence[Dict]] = None,
               require_rollback_chain: bool = False,
               calibration=None,
               rel_tol: float = 1e-6) -> List[str]:
    errors = []
    if trace_records is not None:
        errors += check_span_telescoping(trace_records, rel_tol=rel_tol)
        errors += check_gate_consistency(trace_records)
        if metrics is not None:
            errors += check_trace_counts(trace_records, metrics)
            errors += check_uplink_bytes(trace_records, metrics)
    if metrics is not None:
        errors += check_conservation(metrics)
    if calibration is not None:
        errors += check_calibration(calibration, metrics=metrics,
                                    trace_records=trace_records)
    if require_rollback_chain:
        if audit_records is None:
            errors.append("rollback chain required but no audit log given")
        else:
            chain = verify_rollback_chain(audit_records)
            if not chain["ok"]:
                errors.append(f"rollback chain broken: {chain['why']}")
    return errors


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.check",
        description="Verify trace/metrics/audit artifacts against the "
                    "observability invariants.")
    ap.add_argument("--trace", help="trace JSONL file")
    ap.add_argument("--metrics", help="metrics JSON export")
    ap.add_argument("--audit", help="audit JSONL file")
    ap.add_argument("--require-rollback-chain", action="store_true",
                    help="fail unless the audit log reconstructs a full "
                         "canary rollback")
    ap.add_argument("--calibration",
                    help="reliability-sketch JSON artifact: verify totals "
                         "against counters and ECE against the trace")
    ap.add_argument("--tol", type=float, default=1e-6,
                    help="relative float tolerance for span sums")
    args = ap.parse_args(argv)
    if not (args.trace or args.metrics or args.audit or args.calibration):
        ap.error("give at least one of "
                 "--trace/--metrics/--audit/--calibration")

    traces = read_jsonl(args.trace) if args.trace else None
    metrics = MetricsRegistry.read_json(args.metrics) if args.metrics else None
    audit = read_jsonl(args.audit) if args.audit else None
    sketch = None
    if args.calibration:
        from .calibration import ReliabilitySketch

        sketch = ReliabilitySketch.load(args.calibration)

    errors = run_checks(traces, metrics, audit,
                        require_rollback_chain=args.require_rollback_chain,
                        calibration=sketch,
                        rel_tol=args.tol)
    n_tr = 0 if traces is None else len(traces)
    print(f"repro.obs.check: {n_tr} trace records, "
          f"{0 if audit is None else len(audit)} audit records, "
          f"metrics={'yes' if metrics is not None else 'no'}")
    if args.audit and args.require_rollback_chain and not errors:
        print("rollback chain:", verify_rollback_chain(audit)["why"])
    if errors:
        for e in errors[:50]:
            print("FAIL:", e)
        if len(errors) > 50:
            print(f"... and {len(errors) - 50} more")
        return 1
    print("all invariants hold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
