"""Unified observability plane: tracing, audit, metrics, invariants.

One `Observability` bundle threads through both simulators and the
orchestration plane::

    from repro.obs import Observability, RingBufferSink, AuditLog, MetricsRegistry

    obs = Observability(trace=RingBufferSink(), audit=AuditLog(),
                        metrics=MetricsRegistry())
    tel = run_fleet(bank, scenario, with_controller=True, obs=obs)

Everything is opt-in and **zero-perturbation**: with ``obs=None`` (the
default) no instrumentation code runs and every bench number reproduces
bit-exactly -- `tests/test_obs.py` pins that parity. The artifacts the
sinks collect are cross-examined by `repro.obs.check` (span
telescoping, request conservation, gate/offload consistency, audit
causal chains).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from .audit import AuditLog
from .calibration import ReliabilitySketch
from .export import export_calibration, fleet_metrics, serving_metrics
from .metrics import DEFAULT_BUCKETS_MS, MetricsRegistry
from .trace import (
    SPAN_NAMES,
    JsonlTraceSink,
    RingBufferSink,
    TraceSink,
    build_spans,
    read_jsonl,
    request_record,
)

__all__ = [
    "AuditLog",
    "DEFAULT_BUCKETS_MS",
    "JsonlTraceSink",
    "MetricsRegistry",
    "Observability",
    "ReliabilitySketch",
    "RingBufferSink",
    "SPAN_NAMES",
    "TraceSink",
    "build_spans",
    "export_calibration",
    "fleet_metrics",
    "full_observability",
    "read_jsonl",
    "request_record",
    "serving_metrics",
]


@dataclass
class Observability:
    """Which sinks are attached. Any member may be None (disabled);
    `trace_sample_every` strides the fleet simulator's per-request trace
    emission (1 = every request; the event-driven serving runtime always
    traces every request when a sink is attached)."""

    trace: Optional[TraceSink] = None
    audit: Optional[AuditLog] = None
    metrics: Optional[MetricsRegistry] = None
    calibration: Optional[ReliabilitySketch] = None
    trace_sample_every: int = 1

    @property
    def enabled(self) -> bool:
        return (self.trace is not None or self.audit is not None
                or self.metrics is not None or self.calibration is not None)

    def close(self) -> None:
        if self.trace is not None:
            self.trace.close()


def full_observability(trace_capacity: int = 200_000,
                       trace_sample_every: int = 1) -> Observability:
    """Everything on, in memory -- the one-liner for tests and notebooks."""
    return Observability(trace=RingBufferSink(trace_capacity),
                         audit=AuditLog(), metrics=MetricsRegistry(),
                         calibration=ReliabilitySketch(),
                         trace_sample_every=trace_sample_every)
