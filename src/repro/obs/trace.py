"""Per-request tracing: span timelines through pluggable sinks.

A trace record is one JSON-serializable dict per completed request with a
span timeline that *tiles* the interval ``[arrival_s, complete_s]`` --
each span's end is the next span's start, so span durations telescope to
the end-to-end latency exactly (up to float round-off). That tiling is a
checkable invariant (`repro.obs.check`), not a convention: both the
event-driven `ServingRuntime` and the columnar `FleetSimulator` build
their spans through `build_spans`, so the two stacks cannot drift apart
on what a latency decomposition means.

Span grammar (in timeline order)::

    queue_edge   arrival .. edge service start   (device queue wait)
    edge         edge service start .. edge done (on-device compute)
    -- offloaded requests continue --
    queue_uplink edge done .. uplink start       (microbatch + link wait)
    uplink       uplink start .. uplink done     (transfer)
    queue_cloud  uplink done .. cloud start      (cloud server wait)
    cloud        cloud start .. complete         (cloud compute)

Sinks are deliberately tiny: `emit(record)` + `close()`. The in-memory
`RingBufferSink` bounds live inspection; `JsonlTraceSink` streams one
JSON object per line for offline checking (`python -m repro.obs.check`).
"""
from __future__ import annotations

import json
from collections import deque
from typing import Dict, Iterable, List, Optional

SPAN_NAMES = ("queue_edge", "edge", "queue_uplink", "uplink",
              "queue_cloud", "cloud")


class TraceSink:
    """Minimal sink interface. Subclasses override `emit`."""

    def emit(self, record: Dict) -> None:
        raise NotImplementedError

    def close(self) -> None:  # pragma: no cover - default no-op
        pass


class RingBufferSink(TraceSink):
    """Keep the most recent `capacity` records in memory.

    `emitted` counts every record ever seen (the conservation checks use
    it even after old records fell off the ring)."""

    def __init__(self, capacity: int = 100_000):
        self.capacity = int(capacity)
        self._buf: deque = deque(maxlen=self.capacity)
        self.emitted = 0

    def emit(self, record: Dict) -> None:
        self._buf.append(record)
        self.emitted += 1

    @property
    def records(self) -> List[Dict]:
        return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)


class JsonlTraceSink(TraceSink):
    """Stream records to a file, one JSON object per line."""

    def __init__(self, path: str):
        self.path = str(path)
        self._fh = open(self.path, "w")
        self.emitted = 0

    def emit(self, record: Dict) -> None:
        self._fh.write(json.dumps(record))
        self._fh.write("\n")
        self.emitted += 1

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def read_jsonl(path: str) -> List[Dict]:
    """Load a JSONL trace/audit file back into a list of dicts."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def build_spans(
    arrival_s: float,
    edge_start_s: float,
    edge_done_s: float,
    uplink_start_s: Optional[float] = None,
    uplink_done_s: Optional[float] = None,
    cloud_start_s: Optional[float] = None,
    complete_s: Optional[float] = None,
) -> List[Dict]:
    """The one span grammar both simulators emit through.

    On-device requests pass only the first three timestamps; offloaded
    requests pass all seven. Zero-duration spans are kept (a backhauled
    fleet request has a zero-length edge span) so the timeline always
    tiles ``[arrival, complete]`` without gaps.
    """
    spans = [
        {"name": "queue_edge", "start_s": float(arrival_s),
         "end_s": float(edge_start_s)},
        {"name": "edge", "start_s": float(edge_start_s),
         "end_s": float(edge_done_s)},
    ]
    if uplink_start_s is not None:
        spans.extend([
            {"name": "queue_uplink", "start_s": float(edge_done_s),
             "end_s": float(uplink_start_s)},
            {"name": "uplink", "start_s": float(uplink_start_s),
             "end_s": float(uplink_done_s)},
            {"name": "queue_cloud", "start_s": float(uplink_done_s),
             "end_s": float(cloud_start_s)},
            {"name": "cloud", "start_s": float(cloud_start_s),
             "end_s": float(complete_s)},
        ])
    return spans


def request_record(
    source: str,
    req_id: int,
    arrival_s: float,
    complete_s: float,
    on_device: bool,
    spans: List[Dict],
    gate: Optional[Dict] = None,
    cell: Optional[int] = None,
    device: Optional[int] = None,
    payload_nbytes: Optional[int] = None,
) -> Dict:
    """One completed request. `gate` carries the verdict evidence
    (branch, p_tar threshold, confidence, criterion, context, expert);
    it is None when no gate ran (e.g. cloud-backhauled fleet requests).
    `payload_nbytes` is the wire size of the shipped activation (post
    codec) for offloaded requests; None for on-device completions."""
    return {
        "kind": "request",
        "source": source,
        "req_id": int(req_id),
        "cell": None if cell is None else int(cell),
        "device": None if device is None else int(device),
        "arrival_s": float(arrival_s),
        "complete_s": float(complete_s),
        "latency_s": float(complete_s) - float(arrival_s),
        "on_device": bool(on_device),
        "payload_nbytes": None if payload_nbytes is None else int(payload_nbytes),
        "gate": gate,
        "spans": spans,
    }
