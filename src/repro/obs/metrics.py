"""Metrics registry: counters / gauges / histograms with label sets.

A deliberately small, dependency-free registry with two export formats:
`to_json()` (round-trips through `MetricsRegistry.from_json`, which is
what `repro.obs.check` consumes) and `to_prometheus()` (the text
exposition format, cumulative `_bucket{le=...}` / `_sum` / `_count`
histogram series) so the artifacts `benchmarks/run.py --emit-obs` writes
can be scraped by standard tooling.

Label sets are plain keyword arguments::

    reg = MetricsRegistry()
    reg.inc("fleet_requests_total", 128, cell=3)
    reg.set_gauge("fleet_requests_expected", 102_400)
    reg.observe("serving_latency_ms", 12.5)

Counters only go up; `observe` feeds a histogram (declare custom bucket
bounds once with `declare_histogram`, otherwise `DEFAULT_BUCKETS_MS`
apply). Everything is synchronous, in-process, and cheap enough to sit
on the simulators' per-window path.
"""
from __future__ import annotations

import bisect
import json
from typing import Dict, List, Optional, Sequence, Tuple

#: Default histogram bounds, sized for request latencies in milliseconds.
DEFAULT_BUCKETS_MS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                      500.0, 1000.0, 2500.0, 5000.0, 10000.0)

_LabelKey = Tuple[Tuple[str, str], ...]


def _key(labels: Dict[str, object]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else f"{f:.10g}"


def _escape_label(v: str) -> str:
    """Prometheus label-value escaping: backslash, double quote, newline."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _escape_help(v: str) -> str:
    """Prometheus HELP-text escaping: backslash and newline only."""
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def _label_str(key: _LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{_escape_label(v)}"' for k, v in key) + "}"


#: Seed help texts for the metric families the serving stacks export.
#: `MetricsRegistry.describe` registers/overrides entries per instance.
_HELP_SEED = {
    "serving_requests_total": "Requests completed by the event-driven "
    "serving runtime.",
    "serving_offloaded_total": "Requests the gate sent to the cloud.",
    "serving_deadline_miss_total": "Requests finishing past their deadline.",
    "serving_latency_ms": "End-to-end request latency (ms).",
    "fleet_requests_total": "Requests completed per origin cell.",
    "fleet_offloaded_total": "Fleet requests offloaded to the shared cloud.",
    "fleet_latency_ms": "Fleet end-to-end request latency (ms).",
    "serving_uplink_bytes_total": "Post-codec payload bytes the serving "
    "runtime shipped over the uplink.",
    "fleet_uplink_bytes_total": "Post-codec payload bytes shipped toward "
    "the cloud per origin cell (uplink and backhaul).",
    "trace_records_total": "Trace records emitted per source.",
    "calibration_ece": "Windowed expected calibration error from the "
    "reliability sketch.",
    "calibration_coverage": "Fraction of on-device exits that were correct "
    "(gate precision vs p_tar).",
    "calibration_brier": "Brier score of gate confidence vs edge "
    "correctness.",
    "calibration_gated_total": "Gate decisions accumulated into the "
    "reliability sketch.",
    "calibration_ungated_total": "Requests served without a gate decision "
    "(backhaul routing).",
    "calibration_confidence": "Reliability-bin histogram of gate "
    "confidences.",
}


class MetricsRegistry:
    def __init__(self):
        self._counters: Dict[str, Dict[_LabelKey, float]] = {}
        self._gauges: Dict[str, Dict[_LabelKey, float]] = {}
        self._hists: Dict[str, Dict[_LabelKey, Dict]] = {}
        self._buckets: Dict[str, Tuple[float, ...]] = {}
        self._help: Dict[str, str] = dict(_HELP_SEED)

    def describe(self, name: str, text: str) -> None:
        """Attach/override the `# HELP` text emitted for `name`."""
        self._help[name] = str(text)

    def help_text(self, name: str, kind: str) -> str:
        return self._help.get(name, f"{name} ({kind}).")

    # ------------------------------------------------------------- write
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {name!r} cannot decrease")
        series = self._counters.setdefault(name, {})
        k = _key(labels)
        series[k] = series.get(k, 0.0) + float(value)

    def set_gauge(self, name: str, value: float, **labels) -> None:
        self._gauges.setdefault(name, {})[_key(labels)] = float(value)

    def declare_histogram(self, name: str,
                          buckets: Sequence[float] = DEFAULT_BUCKETS_MS) -> None:
        bounds = tuple(sorted(float(b) for b in buckets))
        if name in self._buckets and self._buckets[name] != bounds:
            raise ValueError(f"histogram {name!r} re-declared with new buckets")
        self._buckets[name] = bounds

    def observe(self, name: str, value: float, **labels) -> None:
        bounds = self._buckets.setdefault(name, tuple(DEFAULT_BUCKETS_MS))
        series = self._hists.setdefault(name, {})
        k = _key(labels)
        h = series.get(k)
        if h is None:
            h = series[k] = {"counts": [0] * (len(bounds) + 1),
                             "sum": 0.0, "count": 0}
        # counts[i] = observations with value <= bounds[i]; last slot = +Inf
        h["counts"][bisect.bisect_left(bounds, float(value))] += 1
        h["sum"] += float(value)
        h["count"] += 1

    def observe_counts(self, name: str, counts: Sequence[float],
                       total_sum: float, **labels) -> None:
        """Bulk-accumulate a pre-binned histogram: `counts[i]` lands in
        slot i of the declared bounds (last slot = +Inf), `total_sum`
        adds to the running sum. The entry point for sketch-derived
        histograms where per-sample `observe` calls would be wasteful."""
        bounds = self._buckets.setdefault(name, tuple(DEFAULT_BUCKETS_MS))
        if len(counts) != len(bounds) + 1:
            raise ValueError(
                f"histogram {name!r}: {len(counts)} counts for "
                f"{len(bounds)} bounds (+Inf slot required)"
            )
        series = self._hists.setdefault(name, {})
        k = _key(labels)
        h = series.get(k)
        if h is None:
            h = series[k] = {"counts": [0] * (len(bounds) + 1),
                             "sum": 0.0, "count": 0}
        n = 0
        for i, c in enumerate(counts):
            c = int(c)
            if c < 0:
                raise ValueError(f"histogram {name!r}: negative bulk count")
            h["counts"][i] += c
            n += c
        h["sum"] += float(total_sum)
        h["count"] += n

    # -------------------------------------------------------------- read
    def counter_total(self, name: str, **labels) -> float:
        """Sum of a counter across label sets matching the given subset."""
        want = dict(_key(labels))
        total = 0.0
        for k, v in self._counters.get(name, {}).items():
            if all(dict(k).get(lk) == lv for lk, lv in want.items()):
                total += v
        return total

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        return self._gauges.get(name, {}).get(_key(labels))

    # ------------------------------------------------------------ export
    def to_json(self) -> Dict:
        def dump(series):
            return {
                name: [{"labels": dict(k), "value": v}
                       for k, v in sorted(vals.items())]
                for name, vals in sorted(series.items())
            }

        hists = {}
        for name, vals in sorted(self._hists.items()):
            bounds = list(self._buckets[name])
            hists[name] = [
                {"labels": dict(k), "buckets": bounds,
                 "counts": list(h["counts"]), "sum": h["sum"],
                 "count": h["count"]}
                for k, h in sorted(vals.items())
            ]
        return {"counters": dump(self._counters),
                "gauges": dump(self._gauges),
                "histograms": hists}

    @classmethod
    def from_json(cls, d: Dict) -> "MetricsRegistry":
        reg = cls()
        for name, rows in d.get("counters", {}).items():
            for r in rows:
                reg.inc(name, r["value"], **r["labels"])
        for name, rows in d.get("gauges", {}).items():
            for r in rows:
                reg.set_gauge(name, r["value"], **r["labels"])
        for name, rows in d.get("histograms", {}).items():
            for r in rows:
                reg.declare_histogram(name, r["buckets"])
                k = _key(r["labels"])
                reg._hists.setdefault(name, {})[k] = {
                    "counts": list(r["counts"]), "sum": float(r["sum"]),
                    "count": int(r["count"])}
        return reg

    def write_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=1, sort_keys=True)

    @classmethod
    def read_json(cls, path: str) -> "MetricsRegistry":
        with open(path) as fh:
            return cls.from_json(json.load(fh))

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        for name, vals in sorted(self._counters.items()):
            lines.append(f"# HELP {name} {_escape_help(self.help_text(name, 'counter'))}")
            lines.append(f"# TYPE {name} counter")
            for k, v in sorted(vals.items()):
                lines.append(f"{name}{_label_str(k)} {_fmt(v)}")
        for name, vals in sorted(self._gauges.items()):
            lines.append(f"# HELP {name} {_escape_help(self.help_text(name, 'gauge'))}")
            lines.append(f"# TYPE {name} gauge")
            for k, v in sorted(vals.items()):
                lines.append(f"{name}{_label_str(k)} {_fmt(v)}")
        for name, vals in sorted(self._hists.items()):
            lines.append(f"# HELP {name} {_escape_help(self.help_text(name, 'histogram'))}")
            lines.append(f"# TYPE {name} histogram")
            bounds = self._buckets[name]
            for k, h in sorted(vals.items()):
                cum = 0
                for b, c in zip(bounds, h["counts"]):
                    cum += c
                    le = dict(k, le=_fmt(b))
                    lines.append(f"{name}_bucket{_label_str(_key(le))} {cum}")
                inf = dict(k, le="+Inf")
                lines.append(
                    f"{name}_bucket{_label_str(_key(inf))} {h['count']}")
                lines.append(f"{name}_sum{_label_str(k)} {_fmt(h['sum'])}")
                lines.append(f"{name}_count{_label_str(k)} {h['count']}")
        return "\n".join(lines) + ("\n" if lines else "")

    def write_prometheus(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_prometheus())
