"""AdamW + schedules + global-norm clipping, pure JAX (no optax here).

Optimizer state is a pytree mirroring params (fp32 moments), so it shards
with the same PartitionSpecs as the parameters; `zero1_specs` additionally
shards the moments' first replicated dim over the data axes (ZeRO-1) --
used by the perf pass to cut optimizer memory 16x on the big dense archs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros, jax.tree.map(jnp.copy, zeros))


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_mu, new_nu), {"grad_norm": gnorm, "lr": lr}


def state_specs(param_specs, zero1: bool = False, dp_axes=("data",), param_shapes=None, dp_size: int = 1):
    """PartitionSpecs for OptState given the params' specs.

    zero1=True: shard each moment's first fully-replicated, evenly-divisible
    dim over dp_axes (ZeRO-1 optimizer sharding) -- the perf-pass memory
    optimization. `param_shapes` (a matching pytree of ShapeDtypeStructs)
    is required to check divisibility by `dp_size`.
    """

    def moment_spec(spec: P, shape=None) -> P:
        if not zero1:
            return spec
        parts = list(spec) if len(spec) else ([None] * len(shape.shape) if shape is not None else [])
        for i, s in enumerate(parts):
            if s is None and (
                shape is None or shape.shape[i] % max(dp_size, 1) == 0
            ):
                parts[i] = tuple(dp_axes)
                return P(*parts)
        return spec

    if param_shapes is not None:
        mu_specs = jax.tree.map(
            moment_spec,
            param_specs,
            param_shapes,
            is_leaf=lambda x: isinstance(x, P),
        )
    else:
        mu_specs = jax.tree.map(
            moment_spec, param_specs, is_leaf=lambda x: isinstance(x, P)
        )
    return OptState(P(), mu_specs, mu_specs)
