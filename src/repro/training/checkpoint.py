"""Checkpointing: msgpack-serialized pytrees with dtype/shape fidelity.

Host-gathered (fully-addressable) save/restore; restore validates the tree
structure against a template so a config drift fails loudly instead of
silently loading mismatched weights. Atomic writes via temp-file rename.
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(leaf):
    a = np.asarray(jax.device_get(leaf))
    if a.dtype == jnp.bfloat16:
        return {
            b"__bf16__": True,
            b"data": a.view(np.uint16).tobytes(),
            b"shape": list(a.shape),
        }
    return {
        b"__nd__": True,
        b"dtype": a.dtype.str,
        b"data": a.tobytes(),
        b"shape": list(a.shape),
    }


def _decode(obj):
    if b"__bf16__" in obj:
        a = np.frombuffer(obj[b"data"], np.uint16).reshape(obj[b"shape"])
        return jnp.asarray(a.view(jnp.bfloat16))
    a = np.frombuffer(obj[b"data"], np.dtype(obj[b"dtype"])).reshape(obj[b"shape"])
    return jnp.asarray(a)


def save(path: str, tree) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = msgpack.packb(
        {"leaves": [_encode(l) for l in leaves], "n": len(leaves)},
        use_bin_type=True,
    )
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d)
    with os.fdopen(fd, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)


def load(path: str, template):
    """Restore into the structure of `template` (shapes/dtypes validated)."""
    with open(path, "rb") as f:
        obj = msgpack.unpackb(f.read(), raw=True)
    leaves = [_decode(l) for l in obj[b"leaves"]]
    t_leaves, treedef = jax.tree.flatten(template)
    if len(leaves) != len(t_leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, template {len(t_leaves)}"
        )
    for got, want in zip(leaves, t_leaves):
        if tuple(got.shape) != tuple(want.shape):
            raise ValueError(f"shape mismatch: {got.shape} vs {want.shape}")
    return treedef.unflatten(leaves)
