"""Training step factories: jitted, sharding-annotated train steps.

`make_train_step(cfg, opt_cfg)` builds the (params, opt_state, batch) ->
(params, opt_state, metrics) step for any zoo architecture (LM families via
registry.forward_train; the convnet via its own image loss). The launcher
jits it with in/out shardings from repro.sharding + optim.state_specs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.training import optim
from repro.training.losses import multi_exit_loss, softmax_xent


def loss_fn(params, cfg: ModelConfig, batch, remat: bool = True):
    out = registry.forward_train(params, cfg, batch, remat=remat)
    if cfg.family == "convnet":
        labels = batch["labels"]
        final = softmax_xent(out["logits"], labels)
        loss = final
        metrics = {"loss_final": final}
        for i, (ex, w) in enumerate(zip(out["exit_logits"], cfg.exit_loss_weights)):
            li = softmax_xent(ex, labels)
            loss = loss + w * li
            metrics[f"loss_exit{i}"] = li
        metrics["loss"] = loss
        return loss, metrics
    return multi_exit_loss(
        out, batch["labels"], cfg.exit_loss_weights, cfg.moe_aux_loss_weight
    )


def make_train_step(cfg: ModelConfig, opt_cfg: optim.AdamWConfig, remat: bool = True):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, remat
        )
        params, opt_state, opt_metrics = optim.update(opt_cfg, params, grads, opt_state)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    """Returns per-sample (exit_logits list, final logits) for calibration."""

    @jax.jit
    def eval_step(params, batch):
        out = registry.forward_train(params, cfg, batch, remat=False)
        return {"logits": out["logits"], "exit_logits": out["exit_logits"]}

    return eval_step
