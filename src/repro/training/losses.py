"""Losses: BranchyNet-style joint multi-exit objective.

L = L_final + sum_i w_i * L_exit_i  (+ moe aux)   [Teerapittayanon+ 2016,
the training recipe the paper uses for B-AlexNet; identical form for the
LM architectures with next-token CE.]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits, labels):
    """Mean cross-entropy. logits (..., C), labels (...) int32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def multi_exit_loss(outputs, labels, exit_weights, moe_aux_weight: float = 0.01):
    """outputs: {logits, exit_logits, [moe_aux_loss]}.

    Returns (scalar loss, metrics dict).
    """
    final = softmax_xent(outputs["logits"], labels)
    loss = final
    metrics = {"loss_final": final}
    for i, (ex, w) in enumerate(zip(outputs["exit_logits"], exit_weights)):
        li = softmax_xent(ex, labels)
        loss = loss + w * li
        metrics[f"loss_exit{i}"] = li
    aux = outputs.get("moe_aux_loss", None)
    if aux is not None:
        loss = loss + moe_aux_weight * aux
        metrics["moe_aux"] = aux
    metrics["loss"] = loss
    return loss, metrics
