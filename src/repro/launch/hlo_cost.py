"""Recursive HLO-text cost model for the dry-run roofline.

Why: XLA's compiled.cost_analysis() counts every while-loop body ONCE,
but jax.lax.scan over L stacked layers lowers to a while loop with
known_trip_count = L. For an 80-layer scanned model that undercounts
compute/bytes by ~80x (verified empirically: a 2-layer scanned stack
reports ~1 layer of flops). The optimized HLO, however, carries
``backend_config={"known_trip_count":{"n":"L"}}`` on every such while op,
and every dot carries operand shapes + contracting dims -- enough to cost
the module exactly:

  flops(computation) = sum over dots: 2*numel(out)*prod(contracting dims)
                     + sum over reduce-window: numel(out)*window
                     + sum over fusion calls: flops(called computation)
                     + sum over whiles: trip * flops(body)

  bytes(computation) = fusion-boundary traffic model: for every top-level
  instruction that touches data (dot/fusion/reduce/collective/copy/...),
  bytes = operand bytes + output bytes; whiles scale by trip count. This
  is the standard "each fusion reads its inputs and writes its outputs
  from/to HBM once" roofline model.

  collective_bytes(computation) likewise, scaled by trip counts.

All sizes are per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from functools import lru_cache

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_list(type_str):
    """All array shapes in a (possibly tuple) type string -> [(dtype, dims)]."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _numel(dims):
    n = 1
    for d in dims:
        n *= d
    return n


def _bytes_of(type_str):
    return sum(DTYPE_BYTES[dt] * _numel(dims) for dt, dims in _shape_list(type_str))


class HloCostModel:
    # ops that are pure plumbing: no HBM traffic attributed
    SKIP = (
        "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
        "after-all", "partition-id", "replica-id", "iota",
    )

    def __init__(self, hlo_text: str):
        self.computations = {}  # name -> list of instruction lines
        self.defs = {}  # instr name -> output type string
        self._parse(hlo_text)

    def _parse(self, text):
        cur = None
        for line in text.splitlines():
            ls = line.strip()
            m = re.match(r"(?:ENTRY )?(%?[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$", ls)
            if m and not ls.startswith("//"):
                cur = m.group(1)
                if not cur.startswith("%"):
                    cur = "%" + cur
                self.computations[cur] = []
                continue
            if ls.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            self.computations[cur].append(ls)
            core = ls[5:] if ls.startswith("ROOT ") else ls
            dm = re.match(r"(%[\w.\-]+) = ((?:\([^)]*\)|[\w\[\],{}/\s]*?)) [\w\-]+\(", core)
            if dm:
                self.defs[dm.group(1)] = dm.group(2)
        # entry = the computation named like ENTRY (last one usually) --
        # detect via 'main' in name, else the largest.
        entries = [n for n in self.computations if "main" in n]
        self.entry = entries[0] if entries else max(
            self.computations, key=lambda n: len(self.computations[n])
        )

    # ------------------------------------------------------------- helpers
    def _operands(self, line):
        call = re.search(r"\w[\w\-]*\((.*)\)(?:, |$)", line)
        if not call:
            return []
        return re.findall(r"%[\w.\-]+", call.group(1))

    def _out_type(self, line):
        m = re.match(r"%[\w.\-]+ = ((?:\([^)]*\)|[\w\[\],{}/\s]*?)) [\w\-]+\(", line)
        return m.group(1) if m else ""

    def _opcode(self, line):
        m = re.match(r"%[\w.\-]+ = (?:\([^)]*\)|[\w\[\],{}/\s]*?) ([\w\-]+)\(", line)
        return m.group(1) if m else ""

    def _dot_flops(self, line):
        out_t = self._out_type(line)
        out_elems = sum(_numel(d) for _, d in _shape_list(out_t))
        ops = self._operands(line)
        if not ops:
            return 0
        lhs_t = self.defs.get(ops[0], "")
        shp = _shape_list(lhs_t)
        if not shp:
            return 0
        lhs_dims = shp[0][1]
        cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        k = 1
        if cdims:
            for i in cdims.group(1).split(","):
                if i:
                    k *= lhs_dims[int(i)] if int(i) < len(lhs_dims) else 1
        return 2 * out_elems * k

    def _conv_flops(self, line):
        out_t = self._out_type(line)
        out_elems = sum(_numel(d) for _, d in _shape_list(out_t))
        ops = self._operands(line)
        if len(ops) < 2:
            return 0
        ker = _shape_list(self.defs.get(ops[1], ""))
        if not ker:
            return 0
        kdims = ker[0][1]
        # kernel HWIO: flops per output elem = 2 * prod(kernel)/O
        o = kdims[-1] if kdims else 1
        return 2 * out_elems * max(_numel(kdims) // max(o, 1), 1)

    def _rw_flops(self, line):
        out_t = self._out_type(line)
        out_elems = sum(_numel(d) for _, d in _shape_list(out_t))
        w = re.search(r"window=\{size=([\dx]+)", line)
        win = 1
        if w:
            for d in w.group(1).split("x"):
                win *= int(d)
        # Large-window reduce-windows are cumulative scans (jnp.cumsum);
        # TPU rewrites them to log-depth parallel prefix, so model the cost
        # as ~2*ceil(log2 w)+1 passes rather than the naive O(w) per element.
        import math

        eff = win if win <= 16 else min(win, 2 * math.ceil(math.log2(win)) + 1)
        return out_elems * eff


    def _fusion_bytes(self, comp_name: str, out_t: str) -> int:
        """HBM traffic of one fusion: slice/alias/convert-aware boundary model.

        On TPU, dtype converts fuse away and dynamic-update-slices alias
        their operand buffer; the XLA:CPU module materializes f32 upcasts
        around bf16 dots/updates. This walks the fused computation to cost
        only REAL traffic: sliced reads count the slice, the in-place DUS
        buffer counts only its update, pass-through converts count nothing.
        """
        lines = self.computations.get(comp_name, ())
        if not lines:
            return _bytes_of(out_t)
        params = {}
        local = {}
        users = {}
        for ln in lines:
            core = ln[5:] if ln.startswith("ROOT ") else ln
            pm = re.match(r"(%[\w.\-]+) = ([^=]*?) parameter\(", core)
            if pm:
                params[pm.group(1)] = pm.group(2).strip()
            dm = re.match(r"(%[\w.\-]+) = ", core)
            if dm:
                local[dm.group(1)] = core
                for o in self._operands(core):
                    users.setdefault(o, []).append(dm.group(1))

        PASS = ("convert", "bitcast", "copy", "reshape", "transpose")

        def chase_back(name, depth=0):
            """Resolve a value back through pass-through ops."""
            ln = local.get(name)
            if not ln or depth > 10:
                return name
            op = self._opcode(ln)
            if op in PASS:
                ops = self._operands(ln)
                if ops:
                    return chase_back(ops[0], depth + 1)
            return name

        # fusions made ONLY of pass-through ops (convert/bitcast/copy/...)
        # are XLA:CPU bf16-emulation artifacts; on TPU they fuse away.
        ops_in = {self._opcode(local[n]) for n in local}
        if ops_in <= set(PASS) | {"parameter", "constant"}:
            return 0

        root_line = next((ln for ln in lines if ln.startswith("ROOT ")), lines[-1])
        root_name = re.match(r"(?:ROOT )?(%[\w.\-]+) = ", root_line).group(1)
        eff_root = chase_back(root_name)
        eff_line = local.get(eff_root, root_line[5:] if root_line.startswith("ROOT ") else root_line)
        eff_op = self._opcode(eff_line)
        eff_ops = self._operands(eff_line)

        total = 0
        aliased = None
        if eff_op == "dynamic-update-slice" and len(eff_ops) > 1:
            aliased = chase_back(eff_ops[0])
            upd = chase_back(eff_ops[1])
            upd_t = params.get(upd) or self._strip_type(local.get(upd, ""))
            total += _bytes_of(upd_t) if upd_t else _bytes_of(out_t)
        else:
            total += _bytes_of(out_t)

        def terminal_uses(name, depth=0):
            """Forward-chase uses through pass-through ops -> terminal lines."""
            outs = []
            for u in users.get(name, []):
                ln = local.get(u, "")
                if self._opcode(ln) in PASS and depth < 10:
                    outs += terminal_uses(u, depth + 1)
                else:
                    outs.append(ln)
            return outs

        for pname, ptype in params.items():
            if aliased == pname:
                continue  # in-place buffer: update already counted
            terms = terminal_uses(pname)
            if terms and all(
                self._opcode(t) == "dynamic-slice" for t in terms
            ):
                total += sum(_bytes_of(self._strip_type(t)) for t in terms)
            elif terms and all(
                self._opcode(t) == "dynamic-update-slice"
                and chase_back(self._operands(t)[0]) == pname
                for t in terms
            ):
                continue  # aliased through a non-root DUS
            else:
                total += _bytes_of(ptype)
        return total

    def _strip_type(self, line):
        m = re.match(r"(?:ROOT )?%[\w.\-]+ = ((?:\([^)]*\)|[\w\[\],{}/\s]*?)) [\w\-]+\(", line)
        return m.group(1) if m else ""

    # --------------------------------------------------------------- costing
    @lru_cache(maxsize=None)
    def cost(self, comp_name: str):
        """Returns (flops, bytes, {collective: bytes}, {collective: count})."""
        flops = 0
        nbytes = 0
        coll = defaultdict(int)
        ccnt = defaultdict(int)
        for line in self.computations.get(comp_name, ()):
            line = line[5:] if line.startswith("ROOT ") else line
            op = self._opcode(line)
            if not op or op in self.SKIP:
                continue
            out_t = self._out_type(line)
            operand_bytes = sum(
                _bytes_of(self.defs.get(o, "")) for o in self._operands(line)
            )
            own_bytes = _bytes_of(out_t) + operand_bytes

            if op == "while":
                trip = 1
                m = re.search(r'known_trip_count[^\d]*(\d+)', line)
                if m:
                    trip = int(m.group(1))
                body = re.search(r"body=(%[\w.\-]+)", line)
                if body:
                    f, b, c, n = self.cost(body.group(1))
                    flops += trip * f
                    nbytes += trip * b
                    for k, v in c.items():
                        coll[k] += trip * v
                    for k, v in n.items():
                        ccnt[k] += trip * v
                cond = re.search(r"condition=(%[\w.\-]+)", line)
                if cond:
                    f, b, c, n = self.cost(cond.group(1))
                    flops += trip * f
                continue
            if op in ("fusion", "call", "async-start"):
                called = re.search(r"(?:calls|to_apply)=(%[\w.\-]+)", line)
                if called:
                    f, b, c, n = self.cost(called.group(1))
                    flops += f  # dots inside fusions still count
                    for k, v in c.items():
                        coll[k] += v
                    for k, v in n.items():
                        ccnt[k] += v
                    nbytes += self._fusion_bytes(called.group(1), out_t)
                else:
                    nbytes += own_bytes
                continue
            if op == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|true_computation=(%[\w.\-]+), false_computation=(%[\w.\-]+))",
                    line,
                )
                names = []
                for tup in branches:
                    for t in tup:
                        if t:
                            names += re.findall(r"%[\w.\-]+", t)
                if names:  # worst-case branch
                    sub = [self.cost(nm) for nm in set(names)]
                    flops += max(s[0] for s in sub)
                    nbytes += max(s[1] for s in sub)
                nbytes += own_bytes
                continue

            kind = next((k for k in COLLECTIVES if op.startswith(k)), None)
            if kind:
                coll[kind] += operand_bytes
                ccnt[kind] += 1
                nbytes += own_bytes
                continue
            if op == "dynamic-update-slice":
                # in-place update: traffic ~ read+write of the UPDATE slice,
                # not the whole buffer (XLA aliases the operand).
                ops = self._operands(line)
                upd = _bytes_of(self.defs.get(ops[1], "")) if len(ops) > 1 else 0
                nbytes += 2 * upd
                continue
            if op in ("dynamic-slice", "gather"):
                # traffic ~ read touched region + write output
                nbytes += 2 * _bytes_of(out_t)
                continue
            if op == "scatter":
                ops = self._operands(line)
                upd = _bytes_of(self.defs.get(ops[-1], "")) if ops else 0
                nbytes += 3 * upd  # read-modify-write of touched region
                continue
            if op == "dot":
                flops += self._dot_flops(line)
                nbytes += own_bytes
                continue
            if op == "convolution":
                flops += self._conv_flops(line)
                nbytes += own_bytes
                continue
            if op == "reduce-window":
                flops += self._rw_flops(line)
                nbytes += own_bytes
                continue
            if op in ("reduce", "sort", "scatter", "gather", "dynamic-slice",
                      "dynamic-update-slice", "copy", "broadcast", "transpose",
                      "reshape", "concatenate", "slice", "pad", "select",
                      "compare", "add", "multiply", "subtract", "divide",
                      "convert", "exponential", "rsqrt", "tanh", "map",
                      "reverse", "clamp", "maximum", "minimum", "rng",
                      "custom-call", "cholesky", "triangular-solve"):
                nbytes += own_bytes
                continue
            # anything else that produces data: boundary traffic
            nbytes += own_bytes
        return flops, nbytes, dict(coll), dict(ccnt)

    def entry_cost(self):
        f, b, c, n = self.cost(self.entry)
        return {"flops": f, "bytes": b, "collective_bytes": c, "collective_counts": n}


def analyze_text(hlo_text: str):
    return HloCostModel(hlo_text).entry_cost()
