"""Production mesh definitions.

Target hardware: TPU v5e pods. Single pod = 256 chips as a (data=16,
model=16) mesh; multi-pod = 2 pods = 512 chips as (pod=2, data=16,
model=16) where the 'pod' axis carries only data parallelism (DCN-friendly:
gradient all-reduce is the sole cross-pod collective).

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} "
            "(dryrun.py sets --xla_force_host_platform_device_count=512)"
        )
    import numpy as np

    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices exist (tests)."""
    import numpy as np

    devices = jax.devices()[: data * model]
    return jax.sharding.Mesh(np.asarray(devices).reshape(data, model), ("data", "model"))
