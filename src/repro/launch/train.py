"""Training driver.

Runs data-parallel + tensor-parallel training of any zoo architecture with
the BranchyNet-style multi-exit loss. On the production pod this jits with
the full param/opt shardings from repro.sharding; on CPU (this container)
use --smoke to train the reduced variant of the same family end-to-end.

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ck.msgpack
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.configs import get_config, get_smoke
from repro.data.pipeline import TokenIterator, prefetch
from repro.data.synthetic import lm_sequences
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import registry
from repro.training import checkpoint, optim
from repro.training.loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count():,} "
          f"active={cfg.active_param_count():,}")

    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        mesh = make_debug_mesh(1, 1) if jax.device_count() == 1 else make_debug_mesh(
            jax.device_count(), 1
        )
    sharding.set_mesh(mesh)

    key = jax.random.PRNGKey(args.seed)
    params = registry.init_params(key, cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"instantiated params: {n_params:,}")

    opt_cfg = optim.AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=min(20, args.steps // 5 + 1))
    opt_state = optim.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=not args.smoke))

    stream = lm_sequences(
        max(600_000, args.batch * (args.seq + 1) * 4), cfg.vocab_size, seed=args.seed
    )
    it = iter(TokenIterator(stream, args.batch, args.seq, seed=args.seed))

    t0 = time.time()
    for step in range(args.steps):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.is_encoder_decoder:
            batch["encoder_frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model),
                jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32,
            )
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            print(
                f"step {step:5d} loss={m['loss']:.4f} final={m['loss_final']:.4f} "
                + " ".join(
                    f"{k}={v:.4f}" for k, v in m.items() if k.startswith("loss_exit")
                )
                + f" gnorm={m['grad_norm']:.2f} ({time.time()-t0:.1f}s)"
            )
    if args.ckpt:
        checkpoint.save(args.ckpt, {"params": params, "step": jnp.int32(args.steps)})
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
