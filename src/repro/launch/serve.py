"""Serving step factories: prefill and single-token decode with the
calibrated early-exit gate fused into the step (the paper's technique as a
first-class serving feature).

serve_step returns, besides the final logits, per-exit (confidence,
prediction) computed from calibrated side-branch logits -- the runtime
(repro.offload.engine) uses them to stop early / route between the edge
and cloud partitions.

Calibration comes from an `OffloadPlan` (one CalibratorState per exit --
richer calibrators than a scalar temperature apply inside the jitted step)
or, as a legacy shim, from a raw `temperatures` list.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.exits import gate_statistics
from repro.core.policy import OffloadPlan
from repro.models import registry


def _make_exit_gater(cfg: ModelConfig, plan, temperatures):
    """-> gates(per_exit_logits_list) -> [(conf, pred, entropy), ...].

    Exactly one of plan/temperatures may be given; neither means T=1
    everywhere (the uncalibrated baseline).
    """
    n_exits = len(cfg.exit_layers)
    if plan is not None:
        if temperatures is not None:
            raise ValueError("pass plan OR temperatures, not both")
        if plan.num_exits != n_exits:
            raise ValueError(
                f"plan covers {plan.num_exits} exit(s) but {cfg.name} "
                f"has {n_exits}"
            )

        def gates(logits_list):
            return [
                gate_statistics(plan.calibrated_logits(l, i))
                for i, l in enumerate(logits_list)
            ]

        return gates
    temps = temperatures or [1.0] * n_exits

    def gates(logits_list):
        return [gate_statistics(l, t) for l, t in zip(logits_list, temps)]

    return gates


def make_prefill_step(cfg: ModelConfig, plan: OffloadPlan = None,
                      temperatures=None):
    gater = _make_exit_gater(cfg, plan, temperatures)

    def prefill_step(params, batch):
        out = registry.forward_prefill(params, cfg, batch)
        gates = gater([l[:, 0, :] for l in out["exit_logits"]])
        return {
            "logits": out["logits"],
            "exit_confidence": jnp.stack([g[0] for g in gates], 0) if gates else jnp.zeros((0, batch["tokens"].shape[0])),
            "exit_prediction": jnp.stack([g[1] for g in gates], 0) if gates else jnp.zeros((0, batch["tokens"].shape[0]), jnp.int32),
            "caches": out["caches"],
        }

    return prefill_step


def make_serve_step(cfg: ModelConfig, plan: OffloadPlan = None,
                    temperatures=None):
    """One decode token + fused exit gates. (params, token, caches, pos) ->
    ({token, logits, exit_confidence, exit_prediction}, new_caches)."""
    gater = _make_exit_gater(cfg, plan, temperatures)

    def serve_step(params, token, caches, pos):
        out, new_caches = registry.decode_step(params, cfg, token, caches, pos)
        logits = out["logits"][:, 0, :]
        b = token.shape[0]
        gates = gater([l[:, 0, :] for l in out["exit_logits"]])
        next_token = jnp.argmax(logits.astype(jnp.float32), axis=-1).astype(jnp.int32)
        return (
            {
                "token": next_token,
                "logits": logits,
                "exit_confidence": jnp.stack([g[0] for g in gates], 0)
                if gates
                else jnp.zeros((0, b)),
                "exit_prediction": jnp.stack([g[1] for g in gates], 0)
                if gates
                else jnp.zeros((0, b), jnp.int32),
            },
            new_caches,
        )

    return serve_step
