"""Serving step factories: prefill and single-token decode with the
calibrated early-exit gate fused into the step (the paper's technique as a
first-class serving feature).

serve_step returns, besides the final logits, per-exit (confidence,
prediction) computed from temperature-scaled side-branch logits -- the
runtime (repro.offload.engine) uses them to stop early / route between the
edge and cloud partitions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.exits import gate_statistics
from repro.models import registry


def make_prefill_step(cfg: ModelConfig, temperatures=None):
    temps = temperatures or [1.0] * len(cfg.exit_layers)

    def prefill_step(params, batch):
        out = registry.forward_prefill(params, cfg, batch)
        gates = [
            gate_statistics(l[:, 0, :], t) for l, t in zip(out["exit_logits"], temps)
        ]
        return {
            "logits": out["logits"],
            "exit_confidence": jnp.stack([g[0] for g in gates], 0) if gates else jnp.zeros((0, batch["tokens"].shape[0])),
            "exit_prediction": jnp.stack([g[1] for g in gates], 0) if gates else jnp.zeros((0, batch["tokens"].shape[0]), jnp.int32),
            "caches": out["caches"],
        }

    return prefill_step


def make_serve_step(cfg: ModelConfig, temperatures=None):
    """One decode token + fused exit gates. (params, token, caches, pos) ->
    ({token, logits, exit_confidence, exit_prediction}, new_caches)."""
    temps = temperatures or [1.0] * len(cfg.exit_layers)

    def serve_step(params, token, caches, pos):
        out, new_caches = registry.decode_step(params, cfg, token, caches, pos)
        logits = out["logits"][:, 0, :]
        b = token.shape[0]
        gates = [
            gate_statistics(l[:, 0, :], t) for l, t in zip(out["exit_logits"], temps)
        ]
        next_token = jnp.argmax(logits.astype(jnp.float32), axis=-1).astype(jnp.int32)
        return (
            {
                "token": next_token,
                "logits": logits,
                "exit_confidence": jnp.stack([g[0] for g in gates], 0)
                if gates
                else jnp.zeros((0, b)),
                "exit_prediction": jnp.stack([g[1] for g in gates], 0)
                if gates
                else jnp.zeros((0, b), jnp.int32),
            },
            new_caches,
        )

    return serve_step
