import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, with NO device allocation (ShapeDtypeStruct inputs).

For each pair it runs jax.jit(step).lower(**specs).compile() and records:
  * memory_analysis()  -- bytes per device (proves the sharding fits),
  * cost_analysis()    -- HLO FLOPs / bytes for the roofline,
  * the collective schedule -- bytes moved per collective kind, parsed from
    the optimized HLO (operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding
from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.training import optim
from repro.training.loop import make_train_step
from repro.launch.serve import make_prefill_step, make_serve_step


def _mesh_context(mesh):
    """jax.sharding.set_mesh is newer-jax; a Mesh is itself the context
    manager on 0.4.x."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh

ASSIGNED = [a for a in list_archs() if a != "b_alexnet"]

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def shape_adapted_config(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """long_500k on attention-quadratic archs -> sliding-window attention.

    SSM/hybrid run natively (O(1)/bounded state). Dense/MoE/VLM/audio get a
    4096-token window so the 524k decode is sub-quadratic, per the shape's
    requirement (noted in DESIGN.md: implemented rather than skipped).
    """
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm", "audio"):
        if cfg.sliding_window == 0:
            cfg = cfg.replace(sliding_window=4096)
    return cfg


def _sds(tree, shardings):
    """Attach shardings to ShapeDtypeStructs."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree,
        shardings,
    )


VARIANTS = {
    "baseline": {},
    "moe_shard_capacity": {"moe_shard_capacity": True},
    "decode_unroll": {"decode_unroll": True},
    "mamba_split_proj": {"mamba_split_proj": True},
    "all_opt": {
        "moe_shard_capacity": True,
        "decode_unroll": True,
        "mamba_split_proj": True,
    },
}


def build_lowering(
    arch: str, shape_name: str, mesh, zero1: bool = False, variant: str = "baseline"
):
    cfg = shape_adapted_config(get_config(arch), INPUT_SHAPES[shape_name])
    cfg = cfg.replace(**VARIANTS[variant])
    shape = INPUT_SHAPES[shape_name]
    sharding.set_mesh(mesh)
    ns = lambda spec: NamedSharding(mesh, spec)

    param_shapes = registry.param_specs_shapes(cfg)
    pspecs = sharding.param_specs(param_shapes)
    psh = jax.tree.map(ns, pspecs)
    batch_shapes = registry.input_specs(cfg, shape)
    bsh = jax.tree.map(ns, sharding.batch_specs_tree(batch_shapes))

    if shape.kind == "train":
        opt_cfg = optim.AdamWConfig()
        step = make_train_step(cfg, opt_cfg)
        opt_shapes = jax.eval_shape(optim.init, param_shapes)
        dp_size = 1
        for ax in sharding.dp_axes():
            dp_size *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
        ospecs = optim.state_specs(
            pspecs,
            zero1=zero1,
            dp_axes=sharding.dp_axes(),
            param_shapes=param_shapes,
            dp_size=dp_size,
        )
        osh = jax.tree.map(ns, ospecs)
        jitted = jax.jit(step, out_shardings=(psh, osh, None))
        args = (_sds(param_shapes, psh), _sds(opt_shapes, osh), _sds(batch_shapes, bsh))
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        jitted = jax.jit(step)
        args = (_sds(param_shapes, psh), _sds(batch_shapes, bsh))
    else:  # decode
        step = make_serve_step(cfg)
        cache_shapes = registry.cache_specs(cfg, shape)
        cspecs = sharding.cache_specs_tree(
            cache_shapes, batch_sharded=shape.global_batch > 1
        )
        csh = jax.tree.map(ns, cspecs)
        # donate the cache: serving reuses the buffer every step; without
        # aliasing, an unrolled decode materializes a copy per layer update
        jitted = jax.jit(step, donate_argnums=(2,))
        tok_sh = jax.tree.map(ns, sharding.batch_specs_tree(batch_shapes))
        args = (
            _sds(param_shapes, psh),
            _sds(batch_shapes["token"], tok_sh["token"]),
            _sds(cache_shapes, csh),
            jax.ShapeDtypeStruct((), jnp.int32, sharding=ns(P())),
        )
    return cfg, jitted, args


def collective_bytes(hlo_text: str):
    """Sum operand bytes per collective kind from optimized HLO."""
    dsize = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
             "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8, "s16": 2, "u16": 2}

    def shape_bytes(s):
        total = 0
        for m in re.finditer(r"(\w+)\[([\d,]*)\]", s):
            dt, dims = m.group(1), m.group(2)
            if dt not in dsize:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * dsize[dt]
        return total

    # map instr name -> output shape bytes
    defs = {}
    for m in re.finditer(r"(%[\w.\-]+) = ((?:\([^)]*\)|[\w\[\],{}\s/]*?)) (\w[\w\-]*)\(", hlo_text):
        defs[m.group(1)] = shape_bytes(m.group(2))

    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for m in re.finditer(
        r"= ((?:\([^)]*\)|[\w\[\],{}\s/]*?)) ((?:all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)[\w\-]*)\(([^)]*)\)",
        hlo_text,
    ):
        kind = next(k for k in COLLECTIVES if m.group(2).startswith(k))
        operands = re.findall(r"%[\w.\-]+", m.group(3))
        b = sum(defs.get(o, 0) for o in operands)
        if b == 0:  # fall back to output size
            b = shape_bytes(m.group(1))
        out[kind] += b
        counts[kind] += 1
    return out, counts


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    outdir: str,
    zero1=False,
    variant: str = "baseline",
):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with _mesh_context(mesh):
        cfg, jitted, args = build_lowering(
            arch, shape_name, mesh, zero1=zero1, variant=variant
        )
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax 0.4.x returns [dict]
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
    coll, coll_counts = collective_bytes(hlo)
    # Recursive while-trip-count-aware cost model (XLA cost_analysis counts
    # scan bodies once; see repro.launch.hlo_cost docstring).
    from repro.launch.hlo_cost import analyze_text

    model_cost = analyze_text(hlo)
    n_chips = mesh.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": n_chips,
        "ok": True,
        "compile_s": round(time.time() - t0, 1),
        "flops": model_cost["flops"],
        "bytes_accessed": model_cost["bytes"],
        "collective_bytes": model_cost["collective_bytes"],
        "collective_counts": model_cost["collective_counts"],
        "xla_raw_flops": cost.get("flops", 0.0),
        "xla_raw_bytes": cost.get("bytes accessed", 0.0),
        "raw_collective_bytes": coll,
        "raw_collective_counts": coll_counts,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "model_params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "sliding_window": cfg.sliding_window,
        "zero1": zero1,
        "variant": variant,
    }
    os.makedirs(outdir, exist_ok=True)
    sfx = "" if variant == "baseline" and not zero1 else (
        f"__{variant}" + ("_zero1" if zero1 else "")
    )
    stem = f"{arch}__{shape_name}__{mesh_name}{sfx}"
    fn = os.path.join(outdir, stem + ".json")
    with open(fn, "w") as f:
        json.dump(result, f, indent=1)
    # archive the optimized HLO so cost-model refinements re-derive terms
    # without recompiling (benchmarks/recost.py)
    try:
        import zstandard

        hlodir = os.path.join(os.path.dirname(outdir) or ".", "hlo")
        os.makedirs(hlodir, exist_ok=True)
        with open(os.path.join(hlodir, stem + ".hlo.zst"), "wb") as f:
            f.write(zstandard.ZstdCompressor(level=9).compress(hlo.encode()))
    except Exception:
        pass
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--zero1", action="store_true", help="ZeRO-1 optimizer sharding")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args()

    pairs = []
    if args.all:
        pairs = [(a, s) for a in ASSIGNED for s in INPUT_SHAPES]
    else:
        pairs = [(args.arch, args.shape)]

    failures = []
    for arch, shape in pairs:
        try:
            r = run_one(
                arch, shape, args.multi_pod, args.outdir,
                zero1=args.zero1, variant=args.variant,
            )
            print(
                f"OK   {arch:24s} {shape:12s} {r['mesh']:8s} "
                f"flops={r['flops']:.3e} bytes={r['bytes_accessed']:.3e} "
                f"coll={sum(r['collective_bytes'].values()):.3e} "
                f"({r['compile_s']}s)"
            )
        except Exception as e:
            failures.append((arch, shape, str(e)))
            print(f"FAIL {arch:24s} {shape:12s}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures")


if __name__ == "__main__":
    main()
