"""Batch-level offloading simulator: missed-deadline probability (Sec. IV-E)
and the end-to-end latency bookkeeping behind Figs. 5 and 6.

For each test batch (paper: 512 samples):
  * every sample pays the edge compute up to its serving branch;
  * samples whose (calibrated) confidence clears p_tar stop there;
  * the rest pay uplink transfer of the partition activation + cloud compute;
  * batch inference time = average per-sample time (the paper's "overall
    time required to infer a batch of samples", normalized per sample so
    t_tar is in per-sample units);
  * a missed deadline occurs if time > t_tar OR batch accuracy (over ALL
    samples, device + cloud) < p_tar.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.core.exits import gate_statistics
from repro.offload import latency as L


@dataclass
class BatchOutcome:
    time_s: float  # mean per-sample inference time
    accuracy: float  # over all samples in the batch
    on_device_frac: float


def simulate_batches(
    exit_logits_list: Sequence[np.ndarray],  # per branch, (N, C) test logits
    final_logits: np.ndarray,  # (N, C) cloud main-exit logits
    labels: np.ndarray,
    p_tar: float = None,
    temperatures: Sequence[float] = None,
    profile: L.LatencyProfile = None,
    batch_size: int = 512,
    branches: Sequence[int] = (1,),
    plan=None,
    drop_last: bool = False,
    network=None,
    batch_times_s: Sequence[float] = None,
) -> List[BatchOutcome]:
    """branches: which physical branches are deployed, e.g. (1,) or (1, 2).
    exit_logits_list and the legacy `temperatures` run parallel to
    `branches` (entry i describes deployed branch branches[i]).

    Calibration comes either from `plan` (an OffloadPlan whose calibrators
    are per-exit, shallowest first: physical branch k gates with
    calibrator state k-1, matching OffloadEngine) or from the legacy
    `temperatures` list with an explicit `p_tar`.

    The final partial batch IS simulated (set drop_last=True for the old
    truncating behavior). `network` (a serving.network.NetworkModel) prices
    each batch's uplink transfer at the rate in effect at that batch's
    timestamp in `batch_times_s` (default: all at t=0); without it the
    profile's fixed uplink is used, numerically unchanged.
    """
    if profile is None:
        raise ValueError("simulate_batches needs a LatencyProfile")
    if plan is not None:
        if p_tar is None:
            p_tar = plan.p_tar
    elif temperatures is None or p_tar is None:
        raise ValueError("simulate_batches needs (p_tar, temperatures) or plan")
    n = len(labels)
    n_br = len(branches)
    conf = np.zeros((n_br, n))
    pred = np.zeros((n_br, n), np.int64)
    for i, logits in enumerate(exit_logits_list[:n_br]):
        if plan is not None:
            c, p, _ = gate_statistics(plan.calibrated_logits(logits, branches[i] - 1))
        else:
            c, p, _ = gate_statistics(logits, temperatures[i])
        conf[i], pred[i] = np.asarray(c), np.asarray(p)
    final_pred = np.asarray(np.argmax(final_logits, axis=-1))

    # per-sample serving branch: first branch clearing p_tar, else cloud (-1)
    serve = np.full(n, -1)
    for i in range(n_br - 1, -1, -1):
        serve[conf[i] >= p_tar] = i
    # note: loop descends so earliest branch wins

    # per-sample latency
    t = np.zeros(n)
    correct = np.zeros(n, bool)
    for i, br in enumerate(branches):
        m = serve == i
        t[m] = L.edge_time(profile, br)
        # samples at branch i already paid earlier branches' edge layers:
        for j_prev in range(i):
            t[m] += L.edge_time(profile, branches[j_prev])  # conservative
        correct[m] = pred[i][m] == labels[m]
    cloud = serve == -1
    deepest = branches[-1]
    t_edge_all = sum(L.edge_time(profile, b) for b in branches)
    # comm is added per batch below so a time-varying network can reprice it
    t[cloud] = t_edge_all + L.cloud_time(profile, deepest)
    correct[cloud] = final_pred[cloud] == labels[cloud]

    out = []
    stop = n - batch_size + 1 if drop_last else n
    n_batches = len(range(0, stop, batch_size))
    if batch_times_s is not None and len(batch_times_s) < n_batches:
        raise ValueError(
            f"batch_times_s has {len(batch_times_s)} entries but "
            f"{n_batches} batches will run (drop_last={drop_last})"
        )
    for k, s in enumerate(range(0, stop, batch_size)):
        sl = slice(s, min(s + batch_size, n))
        t_b = 0.0 if batch_times_s is None else batch_times_s[k]
        comm = L.comm_time(profile, deepest, network=network, t=t_b)
        out.append(
            BatchOutcome(
                time_s=float((t[sl] + comm * cloud[sl]).mean()),
                accuracy=float(correct[sl].mean()),
                on_device_frac=float((serve[sl] >= 0).mean()),
            )
        )
    return out


def missed_deadline_probability(
    outcomes: Sequence[BatchOutcome], t_tar: float, p_tar: float
) -> float:
    """P(batch time > t_tar OR batch accuracy < p_tar) -- paper Sec. IV-E."""
    miss = [o.time_s > t_tar or o.accuracy < p_tar for o in outcomes]
    return float(np.mean(miss))


def missed_deadline_curve(outcomes, t_tars, p_tar):
    return [missed_deadline_probability(outcomes, t, p_tar) for t in t_tars]
