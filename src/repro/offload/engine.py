"""Edge-cloud partitioned serving engine.

A small serving runtime around the two jitted partitions of a model:

    edge partition  = blocks [0..exit_k] + exit head   (the device)
    cloud partition = blocks [exit_k..L] + main head   (the pod)

Per request batch: the edge partition runs first; the calibrated gate of
the deployed OffloadPlan marks which samples exit on-device; only the
refused samples' partition activations are shipped to the cloud partition
(the payload the paper prices at 18.8 Mbps). The engine gates with the
CalibratorState of the branch that is PHYSICALLY deployed on the edge --
not the plan's default exit -- so a plan calibrated for several exits
always pairs branch-k logits with branch-k calibration. The engine keeps
running statistics (offload rate, per-tier latency estimates) and works
for the convnet (per-image classification, the paper's case) and for the
LM families (per-sequence classification at prefill).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.core.policy import OffloadPlan


@dataclass
class EngineStats:
    requests: int = 0
    on_device: int = 0
    offloaded: int = 0
    payload_bytes: int = 0

    @property
    def offload_rate(self):
        return self.offloaded / max(self.requests, 1)


class OffloadEngine:
    """Generic two-tier engine over (edge_fn, cloud_fn) callables.

    edge_fn(batch)  -> {"exit_logits": (b, C), "payload": pytree}
    cloud_fn(payload_subset) -> {"logits": (m, C)}

    `branch` is the index (into plan.calibrators) of the exit the edge
    partition actually computes; defaults to plan.exit_index. use_kernel
    routes gating through the fused Pallas exit-gate kernel when the
    branch's calibration is pure temperature scaling.
    """

    def __init__(
        self,
        edge_fn: Callable,
        cloud_fn: Callable,
        plan: OffloadPlan,
        payload_nbytes: Optional[Callable[[Any], int]] = None,
        branch: Optional[int] = None,
        use_kernel: bool = False,
    ):
        self.edge_fn = edge_fn
        self.cloud_fn = cloud_fn
        self.plan = plan
        self.branch = plan.exit_index if branch is None else branch
        if not 0 <= self.branch < plan.num_exits:
            raise ValueError(
                f"deployed branch index {self.branch} has no calibrator state "
                f"(plan covers {plan.num_exits} exit(s))"
            )
        self.use_kernel = use_kernel
        self.payload_nbytes = payload_nbytes or (
            lambda p: sum(x.nbytes for x in jax.tree.leaves(p))
        )
        self.stats = EngineStats()

    @property
    def policy(self) -> OffloadPlan:  # legacy name
        return self.plan

    def infer(self, batch) -> Dict[str, np.ndarray]:
        edge_out = self.edge_fn(batch)
        exit_logits = edge_out["exit_logits"]
        gate = self.plan.gate(exit_logits, branch=self.branch,
                              use_kernel=self.use_kernel)
        mask = np.asarray(gate.exit_mask)
        pred = np.asarray(gate.prediction).copy()
        conf = np.asarray(gate.confidence).copy()
        b = mask.shape[0]

        self.stats.requests += b
        self.stats.on_device += int(mask.sum())

        if (~mask).any():
            idx = np.nonzero(~mask)[0]
            payload = jax.tree.map(lambda x: x[idx], edge_out["payload"])
            self.stats.offloaded += len(idx)
            self.stats.payload_bytes += self.payload_nbytes(payload)
            cloud_out = self.cloud_fn(payload)
            cloud_logits = np.asarray(cloud_out["logits"])
            pred[idx] = np.argmax(cloud_logits, axis=-1)
            z = cloud_logits - cloud_logits.max(-1, keepdims=True)
            p = np.exp(z) / np.exp(z).sum(-1, keepdims=True)
            conf[idx] = p.max(-1)
        return {
            "prediction": pred,
            "confidence": conf,
            "on_device": mask,
        }


# ------------------------------------------------------- concrete bindings
def convnet_engine(params, plan: OffloadPlan, branch: int = 1,
                   use_kernel: bool = False) -> OffloadEngine:
    """The paper's system: B-AlexNet split at side branch `branch`.

    Physical branch k (1-based) gates with plan.calibrators[k-1] -- a plan
    calibrated per exit deploys any branch without re-fitting.
    """
    from repro.models import convnet

    @jax.jit
    def edge(batch):
        logits, hidden = convnet.edge_forward(params, batch["images"], branch=branch)
        return {"exit_logits": logits, "payload": hidden}

    @jax.jit
    def cloud(hidden):
        return {"logits": convnet.cloud_forward(params, hidden, from_branch=branch)}

    return OffloadEngine(edge, cloud, plan, branch=branch - 1, use_kernel=use_kernel)


def lm_engine(params, cfg, plan: OffloadPlan, exit_index: int = 0,
              use_kernel: bool = False) -> OffloadEngine:
    """LM variant: classify-at-prefill; edge = blocks up to the exit."""
    from repro.models import transformer

    @jax.jit
    def edge(batch):
        out = transformer.edge_forward(params, cfg, batch, exit_index=exit_index)
        return {"exit_logits": out["exit_logits"][:, 0, :], "payload": out["hidden"]}

    @jax.jit
    def cloud(hidden):
        out = transformer.cloud_forward(params, cfg, hidden, exit_index=exit_index)
        return {"logits": out["logits"][:, 0, :]}

    return OffloadEngine(edge, cloud, plan, branch=exit_index, use_kernel=use_kernel)
