"""Edge-cloud partitioned serving engine.

A small serving runtime around the two jitted partitions of a model:

    edge partition  = blocks [0..exit_k] + exit head   (the device)
    cloud partition = blocks [exit_k..L] + main head   (the pod)

Per request batch: the edge partition runs first; the calibrated gate of
the deployed OffloadPlan marks which samples exit on-device; only the
refused samples' partition activations are shipped to the cloud partition
(the payload the paper prices at 18.8 Mbps). The engine gates with the
CalibratorState of the branch that is PHYSICALLY deployed on the edge --
not the plan's default exit -- so a plan calibrated for several exits
always pairs branch-k logits with branch-k calibration. The engine keeps
running statistics (offload rate, per-tier latency estimates) and works
for the convnet (per-image classification, the paper's case) and for the
LM families (per-sequence classification at prefill).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.core.policy import OffloadPlan


@dataclass
class EngineStats:
    requests: int = 0
    on_device: int = 0
    offloaded: int = 0
    payload_bytes: int = 0
    edge_calls: int = 0
    cloud_calls: int = 0
    edge_time_s: float = 0.0  # wall-clock in edge_fn (blocked on device)
    cloud_time_s: float = 0.0  # wall-clock in cloud_fn

    @property
    def offload_rate(self):
        return self.offloaded / max(self.requests, 1)


class OffloadEngine:
    """Generic two-tier engine over (edge_fn, cloud_fn) callables.

    edge_fn(batch)  -> {"exit_logits": (b, C), "payload": pytree}
    cloud_fn(payload_subset) -> {"logits": (m, C)}

    `branch` is the index (into plan.calibrators) of the exit the edge
    partition actually computes; defaults to plan.exit_index. use_kernel
    routes gating through the fused Pallas exit-gate kernel when the
    branch's calibration is pure temperature scaling.

    The engine is the per-batch compute core of the serving layer: the
    event-driven runtime (repro.serving.runtime) calls `edge_step` and
    `cloud_step` separately so queueing and transfer sit between them on
    the simulated clock. Both steps block until the device is done and
    accumulate wall-clock in EngineStats; `timing_hook(tier, seconds,
    batch_size)` observes every call (tier is "edge" or "cloud").
    """

    def __init__(
        self,
        edge_fn: Callable,
        cloud_fn: Callable,
        plan: OffloadPlan,
        payload_nbytes: Optional[Callable[[Any], int]] = None,
        branch: Optional[int] = None,
        use_kernel: bool = False,
        timing_hook: Optional[Callable[[str, float, int], None]] = None,
    ):
        self.edge_fn = edge_fn
        self.cloud_fn = cloud_fn
        self.plan = plan
        self.branch = plan.exit_index if branch is None else branch
        if not 0 <= self.branch < plan.num_exits:
            raise ValueError(
                f"deployed branch index {self.branch} has no calibrator state "
                f"(plan covers {plan.num_exits} exit(s))"
            )
        self.use_kernel = use_kernel
        self.payload_nbytes = payload_nbytes or (
            lambda p: sum(x.nbytes for x in jax.tree.leaves(p))
        )
        self.timing_hook = timing_hook
        self.stats = EngineStats()

    @property
    def policy(self) -> OffloadPlan:  # legacy name
        return self.plan

    # ------------------------------------------------------- timed steps
    def edge_step(self, batch) -> Dict[str, Any]:
        """Run the edge partition on one request batch (timed, blocking)."""
        t0 = time.perf_counter()
        out = jax.block_until_ready(self.edge_fn(batch))
        dt = time.perf_counter() - t0
        b = int(out["exit_logits"].shape[0])
        self.stats.edge_calls += 1
        self.stats.edge_time_s += dt
        if self.timing_hook is not None:
            self.timing_hook("edge", dt, b)
        return out

    def cloud_step(self, payload) -> Dict[str, Any]:
        """Run the cloud partition on a refused-sample payload (timed)."""
        t0 = time.perf_counter()
        out = jax.block_until_ready(self.cloud_fn(payload))
        dt = time.perf_counter() - t0
        m = int(out["logits"].shape[0])
        self.stats.cloud_calls += 1
        self.stats.cloud_time_s += dt
        if self.timing_hook is not None:
            self.timing_hook("cloud", dt, m)
        return out

    def infer(self, batch) -> Dict[str, np.ndarray]:
        edge_out = self.edge_step(batch)
        exit_logits = edge_out["exit_logits"]
        gate = self.plan.gate(exit_logits, branch=self.branch,
                              use_kernel=self.use_kernel)
        mask = np.asarray(gate.exit_mask)
        pred = np.asarray(gate.prediction).copy()
        conf = np.asarray(gate.confidence).copy()
        b = mask.shape[0]

        self.stats.requests += b
        self.stats.on_device += int(mask.sum())

        if (~mask).any():
            idx = np.nonzero(~mask)[0]
            payload = jax.tree.map(lambda x: x[idx], edge_out["payload"])
            self.stats.offloaded += len(idx)
            level = int(getattr(self.plan, "compression_level", 0))
            if level != 0:
                # the plan priced this deployment at the codec's wire
                # bytes; ship the ACTUAL encoded payload (Pallas kernel,
                # interpret mode off-TPU) and charge its analytic size
                from repro.kernels import compress

                leaves, treedef = jax.tree.flatten(payload)
                encs = [compress.encode(x, level) for x in leaves]
                self.stats.payload_bytes += sum(e.nbytes for e in encs)
                payload = jax.tree.unflatten(
                    treedef, [compress.decode(e) for e in encs]
                )
            else:
                self.stats.payload_bytes += self.payload_nbytes(payload)
            cloud_out = self.cloud_step(payload)
            cloud_logits = np.asarray(cloud_out["logits"])
            pred[idx] = np.argmax(cloud_logits, axis=-1)
            z = cloud_logits - cloud_logits.max(-1, keepdims=True)
            p = np.exp(z) / np.exp(z).sum(-1, keepdims=True)
            conf[idx] = p.max(-1)
        return {
            "prediction": pred,
            "confidence": conf,
            "on_device": mask,
        }


# ------------------------------------------------------- concrete bindings
def convnet_engine(params, plan: OffloadPlan, branch: int = 1,
                   use_kernel: bool = False) -> OffloadEngine:
    """The paper's system: B-AlexNet split at side branch `branch`.

    Physical branch k (1-based) gates with plan.calibrators[k-1] -- a plan
    calibrated per exit deploys any branch without re-fitting.
    """
    from repro.models import convnet

    @jax.jit
    def edge(batch):
        logits, hidden = convnet.edge_forward(params, batch["images"], branch=branch)
        return {"exit_logits": logits, "payload": hidden}

    @jax.jit
    def cloud(hidden):
        return {"logits": convnet.cloud_forward(params, hidden, from_branch=branch)}

    return OffloadEngine(edge, cloud, plan, branch=branch - 1, use_kernel=use_kernel)


def lm_engine(params, cfg, plan: OffloadPlan, exit_index: int = 0,
              use_kernel: bool = False) -> OffloadEngine:
    """LM variant: classify-at-prefill; edge = blocks up to the exit."""
    from repro.models import transformer

    @jax.jit
    def edge(batch):
        out = transformer.edge_forward(params, cfg, batch, exit_index=exit_index)
        return {"exit_logits": out["exit_logits"][:, 0, :], "payload": out["hidden"]}

    @jax.jit
    def cloud(hidden):
        out = transformer.cloud_forward(params, cfg, hidden, exit_index=exit_index)
        return {"logits": out["logits"][:, 0, :]}

    return OffloadEngine(edge, cloud, plan, branch=exit_index, use_kernel=use_kernel)
