"""Latency models for the edge-cloud system (paper Sec. IV-E).

The paper's setup:
  * edge compute: per-layer AlexNet delays on an Intel i7 CPU, taken from
    Colburn et al. [16];
  * cloud compute: Google Colab K80 GPU;
  * uplink: 18.8 Mbps average Wi-Fi rate from Hu et al. [7];
  * communication delay = payload bytes / uplink rate.

Those constants ship as the `paper_2020` profile. Because no per-layer i7
table is printed in either paper, the edge numbers are derived from layer
FLOPs at the i7's measured effective throughput for AlexNet conv layers
(~12 GFLOP/s dense f32) -- the simulator consumes profiles as plain data,
so measured tables drop in unchanged. A `tpu_v5e` profile transposes the
same structure to intra-pod tiered serving (ICI instead of Wi-Fi).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.kernels.compress import LEVELS as COMPRESSION_LEVELS
from repro.kernels.compress import scaled_payload_nbytes
from repro.models.convnet import LAYER_TABLE, payload_bytes


@dataclass(frozen=True)
class LatencyProfile:
    name: str
    edge_layer_s: Dict[str, float]  # per-layer edge compute time (s/sample)
    cloud_layer_s: Dict[str, float]  # per-layer cloud compute time (s/sample)
    branch_s: Dict[str, float]  # per-branch head time on the edge
    uplink_bps: float
    # energy model (defaults so existing profile constructors are
    # untouched): radio energy per transmitted bit + edge compute power.
    # 50 nJ/bit is a Wi-Fi-class radio figure; 2 W a mobile SoC under a
    # conv workload. Energy per request = edge compute J + payload
    # bits * J/bit -- additive telemetry, never priced into latency.
    uplink_j_per_bit: float = 50e-9
    edge_power_w: float = 2.0


def _alexnet_layer_flops() -> Dict[str, float]:
    """Per-sample forward FLOPs for the 32x32 B-AlexNet of convnet.py."""
    flops = {}
    hw = {"conv1": 32, "conv2": 16, "conv3": 8, "conv4": 8, "conv5": 8}
    for name, kind, spec in LAYER_TABLE:
        if kind == "conv":
            s = hw[name]
            flops[name] = 2.0 * s * s * spec["k"] ** 2 * spec["cin"] * spec["cout"]
        else:
            flops[name] = 2.0 * spec["din"] * spec["dout"]
    return flops


def paper_2020() -> LatencyProfile:
    """The paper's constants: i7 edge, K80 cloud, 18.8 Mbps uplink."""
    flops = _alexnet_layer_flops()
    EDGE_GFLOPS = 12e9  # i7 effective on small convs [16]
    CLOUD_GFLOPS = 240e9  # K80 effective (fp32, small batches)
    edge = {k: v / EDGE_GFLOPS for k, v in flops.items()}
    cloud = {k: v / CLOUD_GFLOPS for k, v in flops.items()}
    branch_flops = {
        "branch1": 2.0 * 16 * 16 * 9 * 64 * 32 + 2.0 * 32 * 8 * 8 * 10,
        "branch2": 2.0 * 8 * 8 * 9 * 96 * 32 + 2.0 * 32 * 4 * 4 * 10,
    }
    branch = {k: v / EDGE_GFLOPS for k, v in branch_flops.items()}
    return LatencyProfile(
        name="paper_2020",
        edge_layer_s=edge,
        cloud_layer_s=cloud,
        branch_s=branch,
        uplink_bps=18.8e6,  # [7]'s Wi-Fi scenario, as used in the paper
    )


def tpu_v5e(edge_chips: int = 4, cloud_chips: int = 256) -> LatencyProfile:
    """Hardware-adaptation profile: a small edge tier and a pod cloud tier
    connected by ICI (~50 GB/s/link) -- same structure, new constants."""
    flops = _alexnet_layer_flops()
    EDGE = edge_chips * 197e12 * 0.3  # bf16 peak x small-batch efficiency
    CLOUD = cloud_chips * 197e12 * 0.3
    return LatencyProfile(
        name="tpu_v5e",
        edge_layer_s={k: v / EDGE for k, v in flops.items()},
        cloud_layer_s={k: v / CLOUD for k, v in flops.items()},
        branch_s={"branch1": 1e-7, "branch2": 1e-7},
        uplink_bps=50e9 * 8,
    )


# ------------------------------------------------------------- path timings
EDGE_LAYERS_BY_BRANCH = {1: ["conv1"], 2: ["conv1", "conv2"]}
CLOUD_LAYERS_BY_BRANCH = {
    1: ["conv2", "conv3", "conv4", "conv5", "fc1", "fc2", "fc3"],
    2: ["conv3", "conv4", "conv5", "fc1", "fc2", "fc3"],
}


def edge_time(profile: LatencyProfile, branch: int) -> float:
    """Per-sample time to reach + evaluate branch `branch` on the edge."""
    t = sum(profile.edge_layer_s[l] for l in EDGE_LAYERS_BY_BRANCH[branch])
    t += profile.branch_s[f"branch{branch}"]
    return t


def cloud_time(profile: LatencyProfile, from_branch: int) -> float:
    return sum(profile.cloud_layer_s[l] for l in CLOUD_LAYERS_BY_BRANCH[from_branch])


def payload_bytes_for(branch: int, level: int = 0) -> int:
    """THE (branch, level) -> wire bytes entry for the B-AlexNet payloads:
    the raw float32 activation at level 0 (bit-identical to the paper's
    pricing), the codec's analytic compressed size otherwise. Every
    latency/pricing surface reads payload sizes from here instead of
    recomputing tensor nbytes at call sites."""
    return scaled_payload_nbytes(payload_bytes(branch), level)


def payload_bytes_table(
    payload_nbytes: Optional[Callable[[int], int]] = None,
    branches: Tuple[int, ...] = (1, 2),
    levels: Tuple[int, ...] = COMPRESSION_LEVELS,
) -> Dict[Tuple[int, int], int]:
    """Dense (branch, level) -> wire bytes table. `payload_nbytes` maps a
    branch to its RAW float32 payload size (default: the B-AlexNet
    activations); compressed levels derive analytically from the codec's
    wire format, so pricing never touches a tensor."""
    raw = payload_nbytes or payload_bytes
    return {
        (b, l): scaled_payload_nbytes(raw(b), l)
        for b in branches for l in levels
    }


def energy_per_request_j(
    profile: LatencyProfile, edge_time_s: float, payload_nbytes: float = 0.0
) -> float:
    """Edge-side energy for one request: compute J + radio J for the
    shipped payload (0 bytes for an on-device answer)."""
    return (edge_time_s * profile.edge_power_w
            + payload_nbytes * 8.0 * profile.uplink_j_per_bit)


def comm_time(
    profile: LatencyProfile, from_branch: int, network=None, t: float = 0.0,
    level: int = 0,
) -> float:
    """Per-sample uplink time for branch `from_branch`'s activation at
    compression `level` (0 = the raw float32 payload, numerically the
    paper's constant).

    With `network` (a `repro.serving.network.NetworkModel`) the transfer is
    priced at the link's instantaneous rate at time `t`; the default is the
    profile's fixed uplink -- the paper's 18.8 Mbps constant, numerically
    unchanged.
    """
    nbytes = payload_bytes_for(from_branch, level)
    if network is None:
        return nbytes * 8.0 / profile.uplink_bps
    return network.comm_time(nbytes, t)
