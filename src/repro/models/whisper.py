"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a stub: ``input_specs`` provides precomputed frame embeddings of shape
(batch, encoder_seq, d_model). This module implements the transformer
backbone: a bidirectional encoder over frames and a causal decoder with
cross-attention, learned absolute position embeddings, LayerNorm + GELU
(the Whisper recipe), plus early-exit side branches on decoder blocks.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    apply_embed,
    apply_mlp,
    apply_norm,
    apply_unembed,
    cdtype,
    init_embed,
    init_mlp,
    init_norm,
    init_unembed,
)


def _init_enc_block(key, cfg):
    ks = jax.random.split(key, 4)
    return {
        "mixer_norm": init_norm(ks[0], cfg),
        "attn": attn.init_attention(ks[1], cfg),
        "ffn_norm": init_norm(ks[2], cfg),
        "mlp": init_mlp(ks[3], cfg),
    }


def _init_dec_block(key, cfg):
    ks = jax.random.split(key, 6)
    return {
        "mixer_norm": init_norm(ks[0], cfg),
        "attn": attn.init_attention(ks[1], cfg),
        "cross_norm": init_norm(ks[2], cfg),
        "cross_attn": attn.init_attention(ks[3], cfg, cross=True),
        "ffn_norm": init_norm(ks[4], cfg),
        "mlp": init_mlp(ks[5], cfg),
    }


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    dt = cdtype(cfg)
    params: Dict[str, Any] = {
        "embed": init_embed(ks[0], cfg),
        "enc_pos_embed": (
            jax.random.normal(ks[1], (cfg.encoder_seq, cfg.d_model)) * 0.02
        ).astype(dt),
        "pos_embed": (
            jax.random.normal(ks[2], (cfg.max_position_embeddings, cfg.d_model)) * 0.02
        ).astype(dt),
        "enc_blocks": [
            _init_enc_block(k, cfg)
            for k in jax.random.split(ks[3], cfg.encoder_layers)
        ],
        "dec_blocks": [
            _init_dec_block(k, cfg) for k in jax.random.split(ks[4], cfg.num_layers)
        ],
        "enc_final_norm": init_norm(ks[5], cfg),
        "final_norm": init_norm(ks[6], cfg),
        "lm_head": init_unembed(ks[7], cfg),
    }
    exit_keys = jax.random.split(ks[7], max(len(cfg.exit_layers), 1))
    params["exits"] = [
        {"norm": init_norm(ek, cfg), "head": init_unembed(ek, cfg)}
        for ek in exit_keys[: len(cfg.exit_layers)]
    ]
    return params


def encode(params, cfg, frames):
    """frames: (b, enc_seq, d) stubbed frontend output -> encoder memory."""
    x = frames + params["enc_pos_embed"][None]
    x = sharding.constrain(x, "dp", None, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    for blk in params["enc_blocks"]:
        h = apply_norm(blk["mixer_norm"], cfg, x)
        # bidirectional: pass memory=h so no causal mask is applied
        h, _ = attn.attention_prefill(blk["attn"], cfg, h, positions, memory=h)
        x = x + h
        h = apply_norm(blk["ffn_norm"], cfg, x)
        x = x + apply_mlp(blk["mlp"], cfg, h)
        x = sharding.constrain(x, "dp", None, None)
    return apply_norm(params["enc_final_norm"], cfg, x)


def _dec_block_seq(blk, cfg, x, positions, memory):
    h = apply_norm(blk["mixer_norm"], cfg, x)
    h, cache = attn.attention_prefill(blk["attn"], cfg, h, positions)
    x = x + h
    h = apply_norm(blk["cross_norm"], cfg, x)
    h, xcache = attn.attention_prefill(blk["cross_attn"], cfg, h, positions, memory=memory)
    x = x + h
    h = apply_norm(blk["ffn_norm"], cfg, x)
    x = x + apply_mlp(blk["mlp"], cfg, h)
    return sharding.constrain(x, "dp", None, None), cache, xcache


def forward_train(params, cfg: ModelConfig, batch, remat: bool = True):
    """batch: {tokens (b,s), encoder_frames (b,enc_seq,d)}."""
    memory = encode(params, cfg, batch["encoder_frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = apply_embed(params["embed"], tokens) + params["pos_embed"][:s][None]
    x = sharding.constrain(x, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    exit_hiddens = []
    exits = set(cfg.exit_layers)
    block_fn = _dec_block_seq
    if remat:
        block_fn = jax.checkpoint(_dec_block_seq, static_argnums=(1,))
    for i, blk in enumerate(params["dec_blocks"]):
        x, _, _ = block_fn(blk, cfg, x, positions, memory)
        if i in exits:
            exit_hiddens.append(x)
    h = apply_norm(params["final_norm"], cfg, x)
    logits = apply_unembed(params["lm_head"], h)
    ex_logits = []
    for i, eh in enumerate(exit_hiddens):
        ep = params["exits"][i]
        ex_logits.append(
            apply_unembed(ep["head"], apply_norm(ep["norm"], cfg, eh))
        )
    return {
        "logits": sharding.constrain(logits, "dp", None, "tp"),
        "exit_logits": ex_logits,
        "moe_aux_loss": jnp.zeros((), jnp.float32),
    }


def forward_prefill(params, cfg: ModelConfig, batch):
    """Serving prefill: encode frames + teacher-forced decoder pass.

    Returns last-position logits, per-exit last-position logits, and the
    decode caches (self-attn KV + projected cross-attn memory)."""
    memory = encode(params, cfg, batch["encoder_frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = apply_embed(params["embed"], tokens) + params["pos_embed"][:s][None]
    x = sharding.constrain(x, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    exits = set(cfg.exit_layers)
    exit_hiddens = []
    self_caches, cross_caches = [], []
    for i, blk in enumerate(params["dec_blocks"]):
        x, cache, xcache = _dec_block_seq(blk, cfg, x, positions, memory)
        self_caches.append(cache)
        cross_caches.append(xcache)
        if i in exits:
            exit_hiddens.append(x)
    h = apply_norm(params["final_norm"], cfg, x[:, -1:, :])
    logits = apply_unembed(params["lm_head"], h)
    ex_logits = []
    for i, eh in enumerate(exit_hiddens):
        ep = params["exits"][i]
        ex_logits.append(
            apply_unembed(ep["head"], apply_norm(ep["norm"], cfg, eh[:, -1:, :]))
        )
    return {
        "logits": logits,
        "exit_logits": ex_logits,
        "caches": {"self": self_caches, "cross": cross_caches},
    }


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    """Self-attn KV caches + projected cross-attn memory caches."""
    dt = cdtype(cfg)
    mem_kv = {
        "k": jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), dt),
    }
    return {
        "self": [
            attn.init_kv_cache(cfg, batch, seq_len) for _ in range(cfg.num_layers)
        ],
        "cross": [jax.tree.map(jnp.copy, mem_kv) for _ in range(cfg.num_layers)],
    }


def prefill_cross_caches(params, cfg, frames):
    """Encode + project cross-attn K/V once per request (serving)."""
    memory = encode(params, cfg, frames)
    cross = []
    for blk in params["dec_blocks"]:
        k = jnp.einsum("bsd,dhk->bshk", memory, blk["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", memory, blk["cross_attn"]["wv"])
        cross.append({"k": k, "v": v})
    return cross


def decode_step(params, cfg: ModelConfig, token, caches, pos):
    x = apply_embed(params["embed"], token)
    x = x + params["pos_embed"][pos][None, None, :]
    x = sharding.constrain(x, "dp", None, None)
    exits = set(cfg.exit_layers)
    exit_hiddens = []
    new_self = []
    for i, blk in enumerate(params["dec_blocks"]):
        h = apply_norm(blk["mixer_norm"], cfg, x)
        h, c = attn.attention_decode(blk["attn"], cfg, h, caches["self"][i], pos)
        new_self.append(c)
        x = x + h
        h = apply_norm(blk["cross_norm"], cfg, x)
        h, _ = attn.attention_decode(
            blk["cross_attn"], cfg, h, None, pos, memory_cache=caches["cross"][i]
        )
        x = x + h
        h = apply_norm(blk["ffn_norm"], cfg, x)
        x = x + apply_mlp(blk["mlp"], cfg, h)
        if i in exits:
            exit_hiddens.append(x)
    h = apply_norm(params["final_norm"], cfg, x)
    logits = apply_unembed(params["lm_head"], h)
    ex_logits = []
    for i, eh in enumerate(exit_hiddens):
        ep = params["exits"][i]
        ex_logits.append(apply_unembed(ep["head"], apply_norm(ep["norm"], cfg, eh)))
    out = {"logits": logits, "exit_logits": ex_logits}
    return out, {"self": new_self, "cross": caches["cross"]}
