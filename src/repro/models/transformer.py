"""Decoder-only transformer stack with early-exit side branches.

Covers the dense / GQA / MoE / SSM / hybrid / VLM families of the assigned
architectures through ModelConfig.layer_plan(). The stack is organised into
*segments*: maximal runs of layers with identical (mixer, ffn) kind that do
not cross an early-exit boundary. Homogeneous segments are scanned
(jax.lax.scan over stacked params) so an 80-layer dense model compiles as one
scanned block -- essential for dry-run compile times -- while hybrid models
(Jamba) fall out as per-layer segments naturally.

Early exits (the paper's technique): after segment boundaries listed in
cfg.exit_layers, an exit head (norm + unembed) produces side-branch logits.
The stack returns them all; gating/calibration live in repro.core.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models.layers import (
    apply_embed,
    apply_mlp,
    apply_norm,
    apply_unembed,
    cdtype,
    init_embed,
    init_mlp,
    init_norm,
    init_unembed,
)


# ---------------------------------------------------------------- segmentation
def segment_plan(cfg: ModelConfig):
    """[(kind=(mixer,ffn), n_layers, exit_after: bool)] covering all layers."""
    plan = cfg.layer_plan()
    exits = set(cfg.exit_layers)
    segs = []
    start = 0
    for i in range(cfg.num_layers):
        boundary = (
            i + 1 == cfg.num_layers
            or plan[i + 1] != plan[i]
            or i in exits
        )
        if boundary:
            segs.append((plan[i], i - start + 1, i in exits))
            start = i + 1
    return segs


# ------------------------------------------------------------------- one block
def init_block(key, cfg, kind):
    mixer, ffn = kind
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"mixer_norm": init_norm(ks[0], cfg)}
    if mixer == "attn":
        p["attn"] = attn.init_attention(ks[1], cfg)
    else:
        p["mamba"] = mb.init_mamba(ks[1], cfg)
    if ffn != "none":
        p["ffn_norm"] = init_norm(ks[2], cfg)
        if ffn == "dense":
            p["mlp"] = init_mlp(ks[3], cfg)
        else:
            from repro.models.moe import init_moe

            p["moe"] = init_moe(ks[3], cfg)
    return p


def apply_block_seq(p, cfg, kind, x, positions):
    """Full-sequence (train/prefill) block. Returns (x, cache, aux)."""
    mixer, ffn = kind
    h = apply_norm(p["mixer_norm"], cfg, x)
    if mixer == "attn":
        h, cache = attn.attention_prefill(p["attn"], cfg, h, positions)
    else:
        h, cache = mb.mamba_prefill(p["mamba"], cfg, h)
    x = x + h
    aux = {}
    if ffn != "none":
        h = apply_norm(p["ffn_norm"], cfg, x)
        if ffn == "dense":
            h = apply_mlp(p["mlp"], cfg, h)
        else:
            from repro.models.moe import apply_moe

            h, aux = apply_moe(p["moe"], cfg, h)
        x = x + h
    x = sharding.constrain(x, "dp", None, None)
    return x, cache, aux


def apply_block_decode(p, cfg, kind, x, cache, pos):
    mixer, ffn = kind
    h = apply_norm(p["mixer_norm"], cfg, x)
    if mixer == "attn":
        h, cache = attn.attention_decode(p["attn"], cfg, h, cache, pos)
    else:
        h, cache = mb.mamba_decode(p["mamba"], cfg, h, cache, pos)
    x = x + h
    if ffn != "none":
        h = apply_norm(p["ffn_norm"], cfg, x)
        if ffn == "dense":
            h = apply_mlp(p["mlp"], cfg, h)
        else:
            from repro.models.moe import apply_moe

            h, _ = apply_moe(p["moe"], cfg, h)
        x = x + h
    x = sharding.constrain(x, "dp", None, None)
    return x, cache


def _apply_block_decode_stacked(p, cfg, kind, x, cache, pos, layer_idx):
    """Unrolled-decode block against a stacked (n_layers, ...) cache."""
    mixer, ffn = kind
    h = apply_norm(p["mixer_norm"], cfg, x)
    if mixer == "attn":
        h, cache = attn.attention_decode_stacked(p["attn"], cfg, h, cache, pos, layer_idx)
    else:
        # mamba state IS the full per-layer payload: slice, update, write back
        layer_c = jax.tree.map(lambda a: a[layer_idx], cache)
        h, layer_c = mb.mamba_decode(p["mamba"], cfg, h, layer_c, pos)
        cache = jax.tree.map(
            lambda a, l: jax.lax.dynamic_update_slice_in_dim(
                a, l[None].astype(a.dtype), layer_idx, axis=0
            ),
            cache,
            layer_c,
        )
    x = x + h
    if ffn != "none":
        h = apply_norm(p["ffn_norm"], cfg, x)
        if ffn == "dense":
            h = apply_mlp(p["mlp"], cfg, h)
        else:
            from repro.models.moe import apply_moe

            h, _ = apply_moe(p["moe"], cfg, h)
        x = x + h
    x = sharding.constrain(x, "dp", None, None)
    return x, cache


def init_block_cache(cfg, kind, batch, seq_len):
    mixer, _ = kind
    if mixer == "attn":
        return attn.init_kv_cache(cfg, batch, seq_len)
    return mb.init_mamba_cache(cfg, batch)


# ------------------------------------------------------------------- the model
def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    segs = segment_plan(cfg)
    params: Dict[str, Any] = {"embed": init_embed(ks[0], cfg)}
    if cfg.max_position_embeddings:
        params["pos_embed"] = (
            jax.random.normal(ks[1], (cfg.max_position_embeddings, cfg.d_model)) * 0.02
        ).astype(cdtype(cfg))
    seg_params = []
    seg_keys = jax.random.split(ks[2], len(segs))
    for (kind, n, _), sk in zip(segs, seg_keys):
        if n == 1:
            seg_params.append(init_block(sk, cfg, kind))
        else:
            seg_params.append(
                jax.vmap(lambda k: init_block(k, cfg, kind))(jax.random.split(sk, n))
            )
    params["segments"] = seg_params
    params["final_norm"] = init_norm(ks[3], cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_unembed(ks[4], cfg)
    exit_keys = jax.random.split(ks[5], max(len(cfg.exit_layers), 1))
    params["exits"] = [
        {"norm": init_norm(ek, cfg), "head": init_unembed(ek, cfg)}
        for ek in exit_keys[: len(cfg.exit_layers)]
    ]
    return params


def _lm_logits(params, cfg, x):
    h = apply_norm(params["final_norm"], cfg, x)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["w"].T
    else:
        logits = apply_unembed(params["lm_head"], h)
    return sharding.constrain(logits, "dp", None, "tp")


def exit_logits_fn(params, cfg, i, x):
    ep = params["exits"][i]
    h = apply_norm(ep["norm"], cfg, x)
    logits = apply_unembed(ep["head"], h)
    return sharding.constrain(logits, "dp", None, "tp")


def _run_segments_seq(params, cfg, x, positions, remat: bool):
    """Returns (x, exit_hiddens, aux_sum, caches)."""
    segs = segment_plan(cfg)
    exit_hiddens: List[Any] = []
    caches: List[Any] = []
    aux_sum = jnp.zeros((), jnp.float32)
    for sp, (kind, n, exit_after) in zip(params["segments"], segs):
        if n == 1:
            body = apply_block_seq
            if remat:
                body = jax.checkpoint(body, static_argnums=(1, 2))
            x, cache, aux = body(sp, cfg, kind, x, positions)
            if "moe_aux_loss" in aux:
                aux_sum = aux_sum + aux["moe_aux_loss"]
            caches.append(cache)
        else:

            def scan_body(carry, layer_p, _kind=kind):
                xx, acc = carry
                xx, cache, aux = apply_block_seq(layer_p, cfg, _kind, xx, positions)
                acc = acc + aux.get("moe_aux_loss", jnp.zeros((), jnp.float32))
                return (xx, acc), cache

            if remat:
                scan_body = jax.checkpoint(scan_body)
            (x, aux_sum), cache = jax.lax.scan(scan_body, (x, aux_sum), sp)
            caches.append(cache)
        if exit_after:
            exit_hiddens.append(x)
    return x, exit_hiddens, aux_sum, caches


def forward_train(params, cfg: ModelConfig, batch, remat: bool = True):
    """batch: {tokens (b, s) int32, ...}. Returns logits dict for the loss."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = apply_embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.max_position_embeddings:
        x = x + params["pos_embed"][:s][None]
    x = sharding.constrain(x, "dp", None, None)
    x, exit_hiddens, aux_sum, _ = _run_segments_seq(params, cfg, x, positions, remat)
    logits = _lm_logits(params, cfg, x)
    ex_logits = [
        exit_logits_fn(params, cfg, i, h) for i, h in enumerate(exit_hiddens)
    ]
    return {"logits": logits, "exit_logits": ex_logits, "moe_aux_loss": aux_sum}


def forward_prefill(params, cfg: ModelConfig, batch):
    """Prefill: full sequence, returns last-position logits + caches + exits."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = apply_embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.max_position_embeddings:
        x = x + params["pos_embed"][:s][None]
    x = sharding.constrain(x, "dp", None, None)
    x, exit_hiddens, _, caches = _run_segments_seq(params, cfg, x, positions, False)
    logits = _lm_logits(params, cfg, x[:, -1:, :])
    ex_logits = [
        exit_logits_fn(params, cfg, i, h[:, -1:, :])
        for i, h in enumerate(exit_hiddens)
    ]
    return {"logits": logits, "exit_logits": ex_logits, "caches": caches}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    segs = segment_plan(cfg)
    caches = []
    for kind, n, _ in segs:
        c = init_block_cache(cfg, kind, batch, seq_len)
        if n > 1:
            c = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), c)
        caches.append(c)
    return caches


def decode_step(params, cfg: ModelConfig, token, caches, pos):
    """token: (b, 1) int32; pos: scalar int32. Returns (out, new_caches).

    out: {"logits": (b,1,V), "exit_logits": [(b,1,V)...]}
    """
    segs = segment_plan(cfg)
    x = apply_embed(params["embed"], token)
    if cfg.max_position_embeddings:
        x = x + params["pos_embed"][pos][None, None, :]
    x = sharding.constrain(x, "dp", None, None)
    new_caches = []
    exit_hiddens = []
    for sp, cache, (kind, n, exit_after) in zip(params["segments"], caches, segs):
        if n == 1:
            x, cache = apply_block_decode(sp, cfg, kind, x, cache, pos)
        elif cfg.decode_unroll:
            # perf-pass decode: unrolled layers + in-place stacked-cache
            # updates (no scan carry write-back; see EXPERIMENTS.md #Perf)
            for i in range(n):
                layer_p = jax.tree.map(lambda a: a[i], sp)
                x, cache = _apply_block_decode_stacked(
                    layer_p, cfg, kind, x, cache, pos, i
                )
        else:

            def scan_body(xx, inp, _kind=kind):
                layer_p, layer_c = inp
                xx, layer_c = apply_block_decode(layer_p, cfg, _kind, xx, layer_c, pos)
                return xx, layer_c

            x, cache = jax.lax.scan(scan_body, x, (sp, cache))
        new_caches.append(cache)
        if exit_after:
            exit_hiddens.append(x)
    logits = _lm_logits(params, cfg, x)
    ex_logits = [
        exit_logits_fn(params, cfg, i, h) for i, h in enumerate(exit_hiddens)
    ]
    return {"logits": logits, "exit_logits": ex_logits}, new_caches


# ----------------------------------------------------- partitioned execution
def edge_forward(params, cfg: ModelConfig, batch, exit_index: int = 0):
    """The *edge partition*: blocks up to exit `exit_index` + that exit head.

    Returns {"exit_logits": (b,1,V) last position, "hidden": (b,s,d), "caches"}.
    The hidden is the partition payload the offloading engine ships to the
    cloud partition when the gate refuses the sample.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = apply_embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.max_position_embeddings:
        x = x + params["pos_embed"][:s][None]
    segs = segment_plan(cfg)
    caches = []
    n_exits_seen = 0
    for sp, (kind, n, exit_after) in zip(params["segments"], segs):
        if n == 1:
            x, cache, _ = apply_block_seq(sp, cfg, kind, x, positions)
        else:

            def scan_body(xx, layer_p, _kind=kind):
                xx, cache, _ = apply_block_seq(layer_p, cfg, _kind, xx, positions)
                return xx, cache

            x, cache = jax.lax.scan(scan_body, x, sp)
        caches.append(cache)
        if exit_after:
            if n_exits_seen == exit_index:
                logits = exit_logits_fn(params, cfg, n_exits_seen, x[:, -1:, :])
                return {"exit_logits": logits, "hidden": x, "caches": caches}
            n_exits_seen += 1
    raise ValueError(f"exit_index {exit_index} not found in {cfg.name}")


def cloud_forward(params, cfg: ModelConfig, hidden, exit_index: int = 0):
    """The *cloud partition*: remaining blocks after exit `exit_index`."""
    b, s, _ = hidden.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    segs = segment_plan(cfg)
    x = hidden
    n_exits_seen = 0
    started = False
    for sp, (kind, n, exit_after) in zip(params["segments"], segs):
        if started:
            if n == 1:
                x, _, _ = apply_block_seq(sp, cfg, kind, x, positions)
            else:

                def scan_body(xx, layer_p, _kind=kind):
                    xx, cache, _ = apply_block_seq(layer_p, cfg, _kind, xx, positions)
                    return xx, cache

                x, _ = jax.lax.scan(scan_body, x, sp)
        if exit_after and not started:
            if n_exits_seen == exit_index:
                started = True
            n_exits_seen += 1
    return {"logits": _lm_logits(params, cfg, x[:, -1:, :])}
