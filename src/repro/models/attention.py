"""GQA attention: chunked causal prefill + KV-cache decode.

Features driven by ModelConfig: grouped-query attention (num_kv_heads <
num_heads), qk-norm (Qwen3), QKV bias (Qwen2), sliding-window masking
(used for long-context decode on dense archs), RoPE or no-PE (Whisper uses
learned absolute embeddings applied outside).

Prefill uses a lax.scan over query chunks with an O(chunk x seq) working set
(flash-attention-style restructuring, implemented at the XLA level; the
per-chunk body is rematerialized in the backward pass). Decode uses a
ring-buffer cache when a sliding window is configured so the cache size is
min(seq_len, window) -- the steady-state memory of windowed attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, cdtype, rms_norm_headwise, rope_freqs

NEG_INF = -1e30


def init_attention(key, cfg, cross=False):
    d, hd, qh, kvh = cfg.d_model, cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    dt = cdtype(cfg)
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    so = (qh * hd) ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, qh, hd)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, kvh, hd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, kvh, hd)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (qh, hd, d)) * so).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qh, hd), dt)
        p["bk"] = jnp.zeros((kvh, hd), dt)
        p["bv"] = jnp.zeros((kvh, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(p, cfg, x, positions, rope=True):
    """x: (b, s, d) -> q (b,s,qh,hd), k/v (b,s,kvh,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm_headwise(q, p["q_norm"])
        k = rms_norm_headwise(k, p["k_norm"])
    if rope and cfg.use_rope:
        cos, sin = rope_freqs(cfg, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _gqa_scores(q, k):
    """q: (b,sq,qh,hd) k: (b,sk,kvh,hd) -> (b,kvh,g,sq,sk) fp32."""
    b, sq, qh, hd = q.shape
    kvh = k.shape[2]
    g = qh // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bqhgk,bshk->bhgqs", qg, k).astype(jnp.float32)
    return s * (hd ** -0.5)


def _gqa_out(probs, v):
    """probs: (b,kvh,g,sq,sk) fp32; v: (b,sk,kvh,hd) -> (b,sq,qh,hd)."""
    b, kvh, g, sq, sk = probs.shape
    hd = v.shape[-1]
    o = jnp.einsum("bhgqs,bshk->bqhgk", probs.astype(v.dtype), v)
    return o.reshape(b, sq, kvh * g, hd)


def attention_prefill(p, cfg, x, positions, q_chunk=1024, memory=None):
    """Causal (optionally sliding-window) self-attention over a full sequence.

    x: (b, s, d); positions: (b, s) int32. Returns (out (b,s,d), cache).
    ``memory``: if given (cross-attention), attend to it instead (no mask).
    """
    b, s, d = x.shape
    if memory is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
        scores = _gqa_scores(q, k)
        probs = jax.nn.softmax(scores, axis=-1)
        o = _gqa_out(probs, v)
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), {"k": k, "v": v}

    q, k, v = _project_qkv(p, cfg, x, positions)

    q_chunk = min(q_chunk, s)
    n_chunks = s // q_chunk if s % q_chunk == 0 else 0
    if n_chunks <= 1:
        out = _attend_block(cfg, q, k, v, positions, positions)
    else:
        qc = q.reshape(b, n_chunks, q_chunk, *q.shape[2:]).transpose(1, 0, 2, 3, 4)
        pc = positions.reshape(b, n_chunks, q_chunk).transpose(1, 0, 2)

        @jax.checkpoint
        def body(carry, qp):
            qi, pi = qp
            return carry, _attend_block(cfg, qi, k, v, pi, positions)

        _, outs = jax.lax.scan(body, None, (qc, pc))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, q.shape[2], q.shape[3])
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return proj, {"k": k, "v": v}


def _attend_block(cfg, q, k, v, q_pos, k_pos):
    """q: (b,sq,qh,hd); k/v: (b,sk,kvh,hd); positions (b,sq)/(b,sk)."""
    scores = _gqa_scores(q, k)  # (b,kvh,g,sq,sk)
    mask = q_pos[:, :, None] >= k_pos[:, None, :]  # causal (b,sq,sk)
    if cfg.sliding_window:
        mask &= (q_pos[:, :, None] - k_pos[:, None, :]) < cfg.sliding_window
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v)


def attention_decode_stacked(p, cfg, x, cache, pos, layer_idx):
    """Decode against a STACKED multi-layer cache (perf-pass decode path).

    cache: {"k"/"v": (n_layers, b, L, kvh, hd)}. The new token's K/V are
    written with ONE dynamic-update-slice directly into the stacked buffer
    (64 KB-scale write) instead of rebuilding the layer cache and writing
    it back through the scan carry (134 MB-scale write per layer at 32k) --
    the memory-term optimization of EXPERIMENTS.md #Perf.
    """
    positions = jnp.broadcast_to(pos, (x.shape[0], 1)).astype(jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)
    L = cache["k"].shape[2]
    slot = (pos % L).astype(jnp.int32) if cfg.sliding_window else pos.astype(jnp.int32)
    li = jnp.int32(layer_idx)
    zero = jnp.int32(0)
    ck = jax.lax.dynamic_update_slice(cache["k"], k[None], (li, zero, slot, zero, zero))
    cv = jax.lax.dynamic_update_slice(cache["v"], v[None], (li, zero, slot, zero, zero))
    layer_k = jax.lax.dynamic_slice_in_dim(ck, layer_idx, 1, axis=0)[0]
    layer_v = jax.lax.dynamic_slice_in_dim(cv, layer_idx, 1, axis=0)[0]

    scores = _gqa_scores(q, layer_k)
    idx = jnp.arange(L)
    if cfg.sliding_window:
        age = (slot - idx) % L
        valid = age < jnp.minimum(pos + 1, L)
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(probs, layer_v)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": ck, "v": cv}


# --------------------------------------------------------------------- decode
def init_kv_cache(cfg, batch, seq_len):
    """Decode cache. Sliding window => ring buffer of window size."""
    L = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    dt = cdtype(cfg)
    return {
        "k": jnp.zeros((batch, L, cfg.num_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, L, cfg.num_kv_heads, cfg.head_dim), dt),
    }


def attention_decode(p, cfg, x, cache, pos, memory_cache=None):
    """One-token decode. x: (b, 1, d); pos: scalar int32 (same for batch).

    Returns (out (b,1,d), new_cache).
    ``memory_cache``: projected cross-attn K/V (Whisper decoder) -> attends to
    it with no mask and does not update any cache.
    """
    if memory_cache is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        scores = _gqa_scores(q, memory_cache["k"])
        probs = jax.nn.softmax(scores, axis=-1)
        o = _gqa_out(probs, memory_cache["v"])
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), cache

    positions = jnp.broadcast_to(pos, (x.shape[0], 1)).astype(jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)

    L = cache["k"].shape[1]
    slot = (pos % L).astype(jnp.int32) if cfg.sliding_window else pos.astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))

    scores = _gqa_scores(q, ck)  # (b,kvh,g,1,L)
    idx = jnp.arange(L)
    if cfg.sliding_window:
        # ring buffer: entry i holds absolute position p with p % L == i, the
        # latest such p <= pos. Valid iff that p is within the window.
        age = (slot - idx) % L
        valid = age < jnp.minimum(pos + 1, L)
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(probs, cv)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": ck, "v": cv}
