"""Basic layers: norms, MLPs, embeddings, rotary embeddings.

Pure-functional style: ``init_*`` builds a params pytree (nested dicts of
jnp arrays); ``apply`` functions consume it. Compute follows the usual mixed
precision discipline: params and matmuls in cfg.dtype (bf16), normalization
and softmax statistics in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}


def cdtype(cfg):
    return DTYPES[cfg.dtype]


# ----------------------------------------------------------------- norms
def init_norm(key, cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm_type == "nonparametric_ln":  # OLMo: no scale/bias
        return {}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(p, cfg, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_type in ("layernorm", "nonparametric_ln"):
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if p:
            y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
        y = y * p["scale"]
    return y.astype(x.dtype)


def rms_norm_headwise(x, scale, eps=1e-6):
    """qk-norm: RMS over the head_dim of (..., head_dim)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ----------------------------------------------------------------- MLP
def init_mlp(key, cfg, d_ff=None):
    d, dt = cfg.d_model, cdtype(cfg)
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "w_up": (jax.random.normal(k2, (d, d_ff)) * s_in).astype(dt),
        "w_down": (jax.random.normal(k3, (d_ff, d)) * s_out).astype(dt),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = (jax.random.normal(k1, (d, d_ff)) * s_in).astype(dt)
    return p


def apply_mlp(p, cfg, x):
    up = x @ p["w_up"]
    if cfg.mlp_type == "swiglu":
        up = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        up = jax.nn.gelu(up)
    return up @ p["w_down"]


# ----------------------------------------------------------------- embeddings
def init_embed(key, cfg):
    dt = cdtype(cfg)
    w = jax.random.normal(key, (cfg.vocab_size, cfg.d_model)) * 0.02
    return {"w": w.astype(dt)}


def apply_embed(p, tokens):
    return jnp.take(p["w"], tokens, axis=0)


def init_unembed(key, cfg):
    dt = cdtype(cfg)
    w = jax.random.normal(key, (cfg.d_model, cfg.vocab_size)) * cfg.d_model ** -0.5
    return {"w": w.astype(dt)}


def apply_unembed(p, x):
    return x @ p["w"]


# ----------------------------------------------------------------- rotary
def rope_freqs(cfg, positions):
    """positions: int32 (...,). Returns cos/sin of shape (..., head_dim//2)."""
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(
        x.dtype
    )
