"""B-AlexNet: the paper's own experimental vehicle.

AlexNet adapted to 32x32 inputs and trained BranchyNet-style with early-exit
side branches: branch 1 after the first ReLU (the paper's default single-
branch setup, Fig. 1), branch 2 after the second ReLU (Sec. IV-F). The edge
device runs conv1 (+ branch); the cloud runs the rest -- the partition point
used throughout the paper's experiments.

Implemented with jax.lax convolutions; NHWC layout. Dropout is omitted
(the paper's analysis is post-training; weight decay is used instead).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# (name, kind, spec) in execution order; exits attach after relu1 / relu2.
LAYER_TABLE = [
    ("conv1", "conv", dict(cin=3, cout=64, k=5, pool=True)),
    ("conv2", "conv", dict(cin=64, cout=96, k=5, pool=True)),
    ("conv3", "conv", dict(cin=96, cout=192, k=3, pool=False)),
    ("conv4", "conv", dict(cin=192, cout=128, k=3, pool=False)),
    ("conv5", "conv", dict(cin=128, cout=128, k=3, pool=True)),
    ("fc1", "fc", dict(din=128 * 4 * 4, dout=256)),
    ("fc2", "fc", dict(din=256, dout=128)),
    ("fc3", "fc", dict(din=128, dout=10)),
]

B_ALEXNET = ModelConfig(
    name="b_alexnet",
    family="convnet",
    num_layers=8,
    d_model=0,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=10,
    head_dim=1,
    use_rope=False,
    exit_layers=(0, 1),  # after conv1-relu / conv2-relu
    exit_loss_weights=(1.0, 1.0),
    dtype="float32",
    source="BranchyNet AlexNet on CIFAR-10 [Teerapittayanon+ 2016; paper Sec. III]",
)


def _conv_init(key, cin, cout, k):
    w = jax.random.normal(key, (k, k, cin, cout)) * (k * k * cin) ** -0.5
    return {"w": w, "b": jnp.zeros((cout,))}


def _fc_init(key, din, dout):
    w = jax.random.normal(key, (din, dout)) * din ** -0.5
    return {"w": w, "b": jnp.zeros((dout,))}


def init_params(key, cfg: ModelConfig = B_ALEXNET):
    ks = jax.random.split(key, len(LAYER_TABLE) + 4)
    params = {}
    for (name, kind, spec), k in zip(LAYER_TABLE, ks):
        if kind == "conv":
            params[name] = _conv_init(k, spec["cin"], spec["cout"], spec["k"])
        else:
            params[name] = _fc_init(k, spec["din"], spec["dout"])
    # side branches: small conv + fc head (BranchyNet recipe)
    params["branch1"] = {
        "conv": _conv_init(ks[-4], 64, 32, 3),
        "fc": _fc_init(ks[-3], 32 * 8 * 8, 10),
    }
    params["branch2"] = {
        "conv": _conv_init(ks[-2], 96, 32, 3),
        "fc": _fc_init(ks[-1], 32 * 4 * 4, 10),
    }
    return params


def _conv(p, x, pool):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + p["b"]
    y = jax.nn.relu(y)
    if pool:
        y = jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
        )
    return y


def _branch(p, x):
    y = _conv(p["conv"], x, pool=True)
    y = y.reshape(y.shape[0], -1)
    return y @ p["fc"]["w"] + p["fc"]["b"]


def forward(params, images, num_branches: int = 2):
    """images: (b, 32, 32, 3). Returns {exit_logits: [...], logits}."""
    x = _conv(params["conv1"], images, pool=True)  # (b,16,16,64)
    exit_logits = []
    if num_branches >= 1:
        exit_logits.append(_branch(params["branch1"], x))
    x = _conv(params["conv2"], x, pool=True)  # (b,8,8,96)
    if num_branches >= 2:
        exit_logits.append(_branch(params["branch2"], x))
    x = _conv(params["conv3"], x, pool=False)
    x = _conv(params["conv4"], x, pool=False)
    x = _conv(params["conv5"], x, pool=True)  # (b,4,4,128)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
    logits = x @ params["fc3"]["w"] + params["fc3"]["b"]
    return {"exit_logits": exit_logits, "logits": logits}


def edge_forward(params, images, branch: int = 1):
    """Edge partition: layers up to branch `branch` + that branch head.

    Returns (branch_logits, intermediate_activation) -- the activation is the
    offloading payload (what the paper sends over the 18.8 Mbps uplink).
    """
    x = _conv(params["conv1"], images, pool=True)
    if branch == 1:
        return _branch(params["branch1"], x), x
    x = _conv(params["conv2"], x, pool=True)
    return _branch(params["branch2"], x), x


def cloud_forward(params, hidden, from_branch: int = 1):
    """Cloud partition: remaining layers after branch `from_branch`."""
    x = hidden
    if from_branch == 1:
        x = _conv(params["conv2"], x, pool=True)
    x = _conv(params["conv3"], x, pool=False)
    x = _conv(params["conv4"], x, pool=False)
    x = _conv(params["conv5"], x, pool=True)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
    return x @ params["fc3"]["w"] + params["fc3"]["b"]


def payload_bytes(branch: int = 1) -> int:
    """Size of the edge->cloud activation (float32), per sample."""
    if branch == 1:
        return 16 * 16 * 64 * 4
    return 8 * 8 * 96 * 4
