"""Model registry: one uniform API over the zoo.

Dispatches on cfg.family:
  * convnet            -> repro.models.convnet   (the paper's B-AlexNet)
  * audio (enc-dec)    -> repro.models.whisper
  * everything else    -> repro.models.transformer

Also provides ``input_specs``: ShapeDtypeStruct stand-ins for every model
input of a given (cfg, shape, step-kind) -- the multi-pod dry-run lowers
against these without allocating anything.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer, whisper
from repro.models.layers import DTYPES


def _mod(cfg: ModelConfig):
    if cfg.family == "audio" or cfg.is_encoder_decoder:
        return whisper
    return transformer


def init_params(key, cfg: ModelConfig):
    if cfg.family == "convnet":
        from repro.models import convnet

        return convnet.init_params(key, cfg)
    return _mod(cfg).init_params(key, cfg)


def forward_train(params, cfg: ModelConfig, batch, remat: bool = True):
    if cfg.family == "convnet":
        from repro.models import convnet

        return convnet.forward(params, batch["images"])
    return _mod(cfg).forward_train(params, cfg, batch, remat=remat)


def forward_prefill(params, cfg: ModelConfig, batch):
    if cfg.family == "audio" or cfg.is_encoder_decoder:
        return whisper.forward_prefill(params, cfg, batch)
    return transformer.forward_prefill(params, cfg, batch)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return _mod(cfg).init_cache(cfg, batch, seq_len)


def decode_step(params, cfg: ModelConfig, token, caches, pos):
    return _mod(cfg).decode_step(params, cfg, token, caches, pos)


# ----------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for the step the shape exercises.

    train  -> {tokens, labels[, encoder_frames]}
    prefill-> {tokens[, encoder_frames]}
    decode -> {token (b,1), pos scalar} (+cache specs via cache_specs()).
    """
    b, s = shape.global_batch, shape.seq_len
    dt = DTYPES[cfg.dtype]
    i32 = jnp.int32
    if cfg.family == "convnet":
        return {
            "images": jax.ShapeDtypeStruct((b, 32, 32, 3), jnp.float32),
            "labels": jax.ShapeDtypeStruct((b,), i32),
        }
    if shape.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    elif shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode
        out = {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }
    if cfg.is_encoder_decoder and shape.kind != "decode":
        out["encoder_frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dt)
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for the decode cache (no allocation)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
    )


def param_specs_shapes(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init_params(k, cfg), key)
