"""Mamba2 (state-space duality / SSD) block.

Implements the chunked SSD algorithm of Dao & Gu (arXiv:2405.21060):
  * in_proj -> [z, x, B, C, dt]; causal depthwise conv over (x, B, C);
  * intra-chunk "attention-like" quadratic term + inter-chunk linear
    recurrence over per-chunk states (the duality);
  * gated RMSNorm and out_proj.

Decode keeps O(1) state per layer: a (conv_k-1)-step conv buffer and the
(heads, head_dim, state) SSD state -- this is why long_500k decode is
natively cheap for SSM and hybrid architectures.

Sharding: heads/channels shard on the 'model' mesh axis; the scan over
chunks is sequential in the sequence dimension (time), which shards on
nothing -- batch shards on data axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import cdtype


def _dims(cfg):
    di = cfg.d_inner
    h = cfg.ssm_heads
    g, n, ck = cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_conv
    conv_ch = di + 2 * g * n
    return di, h, g, n, ck, conv_ch


def init_mamba(key, cfg):
    d = cfg.d_model
    di, h, g, n, ck, conv_ch = _dims(cfg)
    dt = cdtype(cfg)
    ks = jax.random.split(key, 4)
    if cfg.mamba_split_proj:
        # perf-pass: keep dt separate so in_proj's width (2*di + 2*g*n) is
        # divisible by the 16-way model axis -- the fused width includes the
        # head count (e.g. +24) which breaks divisibility and forces the
        # whole projection to replicate (collective-bound prefill).
        p = {
            "in_proj": (
                jax.random.normal(ks[0], (d, 2 * di + 2 * g * n)) * d ** -0.5
            ).astype(dt),
            "dt_proj": (jax.random.normal(ks[2], (d, h)) * d ** -0.5).astype(dt),
        }
    else:
        in_dim = 2 * di + 2 * g * n + h
        p = {
            "in_proj": (jax.random.normal(ks[0], (d, in_dim)) * d ** -0.5).astype(dt),
        }
    p.update({
        "conv_w": (jax.random.normal(ks[1], (ck, conv_ch)) * ck ** -0.5).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[3], (di, d)) * di ** -0.5).astype(dt),
    })
    return p


def _project_in(p, cfg, x):
    """x @ in_proj -> (z, xbc, dt_raw), handling the split-proj variant."""
    di, h, g, n, _, _ = _dims(cfg)
    if cfg.mamba_split_proj:
        zxbc = x @ p["in_proj"]
        dt_raw = x @ p["dt_proj"]
        z, xbc = jnp.split(zxbc, [di], axis=-1)
        return z, xbc, dt_raw
    proj = x @ p["in_proj"]
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt_raw = jnp.split(xbc_dt, [di + 2 * g * n], axis=-1)
    return z, xbc, dt_raw


def _gated_out(p, cfg, y, z):
    # gated RMSNorm: norm(y * silu(z)) * scale
    yz = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.float32)
    ms = jnp.mean(jnp.square(yz), axis=-1, keepdims=True)
    yn = yz * jax.lax.rsqrt(ms + 1e-6) * p["norm_scale"]
    return yn.astype(cdtype(cfg)) @ p["out_proj"]


def ssd_chunked(x, dt, A, B, C, D, chunk):
    """SSD scan. x:(b,s,h,p) dt:(b,s,h) A:(h,) B,C:(b,s,g,n) D:(h,).

    Returns y:(b,s,h,p) fp32 and the final state (b,h,p,n).
    """
    b, s, h, ph = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g  # heads per B/C group
    nc = s // chunk
    xf = x.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)

    xc = xf.reshape(b, nc, chunk, h, ph)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bf.reshape(b, nc, chunk, g, n)
    Cc = Cf.reshape(b, nc, chunk, g, n)

    dA = dtc * A  # (b,nc,l,h), positive decay rates (A = exp(A_log) > 0)
    dA_cs = jnp.cumsum(dA, axis=2)  # inclusive cumsum

    # ---- intra-chunk (quadratic) term -------------------------------------
    # CB[i,j] per group, decay exp(-(cs_i - cs_j)) for i>=j, weight dt_j
    cb = jnp.einsum("bclgn,bcmgn->bcglm", Cc, Bc)  # (b,nc,g,l,l)
    cb = jnp.repeat(cb, hg, axis=2)  # (b,nc,h,l,l)
    seg = dA_cs[..., :, None, :] - dA_cs[..., None, :, :]  # (b,nc,l,l,h) = cs_i-cs_j
    seg = jnp.moveaxis(seg, -1, 2)  # (b,nc,h,l,l)
    li = jnp.arange(chunk)
    causal = li[:, None] >= li[None, :]
    decay = jnp.where(causal, jnp.exp(-seg), 0.0)
    att = cb * decay * jnp.moveaxis(dtc, -1, 2)[..., None, :]  # * dt_j
    y_diag = jnp.einsum("bchlm,bcmhp->bclhp", att, xc)

    # ---- per-chunk input states --------------------------------------------
    # S_c = sum_j exp(-(cs_last - cs_j)) * dt_j * B_j (x) x_j
    decay_states = jnp.exp(-(dA_cs[:, :, -1:, :] - dA_cs))  # (b,nc,l,h)
    w = decay_states * dtc
    Bh = jnp.repeat(Bc, hg, axis=3)  # (b,nc,l,h,n)
    S_in = jnp.einsum("bclh,bclhn,bclhp->bchpn", w, Bh, xc)

    # ---- inter-chunk recurrence over chunk states ---------------------------
    chunk_decay = jnp.exp(-dA_cs[:, :, -1, :])  # (b,nc,h)

    def step(S, inp):
        dec, Sc = inp  # dec:(b,h)  Sc:(b,h,p,n)
        S = S * dec[:, :, None, None] + Sc
        return S, S

    S0 = jnp.zeros((b, h, ph, n), jnp.float32)
    dec_t = jnp.moveaxis(chunk_decay, 1, 0)  # (nc,b,h)
    Sin_t = jnp.moveaxis(S_in, 1, 0)  # (nc,b,h,p,n)
    S_final, S_all = jax.lax.scan(step, S0, (dec_t, Sin_t))
    # states entering each chunk (exclusive)
    S_prev = jnp.concatenate([S0[None], S_all[:-1]], axis=0)
    S_prev = jnp.moveaxis(S_prev, 0, 1)  # (b,nc,h,p,n)

    # ---- inter-chunk output: C_i . S_prev with decay exp(-cs_i) -------------
    Ch = jnp.repeat(Cc, hg, axis=3)  # (b,nc,l,h,n)
    y_off = jnp.einsum("bclhn,bchpn->bclhp", Ch, S_prev) * jnp.exp(-dA_cs)[..., None]

    y = (y_diag + y_off).reshape(b, s, h, ph)
    y = y + xf * D[None, None, :, None]
    return y, S_final


def mamba_prefill(p, cfg, x, q_chunk_unused=None):
    """x: (b, s, d) -> (out (b,s,d), cache{conv, ssd})."""
    b, s, d = x.shape
    di, h, g, n, ck, conv_ch = _dims(cfg)
    z, xbc, dt_raw = _project_in(p, cfg, x)

    # causal depthwise conv, kernel ck
    pad = jnp.zeros((b, ck - 1, conv_ch), xbc.dtype)
    xbc_pad = jnp.concatenate([pad, xbc], axis=1)
    conv = sum(
        xbc_pad[:, i : i + s, :] * p["conv_w"][i][None, None, :] for i in range(ck)
    )
    xbc_c = jax.nn.silu((conv + p["conv_b"]).astype(jnp.float32)).astype(xbc.dtype)

    xs, B, C = jnp.split(xbc_c, [di, di + g * n], axis=-1)
    xs = xs.reshape(b, s, h, cfg.ssm_head_dim)
    B = B.reshape(b, s, g, n)
    C = C.reshape(b, s, g, n)
    A = jnp.exp(p["A_log"])  # (h,) positive
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (b,s,h)

    chunk = min(cfg.ssm_chunk, s)
    if s % chunk != 0:
        chunk = s  # fall back to single chunk for odd smoke shapes
    y, S = ssd_chunked(xs, dtv, A, B, C, p["D"], chunk)
    y = y.reshape(b, s, di)
    out = _gated_out(p, cfg, y.astype(cdtype(cfg)), z)
    cache = {"conv": xbc_pad[:, -(ck - 1) :, :] if ck > 1 else None, "ssd": S}
    return out, cache


def init_mamba_cache(cfg, batch):
    di, h, g, n, ck, conv_ch = _dims(cfg)
    dt = cdtype(cfg)
    return {
        "conv": jnp.zeros((batch, ck - 1, conv_ch), dt),
        "ssd": jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
    }


def mamba_decode(p, cfg, x, cache, pos=None):
    """One-token step. x: (b, 1, d) -> (out (b,1,d), new cache)."""
    b = x.shape[0]
    di, h, g, n, ck, conv_ch = _dims(cfg)
    z, xbc, dt_raw = _project_in(p, cfg, x[:, 0, :])

    conv_buf = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (b,ck,ch)
    conv = jnp.einsum("bkc,kc->bc", conv_buf, p["conv_w"]) + p["conv_b"]
    xbc_c = jax.nn.silu(conv.astype(jnp.float32)).astype(xbc.dtype)

    xs, B, C = jnp.split(xbc_c, [di, di + g * n], axis=-1)
    xs = xs.reshape(b, h, cfg.ssm_head_dim).astype(jnp.float32)
    B = B.reshape(b, g, n).astype(jnp.float32)
    C = C.reshape(b, g, n).astype(jnp.float32)
    hg = h // g
    Bh = jnp.repeat(B, hg, axis=1)  # (b,h,n)
    Ch = jnp.repeat(C, hg, axis=1)
    A = jnp.exp(p["A_log"])
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (b,h)

    decay = jnp.exp(-dtv * A)  # (b,h)
    S = cache["ssd"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dtv, Bh, xs
    )
    y = jnp.einsum("bhn,bhpn->bhp", Ch, S) + xs * p["D"][None, :, None]
    y = y.reshape(b, 1, di)
    out = _gated_out(p, cfg, y.astype(cdtype(cfg)), z[:, None, :])
    return out, {"conv": conv_buf[:, 1:, :], "ssd": S}
