"""Mixture-of-Experts block: top-k routing with capacity-bounded scatter dispatch.

Design notes (TPU adaptation):
  * Dispatch is scatter/gather based, NOT the classic (tokens, experts,
    capacity) one-hot einsum. The one-hot dispatch matmul costs
    T*E*C*d FLOPs which at train_4k scale (1M tokens, 128 experts) would
    dwarf the expert compute itself and wreck the useful-FLOPs ratio. The
    scatter costs O(T*k*d) data movement instead.
  * Expert weights are stacked (E, d, ff) and sharded on the 'model' mesh
    axis (expert parallelism). Token activations are sharded on the data
    axes, so XLA inserts the all-to-all at the dispatch/combine boundary --
    exactly the collective pattern of expert-parallel serving.
  * Capacity factor bounds the per-expert buffer: C = ceil(T*k/E * cf).
    Overflowing tokens are dropped (combine weight 0) and flow through the
    residual, as in Switch/GShard.
  * Router runs in float32; the aux load-balance loss (Switch-style) is
    returned for the training loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models.layers import cdtype


def n_alloc_experts(cfg) -> int:
    """Allocated expert count: padded to a multiple of 16 under the
    shard-friendly variant so the expert dim divides the model axis
    (e.g. granite's 40 experts -> 48; without it E=40 cannot shard on a
    16-way axis and the expert einsum runs ~an order of magnitude too
    replicated -- see EXPERIMENTS.md #Perf iteration log)."""
    E = cfg.moe_num_experts
    if cfg.moe_shard_capacity:
        return ((E + 15) // 16) * 16
    return E


def init_moe(key, cfg):
    d, E, f = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    Ea = n_alloc_experts(cfg)
    dt = cdtype(cfg)
    k0, k1, k2, k3 = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "router": (jax.random.normal(k0, (d, E)) * s_in).astype(jnp.float32),
        "w_up": (jax.random.normal(k2, (Ea, d, f)) * s_in).astype(dt),
        "w_down": (jax.random.normal(k3, (Ea, f, d)) * s_out).astype(dt),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = (jax.random.normal(k1, (Ea, d, f)) * s_in).astype(dt)
    return p


def moe_capacity(cfg, n_tokens: int) -> int:
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    c = int(n_tokens * k * cfg.moe_capacity_factor / E) + 1
    # keep buffers MXU-aligned but never above what top-k could ever fill
    c = min(max(c, 8), n_tokens)
    return c


def apply_moe(p, cfg, x):
    """x: (..., d). Returns (y, aux) where aux has the load-balance loss."""
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)  # (T, d)
    T = xt.shape[0]
    E, k = n_alloc_experts(cfg), cfg.moe_top_k
    C = moe_capacity(cfg, T)

    # ---- router (fp32) ----
    logits = xt.astype(jnp.float32) @ p["router"]  # (T, E_real)
    if E > cfg.moe_num_experts:  # padded experts can never win top-k
        pad = jnp.full((T, E - cfg.moe_num_experts), -1e30, jnp.float32)
        logits = jnp.concatenate([logits, pad], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- capacity assignment: position of each (token, slot) in its expert --
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(T * k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat)  # (T*k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1)  # (T*k,)
    eidx = expert_idx.reshape(T * k)
    keep = pos < C
    gates = gate_vals.reshape(T * k) * keep.astype(jnp.float32)

    # ---- dispatch: scatter tokens into (E, C, d) buffers ----
    safe_pos = jnp.where(keep, pos, C - 1)
    src = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((E, C, d), xt.dtype)
    buf = buf.at[eidx, safe_pos].add(src, mode="drop")
    # CRITICAL sharding (opt-in; the perf-pass optimization): experts on
    # 'model' AND capacity on the data axes. Without the 'dp' constraint on
    # C, GSPMD replicates the expert einsum over every data shard -- 16x
    # redundant expert FLOPs at mesh (16,16) (measured in the dry-run
    # roofline; see EXPERIMENTS.md #Perf). Kept off in the baseline to
    # document the delta.
    if cfg.moe_shard_capacity:
        buf = sharding.constrain(buf, "tp", "dp", None)

    # ---- expert FFN: (E, C, d) x (E, d, f) ----
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if cfg.mlp_type == "swiglu":
        up = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * up
    else:
        up = jax.nn.gelu(up)
    out_buf = jnp.einsum("ecf,efd->ecd", up, p["w_down"])  # (E, C, d)
    if cfg.moe_shard_capacity:
        out_buf = sharding.constrain(out_buf, "tp", "dp", None)

    # ---- combine: gather each (token, slot)'s expert output ----
    gathered = out_buf[eidx, safe_pos]  # (T*k, d)
    y = jnp.sum(
        (gathered * gates[:, None].astype(gathered.dtype)).reshape(T, k, d), axis=1
    )

    # ---- Switch load-balance aux loss ----
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    aux = {
        "moe_aux_loss": E * jnp.sum(frac_tokens * frac_probs),
        "moe_dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y.reshape(orig_shape), aux
