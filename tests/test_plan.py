"""OffloadPlan + Calibrator protocol: the deployable-artifact contract.

Covers the registry, JSON round-trip bit-identity, equivalence of the
calibrator-state gating with the legacy temperature-list paths, jit/vmap
compatibility of CalibratorState pytrees, and the engine regression that a
deployed branch gates with ITS OWN calibrator state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CalibratorState,
    OffloadPlan,
    apply_calibrator,
    apply_gate,
    available_calibrators,
    cascade_gate,
    choose_partition,
    gate_statistics,
    get_calibrator,
    make_plan,
    select_partition,
)
from repro.core.calibration import TemperatureScaling, fit_temperature


@pytest.fixture(scope="module")
def val_batch():
    z = jax.random.normal(jax.random.PRNGKey(0), (512, 10)) * 4
    y = jax.random.randint(jax.random.PRNGKey(1), (512,), 0, 10)
    return z, y


# ---------------------------------------------------------------- registry
def test_registry_lookup():
    assert set(available_calibrators()) >= {"temperature", "vector", "identity"}
    for name in available_calibrators():
        assert get_calibrator(name).name == name


def test_registry_unknown_name():
    with pytest.raises(KeyError, match="unknown calibrator"):
        get_calibrator("platt")


def test_fit_apply_contract(val_batch):
    z, y = val_batch
    for name in ("temperature", "vector", "identity"):
        cal = get_calibrator(name)
        state = cal.fit(z, y)
        assert state.kind == name
        out = cal.apply(state, z)
        assert out.shape == z.shape
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(apply_calibrator(state, z))
        )


# ------------------------------------------------- legacy-path equivalence
def test_temperature_state_matches_legacy_gating(val_batch):
    """TemperatureScaling.apply + T=1 gate == legacy gate at T, bit-exact
    predictions/mask and allclose confidences; the plan fast path is
    bit-exact because it routes the raw logits + T to the same apply_gate."""
    z, y = val_batch
    T, _ = fit_temperature(z, y)
    T = float(np.float32(float(T)))  # exactly float32-representable
    state = TemperatureScaling.from_temperature(T)
    legacy = apply_gate(z, 0.8, temperature=T)
    via_apply = apply_gate(apply_calibrator(state, z), 0.8, temperature=1.0)
    np.testing.assert_array_equal(legacy.prediction, via_apply.prediction)
    np.testing.assert_array_equal(legacy.exit_mask, via_apply.exit_mask)
    np.testing.assert_allclose(legacy.confidence, via_apply.confidence,
                               rtol=1e-6, atol=1e-7)

    plan = OffloadPlan(p_tar=0.8, calibrators=[state])
    fast = plan.gate(z)
    np.testing.assert_array_equal(legacy.exit_mask, fast.exit_mask)
    np.testing.assert_array_equal(
        np.asarray(legacy.confidence), np.asarray(fast.confidence)
    )


def test_make_plan_matches_make_policy_temperatures(val_batch):
    z, y = val_batch
    plan = make_plan([z], y, p_tar=0.8)
    T, _ = fit_temperature(z, y)
    np.testing.assert_allclose(plan.temperatures[0], float(T), rtol=1e-6)


def test_cascade_gate_plan_equals_temperature_list(val_batch):
    z, y = val_batch
    z2 = jax.random.normal(jax.random.PRNGKey(2), (512, 10)) * 2
    final = jax.random.normal(jax.random.PRNGKey(3), (512, 10)) * 2
    temps = [1.7, 3.1]
    plan = OffloadPlan(
        p_tar=0.7,
        calibrators=[TemperatureScaling.from_temperature(t) for t in temps],
    )
    a = cascade_gate([z, z2], final, 0.7, temps)
    b = cascade_gate([z, z2], final, plan=plan)
    np.testing.assert_array_equal(a["exit_index"], b["exit_index"])
    np.testing.assert_array_equal(a["prediction"], b["prediction"])


def test_choose_partition_plan_equals_temperature_list(val_batch):
    z, _ = val_batch
    z2 = jax.random.normal(jax.random.PRNGKey(2), (512, 10)) * 0.01
    kwargs = dict(
        edge_times_s=[1e-3, 2e-3],
        cloud_times_s=[5e-3, 4e-3],
        payload_bytes=[65536, 24576],
        exit_layer_indices=[0, 1],
        uplink_bps=18.8e6,
    )
    legacy = choose_partition([z, z2], temperatures=[1.0, 1.0], p_tar=0.8, **kwargs)
    plan = OffloadPlan(
        p_tar=0.8,
        calibrators=[TemperatureScaling.from_temperature(1.0)] * 2,
    )
    via_plan = choose_partition([z, z2], plan=plan, **kwargs)
    assert [c.exit_index for c in legacy] == [c.exit_index for c in via_plan]
    np.testing.assert_allclose(
        [c.expected_latency_s for c in legacy],
        [c.expected_latency_s for c in via_plan],
    )

    updated, cands = select_partition(plan, [np.asarray(z), np.asarray(z2)], **kwargs)
    assert updated.exit_index == cands[0].exit_index
    assert updated.partition_layer == cands[0].partition_layer
    assert updated.p_tar == plan.p_tar  # calibration untouched


def test_simulator_plan_maps_physical_branches(val_batch):
    """Regression: a per-exit plan simulated with branches=(2,) must gate
    branch-2 logits with calibrator state 1 (physical mapping, matching
    OffloadEngine), not with state 0."""
    from repro.offload import latency as L
    from repro.offload.simulator import simulate_batches

    z, y = val_batch
    final = jax.random.normal(jax.random.PRNGKey(3), (512, 10)) * 4
    prof = L.paper_2020()
    t2 = 5.0
    plan = OffloadPlan(
        p_tar=0.8,
        calibrators=[
            TemperatureScaling.from_temperature(1.0),
            TemperatureScaling.from_temperature(t2),
        ],
    )
    via_plan = simulate_batches(
        [np.asarray(z)], np.asarray(final), np.asarray(y), profile=prof,
        batch_size=128, branches=(2,), plan=plan,
    )
    legacy = simulate_batches(
        [np.asarray(z)], np.asarray(final), np.asarray(y), 0.8, [t2], prof,
        batch_size=128, branches=(2,),
    )
    wrong = simulate_batches(
        [np.asarray(z)], np.asarray(final), np.asarray(y), 0.8, [1.0], prof,
        batch_size=128, branches=(2,),
    )
    assert [o.on_device_frac for o in legacy] != [o.on_device_frac for o in wrong]
    for a, b in zip(legacy, via_plan):
        assert a.on_device_frac == b.on_device_frac
        assert a.accuracy == b.accuracy


def test_simulator_plan_equals_temperature_list(val_batch):
    from repro.offload import latency as L
    from repro.offload.simulator import simulate_batches

    z, y = val_batch
    final = jax.random.normal(jax.random.PRNGKey(3), (512, 10)) * 4
    prof = L.paper_2020()
    legacy = simulate_batches(
        [np.asarray(z)], np.asarray(final), np.asarray(y), 0.8, [2.0], prof,
        batch_size=128,
    )
    plan = OffloadPlan(
        p_tar=0.8, calibrators=[TemperatureScaling.from_temperature(2.0)]
    )
    via_plan = simulate_batches(
        [np.asarray(z)], np.asarray(final), np.asarray(y), profile=prof,
        batch_size=128, plan=plan,
    )
    for a, b in zip(legacy, via_plan):
        assert a.accuracy == b.accuracy
        assert a.on_device_frac == b.on_device_frac
        np.testing.assert_allclose(a.time_s, b.time_s)


# ----------------------------------------------------------- serialization
def test_plan_json_round_trip_bit_identical(val_batch):
    """A plan serialized to JSON and reloaded produces bit-identical gate
    decisions AND statistics on a fixed validation batch -- for the paper's
    temperature scaling and for vector scaling (non-scalar state)."""
    z, y = val_batch
    for method in ("temperature", "vector", "identity"):
        plan = make_plan([z], y, p_tar=0.85, method=method,
                         metadata={"fit_on": "val_batch"})
        reloaded = OffloadPlan.from_json(plan.to_json())
        assert reloaded.to_dict() == plan.to_dict()
        g0, g1 = plan.gate(z), reloaded.gate(z)
        np.testing.assert_array_equal(np.asarray(g0.exit_mask), np.asarray(g1.exit_mask))
        np.testing.assert_array_equal(np.asarray(g0.prediction), np.asarray(g1.prediction))
        np.testing.assert_array_equal(
            np.asarray(g0.confidence), np.asarray(g1.confidence)
        )


def test_plan_save_load(tmp_path, val_batch):
    z, y = val_batch
    plan = make_plan([z], y, p_tar=0.9).with_partition(0, 3)
    path = str(tmp_path / "plan.json")
    plan.save(path)
    reloaded = OffloadPlan.load(path)
    assert reloaded.partition_layer == 3
    assert reloaded.exit_index == 0
    np.testing.assert_array_equal(
        np.asarray(plan.gate(z).exit_mask), np.asarray(reloaded.gate(z).exit_mask)
    )


def test_plan_rejects_newer_format(val_batch):
    z, y = val_batch
    d = make_plan([z], y, p_tar=0.8).to_dict()
    d["version"] = 99
    with pytest.raises(ValueError, match="newer"):
        OffloadPlan.from_dict(d)


# --------------------------------------------------------------- jit/vmap
def test_calibrator_state_jit_vmap(val_batch):
    z, _ = val_batch

    @jax.jit
    def gate_mask(state, logits):
        return apply_calibrator(state, logits).argmax(-1)

    s1 = TemperatureScaling.from_temperature(1.0)
    s5 = TemperatureScaling.from_temperature(5.0)
    np.testing.assert_array_equal(gate_mask(s1, z), np.asarray(z.argmax(-1)))

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), s1, s5)
    batched = jax.vmap(apply_calibrator, in_axes=(0, None))(stacked, z)
    assert batched.shape == (2,) + z.shape
    np.testing.assert_allclose(np.asarray(batched[1]), np.asarray(z) / 5.0,
                               rtol=1e-6)

    leaves, treedef = jax.tree.flatten(s5)
    assert jax.tree.unflatten(treedef, leaves).kind == "temperature"


def test_plan_gate_jit_with_traced_state(val_batch):
    """The gate fast path must trace when the CalibratorState arrives as a
    jit ARGUMENT (kind dispatch is static aux data; no float() on params)."""
    z, _ = val_batch

    @jax.jit
    def gated_conf(state, logits):
        return OffloadPlan(p_tar=0.8, calibrators=[state]).gate(logits).confidence

    s = TemperatureScaling.from_temperature(2.0)
    eager = OffloadPlan(p_tar=0.8, calibrators=[s]).gate(z).confidence
    np.testing.assert_allclose(np.asarray(gated_conf(s, z)), np.asarray(eager),
                               rtol=1e-6)


def test_cascade_gate_rejects_short_plan(val_batch):
    z, _ = val_batch
    plan = OffloadPlan(
        p_tar=0.8, calibrators=[TemperatureScaling.from_temperature(1.0)]
    )
    with pytest.raises(ValueError, match="no calibrator state"):
        cascade_gate([z, z], z, plan=plan)


# --------------------------------------- engine gates with deployed branch
def test_engine_gates_with_deployed_branch_state():
    """Regression for the exit_index bug: convnet_engine(branch=2) must gate
    with exit 2's calibrator state, not the plan's default exit 0."""
    from repro.data.synthetic import cifar_like
    from repro.models import convnet
    from repro.offload.engine import convnet_engine

    data = cifar_like(n_train=64, n_val=64, n_test=256, seed=7)
    params = convnet.init_params(jax.random.PRNGKey(0))
    x = jnp.asarray(data.test_x[:256])

    t_sharp, t_soft = 0.05, 20.0  # exit0 sharpens, exit1 softens
    plan = OffloadPlan(
        p_tar=0.5,
        calibrators=[
            TemperatureScaling.from_temperature(t_sharp),
            TemperatureScaling.from_temperature(t_soft),
        ],
    )
    engine = convnet_engine(params, plan, branch=2)
    out = engine.infer({"images": x})

    logits2, _ = convnet.edge_forward(params, x, branch=2)
    conf_right, _, _ = gate_statistics(logits2, t_soft)
    conf_wrong, _, _ = gate_statistics(logits2, t_sharp)
    mask_right = np.asarray(conf_right) >= 0.5
    mask_wrong = np.asarray(conf_wrong) >= 0.5
    assert not np.array_equal(mask_right, mask_wrong)  # the test has power
    np.testing.assert_array_equal(out["on_device"], mask_right)


def test_engine_rejects_branch_without_state():
    from repro.models import convnet
    from repro.offload.engine import convnet_engine

    params = convnet.init_params(jax.random.PRNGKey(0))
    plan = OffloadPlan(
        p_tar=0.5, calibrators=[TemperatureScaling.from_temperature(1.0)]
    )
    with pytest.raises(ValueError, match="no calibrator state"):
        convnet_engine(params, plan, branch=2)


# ------------------------------------------------ sequential cascade (fix)
def test_sequential_calibration_matches_subset_fit():
    """The NLL-weighted sequential fit must agree with fitting directly on
    the reached subset (the padded-gather version duplicated sample 0)."""
    from repro.core.calibration import calibrate_cascade

    def overconfident(key, n=3000, c=10, scale=8.0, acc=0.7):
        k1, k2, k3 = jax.random.split(key, 3)
        labels = jax.random.randint(k1, (n,), 0, c)
        correct = jax.random.uniform(k2, (n,)) < acc
        pred = jnp.where(
            correct, labels,
            (labels + 1 + jax.random.randint(k3, (n,), 0, c - 1)) % c,
        )
        z = jax.random.normal(k3, (n, c))
        return z.at[jnp.arange(n), pred].add(scale), labels

    z0, y = overconfident(jax.random.PRNGKey(12))
    z1, _ = overconfident(jax.random.PRNGKey(13), acc=0.9)

    p_tar = 0.8
    temps = calibrate_cascade([z0, z1], y, sequential=True, p_tar=p_tar)

    conf0, _, _ = gate_statistics(z0, temps[0])
    reach = np.asarray(conf0) < p_tar
    assert 0 < reach.sum() < len(reach)  # the gate actually splits the set
    T_subset, _ = fit_temperature(z1[reach], y[reach])
    np.testing.assert_allclose(temps[1], float(T_subset), rtol=1e-3)
