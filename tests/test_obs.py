"""Observability plane tests: sinks, metrics, traces, audit, invariants.

The anchor mirrors the orchestration plane's no-op limit: attaching the
FULL observability bundle (trace + audit + metrics) must not perturb a
single simulated number -- serving and fleet summaries compare `==`
against the uninstrumented run. Everything else cross-examines the
artifacts: span timelines telescope to the end-to-end latency, gate
verdicts in the trace match the telemetry counters, requests are
conserved across churn, and a poisoned-canary rollback reconstructs
from the audit log alone.
"""
import json
import math

import numpy as np
import pytest

from repro.core.calibration import TemperatureScaling
from repro.core.policy import OffloadPlan
from repro.fleet.scenarios import reference_fleet, run_fleet
from repro.obs import (
    AuditLog,
    JsonlTraceSink,
    MetricsRegistry,
    Observability,
    RingBufferSink,
    build_spans,
    full_observability,
    read_jsonl,
    request_record,
)
from repro.obs.check import (
    check_gate_consistency,
    check_span_telescoping,
    main as check_main,
    run_checks,
    verify_rollback_chain,
)
from repro.orchestration import ChurnSchedule, Orchestrator
from repro.orchestration.qos import CellSLO, QoSConfig, QoSMonitor
from repro.serving.scenarios import (
    fit_drift_plans,
    run_congested_markov,
    run_distortion_drift,
    synthetic_cascade_logits,
    synthetic_distorted_cascade,
)


@pytest.fixture(scope="module")
def drift_data():
    val, test = synthetic_distorted_cascade(
        directions={"gaussian_blur": "under"}
    )
    return val, test, fit_drift_plans(val)


def small_fleet(drift_data, seed=0, n_cells=6, requests_per_cell=200):
    val, test, _ = drift_data
    return reference_fleet(
        n_cells=n_cells, requests_per_cell=requests_per_cell, seed=seed,
        val=val, test=test, cloud_servers=2,
    )


def serving_setup():
    exits, final, y = synthetic_cascade_logits(512)
    plan = OffloadPlan(
        p_tar=0.8,
        calibrators=[TemperatureScaling.from_temperature(1.0),
                     TemperatureScaling.from_temperature(1.0)],
    )
    return plan, exits, final, y


# ------------------------------------------------------------------ sinks
def test_ring_buffer_sink_caps_but_counts():
    sink = RingBufferSink(capacity=3)
    for i in range(5):
        sink.emit({"kind": "request", "req_id": i})
    assert sink.emitted == 5
    assert len(sink) == 3
    assert [r["req_id"] for r in sink.records] == [2, 3, 4]


def test_jsonl_sink_roundtrip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    sink = JsonlTraceSink(path)
    sink.emit({"kind": "request", "req_id": 0, "latency_s": 0.01})
    sink.emit({"kind": "request", "req_id": 1, "latency_s": 0.02})
    sink.close()
    back = read_jsonl(path)
    assert [r["req_id"] for r in back] == [0, 1]
    assert back[1]["latency_s"] == 0.02


# ------------------------------------------------------------ span grammar
def test_build_spans_on_device_and_offloaded():
    on = build_spans(1.0, 1.2, 1.5)
    assert [s["name"] for s in on] == ["queue_edge", "edge"]
    off = build_spans(1.0, 1.2, 1.5, uplink_start_s=1.6, uplink_done_s=1.9,
                      cloud_start_s=2.0, complete_s=2.4)
    assert [s["name"] for s in off] == [
        "queue_edge", "edge", "queue_uplink", "uplink", "queue_cloud", "cloud"
    ]
    # the grammar tiles [arrival, complete] by construction
    rec = request_record("test", 0, 1.0, 2.4, False, off)
    assert check_span_telescoping([rec]) == []
    # zero-duration queue spans are kept (no gaps in the timeline)
    instant = build_spans(1.0, 1.0, 1.5)
    assert instant[0]["start_s"] == instant[0]["end_s"] == 1.0


def test_telescoping_check_catches_gaps():
    spans = build_spans(1.0, 1.2, 1.5)
    spans[1]["end_s"] += 0.5  # tear the timeline
    rec = request_record("test", 7, 1.0, 1.5, True, spans)
    errs = check_span_telescoping([rec])
    assert errs and "req 7" in errs[0]


def test_gate_consistency_check():
    on = request_record(
        "test", 0, 0.0, 1.0, True, build_spans(0.0, 0.0, 1.0),
        gate={"confidence": 0.9, "p_tar": 0.8, "criterion": "confidence"})
    assert check_gate_consistency([on]) == []
    # on-device verdict contradicting the threshold
    bad = request_record(
        "test", 1, 0.0, 1.0, True, build_spans(0.0, 0.0, 1.0),
        gate={"confidence": 0.5, "p_tar": 0.8, "criterion": "confidence"})
    assert check_gate_consistency([bad])
    # on_device but the timeline shows an uplink
    lie = request_record(
        "test", 2, 0.0, 2.0, True,
        build_spans(0.0, 0.0, 1.0, uplink_start_s=1.0, uplink_done_s=1.5,
                    cloud_start_s=1.5, complete_s=2.0))
    assert check_gate_consistency([lie])
    # gate=None (backhaul: no gate ran) is never an error
    assert check_gate_consistency([request_record(
        "test", 3, 0.0, 1.0, True, build_spans(0.0, 0.0, 1.0))]) == []


# ---------------------------------------------------------------- metrics
def test_metrics_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    m.inc("reqs_total", 3, cell=0)
    m.inc("reqs_total", 2, cell=1)
    m.inc("reqs_total", cell=0)
    assert m.counter_total("reqs_total") == 6
    assert m.counter_total("reqs_total", cell=0) == 4
    with pytest.raises(ValueError):
        m.inc("reqs_total", -1)
    m.set_gauge("rate", 0.25, source="fleet")
    assert m.gauge_value("rate", source="fleet") == 0.25
    assert m.gauge_value("rate", source="nope") is None
    m.declare_histogram("lat_ms", (1.0, 10.0, 100.0))
    with pytest.raises(ValueError):
        m.declare_histogram("lat_ms", (5.0,))
    for v in (0.5, 5.0, 50.0, 500.0):
        m.observe("lat_ms", v)
    # JSON round-trip preserves everything
    back = MetricsRegistry.from_json(
        json.loads(json.dumps(m.to_json()))
    )
    assert back.counter_total("reqs_total", cell=0) == 4
    assert back.gauge_value("rate", source="fleet") == 0.25


def test_metrics_prometheus_exposition():
    m = MetricsRegistry()
    m.inc("reqs_total", 2, cell=3)
    m.set_gauge("up", 1.0)
    m.declare_histogram("lat_ms", (10.0, 100.0))
    m.observe("lat_ms", 5.0)
    m.observe("lat_ms", 50.0)
    text = m.to_prometheus()
    assert "# TYPE reqs_total counter" in text
    assert 'reqs_total{cell="3"} 2' in text
    assert "# TYPE lat_ms histogram" in text
    # cumulative buckets: le=10 holds 1, le=100 holds 2, +Inf holds 2
    assert 'lat_ms_bucket{le="10"} 1' in text
    assert 'lat_ms_bucket{le="100"} 2' in text
    assert 'lat_ms_bucket{le="+Inf"} 2' in text
    assert "lat_ms_count 2" in text


# --------------------------------------------------- zero-perturbation
def test_serving_obs_is_bit_exact(drift_data):
    """Attaching the full bundle must not move one simulated number."""
    val, test, (_, _, bank) = drift_data
    bare = run_distortion_drift(bank, test, val=val, with_controller=True,
                                n_requests=250).summary()
    obs = full_observability()
    wired = run_distortion_drift(bank, test, val=val, with_controller=True,
                                 n_requests=250, obs=obs).summary()
    assert bare == wired
    assert len(obs.trace) == 250  # and the sink really was live


def test_fleet_obs_is_bit_exact(drift_data):
    scn = small_fleet(drift_data)
    bare = run_fleet(
        drift_data[2][2], scn, with_controller=True).fleet_summary()
    obs = full_observability()
    wired = run_fleet(
        drift_data[2][2], scn, with_controller=True, obs=obs).fleet_summary()
    assert bare == wired
    assert len(obs.trace) == scn.topology.n_requests


# -------------------------------------------------- end-to-end invariants
def test_serving_trace_invariants_and_audit():
    plan, exits, final, y = serving_setup()
    obs = full_observability()
    tel = run_congested_markov(plan, exits, final, y, n_requests=400,
                               with_controller=True, obs=obs)
    recs = obs.trace.records
    assert run_checks(recs, obs.metrics, obs.audit.records) == []
    assert len(recs) == 400
    # every record both paths: spans tile, offloaded ones show the pipeline
    offloaded = [r for r in recs if not r["on_device"]]
    assert offloaded and all(
        [s["name"] for s in r["spans"]][-1] == "cloud" for r in offloaded
    )
    # the controller's rescoring decisions landed in the audit log
    rescored = obs.audit.filter(action="controller_rescore")
    assert rescored and all(
        r["actor"] == "online_controller"
        and {"bandwidth_bps", "held", "chosen"} <= set(r["evidence"])
        for r in rescored
    )
    # metrics agree with telemetry
    s = tel.summary()
    assert obs.metrics.counter_total("serving_requests_total") == s["requests"]
    assert obs.metrics.counter_total(
        "serving_requests_total", path="cloud"
    ) == pytest.approx(s["offload_rate"] * s["requests"], abs=0.5)


def test_fleet_unsampled_trace_conserves(drift_data):
    scn = small_fleet(drift_data)
    obs = full_observability(trace_sample_every=1)
    run_fleet(drift_data[2][2], scn, with_controller=True, obs=obs)
    recs = obs.trace.records
    assert run_checks(recs, obs.metrics, obs.audit.records) == []
    assert len(recs) == scn.topology.n_requests
    m = obs.metrics
    assert m.gauge_value("fleet_requests_completed") == scn.topology.n_requests
    assert m.counter_total("fleet_requests_total") == scn.topology.n_requests
    # trace offload verdicts match the per-cell counters exactly
    n_off = sum(1 for r in recs if not r["on_device"])
    assert m.counter_total("fleet_offloaded_total") == n_off


def test_fleet_sampled_trace(drift_data):
    scn = small_fleet(drift_data)
    obs = full_observability(trace_sample_every=7)
    run_fleet(drift_data[2][2], scn, obs=obs)
    recs = obs.trace.records
    n = scn.topology.n_requests
    assert len(recs) == math.ceil(n / 7)
    # the stride is global over the flattened window order: ids are unique
    # and every per-record invariant still holds on the sample
    ids = [r["req_id"] for r in recs]
    assert len(set(ids)) == len(ids)
    assert run_checks(recs, obs.metrics, obs.audit.records) == []


def test_churn_run_traces_shed_and_conserves(drift_data):
    """Requests shed to a neighbor under churn stay conserved and traced;
    the audit log shows where each shed window was routed."""
    scn = small_fleet(drift_data)
    churn = ChurnSchedule.outage([0, 2], start_s=2.0, duration_s=4.0)
    obs = full_observability(trace_sample_every=1)
    run_fleet(drift_data[2][2], scn, with_controller=True,
              orchestrator=Orchestrator(churn=churn), obs=obs)
    assert run_checks(
        obs.trace.records, obs.metrics, obs.audit.records) == []
    sheds = obs.audit.filter(action="shed_route")
    assert sheds and all(
        not s["evidence"]["backhaul"]
        and s["evidence"]["host_cell"] is not None
        for s in sheds
    )
    assert obs.metrics.counter_total("fleet_shed_total") == sum(
        s["evidence"]["requests"] for s in sheds
    )


def test_whole_fleet_outage_backhaul_traced(drift_data):
    """With every cell down, windows backhaul straight to the cloud: the
    trace shows gate=None (no gate ran) offloaded timelines that still
    telescope, and conservation holds."""
    scn = small_fleet(drift_data)
    n_cells = scn.topology.n_cells
    churn = ChurnSchedule.outage(list(range(n_cells)), start_s=2.0,
                                 duration_s=3.0)
    obs = full_observability(trace_sample_every=1)
    run_fleet(drift_data[2][2], scn,
              orchestrator=Orchestrator(churn=churn), obs=obs)
    assert run_checks(
        obs.trace.records, obs.metrics, obs.audit.records) == []
    backhauled = [r for r in obs.trace.records if r["gate"] is None]
    assert backhauled and all(not r["on_device"] for r in backhauled)
    assert any(s["evidence"]["backhaul"]
               for s in obs.audit.filter(action="shed_route"))


# ------------------------------------------------ compiled-backend parity
def _assert_trace_records_match(recs_a, recs_b):
    """Same sample, same story: non-float fields bit-identical, float
    fields equal to round-off (compiled tree-scan vs host sequential)."""
    assert len(recs_a) == len(recs_b)

    def check(a, b, path):
        if isinstance(a, dict):
            assert isinstance(b, dict) and set(a) == set(b), path
            for k in a:
                check(a[k], b[k], f"{path}.{k}")
        elif isinstance(a, (list, tuple)):
            assert len(a) == len(b), path
            for i, (x, y) in enumerate(zip(a, b)):
                check(x, y, f"{path}[{i}]")
        elif isinstance(a, float) and not isinstance(a, bool):
            assert b == pytest.approx(a, rel=1e-9, abs=1e-12), path
        else:
            assert a == b, path

    for ra, rb in zip(recs_a, recs_b):
        assert ra["req_id"] == rb["req_id"]
        check(ra, rb, f"req {ra['req_id']}")


def _run_both_backends(drift_data, orchestrator=None, every=7):
    scn = small_fleet(drift_data)
    out = []
    for backend in (None, "compiled"):
        obs = full_observability(trace_sample_every=every)
        orch = orchestrator() if orchestrator else None
        run_fleet(drift_data[2][2], scn, backend=backend,
                  orchestrator=orch, obs=obs)
        out.append(obs)
    return out


def test_compiled_trace_passes_checks_identically(drift_data):
    """A compiled-backend fleet run's sampled trace passes the
    `repro.obs.check` invariants and matches the numpy backend's trace
    record for record (same req_ids, same verdicts, floats to
    round-off); the integer metrics counters agree exactly."""
    a, b = _run_both_backends(drift_data, every=7)
    assert run_checks(a.trace.records, a.metrics, a.audit.records) == []
    assert run_checks(b.trace.records, b.metrics, b.audit.records) == []
    _assert_trace_records_match(a.trace.records, b.trace.records)
    for name in ("fleet_requests_total", "fleet_offloaded_total"):
        assert b.metrics.counter_total(name) == a.metrics.counter_total(name)
    assert (b.metrics.gauge_value("fleet_requests_completed")
            == a.metrics.gauge_value("fleet_requests_completed"))


def test_compiled_churn_trace_parity_and_conservation(drift_data):
    """Churn on the compiled path: requests conserved across shed routing,
    audit shows identical routing decisions, every-request trace matches
    the host backend's."""
    def orch():
        return Orchestrator(churn=ChurnSchedule.outage(
            [0, 2], start_s=2.0, duration_s=4.0))

    a, b = _run_both_backends(drift_data, orchestrator=orch, every=1)
    assert run_checks(a.trace.records, a.metrics, a.audit.records) == []
    assert run_checks(b.trace.records, b.metrics, b.audit.records) == []
    _assert_trace_records_match(a.trace.records, b.trace.records)
    sheds_a = a.audit.filter(action="shed_route")
    sheds_b = b.audit.filter(action="shed_route")
    assert [s["evidence"] for s in sheds_a] == [
        s["evidence"] for s in sheds_b]
    assert (b.metrics.counter_total("fleet_shed_total")
            == a.metrics.counter_total("fleet_shed_total"))


def test_compiled_backhaul_trace_parity(drift_data):
    """Whole-fleet outage on the compiled path: gate=None backhaul
    records telescope and match the host trace bit-for-bit on non-float
    fields."""
    scn = small_fleet(drift_data)
    n_cells = scn.topology.n_cells

    def orch():
        return Orchestrator(churn=ChurnSchedule.outage(
            list(range(n_cells)), start_s=2.0, duration_s=3.0))

    a, b = _run_both_backends(drift_data, orchestrator=orch, every=1)
    assert run_checks(b.trace.records, b.metrics, b.audit.records) == []
    _assert_trace_records_match(a.trace.records, b.trace.records)
    backhauled = [r for r in b.trace.records if r["gate"] is None]
    assert backhauled and all(not r["on_device"] for r in backhauled)


# ----------------------------------------- QoS distress -> fleet controller
def test_qos_trip_drives_controller_concession(drift_data):
    """The ROADMAP satellite: the monitor's trip verdict IS the fleet
    controller's distress signal. An impossible latency SLO trips every
    cell; the audit log must show the causal chain end to end --
    qos_trip, then controller_rescore records carrying distressed=true
    for the tripped cells."""
    scn = small_fleet(drift_data)
    monitor = QoSMonitor(
        CellSLO(p99_ms=1e-3, min_requests=1),  # nothing can satisfy this
        QoSConfig(window_s=2.0, trip_after=1, clear_after=1000),
    )
    obs = full_observability()
    run_fleet(drift_data[2][2], scn, with_controller=True,
              orchestrator=Orchestrator(monitor=monitor), obs=obs)
    trips = obs.audit.filter(actor="qos_monitor", action="qos_trip")
    assert trips, "the impossible SLO must trip"
    ev = trips[0]["evidence"]
    assert ev["metric"] == "p99_ms" and ev["value"] > ev["cap"]
    distressed = [
        r for r in obs.audit.filter(action="controller_rescore")
        if r["actor"] == "fleet_controller" and r["evidence"]["distressed"]
    ]
    assert distressed, "tripped cells must rescore under distress"
    # causality: the cell's distress rescore happens at or after its trip
    first_trip = {r["evidence"]["cell"]: r["t_s"] for r in reversed(trips)}
    for r in distressed:
        c = r["evidence"]["cell"]
        assert c in first_trip and r["t_s"] >= first_trip[c]


def test_force_concession_skips_contract_hold():
    from repro.core.control import choose_with_concession

    def row(p_tar, lat, rho, acc=0.95, i=0):
        return {"p_tar": p_tar, "expected_latency_s": lat,
                "uplink_utilization": rho, "accuracy": acc,
                "estimated_gap": 0.0, "exit_index": i, "offload_prob": 0.1}

    contract = row(0.8, 0.050, 0.5, i=0)
    rescue = row(0.5, 0.020, 0.5, i=1)
    table = [contract, rescue]
    # healthy: the contract row holds (stage 1)
    held = choose_with_concession(table, 0.8, 0.95)
    assert held is contract
    # QoS-tripped: stage 1 is skipped, fastest stable row wins
    forced = choose_with_concession(table, 0.8, 0.95, force_concession=True)
    assert forced is rescue
    # feasibility caps still bind under distress
    capped = choose_with_concession(
        table, 0.8, 0.95, min_accuracy=0.99, force_concession=True)
    assert capped is not rescue or rescue["accuracy"] >= 0.99


# ----------------------------------------------------- audit causal chains
def guarded_poisoned_rollout(drift_data):
    from repro.orchestration.scenarios import _rollout_pieces, poisoned_bank

    val, test, (_, _, bank) = drift_data
    scn = small_fleet(drift_data, n_cells=8, requests_per_cell=300)
    orch, monitor, rollout = _rollout_pieces(scn, poisoned_bank(bank))
    audit = AuditLog()
    run_fleet(bank, scn, orchestrator=orch, obs=Observability(audit=audit))
    return audit, rollout


def test_rollback_reconstructs_from_audit_alone(drift_data):
    """Acceptance: trip evidence -> rollback transition -> incumbent
    version restored, all reconstructible from the audit log with no
    telemetry in hand. Truncating the log breaks the chain loudly."""
    audit, rollout = guarded_poisoned_rollout(drift_data)
    assert rollout.state == "rolled_back"
    chain = verify_rollback_chain(audit.records)
    assert chain["ok"], chain["why"]
    ca, rb = chain["canary"], chain["rollback"]
    assert ca["evidence"]["bank_version"] == rb["evidence"]["bank_version"]
    assert (rb["evidence"]["restored_version"]
            == ca["evidence"]["incumbent_version"])
    assert all(t["evidence"]["value"] > t["evidence"]["cap"]
               for t in chain["trips"])
    # drop the rollback record: the chain must refuse to verify
    truncated = [r for r in audit.records
                 if r["action"] != "rollout_rollback"]
    broken = verify_rollback_chain(truncated)
    assert not broken["ok"] and "rollout_rollback" in broken["why"]
    # drop the trips: same
    no_trips = [r for r in audit.records if r["action"] != "qos_trip"]
    assert not verify_rollback_chain(no_trips)["ok"]


def test_audit_jsonl_roundtrip_and_cli(tmp_path, drift_data):
    audit, _ = guarded_poisoned_rollout(drift_data)
    apath = str(tmp_path / "audit.jsonl")
    audit.to_jsonl(apath)
    assert verify_rollback_chain(AuditLog.read_jsonl(apath))["ok"]

    # the CLI wires the same checks: 0 on good artifacts, 1 on broken ones
    scn = small_fleet(drift_data)
    tpath = str(tmp_path / "trace.jsonl")
    mpath = str(tmp_path / "metrics.json")
    metrics = MetricsRegistry()
    obs = Observability(trace=JsonlTraceSink(tpath), metrics=metrics)
    run_fleet(drift_data[2][2], scn, obs=obs)
    obs.close()
    metrics.write_json(mpath)
    assert check_main(["--trace", tpath, "--metrics", mpath,
                       "--audit", apath, "--require-rollback-chain"]) == 0
    # corrupt one record's latency: the telescoping invariant must fail
    recs = read_jsonl(tpath)
    recs[0]["latency_s"] += 1.0
    bad = str(tmp_path / "bad.jsonl")
    with open(bad, "w") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")
    assert check_main(["--trace", bad]) == 1


def test_poisoned_canary_scenario_carries_audit_verdict():
    from repro.orchestration.scenarios import poisoned_canary

    rec = poisoned_canary(quick=True)
    assert rec["wins"]["audit_chain"]["win"], rec["wins"]["audit_chain"]
    assert rec["pass"]
    assert rec["events"]["audit_records"] >= 3
