"""Fleet-scale vectorized simulator, controller, and telemetry tests.

The anchor is the single-cell limit: with one cell, one device, a fixed
link, and per-sample transfers, the windowed vectorized pipeline must
reproduce the event-driven `ServingRuntime` request-for-request -- same
offload decisions, same latencies to float round-off, queues empty or
congested, plain logits or drifting contexts. On top of that: closed-form
agreement, determinism under seeds, batched-gate/estimator plumbing, the
context-aware fleet controller, and the ISSUE 4 acceptance scenario
(calibrated fleet controller beats the static uncalibrated plan on fleet
p99 AND miscalibration gap at >=100k requests across >=64 cells).
"""
import numpy as np
import pytest

from repro.core.calibration import TemperatureScaling
from repro.core.policy import OffloadPlan, rescore_plan
from repro.offload import latency as L
from repro.serving import (
    FixedRateNetwork,
    LogitsCore,
    MarkovNetwork,
    RuntimeConfig,
    ServingRuntime,
    TraceNetwork,
    constant_workload,
    poisson_workload,
)
from repro.serving.drift import ContextualLogitsCore, MarkovContextSchedule
from repro.serving.scenarios import (
    fit_drift_plans,
    severity_drift_schedule,
    synthetic_cascade_logits,
    synthetic_distorted_cascade,
)
from repro.fleet import (
    CellConfig,
    FleetConfig,
    FleetController,
    FleetControllerConfig,
    FleetGateTable,
    FleetSimulator,
    FleetTopology,
)
from repro.fleet.simulator import fifo_done
from repro.fleet.topology import CellWorkload, poisson_cell_workload


def as_cell_workload(requests):
    """The same Request stream the event runtime serves, as columns."""
    return CellWorkload(
        np.asarray([r.arrival_s for r in requests]),
        np.asarray([r.sample for r in requests]),
        np.asarray([r.device for r in requests]),
    )


@pytest.fixture(scope="module")
def cascade():
    exits, final, y = synthetic_cascade_logits(512)
    plan = OffloadPlan(
        p_tar=0.8,
        calibrators=[TemperatureScaling.from_temperature(1.0)] * 2,
    )
    return exits, final, y, plan, L.paper_2020()


@pytest.fixture(scope="module")
def drift_data():
    # the underconfident-blur variant the fleet bench runs
    val, test = synthetic_distorted_cascade(
        directions={"gaussian_blur": "under"}
    )
    return val, test, fit_drift_plans(val)


# ------------------------------------------------------- FIFO recurrence
def test_fifo_done_matches_sequential():
    rng = np.random.default_rng(0)
    t = np.sort(rng.uniform(0, 10, 200))
    s = rng.uniform(0.01, 0.3, 200)
    done = fifo_done(t, s, free_s=2.0)
    prev = 2.0
    for i in range(200):
        prev = max(t[i], prev) + s[i]
        assert done[i] == pytest.approx(prev, rel=1e-12)


# -------------------------------------------------- single-cell equality
@pytest.mark.parametrize("congested", [False, True], ids=["empty", "queued"])
def test_fleet_matches_event_runtime_single_cell(cascade, congested):
    """One cell, one device, fixed link, per-sample transfers: the
    vectorized pipeline IS the event simulator, request for request."""
    exits, final, y, plan, profile = cascade
    n = len(y)
    if congested:
        reqs = poisson_workload(120.0, 800, n, deadline_s=0.1, seed=4)
    else:
        reqs = constant_workload(10.0, n, n, deadline_s=0.1)
    rt = ServingRuntime(
        LogitsCore(exits, final, plan, labels=y), profile, plan, reqs,
        network=FixedRateNetwork(profile.uplink_bps),
        config=RuntimeConfig(max_batch=1),
    )
    tel = rt.run()

    topo = FleetTopology([
        CellConfig(network=FixedRateNetwork(profile.uplink_bps),
                   workload=as_cell_workload(reqs), deadline_s=0.1)
    ], cloud_servers=1)
    table = FleetGateTable.from_logits(exits, final, plan, labels=y)
    ftel = FleetSimulator(table, topo, profile,
                          config=FleetConfig(window_s=0.5)).run()

    f = ftel.fleet_summary()
    s = tel.summary()
    assert f["requests"] == s["requests"]
    assert f["offload_rate"] == pytest.approx(s["offload_rate"], abs=0)
    assert f["accuracy"] == pytest.approx(s["accuracy"], abs=0)
    # request-for-request: the sorted latency vectors agree to round-off
    ev = np.sort(tel.latencies())
    fl = np.sort(ftel._cells[0].column("latency_s"))
    np.testing.assert_allclose(fl, ev, rtol=1e-9, atol=1e-12)
    assert f["p99_ms"] == pytest.approx(s["p99_ms"], rel=1e-9)
    assert f["mean_ms"] == pytest.approx(s["mean_ms"], rel=1e-9)
    assert f["deadline_miss_rate"] == pytest.approx(
        s["deadline_miss_rate"], abs=0
    )


def test_fleet_matches_closed_form(cascade):
    """Empty queues + fixed link: every latency equals the paper's
    closed-form edge / edge+comm+cloud sums."""
    exits, final, y, plan, profile = cascade
    n = len(y)
    reqs = constant_workload(10.0, n, n)
    topo = FleetTopology([
        CellConfig(network=FixedRateNetwork(profile.uplink_bps),
                   workload=as_cell_workload(reqs))
    ])
    table = FleetGateTable.from_logits(exits, final, plan, labels=y)
    tel = FleetSimulator(table, topo, profile).run()
    lat = tel._cells[0].column("latency_s")
    on = tel._cells[0].column("on_device")
    t_edge = L.edge_time(profile, 1)
    t_cloud = t_edge + L.comm_time(profile, 1) + L.cloud_time(profile, 1)
    np.testing.assert_allclose(lat[on], t_edge, rtol=1e-9)
    np.testing.assert_allclose(lat[~on], t_cloud, rtol=1e-9)


def test_fleet_matches_event_runtime_under_drift(drift_data):
    """Single-cell limit with a PlanBank + Markov context schedule: expert
    selection, per-context telemetry, and the miscalibration gap agree
    with ContextualLogitsCore under the event runtime."""
    val, test, (uncal, global_plan, bank) = drift_data
    profile = L.paper_2020()
    n = len(test["labels"])
    reqs = poisson_workload(40.0, 900, n, deadline_s=0.1, seed=7)
    core = ContextualLogitsCore(
        test["exit_logits"], test["final"], bank, severity_drift_schedule(),
        labels=test["labels"], features_by_context=test["features"],
    )
    tel = ServingRuntime(core, profile, bank, reqs,
                         config=RuntimeConfig(max_batch=1)).run()

    topo = FleetTopology([
        CellConfig(network=FixedRateNetwork(profile.uplink_bps),
                   workload=as_cell_workload(reqs),
                   schedule=severity_drift_schedule(), deadline_s=0.1)
    ])
    table = FleetGateTable(
        test["exit_logits"], test["final"], bank,
        labels=test["labels"], features_by_context=test["features"],
    )
    ftel = FleetSimulator(table, topo, profile).run()
    s, f = tel.summary(), ftel.fleet_summary()
    assert f["offload_rate"] == pytest.approx(s["offload_rate"], abs=0)
    assert f["accuracy"] == pytest.approx(s["accuracy"], abs=0)
    assert f["p99_ms"] == pytest.approx(s["p99_ms"], rel=1e-9)
    assert f["miscalibration_gap"] == pytest.approx(
        s["miscalibration_gap"], abs=1e-12
    )
    ev_ctx = tel.per_context_summary()
    fl_ctx = ftel.per_context_summary()
    assert set(fl_ctx) == set(ev_ctx)
    for ctx in ev_ctx:
        for k in ("requests", "offload_rate", "on_device_accuracy",
                  "miscalibration_gap", "est_match_rate"):
            assert fl_ctx[ctx][k] == pytest.approx(ev_ctx[ctx][k], abs=1e-12), (
                ctx, k
            )


# ----------------------------------------------------------- determinism
def test_fleet_deterministic_under_seed(drift_data):
    from repro.fleet.scenarios import reference_fleet, run_fleet

    val, test, (uncal, global_plan, bank) = drift_data

    def run(seed):
        scn = reference_fleet(n_cells=8, requests_per_cell=200, seed=seed,
                              val=val, test=test)
        tel = run_fleet(bank, scn, with_controller=True)
        return tel.fleet_summary()

    a, b = run(0), run(0)
    assert a == b  # bit-identical, dicts and all
    c = run(1)
    assert c["p99_ms"] != a["p99_ms"]  # the seed genuinely matters


def test_vectorized_network_and_schedule_lookups():
    """rates_bps / context_ids_at agree with the scalar paths at every
    query point, in any order."""
    times = np.linspace(0.0, 30.0, 301)
    for net in (
        FixedRateNetwork(5e6),
        MarkovNetwork(seed=3, dwell_s=0.7),
        TraceNetwork([0.0, 4.0, 6.0], [1e6, 2e6, 3e6], period_s=10.0),
    ):
        vec = net.rates_bps(times)
        scalar = [net.rate_bps(float(t)) for t in times]
        np.testing.assert_array_equal(vec, scalar)
    sch = MarkovContextSchedule(["a", "b", "c"], dwell_s=0.9, seed=5)
    ids = sch.context_ids_at(times)
    keys = [sch.contexts[i] for i in ids]
    assert keys == [sch.context_at(float(t)) for t in times]


# ----------------------------------------------------- batched gate path
def test_gate_block_matches_logits_core(cascade):
    exits, final, y, plan, profile = cascade
    core = LogitsCore(exits, final, plan, labels=y)
    for b in (1, 2):
        conf, pred = plan.gate_block(exits[b], branch=b - 1)
        np.testing.assert_array_equal(conf, core.conf[b])
        np.testing.assert_array_equal(pred, core.pred[b])


def test_bank_gate_block_matches_per_sample_selection(drift_data):
    """PlanBank.gate_block under estimator ids == gating each sample with
    its own expert plan."""
    val, test, (uncal, global_plan, bank) = drift_data
    ctx = "gaussian_noise@2"
    z = test["exit_logits"][ctx][1]
    feats = test["features"][ctx]
    conf, pred, eids = bank.gate_block(z, features=feats, branch=0)
    keys = bank.contexts
    for i in range(0, len(z), 97):  # spot-check a spread of samples
        plan = bank.plan_for(keys[eids[i]]) if eids[i] >= 0 else bank.default_plan
        c, p = plan.gate_block(z[i:i + 1], branch=0)
        assert conf[i] == c[0]
        assert pred[i] == p[0]


# ------------------------------------------------------ fleet controller
def test_rescore_plan_sample_weight():
    """Weighting the validation samples moves offload probability and
    accuracy exactly as the weighted mixture dictates."""
    exits, final, y = synthetic_cascade_logits(256)
    plan = OffloadPlan(
        p_tar=0.8, calibrators=[TemperatureScaling.from_temperature(1.0)] * 2
    )
    kw = dict(
        edge_times_s=[1e-3, 2e-3], cloud_times_s=[5e-3, 4e-3],
        payload_bytes=[65536, 24576], uplink_bps=1e7,
        labels=y, final_logits=final,
    )
    _, table_u = rescore_plan(plan, [exits[1], exits[2]], **kw)
    w = np.zeros(256)
    w[:64] = 1.0  # price only the first quarter of the traffic
    _, table_w = rescore_plan(plan, [exits[1], exits[2]], sample_weight=w, **kw)
    row_u = next(r for r in table_u if r["exit_index"] == 0)
    row_w = next(r for r in table_w if r["exit_index"] == 0)
    conf, _ = plan.gate_block(exits[1], branch=0)
    expect = float((conf[:64] < 0.8).mean())
    assert row_w["offload_prob"] == pytest.approx(expect)
    assert row_w["offload_prob"] != row_u["offload_prob"]
    with pytest.raises(ValueError):
        rescore_plan(plan, [exits[1], exits[2]],
                     sample_weight=-np.ones(256), **kw)


def test_fleet_controller_concedes_only_under_distress(drift_data):
    """A cell on the nominal link holds the plan's p_tar; a cell whose
    measured uplink cannot carry full-p_tar traffic makes the weakest
    stable concession; the shared-cloud cap demotes the heaviest cell."""
    val, test, (uncal, global_plan, bank) = drift_data
    profile = L.paper_2020()
    ctrl = FleetController(
        bank, profile, val["exit_logits"], n_cells=2,
        final_logits=val["final"], labels=val["labels"],
        cloud_servers=4,
        config=FleetControllerConfig(
            interval_s=1.0, window_s=2.0,
            p_tar_grid=(0.3, 0.5, 0.7, 0.8), min_accuracy=0.8,
        ),
    )

    class Tel:
        context_keys = sorted(test["exit_logits"])

        def bandwidth_estimate(self, c, w, now):
            return [profile.uplink_bps, 1.5e6][c]

        def arrival_rate_estimate(self, c, w, now):
            return 20.0

        def context_mix_estimate(self, c, w, now):
            k = len(self.context_keys)
            return np.full(k, 1.0 / k)

    decisions = ctrl.update(1.0, Tel())
    (b0, p0, l0), (b1, p1, l1) = decisions
    assert l0 == 0 and l1 == 0  # no codec axis configured: level 0 held
    assert p0 == bank.default_plan.p_tar  # healthy link: contract held
    assert p1 < bank.default_plan.p_tar  # distressed link: conceded
    assert p1 in (0.3, 0.5, 0.7)
    # the concession is the WEAKEST stable one: every higher-p_tar grid
    # point must be uplink-infeasible at the measured 1.5 Mbps for both
    # branches (otherwise the controller should have kept it)
    for p in (0.5, 0.7):
        if p <= p1:
            continue
        for branch in (1, 2):
            payload = [65536, 24576][branch - 1]
            util = 20.0 * _offload_at(bank, val, branch, p) * payload * 8 / 1.5e6
            assert util >= 0.95, (p, branch, util)


def _offload_at(bank, val, branch, p_tar):
    # mean offload over contexts under each context's expert calibrator
    offs = []
    for ctx, z in val["exit_logits"].items():
        conf, _ = bank.plan_for(ctx).gate_block(z[branch], branch=branch - 1)
        offs.append(float((conf < p_tar).mean()))
    return float(np.mean(offs))


def test_fleet_controller_shared_cloud_cap(drift_data):
    """With a tiny shared cloud, the aggregate-utilization pass demotes
    cells relative to the uncapped decisions."""
    val, test, (uncal, global_plan, bank) = drift_data
    profile = L.paper_2020()

    def decisions(rho_max):
        ctrl = FleetController(
            bank, profile, val["exit_logits"], n_cells=8,
            final_logits=val["final"], labels=val["labels"],
            cloud_servers=1,
            config=FleetControllerConfig(
                p_tar_grid=(0.3, 0.5, 0.8), min_accuracy=0.8,
                cloud_rho_max=rho_max,
            ),
        )

        class Tel:
            context_keys = sorted(test["exit_logits"])

            def bandwidth_estimate(self, c, w, now):
                return profile.uplink_bps

            def arrival_rate_estimate(self, c, w, now):
                # gentle enough that every uplink stays stable at full
                # p_tar (no distress concession), so any demotion must
                # come from the shared-cloud pass alone
                return 40.0

            def context_mix_estimate(self, c, w, now):
                k = len(self.context_keys)
                return np.full(k, 1.0 / k)

        return ctrl.update(1.0, Tel())

    free = decisions(rho_max=None)
    capped = decisions(rho_max=0.01)
    total_off_free = sum(_offload_at(bank, val, b, p) for b, p, _ in free)
    total_off_capped = sum(_offload_at(bank, val, b, p) for b, p, _ in capped)
    assert total_off_capped < total_off_free


def test_row_feasible_all_offload_vacuously_holds_gap():
    """A candidate that offloads everything keeps nothing on-device, so
    the reliability-gap cap is vacuously satisfied; an unknown gap on a
    row that DOES keep samples on-device stays infeasible."""
    from repro.core.control import row_feasible, select_candidate

    all_off = dict(exit_index=0, p_tar=0.99, offload_prob=1.0,
                   expected_latency_s=0.09, uplink_utilization=0.1,
                   accuracy=0.95, on_device_accuracy=None,
                   reliability_gap=None)
    broken = dict(all_off, p_tar=0.8, offload_prob=0.4,
                  expected_latency_s=0.01, on_device_accuracy=0.55,
                  reliability_gap=0.25)
    unknown = dict(all_off, offload_prob=0.4)
    assert row_feasible(all_off, max_reliability_gap=0.05)
    assert not row_feasible(broken, max_reliability_gap=0.05)
    assert not row_feasible(unknown, max_reliability_gap=0.05)
    # the contract-safe all-offload row wins over the gap-breaking one
    best = select_candidate([broken, all_off], max_reliability_gap=0.05)
    assert best is all_off


# ------------------------------------------------------ diurnal envelope
def test_diurnal_envelope_workload():
    """envelope=None stays bit-identical to the homogeneous stream; an
    envelope produces a deterministic, sorted, exactly-n stream whose
    arrivals concentrate in the high-rate phase."""
    from repro.fleet.topology import DiurnalEnvelope

    flat = poisson_cell_workload(20.0, 2000, 512, seed=5)
    off = poisson_cell_workload(20.0, 2000, 512, seed=5, envelope=None)
    np.testing.assert_array_equal(flat.arrival_s, off.arrival_s)

    env = DiurnalEnvelope(period_s=40.0, amplitude=0.8)
    wl = poisson_cell_workload(20.0, 2000, 512, seed=5, envelope=env)
    wl2 = poisson_cell_workload(20.0, 2000, 512, seed=5, envelope=env)
    np.testing.assert_array_equal(wl.arrival_s, wl2.arrival_s)
    assert len(wl) == 2000
    assert np.all(np.diff(wl.arrival_s) >= 0)
    # thinning keeps ~(1/2 + amplitude/pi) of arrivals in the >1x phase
    frac_high = float((env.rate_factor(wl.arrival_s) > 1.0).mean())
    assert frac_high > 0.65, frac_high
    # and the envelope genuinely reshapes the stream vs the flat one
    assert float((env.rate_factor(flat.arrival_s) > 1.0).mean()) < frac_high
    # amplitude=1.0 is legal (the trough rate reaches exactly zero);
    # anything beyond would make the rate negative
    DiurnalEnvelope(amplitude=1.0)
    with pytest.raises(ValueError, match="amplitude"):
        DiurnalEnvelope(amplitude=1.1)
    with pytest.raises(ValueError, match="period"):
        DiurnalEnvelope(period_s=0.0)


# --------------------------------------------------------- validation
def test_fleet_validation_errors(cascade):
    exits, final, y, plan, profile = cascade
    table = FleetGateTable.from_logits(exits, final, plan, labels=y)
    wl = poisson_cell_workload(10.0, 50, len(y))
    cell = CellConfig(network=FixedRateNetwork(1e7), workload=wl)
    with pytest.raises(ValueError, match="at least one cell"):
        FleetTopology([])
    with pytest.raises(ValueError, match="window_s"):
        FleetSimulator(table, FleetTopology([cell]), profile,
                       config=FleetConfig(window_s=0.0))
    with pytest.raises(ValueError, match="device"):
        CellConfig(network=FixedRateNetwork(1e7),
                   workload=poisson_cell_workload(10.0, 50, len(y), n_devices=4),
                   n_devices=2)
    entropy_plan = OffloadPlan(
        p_tar=0.8, calibrators=list(plan.calibrators),
        criterion="entropy", entropy_threshold=0.5,
    )
    with pytest.raises(ValueError, match="criteri"):
        FleetGateTable.from_logits(exits, final, entropy_plan)
    ctrl = FleetController(plan, profile, exits, n_cells=1)
    with pytest.raises(ValueError, match="multiple"):
        FleetSimulator(table, FleetTopology([cell]), profile,
                       config=FleetConfig(window_s=0.3), controller=ctrl)


# ----------------------------------------------- ISSUE 4 acceptance
@pytest.mark.slow
def test_fleet_acceptance_controller_beats_uncal(drift_data):
    """THE acceptance criterion: >=100k requests across >=64 cells, and
    the calibrated fleet controller beats the static uncalibrated plan on
    BOTH fleet p99 and miscalibration gap -- the same scenario the
    CI-asserted BENCH_fleet.json is generated from."""
    from repro.fleet.scenarios import reference_fleet, run_fleet

    val, test, (uncal, global_plan, bank) = drift_data
    scn = reference_fleet(val=val, test=test)
    assert scn.topology.n_cells >= 64
    assert scn.topology.n_requests >= 100_000
    u = run_fleet(uncal, scn).fleet_summary()
    c = run_fleet(bank, scn, with_controller=True).fleet_summary()
    assert c["p99_ms"] < 0.8 * u["p99_ms"], (c["p99_ms"], u["p99_ms"])
    assert c["miscalibration_gap"] < 0.6 * u["miscalibration_gap"], (
        c["miscalibration_gap"], u["miscalibration_gap"]
    )
    assert c["accuracy"] > u["accuracy"]


@pytest.mark.slow
def test_fleet_backend_parity_full_scale(drift_data):
    """The jitted JAX gate backend AND the fully compiled window pipeline
    reproduce the numpy-backed reference fleet at FULL scale (>=100k
    requests, 64 cells) -- the window sizes BENCH_fleet.json benchmarks
    the backends at. The tier-1 sized-down version lives in
    test_gatepath.py; this one is nightly/slow-job scale."""
    from repro.fleet.scenarios import reference_fleet, run_fleet

    val, test, (uncal, global_plan, bank) = drift_data
    scn = reference_fleet(val=val, test=test)
    a = run_fleet(bank, scn).fleet_summary()
    for backend in ("jax", "compiled"):
        b = run_fleet(bank, scn, backend=backend).fleet_summary()
        assert a["requests"] == b["requests"]
        assert a["offload_rate"] == pytest.approx(b["offload_rate"], abs=1e-12)
        assert a["p99_ms"] == pytest.approx(b["p99_ms"], rel=1e-9)
        assert a["miscalibration_gap"] == pytest.approx(
            b["miscalibration_gap"], abs=1e-9
        )


def test_fleet_acceptance_small(drift_data):
    """A fast guard on the acceptance direction at 16 cells. The full
    p99-vs-uncal win needs the long horizon of the slow test (uncal's
    saturated cells take tens of seconds to grow their queues); what must
    hold at ANY scale is that the controller rescues the calibrated
    fleet's tail (vs the bank served statically) and beats the
    uncalibrated plan on the miscalibration gap without giving up its
    accuracy win."""
    from repro.fleet.scenarios import reference_fleet, run_fleet

    val, test, (uncal, global_plan, bank) = drift_data
    scn = reference_fleet(n_cells=16, requests_per_cell=400,
                          val=val, test=test)
    u = run_fleet(uncal, scn).fleet_summary()
    b = run_fleet(bank, scn).fleet_summary()
    c = run_fleet(bank, scn, with_controller=True).fleet_summary()
    assert c["miscalibration_gap"] < 0.6 * u["miscalibration_gap"]
    assert c["p99_ms"] < 0.5 * b["p99_ms"]
    assert c["accuracy"] > u["accuracy"]
