"""Property-test harness for the max-plus FIFO solvers (ISSUE 8).

The compiled fleet pipeline replaces the host per-request FIFO loop with
`lax.associative_scan` over the max-plus semiring. Before that kernel is
allowed to serve traffic, this suite pins it against the deliberately
naive Python oracles in `repro.fleet.maxplus`:

- `fifo_done_maxplus` vs `fifo_oracle` (single-server FIFO), and
- `kserver_done_maxplus` vs `kserver_oracle` (shared cloud tier,
  constant service so the residue-class decomposition is exact),

across >= 200 generated examples plus explicit edge cases: empty
windows, zero-service requests, arrival ties, and saturated queues.

On dyadic-rational inputs (small integers scaled by a power of two)
float addition is EXACT, so the tree-shaped scan and the sequential
oracle must agree bit-for-bit; general float inputs are compared to a
tight relative tolerance that only absorbs re-association round-off.

The suite runs under `hypothesis` when available (the CI dev
requirements install it) and falls back to an equivalent seeded
numpy-RNG sweep otherwise, so the >=200-example guarantee holds in both
environments.
"""
import numpy as np
import pytest

from repro.fleet.maxplus import (
    fifo_done_maxplus,
    fifo_oracle,
    kserver_done_maxplus,
    kserver_oracle,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # container without dev extras: seeded sweep below
    HAVE_HYPOTHESIS = False

N_EXAMPLES = 200
RTOL = dict(rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------- helpers
def dyadic_case(rng, n):
    """Arrival/service columns whose float sums are exact.

    Small non-negative integers scaled by 2^-6 keep every partial sum an
    exact dyadic rational well inside float64, so scan vs oracle must be
    bit-identical regardless of association order.
    """
    t = rng.integers(0, 512, n).astype(np.float64) * 2.0**-6
    s = rng.integers(0, 64, n).astype(np.float64) * 2.0**-6
    return t, s


def float_case(rng, n):
    t = rng.uniform(0.0, 30.0, n)
    s = rng.uniform(0.0, 2.0, n)
    return t, s


def assert_fifo_matches(t, s, free=0.0, exact=False):
    got = fifo_done_maxplus(t, s, free)
    want = fifo_oracle(t, s, free)
    if exact:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, **RTOL)


# ------------------------------------------------- generated example sweep
if HAVE_HYPOTHESIS:

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(
        data=st.lists(
            st.tuples(st.integers(0, 512), st.integers(0, 64)),
            min_size=1,
            max_size=128,
        ),
        free=st.integers(0, 256),
    )
    def test_fifo_scan_matches_oracle_exactly(data, free):
        """Dyadic inputs: tree scan == sequential oracle, bit-for-bit."""
        arr = np.asarray(data, dtype=np.float64) * 2.0**-6
        assert_fifo_matches(arr[:, 0], arr[:, 1],
                            free=float(free) * 2.0**-6, exact=True)

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), n=st.integers(1, 200))
    def test_fifo_scan_matches_oracle_floats(seed, n):
        """General float inputs: equal to re-association round-off."""
        rng = np.random.default_rng(seed)
        t, s = float_case(rng, n)
        assert_fifo_matches(t, s, free=rng.uniform(0.0, 5.0))

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), n=st.integers(1, 150),
           k=st.integers(1, 8))
    def test_kserver_scan_matches_oracle(seed, n, k):
        """Constant-service K-server: residue chains == earliest-free."""
        rng = np.random.default_rng(seed)
        t, _ = dyadic_case(rng, n)
        t.sort()  # cloud jobs arrive in completion order
        s = np.full(n, rng.integers(1, 64) * 2.0**-6)
        got = kserver_done_maxplus(t, s, k)
        want = kserver_oracle(t, s, k)
        np.testing.assert_array_equal(got, want)

else:

    def test_fifo_scan_matches_oracle_exactly():
        rng = np.random.default_rng(0)
        for i in range(N_EXAMPLES):
            n = int(rng.integers(1, 129))
            t, s = dyadic_case(rng, n)
            assert_fifo_matches(t, s, free=float(rng.integers(0, 256)) * 2.0**-6,
                                exact=True)

    def test_fifo_scan_matches_oracle_floats():
        rng = np.random.default_rng(1)
        for i in range(N_EXAMPLES):
            n = int(rng.integers(1, 201))
            t, s = float_case(rng, n)
            assert_fifo_matches(t, s, free=rng.uniform(0.0, 5.0))

    def test_kserver_scan_matches_oracle():
        rng = np.random.default_rng(2)
        for i in range(N_EXAMPLES):
            n = int(rng.integers(1, 151))
            k = int(rng.integers(1, 9))
            t, _ = dyadic_case(rng, n)
            t.sort()
            s = np.full(n, rng.integers(1, 64) * 2.0**-6)
            np.testing.assert_array_equal(
                kserver_done_maxplus(t, s, k), kserver_oracle(t, s, k)
            )


# ----------------------------------------------------- explicit edge cases
def test_empty_window():
    out = fifo_done_maxplus(np.empty(0), np.empty(0))
    assert out.shape == (0,) and out.dtype == np.float64


def test_single_request():
    np.testing.assert_array_equal(
        fifo_done_maxplus(np.array([3.0]), np.array([0.5])), [3.5]
    )
    np.testing.assert_array_equal(  # busy server delays the lone arrival
        fifo_done_maxplus(np.array([1.0]), np.array([0.5]), free_s=4.0), [4.5]
    )


def test_zero_service_requests():
    """s == 0 jobs complete at max(arrival, predecessor-done) exactly."""
    t = np.array([0.0, 1.0, 1.0, 2.0, 5.0])
    s = np.zeros(5)
    assert_fifo_matches(t, s, exact=True)
    np.testing.assert_array_equal(fifo_done_maxplus(t, s), t)
    # zero-service interleaved with real work
    s2 = np.array([2.0, 0.0, 0.5, 0.0, 0.0])
    assert_fifo_matches(t, s2, exact=True)


def test_arrival_ties():
    """Simultaneous arrivals queue in column order, deterministically."""
    t = np.full(16, 2.5)
    s = np.full(16, 0.25)
    want = 2.5 + 0.25 * np.arange(1, 17)
    np.testing.assert_array_equal(fifo_done_maxplus(t, s), want)
    assert_fifo_matches(t, s, exact=True)


def test_saturated_queue():
    """All work arrives at t=0: done times are the pure service cumsum."""
    rng = np.random.default_rng(7)
    s = rng.integers(1, 32, 100).astype(np.float64) * 2.0**-4
    t = np.zeros(100)
    np.testing.assert_array_equal(fifo_done_maxplus(t, s), np.cumsum(s))
    assert_fifo_matches(t, s, exact=True)


def test_unsorted_arrivals():
    """The max-plus form never assumes sorted t; the oracle is the spec."""
    rng = np.random.default_rng(11)
    t, s = dyadic_case(rng, 64)
    rng.shuffle(t)
    assert_fifo_matches(t, s, exact=True)


def test_busy_server_free_time():
    t = np.array([0.0, 0.5, 4.0])
    s = np.array([1.0, 1.0, 1.0])
    np.testing.assert_array_equal(
        fifo_done_maxplus(t, s, free_s=10.0), [11.0, 12.0, 13.0]
    )


def test_kserver_edges():
    # k >= n: every job gets its own server
    t = np.array([0.0, 0.0, 1.0])
    s = np.full(3, 2.0)
    np.testing.assert_array_equal(kserver_done_maxplus(t, s, 5), [2.0, 2.0, 3.0])
    # k == 1 degenerates to plain FIFO
    rng = np.random.default_rng(13)
    td, sd = dyadic_case(rng, 40)
    td.sort()
    sc = np.full(40, sd[0])
    np.testing.assert_array_equal(
        kserver_done_maxplus(td, sc, 1), fifo_done_maxplus(td, sc)
    )
    # empty
    assert kserver_done_maxplus(np.empty(0), np.empty(0), 3).shape == (0,)
