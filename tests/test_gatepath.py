"""Gate-backend parity tests (ISSUE 5).

The jitted JAX window gate must match the numpy `gate_block` path --
confidence to tolerance, predictions and on/offload DECISIONS exactly --
on plain plans, expert banks (per-sample temperature gather incl. unknown
verdicts), the dense `GateTable`, and the contextual serving core;
including empty windows and all-offload windows. Both backends run the
same float32 `gate_statistics` math, so the tolerance only absorbs XLA
fusion's last-ulp freedom.
"""
import numpy as np
import pytest

from repro.core.calibration import TemperatureScaling, get_calibrator
from repro.core.gatepath import (
    GateBackend,
    GateTable,
    JaxGateBackend,
    NumpyGateBackend,
    STATIC_CONTEXT,
    available_gate_backends,
    get_gate_backend,
)
from repro.core.policy import OffloadPlan
from repro.serving.drift import ContextualLogitsCore
from repro.serving.scenarios import (
    fit_drift_plans,
    severity_drift_schedule,
    synthetic_cascade_logits,
    synthetic_distorted_cascade,
)

CONF_TOL = dict(rtol=1e-5, atol=1e-6)


@pytest.fixture(scope="module")
def cascade():
    exits, final, y = synthetic_cascade_logits(256)
    plan = OffloadPlan(
        p_tar=0.8,
        calibrators=[TemperatureScaling.from_temperature(1.7),
                     TemperatureScaling.from_temperature(1.3)],
    )
    return exits, final, y, plan


@pytest.fixture(scope="module")
def drift_small():
    val, test = synthetic_distorted_cascade(n=256, n_val=256)
    return val, test, fit_drift_plans(val)


# ------------------------------------------------------------- registry
def test_backend_registry():
    assert {"numpy", "jax", "compiled"} <= set(available_gate_backends())
    assert isinstance(get_gate_backend(None), NumpyGateBackend)
    assert isinstance(get_gate_backend("jax"), JaxGateBackend)
    # the compiled fleet backend gates with the numpy tables (exact parity)
    assert isinstance(get_gate_backend("compiled"), NumpyGateBackend)
    assert get_gate_backend("compiled").name == "compiled"
    # instances pass through; repeated name lookups share the jit caches
    jx = get_gate_backend("jax")
    assert get_gate_backend(jx) is jx
    assert get_gate_backend("jax") is jx
    with pytest.raises(ValueError, match="unknown gate backend"):
        get_gate_backend("tpu_pallas_v9")


# ------------------------------------------------------------ block level
def test_plan_gate_block_parity(cascade):
    exits, final, y, plan = cascade
    for b in (1, 2):
        cn, pn = plan.gate_block(exits[b], branch=b - 1)
        cj, pj = plan.gate_block(exits[b], branch=b - 1, backend="jax")
        np.testing.assert_allclose(cj, cn, **CONF_TOL)
        np.testing.assert_array_equal(pj, pn)
        assert cj.dtype == np.float64 and pj.dtype == np.int64


def test_plan_gate_block_rich_calibrator_falls_back(cascade):
    """Non-temperature calibrators take the exact host path on both
    backends, so parity is bit-level."""
    exits, final, y, plan = cascade
    vec = get_calibrator("vector").fit(exits[1], y)
    rich = OffloadPlan(p_tar=0.8, calibrators=[vec, plan.calibrators[1]])
    cn, pn = rich.gate_block(exits[1], branch=0)
    cj, pj = rich.gate_block(exits[1], branch=0, backend="jax")
    np.testing.assert_array_equal(cj, cn)
    np.testing.assert_array_equal(pj, pn)


def test_bank_gate_block_parity(drift_small):
    """Per-sample expert-temperature gather == one gate_block call per
    distinct expert, unknown (-1 -> default plan) verdicts included."""
    val, test, (uncal, global_plan, bank) = drift_small
    ctx = "gaussian_noise@2"
    z = test["exit_logits"][ctx][1]
    eids = bank.estimator.predict_ids(test["features"][ctx])
    eids = np.asarray(eids, np.int64)
    eids[::7] = -1  # force unknown verdicts through the default-plan slot
    cn, pn, en = bank.gate_block(z, expert_ids=eids, branch=0)
    cj, pj, ej = bank.gate_block(z, expert_ids=eids, branch=0, backend="jax")
    np.testing.assert_allclose(cj, cn, **CONF_TOL)
    np.testing.assert_array_equal(pj, pn)
    np.testing.assert_array_equal(ej, en)


# ----------------------------------------------------------- table level
def test_gate_table_precompute_parity(drift_small):
    val, test, (uncal, global_plan, bank) = drift_small
    kw = dict(labels=test["labels"], features_by_context=test["features"])
    tn = GateTable(test["exit_logits"], test["final"], bank, **kw)
    tj = GateTable(test["exit_logits"], test["final"], bank, backend="jax", **kw)
    np.testing.assert_allclose(tj.conf, tn.conf, **CONF_TOL)
    np.testing.assert_array_equal(tj.pred, tn.pred)
    np.testing.assert_array_equal(tj.final_pred, tn.final_pred)


@pytest.mark.parametrize("p_tar", [0.8, 0.0, 1.1],
                         ids=["mixed", "all-on-device", "all-offload"])
def test_gate_window_parity(drift_small, p_tar):
    """Whole-window gather+compare agrees across backends, including the
    degenerate all-on-device and all-offload windows."""
    val, test, (uncal, global_plan, bank) = drift_small
    kw = dict(labels=test["labels"], features_by_context=test["features"])
    tn = GateTable(test["exit_logits"], test["final"], bank, **kw)
    tj = GateTable(test["exit_logits"], test["final"], bank, backend="jax", **kw)
    rng = np.random.default_rng(3)
    ctx = rng.integers(0, len(tn.ctx_keys), 501)
    smp = rng.integers(0, tn.n_samples, 501)
    for branch in tn.branches:
        cn, pn, on_n = tn.gate_window(ctx, smp, branch, p_tar)
        cj, pj, on_j = tj.gate_window(ctx, smp, branch, p_tar)
        np.testing.assert_allclose(cj, cn, **CONF_TOL)
        np.testing.assert_array_equal(pj, pn)
        np.testing.assert_array_equal(on_j, on_n)
    if p_tar == 1.1:
        assert not on_n.any()
    if p_tar == 0.0:
        assert on_n.all()


def test_gate_window_empty(drift_small):
    val, test, (uncal, global_plan, bank) = drift_small
    kw = dict(labels=test["labels"], features_by_context=test["features"])
    empty = np.empty(0, np.int64)
    for backend in (None, "jax"):
        t = GateTable(test["exit_logits"], test["final"], bank,
                      backend=backend, **kw)
        conf, pred, on = t.gate_window(empty, empty, 1, 0.8)
        assert conf.shape == pred.shape == on.shape == (0,)
        r = t.gate_window_cells(empty, empty, empty, [1, 2], [0.8, 0.5], 2)
        assert r["on_device"].shape == (0,)
        np.testing.assert_array_equal(r["on_count"], [0, 0])
        np.testing.assert_array_equal(r["offload_count"], [0, 0])


def test_gate_window_cells_parity_and_reductions(cascade):
    """The fleet-wide window entry point: per-sample decisions match and
    the per-cell segment reductions equal the host bincount."""
    exits, final, y, plan = cascade
    tn = GateTable.from_logits(exits, final, plan, labels=y)
    tj = GateTable.from_logits(exits, final, plan, labels=y, backend="jax")
    rng = np.random.default_rng(11)
    n, n_cells = 777, 5
    ctx = np.zeros(n, np.int64)
    smp = rng.integers(0, tn.n_samples, n)
    cells = rng.integers(0, n_cells, n)
    branch_by_cell = [1, 2, 1, 2, 1]
    p_tar_by_cell = [0.8, 0.5, 0.95, 0.8, 1.1]
    rn = tn.gate_window_cells(ctx, smp, cells, branch_by_cell,
                              p_tar_by_cell, n_cells)
    rj = tj.gate_window_cells(ctx, smp, cells, branch_by_cell,
                              p_tar_by_cell, n_cells)
    np.testing.assert_allclose(rj["confidence"], rn["confidence"], **CONF_TOL)
    np.testing.assert_array_equal(rj["prediction"], rn["prediction"])
    np.testing.assert_array_equal(rj["on_device"], rn["on_device"])
    for r in (rn, rj):
        np.testing.assert_array_equal(
            r["on_count"],
            np.bincount(cells, weights=r["on_device"],
                        minlength=n_cells).astype(np.int64),
        )
        np.testing.assert_array_equal(
            r["on_count"] + r["offload_count"],
            np.bincount(cells, minlength=n_cells),
        )


# ----------------------------------------------------- serving-core level
def test_contextual_core_backend_parity(drift_small):
    val, test, (uncal, global_plan, bank) = drift_small
    sched = severity_drift_schedule()
    kw = dict(labels=test["labels"], features_by_context=test["features"])
    cn = ContextualLogitsCore(test["exit_logits"], test["final"], bank,
                              sched, **kw)
    cj = ContextualLogitsCore(test["exit_logits"], test["final"], bank,
                              sched, backend="jax", **kw)
    for key in cn.conf:
        np.testing.assert_allclose(cj.conf[key], cn.conf[key], **CONF_TOL)
        np.testing.assert_array_equal(cj.pred[key], cn.pred[key])
    for t in np.linspace(0.0, 30.0, 7):
        for s in (0, 17, 101, 255):
            gn = cn.gate(s, 1, 0.8, t)
            gj = cj.gate(s, 1, 0.8, t)
            assert gn[0] == gj[0] and gn[1] == gj[1]  # decision + prediction
            assert gn[3:] == gj[3:]  # (true ctx, est ctx)
            assert gn[2] == pytest.approx(gj[2], rel=1e-5)


# --------------------------------------------------------- retrace count
def test_gate_window_cells_pow2_padding_retrace_count(cascade):
    """`gate_window_cells` pads every window to the next power of two, so
    sweeping window sizes 1..N may trigger at most log2(N)+1 distinct
    compilations of the jitted cells kernel -- pinned by inspecting the
    jit cache of a FRESH backend instance. A second sweep must be free."""
    exits, final, y, plan = cascade
    be = JaxGateBackend()  # private jit caches, no shared-instance noise
    table = GateTable.from_logits(exits, final, plan, labels=y, backend=be)
    rng = np.random.default_rng(5)
    N, n_cells = 64, 3

    def sweep():
        for n in range(1, N + 1):
            ctx = np.zeros(n, np.int64)
            smp = rng.integers(0, table.n_samples, n)
            cells = rng.integers(0, n_cells, n)
            table.gate_window_cells(ctx, smp, cells, [1] * n_cells,
                                    [0.8] * n_cells, n_cells)

    sweep()
    fn = be._cells_fn()
    n_compiles = fn._cache_size()
    assert 1 <= n_compiles <= int(np.log2(N)) + 1, n_compiles
    sweep()  # every padded shape is now cached: zero fresh traces
    assert fn._cache_size() == n_compiles


# ------------------------------------------------------- simulator level
def test_fleet_simulator_backend_parity(drift_small):
    """End to end: the same ~2k-request fleet simulated over the numpy,
    jax, and compiled backends produces the same telemetry -- the tier-1
    sized-down version of the full-scale @slow parity in test_fleet.py,
    so every CI run exercises the compiled gate path."""
    from repro.fleet.scenarios import reference_fleet, run_fleet

    val, test, (uncal, global_plan, bank) = drift_small
    scn = reference_fleet(n_cells=4, requests_per_cell=500,
                          val=val, test=test)
    a = run_fleet(bank, scn).fleet_summary()
    for backend in ("jax", "compiled"):
        b = run_fleet(bank, scn, backend=backend).fleet_summary()
        assert a["requests"] == b["requests"]
        assert a["offload_rate"] == pytest.approx(b["offload_rate"], abs=1e-12)
        assert a["p99_ms"] == pytest.approx(b["p99_ms"], rel=1e-9)
        assert a["miscalibration_gap"] == pytest.approx(
            b["miscalibration_gap"], abs=1e-9
        )
