"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracle
(interpret=True executes the kernel body on CPU).

Hypothesis property sweeps live in test_kernels_properties.py (skipped when
hypothesis is not installed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import exit_gate
from repro.kernels.ref import exit_gate_ref


@pytest.mark.parametrize("rows", [1, 3, 8, 17, 64])
@pytest.mark.parametrize("vocab", [10, 128, 512, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_exit_gate_shape_dtype_sweep(rows, vocab, dtype):
    key = jax.random.PRNGKey(rows * 10007 + vocab)
    z = (jax.random.normal(key, (rows, vocab)) * 6).astype(dtype)
    conf, pred, ent = exit_gate(z, 1.0)
    rconf, rent, rpred = exit_gate_ref(z, 1.0)
    np.testing.assert_allclose(conf, rconf, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(ent, rent, rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(pred, rpred)


@pytest.mark.parametrize("temp", [0.25, 1.0, 2.0, 7.5])
def test_exit_gate_temperatures(temp):
    z = jax.random.normal(jax.random.PRNGKey(0), (16, 1536)) * 4
    conf, pred, ent = exit_gate(z, temp)
    rconf, rent, rpred = exit_gate_ref(z, temp)
    np.testing.assert_allclose(conf, rconf, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(ent, rent, rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(pred, rpred)


def test_exit_gate_leading_dims():
    z = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 5, 700)) * 3
    conf, pred, ent = exit_gate(z, 1.3)
    rconf, rent, rpred = exit_gate_ref(z, 1.3)
    assert conf.shape == (2, 3, 5)
    np.testing.assert_allclose(conf, rconf, rtol=2e-5, atol=1e-6)
    np.testing.assert_array_equal(pred, rpred)


def test_exit_gate_extreme_logits():
    """Online-softmax must be stable for huge magnitude logits."""
    z = jnp.array([[1e4, -1e4, 0.0, 500.0] + [0.0] * 124], jnp.float32)
    conf, pred, ent = exit_gate(z, 1.0)
    assert not bool(jnp.isnan(conf).any() | jnp.isnan(ent).any())
    np.testing.assert_allclose(conf, [1.0], atol=1e-6)
    assert int(pred[0]) == 0


def test_core_gate_kernel_path_equals_jnp_path():
    from repro.core.exits import gate_statistics

    z = jax.random.normal(jax.random.PRNGKey(2), (32, 50280)) * 4
    c1, p1, e1 = gate_statistics(z, 1.7, use_kernel=False)
    c2, p2, e2 = gate_statistics(z, 1.7, use_kernel=True)
    np.testing.assert_allclose(c1, c2, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(e1, e2, rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(p1, p2)


# ---------------------------------------------------------------- calib_nll
class TestCalibNllKernel:
    """Kernel #2: fused one-pass Temperature-Scaling Newton statistics."""

    @pytest.mark.parametrize("rows,vocab", [(5, 130), (16, 512), (37, 700), (3, 50280)])
    @pytest.mark.parametrize("temp", [0.5, 1.0, 2.7])
    def test_matches_ref_and_autodiff(self, rows, vocab, temp):
        from repro.core.calibration import nll as nll_ref
        from repro.kernels.ops import calib_stats
        from repro.kernels.ref import calib_nll_ref

        key = jax.random.PRNGKey(rows * 131 + vocab)
        z = jax.random.normal(key, (rows, vocab)) * 4
        y = jax.random.randint(jax.random.PRNGKey(1), (rows,), 0, vocab)
        n, d1, d2 = calib_stats(z, y, temp)
        e1, e2, zy, nll_rows = calib_nll_ref(z, y, temp)
        np.testing.assert_allclose(float(n), float(jnp.mean(nll_rows)), rtol=1e-5)
        np.testing.assert_allclose(float(n), float(nll_ref(z, y, temp)), rtol=1e-5)
        g = jax.grad(lambda t: nll_ref(z, y, t))(jnp.float32(temp))
        h = jax.grad(jax.grad(lambda t: nll_ref(z, y, t)))(jnp.float32(temp))
        np.testing.assert_allclose(float(d1), float(g), rtol=5e-3, atol=1e-5)
        np.testing.assert_allclose(float(d2), float(h), rtol=5e-3, atol=1e-3)

    def test_newton_fit_matches_reference_fitter(self):
        from repro.core.calibration import fit_temperature
        from repro.kernels.ops import fit_temperature_kernel

        key = jax.random.PRNGKey(7)
        z = jax.random.normal(key, (4000, 50)) * 3
        y = jax.random.categorical(jax.random.PRNGKey(8), z / 2.5)
        t_k, _ = fit_temperature_kernel(z, y)
        t_r, _ = fit_temperature(z, y)
        assert abs(float(t_k) - float(t_r)) < 0.05
        assert 2.2 < float(t_k) < 2.9  # planted T* = 2.5
