"""Offload engine + simulator + partition optimizer integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import OffloadPolicy, make_policy
from repro.data.synthetic import cifar_like
from repro.models import convnet
from repro.offload import latency as L
from repro.offload.engine import convnet_engine
from repro.offload.simulator import (
    missed_deadline_curve,
    missed_deadline_probability,
    simulate_batches,
)


@pytest.fixture(scope="module")
def setup():
    data = cifar_like(n_train=64, n_val=512, n_test=1024, seed=3)
    params = convnet.init_params(jax.random.PRNGKey(0))
    return data, params


def test_engine_routes_by_confidence(setup):
    data, params = setup
    policy = OffloadPolicy(p_tar=0.5, temperatures=[1.0])
    engine = convnet_engine(params, policy, branch=1)
    out = engine.infer({"images": jnp.asarray(data.test_x[:256])})
    assert out["prediction"].shape == (256,)
    assert engine.stats.requests == 256
    assert engine.stats.on_device + engine.stats.offloaded == 256
    # engine prediction must agree with running the branches manually
    logits, hidden = convnet.edge_forward(params, jnp.asarray(data.test_x[:256]), 1)
    conf = np.asarray(jax.nn.softmax(logits, -1).max(-1))
    np.testing.assert_array_equal(np.asarray(out["on_device"]), conf >= 0.5)


def test_engine_cloud_equals_full_model(setup):
    """Offloaded samples must get EXACTLY the full model's prediction
    (partitioned execution is numerically the unpartitioned model)."""
    data, params = setup
    x = jnp.asarray(data.test_x[:128])
    policy = OffloadPolicy(p_tar=1.1, temperatures=[1.0])  # force offload all
    engine = convnet_engine(params, policy, branch=1)
    out = engine.infer({"images": x})
    assert engine.stats.offloaded == 128
    full = convnet.forward(params, x)
    np.testing.assert_array_equal(
        out["prediction"], np.asarray(jnp.argmax(full["logits"], -1))
    )


def test_engine_all_on_device(setup):
    data, params = setup
    policy = OffloadPolicy(p_tar=0.0, temperatures=[1.0])
    engine = convnet_engine(params, policy, branch=1)
    out = engine.infer({"images": jnp.asarray(data.test_x[:64])})
    assert engine.stats.offloaded == 0
    assert engine.stats.payload_bytes == 0


def test_simulator_latency_accounting():
    """Hand-built logits: half supremely confident, half uniform."""
    n, c = 1024, 10
    z_conf = np.zeros((n, c), np.float32)
    z_conf[: n // 2, 0] = 100.0  # first half exits on device
    final = np.zeros((n, c), np.float32)
    final[:, 1] = 100.0
    labels = np.concatenate(
        [np.zeros(n // 2, np.int64), np.ones(n // 2, np.int64)]
    )
    prof = L.paper_2020()
    outs = simulate_batches([z_conf], final, labels, 0.9, [1.0], prof, batch_size=256)
    t_dev = L.edge_time(prof, 1)
    t_cloud = t_dev + L.comm_time(prof, 1) + L.cloud_time(prof, 1)
    for o in outs:
        assert o.accuracy == 1.0  # device half correct cls 0, cloud half cls 1
        assert t_dev <= o.time_s <= t_cloud
    # batches are ordered: first two all-device, last two all-cloud
    np.testing.assert_allclose(outs[0].time_s, t_dev, rtol=1e-6)
    np.testing.assert_allclose(outs[-1].time_s, t_cloud, rtol=1e-6)


def test_simulator_includes_tail_batch():
    """n not divisible by batch_size: the final partial batch must be
    simulated (the old code silently dropped it); drop_last=True restores
    the truncating behavior."""
    n, c = 1000, 10  # 1000 = 3*256 + 232
    z = np.zeros((n, c), np.float32)
    z[:, 0] = 100.0  # everyone exits on device
    final = np.zeros((n, c), np.float32)
    labels = np.zeros(n, np.int64)
    prof = L.paper_2020()
    outs = simulate_batches([z], final, labels, 0.9, [1.0], prof, batch_size=256)
    assert len(outs) == 4  # 3 full + 1 tail of 232
    assert all(o.accuracy == 1.0 and o.on_device_frac == 1.0 for o in outs)
    trunc = simulate_batches(
        [z], final, labels, 0.9, [1.0], prof, batch_size=256, drop_last=True
    )
    assert len(trunc) == 3
    assert [o.time_s for o in trunc] == [o.time_s for o in outs[:3]]


def test_simulator_network_repricing():
    """A time-varying network changes ONLY the comm component, per batch."""
    from repro.serving.network import FixedRateNetwork, TraceNetwork

    n, c = 512, 10
    z = np.zeros((n, c), np.float32)  # uniform logits: everyone offloads
    final = np.zeros((n, c), np.float32)
    final[:, 0] = 100.0
    labels = np.zeros(n, np.int64)
    prof = L.paper_2020()
    base = simulate_batches([z], final, labels, 0.9, [1.0], prof, batch_size=256)
    fixed = simulate_batches(
        [z], final, labels, 0.9, [1.0], prof, batch_size=256,
        network=FixedRateNetwork(prof.uplink_bps),
    )
    assert [o.time_s for o in fixed] == [o.time_s for o in base]
    halved = TraceNetwork([0.0, 1.0], [prof.uplink_bps, prof.uplink_bps / 2])
    slow = simulate_batches(
        [z], final, labels, 0.9, [1.0], prof, batch_size=256,
        network=halved, batch_times_s=[0.0, 2.0],
    )
    assert slow[0].time_s == pytest.approx(base[0].time_s)
    assert slow[1].time_s == pytest.approx(
        base[1].time_s + L.comm_time(prof, 1)
    )
    with pytest.raises(ValueError):  # one timestamp per simulated batch
        simulate_batches(
            [z], final, labels, 0.9, [1.0], prof, batch_size=256,
            network=halved, batch_times_s=[0.0],
        )


def test_engine_timing_hooks():
    """edge_step/cloud_step accumulate wall-clock and fire the hook."""
    from repro.core.policy import OffloadPlan
    from repro.core.calibration import TemperatureScaling
    from repro.offload.engine import OffloadEngine

    calls = []
    engine = OffloadEngine(
        edge_fn=lambda b: {"exit_logits": np.zeros((4, 10), np.float32),
                           "payload": np.zeros((4, 8), np.float32)},
        cloud_fn=lambda p: {"logits": np.ones((p.shape[0], 10), np.float32)},
        plan=OffloadPlan(p_tar=0.5,
                         calibrators=[TemperatureScaling.from_temperature(1.0)]),
        timing_hook=lambda tier, dt, b: calls.append((tier, b)),
    )
    out = engine.infer({"x": None})
    assert out["prediction"].shape == (4,)
    assert engine.stats.edge_calls == 1
    assert engine.stats.cloud_calls == 1  # uniform logits: all offloaded
    assert engine.stats.edge_time_s > 0 and engine.stats.cloud_time_s > 0
    assert ("edge", 4) in calls and ("cloud", 4) in calls


def test_missed_deadline_monotone_in_t_tar():
    n, c = 2048, 10
    rng = np.random.default_rng(0)
    z = rng.normal(size=(n, c)).astype(np.float32) * 3
    final = rng.normal(size=(n, c)).astype(np.float32) * 3
    labels = rng.integers(0, c, n)
    prof = L.paper_2020()
    outs = simulate_batches([z], final, labels, 0.5, [1.0], prof)
    ts = [1e-4, 1e-3, 1e-2, 1e-1]
    curve = missed_deadline_curve(outs, ts, 0.0)  # p_tar=0: latency-only
    assert all(a >= b for a, b in zip(curve, curve[1:]))  # non-increasing
    assert curve[-1] == 0.0  # huge deadline always met (accuracy ignored)


def test_partition_optimizer_prefers_cheap_exit():
    from repro.core.partition import choose_partition

    rng = np.random.default_rng(1)
    # exit0 confident (cheap, rarely offloads); exit1 unconfident
    z0 = np.zeros((512, 10), np.float32)
    z0[:, 0] = 20.0
    z1 = rng.normal(size=(512, 10)).astype(np.float32) * 0.01
    cands = choose_partition(
        [z0, z1],
        temperatures=[1.0, 1.0],
        p_tar=0.8,
        edge_times_s=[1e-3, 2e-3],
        cloud_times_s=[5e-3, 4e-3],
        payload_bytes=[65536, 24576],
        exit_layer_indices=[0, 1],
        uplink_bps=18.8e6,
    )
    assert cands[0].exit_index == 0
    assert cands[0].offload_prob < 0.01
    assert cands[1].offload_prob > 0.9
