"""Model correctness: prefill/decode equivalence, SSD scan vs recurrence,
sliding-window semantics, GQA vs MHA reference, MoE properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mb
from repro.models import registry


def _f32(arch, **kw):
    return get_smoke(arch).replace(dtype="float32", **kw)


@pytest.mark.parametrize(
    "arch,kw",
    [
        ("qwen3-8b", {}),
        ("qwen2-72b", {}),  # qkv bias
        ("olmo-1b", {}),  # non-parametric LN
        ("mamba2-130m", {}),
        ("jamba-v0.1-52b", {"moe_capacity_factor": 8.0}),
        ("granite-moe-3b-a800m", {"moe_capacity_factor": 8.0}),
        ("qwen2-72b", {"sliding_window": 8}),
    ],
)
def test_prefill_decode_equivalence(arch, kw):
    """Stepwise decode must reproduce teacher-forced prefill logits."""
    cfg = _f32(arch, **kw)
    key = jax.random.PRNGKey(0)
    params = registry.init_params(key, cfg)
    b, s = 2, 16
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full = registry.forward_train(params, cfg, {"tokens": toks}, remat=False)
    caches = registry.init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        out, caches = registry.decode_step(
            params, cfg, toks[:, t : t + 1], caches, jnp.int32(t)
        )
        outs.append(out["logits"][:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full["logits"], rtol=2e-4, atol=2e-4)


def test_ssd_chunked_matches_naive_recurrence():
    """The chunked SSD scan equals the O(s) per-step recurrence."""
    key = jax.random.PRNGKey(0)
    b, s, h, p, g, n = 2, 32, 4, 8, 2, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    D = jnp.zeros((h,))

    y_chunk, S_chunk = mb.ssd_chunked(x, dt, A, B, C, D, chunk=8)

    # naive recurrence
    hg = h // g
    Bh = jnp.repeat(B, hg, axis=2)
    Ch = jnp.repeat(C, hg, axis=2)
    S = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        decay = jnp.exp(-dt[:, t] * A)  # (b,h)
        S = S * decay[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t], Bh[:, t], x[:, t]
        )
        ys.append(jnp.einsum("bhn,bhpn->bhp", Ch[:, t], S))
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_naive, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(S_chunk, S, rtol=1e-4, atol=1e-4)


def test_sliding_window_prefill_masks_old_tokens():
    """With window w, logits at position t must not depend on tokens < t-w+1."""
    cfg = _f32("qwen3-8b", sliding_window=4, num_layers=2)
    key = jax.random.PRNGKey(0)
    params = registry.init_params(key, cfg)
    b, s = 1, 12
    t1 = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    t2 = t1.at[:, 0:4].set((t1[:, 0:4] + 7) % cfg.vocab_size)  # perturb old tokens
    o1 = registry.forward_train(params, cfg, {"tokens": t1}, remat=False)["logits"]
    o2 = registry.forward_train(params, cfg, {"tokens": t2}, remat=False)["logits"]
    # last position attends to [s-4, s): identical in both inputs
    np.testing.assert_allclose(o1[:, -1], o2[:, -1], rtol=1e-5, atol=1e-5)
    # an early position inside the perturbed window must differ
    assert float(jnp.max(jnp.abs(o1[:, 3] - o2[:, 3]))) > 1e-4


def test_chunked_attention_matches_unchunked():
    cfg = _f32("qwen3-8b", num_layers=1)
    key = jax.random.PRNGKey(1)
    p = attn_mod.init_attention(key, cfg)
    b, s = 2, 64
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32) * 0.1
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    o1, _ = attn_mod.attention_prefill(p, cfg, x, pos, q_chunk=s)
    o2, _ = attn_mod.attention_prefill(p, cfg, x, pos, q_chunk=16)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


def test_gqa_reduces_to_mha_reference():
    """GQA with kv_heads == num_heads equals straightforward MHA."""
    cfg = _f32("olmo-1b", num_layers=1)  # kv == heads
    key = jax.random.PRNGKey(2)
    p = attn_mod.init_attention(key, cfg)
    b, s, hd = 1, 8, cfg.head_dim
    x = jax.random.normal(key, (b, s, cfg.d_model)) * 0.1
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    out, _ = attn_mod.attention_prefill(p, cfg, x, pos)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    from repro.models.layers import rope_freqs, apply_rope

    cos, sin = rope_freqs(cfg, pos)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -1e30)
    ref = jnp.einsum("bhqs,bshk->bqhk", jax.nn.softmax(scores, -1), v)
    ref = jnp.einsum("bshk,hkd->bsd", ref, p["wo"])
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


def test_moe_routes_topk_and_balances():
    from repro.models.moe import apply_moe, init_moe

    cfg = _f32("qwen3-moe-30b-a3b")
    key = jax.random.PRNGKey(3)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (4, 8, cfg.d_model)) * 0.5
    y, aux = apply_moe(p, cfg, x)
    assert y.shape == x.shape
    assert float(aux["moe_aux_loss"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz
    assert 0.0 <= float(aux["moe_dropped_frac"]) <= 1.0


def test_moe_zero_router_is_uniform_mixture():
    """With identical experts, MoE output must equal that single expert's MLP."""
    from repro.models.moe import apply_moe, init_moe

    cfg = _f32("granite-moe-3b-a800m", moe_capacity_factor=10.0)
    key = jax.random.PRNGKey(4)
    p = init_moe(key, cfg)
    # make all experts identical
    p = dict(p)
    for k in ("w_up", "w_down", "w_gate"):
        if k in p:
            p[k] = jnp.broadcast_to(p[k][:1], p[k].shape)
    x = jax.random.normal(key, (2, 4, cfg.d_model)) * 0.5
    y, _ = apply_moe(p, cfg, x)
    up = jax.nn.silu(x @ p["w_gate"][0]) * (x @ p["w_up"][0])
    ref = up @ p["w_down"][0]
    np.testing.assert_allclose(y, ref, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-130m", "jamba-v0.1-52b"])
def test_decode_unroll_matches_scan(arch):
    """The perf-pass unrolled decode (in-place stacked cache) is exact."""
    cfg = _f32(arch)
    cfg_u = cfg.replace(decode_unroll=True)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    b, L = 2, 32
    c1 = registry.init_cache(cfg, b, L)
    c2 = jax.tree.map(jnp.copy, c1)
    tok = jnp.ones((b, 1), jnp.int32)
    o1, c1 = registry.decode_step(params, cfg, tok, c1, jnp.int32(0))
    o2, c2 = registry.decode_step(params, cfg_u, tok, c2, jnp.int32(0))
    o1b, _ = registry.decode_step(params, cfg, tok, c1, jnp.int32(1))
    o2b, _ = registry.decode_step(params, cfg_u, tok, c2, jnp.int32(1))
    np.testing.assert_allclose(o1b["logits"], o2b["logits"], atol=1e-5)


def test_moe_shard_capacity_same_numerics_with_padded_experts():
    """The shard-friendly variant (experts padded to a multiple of 16 +
    capacity sharding constraints) must not change numerics: padded
    experts get -inf router logits and zero weights."""
    from repro.models.moe import apply_moe, init_moe, n_alloc_experts

    cfg = _f32("granite-moe-3b-a800m", moe_num_experts=6, moe_top_k=2,
               moe_capacity_factor=8.0)
    cfg_p = cfg.replace(moe_shard_capacity=True)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    Ea = n_alloc_experts(cfg_p)
    pad = Ea - cfg.moe_num_experts
    p_pad = dict(p)
    for k in ("w_up", "w_down", "w_gate"):
        if k in p_pad:
            p_pad[k] = jnp.pad(p_pad[k], ((0, pad), (0, 0), (0, 0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * 0.5
    y1, _ = apply_moe(p, cfg, x)
    y2, _ = apply_moe(p_pad, cfg_p, x)
    np.testing.assert_allclose(y1, y2, atol=1e-5)
