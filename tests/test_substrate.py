"""Substrate tests: optimizer, checkpointing, data pipeline, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.configs import get_smoke
from repro.data.pipeline import BatchIterator, TokenIterator
from repro.data.synthetic import cifar_like, lm_sequences
from repro.models import registry
from repro.training import checkpoint, optim


# ----------------------------------------------------------------- optimizer
def test_adamw_minimizes_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=200, warmup_steps=0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = optim.init(params)
    target = jnp.array([1.0, 2.0])

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return optim.update(cfg, params, g, state)

    for _ in range(200):
        params, state, m = step(params, state)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_grad_clip_bounds_update():
    cfg = optim.AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = optim.init(params)
    g = {"w": jnp.full(4, 1e6)}
    _, _, m = optim.update(cfg, params, g, state)
    assert float(m["grad_norm"]) > 1e5  # raw norm reported


def test_schedule_warmup_and_decay():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(optim.schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 0.1) < 1e-2


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke("qwen3-8b")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "ck.msgpack")
    checkpoint.save(path, params)
    template = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), params)
    restored = checkpoint.load(path, template)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.msgpack")
    checkpoint.save(path, {"w": jnp.zeros((3, 3))})
    with pytest.raises(ValueError):
        checkpoint.load(path, {"w": jnp.zeros((4, 3))})


# ---------------------------------------------------------------------- data
def test_cifar_like_deterministic_and_split_sizes():
    a = cifar_like(n_train=100, n_val=50, n_test=30, seed=7)
    b = cifar_like(n_train=100, n_val=50, n_test=30, seed=7)
    np.testing.assert_array_equal(a.train_x, b.train_x)
    assert a.train_x.shape == (100, 32, 32, 3)
    assert a.val_y.shape == (50,)
    assert a.test_y.shape == (30,)
    assert set(np.unique(a.train_y)) <= set(range(10))


def test_lm_sequences_learnable_structure():
    s = lm_sequences(20_000, 128, seed=1, order=1, branch=4)
    assert s.min() >= 0 and s.max() < 128
    # successor entropy per context must be ~log(branch), far below log(V)
    from collections import Counter, defaultdict

    succ = defaultdict(Counter)
    for a, b in zip(s[:-1], s[1:]):
        succ[int(a)][int(b)] += 1
    ents = []
    for c, counter in succ.items():
        tot = sum(counter.values())
        if tot < 20:
            continue
        p = np.array([v / tot for v in counter.values()])
        ents.append(-(p * np.log(p)).sum())
    assert np.mean(ents) < np.log(4) + 0.6  # vs log(128)=4.85


def test_batch_iterator_epochs_cover_data():
    arrays = {"x": np.arange(10), "y": np.arange(10) * 2}
    it = iter(BatchIterator(arrays, batch_size=5, seed=0))
    seen = np.concatenate([next(it)["x"], next(it)["x"]])
    assert sorted(seen.tolist()) == list(range(10))


def test_token_iterator_labels_shifted():
    stream = np.arange(1000, dtype=np.int32)
    it = iter(TokenIterator(stream, 4, 16, seed=0))
    b = next(it)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ------------------------------------------------------------------ sharding
def test_param_spec_rules():
    mesh = None
    try:
        from repro.launch.mesh import make_debug_mesh

        mesh = make_debug_mesh(1, 1)
    except Exception:
        pytest.skip("no devices")
    sharding.set_mesh(mesh)
    spec = sharding.spec_for("segments/0/attn/wq", (512, 16, 64))
    assert spec == P(None, "model", None) or spec == P(None, None, None)
    sharding.set_mesh(None)


def test_fit_spec_degrades_indivisible():
    import numpy as np

    from repro.launch.mesh import make_debug_mesh

    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    mesh = make_debug_mesh(1, 1)
    sharding.set_mesh(mesh)
    # model axis size 1: every sym resolves but axis size 1 keeps spec
    s = sharding.fit_spec(["model", None], (24, 8))
    assert s == P("model", None)
    sharding.set_mesh(None)


def test_param_specs_cover_whole_tree():
    cfg = get_smoke("jamba-v0.1-52b")
    shapes = registry.param_specs_shapes(cfg)
    specs = sharding.param_specs(shapes)
    n_leaves = len(jax.tree.leaves(shapes))
    n_specs = len(
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    )
    assert n_leaves == n_specs
