"""Distortion taxonomy, expert PlanBank, drifting-context serving.

Covers: distortion determinism under a fixed seed and the severity/identity
contracts; the edge-side feature estimator recognizing contexts from real
distorted images; PlanBank JSON round-trip with bit-identical per-context
gate decisions (mirroring tests/test_plan.py); drift schedules; and the
ISSUE 3 acceptance scenario -- under severity drift the expert bank must
beat the single global calibrated plan on the miscalibration gap, shared
verbatim with the CI-asserted benchmark via repro.serving.scenarios.
"""
import numpy as np
import pytest

from repro.core import DistortionEstimator, OffloadPlan, PlanBank, fit_bank
from repro.core.calibration import TemperatureScaling
from repro.data.distortion import (
    CLEAN,
    DistortionSpec,
    FEATURE_NAMES,
    apply_distortion,
    default_contexts,
    distort_splits,
    input_features,
)
from repro.serving.drift import (
    ContextualLogitsCore,
    MarkovContextSchedule,
    PiecewiseSchedule,
)
from repro.serving.scenarios import (
    drift_contexts,
    fit_drift_plans,
    run_distortion_drift,
    severity_drift_schedule,
    synthetic_distorted_cascade,
)


@pytest.fixture(scope="module")
def images():
    rng = np.random.default_rng(0)
    return (rng.standard_normal((64, 32, 32, 3)) * 1.5).astype(np.float32)


@pytest.fixture(scope="module")
def drift_data():
    # the reference (full-size) scenario -- the same data the CI-asserted
    # benchmark runs, so the acceptance margin here transfers to CI
    val, test = synthetic_distorted_cascade()
    return val, test


# ------------------------------------------------------------- distortions
def test_spec_key_round_trip():
    for spec in default_contexts() + [DistortionSpec("box_blur", 5)]:
        assert DistortionSpec.parse(spec.key) == spec
    assert CLEAN.key == "clean"
    with pytest.raises(ValueError):
        DistortionSpec("motion_blur", 1)
    with pytest.raises(ValueError):
        DistortionSpec("gaussian_noise", 6)
    with pytest.raises(ValueError):
        DistortionSpec("clean", 2)
    with pytest.raises(ValueError):
        DistortionSpec.parse("gaussian_noise")


def test_distortion_deterministic(images):
    """Same (x, spec, seed) -> bit-identical output, any call order."""
    for spec in default_contexts(severities=(2, 4), include_clean=False):
        a = apply_distortion(images, spec, seed=3)
        b = apply_distortion(images, spec, seed=3)
        np.testing.assert_array_equal(a, b)
    noisy1 = apply_distortion(images, DistortionSpec("gaussian_noise", 3), seed=3)
    noisy2 = apply_distortion(images, DistortionSpec("gaussian_noise", 3), seed=4)
    assert not np.array_equal(noisy1, noisy2)  # seed actually matters


def test_clean_is_identity_and_severity_monotone(images):
    np.testing.assert_array_equal(apply_distortion(images, CLEAN), images)
    # distance from the original grows with severity, for every kind
    for kind in ("gaussian_noise", "gaussian_blur", "box_blur", "contrast",
                 "brightness"):
        dists = [
            float(np.mean((apply_distortion(images, DistortionSpec(kind, s),
                                            seed=0) - images) ** 2))
            for s in range(1, 6)
        ]
        assert dists == sorted(dists), (kind, dists)
        assert dists[0] > 0


def test_distort_splits_labels_untouched():
    from repro.data.synthetic import cifar_like

    data = cifar_like(n_train=32, n_val=16, n_test=16, seed=1)
    out = distort_splits(data, DistortionSpec("gaussian_blur", 2))
    np.testing.assert_array_equal(out.train_y, data.train_y)
    np.testing.assert_array_equal(out.test_y, data.test_y)
    assert out.train_x.shape == data.train_x.shape
    assert not np.array_equal(out.train_x, data.train_x)
    # each split independently seeded: val noise != test noise pattern
    spec = DistortionSpec("gaussian_noise", 3)
    out = distort_splits(data, spec)
    assert not np.array_equal(out.val_x[:16] - data.val_x[:16],
                              out.test_x[:16] - data.test_x[:16])


def test_input_features_shape_and_blur_response(images):
    f = input_features(images)
    assert f.shape == (len(images), len(FEATURE_NAMES))
    blurred = input_features(
        apply_distortion(images, DistortionSpec("gaussian_blur", 4))
    )
    noisy = input_features(
        apply_distortion(images, DistortionSpec("gaussian_noise", 4))
    )
    i_lap = FEATURE_NAMES.index("lap_var")
    assert blurred[:, i_lap].mean() < f[:, i_lap].mean() < noisy[:, i_lap].mean()


# -------------------------------------------------------------- estimator
def test_estimator_recognizes_contexts(images):
    contexts = drift_contexts()
    feats = {
        spec.key: input_features(apply_distortion(images, spec, seed=1))
        for spec in contexts
    }
    est = DistortionEstimator.fit(feats, feature_names=FEATURE_NAMES)
    # held-out realizations of the same distortions
    for spec in contexts:
        held_out = input_features(apply_distortion(images, spec, seed=9))
        assert est.predict(held_out) == spec.key
        per_sample = est.predict_per_sample(held_out)
        # per-sample on unstructured noise images is harder than the
        # per-batch rule the serving path uses; structured cifar_like
        # frames (the acceptance test) give >0.95
        assert np.mean([p == spec.key for p in per_sample]) > 0.8
    # round-trip preserves every verdict
    rt = DistortionEstimator.from_dict(est.to_dict())
    for spec in contexts:
        f = input_features(apply_distortion(images, spec, seed=5))
        assert rt.predict_per_sample(f) == est.predict_per_sample(f)


def test_estimator_unknown_verdict_on_composed_distortions():
    """Estimator robustness (ROADMAP): the bank is fit on PURE contexts;
    composed distortions (noise then blur, blur then noise) are inputs no
    expert was fit for. With the distance/margin thresholds set, the
    batch-level verdict must stay correct on held-out pure contexts and
    become UNKNOWN on composed ones -- and `PlanBank.select` must then
    fall back to the DEFAULT plan instead of the nearest wrong expert."""
    from repro.core import UNKNOWN_CONTEXT
    from repro.data.synthetic import cifar_like
    from repro.serving.scenarios import drift_contexts

    imgs = cifar_like(n_train=8, n_val=256, n_test=256, seed=1)
    contexts = drift_contexts()
    feats = {
        s.key: input_features(apply_distortion(imgs.val_x, s, seed=11))
        for s in contexts
    }
    est = DistortionEstimator.fit(
        feats, feature_names=FEATURE_NAMES,
        unknown_distance=0.15, unknown_margin=0.15,
    )
    # held-out realizations of the PURE fit contexts still classify
    for s in contexts:
        f = input_features(apply_distortion(imgs.test_x, s, seed=12))
        assert est.predict(f) == s.key
    # composed distortions the bank never saw -> unknown, not wrong-expert
    composed = []
    for a, b in [(("gaussian_blur", 3), ("gaussian_noise", 2)),
                 (("gaussian_noise", 4), ("gaussian_blur", 4))]:
        x = apply_distortion(imgs.test_x, DistortionSpec(*a), seed=12)
        x = apply_distortion(x, DistortionSpec(*b), seed=13)
        composed.append(input_features(x))
    for f in composed:
        assert est.predict(f) == UNKNOWN_CONTEXT

    # a bank embedding this estimator serves composed traffic with the
    # default plan (the broadest calibrator), never a wrong expert
    logits = {s.key: np.random.default_rng(0).normal(size=(256, 10)) for s in contexts}
    y = np.random.default_rng(1).integers(0, 10, 256)
    bank = fit_bank(
        {k: [z, z] for k, z in logits.items()}, y, p_tar=0.8,
        default_context="clean", features_by_context=feats,
        estimator_kwargs=dict(unknown_distance=0.15, unknown_margin=0.15),
    )
    ctx, plan = bank.select(composed[0])
    assert ctx == UNKNOWN_CONTEXT
    assert plan is bank.default_plan

    # thresholds survive the JSON round-trip verbatim
    rt = DistortionEstimator.from_dict(est.to_dict())
    assert rt.unknown_distance == est.unknown_distance
    assert rt.unknown_margin == est.unknown_margin
    for f in composed:
        assert rt.predict(f) == UNKNOWN_CONTEXT


def test_estimator_unknown_ids_and_per_sample():
    """predict_ids marks unknowns as -1 and predict_per_sample mirrors it;
    thresholds off (None) never produce unknowns -- the pre-existing
    behavior."""
    from repro.core import UNKNOWN_CONTEXT

    rng = np.random.default_rng(0)
    feats = {"a": rng.normal(size=(64, 4)), "b": rng.normal(3.0, 1.0, (64, 4))}
    est = DistortionEstimator.fit(feats)
    assert (est.predict_ids(feats["a"]) >= 0).all()
    strict = DistortionEstimator.fit(feats, unknown_distance=0.0)
    ids = strict.predict_ids(feats["a"])
    assert (ids == -1).all()
    assert set(strict.predict_per_sample(feats["a"])) == {UNKNOWN_CONTEXT}
    # margin rule alone: ambiguous points midway between centroids
    margin_est = DistortionEstimator.fit(feats, unknown_margin=1e9)
    assert set(margin_est.predict_per_sample(feats["b"])) == {UNKNOWN_CONTEXT}


# --------------------------------------------------------------- plan bank
def test_plan_bank_json_round_trip_bit_identical(drift_data):
    """A bank serialized to JSON and reloaded produces bit-identical gate
    decisions per context (the tests/test_plan.py contract, per expert)."""
    val, test = drift_data
    _, _, bank = fit_drift_plans(val)
    reloaded = PlanBank.from_json(bank.to_json())
    assert reloaded.to_dict() == bank.to_dict()
    assert reloaded.contexts == bank.contexts
    for ctx in bank.contexts:
        z = test["exit_logits"][ctx][1]
        g0 = bank.plans[ctx].gate(z)
        g1 = reloaded.plans[ctx].gate(z)
        np.testing.assert_array_equal(np.asarray(g0.exit_mask),
                                      np.asarray(g1.exit_mask))
        np.testing.assert_array_equal(np.asarray(g0.confidence),
                                      np.asarray(g1.confidence))
    # the embedded estimator survives too
    for ctx in bank.contexts:
        f = test["features"][ctx]
        assert reloaded.estimator.predict(f) == bank.estimator.predict(f)


def test_plan_bank_save_load_and_validation(tmp_path, drift_data):
    val, _ = drift_data
    _, _, bank = fit_drift_plans(val)
    path = str(tmp_path / "bank.json")
    bank.save(path)
    reloaded = PlanBank.load(path)
    assert reloaded.default_context == "clean"
    assert reloaded.default_plan.p_tar == bank.default_plan.p_tar

    with pytest.raises(ValueError, match="newer"):
        d = bank.to_dict()
        d["version"] = 99
        PlanBank.from_dict(d)
    with pytest.raises(ValueError, match="default context"):
        PlanBank(plans=dict(bank.plans), default_context="fog@9")
    with pytest.raises(ValueError, match="at least one"):
        PlanBank(plans={}, default_context="clean")


def test_plan_bank_fallback_and_select(drift_data):
    val, test = drift_data
    _, _, bank = fit_drift_plans(val)
    assert bank.plan_for(None) is bank.default_plan
    assert bank.plan_for("never_fitted") is bank.default_plan
    assert bank.plan_for("gaussian_blur@3") is bank.plans["gaussian_blur@3"]
    ctx, plan = bank.select(test["features"]["gaussian_blur@3"])
    assert ctx == "gaussian_blur@3"
    assert plan is bank.plans[ctx]
    bare = PlanBank(
        plans={"clean": bank.default_plan}, default_context="clean"
    )
    with pytest.raises(ValueError, match="estimator"):
        bare.select(test["features"]["clean"])


def test_fit_bank_validation(drift_data):
    val, _ = drift_data
    y = val["labels"]
    logits = {k: [v[1], v[2]] for k, v in val["exit_logits"].items()}
    with pytest.raises(ValueError, match="default context"):
        fit_bank(logits, y, p_tar=0.8, default_context="fog@9")
    with pytest.raises(ValueError, match="no logits"):
        fit_bank({"clean": logits["clean"]}, y, p_tar=0.8,
                 features_by_context={"clean": val["features"]["clean"],
                                      "extra": val["features"]["clean"]})
    # experts genuinely differ: distorted temperatures exceed the clean fit
    _, global_plan, bank = fit_drift_plans(val)
    t_clean = bank.plans["clean"].temperatures[0]
    assert bank.plans["clean"].temperatures == global_plan.temperatures
    for ctx in bank.contexts:
        if ctx != "clean":
            assert bank.plans[ctx].temperatures[0] > t_clean * 1.5


# -------------------------------------------------------------- schedules
def test_piecewise_schedule():
    sch = PiecewiseSchedule([(0.0, "clean"), (10.0, "fog"), (20.0, "clean")])
    assert sch.context_at(0.0) == "clean"
    assert sch.context_at(9.999) == "clean"
    assert sch.context_at(10.0) == "fog"
    assert sch.context_at(25.0) == "clean"
    assert sch.contexts == ["clean", "fog"]
    with pytest.raises(ValueError):
        PiecewiseSchedule([(1.0, "clean")])  # must start at 0
    with pytest.raises(ValueError):
        PiecewiseSchedule([(0.0, "a"), (0.0, "b")])  # strictly increasing


def test_markov_schedule_deterministic():
    def seq(seed):
        sch = MarkovContextSchedule(["a", "b", "c"], dwell_s=1.0, p_stay=0.5,
                                    seed=seed)
        return [sch.context_at(t * 0.5) for t in range(40)]

    assert seq(3) == seq(3)
    assert seq(3) != seq(4)
    # query order must not change materialized states
    sch = MarkovContextSchedule(["a", "b"], dwell_s=1.0, p_stay=0.5, seed=7)
    late_first = sch.context_at(15.0)
    assert sch.context_at(15.0) == late_first
    fresh = MarkovContextSchedule(["a", "b"], dwell_s=1.0, p_stay=0.5, seed=7)
    for t in range(16):
        fresh.context_at(float(t))
    assert fresh.context_at(15.0) == late_first
    with pytest.raises(ValueError):
        MarkovContextSchedule(["a", "b"], transition=np.array([[0.5, 0.2],
                                                              [0.5, 0.5]]))


# ------------------------------------------- acceptance: drifting serving
def test_contextual_core_oracle_vs_estimator(drift_data):
    """With a near-perfect estimator the estimated-context path must agree
    with the honest path's telemetry on context assignment."""
    val, test = drift_data
    _, _, bank = fit_drift_plans(val)
    sched = severity_drift_schedule()
    core = ContextualLogitsCore(
        test["exit_logits"], test["final"], bank, sched,
        labels=test["labels"], features_by_context=test["features"],
    )
    on, pred, conf, ctx, est = core.gate(0, 1, 0.8, t=0.0)
    assert ctx == sched.context_at(0.0)
    assert est in bank.contexts
    assert isinstance(on, bool) and isinstance(pred, int)
    # single-plan core: no estimated context to report
    plain = ContextualLogitsCore(
        test["exit_logits"], test["final"], bank.default_plan, sched,
        labels=test["labels"],
    )
    assert plain.gate(0, 1, 0.8, t=0.0)[4] is None


def test_contextual_core_validation(drift_data):
    val, test = drift_data
    _, _, bank = fit_drift_plans(val)
    with pytest.raises(ValueError, match="no logits"):
        ContextualLogitsCore(
            {"clean": test["exit_logits"]["clean"]},
            {"clean": test["final"]["clean"]},
            bank, severity_drift_schedule(), labels=test["labels"],
        )
    entropy_plan = OffloadPlan(
        p_tar=0.8,
        calibrators=[TemperatureScaling.from_temperature(1.0)] * 2,
        criterion="entropy", entropy_threshold=0.5,
    )
    with pytest.raises(ValueError, match="criteri"):
        ContextualLogitsCore(
            test["exit_logits"], test["final"], entropy_plan,
            severity_drift_schedule(),
        )


def test_bank_beats_global_under_drift(drift_data):
    """THE acceptance criterion: under severity drift the expert bank's
    on-device-weighted miscalibration gap must beat the single global
    calibrated plan's, which must beat the uncalibrated plan's -- same
    scenario the CI-asserted BENCH_distortion.json is generated from."""
    val, test = drift_data
    uncal, global_plan, bank = fit_drift_plans(val)
    tels = {
        name: run_distortion_drift(p, test, n_requests=900)
        for name, p in (("uncal", uncal), ("global", global_plan),
                        ("bank", bank))
    }
    gaps = {k: t.miscalibration_gap() for k, t in tels.items()}
    assert gaps["bank"] < 0.5 * gaps["global"], gaps
    assert gaps["global"] < gaps["uncal"], gaps
    # accuracy must not be sacrificed for the gap win
    assert tels["bank"].accuracy >= tels["global"].accuracy - 0.01
    # per-context telemetry is populated and the estimator is near-perfect
    per_ctx = tels["bank"].per_context_summary()
    assert len(per_ctx) >= 3  # the schedule visited several regimes
    for ctx, row in per_ctx.items():
        assert row["est_match_rate"] > 0.9, (ctx, row)


def test_bank_composes_with_controller(drift_data):
    """PlanBank + OnlineController: bandwidth-driven (branch, p_tar)
    re-scoring must coexist with per-context expert selection."""
    val, test = drift_data
    _, global_plan, bank = fit_drift_plans(val)
    tel = run_distortion_drift(bank, test, n_requests=900,
                               with_controller=True, val=val)
    assert len(tel.records) == 900
    # the controller acted at least once and per-context records remain
    assert len(tel.controller_events) >= 1
    g_tel = run_distortion_drift(global_plan, test, n_requests=900)
    assert tel.miscalibration_gap() < g_tel.miscalibration_gap()


def test_context_aware_controller_beats_clean_rescore(drift_data):
    """ISSUE 5 acceptance: on the Markov drift scenario, the
    context-aware OnlineController arm (candidate tables weighted by the
    traffic mix the runtime's own telemetry observed) must show a
    strictly smaller miscalibration gap than the clean-validation-only
    re-score -- same global plan, same reference controller config, the
    INFORMATION is the only difference. The same comparison is asserted
    in CI from BENCH_distortion.json at the full request count."""
    from repro.serving.scenarios import drift_controller_config

    val, test = drift_data
    _, global_plan, _ = fit_drift_plans(val)
    gaps = {}
    for name, ca in (("clean", False), ("context_aware", True)):
        tel = run_distortion_drift(
            global_plan, test, n_requests=600, with_controller=True,
            val=val, context_aware=ca,
            controller_config=drift_controller_config(),
        )
        gaps[name] = tel.miscalibration_gap()
        if ca:  # the mix-weighted arm genuinely moved the deployment
            assert len(tel.controller_events) >= 2
    assert gaps["context_aware"] < gaps["clean"], gaps


def test_telemetry_context_mix_estimate(drift_data):
    """The runtime records gate-time context verdicts and the windowed
    mix excludes unknown verdicts -- the event-runtime analogue of
    FleetTelemetry.context_mix_estimate."""
    from repro.core.bank import UNKNOWN_CONTEXT

    val, test = drift_data
    _, _, bank = fit_drift_plans(val)
    tel = run_distortion_drift(bank, test, n_requests=300)
    assert tel.context_samples, "gate-time contexts were not observed"
    t_last = max(t for t, _ in tel.context_samples)
    mix = tel.context_mix_estimate(window_s=t_last + 1.0, now=t_last)
    assert mix is not None
    assert sum(mix.values()) == pytest.approx(1.0)
    assert UNKNOWN_CONTEXT not in mix
    assert set(mix) <= set(test["exit_logits"])
    # an empty window far in the future has nothing recognizable
    assert tel.context_mix_estimate(window_s=0.5, now=t_last + 1e6) is None


def test_contextual_records_round_trip_summary(drift_data):
    import json

    val, test = drift_data
    _, _, bank = fit_drift_plans(val)
    tel = run_distortion_drift(bank, test, n_requests=300)
    json.dumps(tel.summary())
    json.dumps(tel.per_context_summary())
    assert "miscalibration_gap" in tel.summary()
    for r in tel.records:
        assert r.context in bank.contexts
        assert r.est_context in bank.contexts
