"""Core library: gating, temperature scaling, metrics.

Hypothesis property tests on the same invariants live in
test_core_properties.py (skipped when hypothesis is not installed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    apply_gate,
    cascade_gate,
    ece,
    fit_temperature,
    gate_statistics,
    inference_outage_probability,
    make_policy,
)
from repro.core.calibration import nll


# ------------------------------------------------------------------ gating
def test_gate_statistics_match_softmax():
    z = jax.random.normal(jax.random.PRNGKey(0), (16, 10)) * 3
    conf, pred, ent = gate_statistics(z, 1.0)
    p = jax.nn.softmax(z, -1)
    np.testing.assert_allclose(conf, jnp.max(p, -1), rtol=1e-6)
    np.testing.assert_array_equal(pred, jnp.argmax(z, -1))
    np.testing.assert_allclose(
        ent, -jnp.sum(p * jnp.log(p + 1e-30), -1), rtol=1e-4, atol=1e-5
    )


def test_cascade_earliest_exit_wins():
    b, c = 6, 5
    # exit0 very confident for first 3 samples, exit1 confident for next 2
    e0 = np.full((b, c), 0.0, np.float32)
    e0[:3, 0] = 50.0
    e1 = np.full((b, c), 0.0, np.float32)
    e1[:5, 1] = 50.0
    f = np.zeros((b, c), np.float32)
    f[:, 2] = 50.0
    out = cascade_gate([jnp.asarray(e0), jnp.asarray(e1)], jnp.asarray(f), 0.9)
    np.testing.assert_array_equal(np.asarray(out["exit_index"]), [0, 0, 0, 1, 1, 2])
    np.testing.assert_array_equal(np.asarray(out["prediction"]), [0, 0, 0, 1, 1, 2])


# ------------------------------------------------------------- calibration
def _make_overconfident_logits(key, n=4000, c=10, scale=8.0, acc=0.7):
    """Synthetic overconfident classifier: correct with prob `acc` but
    logit margins imply much higher confidence."""
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.randint(k1, (n,), 0, c)
    correct = jax.random.uniform(k2, (n,)) < acc
    pred = jnp.where(
        correct, labels, (labels + 1 + jax.random.randint(k3, (n,), 0, c - 1)) % c
    )
    z = jax.random.normal(k3, (n, c))
    z = z.at[jnp.arange(n), pred].add(scale)
    return z, labels


def test_temperature_scaling_reduces_nll_and_ece():
    z, y = _make_overconfident_logits(jax.random.PRNGKey(0))
    T, info = fit_temperature(z, y)
    assert float(T) > 1.5  # overconfident -> needs softening
    assert float(info["nll_after"]) < float(info["nll_before"]) - 0.05
    conf1, pred, _ = gate_statistics(z, 1.0)
    confT, _, _ = gate_statistics(z, T)
    correct = np.asarray(pred == y)
    assert ece(confT, correct) < ece(conf1, correct) - 0.02


def test_fit_temperature_identity_when_calibrated():
    """Logits that are already log-probs of the true generative process
    should get T close to 1."""
    key = jax.random.PRNGKey(1)
    n, c = 8000, 5
    logp = jax.nn.log_softmax(jax.random.normal(key, (n, c)) * 1.5)
    labels = jax.random.categorical(jax.random.PRNGKey(2), logp)
    T, _ = fit_temperature(logp, labels)
    assert 0.9 < float(T) < 1.15


def test_nll_convex_minimum_interior():
    z, y = _make_overconfident_logits(jax.random.PRNGKey(3))
    T, _ = fit_temperature(z, y)
    for delta in (0.8, 1.25):
        assert float(nll(z, y, T)) <= float(nll(z, y, T * delta)) + 1e-6


# ---------------------------------------------------------------- metrics
def test_outage_probability_calibrated_lower():
    """The paper's headline: calibrated branch has lower outage."""
    z, y = _make_overconfident_logits(jax.random.PRNGKey(4), n=14336)
    T, _ = fit_temperature(z, y)
    p_tar = 0.85
    out_conv = inference_outage_probability(z, y, p_tar, 1.0)
    out_cal = inference_outage_probability(z, y, p_tar, float(T))
    assert out_cal <= out_conv
    assert out_conv > 0.5  # overconfident model misses the target often


def test_make_policy_conventional_vs_calibrated():
    z, y = _make_overconfident_logits(jax.random.PRNGKey(5))
    pol_conv = make_policy([z], y, p_tar=0.8, calibrated=False)
    pol_cal = make_policy([z], y, p_tar=0.8, calibrated=True)
    assert pol_conv.temperatures == [1.0]
    assert pol_cal.temperatures[0] > 1.2
    # calibration lowers on-device rate for overconfident nets (Fig. 2)
    g_conv = pol_conv.gate(z)
    g_cal = pol_cal.gate(z)
    assert int(g_cal.exit_mask.sum()) < int(g_conv.exit_mask.sum())


def test_ece_perfect_and_worst():
    conf = np.array([0.8] * 100)
    assert ece(conf, np.array([1.0] * 80 + [0.0] * 20)) < 0.01
    assert ece(conf, np.array([0.0] * 100)) > 0.75


def test_vector_scaling_reduces_nll():
    from repro.core.calibration import fit_vector_scaling

    z, y = _make_overconfident_logits(jax.random.PRNGKey(9))
    w, b, info = fit_vector_scaling(z, y)
    assert float(info["nll_after"]) < float(info["nll_before"])
    assert w.shape == (10,) and b.shape == (10,)


def test_sequential_cascade_calibration():
    """Beyond-paper: exit i fit only on samples that reach it."""
    from repro.core.calibration import calibrate_cascade

    key = jax.random.PRNGKey(10)
    z0, y = _make_overconfident_logits(key, n=3000)
    z1, _ = _make_overconfident_logits(jax.random.PRNGKey(11), n=3000, acc=0.9)
    temps_all = calibrate_cascade([z0, z1], y, sequential=False)
    temps_seq = calibrate_cascade([z0, z1], y, sequential=True, p_tar=0.8)
    assert len(temps_all) == len(temps_seq) == 2
    assert temps_all[0] == temps_seq[0]  # first exit sees all samples
    assert all(t > 1.0 for t in temps_all)  # overconfident -> soften
