"""End-to-end behaviour tests for the paper's system.

The full loop on CPU: train the early-exit convnet on synthetic CIFAR ->
side branch is overconfident -> Temperature Scaling fixes ECE -> the
calibrated offloading policy meets p_tar while the conventional one misses
it (the paper's central claim), exercised through the real OffloadEngine.

Plus a subprocess integration test of the multi-pod dry-run machinery.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ece, fit_temperature, make_policy
from repro.core.exits import gate_statistics
from repro.core.metrics import device_statistics, inference_outage_probability
from repro.data.synthetic import cifar_like
from repro.models import convnet
from repro.models.convnet import B_ALEXNET
from repro.offload.engine import convnet_engine
from repro.training import optim
from repro.training.loop import make_train_step


@pytest.fixture(scope="module")
def trained():
    data = cifar_like(n_train=6_000, n_val=1_500, n_test=3_072, seed=11)
    params = convnet.init_params(jax.random.PRNGKey(0))
    opt = optim.AdamWConfig(lr=2e-3, weight_decay=1e-4, total_steps=250, warmup_steps=30)
    step = jax.jit(make_train_step(B_ALEXNET, opt, remat=False))
    state = optim.init(params)
    rng = np.random.default_rng(0)
    for _ in range(5):
        order = rng.permutation(len(data.train_y))
        for s in range(0, len(order) - 128 + 1, 128):
            idx = order[s : s + 128]
            b = {
                "images": jnp.asarray(data.train_x[idx]),
                "labels": jnp.asarray(data.train_y[idx]),
            }
            params, state, m = step(params, state, b)

    @jax.jit
    def infer(x):
        return convnet.forward(params, x)

    def logits(x):
        outs = [infer(jnp.asarray(x[s : s + 512])) for s in range(0, len(x), 512)]
        return (
            np.concatenate([np.asarray(o["exit_logits"][0]) for o in outs]),
            np.concatenate([np.asarray(o["logits"]) for o in outs]),
        )

    vb1, vmain = logits(data.val_x)
    tb1, tmain = logits(data.test_x)
    return data, params, vb1, tb1, tmain


def test_training_learned_something(trained):
    data, params, vb1, tb1, tmain = trained
    _, pred, _ = gate_statistics(tmain, 1.0)
    acc_main = float(np.mean(np.asarray(pred) == data.test_y))
    _, pred1, _ = gate_statistics(tb1, 1.0)
    acc_b1 = float(np.mean(np.asarray(pred1) == data.test_y))
    assert acc_main > 0.5  # 10-class chance = 0.1
    assert acc_b1 > 0.4
    assert acc_main >= acc_b1 - 0.02  # deeper exit at least as good


def test_branch_overconfident_and_calibration_fixes_it(trained):
    data, params, vb1, tb1, tmain = trained
    conf, pred, _ = gate_statistics(tb1, 1.0)
    correct = np.asarray(pred) == data.test_y
    e_before = ece(np.asarray(conf), correct)
    overconf = float(np.asarray(conf).mean()) - float(correct.mean())
    assert overconf > 0.02  # conventionally trained net is overconfident

    T, _ = fit_temperature(jnp.asarray(vb1), jnp.asarray(data.val_y))
    assert float(T) > 1.0
    confT, _, _ = gate_statistics(tb1, float(T))
    e_after = ece(np.asarray(confT), correct)
    assert e_after < e_before


def test_calibrated_policy_meets_target_better(trained):
    """Paper Fig. 3(b)/4: device accuracy under calibration tracks p_tar."""
    data, params, vb1, tb1, tmain = trained
    T, _ = fit_temperature(jnp.asarray(vb1), jnp.asarray(data.val_y))
    p_tar = 0.85
    conv = device_statistics(tb1, data.test_y, p_tar, 1.0)
    cal = device_statistics(tb1, data.test_y, p_tar, float(T))
    # calibrated device accuracy must be closer to (or above) the target
    short_conv = p_tar - float(conv["device_accuracy"])
    short_cal = p_tar - float(cal["device_accuracy"])
    assert short_cal < short_conv + 1e-6
    o_conv = inference_outage_probability(tb1, data.test_y, p_tar, 1.0, batch_size=256)
    o_cal = inference_outage_probability(
        tb1, data.test_y, p_tar, float(T), batch_size=256
    )
    assert o_cal <= o_conv


def test_engine_end_to_end_accuracy_gain(trained):
    """Through the REAL partitioned engine: calibrated policy yields overall
    accuracy >= conventional at equal p_tar (paper Fig. 3c)."""
    data, params, vb1, tb1, tmain = trained
    accs = {}
    for calibrated in (False, True):
        policy = make_policy(
            [jnp.asarray(vb1)], jnp.asarray(data.val_y), p_tar=0.85,
            calibrated=calibrated,
        )
        engine = convnet_engine(params, policy, branch=1)
        correct = 0
        for s in range(0, len(data.test_y), 512):
            out = engine.infer({"images": jnp.asarray(data.test_x[s : s + 512])})
            correct += int((out["prediction"] == data.test_y[s : s + 512]).sum())
        accs[calibrated] = correct / len(data.test_y)
    # Fig. 3c's >= holds in expectation; at n=3072 the gate flip of a
    # handful of borderline samples is within sampling noise
    assert accs[True] >= accs[False] - 3.5 / len(data.test_y)


@pytest.mark.slow
def test_dryrun_subprocess_single_pair():
    """The multi-pod dry-run machinery lowers+compiles a real pair."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo_1b",
         "--shape", "long_500k", "--outdir", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
