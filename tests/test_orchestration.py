"""Orchestration plane tests: churn, QoS hysteresis, canary rollouts,
the adversarial scenario registry, and the no-op limits.

The anchor mirrors PR 4's single-cell limit: an orchestrated run with no
churn and no rollout must reproduce the plain fleet run BIT-EXACTLY
(summaries compared with ``==``), with and without the fleet controller.
The live telemetry views, the per-window hooks, the activation mask --
none of it may perturb service until an orchestration action actually
fires.
"""
import json
import math
import warnings

import numpy as np
import pytest

from repro.core.bank import PlanBank
from repro.fleet.scenarios import fleet_gate_table, reference_fleet, run_fleet
from repro.fleet.topology import (
    CellConfig,
    DiurnalEnvelope,
    FleetTopology,
    poisson_cell_workload,
)
from repro.orchestration import (
    JOIN,
    LEAVE,
    CellSLO,
    ChurnEvent,
    ChurnSchedule,
    Orchestrator,
    QoSConfig,
    QoSMonitor,
    RolloutManager,
    SCENARIOS,
    poisoned_bank,
    register_scenario,
    run_scenarios,
)
from repro.orchestration.rollout import CANARY, IDLE, PROMOTED, ROLLED_BACK
from repro.serving.drift import MarkovContextSchedule
from repro.serving.network import FixedRateNetwork
from repro.serving.scenarios import fit_drift_plans, synthetic_distorted_cascade


@pytest.fixture(scope="module")
def drift_data():
    val, test = synthetic_distorted_cascade(
        directions={"gaussian_blur": "under"}
    )
    return val, test, fit_drift_plans(val)


def small_fleet(drift_data, seed=0, n_cells=6, requests_per_cell=200):
    val, test, _ = drift_data
    return reference_fleet(
        n_cells=n_cells, requests_per_cell=requests_per_cell, seed=seed,
        val=val, test=test, cloud_servers=2,
    )


# ------------------------------------------------------------ churn engine
def test_churn_event_validation():
    with pytest.raises(ValueError, match="kind"):
        ChurnEvent(1.0, 0, "reboot")
    with pytest.raises(ValueError, match="t_s"):
        ChurnEvent(-1.0, 0, JOIN)
    with pytest.raises(ValueError, match="cell"):
        ChurnEvent(1.0, -1, LEAVE)


def test_churn_schedule_sorted_and_cursor():
    sched = ChurnSchedule([
        ChurnEvent(5.0, 1, LEAVE),
        ChurnEvent(1.0, 0, LEAVE),
        ChurnEvent(3.0, 0, JOIN),
        # same-instant bounce on cell 2: join sorts BEFORE leave, so the
        # net effect of applying both in order is down
        ChurnEvent(2.0, 2, LEAVE),
        ChurnEvent(2.0, 2, JOIN),
    ])
    times = [e.t_s for e in sched.events]
    assert times == sorted(times)
    bounce = [e.kind for e in sched.events if e.t_s == 2.0]
    assert bounce == [JOIN, LEAVE]

    due, cur = sched.due(0, 2.0)
    assert [e.t_s for e in due] == [1.0, 2.0, 2.0]
    # the caller owns the cursor: re-querying from 0 replays the events
    again, _ = sched.due(0, 2.0)
    assert again == due
    due2, cur = sched.due(cur, 10.0)
    assert [e.t_s for e in due2] == [3.0, 5.0]
    assert cur == len(sched)


def test_churn_outage_and_random_deterministic():
    out = ChurnSchedule.outage([0, 2], start_s=4.0, duration_s=3.0)
    assert len(out) == 4
    assert {(e.cell, e.kind) for e in out.events if e.t_s == 4.0} == {
        (0, LEAVE), (2, LEAVE)
    }
    assert {(e.cell, e.kind) for e in out.events if e.t_s == 7.0} == {
        (0, JOIN), (2, JOIN)
    }
    with pytest.raises(ValueError, match="duration"):
        ChurnSchedule.outage([0], 1.0, 0.0)

    a = ChurnSchedule.random(16, 200.0, seed=3)
    b = ChurnSchedule.random(16, 200.0, seed=3)
    assert a.events == b.events
    assert len(a) > 0
    assert all(e.t_s < 200.0 for e in a.events)
    c = ChurnSchedule.random(16, 200.0, seed=4)
    assert c.events != a.events


def test_shed_order_ring_geometry():
    wl = poisson_cell_workload(10.0, 20, 64)
    topo = FleetTopology([
        CellConfig(network=FixedRateNetwork(1e7), workload=wl)
        for _ in range(6)
    ])
    # nearest ring neighbors first, ties broken toward the lower index
    assert list(topo.shed_order(0)) == [1, 5, 2, 4, 3]
    assert list(topo.shed_order(3)) == [2, 4, 1, 5, 0]
    assert 2 not in topo.shed_order(2)


# -------------------------------------------------------------- QoS monitor
def test_slo_validation():
    with pytest.raises(ValueError, match="at least one"):
        CellSLO()
    with pytest.raises(ValueError, match="min_requests"):
        CellSLO(p99_ms=100.0, min_requests=0)
    with pytest.raises(ValueError, match="trip_after"):
        QoSConfig(trip_after=0)


def _qos(requests=100, gate_samples=100, p99=10.0, miss=0.0, gap=0.0,
         short=0.0):
    return {
        "requests": requests, "gate_samples": gate_samples, "p99_ms": p99,
        "deadline_miss_rate": miss, "reliability_gap": gap,
        "reliability_shortfall": short,
    }


def test_violation_evidence_gating():
    mon = QoSMonitor(CellSLO(p99_ms=50.0, reliability_shortfall=0.1,
                             min_requests=20, min_gate_samples=30))
    assert mon.violation(_qos()) == ""
    assert mon.violation(_qos(p99=80.0)) == "p99_ms"
    assert mon.violation(_qos(short=0.2)) == "reliability_shortfall"
    # thin completions: the latency verdict abstains, reliability still judged
    assert mon.violation(_qos(requests=5, p99=500.0)) == ""
    assert mon.violation(_qos(requests=5, p99=500.0, short=0.2)) == (
        "reliability_shortfall"
    )
    # thin gate stream: reliability abstains, latency still judged
    assert mon.violation(_qos(gate_samples=10, short=0.9)) == ""
    # no evidence anywhere -> no verdict at all
    assert mon.violation(_qos(requests=5, gate_samples=10, p99=500.0,
                              short=0.9)) is None
    # NaN (telemetry's no-evidence spelling) never violates
    assert mon.violation(_qos(p99=float("nan"))) == ""
    # over-delivery: gap trips on |acc - p_tar|, shortfall does not
    gapped = QoSMonitor(CellSLO(reliability_gap=0.1))
    assert gapped.violation(_qos(gap=0.2, short=0.0)) == "reliability_gap"
    shortfall = QoSMonitor(CellSLO(reliability_shortfall=0.1))
    assert shortfall.violation(_qos(gap=0.2, short=0.0)) == ""


class _ScriptedTel:
    """cell_qos_estimate scripted per cell as a list of window dicts."""

    def __init__(self, script):
        self.script = script
        self.calls = {c: 0 for c in script}

    def cell_qos_estimate(self, cell, window_s, now):
        i = min(self.calls[cell], len(self.script[cell]) - 1)
        self.calls[cell] += 1
        return self.script[cell][i]


def test_qos_hysteresis_trip_and_clear():
    bad, good = _qos(p99=200.0), _qos()
    none = _qos(requests=0, gate_samples=0)
    tel = _ScriptedTel({0: [bad, bad, none, bad, good, good, good, good]})
    mon = QoSMonitor(CellSLO(p99_ms=50.0),
                     QoSConfig(trip_after=3, clear_after=2))
    mon.reset(1)
    events = []
    for t in range(8):
        events.append(mon.observe(tel, float(t)))
        if t == 2:
            # the no-verdict window froze the two-bad streak, no trip yet
            assert mon._bad[0] == 2 and not mon.is_tripped(0)
    # two bad windows: not yet
    assert not events[0]["tripped"] and not events[1]["tripped"]
    assert not events[2]["tripped"]
    # third bad window trips, naming the metric
    assert events[3]["tripped"] == [(0, "p99_ms")]
    assert list(mon.tripped_cells()) == []  # cleared again by the end
    # one clean window is not enough; the second clears
    assert not events[4]["cleared"]
    assert events[5]["cleared"] == [0]
    assert not mon.is_tripped(0)
    assert mon.trip_log == [(3.0, 0, "p99_ms")]
    assert mon.clear_log == [(5.0, 0)]


def test_qos_evidence_floor_alternation_freezes_not_resets():
    """Satellite (ISSUE 7): a window under the evidence floor must neither
    ADVANCE nor RESET the trip streak. Alternating bad / no-evidence
    windows therefore still trips on the second bad window (the streak
    survives the gap) -- but lowering the floor so the same thin window
    is judged, with a CLEAN value, resets the streak and no trip ever
    fires. The floor is load-bearing in both directions."""
    bad = _qos(p99=200.0)
    thin_clean = _qos(requests=5, p99=10.0)  # clean value, under the floor
    script = [bad, thin_clean, bad]

    # floor at 20: the thin window is a no-verdict -> freeze -> trip at t=2
    mon = QoSMonitor(CellSLO(p99_ms=50.0, min_requests=20),
                     QoSConfig(trip_after=2, clear_after=2))
    mon.reset(1)
    tel = _ScriptedTel({0: script})
    verdicts = [mon.observe(tel, float(t))["tripped"] for t in range(3)]
    assert verdicts == [[], [], [(0, "p99_ms")]]
    assert mon._bad[0] == 2

    # floor at 1: the same window is JUDGED clean -> streak resets -> the
    # alternation can run forever without tripping
    mon2 = QoSMonitor(CellSLO(p99_ms=50.0, min_requests=1),
                      QoSConfig(trip_after=2, clear_after=2))
    mon2.reset(1)
    tel2 = _ScriptedTel({0: [bad, thin_clean] * 6})
    for t in range(12):
        assert not mon2.observe(tel2, float(t))["tripped"]
    assert mon2._bad[0] <= 1


def test_qos_alternating_evidence_freezes_clear_streak():
    """The mirror image: a TRIPPED cell cannot clear through no-evidence
    windows -- silence is not health. Good windows interleaved with thin
    ones take strictly longer (in windows) to clear than consecutive
    ones, because each thin window freezes the good streak."""
    bad, good = _qos(p99=200.0), _qos()
    thin = _qos(requests=0, gate_samples=0)
    script = [bad, bad] + [good, thin] * 3
    tel = _ScriptedTel({0: script})
    mon = QoSMonitor(CellSLO(p99_ms=50.0),
                     QoSConfig(trip_after=2, clear_after=3))
    mon.reset(1)
    cleared_at = None
    for t in range(len(script)):
        out = mon.observe(tel, float(t))
        if out["cleared"]:
            cleared_at = t
    # trips at t=1; three GOOD windows land at t=2,4,6 -> clears at t=6,
    # not t=4 (the thin windows at 3 and 5 bought no progress)
    assert mon.trip_log == [(1.0, 0, "p99_ms")]
    assert cleared_at == 6


def test_qos_per_metric_evidence_floors_with_hysteresis():
    """Gate-metric floors and completion floors gate INDEPENDENT verdicts:
    a window thin on completions but rich in gate samples still advances a
    reliability-trip streak, and vice versa."""
    # plenty of gate evidence, almost no completions: reliability judged
    gate_rich = _qos(requests=2, gate_samples=100, p99=999.0, short=0.5)
    tel = _ScriptedTel({0: [gate_rich, gate_rich]})
    mon = QoSMonitor(
        CellSLO(p99_ms=50.0, reliability_shortfall=0.1,
                min_requests=20, min_gate_samples=30),
        QoSConfig(trip_after=2, clear_after=2),
    )
    mon.reset(1)
    assert mon.observe(tel, 0.0)["tripped"] == []
    assert mon.observe(tel, 1.0)["tripped"] == [(0, "reliability_shortfall")]
    # the p99 number was far over cap both windows but never judged
    assert mon.trip_log[0][2] == "reliability_shortfall"


def test_qos_trip_evidence_payload():
    """The observe() evidence dict carries what the audit log needs: the
    windowed value, the cap it crossed, and the streak that tripped."""
    bad = _qos(p99=200.0)
    tel = _ScriptedTel({0: [bad, bad]})
    mon = QoSMonitor(CellSLO(p99_ms=50.0), QoSConfig(trip_after=2))
    mon.reset(1)
    mon.observe(tel, 0.0)
    out = mon.observe(tel, 1.0)
    ev = out["evidence"][0]
    assert ev["metric"] == "p99_ms" and ev["value"] == 200.0
    assert ev["cap"] == 50.0 and ev["bad_streak"] == 2
    assert ev["requests"] == 100
    # tripped_mask is the distress signal the fleet controller consumes
    mask = mon.tripped_mask()
    assert mask.dtype == bool and mask[0]
    mask[0] = False
    assert mon.is_tripped(0)  # a copy: callers cannot reach in


def test_qos_watched_subset():
    bad = _qos(p99=200.0)
    tel = _ScriptedTel({0: [bad], 1: [bad]})
    mon = QoSMonitor(CellSLO(p99_ms=50.0), QoSConfig(trip_after=1),
                     cells=[1])
    mon.reset(2)
    out = mon.observe(tel, 0.0)
    assert out["tripped"] == [(1, "p99_ms")]
    assert tel.calls[0] == 0  # unwatched cell never queried


# ---------------------------------------------------------- rollout manager
class _FakeSim:
    def __init__(self, n_cells):
        class T:
            pass

        self.topology = T()
        self.topology.n_cells = n_cells
        self.tables = {}

    def set_cell_table(self, c, table):
        self.tables[c] = table


class _FakeTel:
    def __init__(self):
        self.events = []

    def record_orchestration(self, t, kind, **payload):
        self.events.append((t, kind, payload))


class _FakeMonitor:
    def __init__(self):
        self.bad = set()

    def is_tripped(self, c):
        return c in self.bad


def _mini_bank(drift_data):
    _, _, (_, _, bank) = drift_data
    return bank


def test_rollout_requires_monotonic_version(drift_data):
    bank = _mini_bank(drift_data)
    assert bank.bank_version == 0
    with pytest.raises(ValueError, match="monotonic"):
        RolloutManager(bank, lambda b: b, canary_cells=(0,))
    b1 = bank.bumped()
    assert b1.bank_version == 1
    assert b1.bumped(7).bank_version == 7
    with pytest.raises(ValueError, match="increase"):
        b1.bumped(1)
    with pytest.raises(ValueError, match="canary"):
        RolloutManager(b1, lambda b: b, canary_cells=())
    # versions compose: a rollout over generation 3 rejects generation 3
    with pytest.raises(ValueError, match="monotonic"):
        RolloutManager(b1.bumped(3), lambda b: b, canary_cells=(0,),
                       incumbent_version=3)


def test_rollout_promotes_after_clear_probation(drift_data):
    bank = _mini_bank(drift_data).bumped()
    sim, tel, mon = _FakeSim(4), _FakeTel(), _FakeMonitor()
    ro = RolloutManager(bank, lambda b: ("table", b.bank_version),
                        canary_cells=(0, 2), promote_after=3, start_at_s=2.0)
    ro.step(sim, tel, mon, 1.0)
    assert ro.state == IDLE and not sim.tables
    ro.step(sim, tel, mon, 2.0)
    assert ro.state == CANARY and ro.started_at == 2.0
    assert sim.tables == {0: ("table", 1), 2: ("table", 1)}
    ro.step(sim, tel, mon, 3.0)
    ro.step(sim, tel, mon, 4.0)
    assert ro.state == CANARY
    ro.step(sim, tel, mon, 5.0)
    assert ro.state == PROMOTED and ro.promoted_at == 5.0
    assert set(sim.tables) == {0, 1, 2, 3}  # fleet-wide install
    kinds = [k for _, k, _ in tel.events]
    assert kinds == ["rollout_canary", "rollout_promote"]


def test_rollout_rolls_back_on_canary_trip(drift_data):
    bank = _mini_bank(drift_data).bumped()
    sim, tel, mon = _FakeSim(4), _FakeTel(), _FakeMonitor()
    ro = RolloutManager(bank, lambda b: "cand", canary_cells=(0, 2),
                        promote_after=10, start_at_s=0.0)
    ro.step(sim, tel, mon, 0.0)
    assert ro.state == CANARY
    mon.bad = {2}
    ro.step(sim, tel, mon, 1.0)
    assert ro.state == ROLLED_BACK and ro.rolled_back_at == 1.0
    assert ro.tripped_canaries == [2]
    assert sim.tables == {0: None, 2: None}  # overrides removed, nothing else
    # terminal: later clean windows change nothing
    mon.bad = set()
    ro.step(sim, tel, mon, 2.0)
    assert ro.state == ROLLED_BACK
    assert [k for _, k, _ in tel.events] == ["rollout_canary",
                                             "rollout_rollback"]


def test_orchestrator_validation(drift_data):
    bank = _mini_bank(drift_data).bumped()
    ro = RolloutManager(bank, lambda b: b, canary_cells=(5,))
    with pytest.raises(ValueError, match="monitor"):
        Orchestrator(rollout=ro)


# ------------------------------------------------------------- no-op limits
def test_orchestrated_noop_is_bit_exact(drift_data):
    """THE churn-free limit: an attached orchestrator with nothing to do
    must not move a single bit of the fleet summary -- plain or with the
    controller in the loop."""
    _, _, (uncal, _, bank) = drift_data
    scn = small_fleet(drift_data)
    plain = run_fleet(bank, scn).fleet_summary()
    noop = run_fleet(bank, scn, orchestrator=Orchestrator()).fleet_summary()
    assert plain == noop

    ctrl = run_fleet(bank, scn, with_controller=True).fleet_summary()
    ctrl_noop = run_fleet(
        bank, scn, with_controller=True, orchestrator=Orchestrator()
    ).fleet_summary()
    assert ctrl == ctrl_noop


def test_orchestrated_run_is_deterministic(drift_data):
    _, _, (_, _, bank) = drift_data
    scn = small_fleet(drift_data)
    churn = ChurnSchedule.outage([0, 3], start_s=3.0, duration_s=4.0)

    def go():
        return run_fleet(
            bank, scn, with_controller=True,
            orchestrator=Orchestrator(churn=churn),
        )

    a, b = go().fleet_summary(), go().fleet_summary()
    assert a == b


# ------------------------------------------------------- churn through sim
def test_outage_sheds_conserves_and_records(drift_data):
    _, _, (_, _, bank) = drift_data
    scn = small_fleet(drift_data)
    churn = ChurnSchedule.outage([0, 3], start_s=3.0, duration_s=4.0)
    orch = Orchestrator(churn=churn)
    tel = run_fleet(bank, scn, orchestrator=orch)

    # every request of the down cells is still served and attributed home
    assert tel.requests() == scn.topology.n_requests
    for c in range(scn.topology.n_cells):
        assert len(tel._cells[c].column("latency_s")) == len(
            scn.topology.cells[c].workload
        )

    kinds = [k for _, k, _ in tel.orchestration_events]
    assert kinds.count("churn_leave") == 2
    assert kinds.count("churn_join") == 2
    finish = [e for e in tel.orchestration_events if e[1] == "finish"][0]
    assert finish[2]["shed_requests"] > 0
    assert finish[2]["active_cells"] == scn.topology.n_cells  # all recovered

    # shedding hurt the down cells' latency but no request went missing
    plain = run_fleet(bank, scn)
    assert tel.fleet_summary()["p99_ms"] >= plain.fleet_summary()["p99_ms"]


def test_whole_fleet_down_backhauls_to_cloud(drift_data):
    """No live neighbor anywhere: every arrival in the outage window rides
    the backhaul to the cloud, and the books still balance."""
    _, _, (_, _, bank) = drift_data
    scn = small_fleet(drift_data, n_cells=2, requests_per_cell=120)
    churn = ChurnSchedule.outage([0, 1], start_s=1.0, duration_s=2.0)
    tel = run_fleet(bank, scn, orchestrator=Orchestrator(churn=churn))
    assert tel.requests() == scn.topology.n_requests
    s = tel.fleet_summary()
    assert s["offload_rate"] > run_fleet(bank, scn).fleet_summary()[
        "offload_rate"
    ]


def test_churn_event_out_of_range_rejected(drift_data):
    _, _, (_, _, bank) = drift_data
    scn = small_fleet(drift_data, n_cells=2, requests_per_cell=50)
    churn = ChurnSchedule([ChurnEvent(1.0, 9, LEAVE)])
    with pytest.raises(ValueError, match="cell 9"):
        run_fleet(bank, scn, orchestrator=Orchestrator(churn=churn))


# ------------------------------------------------- canary, both directions
def test_canary_rollback_and_promotion_e2e(drift_data):
    """The acceptance pincer at test scale: the poisoned candidate trips
    its canaries and rolls back before the fleet gap exceeds 1.5x the
    incumbent's; the good candidate promotes and the promoted run equals
    the incumbent run to round-off."""
    _, _, (_, _, bank) = drift_data
    scn = small_fleet(drift_data, n_cells=8, requests_per_cell=300)

    def pieces(candidate):
        monitor = QoSMonitor(
            CellSLO(reliability_shortfall=0.12, min_requests=12,
                    min_gate_samples=25),
            QoSConfig(window_s=3.0, trip_after=2, clear_after=4),
        )
        rollout = RolloutManager(
            candidate, table_factory=lambda b: fleet_gate_table(b, scn),
            canary_cells=(0, 1), promote_after=8, start_at_s=4.0,
        )
        return Orchestrator(monitor=monitor, rollout=rollout), rollout

    incumbent = run_fleet(bank, scn).fleet_summary()

    bad = poisoned_bank(bank)
    assert bad.bank_version == 1
    assert bad.metadata["poisoned"]
    orch, ro = pieces(bad)
    guarded = run_fleet(bank, scn, orchestrator=orch).fleet_summary()
    assert ro.state == ROLLED_BACK
    assert ro.tripped_canaries and set(ro.tripped_canaries) <= {0, 1}
    assert guarded["miscalibration_gap"] <= 1.5 * incumbent[
        "miscalibration_gap"
    ]
    # and the guard genuinely mattered: unguarded promotion is a disaster
    unguarded = run_fleet(bad, scn).fleet_summary()
    assert unguarded["miscalibration_gap"] > 1.5 * incumbent[
        "miscalibration_gap"
    ]

    orch2, ro2 = pieces(bank.bumped())
    promoted = run_fleet(bank, scn, orchestrator=orch2).fleet_summary()
    assert ro2.state == PROMOTED
    for k in ("p99_ms", "miscalibration_gap", "accuracy", "offload_rate"):
        a, b = incumbent[k], promoted[k]
        assert (math.isnan(a) and math.isnan(b)) or a == pytest.approx(
            b, rel=1e-9, abs=1e-12
        ), k


def test_set_cell_table_validates_compatibility(drift_data):
    _, _, (_, _, bank) = drift_data
    scn = small_fleet(drift_data, n_cells=2, requests_per_cell=50)
    table = fleet_gate_table(bank, scn)
    from repro.fleet.simulator import FleetConfig, FleetSimulator
    from repro.offload import latency as L

    sim = FleetSimulator(table, scn.topology, L.paper_2020(),
                         config=FleetConfig(window_s=0.5))
    with pytest.raises(IndexError):
        sim.set_cell_table(5, table)
    # a table over different data (here: truncated samples) is rejected
    val, test, _ = drift_data
    trunc = {
        "exit_logits": {
            c: {b: z[:10] for b, z in d.items()}
            for c, d in test["exit_logits"].items()
        },
        "final": {c: f[:10] for c, f in test["final"].items()},
        "labels": test["labels"][:10],
        "features": {c: f[:10] for c, f in test["features"].items()},
    }
    other = reference_fleet(n_cells=2, requests_per_cell=50, seed=0,
                            val=val, test=trunc)
    with pytest.raises(ValueError, match="incumbent"):
        sim.set_cell_table(0, fleet_gate_table(bank, other))


# ------------------------------------------------------- scenario registry
def test_registry_contents_and_unknown_name():
    assert {"weather_front", "flash_crowd", "link_outage", "cloud_brownout",
            "poisoned_canary", "good_rollout"} <= set(SCENARIOS)
    with pytest.raises(ValueError, match="unknown scenario"):
        run_scenarios(["nope"])


def test_register_scenario_is_open():
    @register_scenario("_tmp_probe")
    def probe(quick=False, seed=0):
        return {"name": "_tmp_probe", "arms": {}, "wins": {},
                "events": {"quick": quick, "seed": seed}, "pass": True}

    try:
        (rec,) = run_scenarios(["_tmp_probe"], quick=True, seed=3)
        assert rec["events"] == {"quick": True, "seed": 3}
        assert rec["pass"] is True
    finally:
        del SCENARIOS["_tmp_probe"]


def test_link_outage_scenario_quick_record():
    (rec,) = run_scenarios(["link_outage"], quick=True)
    assert rec["name"] == "link_outage"
    assert set(rec["arms"]) == {"bank_static", "bank_controller"}
    assert rec["events"]["requests_conserved"]
    assert rec["events"]["shed_requests"] > 0
    assert "p99_ms" in rec["wins"]
    assert json.dumps(rec)  # the record is a pure-JSON artifact


@pytest.mark.slow
def test_scenario_matrix_full_scale_all_pass():
    """The CI gate, run directly: every registered adversarial scenario
    passes its required wins at bench scale."""
    records = run_scenarios()
    failed = [r["name"] for r in records if not r["pass"]]
    assert not failed, failed


# ------------------------------------------------------- gate shim, drifts
def test_fleet_gate_shim_deprecated_but_identical():
    import repro.fleet.gate as shim
    from repro.core.gatepath import GateTable, get_gate_backend

    with pytest.warns(DeprecationWarning, match="repro.core.gatepath"):
        assert shim.FleetGateTable is GateTable
    with pytest.warns(DeprecationWarning):
        assert shim.get_gate_backend is get_gate_backend
    with pytest.raises(AttributeError):
        shim.definitely_not_here
    assert "FleetGateTable" in dir(shim)

    # the package-level alias stays warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        from repro.fleet import FleetGateTable

        assert FleetGateTable is GateTable


def test_single_state_markov_schedule():
    sch = MarkovContextSchedule(["clean"], dwell_s=0.5, seed=0)
    t = np.linspace(0.0, 20.0, 101)
    assert np.all(sch.context_ids_at(t) == 0)
    assert sch.context_at(13.7) == "clean"


def test_diurnal_amplitude_one_trough_hits_zero():
    env = DiurnalEnvelope(period_s=10.0, amplitude=1.0)
    t = np.linspace(0.0, 10.0, 1001)
    f = env.rate_factor(t)
    assert float(f.min()) == pytest.approx(0.0, abs=1e-9)
    wl = poisson_cell_workload(20.0, 500, 64, seed=2, envelope=env)
    assert len(wl) == 500
    assert np.all(np.diff(wl.arrival_s) >= 0)
    # nothing arrives at the dead trough: factor at every arrival is > 0
    assert float(env.rate_factor(wl.arrival_s).min()) > 0.0


def test_empty_arrival_windows_through_orchestrated_path(drift_data):
    """One cell's stream ends long before the other's: its later windows
    are empty, the QoS monitor gets no-verdict windows (frozen streaks,
    no spurious trips), and the orchestrated run still balances."""
    _, _, (_, _, bank) = drift_data
    scn = small_fleet(drift_data, n_cells=2, requests_per_cell=200)
    short = poisson_cell_workload(200.0, 40, 512, seed=9)
    cells = list(scn.topology.cells)
    cells[0] = CellConfig(
        network=cells[0].network, workload=short,
        n_devices=cells[0].n_devices, schedule=cells[0].schedule,
        deadline_s=cells[0].deadline_s,
    )
    scn.topology = FleetTopology(cells, cloud_servers=2)
    monitor = QoSMonitor(CellSLO(p99_ms=1e4, min_requests=5),
                         QoSConfig(window_s=1.0, trip_after=1))
    tel = run_fleet(bank, scn, orchestrator=Orchestrator(monitor=monitor))
    assert tel.requests() == 40 + 200
    assert not monitor.trip_log  # idle windows never tripped anything


# ------------------------------------------------------- bank round-trips
def test_bank_json_roundtrip_with_versions(drift_data):
    _, _, (_, _, bank) = drift_data
    b3 = bank.bumped(3)
    d = b3.to_dict()
    assert d["schema_version"] == 1
    assert d["version"] == 1  # legacy spelling still written
    assert d["bank_version"] == 3
    back = PlanBank.from_json(b3.to_json())
    assert back.bank_version == 3
    assert back.to_json() == b3.to_json()  # bit-identical round trip

    # a pre-orchestration file (no schema_version / bank_version) migrates
    legacy = bank.to_dict()
    del legacy["schema_version"]
    del legacy["bank_version"]
    old = PlanBank.from_dict(legacy)
    assert old.bank_version == 0
    assert old.contexts == bank.contexts
    z = np.random.default_rng(0).normal(size=(16, 10))
    for ctx in bank.contexts:
        a, _ = bank.plan_for(ctx).gate_block(z, branch=0)
        b, _ = old.plan_for(ctx).gate_block(z, branch=0)
        np.testing.assert_array_equal(a, b)

    with pytest.raises(ValueError, match="newer"):
        PlanBank.from_dict({**bank.to_dict(), "schema_version": 99})


def test_poisoned_bank_validation(drift_data):
    _, _, (_, _, bank) = drift_data
    with pytest.raises(ValueError, match="temp_scale"):
        poisoned_bank(bank, temp_scale=0.0)
    bad = poisoned_bank(bank)
    for ctx in bank.contexts:
        good_t = bank.plan_for(ctx).temperatures
        bad_t = bad.plan_for(ctx).temperatures
        assert all(b == pytest.approx(0.05 * g) for g, b in zip(good_t, bad_t))
