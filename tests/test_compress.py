"""Pallas bottleneck codec (ISSUE 10 tentpole): kernel-vs-oracle parity,
absmax edge cases, analytic wire pricing, and the level-0 identity
contract across the serving / fleet / compiled stacks.

The codec's wire format is pinned by the numpy oracle in
`repro.kernels.ref`; the Pallas encode/decode pair must reproduce it
BIT-exactly (words, scales, and decoded floats), because the control
plane's fit-time accuracy-delta tables are computed through the oracle
while the hot path ships payloads through the kernel. Level 0 is the
identity, and a level-0 deployment must be indistinguishable -- float
for float -- from the pre-codec stacks.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibration import TemperatureScaling
from repro.core.policy import OffloadPlan
from repro.kernels import compress
from repro.kernels.ref import (
    CODEC_BITS,
    CODEC_TILE,
    decode_codec_ref,
    encode_codec_ref,
    roundtrip_codec_ref,
)


@pytest.fixture(autouse=True, scope="module")
def _release_codec_executables():
    """The interpret-mode encode/decode kernels compile one executable
    per (shape, level) this module sweeps; drop them at teardown so the
    suite-wide XLA executable footprint stays at its pre-codec level
    (the CPU backend has segfaulted compiling later LM smoke archs with
    the extra residents held alive)."""
    yield
    import jax

    jax.clear_caches()


def _rand(shape, seed=0, scale=3.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ------------------------------------------------------ kernel vs oracle
@pytest.mark.parametrize("level", [1, 2])
@pytest.mark.parametrize("shape", [
    (4, 256, 13, 13),   # branch-1 style conv payload
    (8, 1536),          # aligned 2D
    (3, 700),           # ragged rows and cols (pad both axes)
    (130,),             # 1D payload -> single row
])
def test_encode_matches_oracle_bitexact(level, shape):
    x = _rand(shape, seed=level * 101 + len(shape))
    enc = compress.encode(x, level)
    words, scales = encode_codec_ref(x, level)
    np.testing.assert_array_equal(np.asarray(enc.words), words)
    np.testing.assert_array_equal(np.asarray(enc.scales), scales)
    out = np.asarray(compress.decode(enc))
    ref = decode_codec_ref(words, scales, x.shape, level)
    assert out.dtype == np.float32 and out.shape == x.shape
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("level", [1, 2])
def test_roundtrip_error_bounded_by_quantization_step(level):
    x = _rand((16, 640), seed=7)
    out = np.asarray(compress.roundtrip(x, level))
    qmax = (1 << (CODEC_BITS[level] - 1)) - 1
    step = np.abs(x.reshape(16, -1, CODEC_TILE)).max(axis=2) / qmax
    err = np.abs(out - x).reshape(16, -1, CODEC_TILE)
    assert (err <= step[:, :, None] * 0.5 + 1e-7).all()


def test_all_zero_tile_stores_zero_scale_and_decodes_zero():
    x = np.zeros((8, 512), np.float32)
    x[:, 256:] = _rand((8, 256), seed=3)  # half the tiles are live
    for level in (1, 2):
        enc = compress.encode(x, level)
        scales = np.asarray(enc.scales)
        assert (scales[:, :2] == 0.0).all() and (scales[:, 2:] > 0).all()
        out = np.asarray(compress.decode(enc))
        assert np.isfinite(out).all()
        assert (out[:, :256] == 0.0).all()


def test_nonfinite_inputs_are_zeroed_not_flushed():
    """One inf must not give its tile an inf scale (flushing every other
    value to zero on decode); nan must not poison the absmax."""
    x = _rand((8, 512), seed=11)
    x[0, 5] = np.inf
    x[3, 200] = -np.inf
    x[7, 300] = np.nan
    for level in (1, 2):
        enc = compress.encode(x, level)
        assert np.isfinite(np.asarray(enc.scales)).all()
        out = np.asarray(compress.decode(enc))
        assert np.isfinite(out).all()
        clean = np.where(np.isfinite(x), x, np.float32(0.0))
        np.testing.assert_array_equal(out, roundtrip_codec_ref(clean, level))


def test_level0_roundtrip_is_identity_no_cast():
    x = _rand((4, 320), seed=5)
    ref = roundtrip_codec_ref(x, 0)
    assert ref is x  # the input object itself: no cast, no copy
    np.testing.assert_array_equal(np.asarray(compress.roundtrip(x, 0)), x)
    with pytest.raises(ValueError):
        compress.encode(x, 0)


# ------------------------------------------------------- analytic pricing
def test_analytic_nbytes_matches_wire_image():
    for shape in [(4, 256, 13, 13), (3, 700), (130,)]:
        x = _rand(shape, seed=1)
        for level in (1, 2):
            enc = compress.encode(x, level)
            bits = CODEC_BITS[level]
            packed = np.asarray(enc.words).shape[0] * np.asarray(
                enc.words).shape[1] * 4
            scale_bytes = np.asarray(enc.scales).size * 4
            # padded buffers equal the analytic padded size; the analytic
            # UNPADDED size never exceeds them
            assert enc.nbytes <= packed + scale_bytes
            rows = np.asarray(enc.scales).shape[0]
            cols = int(np.prod(shape[1:])) if len(shape) > 1 else shape[0]
            groups = -(-cols // CODEC_TILE)
            want = rows * ((cols * bits + 7) // 8 + 4 * groups)
            assert enc.nbytes == want


def test_branch_payload_byte_table():
    """The paper's two branch payloads at each level -- level 2 clears the
    4x floor the congested-uplink CI assertion relies on."""
    assert [compress.scaled_payload_nbytes(65536, l) for l in (0, 1, 2)] \
        == [65536, 16896, 8704]
    assert [compress.scaled_payload_nbytes(24576, l) for l in (0, 1, 2)] \
        == [24576, 6336, 3264]
    assert 65536 / 8704 > 4.0 and 24576 / 3264 > 4.0


# ---------------------------------------------- control-plane integration
def _plan(p_tar=0.8):
    return OffloadPlan(
        p_tar=p_tar,
        calibrators=[TemperatureScaling.from_temperature(1.0),
                     TemperatureScaling.from_temperature(1.0)],
    )


@pytest.fixture(scope="module")
def cascade():
    from repro.serving.scenarios import synthetic_cascade_logits

    return synthetic_cascade_logits(512)


def test_rescore_level0_only_reproduces_legacy_table(cascade):
    from repro.core.control import rescore_plan
    from repro.offload import latency as L

    exits, final, y = cascade
    plan = _plan()
    profile = L.paper_2020()
    args = ([exits[1], exits[2]],
            [L.edge_time(profile, b) for b in (1, 2)],
            [L.cloud_time(profile, b) for b in (1, 2)],
            [L.payload_bytes_for(b) for b in (1, 2)])
    kw = dict(final_logits=final, labels=y, uplink_bps=2e6,
              p_tar_grid=(0.5, 0.8), min_accuracy=0.5,
              arrival_rate_hz=50.0)
    legacy_plan, legacy = rescore_plan(plan, *args, **kw)
    lvl0_plan, lvl0 = rescore_plan(plan, *args,
                                   compression_levels=(0,), **kw)
    assert len(legacy) == len(lvl0)
    for a, b in zip(legacy, lvl0):
        assert b["compression_level"] == 0
        for k in a:
            assert a[k] == b[k] or (a[k] != a[k] and b[k] != b[k]), k
    assert lvl0_plan.compression_level == 0
    assert lvl0_plan.exit_index == legacy_plan.exit_index
    assert lvl0_plan.p_tar == legacy_plan.p_tar


def test_rescore_compression_axis_prices_bytes_and_accuracy(cascade):
    from repro.core.control import rescore_plan
    from repro.offload import latency as L

    exits, final, y = cascade
    plan = _plan()
    profile = L.paper_2020()
    _, table = rescore_plan(
        plan, [exits[1], exits[2]],
        [L.edge_time(profile, b) for b in (1, 2)],
        [L.cloud_time(profile, b) for b in (1, 2)],
        [L.payload_bytes_for(b) for b in (1, 2)],
        final_logits=final, labels=y,
        uplink_bps=1.5e6, arrival_rate_hz=40.0,
        p_tar_grid=(0.8,), compression_levels=(0, 1, 2),
    )
    assert len(table) == 2 * 1 * 3  # branch x p_tar x level
    by = {(r["exit_index"], r["compression_level"]): r for r in table}
    for i, raw in ((0, 65536), (1, 24576)):
        for lvl in (0, 1, 2):
            r = by[(i, lvl)]
            pb = compress.scaled_payload_nbytes(raw, lvl)
            assert r["uplink_nbytes"] == pytest.approx(
                pb * r["offload_prob"])
            if lvl > 0:
                # smaller payload: strictly better latency and utilization
                assert r["expected_latency_s"] < by[(i, 0)][
                    "expected_latency_s"]
                assert r["uplink_utilization"] < by[(i, 0)][
                    "uplink_utilization"]


def test_plan_compression_level_survives_serialization():
    plan = _plan().with_compression(2)
    assert plan.compression_level == 2
    back = OffloadPlan.from_dict(plan.to_dict())
    assert back.compression_level == 2
    # pre-codec plan dicts load at level 0
    d = plan.to_dict()
    d.pop("compression_level")
    assert OffloadPlan.from_dict(d).compression_level == 0


def test_serving_level0_controller_bitexact_with_legacy(cascade):
    """A bytes-aware controller restricted to level 0 must reproduce the
    bytes-blind controller's run float-for-float (the PR 8/9 parity
    rule, at serving scale)."""
    from repro.serving.controller import ControllerConfig
    from repro.serving.scenarios import run_congested_markov

    exits, final, y = cascade
    base = dict(interval_s=0.5, window_s=1.0, min_accuracy=0.9)
    a = run_congested_markov(_plan(), exits, final, y, n_requests=300,
                             with_controller=True,
                             controller_config=ControllerConfig(**base))
    b = run_congested_markov(_plan(), exits, final, y, n_requests=300,
                             with_controller=True,
                             controller_config=ControllerConfig(
                                 **base, compression_levels=(0,)))
    assert a.summary() == b.summary()


def test_serving_compressed_plan_ships_scaled_bytes(cascade):
    from repro.serving.scenarios import run_congested_markov

    exits, final, y = cascade
    a = run_congested_markov(_plan(), exits, final, y, n_requests=300)
    b = run_congested_markov(_plan().with_compression(2), exits, final, y,
                             n_requests=300)
    sa, sb = a.summary(), b.summary()
    assert sb["requests"] == sa["requests"] == 300
    # int4 payloads cross the congested link ~7.5x faster
    assert sb["p99_ms"] < sa["p99_ms"]
    assert sb["energy_j_total"] < sa["energy_j_total"]


def test_fleet_compiled_parity_at_level2(cascade):
    """Host and compiled fleet backends agree per-request on a COMPRESSED
    static deployment (scaled wire bytes, per-level cloud predictions,
    energy column)."""
    from repro.fleet.scenarios import reference_fleet, run_fleet
    from repro.serving.scenarios import (
        fit_drift_plans,
        synthetic_distorted_cascade,
    )

    val, test = synthetic_distorted_cascade(
        directions={"gaussian_blur": "under"})
    _, global_plan, _ = fit_drift_plans(val)
    plan = global_plan.with_compression(2)
    scn = reference_fleet(n_cells=4, requests_per_cell=120, seed=0,
                          val=val, test=test, cloud_servers=2)
    a = run_fleet(plan, scn)
    b = run_fleet(plan, scn, backend="compiled")
    sa, sb = a.fleet_summary(), b.fleet_summary()
    assert set(sa) == set(sb)
    for k in sa:
        np.testing.assert_allclose(sb[k], sa[k], rtol=1e-9, atol=1e-12)
    # and the compressed run genuinely differs from the raw one
    raw = run_fleet(global_plan, scn).fleet_summary()
    assert raw["energy_j_total"] > sa["energy_j_total"]


def test_engine_infer_compresses_actual_payload():
    """OffloadEngine runs the REAL kernel codec on the shipped activation
    when the plan carries a level: stats charge the encoded wire bytes
    and the cloud partition sees the dequantized floats."""
    seen = {}

    def edge(batch):
        n = batch["x"].shape[0]
        logits = jnp.stack([jnp.zeros(n), jnp.linspace(-2, 2, n)], axis=1)
        return {"exit_logits": logits, "payload": jnp.asarray(batch["x"])}

    def cloud(payload):
        seen["payload"] = np.asarray(payload)
        return {"logits": jnp.zeros((payload.shape[0], 2))}

    from repro.offload.engine import OffloadEngine

    x = _rand((32, 256), seed=9)
    plan = OffloadPlan(
        p_tar=0.9, calibrators=[TemperatureScaling.from_temperature(1.0)],
    ).with_compression(1)
    eng = OffloadEngine(edge, cloud, plan)
    res = eng.infer({"x": x})
    m = eng.stats.offloaded
    assert m > 0
    # charged bytes = analytic encoded size of the offloaded subset
    assert eng.stats.payload_bytes == compress.compressed_nbytes(256, 1) * m
    # the cloud saw the dequantized payload (the oracle roundtrip of the
    # refused rows), not the raw floats
    refused = x[~np.asarray(res["on_device"])]
    np.testing.assert_array_equal(seen["payload"],
                                  roundtrip_codec_ref(refused, 1))
    assert not np.array_equal(seen["payload"], refused)


def test_rescore_branch_pin_isolates_codec_axis(cascade):
    """branches=(k,) restricts the table to one split, so with
    p_tar_grid=None the codec level is the ONLY candidate axis -- the
    controlled comparison the BENCH compression sweep asserts on."""
    import pytest

    from repro.core.control import rescore_plan
    from repro.offload import latency as L

    exits, final, y = cascade
    plan = _plan()
    profile = L.paper_2020()
    args = ([exits[1], exits[2]],
            [L.edge_time(profile, b) for b in (1, 2)],
            [L.cloud_time(profile, b) for b in (1, 2)],
            [L.payload_bytes_for(b) for b in (1, 2)])
    kw = dict(final_logits=final, labels=y, uplink_bps=1.5e6,
              arrival_rate_hz=50.0)
    _, table = rescore_plan(plan, *args, branches=(1,),
                            compression_levels=(0, 1, 2), **kw)
    assert len(table) == 3  # one branch x plan's p_tar x three levels
    assert {r["exit_index"] for r in table} == {0}
    assert {r["compression_level"] for r in table} == {0, 1, 2}
    # pinning changes WHICH rows exist, not how a row is priced
    _, free = rescore_plan(plan, *args,
                           compression_levels=(0, 1, 2), **kw)
    by_lvl = {r["compression_level"]: r for r in free if r["exit_index"] == 0}
    for r in table:
        assert r == by_lvl[r["compression_level"]]
    with pytest.raises(ValueError):
        rescore_plan(plan, *args, branches=(3,), **kw)
