"""Compiled fleet pipeline parity tests (ISSUE 8 tentpole).

`repro.fleet.compiled.CompiledFleetSimulator` runs the whole window
pipeline -- gate -> per-device FIFO edge queues -> per-cell uplink ->
shared cloud tier -- as ONE jitted JAX program (max-plus
`associative_scan` recurrences, `shard_map` over the cell axis). The
host numpy `FleetSimulator` is the spec: these tests pin per-request
parity to float round-off on `reference_fleet`, identical churn
shed/backhaul accounting and orchestration events, and the declared
scope limits (static deployments only: no controller, no rollouts).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.offload import latency as L
from repro.orchestration import ChurnSchedule, Orchestrator
from repro.orchestration.qos import CellSLO, QoSConfig, QoSMonitor
from repro.serving.scenarios import fit_drift_plans, synthetic_distorted_cascade
from repro.fleet.scenarios import fleet_gate_table, reference_fleet, run_fleet

LAT_TOL = dict(rtol=1e-9, atol=1e-12)


@pytest.fixture(scope="module")
def drift_data():
    val, test = synthetic_distorted_cascade(
        directions={"gaussian_blur": "under"}
    )
    return val, test, fit_drift_plans(val)


@pytest.fixture(scope="module")
def scenario(drift_data):
    val, test, _ = drift_data
    return reference_fleet(n_cells=6, requests_per_cell=200, seed=0,
                           val=val, test=test, cloud_servers=2)


def assert_per_request_parity(a, b):
    """Every per-cell telemetry column matches: int/bool columns exactly,
    latencies to float round-off (tree-scan vs sequential rounding)."""
    assert a.n_cells == b.n_cells
    for c in range(a.n_cells):
        ca, cb = a._cells[c], b._cells[c]
        assert len(ca) == len(cb)
        for f in ca.FIELDS:
            va, vb = ca.column(f), cb.column(f)
            if f == "latency_s":
                np.testing.assert_allclose(vb, va, **LAT_TOL)
            else:
                np.testing.assert_array_equal(vb, va)


def assert_summary_parity(a, b):
    sa, sb = a.fleet_summary(), b.fleet_summary()
    assert set(sa) == set(sb)
    for k in sa:
        np.testing.assert_allclose(sb[k], sa[k], **LAT_TOL)


# ------------------------------------------------------------ plain parity
def test_compiled_per_request_parity(drift_data, scenario):
    val, test, (uncal, global_plan, bank) = drift_data
    a = run_fleet(bank, scenario)
    b = run_fleet(bank, scenario, backend="compiled")
    assert_per_request_parity(a, b)
    assert_summary_parity(a, b)


def test_compiled_parity_plain_plan(drift_data, scenario):
    """The non-bank path (single plan, static context) also matches."""
    val, test, (uncal, global_plan, bank) = drift_data
    a = run_fleet(global_plan, scenario)
    b = run_fleet(global_plan, scenario, backend="compiled")
    assert_per_request_parity(a, b)
    assert_summary_parity(a, b)


# ------------------------------------------------------------ churn parity
def test_compiled_churn_shed_parity(drift_data, scenario):
    """Outage with live neighbors: shed arrivals land on the same serving
    cells with identical latencies and orchestration events."""
    val, test, (uncal, global_plan, bank) = drift_data
    churn = ChurnSchedule.outage([0, 2], start_s=2.0, duration_s=2.0)
    a = run_fleet(bank, scenario, orchestrator=Orchestrator(churn=churn))
    b = run_fleet(bank, scenario, orchestrator=Orchestrator(churn=churn),
                  backend="compiled")
    assert a.orchestration_events == b.orchestration_events
    assert_per_request_parity(a, b)
    assert_summary_parity(a, b)


def test_compiled_backhaul_parity(drift_data, scenario):
    """Whole-fleet outage: every arrival rides the backhaul to the cloud
    on both backends, request conservation included."""
    val, test, (uncal, global_plan, bank) = drift_data
    cells = list(range(scenario.topology.n_cells))
    churn = ChurnSchedule.outage(cells, start_s=1.0, duration_s=2.0)
    a = run_fleet(bank, scenario, orchestrator=Orchestrator(churn=churn))
    b = run_fleet(bank, scenario, orchestrator=Orchestrator(churn=churn),
                  backend="compiled")
    assert a.orchestration_events == b.orchestration_events
    assert a.fleet_summary()["requests"] == b.fleet_summary()["requests"]
    assert_per_request_parity(a, b)
    assert_summary_parity(a, b)


def test_compiled_qos_monitor_parity(drift_data, scenario):
    """The compiled run drives the QoS monitor through the same live
    telemetry views: identical trip/clear events."""
    val, test, (uncal, global_plan, bank) = drift_data

    def orch():
        return Orchestrator(monitor=QoSMonitor(
            CellSLO(p99_ms=1e-3, min_requests=1),
            QoSConfig(window_s=2.0, trip_after=1, clear_after=1000),
        ))

    a = run_fleet(bank, scenario, orchestrator=orch())
    b = run_fleet(bank, scenario, orchestrator=orch(), backend="compiled")
    trips = [k for _, k, _ in a.orchestration_events]
    assert "qos_trip" in trips  # the SLO is designed to trip
    assert a.orchestration_events == b.orchestration_events
    assert_per_request_parity(a, b)


# ------------------------------------------------------------- scope limits
def test_compiled_rejects_controller(drift_data, scenario):
    val, test, (uncal, global_plan, bank) = drift_data
    with pytest.raises(ValueError, match="host backend"):
        run_fleet(bank, scenario, with_controller=True, backend="compiled")


def test_compiled_rejects_rollout(drift_data, scenario):
    from repro.orchestration import RolloutManager

    val, test, (uncal, global_plan, bank) = drift_data
    ro = RolloutManager(bank.bumped(), lambda b: b, canary_cells=(0,))
    with pytest.raises(ValueError, match="rollout"):
        run_fleet(bank, scenario, orchestrator=Orchestrator(rollout=ro),
                  backend="compiled")


# ------------------------------------------------------------ mesh sharding
def test_compiled_explicit_mesh_parity(drift_data, scenario):
    """Forcing the `shard_map` path on the 1-device CPU mesh must change
    nothing: the sharded program is the same program."""
    from repro.sharding import fleet_mesh
    from repro.fleet.compiled import CompiledFleetSimulator
    from repro.fleet.simulator import FleetConfig, FleetSimulator

    val, test, (uncal, global_plan, bank) = drift_data
    table = fleet_gate_table(bank, scenario, backend="compiled")
    profile = L.paper_2020()
    cfg = FleetConfig(window_s=0.5)
    a = FleetSimulator(table, scenario.topology, profile, config=cfg).run()
    b = CompiledFleetSimulator(table, scenario.topology, profile,
                               config=cfg, mesh=fleet_mesh()).run()
    assert_per_request_parity(a, b)
    assert_summary_parity(a, b)


def test_compiled_mesh_must_divide_cells(drift_data, scenario):
    from repro.fleet.compiled import CompiledFleetSimulator
    from repro.fleet.simulator import FleetConfig

    class FakeMesh:  # 4 devices over 6 cells: not an even split
        size = 4

    val, test, (uncal, global_plan, bank) = drift_data
    table = fleet_gate_table(bank, scenario, backend="compiled")
    sim = CompiledFleetSimulator(table, scenario.topology, L.paper_2020(),
                                 config=FleetConfig(window_s=0.5),
                                 mesh=FakeMesh())
    with pytest.raises(ValueError, match="shard evenly"):
        sim._resolve_mesh(scenario.topology.n_cells)


@pytest.mark.nightly
def test_compiled_multi_device_shard_map():
    """Real multi-device sharding: 4 forced host devices, cells sharded
    2-per-device through `shard_map`, parity against host numpy. Runs in
    a subprocess because XLA device count is fixed at backend init."""
    code = textwrap.dedent("""
        import numpy as np
        from repro.serving.scenarios import (
            fit_drift_plans, synthetic_distorted_cascade)
        from repro.fleet.scenarios import reference_fleet, run_fleet
        import jax
        assert jax.device_count() == 4, jax.device_count()
        val, test = synthetic_distorted_cascade(
            directions={"gaussian_blur": "under"})
        _, _, bank = fit_drift_plans(val)
        scn = reference_fleet(n_cells=8, requests_per_cell=150, seed=0,
                              val=val, test=test)
        a = run_fleet(bank, scn).fleet_summary()
        b = run_fleet(bank, scn, backend="compiled").fleet_summary()
        for k in a:
            np.testing.assert_allclose(b[k], a[k], rtol=1e-9, atol=1e-12)
        print("MULTI_DEVICE_PARITY_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "MULTI_DEVICE_PARITY_OK" in out.stdout
