"""Hypothesis property tests on the core invariants.

Kept separate from test_core.py so the deterministic suite still collects
when hypothesis is absent (it is a dev-only dependency; see
requirements-dev.txt)."""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import apply_gate, fit_temperature, gate_statistics


@settings(deadline=None, max_examples=50)
@given(
    st.integers(2, 30),  # classes
    st.floats(0.1, 10.0),  # temperature
    st.integers(0, 2**31 - 1),
)
def test_property_temperature_monotone_confidence(c, t, seed):
    """T>1 softens: confidence at T >= 1 is <= confidence at T=1 <= at T<1.
    Also prediction is temperature-invariant."""
    z = jax.random.normal(jax.random.PRNGKey(seed), (8, c)) * 4
    c1, p1, _ = gate_statistics(z, 1.0)
    ct, pt, _ = gate_statistics(z, t)
    np.testing.assert_array_equal(p1, pt)
    if t >= 1.0:
        assert bool(jnp.all(ct <= c1 + 1e-6))
    else:
        assert bool(jnp.all(ct >= c1 - 1e-6))


@settings(deadline=None, max_examples=30)
@given(st.integers(2, 20), st.integers(0, 2**31 - 1), st.floats(0.3, 0.99))
def test_property_gate_mask_iff_confidence(c, seed, p_tar):
    z = jax.random.normal(jax.random.PRNGKey(seed), (32, c)) * 2
    res = apply_gate(z, p_tar)
    np.testing.assert_array_equal(res.exit_mask, res.confidence >= p_tar)


@settings(deadline=None, max_examples=15)
@given(st.floats(1.5, 6.0), st.integers(0, 2**31 - 1))
def test_property_fit_recovers_planted_temperature(t_true, seed):
    """If data is generated from softmax(z/T*), fitting on z recovers ~T*."""
    key = jax.random.PRNGKey(seed)
    n, c = 6000, 8
    z = jax.random.normal(key, (n, c)) * 3
    labels = jax.random.categorical(jax.random.PRNGKey(seed ^ 1), z / t_true)
    T, _ = fit_temperature(z, labels)
    assert abs(float(T) - t_true) / t_true < 0.25
