"""Calibration-health plane tests: reliability sketches, SLOs, reports.

Three stacks maintain the same mergeable windowed reliability sketch --
the event-driven ServingRuntime (per request at gate time), the host
FleetSimulator (columnar per window), and the CompiledFleetSimulator
(bin histograms inside the jitted window program). The anchor tests pin
them together bin-for-bin, pin the sketch's ECE to
`repro.core.metrics.ece`, and drive the calibration SLO end to end:
an under-confident poisoned canary trips the windowed ECE cap BEFORE
any gap-family verdict, rolls back, and the whole chain reconstructs
from the audit log. The satellites ride along: negative tests that
corrupt artifacts in memory and demand the right violation, Prometheus
exposition conformance with a round-trip parser, and the drift report
that diffs deployed ECE against the fit-time promise frozen into the
PlanBank.
"""
import copy
import json

import numpy as np
import pytest

from repro.core.metrics import ece as core_ece
from repro.fleet.scenarios import reference_fleet, run_fleet
from repro.obs import (
    AuditLog,
    MetricsRegistry,
    Observability,
    ReliabilitySketch,
    export_calibration,
    full_observability,
)
from repro.obs.calibration import (
    GLOBAL_CONTEXT,
    bin_edges,
    bin_index,
    block_reliability,
    merge_sketches,
)
from repro.obs.calibration_report import build_report, main as report_main
from repro.obs.check import (
    check_calibration,
    run_checks,
    verify_rollback_chain,
)
from repro.orchestration import ChurnSchedule, Orchestrator
from repro.orchestration.qos import CellSLO
from repro.serving.scenarios import (
    fit_drift_plans,
    run_congested_markov,
    synthetic_cascade_logits,
    synthetic_distorted_cascade,
)


@pytest.fixture(scope="module")
def drift_data():
    val, test = synthetic_distorted_cascade(
        directions={"gaussian_blur": "under"}
    )
    return val, test, fit_drift_plans(val)


def small_fleet(drift_data, seed=0, n_cells=6, requests_per_cell=200):
    val, test, _ = drift_data
    return reference_fleet(
        n_cells=n_cells, requests_per_cell=requests_per_cell, seed=seed,
        val=val, test=test, cloud_servers=2,
    )


def _synthetic_stream(n=4000, seed=0):
    rng = np.random.default_rng(seed)
    conf = rng.uniform(0.05, 1.0, n)
    correct = rng.random(n) < conf ** 1.7  # miscalibrated on purpose
    on = conf >= 0.8
    return conf, correct.astype(bool), on


# ------------------------------------------------------------ sketch unit
def test_bin_edges_and_boundary_assignment():
    edges = bin_edges(15)
    assert len(edges) == 16 and edges[0] == 0.0 and edges[-1] == 1.0
    # searchsorted(side="left"): a confidence exactly ON an edge lands in
    # the bin BELOW it (bins are left-open, right-closed], and conf <= 0
    # goes to the overflow slot -- the same rule the compiled backend
    # applies, so boundary confidences bin identically on both paths
    idx = bin_index(np.array([0.0, 1e-12, edges[1], 0.5, 1.0]))
    assert idx[0] == 15          # overflow: nothing has conf <= 0
    assert idx[1] == 0
    assert idx[2] == 0           # exactly on edge 1 -> bin 0
    assert idx[4] == 14          # conf == 1.0 -> top bin, not overflow


def test_sketch_merge_is_exact_sum():
    conf, correct, on = _synthetic_stream()
    full = ReliabilitySketch()
    full.update(0, "clean", 1, conf, correct, on)
    full.note_ungated(0, 7)
    half_a, half_b = ReliabilitySketch(), ReliabilitySketch()
    half_a.update(0, "clean", 1, conf[:2000], correct[:2000], on[:2000])
    half_b.update(0, "clean", 1, conf[2000:], correct[2000:], on[2000:])
    half_b.note_ungated(0, 7)
    merged = merge_sketches([half_a, half_b])
    assert merged.keys() == full.keys()
    for key in full.keys():
        a, b = full.block(*key), merged.block(*key)
        # integer-valued rows (counts, correct, on, on_correct) are exact;
        # the accumulated float sums differ only by summation order
        np.testing.assert_array_equal(b[[0, 1, 5, 6]], a[[0, 1, 5, 6]])
        np.testing.assert_allclose(b[2:5], a[2:5], rtol=0, atol=1e-9)
    assert merged.ungated_count(0) == 7
    assert merged.total_count() == full.total_count() == 4007
    with pytest.raises(ValueError):
        full.merge(ReliabilitySketch(n_bins=7))


def test_sketch_statistics_match_closed_forms():
    conf, correct, on = _synthetic_stream()
    sk = ReliabilitySketch()
    sk.update(3, GLOBAL_CONTEXT, 2, conf, correct, on)
    assert sk.ece() == pytest.approx(
        float(core_ece(conf, correct)), abs=1e-12)
    assert sk.brier() == pytest.approx(
        float(np.mean((conf - correct) ** 2)), abs=1e-12)
    assert sk.coverage() == pytest.approx(
        float(correct[on].mean()), abs=1e-12)
    bins = sk.reliability()
    assert sum(b["count"] for b in bins) == len(conf)
    for b in bins:
        assert b["residual"] == pytest.approx(
            b["mean_conf"] - b["accuracy"], abs=1e-12)


def test_sketch_json_roundtrip(tmp_path):
    conf, correct, on = _synthetic_stream(n=500)
    sk = ReliabilitySketch()
    sk.update(0, "clean", 1, conf, correct, on)
    sk.update(2, "contrast@4", 2, conf[:100], correct[:100], on[:100])
    sk.note_ungated(2, 13)
    path = str(tmp_path / "sketch.json")
    sk.save(path)
    back = ReliabilitySketch.load(path)
    assert back.n_bins == sk.n_bins and back.keys() == sk.keys()
    for key in sk.keys():
        assert np.array_equal(back.block(*key), sk.block(*key))
    assert back.ungated_count() == 13
    with pytest.raises(ValueError):
        sk.update_binned(0, "clean", 1, np.zeros((7, 3)))


# ----------------------------------------------------------- serving stack
def test_serving_sketch_reproduces_trace_ece():
    """The runtime's sketch must reproduce `core.metrics.ece` from the
    raw unsampled trace: the gate records carry the EDGE prediction's
    correctness captured at gate time, offloaded requests included."""
    exits, final, y = synthetic_cascade_logits(512)
    from repro.core.calibration import TemperatureScaling
    from repro.core.policy import OffloadPlan

    plan = OffloadPlan(
        p_tar=0.8,
        calibrators=[TemperatureScaling.from_temperature(1.0),
                     TemperatureScaling.from_temperature(1.0)],
    )
    obs = full_observability()
    run_congested_markov(plan, exits, final, y, n_requests=400,
                         with_controller=True, obs=obs)
    recs = obs.trace.records
    assert run_checks(recs, obs.metrics, obs.audit.records,
                      calibration=obs.calibration) == []
    gates = [r["gate"] for r in recs if r["gate"] is not None]
    assert gates and all(g["correct"] in (0, 1) for g in gates)
    conf = np.array([g["confidence"] for g in gates])
    cor = np.array([g["correct"] for g in gates], bool)
    assert obs.calibration.ece() == pytest.approx(
        float(core_ece(conf, cor)), abs=1e-9)
    assert obs.calibration.gated_count() == len(gates) == 400
    # the derived gauges landed in the registry under stable names
    assert obs.metrics.gauge_value("calibration_ece") is not None
    assert obs.metrics.gauge_value("calibration_gated_total", cell=0) == 400


# ----------------------------------------------- host <-> compiled parity
def _fleet_sketches(drift_data, orchestrator=None):
    scn = small_fleet(drift_data)
    out = []
    for backend in (None, "compiled"):
        cal = ReliabilitySketch()
        metrics = MetricsRegistry()
        orch = orchestrator() if orchestrator else None
        run_fleet(drift_data[2][2], scn, backend=backend, orchestrator=orch,
                  obs=Observability(metrics=metrics, calibration=cal))
        assert check_calibration(cal, metrics=metrics) == []
        out.append(cal)
    return out


def _assert_sketch_parity(host, compiled):
    """Counts exact, accumulated float sums to round-off, key for key."""
    assert compiled.keys() == host.keys()
    for key in host.keys():
        a, b = host.block(*key), compiled.block(*key)
        np.testing.assert_array_equal(b[0], a[0])  # counts
        np.testing.assert_array_equal(b[1], a[1])  # correct
        np.testing.assert_array_equal(b[5], a[5])  # on-device
        np.testing.assert_array_equal(b[6], a[6])  # on-device correct
        np.testing.assert_allclose(b[2:5], a[2:5], rtol=0, atol=1e-9)
    assert {c: compiled.ungated_count(c) for c in compiled.cells()} == \
        {c: host.ungated_count(c) for c in host.cells()}


def test_compiled_sketch_parity(drift_data):
    """The jitted window program's segment-summed bin histograms must
    agree with the host simulator's columnar accumulation bin-for-bin."""
    host, compiled = _fleet_sketches(drift_data)
    assert host.keys(), "sketch must be populated"
    _assert_sketch_parity(host, compiled)


def test_compiled_sketch_parity_under_churn(drift_data):
    def orch():
        return Orchestrator(churn=ChurnSchedule.outage(
            [0, 2], start_s=2.0, duration_s=4.0))

    host, compiled = _fleet_sketches(drift_data, orchestrator=orch)
    _assert_sketch_parity(host, compiled)


def test_compiled_sketch_parity_backhaul_counts_ungated(drift_data):
    scn = small_fleet(drift_data)

    def orch():
        return Orchestrator(churn=ChurnSchedule.outage(
            list(range(scn.topology.n_cells)), start_s=2.0, duration_s=3.0))

    host, compiled = _fleet_sketches(drift_data, orchestrator=orch)
    _assert_sketch_parity(host, compiled)
    # backhauled windows never ran a gate: they land in the ungated
    # column, and gated + ungated still conserves every request
    assert host.ungated_count() > 0


# ------------------------------------------------- calibration SLO + audit
@pytest.fixture(scope="module")
def calibration_canary(drift_data):
    """A guarded rollout whose SLO watches the windowed calibration
    gauges. The poison is UNDER-confidence (T x20): the canary offloads
    nearly everything, so the gap-family SLOs starve below their
    gate-sample evidence floor and only the calibration stream -- which
    covers offloaded requests too -- can see the failure."""
    from repro.orchestration.scenarios import _rollout_pieces, poisoned_bank

    val, test, (_, _, bank) = drift_data
    scn = small_fleet(drift_data, n_cells=8, requests_per_cell=300)
    cal_slo = CellSLO(reliability_shortfall=0.12, ece_cap=0.30,
                      min_requests=12, min_gate_samples=25)
    orch, monitor, rollout = _rollout_pieces(
        scn, poisoned_bank(bank, temp_scale=20.0), slo=cal_slo)
    audit, metrics = AuditLog(), MetricsRegistry()
    cal = ReliabilitySketch()
    run_fleet(bank, scn, orchestrator=orch,
              obs=Observability(audit=audit, metrics=metrics,
                                calibration=cal))
    return audit, metrics, cal, rollout


def test_calibration_slo_trips_before_gap_and_rolls_back(calibration_canary):
    audit, metrics, cal, rollout = calibration_canary
    assert rollout.state == "rolled_back"
    trips = audit.filter(actor="qos_monitor", action="qos_trip")
    ece_trips = [r for r in trips if r["evidence"]["metric"] == "ece"]
    gap_trips = [r for r in trips if r["evidence"]["metric"]
                 in ("reliability_gap", "reliability_shortfall")]
    assert ece_trips, "the calibration SLO must trip on the canary"
    if gap_trips:  # early warning: calibration sees it first
        assert min(r["t_s"] for r in ece_trips) < min(
            r["t_s"] for r in gap_trips)
    # trip evidence is self-contained: metric/value/cap/op plus the
    # offending reliability bins and the evidence floor that was met
    for r in ece_trips:
        ev = r["evidence"]
        assert ev["value"] > ev["cap"] and ev["op"] == ">"
        assert ev["cal_samples"] >= 25
        assert ev["bins"] and all(
            {"bin", "count", "residual"} <= set(b) for b in ev["bins"])


def test_calibration_rollback_reconstructs_from_audit(calibration_canary):
    audit, metrics, cal, _ = calibration_canary
    chain = verify_rollback_chain(audit.records)
    assert chain["ok"], chain["why"]
    assert all(t["evidence"]["metric"] == "ece" for t in chain["trips"])
    assert check_calibration(cal, metrics=metrics) == []
    # run_checks wires the same chain requirement
    assert run_checks(metrics=metrics, audit_records=audit.records,
                      require_rollback_chain=True, calibration=cal) == []


# ------------------------------------- negative tests: corrupted artifacts
@pytest.fixture(scope="module")
def churn_artifacts(drift_data):
    scn = small_fleet(drift_data)
    churn = ChurnSchedule.outage([0, 2], start_s=2.0, duration_s=4.0)
    obs = full_observability(trace_sample_every=1)
    run_fleet(drift_data[2][2], scn, with_controller=True,
              orchestrator=Orchestrator(churn=churn), obs=obs)
    assert run_checks(obs.trace.records, obs.metrics, obs.audit.records,
                      calibration=obs.calibration) == []
    return obs


def test_check_fails_on_torn_span_timeline(churn_artifacts):
    recs = copy.deepcopy(churn_artifacts.trace.records)
    recs[5]["spans"][-1]["end_s"] += 0.25  # tear the telescoping timeline
    errs = run_checks(recs)
    assert errs and any(
        "gap between" in e or "last span ends" in e for e in errs)
    assert any(f"req {recs[5]['req_id']}" in e for e in errs)


def test_check_fails_on_dropped_churn_request(churn_artifacts):
    """Conservation across churn: silently dropping one completed request
    from the unsampled trace must break the trace-accounting check."""
    recs = [r for r in churn_artifacts.trace.records[1:]]
    errs = run_checks(recs, churn_artifacts.metrics)
    assert errs and any("trace" in e and "records" in e for e in errs)


def test_check_fails_on_truncated_rollback_chain(calibration_canary):
    audit, metrics, cal, _ = calibration_canary
    truncated = [r for r in audit.records if r["action"] != "rollout_rollback"]
    errs = run_checks(audit_records=truncated, require_rollback_chain=True)
    assert errs and "rollout_rollback" in errs[0]
    no_trips = [r for r in audit.records if r["action"] != "qos_trip"]
    errs = run_checks(audit_records=no_trips, require_rollback_chain=True)
    assert errs and "qos_trip" in errs[0]


def test_check_calibration_catches_tampered_sketch(churn_artifacts):
    obs = churn_artifacts
    # inflate one cell's counts: totals no longer match the counters
    forged = copy.deepcopy(obs.calibration)
    key = forged.keys()[0]
    forged.update(key[0], key[1], key[2], [0.9], [True], [True])
    errs = check_calibration(forged, metrics=obs.metrics)
    assert errs and "sketch total" in errs[0]
    # corrupt the accumulated confidence sums: counts still conserve,
    # but the unsampled-trace ECE reproduction must now fail
    warped = copy.deepcopy(obs.calibration)
    warped.block(*warped.keys()[0])[2] *= 1.5
    errs = check_calibration(warped, trace_records=obs.trace.records)
    assert errs and "ECE" in errs[0]


# ------------------------------------ Prometheus exposition conformance
def _parse_prometheus(text):
    """Minimal 0.0.4 parser: families {name: {type, help, samples}} where
    samples is a list of (sample_name, labels_dict, value)."""
    import re

    families, cur = {}, None
    label_re = re.compile(r'(\w+)="((?:[^"\\]|\\.)*)"')
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            name, help_text = line[len("# HELP "):].split(" ", 1)
            cur = families.setdefault(
                name, {"type": None, "help": None, "samples": []})
            cur["help"] = (help_text.replace("\\n", "\n")
                           .replace("\\\\", "\\"))
        elif line.startswith("# TYPE "):
            name, kind = line[len("# TYPE "):].split(" ", 1)
            families.setdefault(
                name, {"type": None, "help": None, "samples": []}
            )["type"] = kind
        else:
            sample_name, rest = re.match(r"([\w:]+)(.*)", line).groups()
            labels = {}
            if rest.startswith("{"):
                body, rest = rest[1:].split("}", 1)
                for k, v in label_re.findall(body):
                    labels[k] = (v.replace("\\n", "\n")
                                 .replace('\\"', '"').replace("\\\\", "\\"))
            value = float(rest.strip())
            fam = sample_name
            for suffix in ("_bucket", "_sum", "_count"):
                if fam.endswith(suffix) and fam[:-len(suffix)] in families:
                    fam = fam[:-len(suffix)]
                    break
            families[fam]["samples"].append((sample_name, labels, value))
    return families


def _assert_conformant(m: MetricsRegistry):
    text = m.to_prometheus()
    families = _parse_prometheus(text)
    for name, fam in families.items():
        assert fam["type"] in ("counter", "gauge", "histogram"), name
        assert fam["help"], f"{name} lacks HELP"
        assert fam["samples"], f"{name} has no samples"
        if fam["type"] != "histogram":
            continue
        # per label-set series: le ascending, +Inf terminal, cumulative
        # counts non-decreasing, _count == the +Inf bucket
        series = {}
        for sname, labels, value in fam["samples"]:
            rest = tuple(sorted((k, v) for k, v in labels.items()
                                if k != "le"))
            series.setdefault(rest, {})[
                (sname, labels.get("le"))] = value
        for rest, samples in series.items():
            les = [(le, v) for (sn, le), v in samples.items()
                   if sn == f"{name}_bucket"]
            assert les, (name, rest)
            finite = [(float(le), v) for le, v in les if le != "+Inf"]
            assert sorted(l for l, _ in finite) == [l for l, _ in finite]
            cum = [v for _, v in sorted(finite)]
            assert cum == sorted(cum), (name, rest)
            inf = [v for le, v in les if le == "+Inf"]
            assert len(inf) == 1, f"{name}: need exactly one +Inf bucket"
            assert not finite or inf[0] >= cum[-1]
            assert samples[(f"{name}_count", None)] == inf[0]
    return families


def test_prometheus_conformance_on_real_artifacts(churn_artifacts):
    """The artifact CI uploads must parse: HELP/TYPE per family, ordered
    cumulative buckets with a terminal +Inf, and the parsed numbers
    round-trip against the registry that wrote them."""
    m = churn_artifacts.metrics
    families = _assert_conformant(m)
    assert "calibration_confidence" in families
    assert families["calibration_confidence"]["type"] == "histogram"
    assert "calibration_ece" in families
    # round-trip: parsed counter samples sum to the registry totals
    parsed_total = sum(
        v for _, _, v in families["fleet_requests_total"]["samples"])
    assert parsed_total == m.counter_total("fleet_requests_total")
    cells = churn_artifacts.calibration.cells()
    gated = {
        labels["cell"]: v
        for _, labels, v in families["calibration_gated_total"]["samples"]}
    assert gated == {
        str(c): churn_artifacts.calibration.gated_count(c) for c in cells}


def test_prometheus_label_escaping_roundtrip():
    m = MetricsRegistry()
    nasty = 'quote " backslash \\ newline \n done'
    m.set_gauge("escape_check", 1.5, ctx=nasty)
    m.describe("escape_check", "help with \\ and\nnewline")
    text = m.to_prometheus()
    assert '\\"' in text and "\\n" in text and "\n done" not in text
    families = _parse_prometheus(text)
    (_, labels, value), = families["escape_check"]["samples"]
    assert labels["ctx"] == nasty and value == 1.5
    assert families["escape_check"]["help"] == "help with \\ and\nnewline"


def test_prometheus_histogram_le_ordering_unit():
    m = MetricsRegistry()
    m.declare_histogram("order_check", (0.5, 1.0, 2.0, 4.0))
    for v in (0.1, 0.7, 1.5, 3.0, 9.0):
        m.observe("order_check", v, cell=0)
    fam = _assert_conformant(m)["order_check"]
    buckets = [(labels["le"], v) for sname, labels, v in fam["samples"]
               if sname == "order_check_bucket"]
    assert [b[0] for b in buckets] == ["0.5", "1", "2", "4", "+Inf"]
    assert [b[1] for b in buckets] == [1, 2, 3, 4, 5]


# ----------------------------------------------------- drift report + CLI
def test_fit_ece_frozen_into_bank_metadata(drift_data):
    from repro.orchestration.scenarios import poisoned_bank

    _, _, (_, _, bank) = drift_data
    fit = bank.metadata.get("fit_ece")
    assert fit and set(fit) == set(bank.contexts)
    for per_branch in fit.values():
        assert per_branch and all(
            0.0 <= v <= 1.0 for v in per_branch.values())
    # the poisoned candidate inherits the HONEST fit-time promise: that
    # is exactly what the drift report diffs against
    assert poisoned_bank(bank).metadata["fit_ece"] == fit


def test_build_report_flags_only_drifted_regimes():
    rng = np.random.default_rng(1)
    sk = ReliabilitySketch()
    conf = rng.uniform(0.3, 1.0, 3000)
    sk.update(0, "clean", 1, conf, rng.random(3000) < conf, conf >= 0.8)
    sk.update(0, "contrast@4", 1, conf, rng.random(3000) < conf - 0.25,
              conf >= 0.8)
    well = sk.ece(context="clean")
    bank_meta = {"fit_ece": {"clean": {"1": well},
                             "contrast@4": {"1": 0.01}},
                 "default_context": "clean"}
    report = build_report(sk, bank_meta=bank_meta, drift_cap=0.05)
    assert report["flagged"]
    assert not report["regimes"]["clean"]["drifted"]
    assert report["regimes"]["contrast@4"]["drifted"]
    assert report["flags"] and "contrast@4" in report["flags"][0]
    # per-regime diagram data is self-consistent with the block view
    bins = report["regimes"]["clean"]["bins"]
    assert bins == block_reliability(sk.merged_block(context="clean"))
    # without a bank there is no promise to diff: nothing can be flagged
    bare = build_report(sk, drift_cap=0.05)
    assert not bare["flagged"]
    assert bare["regimes"]["contrast@4"]["fit_ece"] is None


def test_report_resolves_global_context_to_default():
    """A context-free serving deployment keys its sketch by
    GLOBAL_CONTEXT; the report resolves that against the bank's default
    context so the fit-time promise still applies."""
    rng = np.random.default_rng(2)
    sk = ReliabilitySketch()
    conf = rng.uniform(0.3, 1.0, 2000)
    sk.update(0, GLOBAL_CONTEXT, 1, conf, rng.random(2000) < conf - 0.3,
              conf >= 0.8)
    report = build_report(
        sk, bank_meta={"fit_ece": {"clean": {"1": 0.02}},
                       "default_context": "clean"})
    reg = report["regimes"][GLOBAL_CONTEXT]
    assert reg["fit_ece"] == 0.02 and reg["drifted"]


def test_calibration_report_cli(tmp_path, drift_data):
    """Exit code 1 == drift found (linter convention); multiple sketches
    merge; the JSON artifact carries the flags CI asserts on."""
    _, _, (_, _, bank) = drift_data
    bank_path = str(tmp_path / "bank.json")
    bank.save(bank_path)
    rng = np.random.default_rng(3)
    conf = rng.uniform(0.3, 1.0, 2000)
    a, b = ReliabilitySketch(), ReliabilitySketch()
    ctx = bank.default_context
    a.update(0, ctx, 1, conf[:1000], rng.random(1000) < conf[:1000] - 0.3,
             conf[:1000] >= 0.8)
    b.update(0, ctx, 1, conf[1000:], rng.random(1000) < conf[1000:] - 0.3,
             conf[1000:] >= 0.8)
    pa, pb = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    a.save(pa)
    b.save(pb)
    out = str(tmp_path / "report.json")
    rc = report_main(["--sketch", pa, pb, "--bank", bank_path, "--out", out])
    assert rc == 1
    report = json.loads(open(out).read())
    assert report["flagged"] and report["regimes"][ctx]["drifted"]
    assert report["regimes"][ctx]["count"] == 2000  # both sketches merged
    # a well-calibrated deployment exits 0
    good = ReliabilitySketch()
    good.update(0, ctx, 1, conf, rng.random(2000) < conf, conf >= 0.8)
    pg = str(tmp_path / "good.json")
    good.save(pg)
    fit = bank.metadata["fit_ece"][ctx]["1"]
    cap = abs(good.ece() - fit) + 0.05
    assert report_main(["--sketch", pg, "--bank", bank_path,
                        "--drift-cap", str(cap)]) == 0
