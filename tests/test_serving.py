"""Event-driven serving runtime, telemetry, and online controller tests.

The anchor test pins the runtime to the paper's static numbers: one device,
the fixed 18.8 Mbps link, arrivals slow enough that queues stay empty --
then every per-request latency equals the closed-form edge/comm/cloud sums
to 1e-9 and the offload rate matches the offline batch simulator on the
same logits. The congestion tests then exercise what the static math
cannot express: queueing, microbatching, time-varying links, and the
Edgent-style controller.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibration import TemperatureScaling
from repro.core.policy import OffloadPlan, rescore_plan
from repro.models.convnet import payload_bytes
from repro.offload import latency as L
from repro.offload.simulator import simulate_batches
from repro.serving import (
    ControllerConfig,
    FixedRateNetwork,
    LogitsCore,
    MarkovNetwork,
    OnlineController,
    RuntimeConfig,
    ServingRuntime,
    Telemetry,
    constant_workload,
    poisson_workload,
    trace_workload,
)


def _synthetic_logits(n=512, c=10, seed=0):
    """Branch 1 moderately confident, branch 2 strictly more confident,
    cloud main head always right -- the shared reference cascade."""
    from repro.serving.scenarios import synthetic_cascade_logits

    exits, final, y = synthetic_cascade_logits(n, c, seed)
    return exits[1], exits[2], final, y


@pytest.fixture(scope="module")
def setup():
    z1, z2, final, y = _synthetic_logits()
    plan = OffloadPlan(
        p_tar=0.8,
        calibrators=[
            TemperatureScaling.from_temperature(1.0),
            TemperatureScaling.from_temperature(1.0),
        ],
    )
    profile = L.paper_2020()
    core = LogitsCore({1: z1, 2: z2}, final, plan, labels=y)
    return z1, z2, final, y, plan, profile, core


# --------------------------------------------------- static special case
def test_runtime_reproduces_static_numbers(setup):
    """Empty queues + fixed link => the runtime IS the paper's closed-form
    model, request by request, and agrees with simulate_batches."""
    z1, z2, final, y, plan, profile, core = setup
    n = len(y)
    reqs = constant_workload(10.0, n, n)  # 100 ms spacing >> ~30 ms service
    rt = ServingRuntime(
        core, profile, plan, reqs,
        network=FixedRateNetwork(profile.uplink_bps),
        config=RuntimeConfig(max_batch=1),
    )
    tel = rt.run()
    assert len(tel.records) == n

    t_edge = L.edge_time(profile, 1)
    t_cloud = t_edge + L.comm_time(profile, 1) + L.cloud_time(profile, 1)
    for r in tel.records:
        expected = t_edge if r.on_device else t_cloud
        assert abs(r.latency_s - expected) < 1e-9

    # offload rate and accuracy match the offline simulator on these logits
    outs = simulate_batches(
        [z1], final, y, profile=profile, plan=plan, batch_size=n, branches=(1,)
    )
    assert len(outs) == 1
    assert tel.offload_rate == pytest.approx(1.0 - outs[0].on_device_frac, abs=0)
    assert tel.accuracy == pytest.approx(outs[0].accuracy, abs=0)
    # and per-request mean equals the simulator's mean batch time
    assert tel.latencies().mean() == pytest.approx(outs[0].time_s, rel=1e-9)


def test_runtime_deterministic(setup):
    z1, z2, final, y, plan, profile, core = setup
    def run():
        reqs = poisson_workload(50.0, 300, len(y), seed=4)
        net = MarkovNetwork(seed=3)
        rt = ServingRuntime(core, profile, plan, reqs, network=net,
                            config=RuntimeConfig(max_batch=4, batch_window_s=0.01))
        return rt.run().latencies()
    np.testing.assert_array_equal(run(), run())


# ----------------------------------------------------- queueing dynamics
def test_queueing_inflates_latency(setup):
    """Arrivals near the service rate queue up; the closed-form model
    cannot see this, the event simulator must."""
    z1, z2, final, y, plan, profile, core = setup
    t_edge = L.edge_time(profile, 1)
    slow = constant_workload(0.1 / t_edge, 200, len(y))
    fast = constant_workload(2.0 / t_edge, 200, len(y))  # 2x over capacity
    def p95(reqs):
        rt = ServingRuntime(core, profile, plan, reqs,
                            config=RuntimeConfig(max_batch=1))
        return rt.run().p95_s
    assert p95(fast) > 2 * p95(slow)


def test_multi_device_spreads_load(setup):
    z1, z2, final, y, plan, profile, core = setup
    t_edge = L.edge_time(profile, 1)
    reqs = constant_workload(3.0 / t_edge, 300, len(y), n_devices=4)
    def p95(n_dev):
        rt = ServingRuntime(core, profile, plan, reqs,
                            config=RuntimeConfig(n_devices=n_dev, max_batch=1))
        return rt.run().p95_s
    assert p95(4) < p95(1)


def test_microbatcher_coalesces(setup):
    """max_batch > 1 means fewer uplink transfers than offloaded samples."""
    z1, z2, final, y, plan, profile, core = setup
    reqs = poisson_workload(500.0, 400, len(y), seed=1)
    rt = ServingRuntime(core, profile, plan, reqs,
                        config=RuntimeConfig(max_batch=8, batch_window_s=0.05))
    tel = rt.run()
    offloaded = sum(not r.on_device for r in tel.records)
    assert offloaded > 0
    n_transfers = len(tel.bandwidth_samples)
    assert n_transfers < offloaded  # coalesced
    assert len(tel.records) == 400  # nobody lost in the batcher


def test_batch_window_flushes_partial_batch(setup):
    """A lone refused sample must not wait forever for batch-mates."""
    z1, z2, final, y, plan, profile, core = setup
    reqs = constant_workload(5.0, 40, len(y))
    rt = ServingRuntime(core, profile, plan, reqs,
                        config=RuntimeConfig(max_batch=64, batch_window_s=0.03))
    tel = rt.run()
    assert len(tel.records) == 40
    for r in tel.records:
        if not r.on_device:
            # waited at most the window + transfer + cloud service
            assert r.latency_s < 0.03 + 0.2


# -------------------------------------------------------------- workload
def test_workload_generators():
    reqs = poisson_workload(100.0, 50, 20, n_devices=3, deadline_s=0.1, seed=0)
    assert len(reqs) == 50
    arr = [r.arrival_s for r in reqs]
    assert arr == sorted(arr)
    assert [r.sample for r in reqs[:20]] == list(range(20))  # sequential pass
    assert {r.device for r in reqs} == {0, 1, 2}
    assert all(r.deadline_s == 0.1 for r in reqs)
    # same seed, same arrivals
    again = poisson_workload(100.0, 50, 20, n_devices=3, deadline_s=0.1, seed=0)
    assert [r.arrival_s for r in again] == arr

    tr = trace_workload([0.0, 0.5, 0.5, 1.0], 4)
    assert [r.arrival_s for r in tr] == [0.0, 0.5, 0.5, 1.0]
    with pytest.raises(ValueError):
        trace_workload([1.0, 0.5], 4)

    const = constant_workload(10.0, 5, 100, sample_order="random", seed=3)
    assert all(0 <= r.sample < 100 for r in const)


# ------------------------------------------------------------- telemetry
def test_telemetry_summary_json_safe(setup):
    z1, z2, final, y, plan, profile, core = setup
    reqs = poisson_workload(100.0, 128, len(y), deadline_s=0.05, seed=2)
    rt = ServingRuntime(core, profile, plan, reqs)
    tel = rt.run()
    s = tel.summary()
    json.dumps(s)  # must be serializable
    assert s["requests"] == 128
    assert s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"]
    assert 0.0 <= s["offload_rate"] <= 1.0
    assert 0.0 <= s["deadline_miss_rate"] <= 1.0


def test_telemetry_windowed_estimates():
    tel = Telemetry()
    assert tel.bandwidth_estimate(1.0, now=10.0) is None
    tel.observe_bandwidth(9.5, 4e6)
    tel.observe_bandwidth(5.0, 20e6)  # outside the window
    assert tel.bandwidth_estimate(1.0, now=10.0) == pytest.approx(4e6)
    assert tel.bandwidth_estimate() == pytest.approx(12e6)  # all samples
    # empty window with older observations: most recent stale sample wins
    assert tel.bandwidth_estimate(1.0, now=20.0) == pytest.approx(4e6)
    assert tel.arrival_rate_estimate(1.0, now=10.0) is None
    for t in (9.2, 9.4, 9.6, 9.8, 4.0):
        tel.observe_arrival(t)
    assert tel.arrival_rate_estimate(1.0, now=10.0) == pytest.approx(4.0)


def test_windowed_estimators_future_only_fallback():
    """Regression (ISSUE 7 satellite): the stale-sample fallback must also
    cover observations that all post-date `now` -- a congested cell's
    in-flight transfers are priced at their FUTURE ready times, so a
    controller tick early in the run can find nothing at or before now.
    The documented contract is None only when nothing was ever observed."""
    from repro.core.control import windowed_mean

    # single future record, empty trailing window
    assert windowed_mean([7.0], [3e6], 0.5, now=1.0) == pytest.approx(3e6)
    # all future: the EARLIEST upcoming observation wins (nearest to now)
    assert windowed_mean([5.0, 9.0], [4e6, 2e6], 1.0, now=1.0) == (
        pytest.approx(4e6)
    )
    # mixed: the most recent PAST sample still beats any future one
    assert windowed_mean([0.5, 9.0], [5e6, 2e6], 1.0, now=2.0) == (
        pytest.approx(5e6)
    )
    # nothing ever observed stays None; queue contract keeps strict windows
    assert windowed_mean([], [], 1.0, now=1.0) is None
    assert windowed_mean([7.0], [3e6], 0.5, now=1.0,
                         stale_fallback=False) is None

    # the same guarantees through Telemetry's estimator surface
    tel = Telemetry()
    tel.observe_bandwidth(9.5, 4e6)  # future relative to now=1.0
    assert tel.bandwidth_estimate(1.0, now=1.0) == pytest.approx(4e6)
    # single-record window: that one sample IS the estimate
    assert tel.bandwidth_estimate(1.0, now=9.6) == pytest.approx(4e6)


# ------------------------------------------------------- plan re-scoring
def test_rescore_plan_switches_under_bad_link(setup):
    """Under a starved uplink the small-payload, rarely-offloading deep
    exit must win; under the nominal link the shallow exit is fine."""
    z1, z2, final, y, plan, profile, core = setup
    kw = dict(
        edge_times_s=[L.edge_time(profile, 1), L.edge_time(profile, 2)],
        cloud_times_s=[L.cloud_time(profile, 1), L.cloud_time(profile, 2)],
        payload_bytes=[payload_bytes(1), payload_bytes(2)],
        labels=y,
        final_logits=final,
        min_accuracy=0.9,
    )
    fast, _ = rescore_plan(plan, [z1, z2], uplink_bps=1e9, **kw)
    slow, table = rescore_plan(plan, [z1, z2], uplink_bps=1e5, **kw)
    assert fast.exit_index == 0  # cheap shallow exit when comm is free
    assert slow.exit_index == 1  # small payload when comm dominates
    assert all(
        r["accuracy"] is not None and 0 <= r["accuracy"] <= 1 for r in table
    )
    # calibrators are re-used, never re-fit
    assert slow.calibrators is not plan.calibrators
    assert slow.temperatures == plan.temperatures


def test_rescore_plan_accuracy_floor(setup):
    """Infeasible floor: fall back to the most accurate candidate rather
    than the fastest."""
    z1, z2, final, y, plan, profile, core = setup
    best, _ = rescore_plan(
        plan, [z1, z2],
        edge_times_s=[1e-3, 2e-3],
        cloud_times_s=[5e-3, 4e-3],
        payload_bytes=[payload_bytes(1), payload_bytes(2)],
        uplink_bps=1e9,
        labels=y,
        final_logits=final,
        p_tar_grid=[0.0, 0.8],  # p_tar=0 exits everything on-device (fast)
        min_accuracy=1.1,  # impossible
    )
    # most accurate candidate keeps the strict gate, not the p_tar=0 one
    assert best.p_tar == 0.8


def test_plan_with_p_tar_keeps_calibration(setup):
    z1, z2, final, y, plan, profile, core = setup
    moved = plan.with_p_tar(0.6)
    assert moved.p_tar == 0.6
    assert moved.temperatures == plan.temperatures
    assert moved.exit_index == plan.exit_index
    rt = OffloadPlan.from_json(moved.to_json())
    assert rt.p_tar == 0.6


def test_rescore_plan_argument_validation(setup):
    z1, z2, final, y, plan, profile, core = setup
    kw = dict(
        edge_times_s=[1e-3, 2e-3], cloud_times_s=[5e-3, 4e-3],
        payload_bytes=[payload_bytes(1), payload_bytes(2)], uplink_bps=1e7,
    )
    with pytest.raises(ValueError):  # accuracy floor needs the data to score it
        rescore_plan(plan, [z1, z2], min_accuracy=0.9, **kw)
    entropy_plan = OffloadPlan(
        p_tar=0.8, calibrators=list(plan.calibrators),
        criterion="entropy", entropy_threshold=0.5,
    )
    with pytest.raises(ValueError):  # p_tar re-scoring is confidence-only
        rescore_plan(entropy_plan, [z1, z2], **kw)


def test_rescore_plan_partition_layer_not_stale(setup):
    """Switching exits without exit_layer_indices must clear the recorded
    partition layer rather than keep the old exit's."""
    z1, z2, final, y, plan, profile, core = setup
    src = plan.with_partition(0, 7)
    moved, _ = rescore_plan(
        src, [z1, z2],
        edge_times_s=[L.edge_time(profile, 1), L.edge_time(profile, 2)],
        cloud_times_s=[L.cloud_time(profile, 1), L.cloud_time(profile, 2)],
        payload_bytes=[payload_bytes(1), payload_bytes(2)],
        uplink_bps=1e5,  # starved link: exit 1 wins (smaller payload)
    )
    assert moved.exit_index == 1
    assert moved.partition_layer is None
    kept, _ = rescore_plan(
        src, [z1, z2],
        edge_times_s=[L.edge_time(profile, 1), L.edge_time(profile, 2)],
        cloud_times_s=[L.cloud_time(profile, 1), L.cloud_time(profile, 2)],
        payload_bytes=[payload_bytes(1), payload_bytes(2)],
        uplink_bps=1e5,
        exit_layer_indices=[0, 1],
    )
    assert kept.partition_layer == 1


def test_logits_core_entropy_criterion():
    """LogitsCore honors the plan's entropy criterion (BranchyNet rule)."""
    z1, z2, final, y = _synthetic_logits(n=256)
    plan = OffloadPlan(
        p_tar=0.8,
        calibrators=[TemperatureScaling.from_temperature(1.0)],
        criterion="entropy",
        entropy_threshold=0.5,
    )
    core = LogitsCore({1: z1}, final, plan, labels=y)
    from repro.core.exits import apply_gate

    expected = np.asarray(
        apply_gate(jnp.asarray(z1), 0.8, criterion="entropy",
                   entropy_threshold=0.5).exit_mask
    )
    got = np.array([core.gate(i, 1, 0.8)[0] for i in range(len(y))])
    np.testing.assert_array_equal(got, expected)
    with pytest.raises(ValueError):  # threshold is mandatory for entropy
        LogitsCore({1: z1}, final,
                   OffloadPlan(p_tar=0.8, calibrators=list(plan.calibrators),
                               criterion="entropy"))


def test_runtime_rejects_controller_core_mismatch(setup):
    """A controller that may deploy a branch the core cannot serve must be
    rejected at construction, not silently desynchronize later."""
    z1, z2, final, y, plan, profile, _ = setup
    one_branch_core = LogitsCore({1: z1}, final, plan, labels=y)
    controller = OnlineController(
        plan, profile, {1: z1, 2: z2}, final_logits=final, labels=y,
    )
    reqs = constant_workload(10.0, 10, len(y))
    with pytest.raises(ValueError):
        ServingRuntime(one_branch_core, profile, plan, reqs,
                       controller=controller)


# ---------------------------------------------- controller under congestion
def _congestion_scenario(setup, with_controller):
    """The ISSUE 2 acceptance scenario -- shared verbatim with the
    CI-asserted benchmark via repro.serving.scenarios."""
    from repro.serving.scenarios import run_congested_markov

    z1, z2, final, y, plan, profile, core = setup
    return run_congested_markov(
        plan, {1: z1, 2: z2}, final, y,
        with_controller=with_controller, profile=profile,
    )


def test_controller_beats_static_under_congestion(setup):
    """The acceptance scenario: on a congested Markov link the online
    controller (re-scoring the SAME calibrators) must cut tail latency
    without giving up accuracy."""
    static = _congestion_scenario(setup, with_controller=False)
    ctrl = _congestion_scenario(setup, with_controller=True)
    assert len(ctrl.controller_events) > 0  # it actually acted
    assert ctrl.p99_s < 0.8 * static.p99_s
    assert ctrl.deadline_miss_rate <= static.deadline_miss_rate
    assert ctrl.accuracy >= static.accuracy - 0.01


def test_controller_settles_on_fixed_link(setup):
    """On a constant link the controller must converge: at most one initial
    re-selection, then hysteresis holds the configuration (controller
    events only fire on change, so settling == at most one event)."""
    z1, z2, final, y, plan, profile, core = setup
    reqs = constant_workload(10.0, 200, len(y))
    controller = OnlineController(
        plan, profile, {1: z1, 2: z2}, final_logits=final, labels=y,
        config=ControllerConfig(interval_s=1.0, window_s=2.0, min_accuracy=0.9),
    )
    rt = ServingRuntime(core, profile, plan, reqs,
                        network=FixedRateNetwork(profile.uplink_bps),
                        config=RuntimeConfig(max_batch=1),
                        controller=controller)
    tel = rt.run()
    assert len(tel.controller_events) <= 1


# ------------------------------------------- serve steps consume the plan
def test_serve_steps_accept_plan():
    """launch/serve.py gates with the plan's calibrators; the legacy
    temperatures kwarg remains as a shim and must agree for scalar-T
    plans."""
    from repro.configs import get_smoke
    from repro.launch.serve import make_prefill_step, make_serve_step
    from repro.models import registry

    cfg = get_smoke("qwen3-8b")
    n_exits = len(cfg.exit_layers)
    plan = OffloadPlan(
        p_tar=0.5,
        calibrators=[TemperatureScaling.from_temperature(1.7)] * n_exits,
    )
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}

    out_plan = make_prefill_step(cfg, plan=plan)(params, batch)
    out_temp = make_prefill_step(cfg, temperatures=[1.7] * n_exits)(params, batch)
    np.testing.assert_array_equal(
        np.asarray(out_plan["exit_confidence"]),
        np.asarray(out_temp["exit_confidence"]),
    )
    np.testing.assert_array_equal(
        np.asarray(out_plan["exit_prediction"]),
        np.asarray(out_temp["exit_prediction"]),
    )

    caches = registry.init_cache(cfg, 2, 32)
    step = make_serve_step(cfg, plan=plan)
    tok = jnp.ones((2, 1), jnp.int32)
    out, _ = step(params, tok, caches, jnp.int32(1))
    assert out["exit_confidence"].shape[0] == n_exits

    with pytest.raises(ValueError):
        make_prefill_step(cfg, plan=plan, temperatures=[1.0] * n_exits)
    bad = OffloadPlan(
        p_tar=0.5,
        calibrators=[TemperatureScaling.from_temperature(1.0)] * (n_exits + 1),
    )
    with pytest.raises(ValueError):
        make_serve_step(cfg, plan=bad)


# --------------------------------------------------- engine-backed core
def test_engine_core_matches_logits_core(setup):
    """The runtime driving real jitted partitions (EngineCore) must agree
    with the precomputed-logits core on decisions and predictions."""
    from repro.offload.engine import convnet_engine
    from repro.models import convnet
    from repro.serving.runtime import EngineCore

    n = 32
    rng = np.random.default_rng(0)
    images = rng.normal(size=(n, 32, 32, 3)).astype(np.float32)
    labels = rng.integers(0, 10, n)
    params = convnet.init_params(jax.random.PRNGKey(0))
    plan = OffloadPlan(
        p_tar=0.5, calibrators=[TemperatureScaling.from_temperature(1.0)]
    )
    profile = L.paper_2020()

    hook_calls = []
    engine = convnet_engine(params, plan, branch=1)
    engine.timing_hook = lambda tier, dt, b: hook_calls.append((tier, b))
    ecore = EngineCore({1: engine}, {"images": jnp.asarray(images)}, labels=labels)

    logits, _ = convnet.edge_forward(params, jnp.asarray(images), branch=1)
    final = convnet.forward(params, jnp.asarray(images))["logits"]
    lcore = LogitsCore({1: np.asarray(logits)}, np.asarray(final), plan,
                       labels=labels)

    reqs = constant_workload(10.0, n, n)
    t_e = ServingRuntime(ecore, profile, plan, reqs,
                         config=RuntimeConfig(max_batch=1)).run()
    t_l = ServingRuntime(lcore, profile, plan, reqs,
                         config=RuntimeConfig(max_batch=1)).run()
    by_id = lambda tel: {r.req_id: r for r in tel.records}
    e, l = by_id(t_e), by_id(t_l)
    assert set(e) == set(l)
    for rid in e:
        assert e[rid].on_device == l[rid].on_device
        assert e[rid].correct == l[rid].correct
        assert e[rid].latency_s == pytest.approx(l[rid].latency_s, rel=1e-12)
    # the engine's timing hooks saw every edge call
    assert engine.stats.edge_calls == n
    assert engine.stats.edge_time_s > 0
    assert ("edge", 1) in hook_calls
