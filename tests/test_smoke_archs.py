"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED same-family variant
(<=2 layers, d_model<=512, <=4 experts) and runs one forward/train step on
CPU, asserting output shapes and absence of NaNs; decode-capable archs also
run one serve step.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke, list_archs
from repro.models import registry
from repro.training import optim
from repro.training.loop import make_train_step

ARCHS = [a for a in list_archs() if a != "b_alexnet"]


def _batch(cfg, key, b=2, s=32):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.is_encoder_decoder:
        batch["encoder_frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)
        ).astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_nans(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = registry.init_params(key, cfg)
    b, s = 2, 32
    out = registry.forward_train(params, cfg, _batch(cfg, key, b, s))
    assert out["logits"].shape == (b, s, cfg.vocab_size)
    assert len(out["exit_logits"]) == len(cfg.exit_layers)
    for ex in out["exit_logits"]:
        assert ex.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(out["logits"].astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = registry.init_params(key, cfg)
    opt_cfg = optim.AdamWConfig(lr=1e-3, total_steps=10)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=False))
    state = optim.init(params)
    params2, state2, metrics = step(params, state, _batch(cfg, key))
    assert not bool(jnp.isnan(metrics["loss"])), metrics
    assert int(state2.step) == 1
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: bool(jnp.any(a.astype(jnp.float32) != b.astype(jnp.float32))),
        params,
        params2,
    )
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_decode_step(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(2)
    params = registry.init_params(key, cfg)
    b, L = 2, 64
    caches = registry.init_cache(cfg, b, L)
    if cfg.is_encoder_decoder:
        from repro.models import whisper

        frames = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model)).astype(
            jnp.bfloat16
        )
        caches = {
            "self": caches["self"],
            "cross": whisper.prefill_cross_caches(params, cfg, frames),
        }
    tok = jnp.ones((b, 1), jnp.int32)
    out, caches2 = registry.decode_step(params, cfg, tok, caches, jnp.int32(3))
    assert out["logits"].shape == (b, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(out["logits"].astype(jnp.float32)).any())


def test_b_alexnet_smoke():
    from repro.models import convnet

    params = convnet.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
    out = convnet.forward(params, x)
    assert out["logits"].shape == (4, 10)
    assert len(out["exit_logits"]) == 2
    for e in out["exit_logits"]:
        assert e.shape == (4, 10)
        assert not bool(jnp.isnan(e).any())
