"""Docs-as-tests: every fenced ```python block in README.md and docs/*.md
must execute. Blocks run top-to-bottom per file in one shared namespace
(later snippets may build on earlier ones), inside a temp directory so
snippets that save plan/bank artifacts don't litter the repo.

Keeping the snippets executable is the whole point of the docs tree: a
snippet that stops running is a doc that started lying.
"""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = [
    ROOT / "README.md",
    ROOT / "docs" / "ARCHITECTURE.md",
    ROOT / "docs" / "calibration.md",
    ROOT / "docs" / "fleet.md",
    ROOT / "docs" / "orchestration.md",
    ROOT / "docs" / "observability.md",
]

_FENCE = re.compile(r"```python\n(.*?)```", re.S)


def snippets(path: Path):
    return _FENCE.findall(path.read_text())


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_snippets_run(path, tmp_path, monkeypatch):
    assert path.exists(), f"{path} is missing"
    blocks = snippets(path)
    assert blocks, f"{path.name} has no python snippets to test"
    monkeypatch.chdir(tmp_path)
    ns = {"__name__": f"docs_{path.stem}"}
    for i, code in enumerate(blocks):
        try:
            exec(compile(code, f"{path.name}[snippet {i}]", "exec"), ns)
        except Exception as e:  # pragma: no cover - failure reporting
            pytest.fail(
                f"{path.name} snippet {i} raised {type(e).__name__}: {e}\n"
                f"--- snippet ---\n{code}"
            )
