"""Hypothesis property sweeps of the Pallas kernels against the jnp oracle.

Kept separate from test_kernels.py so the deterministic suite still
collects when hypothesis is absent (dev-only dependency; see
requirements-dev.txt)."""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import exit_gate
from repro.kernels.ref import exit_gate_ref


@settings(deadline=None, max_examples=25)
@given(
    st.integers(1, 12),
    st.integers(2, 900),
    st.floats(0.2, 5.0),
    st.integers(0, 2**31 - 1),
)
def test_property_exit_gate_matches_ref(rows, vocab, temp, seed):
    z = jax.random.normal(jax.random.PRNGKey(seed), (rows, vocab)) * 5
    conf, pred, ent = exit_gate(z, temp)
    rconf, rent, rpred = exit_gate_ref(z, temp)
    np.testing.assert_allclose(conf, rconf, rtol=5e-5, atol=1e-6)
    np.testing.assert_allclose(ent, rent, rtol=5e-5, atol=5e-5)
    np.testing.assert_array_equal(pred, rpred)
    # invariants: conf in (0,1]; entropy in [0, log V]; conf=1 -> ent~0
    assert bool(jnp.all((conf > 0) & (conf <= 1 + 1e-6)))
    assert bool(jnp.all((ent >= -1e-5) & (ent <= np.log(vocab) + 1e-4)))


@settings(deadline=None, max_examples=15)
@given(st.integers(2, 10), st.integers(3, 400), st.floats(0.3, 4.0),
       st.integers(0, 2**31 - 1))
def test_property_nll_matches(rows, vocab, temp, seed):
    from repro.core.calibration import nll as nll_ref
    from repro.kernels.ops import calib_stats

    z = jax.random.normal(jax.random.PRNGKey(seed), (rows, vocab)) * 5
    y = jax.random.randint(jax.random.PRNGKey(seed ^ 3), (rows,), 0, vocab)
    n, _, _ = calib_stats(z, y, temp)
    np.testing.assert_allclose(float(n), float(nll_ref(z, y, temp)), rtol=5e-5)
