"""Latency-profile and network-model tests (offload/latency.py was
previously untested)."""
import numpy as np
import pytest

from repro.models.convnet import payload_bytes
from repro.offload import latency as L
from repro.serving.network import (
    FixedRateNetwork,
    MarkovNetwork,
    TraceNetwork,
    network_for,
)


@pytest.fixture(scope="module")
def paper():
    return L.paper_2020()


@pytest.fixture(scope="module")
def tpu():
    return L.tpu_v5e()


def test_profile_construction(paper, tpu):
    for prof in (paper, tpu):
        assert set(prof.edge_layer_s) == set(prof.cloud_layer_s)
        assert {"branch1", "branch2"} <= set(prof.branch_s)
        assert prof.uplink_bps > 0
        for table in (prof.edge_layer_s, prof.cloud_layer_s, prof.branch_s):
            assert all(v > 0 for v in table.values())
    assert paper.name == "paper_2020" and tpu.name == "tpu_v5e"


def test_path_times_positive(paper, tpu):
    for prof in (paper, tpu):
        for b in (1, 2):
            assert L.edge_time(prof, b) > 0
            assert L.cloud_time(prof, b) > 0
            assert L.comm_time(prof, b) > 0


def test_monotone_in_branch_depth(paper, tpu):
    for prof in (paper, tpu):
        # deeper split: more edge compute, less cloud compute, smaller payload
        assert L.edge_time(prof, 2) > L.edge_time(prof, 1)
        assert L.cloud_time(prof, 2) < L.cloud_time(prof, 1)
        assert L.comm_time(prof, 2) < L.comm_time(prof, 1)
    assert payload_bytes(2) < payload_bytes(1)


def test_tpu_v5e_dominates_paper_hardware(paper, tpu):
    """The pod profile is faster on every leg than the i7/K80/Wi-Fi setup."""
    for b in (1, 2):
        assert L.edge_time(tpu, b) < L.edge_time(paper, b)
        assert L.cloud_time(tpu, b) < L.cloud_time(paper, b)
        assert L.comm_time(tpu, b) < L.comm_time(paper, b)


def test_paper_comm_constant(paper):
    """The paper's number: branch-1 payload at 18.8 Mbps."""
    expected = payload_bytes(1) * 8.0 / 18.8e6
    assert L.comm_time(paper, 1) == pytest.approx(expected, rel=0, abs=0)


# ------------------------------------------------------------ network models
def test_comm_time_network_interface(paper):
    """network=None and an equivalent FixedRateNetwork agree exactly."""
    net = network_for(paper)
    for b in (1, 2):
        assert L.comm_time(paper, b, network=net, t=123.4) == L.comm_time(paper, b)
    slow = FixedRateNetwork(paper.uplink_bps / 4)
    assert L.comm_time(paper, 1, network=slow) == pytest.approx(
        4 * L.comm_time(paper, 1)
    )


def test_fixed_network_rate():
    net = FixedRateNetwork(10e6)
    assert net.rate_bps(0.0) == net.rate_bps(99.0) == 10e6
    assert net.comm_time(1_000_000, 5.0) == pytest.approx(0.8)


def test_markov_network_deterministic_any_query_order():
    kw = dict(good_bps=20e6, bad_bps=2e6, p_good_to_bad=0.3,
              p_bad_to_good=0.3, dwell_s=0.5, seed=7)
    a, b = MarkovNetwork(**kw), MarkovNetwork(**kw)
    ts = [4.9, 0.1, 2.3, 9.7, 1.1, 7.0]
    ra = [a.rate_bps(t) for t in ts]  # out-of-order queries
    rb = [b.rate_bps(t) for t in sorted(ts)]
    rb = [rb[sorted(ts).index(t)] for t in ts]
    assert ra == rb
    assert set(ra) <= {20e6, 2e6}
    # piecewise constant within a dwell slot
    assert a.rate_bps(1.26) == a.rate_bps(1.01)


def test_markov_network_visits_both_states():
    net = MarkovNetwork(p_good_to_bad=0.5, p_bad_to_good=0.5, dwell_s=1.0, seed=0)
    rates = {net.rate_bps(t) for t in range(200)}
    assert rates == {net.good_bps, net.bad_bps}


def test_trace_network_replay_and_period():
    net = TraceNetwork([0.0, 1.0, 3.0], [10e6, 2e6, 8e6], period_s=4.0)
    assert net.rate_bps(0.5) == 10e6
    assert net.rate_bps(1.0) == 2e6
    assert net.rate_bps(2.9) == 2e6
    assert net.rate_bps(3.5) == 8e6
    assert net.rate_bps(4.5) == 10e6  # wrapped
    with pytest.raises(ValueError):
        TraceNetwork([1.0, 2.0], [1e6, 2e6])  # must start at 0
    with pytest.raises(ValueError):
        TraceNetwork([0.0, 1.0], [1e6, 2e6], period_s=0.5)


def test_nonpositive_rate_rejected():
    with pytest.raises(ValueError):
        FixedRateNetwork(0.0).comm_time(100, 0.0)
