"""One benchmark per paper figure (deliverable d).

Each function writes a CSV under experiments/paper/ and returns headline
numbers used by run.py's summary and EXPERIMENTS.md's claim validation.

  fig2  on-device classification probability vs p_tar   (Sec. IV-B)
  fig3a accuracy-vs-confidence reliability curve         (Sec. IV-C)
  fig3b on-device accuracy vs p_tar
  fig3c overall accuracy vs p_tar
  fig4  inference outage probability vs p_tar            (Sec. IV-D)
  fig5  missed-deadline probability vs t_tar             (Sec. IV-E)
  fig6  missed-deadline, two branches                    (Sec. IV-F)
  fig7  outage one- vs two-branch                        (Sec. IV-F)
"""
from __future__ import annotations

import csv
import os

import numpy as np

from benchmarks.paper_common import P_TAR_GRID, temperatures, train_and_collect
from repro.core.calibration import TemperatureScaling
from repro.core.policy import OffloadPlan
from repro.core.metrics import (
    device_statistics,
    inference_outage_probability,
    outage_probability_cascade,
    overall_accuracy,
)
from repro.offload import latency as L
from repro.offload.simulator import missed_deadline_curve, simulate_batches

OUT = os.path.join("experiments", "paper")


def _write(name, header, rows):
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, name), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)


def fig2_offloading_probability(z, temps):
    rows = []
    for p_tar in P_TAR_GRID:
        conv = device_statistics(z["test_b1"], z["test_y"], p_tar, 1.0)
        cal = device_statistics(z["test_b1"], z["test_y"], p_tar, temps[0])
        rows.append(
            [p_tar, float(conv["on_device_prob"]), float(cal["on_device_prob"])]
        )
    _write("fig2_on_device_prob.csv", ["p_tar", "conventional", "calibrated"], rows)
    return rows


def fig3a_reliability(z, temps):
    rows = []
    for p_tar in P_TAR_GRID:
        conv = device_statistics(z["test_b1"], z["test_y"], p_tar, 1.0)
        cal = device_statistics(z["test_b1"], z["test_y"], p_tar, temps[0])
        rows.append(
            [
                p_tar,
                float(conv["mean_confidence"]),
                float(conv["device_accuracy"]),
                float(cal["mean_confidence"]),
                float(cal["device_accuracy"]),
            ]
        )
    _write(
        "fig3a_reliability.csv",
        ["p_tar", "conf_conv", "acc_conv", "conf_cal", "acc_cal"],
        rows,
    )
    return rows


def fig3b_device_accuracy(z, temps):
    rows = []
    for p_tar in P_TAR_GRID:
        conv = device_statistics(z["test_b1"], z["test_y"], p_tar, 1.0)
        cal = device_statistics(z["test_b1"], z["test_y"], p_tar, temps[0])
        rows.append(
            [p_tar, float(conv["device_accuracy"]), float(cal["device_accuracy"])]
        )
    _write("fig3b_device_accuracy.csv", ["p_tar", "conventional", "calibrated"], rows)
    return rows


def fig3c_overall_accuracy(z, temps):
    rows = []
    for p_tar in P_TAR_GRID:
        conv = overall_accuracy([z["test_b1"]], z["test_main"], z["test_y"], p_tar, [1.0])
        cal = overall_accuracy(
            [z["test_b1"]], z["test_main"], z["test_y"], p_tar, [temps[0]]
        )
        rows.append([p_tar, conv, cal])
    _write("fig3c_overall_accuracy.csv", ["p_tar", "conventional", "calibrated"], rows)
    return rows


def fig4_outage(z, temps):
    rows = []
    for p_tar in P_TAR_GRID:
        conv = inference_outage_probability(z["test_b1"], z["test_y"], p_tar, 1.0)
        cal = inference_outage_probability(z["test_b1"], z["test_y"], p_tar, temps[0])
        rows.append([p_tar, conv, cal])
    _write("fig4_outage.csv", ["p_tar", "conventional", "calibrated"], rows)
    return rows


T_TAR_GRID = [0.5e-3, 1e-3, 2e-3, 3e-3, 5e-3, 7.5e-3, 10e-3, 15e-3, 25e-3, 50e-3]


def _missed_deadline(z, temps, p_tar, branches):
    prof = L.paper_2020()
    logits = [z["test_b1"], z["test_b2"]][: len(branches)]
    ts = list(temps)[: len(branches)]
    conv = simulate_batches(
        logits, z["test_main"], z["test_y"], p_tar, [1.0] * len(branches), prof,
        branches=branches,
    )
    cal_plan = OffloadPlan(
        p_tar=p_tar,
        calibrators=[TemperatureScaling.from_temperature(t) for t in ts],
    )
    cal = simulate_batches(
        logits, z["test_main"], z["test_y"], profile=prof, branches=branches,
        plan=cal_plan,
    )
    return (
        missed_deadline_curve(conv, T_TAR_GRID, p_tar),
        missed_deadline_curve(cal, T_TAR_GRID, p_tar),
    )


def fig5_missed_deadline(z, temps):
    all_rows = []
    for p_tar in (0.75, 0.825, 0.85):
        conv, cal = _missed_deadline(z, temps, p_tar, branches=(1,))
        for t, c1, c2 in zip(T_TAR_GRID, conv, cal):
            all_rows.append([p_tar, t, c1, c2])
    _write(
        "fig5_missed_deadline_1branch.csv",
        ["p_tar", "t_tar_s", "conventional", "calibrated"],
        all_rows,
    )
    return all_rows


def fig6_missed_deadline_two_branch(z, temps):
    all_rows = []
    for p_tar in (0.825, 0.85):
        conv, cal = _missed_deadline(z, temps, p_tar, branches=(1, 2))
        for t, c1, c2 in zip(T_TAR_GRID, conv, cal):
            all_rows.append([p_tar, t, c1, c2])
    _write(
        "fig6_missed_deadline_2branch.csv",
        ["p_tar", "t_tar_s", "conventional", "calibrated"],
        all_rows,
    )
    return all_rows


def fig7_outage_two_branch(z, temps):
    rows = []
    for p_tar in P_TAR_GRID:
        c1 = outage_probability_cascade([z["test_b1"]], z["test_y"], p_tar, [1.0])
        c2 = outage_probability_cascade(
            [z["test_b1"], z["test_b2"]], z["test_y"], p_tar, [1.0, 1.0]
        )
        k1 = outage_probability_cascade([z["test_b1"]], z["test_y"], p_tar, [temps[0]])
        k2 = outage_probability_cascade(
            [z["test_b1"], z["test_b2"]], z["test_y"], p_tar, list(temps[:2])
        )
        rows.append([p_tar, c1, c2, k1, k2])
    _write(
        "fig7_outage_branches.csv",
        ["p_tar", "conv_1br", "conv_2br", "cal_1br", "cal_2br"],
        rows,
    )
    return rows


def run_all(epochs: int = 6):
    z = train_and_collect(epochs=epochs)
    temps = temperatures(z)
    print(f"fitted temperatures: branch1={temps[0]:.3f} branch2={temps[1]:.3f} "
          f"main={temps[2]:.3f}")
    results = {
        "temps": temps,
        "fig2": fig2_offloading_probability(z, temps),
        "fig3a": fig3a_reliability(z, temps),
        "fig3b": fig3b_device_accuracy(z, temps),
        "fig3c": fig3c_overall_accuracy(z, temps),
        "fig4": fig4_outage(z, temps),
        "fig5": fig5_missed_deadline(z, temps),
        "fig6": fig6_missed_deadline_two_branch(z, temps),
        "fig7": fig7_outage_two_branch(z, temps),
    }
    return results
