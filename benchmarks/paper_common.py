"""Shared harness for the paper-figure benchmarks.

Trains the B-AlexNet on the synthetic CIFAR-10 stand-in with the
BranchyNet joint loss (exactly once -- results are cached as logits npz so
every figure benchmark reuses the same trained network, as in the paper),
then fits Temperature Scaling on the validation split.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import fit_temperature
from repro.data.synthetic import cifar_like
from repro.models import convnet
from repro.models.convnet import B_ALEXNET
from repro.training import optim
from repro.training.loop import make_train_step

CACHE = os.path.join("experiments", "paper", "b_alexnet_logits.npz")


def train_and_collect(epochs: int = 6, batch: int = 256, seed: int = 0, force=False):
    """Returns dict with val/test logits for both branches + main exit + labels."""
    if os.path.exists(CACHE) and not force:
        z = np.load(CACHE)
        return {k: z[k] for k in z.files}

    data = cifar_like(seed=seed)
    key = jax.random.PRNGKey(seed)
    params = convnet.init_params(key)
    n_steps = epochs * (len(data.train_y) // batch)
    # No weight decay: the conventional-training recipe the paper studies --
    # the network memorizes ambiguous samples and becomes overconfident.
    opt_cfg = optim.AdamWConfig(
        lr=2e-3, weight_decay=0.0, total_steps=n_steps, warmup_steps=200
    )
    step_fn = jax.jit(make_train_step(B_ALEXNET, opt_cfg, remat=False))
    state = optim.init(params)

    rng = np.random.default_rng(seed)
    ntr = len(data.train_y)
    step = 0
    for ep in range(epochs):
        order = rng.permutation(ntr)
        for s in range(0, ntr - batch + 1, batch):
            idx = order[s : s + batch]
            b = {
                "images": jnp.asarray(data.train_x[idx]),
                "labels": jnp.asarray(data.train_y[idx]),
            }
            params, state, metrics = step_fn(params, state, b)
            step += 1
        print(f"epoch {ep}: loss={float(metrics['loss']):.4f}")

    @jax.jit
    def infer(images):
        return convnet.forward(params, images)

    def collect(x):
        outs = {"b1": [], "b2": [], "main": []}
        for s in range(0, len(x), 512):
            o = infer(jnp.asarray(x[s : s + 512]))
            outs["b1"].append(np.asarray(o["exit_logits"][0]))
            outs["b2"].append(np.asarray(o["exit_logits"][1]))
            outs["main"].append(np.asarray(o["logits"]))
        return {k: np.concatenate(v) for k, v in outs.items()}

    val = collect(data.val_x)
    test = collect(data.test_x)
    out = {
        "val_b1": val["b1"],
        "val_b2": val["b2"],
        "val_main": val["main"],
        "val_y": data.val_y,
        "test_b1": test["b1"],
        "test_b2": test["b2"],
        "test_main": test["main"],
        "test_y": data.test_y,
    }
    os.makedirs(os.path.dirname(CACHE), exist_ok=True)
    np.savez(CACHE, **out)
    return out


def temperatures(z):
    """Fit T on validation logits for both branches (and the main exit)."""
    t1, _ = fit_temperature(jnp.asarray(z["val_b1"]), jnp.asarray(z["val_y"]))
    t2, _ = fit_temperature(jnp.asarray(z["val_b2"]), jnp.asarray(z["val_y"]))
    tm, _ = fit_temperature(jnp.asarray(z["val_main"]), jnp.asarray(z["val_y"]))
    return float(t1), float(t2), float(tm)


# The paper sweeps p_tar up to ~0.9 because its CIFAR-10 B-AlexNet branch
# has ~0.7-0.85 selective accuracy. Our synthetic branch is stronger
# (selective accuracy ~0.98 at the top of its confidence range), so the
# outage/missed-deadline knee lives higher; the grid extends to 0.99 to
# cover the same qualitative regimes (comfortably-met .. unreachable).
P_TAR_GRID = [
    0.7, 0.75, 0.775, 0.8, 0.825, 0.85, 0.875, 0.9, 0.925, 0.95,
    0.96, 0.97, 0.975, 0.98, 0.985, 0.99,
]
